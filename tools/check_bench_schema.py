#!/usr/bin/env python3
"""Schema lint for the committed ``BENCH_*.json`` baselines.

Verifies, for every ``BENCH_*.json`` at the repo root, the unified
``BenchReport`` schema (v1) that ``rust/src/bench/report.rs`` defines
and ``bench-compare`` consumes:

* ``schema_version`` is the integer 1;
* ``bench``, ``arch`` and — the provenance field this lint exists to
  enforce — ``source`` are present, non-empty strings;
* ``source_kind`` is ``"native"`` or ``"surrogate"`` and ``smoke`` is
  a boolean (a committed baseline should not be a smoke run, warned
  but not fatal);
* ``backend``, when present, is one of the SIMD backend names
  (``scalar``/``neon``/``sse4.2``/``avx2``) — ``bench-compare``
  refuses to rates-compare across different stamps, and a committed
  baseline without one is warned (pre-backend artifact);
* ``params`` is an object of finite numbers, ``marks`` an object of
  non-empty strings;
* ``metrics`` is a non-empty array of objects with unique non-empty
  ``name``, finite ``value``, string ``unit``, ``better`` in
  ``higher``/``lower``/``info``, and (optional) positive finite
  ``tol``;
* ``notes``, when present, is an array of strings.

This is a structural lint only — value drift is ``bench-compare``'s
job. Exit code 1 with a findings list when anything is malformed; 0
otherwise.

Usage: ``python3 tools/check_bench_schema.py [repo_root]``
"""
import json
import math
import os
import sys

BETTER = {"higher", "lower", "info"}
SOURCE_KINDS = {"native", "surrogate"}
BACKENDS = {"scalar", "neon", "sse4.2", "avx2"}
REQUIRED_STRINGS = ("bench", "arch", "source")
# Baselines CI gates against; must exist at the repo root. Keep in
# sync with committed_baselines_parse_validate_and_round_trip in
# rust/src/bench/report.rs.
REQUIRED_BASELINES = (
    "BENCH_width_sweep.json",
    "BENCH_elem_width.json",
    "BENCH_routing_adaptive.json",
    "BENCH_qos_fairness.json",
    "BENCH_net_soak.json",
)


def is_finite_number(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool) \
        and math.isfinite(v)


def check_report(name, data, findings):
    if not isinstance(data, dict):
        findings.append(f"{name}: root is not a JSON object")
        return
    if data.get("schema_version") != 1:
        findings.append(
            f"{name}: schema_version is {data.get('schema_version')!r}, "
            f"want 1")
    for key in REQUIRED_STRINGS:
        v = data.get(key)
        if not isinstance(v, str) or not v.strip():
            what = "missing" if key not in data else "empty or non-string"
            findings.append(
                f"{name}: {what} \"{key}\" field"
                + (" — every baseline must carry provenance"
                   if key == "source" else ""))
    kind = data.get("source_kind")
    if kind not in SOURCE_KINDS:
        findings.append(
            f"{name}: source_kind is {kind!r}, want one of "
            f"{sorted(SOURCE_KINDS)}")
    backend = data.get("backend")
    if backend is None:
        print(f"  note: {name} carries no \"backend\" stamp — "
              f"bench-compare treats it as unrecorded and will not "
              f"rates-compare it against stamped runs")
    elif backend not in BACKENDS:
        findings.append(
            f"{name}: backend is {backend!r}, want one of "
            f"{sorted(BACKENDS)}")
    if not isinstance(data.get("smoke"), bool):
        findings.append(f"{name}: smoke must be a boolean")
    elif data["smoke"]:
        print(f"  note: {name} is a smoke-mode artifact — committed "
              f"baselines should come from full runs")
    params = data.get("params")
    if not isinstance(params, dict):
        findings.append(f"{name}: params must be an object")
    else:
        for k, v in params.items():
            if not is_finite_number(v):
                findings.append(
                    f"{name}: param \"{k}\" is not a finite number")
    marks = data.get("marks")
    if not isinstance(marks, dict):
        findings.append(f"{name}: marks must be an object")
    else:
        for k, v in marks.items():
            if not isinstance(v, str) or not v:
                findings.append(f"{name}: mark \"{k}\" is not a string")
    metrics = data.get("metrics")
    if not isinstance(metrics, list) or not metrics:
        findings.append(f"{name}: metrics must be a non-empty array")
        metrics = []
    seen = set()
    for i, m in enumerate(metrics):
        where = f"{name}: metrics[{i}]"
        if not isinstance(m, dict):
            findings.append(f"{where}: not an object")
            continue
        metric_name = m.get("name")
        if not isinstance(metric_name, str) or not metric_name:
            findings.append(f"{where}: missing metric name")
        elif metric_name in seen:
            findings.append(f"{where}: duplicate metric \"{metric_name}\"")
        else:
            seen.add(metric_name)
        if not is_finite_number(m.get("value")):
            findings.append(f"{where}: value is not a finite number")
        if not isinstance(m.get("unit"), str):
            findings.append(f"{where}: unit is not a string")
        if m.get("better") not in BETTER:
            findings.append(
                f"{where}: better is {m.get('better')!r}, want one of "
                f"{sorted(BETTER)}")
        if "tol" in m and not (is_finite_number(m["tol"]) and m["tol"] > 0):
            findings.append(f"{where}: tol must be a positive finite number")
    notes = data.get("notes", [])
    if not isinstance(notes, list) or any(
            not isinstance(n, str) for n in notes):
        findings.append(f"{name}: notes must be an array of strings")


def main():
    root = os.path.abspath(sys.argv[1] if len(sys.argv) > 1 else ".")
    names = sorted(
        f for f in os.listdir(root)
        if f.startswith("BENCH_") and f.endswith(".json"))
    findings = []
    for name in names:
        try:
            with open(os.path.join(root, name), encoding="utf-8") as fh:
                data = json.load(fh)
        except (OSError, ValueError) as e:
            findings.append(f"{name}: unreadable or invalid JSON ({e})")
            continue
        check_report(name, data, findings)
    if not names:
        findings.append("no BENCH_*.json baselines found at the repo root")
    for required in REQUIRED_BASELINES:
        if required not in names:
            findings.append(
                f"{required}: required baseline missing from the repo root "
                f"(a CI job gates against it)")
    if findings:
        print(f"bench schema check FAILED: {len(findings)} finding(s) "
              f"across {len(names)} baseline(s)")
        for f in findings:
            print(f"  {f}")
        return 1
    print(f"bench schema check OK: {len(names)} baseline(s) conform to "
          f"BenchReport schema v1 with provenance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
