#!/usr/bin/env python3
"""Offline markdown link check for the repo's doc set.

Verifies, for every tracked ``*.md`` file (repo root + docs/, skipping
build output and vendored trees):

* relative links point at files/directories that exist;
* intra-doc anchors (``#heading`` and ``file.md#heading``) resolve to
  a real heading in the target file, using GitHub's slug rules
  (lowercase, punctuation stripped, spaces to dashes, ``-N`` suffixes
  for duplicates);
* reference-style definitions ``[label]: target`` get the same checks.

External links (http/https/mailto) are deliberately **skipped** — CI
must stay offline-safe and deterministic. Exit code 1 with a findings
list when anything is broken; 0 otherwise.

Usage: ``python3 tools/check_links.py [repo_root]``
"""
import os
import re
import sys

SKIP_DIRS = {".git", "target", "node_modules", "vendor", ".github"}
LINK_RE = re.compile(r"(?<!!)\[(?:[^\]\\]|\\.)*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
IMAGE_RE = re.compile(r"!\[(?:[^\]\\]|\\.)*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
REFDEF_RE = re.compile(r"^\s{0,3}\[([^\]]+)\]:\s+(\S+)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*)$")
EXTERNAL = ("http://", "https://", "mailto:", "ftp:")


def md_files(root):
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        for f in filenames:
            if f.lower().endswith(".md"):
                out.append(os.path.join(dirpath, f))
    return sorted(out)


def strip_code_fences(text):
    """Blank out fenced code blocks and inline code spans so links in
    code samples are not treated as document links."""
    out, in_fence = [], False
    for line in text.splitlines():
        stripped = line.lstrip()
        if stripped.startswith("```") or stripped.startswith("~~~"):
            in_fence = not in_fence
            out.append("")
            continue
        out.append("" if in_fence else re.sub(r"`[^`]*`", "", line))
    return out


def github_slugs(path):
    """The set of anchor slugs a markdown file exposes, GitHub-style."""
    slugs = {}
    try:
        text = open(path, encoding="utf-8").read()
    except OSError:
        return set()
    for line in strip_code_fences(text):
        m = HEADING_RE.match(line)
        if not m:
            continue
        title = re.sub(r"`([^`]*)`", r"\1", m.group(2)).strip()
        # strip markdown emphasis/links from the heading text
        title = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", title)
        title = title.replace("*", "").replace("_", " ")
        slug = re.sub(r"[^\w\- ]", "", title.lower(), flags=re.UNICODE)
        slug = slug.strip().replace(" ", "-")
        n = slugs.get(slug, 0)
        slugs[slug] = n + 1
        if n:
            slugs[f"{slug}-{n}"] = 1
    return set(slugs)


def check_file(path, root, findings):
    text = open(path, encoding="utf-8").read()
    base = os.path.dirname(path)
    for lineno, line in enumerate(strip_code_fences(text), 1):
        targets = LINK_RE.findall(line) + IMAGE_RE.findall(line)
        m = REFDEF_RE.match(line)
        if m and not m.group(1).startswith("^"):
            targets.append(m.group(2))
        for target in targets:
            if target.startswith(EXTERNAL) or target.startswith("<"):
                continue
            dest, _, anchor = target.partition("#")
            dest = dest.strip()
            if dest == "":
                dest_path = path  # same-file anchor
            else:
                dest_path = os.path.normpath(os.path.join(base, dest))
                if not os.path.exists(dest_path):
                    findings.append(
                        f"{os.path.relpath(path, root)}:{lineno}: "
                        f"broken relative link -> {target}")
                    continue
            if anchor and dest_path.lower().endswith(".md"):
                if anchor.lower() not in github_slugs(dest_path):
                    findings.append(
                        f"{os.path.relpath(path, root)}:{lineno}: "
                        f"missing anchor -> {target}")


def main():
    root = os.path.abspath(sys.argv[1] if len(sys.argv) > 1 else ".")
    findings = []
    files = md_files(root)
    for path in files:
        check_file(path, root, findings)
    if findings:
        print(f"link check FAILED: {len(findings)} broken link(s) "
              f"across {len(files)} markdown files")
        for f in findings:
            print(f"  {f}")
        return 1
    print(f"link check OK: {len(files)} markdown files, all relative "
          f"links and anchors resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
