#!/usr/bin/env python3
"""Toolchain-free cross-check of the SIMD backend lowerings.

``rust/src/simd/backend/{neon,x86}.rs`` lower the register-model ops
through real intrinsics; ``scalar.rs`` is the reference model. The
Rust equivalence suite (``backend/tests.rs``) proves scalar == native
*on the machine running the tests* — but only for the backends that
machine can execute. This script closes the gap for the other
architecture: it models each intrinsic's architecturally documented
semantics (from the Intel SDM / Arm ARM pseudocode) in pure Python,
transcribes the exact instruction sequences the Rust backends use,
and property-tests both transcriptions against the scalar formulas.

A mismatch here means the Rust file picked the wrong intrinsic or the
wrong immediate — the kind of bug ``cargo check`` cannot see and only
the missing hardware would catch.

Usage: ``python3 tools/verify_backend_lowering.py`` — exits 0 when
every lowering matches the scalar model, 1 with a findings list.
"""
import itertools
import random
import struct
import sys

MASK32 = 0xFFFFFFFF
MASK64 = 0xFFFFFFFFFFFFFFFF


# ---------------------------------------------------------------------
# Register values: 16-byte little-endian blobs, viewed as lane tuples.
# ---------------------------------------------------------------------

def from_u32(lanes):
    return struct.pack("<4I", *[x & MASK32 for x in lanes])


def to_u32(b):
    return list(struct.unpack("<4I", b))


def from_u64(lanes):
    return struct.pack("<2Q", *[x & MASK64 for x in lanes])


def to_u64(b):
    return list(struct.unpack("<2Q", b))


def to_i32(b):
    return list(struct.unpack("<4i", b))


def to_i64(b):
    return list(struct.unpack("<2q", b))


def to_f32(b):
    return list(struct.unpack("<4f", b))


def from_f32(lanes):
    return struct.pack("<4f", *lanes)


# ---------------------------------------------------------------------
# Scalar reference model — transcribed from backend/scalar.rs.
# ---------------------------------------------------------------------

def s_zip1_32(a, b):
    x, y = to_u32(a), to_u32(b)
    return from_u32([x[0], y[0], x[1], y[1]])


def s_zip2_32(a, b):
    x, y = to_u32(a), to_u32(b)
    return from_u32([x[2], y[2], x[3], y[3]])


def s_uzp1_32(a, b):
    x, y = to_u32(a), to_u32(b)
    return from_u32([x[0], x[2], y[0], y[2]])


def s_uzp2_32(a, b):
    x, y = to_u32(a), to_u32(b)
    return from_u32([x[1], x[3], y[1], y[3]])


def s_trn1_32(a, b):
    x, y = to_u32(a), to_u32(b)
    return from_u32([x[0], y[0], x[2], y[2]])


def s_trn2_32(a, b):
    x, y = to_u32(a), to_u32(b)
    return from_u32([x[1], y[1], x[3], y[3]])


def s_rev64_32(a):
    x = to_u32(a)
    return from_u32([x[1], x[0], x[3], x[2]])


def s_swap64(a):
    x = to_u64(a)
    return from_u64([x[1], x[0]])


def s_rev_32(a):
    x = to_u32(a)
    return from_u32([x[3], x[2], x[1], x[0]])


def s_blend64_lo_hi(lo, hi):
    x, y = to_u64(lo), to_u64(hi)
    return from_u64([x[0], y[1]])


def s_blend_even_odd_32(ev, od):
    x, y = to_u32(ev), to_u32(od)
    return from_u32([x[0], y[1], x[2], y[3]])


def s_blend_outer_32(a, b):
    x, y = to_u32(a), to_u32(b)
    return from_u32([x[0], y[1], y[2], x[3]])


def s_zip1_64(a, b):
    x, y = to_u64(a), to_u64(b)
    return from_u64([x[0], y[0]])


def s_zip2_64(a, b):
    x, y = to_u64(a), to_u64(b)
    return from_u64([x[1], y[1]])


def _lanewise(a, b, to, frm, f):
    return frm([f(x, y) for x, y in zip(to(a), to(b))])


def s_min128_i32(a, b):
    return _lanewise(a, b, to_i32, lambda l: struct.pack("<4i", *l), min)


def s_max128_i32(a, b):
    return _lanewise(a, b, to_i32, lambda l: struct.pack("<4i", *l), max)


def s_min128_u32(a, b):
    return _lanewise(a, b, to_u32, from_u32, min)


def s_max128_u32(a, b):
    return _lanewise(a, b, to_u32, from_u32, max)


def s_min128_u64(a, b):
    return _lanewise(a, b, to_u64, from_u64, min)


def s_max128_u64(a, b):
    return _lanewise(a, b, to_u64, from_u64, max)


def s_min128_f32(a, b):
    # `if a < b { a } else { b }` on the *bit patterns*: ties (incl.
    # -0.0 vs +0.0, which compare equal) take the second operand.
    out = bytearray()
    for i in range(4):
        xa, xb = a[4 * i:4 * i + 4], b[4 * i:4 * i + 4]
        fa, fb = struct.unpack("<f", xa)[0], struct.unpack("<f", xb)[0]
        out += xa if fa < fb else xb
    return bytes(out)


def s_max128_f32(a, b):
    out = bytearray()
    for i in range(4):
        xa, xb = a[4 * i:4 * i + 4], b[4 * i:4 * i + 4]
        fa, fb = struct.unpack("<f", xa)[0], struct.unpack("<f", xb)[0]
        out += xa if fa > fb else xb
    return bytes(out)


# ---------------------------------------------------------------------
# x86 intrinsic semantics (Intel SDM), then the x86.rs transcriptions.
# ---------------------------------------------------------------------

def mm_unpacklo_epi32(a, b):
    x, y = to_u32(a), to_u32(b)
    return from_u32([x[0], y[0], x[1], y[1]])


def mm_unpackhi_epi32(a, b):
    x, y = to_u32(a), to_u32(b)
    return from_u32([x[2], y[2], x[3], y[3]])


def mm_unpacklo_epi64(a, b):
    x, y = to_u64(a), to_u64(b)
    return from_u64([x[0], y[0]])


def mm_unpackhi_epi64(a, b):
    x, y = to_u64(a), to_u64(b)
    return from_u64([x[1], y[1]])


def mm_shuffle_ps(a, b, imm):
    # r0/r1 from a, r2/r3 from b, 2-bit selectors low-to-high.
    x, y = to_u32(a), to_u32(b)
    return from_u32([
        x[imm & 3], x[(imm >> 2) & 3], y[(imm >> 4) & 3], y[(imm >> 6) & 3],
    ])


def mm_shuffle_epi32(a, imm):
    x = to_u32(a)
    return from_u32([x[(imm >> (2 * i)) & 3] for i in range(4)])


def mm_blend_epi16(a, b, mask):
    # Word i (16-bit) from b where mask bit i is set.
    out = bytearray()
    for i in range(8):
        src = b if (mask >> i) & 1 else a
        out += src[2 * i:2 * i + 2]
    return bytes(out)


def mm_slli_epi64(a, n):
    return from_u64([(x << n) & MASK64 for x in to_u64(a)])


def mm_srli_epi64(a, n):
    return from_u64([x >> n for x in to_u64(a)])


def mm_min_epi32(a, b):
    return struct.pack("<4i", *[min(x, y) for x, y in zip(to_i32(a), to_i32(b))])


def mm_max_epi32(a, b):
    return struct.pack("<4i", *[max(x, y) for x, y in zip(to_i32(a), to_i32(b))])


def mm_min_epu32(a, b):
    return from_u32([min(x, y) for x, y in zip(to_u32(a), to_u32(b))])


def mm_max_epu32(a, b):
    return from_u32([max(x, y) for x, y in zip(to_u32(a), to_u32(b))])


def mm_min_ps(a, b):
    # SDM: MIN(SRC1, SRC2) = SRC1 < SRC2 ? SRC1 : SRC2 — ties and
    # zero-sign ties return the second operand.
    out = bytearray()
    for i in range(4):
        xa, xb = a[4 * i:4 * i + 4], b[4 * i:4 * i + 4]
        fa, fb = struct.unpack("<f", xa)[0], struct.unpack("<f", xb)[0]
        out += xa if fa < fb else xb
    return bytes(out)


def mm_max_ps(a, b):
    out = bytearray()
    for i in range(4):
        xa, xb = a[4 * i:4 * i + 4], b[4 * i:4 * i + 4]
        fa, fb = struct.unpack("<f", xa)[0], struct.unpack("<f", xb)[0]
        out += xa if fa > fb else xb
    return bytes(out)


def mm_cmpgt_epi64(a, b):
    return from_u64([
        MASK64 if x > y else 0 for x, y in zip(to_i64(a), to_i64(b))
    ])


def mm_xor(a, b):
    return bytes(x ^ y for x, y in zip(a, b))


def mm_set1_epi64x(v):
    return from_u64([v & MASK64, v & MASK64])


def mm_blendv_epi8(a, b, mask):
    # Byte from b where the mask byte's MSB is set.
    return bytes(
        yb if m & 0x80 else xb for xb, yb, m in zip(a, b, mask)
    )


def x_trn1_32(a, b):
    return mm_blend_epi16(a, mm_slli_epi64(b, 32), 0xCC)


def x_trn2_32(a, b):
    return mm_blend_epi16(mm_srli_epi64(a, 32), b, 0xCC)


def x_min128_u64(a, b):
    flip = mm_set1_epi64x(1 << 63)
    a_gt_b = mm_cmpgt_epi64(mm_xor(a, flip), mm_xor(b, flip))
    return mm_blendv_epi8(a, b, a_gt_b)


def x_max128_u64(a, b):
    flip = mm_set1_epi64x(1 << 63)
    a_gt_b = mm_cmpgt_epi64(mm_xor(a, flip), mm_xor(b, flip))
    return mm_blendv_epi8(b, a, a_gt_b)


X86_OPS2 = {
    "zip1_32": lambda a, b: mm_unpacklo_epi32(a, b),
    "zip2_32": lambda a, b: mm_unpackhi_epi32(a, b),
    "uzp1_32": lambda a, b: mm_shuffle_ps(a, b, 0x88),
    "uzp2_32": lambda a, b: mm_shuffle_ps(a, b, 0xDD),
    "trn1_32": x_trn1_32,
    "trn2_32": x_trn2_32,
    "blend64_lo_hi": lambda a, b: mm_blend_epi16(a, b, 0xF0),
    "blend_even_odd_32": lambda a, b: mm_blend_epi16(a, b, 0xCC),
    "blend_outer_32": lambda a, b: mm_blend_epi16(a, b, 0x3C),
    "zip1_64": mm_unpacklo_epi64,
    "zip2_64": mm_unpackhi_epi64,
    "min128_i32": mm_min_epi32,
    "max128_i32": mm_max_epi32,
    "min128_u32": mm_min_epu32,
    "max128_u32": mm_max_epu32,
    "min128_f32": mm_min_ps,
    "max128_f32": mm_max_ps,
    "min128_u64": x_min128_u64,
    "max128_u64": x_max128_u64,
}

X86_OPS1 = {
    "rev64_32": lambda a: mm_shuffle_epi32(a, 0xB1),
    "swap64": lambda a: mm_shuffle_epi32(a, 0x4E),
    "rev_32": lambda a: mm_shuffle_epi32(a, 0x1B),
}


# ---------------------------------------------------------------------
# NEON intrinsic semantics (Arm ARM), then the neon.rs transcriptions.
# ---------------------------------------------------------------------

def vzip1q_u32(a, b):
    x, y = to_u32(a), to_u32(b)
    return from_u32([x[0], y[0], x[1], y[1]])


def vzip2q_u32(a, b):
    x, y = to_u32(a), to_u32(b)
    return from_u32([x[2], y[2], x[3], y[3]])


def vuzp1q_u32(a, b):
    x, y = to_u32(a), to_u32(b)
    return from_u32([x[0], x[2], y[0], y[2]])


def vuzp2q_u32(a, b):
    x, y = to_u32(a), to_u32(b)
    return from_u32([x[1], x[3], y[1], y[3]])


def vtrn1q_u32(a, b):
    x, y = to_u32(a), to_u32(b)
    return from_u32([x[0], y[0], x[2], y[2]])


def vtrn2q_u32(a, b):
    x, y = to_u32(a), to_u32(b)
    return from_u32([x[1], y[1], x[3], y[3]])


def vrev64q_u32(a):
    x = to_u32(a)
    return from_u32([x[1], x[0], x[3], x[2]])


def vextq_u64_1(a, b):
    # Extract starting at element 1 of the (a, b) concatenation.
    x, y = to_u64(a), to_u64(b)
    return from_u64([x[1], y[0]])


def vcombine_u64(lo, hi):
    return from_u64([lo, hi])


def vbslq(mask, a, b):
    # BSL: bit from a where the mask bit is 1, from b where 0.
    return bytes((m & x) | (~m & y) & 0xFF for m, x, y in zip(mask, a, b))


def vcltq_f32(a, b):
    out = bytearray()
    for fa, fb in zip(to_f32(a), to_f32(b)):
        out += struct.pack("<I", MASK32 if fa < fb else 0)
    return bytes(out)


def vcgtq_f32(a, b):
    out = bytearray()
    for fa, fb in zip(to_f32(a), to_f32(b)):
        out += struct.pack("<I", MASK32 if fa > fb else 0)
    return bytes(out)


def vcgtq_u64(a, b):
    return from_u64([
        MASK64 if x > y else 0 for x, y in zip(to_u64(a), to_u64(b))
    ])


def n_swap64(a):
    return vextq_u64_1(a, a)


def n_rev_32(a):
    r = vrev64q_u32(a)
    return vextq_u64_1(r, r)


def n_blend64_lo_hi(lo, hi):
    return vcombine_u64(to_u64(lo)[0], to_u64(hi)[1])


def n_blend_even_odd_32(ev, od):
    mask = from_u32([MASK32, 0, MASK32, 0])
    return vbslq(mask, ev, od)


def n_blend_outer_32(a, b):
    mask = from_u32([MASK32, 0, 0, MASK32])
    return vbslq(mask, a, b)


def n_min128_f32(a, b):
    return vbslq(vcltq_f32(a, b), a, b)


def n_max128_f32(a, b):
    return vbslq(vcgtq_f32(a, b), a, b)


def n_min128_u64(a, b):
    return vbslq(vcgtq_u64(a, b), b, a)


def n_max128_u64(a, b):
    return vbslq(vcgtq_u64(a, b), a, b)


NEON_OPS2 = {
    "zip1_32": vzip1q_u32,
    "zip2_32": vzip2q_u32,
    "uzp1_32": vuzp1q_u32,
    "uzp2_32": vuzp2q_u32,
    "trn1_32": vtrn1q_u32,
    "trn2_32": vtrn2q_u32,
    "blend64_lo_hi": n_blend64_lo_hi,
    "blend_even_odd_32": n_blend_even_odd_32,
    "blend_outer_32": n_blend_outer_32,
    "zip1_64": lambda a, b: from_u64([to_u64(a)[0], to_u64(b)[0]]),
    "zip2_64": lambda a, b: from_u64([to_u64(a)[1], to_u64(b)[1]]),
    # vminq_s32 / vminq_u32 are exact lane-wise min — model directly.
    "min128_i32": lambda a, b: struct.pack(
        "<4i", *[min(x, y) for x, y in zip(to_i32(a), to_i32(b))]),
    "max128_i32": lambda a, b: struct.pack(
        "<4i", *[max(x, y) for x, y in zip(to_i32(a), to_i32(b))]),
    "min128_u32": lambda a, b: from_u32(
        [min(x, y) for x, y in zip(to_u32(a), to_u32(b))]),
    "max128_u32": lambda a, b: from_u32(
        [max(x, y) for x, y in zip(to_u32(a), to_u32(b))]),
    "min128_f32": n_min128_f32,
    "max128_f32": n_max128_f32,
    "min128_u64": n_min128_u64,
    "max128_u64": n_max128_u64,
}

NEON_OPS1 = {
    "rev64_32": vrev64q_u32,
    "swap64": n_swap64,
    "rev_32": n_rev_32,
}

SCALAR_OPS2 = {
    "zip1_32": s_zip1_32,
    "zip2_32": s_zip2_32,
    "uzp1_32": s_uzp1_32,
    "uzp2_32": s_uzp2_32,
    "trn1_32": s_trn1_32,
    "trn2_32": s_trn2_32,
    "blend64_lo_hi": s_blend64_lo_hi,
    "blend_even_odd_32": s_blend_even_odd_32,
    "blend_outer_32": s_blend_outer_32,
    "zip1_64": s_zip1_64,
    "zip2_64": s_zip2_64,
    "min128_i32": s_min128_i32,
    "max128_i32": s_max128_i32,
    "min128_u32": s_min128_u32,
    "max128_u32": s_max128_u32,
    "min128_f32": s_min128_f32,
    "max128_f32": s_max128_f32,
    "min128_u64": s_min128_u64,
    "max128_u64": s_max128_u64,
}

SCALAR_OPS1 = {
    "rev64_32": s_rev64_32,
    "swap64": s_swap64,
    "rev_32": s_rev_32,
}


# ---------------------------------------------------------------------
# Input pools: random, lane-boundary, and float-tie cases.
# ---------------------------------------------------------------------

def input_pool(rng):
    pool = [rng.randbytes(16) for _ in range(256)]
    # Sign/magnitude boundaries for every lane interpretation.
    for v in (0, 1, 0x7FFFFFFF, 0x80000000, MASK32):
        pool.append(from_u32([v] * 4))
    for v in (0, 1, (1 << 63) - 1, 1 << 63, MASK64):
        pool.append(from_u64([v, MASK64 - v]))
    # f32 ties and signed zeros (bit patterns: +0.0, -0.0, 1.0, -1.0,
    # +inf, -inf) — no NaN: out of the sort contract.
    for f in (0.0, -0.0, 1.0, -1.0, float("inf"), float("-inf")):
        pool.append(from_f32([f, -f if f == f else f, f, f]))
    pool.append(from_f32([0.0, -0.0, -0.0, 0.0]))
    return pool


def main():
    rng = random.Random(0x9E0935)
    pool = input_pool(rng)
    findings = []
    checked = 0

    for backend, ops2, ops1 in (
        ("x86", X86_OPS2, X86_OPS1),
        ("neon", NEON_OPS2, NEON_OPS1),
    ):
        assert set(ops2) == set(SCALAR_OPS2), f"{backend}: binary op set drift"
        assert set(ops1) == set(SCALAR_OPS1), f"{backend}: unary op set drift"
        pairs = list(itertools.islice(
            itertools.product(pool, pool), 0, None, 7))  # ~10k diverse pairs
        for name, f in sorted(ops2.items()):
            ref = SCALAR_OPS2[name]
            for a, b in pairs:
                if "f32" in name:
                    # Skip NaN-holding inputs for float comparators.
                    if any(x != x for x in to_f32(a) + to_f32(b)):
                        continue
                got, want = f(a, b), ref(a, b)
                checked += 1
                if got != want:
                    findings.append(
                        f"{backend}.{name}: a={a.hex()} b={b.hex()} -> "
                        f"{got.hex()}, scalar says {want.hex()}")
                    break
        for name, f in sorted(ops1.items()):
            ref = SCALAR_OPS1[name]
            for a in pool:
                got, want = f(a), ref(a)
                checked += 1
                if got != want:
                    findings.append(
                        f"{backend}.{name}: a={a.hex()} -> {got.hex()}, "
                        f"scalar says {want.hex()}")
                    break

    # The composite 256-bit fallback (non-AVX2 paths): join of two
    # 128-bit halves must equal a 32-byte lane-wise op.
    for name in ("min128_u32", "max128_u32", "min128_u64", "max128_u64",
                 "min128_i32", "max128_i32", "min128_f32", "max128_f32"):
        ref = SCALAR_OPS2[name]
        for _ in range(512):
            a, b = rng.randbytes(32), rng.randbytes(32)
            if "f32" in name and any(
                    x != x for x in to_f32(a[:16]) + to_f32(a[16:])
                    + to_f32(b[:16]) + to_f32(b[16:])):
                continue
            whole = ref(a[:16], b[:16]) + ref(a[16:], b[16:])
            lanes = 8 if "64" not in name else 4
            step = 32 // lanes
            ok = all(
                whole[i * step:(i + 1) * step]
                == ref(
                    a[(i // (16 // step)) * 16:][:16],
                    b[(i // (16 // step)) * 16:][:16],
                )[(i % (16 // step)) * step:][:step]
                for i in range(lanes))
            checked += 1
            if not ok:
                findings.append(f"join128 composition broken for {name}")
                break

    if findings:
        print(f"backend lowering check FAILED: {len(findings)} finding(s)")
        for f in findings:
            print(f"  {f}")
        return 1
    print(f"backend lowering check OK: {checked} op evaluations, "
          f"x86 and neon transcriptions match the scalar model")
    return 0


if __name__ == "__main__":
    sys.exit(main())
