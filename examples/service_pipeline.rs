//! **End-to-end driver** (DESIGN.md §4 E2E): the full three-layer
//! stack serving a realistic request stream.
//!
//! Layers exercised per request routed to XLA:
//!   L3 rust coordinator (queue → router → batcher → worker)
//!   → XLA executor thread (PJRT, AOT artifact from `make artifacts`)
//!   → L2 block-sort graph (= L1 Pallas tile sort + merge passes)
//!   → rust cross-block hybrid merge → response.
//!
//! The workload mimics an analytics frontend: bursts of small sorts
//! (facet counts), a steady stream of medium sorts (result pages) and
//! occasional large jobs (report builds), sizes Zipf-flavored.
//! Reports per-class latency and total throughput; the run is recorded
//! in EXPERIMENTS.md §E2E.

use neonms::coordinator::{CoordinatorConfig, SortService};
use neonms::testutil::Rng;
use std::path::Path;
use std::time::Instant;

fn main() {
    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let have_artifacts = artifacts.join("manifest.json").exists()
        || std::fs::read_dir(&artifacts).map(|mut d| d.next().is_some()).unwrap_or(false);
    if !have_artifacts {
        eprintln!("NOTE: artifacts/ missing — run `make artifacts`; continuing without XLA");
    }

    let cfg = CoordinatorConfig {
        workers: 2,
        shards: 2,
        queue_capacity: 512,
        batch_max: 32,
        fuse_cutoff: 4096,
        tiny_cutoff: 64,
        parallel_cutoff: 1 << 21,
        threads_per_parallel_sort: 4,
        xla_cutoff: Some(4096),
    };
    let svc = SortService::start(cfg, have_artifacts.then_some(artifacts)).expect("start service");
    println!(
        "service up: 2 workers over 2 shards, XLA offload {}",
        if svc.xla_enabled() { "ENABLED (≥4096-element requests)" } else { "disabled" }
    );

    // Zipf-flavored request mix.
    let mut rng = Rng::new(2024);
    let classes: [(&str, usize, usize); 4] = [
        ("facet (tiny)", 16, 600),     // 600 requests of ~16
        ("page (small)", 2_000, 250),  // 250 of ~2K
        ("shard (xla)", 16_384, 120),  // 120 of ~16K → XLA route
        ("report (large)", 3 << 20, 4), // 4 of ~3M → parallel route
    ];

    let t0 = Instant::now();
    let mut pending: Vec<(&str, usize, neonms::coordinator::SortHandle)> = Vec::new();
    let mut shed = 0usize;
    for &(name, base, count) in &classes {
        for _ in 0..count {
            let len = base + rng.below(base / 2 + 1);
            let data = rng.vec_u32(len);
            match svc.try_submit(data) {
                Ok(h) => pending.push((name, len, h)),
                Err(data) => {
                    // Backpressure: block on the slow path instead.
                    shed += 1;
                    pending.push((name, len, svc.submit(data)));
                }
            }
        }
    }
    let mut per_class: std::collections::BTreeMap<&str, (usize, usize)> = Default::default();
    for (name, len, h) in pending {
        let sorted = h.wait().expect("response");
        assert_eq!(sorted.len(), len);
        assert!(sorted.windows(2).all(|w| w[0] <= w[1]), "unsorted response!");
        let e = per_class.entry(name).or_default();
        e.0 += 1;
        e.1 += len;
    }
    let dt = t0.elapsed();

    let m = svc.metrics();
    println!("\n== E2E summary ==");
    for (name, (cnt, elems)) in &per_class {
        println!("  {name:15} {cnt:4} requests, {elems:>9} elements");
    }
    println!(
        "total: {} requests / {} elements in {:.3}s → {:.2} ME/s end-to-end",
        m.completed,
        m.elements,
        dt.as_secs_f64(),
        m.elements as f64 / dt.as_secs_f64() / 1e6
    );
    println!(
        "routes: tiny={} single={} parallel={} xla={} | batches={} occupancy={:.1} \
         steals={} shed-then-blocked={shed}",
        m.route_tiny,
        m.route_single,
        m.route_parallel,
        m.route_xla,
        m.batches,
        m.batch_occupancy,
        m.steals
    );
    println!(
        "latency: mean {:.0}µs, p50 ≤{}µs, p99 ≤{}µs",
        m.mean_latency_us, m.p50_us, m.p99_us
    );
    assert_eq!(m.completed as usize, classes.iter().map(|c| c.2).sum::<usize>());
    if svc.xla_enabled() {
        assert!(m.route_xla > 0, "XLA route must be exercised when enabled");
    }
    svc.shutdown();
    println!("service_pipeline OK");
}
