//! **End-to-end driver** (DESIGN.md §4 E2E): the full three-layer
//! stack serving a realistic **multi-tenant** request stream.
//!
//! Layers exercised per request routed to XLA:
//!   L3 rust coordinator (client → queue → router → batcher → worker)
//!   → XLA executor thread (PJRT, AOT artifact from `make artifacts`)
//!   → L2 block-sort graph (= L1 Pallas tile sort + merge passes)
//!   → rust cross-block hybrid merge → response.
//!
//! The workload mimics an analytics platform with four in-process
//! tenants sharing one service instance, each driving its own class
//! of traffic from its own thread through a cloned [`SortClient`]:
//! bursts of small sorts (facet counts), a steady stream of medium
//! sorts (result pages), XLA-sized shard merges, and occasional large
//! report builds. Every submit is **non-blocking**: `try_submit`
//! either returns a pollable [`SortHandle`] or sheds with `Busy`, in
//! which case the tenant drains whatever handles already resolved,
//! backs off (by the service's hint when the reason is
//! [`BusyReason::OverShare`]) and retries — zero blocking submits
//! anywhere. Per-tenant accepted / shed / completed counts, latency
//! quantiles, and the fair-share gauges come straight from
//! `MetricsSnapshot::tenants`.
//!
//! Each tenant carries a QoS [`ClientConfig`]: report builds get the
//! largest weight *and* a burst allowance sized to their multi-MB
//! requests, so batch traffic is first-class without being able to
//! starve the interactive tenants — under contention the service
//! sheds whichever tenant is furthest over its weighted share, not
//! whoever submitted last.
//!
//! [`SortClient`]: neonms::coordinator::SortClient
//! [`SortHandle`]: neonms::coordinator::SortHandle
//! [`BusyReason::OverShare`]: neonms::coordinator::BusyReason::OverShare
//! [`ClientConfig`]: neonms::coordinator::ClientConfig

use neonms::coordinator::{
    BusyReason, ClientConfig, CoordinatorConfig, SortClient, SortHandle, SortService,
};
use neonms::testutil::Rng;
use std::path::Path;
use std::time::{Duration, Instant};

/// One tenant's traffic class.
struct TenantPlan {
    name: &'static str,
    base: usize,
    count: usize,
    /// Fair-share weight + burst allowance for this traffic class
    /// (bursts sized so each class rides within its allowance: the
    /// demo showcases weighted *service order*, not forced sheds).
    qos: ClientConfig,
}

/// Take every handle that already resolved; verify its response.
fn drain_ready(pending: &mut Vec<SortHandle>) -> usize {
    let mut done = 0;
    pending.retain_mut(|h| match h.try_take() {
        Some(r) => {
            let sorted = r.expect("response");
            assert!(sorted.windows(2).all(|w| w[0] <= w[1]), "unsorted response!");
            done += 1;
            false
        }
        None => true,
    });
    done
}

/// Drive one tenant: submit `plan.count` requests through a *cloned*
/// client with `try_submit` only, polling completed handles while
/// shed. Returns (completed, sheds).
fn run_tenant(client: &SortClient, plan: &TenantPlan, seed: u64) -> (usize, usize) {
    let client = client.clone(); // cheap: two Arc bumps, same tenant
    let mut rng = Rng::new(seed);
    let mut pending: Vec<SortHandle> = Vec::new();
    let mut done = 0usize;
    let mut sheds = 0usize;
    for _ in 0..plan.count {
        let len = plan.base + rng.below(plan.base / 2 + 1);
        let mut data = rng.vec_u32(len);
        loop {
            match client.try_submit(data) {
                Ok(h) => {
                    pending.push(h);
                    break;
                }
                Err(busy) => {
                    // Shed under backpressure: reclaim the input,
                    // drain what's ready, back off, retry — never a
                    // blocking submit. OverShare carries the
                    // service's own back-off hint; a Shutdown reason
                    // would mean retrying can never succeed.
                    let backoff = match busy.reason {
                        BusyReason::QueueFull { retry_after_hint }
                        | BusyReason::OverShare { retry_after_hint } => retry_after_hint,
                        BusyReason::Shutdown => panic!("service shut down mid-run"),
                    };
                    sheds += 1;
                    data = busy.data;
                    done += drain_ready(&mut pending);
                    std::thread::sleep(backoff);
                }
            }
        }
        if pending.len() >= 64 {
            done += drain_ready(&mut pending);
        }
    }
    // Final drain may park — on *completions*, not submits.
    for h in pending {
        let sorted = h.wait().expect("response");
        assert!(sorted.windows(2).all(|w| w[0] <= w[1]), "unsorted response!");
        done += 1;
    }
    (done, sheds)
}

fn main() {
    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let have_artifacts = artifacts.join("manifest.json").exists()
        || std::fs::read_dir(&artifacts).map(|mut d| d.next().is_some()).unwrap_or(false);
    if !have_artifacts {
        eprintln!("NOTE: artifacts/ missing — run `make artifacts`; continuing without XLA");
    }

    let cfg = CoordinatorConfig {
        workers: 2,
        shards: 2,
        queue_capacity: 512,
        batch_max: 32,
        fuse_cutoff: 4096,
        tiny_cutoff: 64,
        parallel_cutoff: 1 << 21,
        threads_per_parallel_sort: 4,
        xla_cutoff: Some(4096),
        // Kernel config, static routing, fair-share QoS — defaults.
        ..Default::default()
    };
    let svc = SortService::start(cfg, have_artifacts.then_some(artifacts)).expect("start service");
    println!(
        "service up: 2 workers over 2 shards, fair-share QoS, SIMD backend {}, XLA offload {}",
        svc.metrics().simd_backend,
        if svc.xla_enabled() { "ENABLED (≥4096-element requests)" } else { "disabled" }
    );

    // Four concurrent tenants, Zipf-flavored class mix. Weights rank
    // the classes; bursts are sized to each class's in-flight ceiling
    // (window × typical request) so none trips over-share shedding.
    let plans: [TenantPlan; 4] = [
        TenantPlan {
            name: "facet-frontend",
            base: 16,
            count: 600,
            qos: ClientConfig { weight: 1, burst: 1 << 16, ..Default::default() },
        },
        TenantPlan {
            name: "page-backend",
            base: 2_000,
            count: 250,
            qos: ClientConfig { weight: 2, burst: 1 << 20, ..Default::default() },
        },
        TenantPlan {
            name: "shard-analytics",
            base: 16_384,
            count: 120,
            qos: ClientConfig { weight: 2, burst: 4 << 20, ..Default::default() },
        },
        TenantPlan {
            name: "report-builder",
            base: 3 << 20,
            count: 4,
            qos: ClientConfig { weight: 4, burst: 32 << 20, ..Default::default() },
        },
    ];
    println!("{} tenants submitting concurrently, zero blocking submits", plans.len());

    let t0 = Instant::now();
    let results: Vec<(usize, usize)> = std::thread::scope(|s| {
        let joins: Vec<_> = plans
            .iter()
            .enumerate()
            .map(|(i, plan)| {
                let client = svc.client_with(plan.name, plan.qos);
                s.spawn(move || run_tenant(&client, plan, 2024 + i as u64))
            })
            .collect();
        joins.into_iter().map(|j| j.join().expect("tenant thread")).collect()
    });
    let dt = t0.elapsed();

    let m = svc.metrics();
    println!("\n== per-tenant ==");
    println!(
        "  {:16} {:>2} {:>5} {:>8} {:>6} {:>9} {:>8} {:>8}",
        "tenant", "w", "share", "accepted", "shed", "completed", "p50(µs)", "p99(µs)"
    );
    for t in &m.tenants {
        println!(
            "  {:16} {:>2} {:>5.2} {:>8} {:>6} {:>9} {:>8} {:>8}",
            t.name, t.weight, t.share, t.accepted, t.shed, t.completed, t.p50_us, t.p99_us
        );
    }
    println!(
        "\ntotal: {} requests / {} elements in {:.3}s → {:.2} ME/s end-to-end",
        m.completed,
        m.elements,
        dt.as_secs_f64(),
        m.elements as f64 / dt.as_secs_f64() / 1e6
    );
    println!(
        "routes: tiny={} single={} parallel={} xla={} | batches={} occupancy={:.1} \
         steals={}",
        m.route_tiny,
        m.route_single,
        m.route_parallel,
        m.route_xla,
        m.batches,
        m.batch_occupancy,
        m.steals
    );
    println!(
        "latency: mean {:.0}µs, p50 ≤{}µs, p99 ≤{}µs",
        m.mean_latency_us, m.p50_us, m.p99_us
    );

    // Acceptance: every tenant's traffic fully served, attribution
    // exact, and the shed counter equals the retries we performed.
    let total: usize = plans.iter().map(|p| p.count).sum();
    assert_eq!(m.completed as usize, total);
    for (plan, (done, sheds)) in plans.iter().zip(&results) {
        let t = m
            .tenants
            .iter()
            .find(|t| t.name == plan.name)
            .expect("tenant reported in MetricsSnapshot");
        assert_eq!(*done, plan.count, "{}: all requests completed", plan.name);
        assert_eq!(t.accepted as usize, plan.count, "{}: accepted count", plan.name);
        assert_eq!(t.completed as usize, plan.count, "{}: completed count", plan.name);
        assert_eq!(t.shed as usize, *sheds, "{}: shed counter matches retries", plan.name);
    }
    if svc.xla_enabled() {
        assert!(m.route_xla > 0, "XLA route must be exercised when enabled");
    }
    svc.shutdown();
    println!("service_pipeline OK");
}
