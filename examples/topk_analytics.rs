//! Visual-computing / analytics scenario (paper intro refs [4], [7]):
//! per-block top-k selection over a stream of frames.
//!
//! Each "frame" is a block of pixel scores; the pipeline keeps the
//! top-k of every frame (e.g. brightest samples for a tone-mapping
//! pass). NEON-MS's in-register sort makes a natural streaming
//! primitive: sort each 64-element tile, keep tile maxima runs, and
//! merge — here we compare full-sort-then-take against a
//! select-via-partial-merge built from the same kernels.

use neonms::bench::Workload;
use neonms::kernels::inregister::InRegisterSorter;
use neonms::kernels::runmerge::RunMerger;
use neonms::sort::NeonMergeSort;
use std::time::Instant;

/// Top-k via full sort (baseline).
fn topk_full_sort(frame: &[u32], k: usize, sorter: &NeonMergeSort) -> Vec<u32> {
    let mut v = frame.to_vec();
    sorter.sort(&mut v);
    v[v.len() - k..].to_vec()
}

/// Top-k via tile sort + tournament of sorted 64-runs: sort tiles
/// in-register, then repeatedly merge the two best runs and truncate
/// to k — O(n) tile pass + O((n/64)·k) merge work.
fn topk_tile_merge(frame: &[u32], k: usize, inreg: &InRegisterSorter, merger: &RunMerger) -> Vec<u32> {
    assert!(k <= 64 && frame.len() % 64 == 0);
    let mut v = frame.to_vec();
    inreg.sort_runs(&mut v);
    // Keep a running top-k (ascending slice of length k).
    let mut best: Vec<u32> = v[..64][64 - k..].to_vec();
    let mut merged = vec![0u32; k + 64];
    for tile in v.chunks_exact(64).skip(1) {
        merger.merge(&best, tile, &mut merged);
        best.copy_from_slice(&merged[64..]);
    }
    best
}

fn main() {
    let frames = 64usize;
    let frame_len = 256 * 1024;
    let k = 32;
    let sorter = NeonMergeSort::paper_default();
    let inreg = InRegisterSorter::paper_default();
    let merger = RunMerger::paper_default();

    let inputs: Vec<Vec<u32>> =
        (0..frames).map(|f| Workload::Clustered.generate(frame_len, f as u64)).collect();

    let t0 = Instant::now();
    let full: Vec<Vec<u32>> = inputs.iter().map(|f| topk_full_sort(f, k, &sorter)).collect();
    let t_full = t0.elapsed();

    let t0 = Instant::now();
    let tiled: Vec<Vec<u32>> =
        inputs.iter().map(|f| topk_tile_merge(f, k, &inreg, &merger)).collect();
    let t_tiled = t0.elapsed();

    assert_eq!(full, tiled, "top-k methods disagree");
    let total = frames * frame_len;
    println!(
        "top-{k} over {frames} frames × {frame_len} samples:\n\
         full sort:          {:.3}s ({:.1} ME/s)\n\
         tile sort + merge:  {:.3}s ({:.1} ME/s, {:.1}× vs full sort)",
        t_full.as_secs_f64(),
        total as f64 / t_full.as_secs_f64() / 1e6,
        t_tiled.as_secs_f64(),
        total as f64 / t_tiled.as_secs_f64() / 1e6,
        t_full.as_secs_f64() / t_tiled.as_secs_f64()
    );
    println!("topk_analytics OK");
}
