//! Visual-computing / analytics scenario (paper intro refs [4], [7]):
//! per-frame top-k selection **with provenance** over a stream of
//! frames.
//!
//! Each "frame" is a block of pixel scores; the pipeline keeps the
//! top-k of every frame (e.g. brightest samples for a tone-mapping
//! pass) *and needs to know which samples won*, not just their
//! values. The element-generic stack makes that one sort: every
//! sample becomes a [`KeyValue`] pair (score in the key half, sample
//! index in the payload half) and the kernels sort the pairs directly
//! on the 8-byte SIMD lanes — provenance rides along for free, and
//! equal scores break ties by index deterministically.
//!
//! The in-register tile length is per element type:
//! [`InRegisterSorter::block_len_for`] gives R×2 = 32 pairs at R = 16
//! on `V128D`'s two 64-bit lanes (half the 64-element `u32` tile).
//! We compare full-sort-then-take against a select-via-partial-merge
//! built from the same kernels at that tile size.

use neonms::bench::Workload;
use neonms::kernels::inregister::InRegisterSorter;
use neonms::kernels::runmerge::RunMerger;
use neonms::simd::KeyValue;
use neonms::sort::NeonMergeSort;
use std::time::Instant;

/// Top-k via full pair sort (baseline).
fn topk_full_sort(frame: &[KeyValue], k: usize, sorter: &NeonMergeSort) -> Vec<KeyValue> {
    let mut v = frame.to_vec();
    sorter.sort(&mut v);
    v[v.len() - k..].to_vec()
}

/// Top-k via tile sort + tournament of sorted tile-runs: sort tiles
/// in-register, then repeatedly merge the running best against the
/// next tile and truncate to k — O(n) tile pass + O((n/tile)·k)
/// merge work, all on the 8-byte vector kernels.
fn topk_tile_merge(
    frame: &[KeyValue],
    k: usize,
    inreg: &InRegisterSorter,
    merger: &RunMerger,
) -> Vec<KeyValue> {
    let tile = inreg.block_len_for::<KeyValue>();
    assert!(k <= tile && frame.len() % tile == 0);
    let mut v = frame.to_vec();
    inreg.sort_runs(&mut v);
    // Keep a running top-k (ascending slice of length k).
    let mut best: Vec<KeyValue> = v[..tile][tile - k..].to_vec();
    let mut merged = vec![KeyValue::new(0, 0); k + tile];
    for t in v.chunks_exact(tile).skip(1) {
        merger.merge(&best, t, &mut merged);
        best.copy_from_slice(&merged[tile..]);
    }
    best
}

fn main() {
    let frames = 64usize;
    let frame_len = 256 * 1024;
    let k = 32;
    let sorter = NeonMergeSort::paper_default();
    let inreg = InRegisterSorter::paper_default();
    let merger = RunMerger::paper_default();

    // Score + sample-index pairs: the index payload is the
    // provenance the tone-mapping pass actually consumes.
    let inputs: Vec<Vec<KeyValue>> = (0..frames)
        .map(|f| {
            Workload::Clustered
                .generate(frame_len, f as u64)
                .into_iter()
                .enumerate()
                .map(|(i, score)| KeyValue::new(score, i as u32))
                .collect()
        })
        .collect();

    let t0 = Instant::now();
    let full: Vec<Vec<KeyValue>> =
        inputs.iter().map(|f| topk_full_sort(f, k, &sorter)).collect();
    let t_full = t0.elapsed();

    let t0 = Instant::now();
    let tiled: Vec<Vec<KeyValue>> =
        inputs.iter().map(|f| topk_tile_merge(f, k, &inreg, &merger)).collect();
    let t_tiled = t0.elapsed();

    // Pair order is strict (score, then index), so the two methods
    // must agree *exactly* — including which of several equal-score
    // samples made the cut.
    assert_eq!(full, tiled, "top-k methods disagree");
    // Provenance check: every winner's payload indexes a sample in
    // its frame that really has that score.
    for (frame, top) in inputs.iter().zip(&tiled) {
        for kv in top {
            assert_eq!(
                kv.key(),
                frame[kv.payload() as usize].key(),
                "payload index does not point at the winning sample"
            );
        }
    }

    let total = frames * frame_len;
    println!(
        "top-{k} (score, index) over {frames} frames × {frame_len} samples:\n\
         full pair sort:          {:.3}s ({:.1} ME/s)\n\
         tile sort + merge:       {:.3}s ({:.1} ME/s, {:.1}× vs full sort)",
        t_full.as_secs_f64(),
        total as f64 / t_full.as_secs_f64() / 1e6,
        t_tiled.as_secs_f64(),
        total as f64 / t_tiled.as_secs_f64() / 1e6,
        t_full.as_secs_f64() / t_tiled.as_secs_f64()
    );
    println!("topk_analytics OK");
}
