//! Database-retrieval scenario (the paper's intro motivation [11]):
//! build a sorted index over 4M `(key, rowid)` pairs.
//!
//! The element-generic stack sorts the pairs **directly on the 8-byte
//! SIMD lanes**: each pair packs into a [`KeyValue`] (key in the high
//! half, rowid in the low), so key-major order with rowid tie-break
//! *is* the packed integer order, and NEON-MS sorts the pairs on the
//! `V128D`/`V256D` register types — no scalar gather pass, no
//! second-stage permutation.
//!
//! Three builds of the same index:
//!
//! 1. **pack-and-sort (scalar baseline)** — pack key+rowid into
//!    `u64`, `sort_unstable` (the conventional approach);
//! 2. **vectorized pair sort** — [`NeonMergeSort::sort`] over
//!    `Vec<KeyValue>`: the same O(n log n) hot loop the paper
//!    vectorizes, running on the 2-lane 64-bit registers;
//! 3. **service round-trip** — the same pairs through a live
//!    [`SortService`] via [`SortClient::submit_pairs`], exercising
//!    the typed submission path end to end.
//!
//! Verifies all three produce the identical stable index order.
//!
//! [`SortClient::submit_pairs`]: neonms::coordinator::SortClient::submit_pairs

use neonms::bench::Workload;
use neonms::coordinator::SortService;
use neonms::simd::{pack_key_rowid, KeyValue};
use neonms::sort::NeonMergeSort;
use std::time::Instant;

fn main() {
    let n: usize = 4 << 20;
    let keys = Workload::FewDups.generate(n, 11); // realistic dup-heavy keys
    let rowids: Vec<u32> = (0..n as u32).collect();

    // --- 1. conventional scalar baseline: pack into u64, scalar sort ---
    let t0 = Instant::now();
    let mut packed: Vec<u64> =
        keys.iter().zip(&rowids).map(|(&k, &r)| pack_key_rowid(k, r)).collect();
    packed.sort_unstable(); // rowid ascending within key == stable by key
    let t_scalar = t0.elapsed();

    // --- 2. vectorized pair sort on the 8-byte lanes ---
    let sorter = NeonMergeSort::paper_default();
    let mut pairs: Vec<KeyValue> =
        keys.iter().zip(&rowids).map(|(&k, &r)| KeyValue::new(k, r)).collect();
    let t0 = Instant::now();
    sorter.sort(&mut pairs); // the SIMD hot loop, V128D registers
    let t_simd = t0.elapsed();

    // --- verify: pair order == packed baseline order exactly ---
    assert_eq!(pairs.len(), packed.len());
    for (p, &q) in pairs.iter().zip(&packed) {
        assert_eq!(p.packed(), q, "pair sort diverged from the scalar baseline");
    }

    // --- 3. the same pairs through a live sort service ---
    let svc = SortService::start_default().expect("service start");
    let client = svc.client("index-builder");
    let resubmit: Vec<KeyValue> =
        keys.iter().zip(&rowids).map(|(&k, &r)| KeyValue::new(k, r)).collect();
    let t0 = Instant::now();
    let served = client.submit_pairs(resubmit).wait().expect("service sort");
    let t_svc = t0.elapsed();
    assert_eq!(served, pairs, "service round-trip diverged");
    svc.shutdown();

    println!(
        "index build over {n} (key,rowid) pairs (SIMD backend {}):\n\
         pack-and-sort (u64 scalar baseline):   {:.3}s ({:.1} ME/s)\n\
         NEON-MS pair sort (8-byte lanes):      {:.3}s ({:.1} ME/s)\n\
         service submit_pairs round-trip:       {:.3}s ({:.1} ME/s)",
        neonms::simd::backend::active().name(),
        t_scalar.as_secs_f64(),
        n as f64 / t_scalar.as_secs_f64() / 1e6,
        t_simd.as_secs_f64(),
        n as f64 / t_simd.as_secs_f64() / 1e6,
        t_svc.as_secs_f64(),
        n as f64 / t_svc.as_secs_f64() / 1e6,
    );
    println!("database_keys OK");
}
