//! Database-retrieval scenario (the paper's intro motivation [11]):
//! build a sorted index over 4M `(key, rowid)` pairs, two ways:
//!
//! 1. **pack-and-sort** — pack key+rowid into `u64`, scalar sort
//!    (the conventional approach);
//! 2. **NEON-MS key column + stable gather** — SIMD-sort the 32-bit
//!    key column with NEON-MS, then place each original pair at the
//!    next free slot of its key's run (a stable counting gather).
//!    This keeps the hot O(n log n) work on the vectorized sorter and
//!    leaves only O(n) scalar placement.
//!
//! Verifies both produce the same stable index order, reports rates.

use neonms::bench::Workload;
use neonms::simd::{pack_key_rowid, unpack_key_rowid};
use neonms::sort::NeonMergeSort;
use std::collections::HashMap;
use std::time::Instant;

fn main() {
    let n: usize = 4 << 20;
    let keys = Workload::FewDups.generate(n, 11); // realistic dup-heavy keys
    let rowids: Vec<u32> = (0..n as u32).collect();

    // --- 1. conventional: pack into u64, scalar sort ---
    let t0 = Instant::now();
    let mut packed: Vec<u64> =
        keys.iter().zip(&rowids).map(|(&k, &r)| pack_key_rowid(k, r)).collect();
    packed.sort_unstable(); // rowid ascending within key == stable by key
    let t_pack = t0.elapsed();
    let conventional: Vec<(u32, u32)> =
        packed.iter().map(|&p| unpack_key_rowid(p)).collect();

    // --- 2. NEON-MS key column + stable counting gather ---
    let t0 = Instant::now();
    let sorter = NeonMergeSort::paper_default();
    let mut sorted_keys = keys.clone();
    sorter.sort(&mut sorted_keys); // the SIMD hot loop
    // Next-free-slot cursor per distinct key (first slot found by
    // binary search on the sorted column).
    let mut cursor: HashMap<u32, usize> = HashMap::new();
    let mut index: Vec<(u32, u32)> = vec![(0, 0); n];
    for (&k, &r) in keys.iter().zip(&rowids) {
        let slot = cursor
            .entry(k)
            .or_insert_with(|| sorted_keys.partition_point(|&x| x < k));
        index[*slot] = (k, r);
        *slot += 1;
    }
    let t_simd = t0.elapsed();

    // --- verify agreement (stable order ⇒ exact match) ---
    assert_eq!(index, conventional, "index orders diverged");
    for (ks, &(kp, _)) in sorted_keys.iter().zip(&index) {
        assert_eq!(*ks, kp);
    }

    println!(
        "index build over {n} (key,rowid) pairs:\n\
         pack-and-sort (u64 scalar):          {:.3}s ({:.1} ME/s)\n\
         NEON-MS key sort + stable gather:    {:.3}s ({:.1} ME/s)",
        t_pack.as_secs_f64(),
        n as f64 / t_pack.as_secs_f64() / 1e6,
        t_simd.as_secs_f64(),
        n as f64 / t_simd.as_secs_f64() / 1e6,
    );
    println!("database_keys OK");
}
