//! Quickstart: sort a vector with the public API, verify, report rate.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use neonms::bench::Workload;
use neonms::sort::{NeonMergeSort, ParallelNeonMergeSort};
use std::time::Instant;

fn main() {
    // 4M uniform random u32 — the paper's §3 workload at a midsize point.
    let n = 4 << 20;
    let data = Workload::Uniform.generate(n, 1);

    // Single-thread NEON-MS with the paper's configuration:
    // R = 16 registers, best-16 column network, hybrid 2×16 merges.
    let sorter = NeonMergeSort::paper_default();
    let mut v = data.clone();
    let t0 = Instant::now();
    sorter.sort(&mut v);
    let dt = t0.elapsed();
    assert!(v.windows(2).all(|w| w[0] <= w[1]));
    println!(
        "single-thread: {n} u32 in {:.3}s → {:.1} ME/s",
        dt.as_secs_f64(),
        n as f64 / dt.as_secs_f64() / 1e6
    );

    // Multi-thread (merge-path cooperative merge).
    let mut v = data.clone();
    let par = ParallelNeonMergeSort::with_threads(4);
    let t0 = Instant::now();
    par.sort(&mut v);
    let dt = t0.elapsed();
    assert!(v.windows(2).all(|w| w[0] <= w[1]));
    println!(
        "T=4 parallel:  {n} u32 in {:.3}s → {:.1} ME/s",
        dt.as_secs_f64(),
        n as f64 / dt.as_secs_f64() / 1e6
    );

    // Comparison against the paper's single-thread baseline.
    let mut v = data.clone();
    let t0 = Instant::now();
    neonms::baselines::introsort::sort(&mut v);
    println!(
        "std::sort (introsort) reference: {:.3}s",
        t0.elapsed().as_secs_f64()
    );
    println!("quickstart OK");
}
