//! Bench: adaptive-routing convergence on skewed workloads, versus
//! the same service with static default cutoffs.
//!
//! Three deliberately skewed scenarios stress one learned parameter
//! each:
//!
//! * `burst_tiny` — every request lands within an octave of the tiny
//!   cutoff, so the insertion-vs-vector boundary is the whole game.
//! * `heavy_tail` — mostly small requests plus a heavy tail straddling
//!   the parallel cutoff, exercising the single-vs-parallel boundary.
//! * `fuse_burst` — a one-worker queue pileup of small requests, where
//!   fused batching either pays or doesn't; the tuner sizes
//!   `batch_max`/`fuse_cutoff` from the fused-vs-solo comparison.
//!
//! Each scenario runs twice — [`AdaptivePolicy::Off`] then
//! [`AdaptivePolicy::Adaptive`] — and the run records throughput, the
//! initial/final cutoffs, and the tuner's decision trace (each entry
//! carries the per-tier elements/µs that drove it, so "moved toward
//! the measured-better tier" is checkable from the artifact alone) to
//! a JSON artifact like the width sweep's.
//!
//! Env knobs:
//! * `NEONMS_BENCH_SMOKE=1` — CI smoke mode (fewer, smaller jobs).
//! * `NEONMS_BENCH_JOBS` — override jobs per scenario run.
//! * `NEONMS_BENCH_OUT` — artifact path (default
//!   `../BENCH_routing_adaptive.json`, the repo root when run via
//!   `cargo bench` from `rust/`).

use neonms::bench::report::{self, BenchReport, Better, SourceKind};
use neonms::coordinator::{
    AdaptivePolicy, CoordinatorConfig, Decision, RoutingBounds, RoutingSnapshot, SortService,
};
use neonms::testutil::Rng;
use std::time::Instant;

/// One skewed workload: a config plus a request-length generator.
struct Scenario {
    name: &'static str,
    cfg: CoordinatorConfig,
    epoch_jobs: u64,
    bounds: RoutingBounds,
    jobs: usize,
    /// Submits outstanding at once (bounds memory; creates the queue
    /// depth dynamic batching needs).
    wave: usize,
    len: fn(&mut Rng) -> usize,
}

fn scenarios(smoke: bool, jobs_override: Option<usize>) -> Vec<Scenario> {
    let scale = |full: usize, smoke_n: usize| {
        jobs_override.unwrap_or(if smoke { smoke_n } else { full })
    };
    vec![
        Scenario {
            name: "burst_tiny",
            cfg: CoordinatorConfig {
                workers: 2,
                shards: 2,
                batch_max: 1, // isolate the solo tiny/single boundary
                ..Default::default()
            },
            epoch_jobs: 64,
            bounds: RoutingBounds::default(),
            jobs: scale(8000, 1600),
            wave: 64,
            len: |rng| 16 + rng.below(176), // within an octave of 64
        },
        Scenario {
            name: "heavy_tail",
            cfg: CoordinatorConfig {
                workers: 2,
                shards: 2,
                batch_max: 1,
                parallel_cutoff: 1 << 15,
                threads_per_parallel_sort: 4,
                ..Default::default()
            },
            epoch_jobs: 48,
            bounds: RoutingBounds {
                parallel: (1 << 13, 1 << 18),
                ..Default::default()
            },
            jobs: scale(1200, 300),
            wave: 32,
            // 85% small, 15% heavy tail straddling the 32K cutoff.
            len: |rng| {
                if rng.below(100) < 85 {
                    256 + rng.below(1792)
                } else {
                    (1 << 13) + rng.below((1 << 17) - (1 << 13))
                }
            },
        },
        Scenario {
            name: "fuse_burst",
            cfg: CoordinatorConfig {
                workers: 1,
                shards: 1,
                batch_max: 4,
                ..Default::default()
            },
            epoch_jobs: 64,
            bounds: RoutingBounds::default(),
            jobs: scale(8000, 1600),
            wave: 128, // deep waves → the queue actually piles up
            len: |rng| 32 + rng.below(480),
        },
    ]
}

/// Drive one service through the scenario's request stream in waves,
/// returning jobs/second of wall time.
fn drive(svc: &SortService, sc: &Scenario, seed: u64) -> f64 {
    let client = svc.client("bench");
    let mut rng = Rng::new(seed);
    let t0 = Instant::now();
    let mut submitted = 0;
    while submitted < sc.jobs {
        let wave = sc.wave.min(sc.jobs - submitted);
        let handles: Vec<_> =
            (0..wave).map(|_| client.submit(rng.vec_u32((sc.len)(&mut rng)))).collect();
        for h in handles {
            h.wait().expect("reply");
        }
        submitted += wave;
    }
    sc.jobs as f64 / t0.elapsed().as_secs_f64()
}

struct ScenarioReport {
    name: &'static str,
    jobs: usize,
    static_jobs_per_s: f64,
    adaptive_jobs_per_s: f64,
    initial: RoutingSnapshot,
    fin: RoutingSnapshot,
    decisions: Vec<Decision>,
    routes: Vec<(String, u64, f64)>, // (tier, jobs, elems/µs)
}

fn run_scenario(sc: &Scenario) -> ScenarioReport {
    // Static pass: the scenario config as-is, policy off.
    let svc = SortService::start(sc.cfg.clone(), None).expect("static service");
    let static_rate = drive(&svc, sc, 42);
    svc.shutdown();

    // Adaptive pass: same config, learning on.
    let cfg = CoordinatorConfig {
        adaptive: AdaptivePolicy::Adaptive {
            epoch_jobs: sc.epoch_jobs,
            bounds: sc.bounds.clone(),
        },
        ..sc.cfg.clone()
    };
    let svc = SortService::start(cfg, None).expect("adaptive service");
    let initial = svc.routing();
    let adaptive_rate = drive(&svc, sc, 42);
    let fin = svc.routing();
    let decisions = svc.decisions();
    let routes = svc
        .metrics()
        .routes
        .iter()
        .map(|r| (r.tier.to_string(), r.jobs, r.elems_per_us))
        .collect();
    svc.shutdown();

    ScenarioReport {
        name: sc.name,
        jobs: sc.jobs,
        static_jobs_per_s: static_rate,
        adaptive_jobs_per_s: adaptive_rate,
        initial,
        fin,
        decisions,
        routes,
    }
}

/// Direction of a cutoff between two snapshots ("up"/"down"/"hold").
fn direction(from: usize, to: usize) -> &'static str {
    match to.cmp(&from) {
        std::cmp::Ordering::Greater => "up",
        std::cmp::Ordering::Less => "down",
        std::cmp::Ordering::Equal => "hold",
    }
}

/// Build the unified `BenchReport`: per scenario, throughput metrics
/// (gated on native baselines), the final cutoffs and decision count
/// as info, the learned *directions* as structural marks (the
/// surrogate baseline pins those — e.g. `burst_tiny` must move or
/// hold its tiny cutoff upward, never down), and the full decision
/// trace + route tallies as notes.
fn build_report(reports: &[ScenarioReport], smoke: bool, source: &str) -> BenchReport {
    let mut r = BenchReport::new("routing_adaptive", source, SourceKind::Native, smoke);
    for sc in reports {
        r.param(format!("jobs/{}", sc.name), sc.jobs as f64);
    }
    for sc in reports {
        let n = sc.name;
        r.metric(
            format!("static_jobs_per_s/{n}"),
            report::round_dp(sc.static_jobs_per_s, 1),
            "jobs/s",
            Better::Higher,
        );
        r.metric(
            format!("adaptive_jobs_per_s/{n}"),
            report::round_dp(sc.adaptive_jobs_per_s, 1),
            "jobs/s",
            Better::Higher,
        );
        r.metric(format!("decisions/{n}"), sc.decisions.len() as f64, "count", Better::Info);
        let cutoffs = [
            ("final_tiny_cutoff", sc.fin.tiny_cutoff, "elements"),
            ("final_fuse_cutoff", sc.fin.fuse_cutoff, "elements"),
            ("final_parallel_cutoff", sc.fin.parallel_cutoff, "elements"),
            ("final_batch_max", sc.fin.batch_max, "jobs"),
        ];
        for (what, value, unit) in cutoffs {
            r.metric(format!("{what}/{n}"), value as f64, unit, Better::Info);
        }
        let moves = [
            ("tiny_direction", sc.initial.tiny_cutoff, sc.fin.tiny_cutoff),
            ("fuse_direction", sc.initial.fuse_cutoff, sc.fin.fuse_cutoff),
            ("parallel_direction", sc.initial.parallel_cutoff, sc.fin.parallel_cutoff),
            ("batch_direction", sc.initial.batch_max, sc.fin.batch_max),
        ];
        for (what, from, to) in moves {
            r.mark(format!("{what}/{n}"), direction(from, to));
        }
        for d in &sc.decisions {
            r.note(format!(
                "{n}: epoch {}: {} {} -> {} ({:.2} vs {:.2} elems/us)",
                d.epoch, d.param, d.from, d.to, d.lo_elems_per_us, d.hi_elems_per_us
            ));
        }
        for (tier, jobs, eu) in &sc.routes {
            if *jobs > 0 {
                r.note(format!("{n}: route {tier}: {jobs} jobs at {eu:.2} elems/us"));
            }
        }
    }
    r
}

fn main() {
    let smoke = report::smoke_from_env();
    let jobs_override =
        std::env::var("NEONMS_BENCH_JOBS").ok().and_then(|v| v.parse().ok());

    println!("adaptive routing: skewed workloads, static vs adaptive (smoke={smoke})");
    println!(
        "| scenario   | static jobs/s | adaptive jobs/s | decisions | final cutoffs (t/f/p/b) |"
    );
    let mut reports = Vec::new();
    for sc in scenarios(smoke, jobs_override) {
        let r = run_scenario(&sc);
        println!(
            "| {:10} | {:13.0} | {:15.0} | {:9} | {}/{}/{}/{} |",
            r.name,
            r.static_jobs_per_s,
            r.adaptive_jobs_per_s,
            r.decisions.len(),
            r.fin.tiny_cutoff,
            r.fin.fuse_cutoff,
            r.fin.parallel_cutoff,
            r.fin.batch_max
        );
        for d in &r.decisions {
            println!(
                "|   epoch {:3}: {} {} -> {} (lower {:.1} vs upper {:.1} e/µs)",
                d.epoch, d.param, d.from, d.to, d.lo_elems_per_us, d.hi_elems_per_us
            );
        }
        reports.push(r);
    }
    let moved = reports.iter().any(|r| !r.decisions.is_empty());
    println!(
        "convergence: {}",
        if moved {
            "the tuner committed cutoff moves (see decision trace for the measured winners)"
        } else {
            "no confirmed moves — tiers measured within the hysteresis band on this host"
        }
    );

    let source = report::source_label(smoke);
    let artifact = build_report(&reports, smoke, source);
    report::write_report(&artifact, "NEONMS_BENCH_OUT", "../BENCH_routing_adaptive.json");
}
