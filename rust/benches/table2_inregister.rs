//! Bench: paper Table 2 — in-register sort timing across register
//! configurations, plus the regmachine cost model on the NEON
//! geometry. Run via `cargo bench --bench table2_inregister`.
//!
//! Protocol follows §3: 64K random u32 per repetition; we report the
//! median of 100 repetitions (the paper averages 100 iterations).

fn main() {
    let reps = std::env::var("NEONMS_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(100);
    let (text, _rows) = neonms::bench::tables::table2_measured(reps);
    print!("{text}");
    println!();
    print!("{}", neonms::bench::tables::table2_model());
}
