//! Bench: paper Table 2 — in-register sort timing across register
//! configurations, plus the regmachine cost model on the NEON
//! geometry. Run via `cargo bench --bench table2_inregister`.
//!
//! Protocol follows §3: 64K random u32 per repetition; we report the
//! median of 100 repetitions (the paper averages 100 iterations).
//!
//! Env knobs (shared bench conventions):
//! * `NEONMS_BENCH_SMOKE=1` — CI smoke mode (5 reps).
//! * `NEONMS_BENCH_REPS` — repetitions (default 100, smoke 5).
//! * `NEONMS_BENCH_OUT` — `BenchReport` artifact path (default
//!   `../BENCH_table2_inregister.json`, the repo root when run via
//!   `cargo bench` from `rust/`).

use neonms::bench::report::{self, BenchReport, Better, SourceKind};

fn main() {
    let smoke = report::smoke_from_env();
    let reps = report::reps_from_env(if smoke { 5 } else { 100 });
    let (text, rows) = neonms::bench::tables::table2_measured(reps);
    print!("{text}");
    println!();
    print!("{}", neonms::bench::tables::table2_model());

    let source = report::source_label(smoke);
    let mut r = BenchReport::new("table2_inregister", source, SourceKind::Native, smoke);
    r.param("n", neonms::bench::tables::TABLE2_N as f64).param("reps", reps as f64);
    // Raw config labels ("R=16", "R=16*") are kept verbatim in metric
    // names — slugging would collide the starred and plain variants.
    for (label, x, us) in &rows {
        let key = format!("inreg_us/{label}/x{x}");
        r.metric(key, report::round_dp(*us, 1), "us", Better::Lower);
    }
    // The cost model is deterministic; record it as info so artifact
    // diffs surface model changes without rate-gating them.
    for (label, x, rep) in neonms::regmachine::model_table2(32) {
        r.metric(format!("model_cycles/{label}/x{x}"), rep.cycles as f64, "cycles", Better::Info);
        r.metric(format!("model_spills/{label}/x{x}"), rep.spills as f64, "count", Better::Info);
    }
    report::write_report(&r, "NEONMS_BENCH_OUT", "../BENCH_table2_inregister.json");
}
