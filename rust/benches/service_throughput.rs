//! Bench: sort-service small-job throughput under multi-tenant load.
//! Each repetition drives the service through `tenants` concurrent
//! [`SortClient`]s (one thread per tenant, handles drained per
//! tenant), so the numbers include client-layer admission and
//! completion signaling. Compares the dynamic batcher ON vs OFF
//! (fused sorts amortize queue wakeups + thread-scope setup across
//! many small requests), sweeps the shard count at a fixed batching
//! config, and sweeps the tenant count at a fixed service config.
//! Run via `cargo bench --bench service_throughput`.
//!
//! Env knobs (shared bench conventions):
//! * `NEONMS_BENCH_SMOKE=1` — CI smoke mode (fewer jobs and reps).
//! * `NEONMS_BENCH_JOBS` / `NEONMS_BENCH_JOBLEN` /
//!   `NEONMS_BENCH_TENANTS` / `NEONMS_BENCH_REPS` — workload shape.
//! * `NEONMS_BENCH_OUT` — `BenchReport` artifact path (default
//!   `../BENCH_service_throughput.json`, the repo root when run via
//!   `cargo bench` from `rust/`).
//!
//! [`SortClient`]: neonms::coordinator::SortClient

use neonms::bench::report::{self, slug, BenchReport, Better, SourceKind};
use neonms::bench::{bench, BenchResult};
use neonms::coordinator::{AdaptivePolicy, CoordinatorConfig, SortService};
use neonms::testutil::Rng;

/// One repetition: `tenants` clients submit `jobs` small requests in
/// total (split evenly), each tenant waiting its own replies.
fn drive(svc: &SortService, tenants: usize, jobs: usize, len: usize, seed: u64) {
    std::thread::scope(|s| {
        for t in 0..tenants {
            let client = svc.client(&format!("bench-{t}"));
            let share = jobs / tenants + usize::from(t < jobs % tenants);
            s.spawn(move || {
                let mut rng = Rng::new(seed.wrapping_mul(1000) + t as u64);
                let handles: Vec<_> =
                    (0..share).map(|_| client.submit(rng.vec_u32(len))).collect();
                for h in handles {
                    h.wait().expect("reply");
                }
            });
        }
    });
}

/// Measured row: config label, jobs/s, and the batcher/steal context.
struct Row {
    name: String,
    jobs_per_s: f64,
    occupancy: f64,
    steals: u64,
}

fn run_config(
    name: &str,
    cfg: CoordinatorConfig,
    tenants: usize,
    jobs: usize,
    len: usize,
    reps: usize,
) -> Row {
    let svc = SortService::start(cfg, None).expect("service start");
    let res: BenchResult = bench(
        name,
        jobs, // "elements" = requests per repetition
        1,
        reps,
        |r| r as u64,
        |seed| drive(&svc, tenants, jobs, len, seed),
    );
    let m = svc.metrics();
    println!(
        "| {name:26} | {:9.0} jobs/s | occupancy {:5.1} | steals {:4} | p99 {:6}µs |",
        res.per_sec(),
        m.batch_occupancy,
        m.steals,
        m.p99_us
    );
    svc.shutdown();
    Row {
        name: name.to_string(),
        jobs_per_s: res.per_sec(),
        occupancy: m.batch_occupancy,
        steals: m.steals,
    }
}

fn main() {
    let smoke = report::smoke_from_env();
    let jobs: usize = std::env::var("NEONMS_BENCH_JOBS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke { 400 } else { 4000 });
    let len: usize = std::env::var("NEONMS_BENCH_JOBLEN")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256);
    let reps = report::reps_from_env(if smoke { 2 } else { 5 });
    let tenants: usize = std::env::var("NEONMS_BENCH_TENANTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4);

    println!(
        "service throughput: {jobs} requests × {len} u32 per repetition, \
         {tenants} tenants, {reps} reps (smoke={smoke})"
    );
    let mut rows = Vec::new();
    println!("-- batching ablation (2 workers, 2 shards, {tenants} tenants) --");
    for (name, batch_max) in [("unbatched (batch_max=1)", 1usize), ("batched (batch_max=32)", 32)] {
        let cfg = CoordinatorConfig { workers: 2, shards: 2, batch_max, ..Default::default() };
        rows.push(run_config(name, cfg, tenants, jobs, len, reps));
    }
    println!("-- shard sweep (batched, workers = shards, {tenants} tenants) --");
    for shards in [1usize, 2, 4, 8] {
        let cfg = CoordinatorConfig {
            workers: shards,
            shards,
            batch_max: 32,
            ..Default::default()
        };
        rows.push(run_config(&format!("shards={shards}"), cfg, tenants, jobs, len, reps));
    }
    println!("-- tenant sweep (2 workers, 2 shards, batched) --");
    for t in [1usize, 2, 4, 8] {
        let cfg = CoordinatorConfig { workers: 2, shards: 2, batch_max: 32, ..Default::default() };
        rows.push(run_config(&format!("tenants={t}"), cfg, t, jobs, len, reps));
    }
    println!("-- adaptive routing (2 workers, 2 shards, batched, {tenants} tenants) --");
    for (name, adaptive) in
        [("routing static", AdaptivePolicy::Off), ("routing adaptive", AdaptivePolicy::adaptive())]
    {
        let cfg = CoordinatorConfig {
            workers: 2,
            shards: 2,
            batch_max: 32,
            adaptive,
            ..Default::default()
        };
        rows.push(run_config(name, cfg, tenants, jobs, len, reps));
    }

    let source = report::source_label(smoke);
    let mut r = BenchReport::new("service_throughput", source, SourceKind::Native, smoke);
    r.param("jobs", jobs as f64)
        .param("job_len", len as f64)
        .param("reps", reps as f64)
        .param("tenants", tenants as f64);
    for row in &rows {
        let key = slug(&row.name);
        r.metric(
            format!("jobs_per_s/{key}"),
            report::round_dp(row.jobs_per_s, 1),
            "jobs/s",
            Better::Higher,
        );
        r.metric(
            format!("batch_occupancy/{key}"),
            report::round_dp(row.occupancy, 2),
            "jobs/batch",
            Better::Info,
        );
        r.metric(format!("steals/{key}"), row.steals as f64, "count", Better::Info);
    }
    report::write_report(&r, "NEONMS_BENCH_OUT", "../BENCH_service_throughput.json");
}
