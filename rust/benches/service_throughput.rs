//! Bench: sort-service small-job throughput — the PR-1 coordinator
//! acceptance bench. Compares the dynamic batcher ON vs OFF (fused
//! sorts amortize queue wakeups + thread-scope setup across many
//! small requests) and sweeps the shard count at a fixed batching
//! config. Run via `cargo bench --bench service_throughput`.

use neonms::bench::{bench, BenchResult};
use neonms::coordinator::{CoordinatorConfig, SortService};
use neonms::testutil::Rng;

/// One repetition: submit `jobs` small requests, wait for every reply.
fn drive(svc: &SortService, jobs: usize, len: usize, seed: u64) {
    let mut rng = Rng::new(seed);
    let handles: Vec<_> = (0..jobs).map(|_| svc.submit(rng.vec_u32(len))).collect();
    for h in handles {
        h.wait().expect("reply");
    }
}

fn run_config(name: &str, cfg: CoordinatorConfig, jobs: usize, len: usize, reps: usize) {
    let svc = SortService::start(cfg, None).expect("service start");
    let res: BenchResult = bench(
        name,
        jobs, // "elements" = requests per repetition
        1,
        reps,
        |r| r as u64,
        |seed| drive(&svc, jobs, len, seed),
    );
    let m = svc.metrics();
    println!(
        "| {name:26} | {:9.0} jobs/s | occupancy {:5.1} | steals {:4} | p99 {:6}µs |",
        res.per_sec(),
        m.batch_occupancy,
        m.steals,
        m.p99_us
    );
    svc.shutdown();
}

fn main() {
    let jobs: usize = std::env::var("NEONMS_BENCH_JOBS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4000);
    let len: usize = std::env::var("NEONMS_BENCH_JOBLEN")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256);
    let reps: usize = std::env::var("NEONMS_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(5);

    println!("service throughput: {jobs} requests × {len} u32 per repetition, {reps} reps");
    println!("-- batching ablation (2 workers, 2 shards) --");
    for (name, batch_max) in [("unbatched (batch_max=1)", 1usize), ("batched (batch_max=32)", 32)] {
        let cfg = CoordinatorConfig { workers: 2, shards: 2, batch_max, ..Default::default() };
        run_config(name, cfg, jobs, len, reps);
    }
    println!("-- shard sweep (batched, workers = shards) --");
    for shards in [1usize, 2, 4, 8] {
        let cfg = CoordinatorConfig {
            workers: shards,
            shards,
            batch_max: 32,
            ..Default::default()
        };
        run_config(&format!("shards={shards}"), cfg, jobs, len, reps);
    }
}
