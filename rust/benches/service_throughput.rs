//! Bench: sort-service small-job throughput under multi-tenant load.
//! Each repetition drives the service through `tenants` concurrent
//! [`SortClient`]s (one thread per tenant, handles drained per
//! tenant), so the numbers include client-layer admission and
//! completion signaling. Compares the dynamic batcher ON vs OFF
//! (fused sorts amortize queue wakeups + thread-scope setup across
//! many small requests), sweeps the shard count at a fixed batching
//! config, and sweeps the tenant count at a fixed service config.
//! Run via `cargo bench --bench service_throughput`.
//!
//! [`SortClient`]: neonms::coordinator::SortClient

use neonms::bench::{bench, BenchResult};
use neonms::coordinator::{AdaptivePolicy, CoordinatorConfig, SortService};
use neonms::testutil::Rng;

/// One repetition: `tenants` clients submit `jobs` small requests in
/// total (split evenly), each tenant waiting its own replies.
fn drive(svc: &SortService, tenants: usize, jobs: usize, len: usize, seed: u64) {
    std::thread::scope(|s| {
        for t in 0..tenants {
            let client = svc.client(&format!("bench-{t}"));
            let share = jobs / tenants + usize::from(t < jobs % tenants);
            s.spawn(move || {
                let mut rng = Rng::new(seed.wrapping_mul(1000) + t as u64);
                let handles: Vec<_> =
                    (0..share).map(|_| client.submit(rng.vec_u32(len))).collect();
                for h in handles {
                    h.wait().expect("reply");
                }
            });
        }
    });
}

fn run_config(
    name: &str,
    cfg: CoordinatorConfig,
    tenants: usize,
    jobs: usize,
    len: usize,
    reps: usize,
) {
    let svc = SortService::start(cfg, None).expect("service start");
    let res: BenchResult = bench(
        name,
        jobs, // "elements" = requests per repetition
        1,
        reps,
        |r| r as u64,
        |seed| drive(&svc, tenants, jobs, len, seed),
    );
    let m = svc.metrics();
    println!(
        "| {name:26} | {:9.0} jobs/s | occupancy {:5.1} | steals {:4} | p99 {:6}µs |",
        res.per_sec(),
        m.batch_occupancy,
        m.steals,
        m.p99_us
    );
    svc.shutdown();
}

fn main() {
    let jobs: usize = std::env::var("NEONMS_BENCH_JOBS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4000);
    let len: usize = std::env::var("NEONMS_BENCH_JOBLEN")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256);
    let reps: usize = std::env::var("NEONMS_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(5);
    let tenants: usize = std::env::var("NEONMS_BENCH_TENANTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4);

    println!(
        "service throughput: {jobs} requests × {len} u32 per repetition, \
         {tenants} tenants, {reps} reps"
    );
    println!("-- batching ablation (2 workers, 2 shards, {tenants} tenants) --");
    for (name, batch_max) in [("unbatched (batch_max=1)", 1usize), ("batched (batch_max=32)", 32)] {
        let cfg = CoordinatorConfig { workers: 2, shards: 2, batch_max, ..Default::default() };
        run_config(name, cfg, tenants, jobs, len, reps);
    }
    println!("-- shard sweep (batched, workers = shards, {tenants} tenants) --");
    for shards in [1usize, 2, 4, 8] {
        let cfg = CoordinatorConfig {
            workers: shards,
            shards,
            batch_max: 32,
            ..Default::default()
        };
        run_config(&format!("shards={shards}"), cfg, tenants, jobs, len, reps);
    }
    println!("-- tenant sweep (2 workers, 2 shards, batched) --");
    for t in [1usize, 2, 4, 8] {
        let cfg = CoordinatorConfig { workers: 2, shards: 2, batch_max: 32, ..Default::default() };
        run_config(&format!("tenants={t}"), cfg, t, jobs, len, reps);
    }
    println!("-- adaptive routing (2 workers, 2 shards, batched, {tenants} tenants) --");
    for (name, adaptive) in
        [("routing static", AdaptivePolicy::Off), ("routing adaptive", AdaptivePolicy::adaptive())]
    {
        let cfg = CoordinatorConfig {
            workers: 2,
            shards: 2,
            batch_max: 32,
            adaptive,
            ..Default::default()
        };
        run_config(name, cfg, tenants, jobs, len, reps);
    }
}
