//! Bench: chaos soak — the failure-domain hardening exercised as a
//! workload, not a unit test. A fixed-seed [`FaultPlan`] injects
//! contained sort panics, worker-killing panics, stalls, and forced
//! sheds while three QoS-weighted tenants (one carrying a tight
//! default deadline) drive mixed-size traffic through
//! `try_submit_with_retry`; the service must absorb all of it —
//! panicking jobs resolve to typed errors, killed workers respawn,
//! expired requests reap with their charge refunded — without wedging
//! a single submitter or losing a single count.
//!
//! Three structural marks are the headline claims:
//!
//! * **`no_wedged_submitters`** — every tenant thread joins and every
//!   kept handle resolves (a result or a typed error, never a parked
//!   waiter), with shutdown racing the tail of the storm.
//! * **`accounting_exact`** — per tenant, after shutdown:
//!   `accepted == completed + cancelled + failed`, and the
//!   `in_flight_bytes` / `queued_jobs` gauges drain to exactly zero.
//! * **`breaker_recovers`** — a scripted [`CircuitBreaker`] sequence
//!   (injected clock) trips Closed → Open on consecutive failures,
//!   half-opens after the cooloff, reopens on a failed probe, and
//!   closes again on a successful one.
//!
//! The one gateable metric is **`completion_rate`** = completed /
//! accepted across all tenants: under a fixed injection schedule the
//! survival rate is a property of the recovery machinery, so a drop
//! means containment or requeue regressed. Fault/recovery counters
//! (`panics_contained`, `workers_respawned`, `quarantined`,
//! `deadline_expired`) are recorded as context — their exact values
//! depend on thread interleaving even with a fixed plan, because the
//! per-admission fault sequence is racing three submitter threads.
//!
//! Env knobs:
//! * `NEONMS_BENCH_SMOKE=1` — CI smoke mode (shorter storm).
//! * `NEONMS_BENCH_JOBS` — jobs per tenant.
//! * `NEONMS_BENCH_OUT` — artifact path (default
//!   `../BENCH_chaos_soak.json`, the repo root when run via
//!   `cargo bench` from `rust/`).

use neonms::bench::report::{self, BenchReport, Better, SourceKind};
use neonms::coordinator::{
    ClientConfig, CoordinatorConfig, FaultPlan, RetryPolicy, SortService,
};
use neonms::runtime::{BreakerState, CircuitBreaker};
use neonms::testutil::Rng;
use std::time::{Duration, Instant};

const WORKERS: usize = 4;
const TENANTS: usize = 3;

/// The injection mix: roughly 1 in 5 admissions carries a fault.
/// Worker-killing panics are kept rare (each one costs a thread
/// respawn and a requeue) but present, so the supervisor path is
/// always exercised.
fn plan() -> FaultPlan {
    FaultPlan {
        seed: 0x0C4A05,
        sort_panic_per_mille: 100,
        fatal_panic_per_mille: 10,
        stall_per_mille: 50,
        stall: Duration::from_micros(200),
        shed_per_mille: 40,
        ..Default::default()
    }
}

/// Drive one tenant: `jobs` requests through the retrying submit
/// path, draining handles opportunistically. Returns
/// (resolved_ok, resolved_err, gave_up) — every accepted handle is
/// waited on, so a wedged waiter hangs the bench (that *is* the
/// no-wedge check).
fn run_tenant(svc: &SortService, tenant: usize, jobs: usize, seed: u64) -> (u64, u64, u64) {
    let deadline = (tenant == 2).then(|| Duration::from_millis(2));
    let client = svc.client_with(
        &format!("chaos-{tenant}"),
        ClientConfig {
            weight: 1 + tenant as u32,
            burst: 1 << 20,
            default_deadline: deadline,
        },
    );
    let policy = RetryPolicy {
        max_attempts: 6,
        base: Duration::from_micros(50),
        cap: Duration::from_millis(2),
        jitter_seed: seed,
    };
    let mut rng = Rng::new(seed);
    let mut pending = Vec::new();
    let (mut ok, mut err, mut gave_up) = (0u64, 0u64, 0u64);
    for _ in 0..jobs {
        let len = 64 + rng.below(2000);
        match client.try_submit_with_retry(rng.vec_u32(len), &policy) {
            Ok(h) => pending.push(h),
            // Forced sheds under a saturated queue can outlast the
            // policy; the input comes back and the request is simply
            // not accepted — that's degradation, not a failure.
            Err(_) => gave_up += 1,
        }
        if pending.len() >= 32 {
            for h in pending.drain(..) {
                match h.wait() {
                    Ok(_) => ok += 1,
                    Err(_) => err += 1,
                }
            }
        }
    }
    for h in pending {
        match h.wait() {
            Ok(_) => ok += 1,
            Err(_) => err += 1,
        }
    }
    (ok, err, gave_up)
}

/// Scripted breaker lifecycle on an injected clock: trip, cool off,
/// fail the first probe (reopen), pass the second (close). Returns
/// true when every transition lands where the state machine promises.
fn breaker_recovers() -> bool {
    let cooloff = Duration::from_millis(50);
    let mut b = CircuitBreaker::new(3, cooloff);
    let t0 = Instant::now();
    for _ in 0..3 {
        if !b.allow_at(t0) {
            return false; // must stay closed below the threshold
        }
        b.record_failure_at(t0);
    }
    if !matches!(b.state(), BreakerState::Open { .. }) || b.allow_at(t0) || b.trips() != 1 {
        return false;
    }
    // Cooloff elapses: the next caller is admitted as the probe.
    let t1 = t0 + cooloff;
    if !b.allow_at(t1) || b.state() != BreakerState::HalfOpen {
        return false;
    }
    b.record_failure_at(t1); // failed probe: straight back to Open
    if !matches!(b.state(), BreakerState::Open { .. }) || b.trips() != 2 {
        return false;
    }
    let t2 = t1 + cooloff;
    if !b.allow_at(t2) {
        return false;
    }
    b.record_success(); // healthy probe: Closed, counters reset
    b.state() == BreakerState::Closed && b.allow_at(t2)
}

fn main() {
    let smoke = report::smoke_from_env();
    let jobs: usize = std::env::var("NEONMS_BENCH_JOBS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke { 300 } else { 3000 });

    println!(
        "chaos soak: {TENANTS} tenants x {jobs} jobs, seeded fault plan \
         (sort-panic 10%, fatal 1%, stall 5%, shed 4%), {WORKERS} workers (smoke={smoke})"
    );

    let cfg = CoordinatorConfig {
        workers: WORKERS,
        shards: 2,
        queue_capacity: 64,
        batch_max: 16,
        faults: Some(plan()),
        ..Default::default()
    };
    let svc = SortService::start(cfg, None).expect("service start");
    let t0 = Instant::now();
    let outcomes: Vec<(u64, u64, u64)> = std::thread::scope(|s| {
        let svc = &svc;
        let joins: Vec<_> = (0..TENANTS)
            .map(|t| s.spawn(move || run_tenant(svc, t, jobs, 0xC4A0 + t as u64)))
            .collect();
        joins.into_iter().map(|j| j.join().expect("tenant thread")).collect()
    });
    let dt = t0.elapsed();
    // Every thread joined and every handle resolved — nobody wedged.
    let no_wedge = true;

    let m = svc.metrics();
    svc.shutdown();

    let (ok, err, gave_up) = outcomes
        .iter()
        .fold((0, 0, 0), |(a, b, c), (x, y, z)| (a + x, b + y, c + z));
    let accepted: u64 = m.tenants.iter().map(|t| t.accepted).sum();
    let completed: u64 = m.tenants.iter().map(|t| t.completed).sum();
    let accounting_exact = m.tenants.iter().all(|t| {
        t.accepted == t.completed + t.cancelled + t.failed
            && t.in_flight_bytes == 0
            && t.queued_jobs == 0
    });
    let completion_rate = if accepted == 0 { 0.0 } else { completed as f64 / accepted as f64 };
    let breaker_ok = breaker_recovers();

    println!("resolved: {ok} ok / {err} typed errors / {gave_up} gave up after retries");
    println!(
        "injection absorbed: panics_contained={} workers_respawned={} quarantined={} \
         deadline_expired={} failed={}",
        m.panics_contained, m.workers_respawned, m.quarantined, m.deadline_expired, m.failed
    );
    println!(
        "completion rate {completion_rate:.3} ({completed}/{accepted} accepted) in {:.3}s; \
         accounting_exact={accounting_exact} breaker_recovers={breaker_ok}",
        dt.as_secs_f64()
    );

    let source = report::source_label(smoke);
    let mut r = BenchReport::new("chaos_soak", source, SourceKind::Native, smoke);
    r.param("tenants", TENANTS as f64)
        .param("jobs_per_tenant", jobs as f64)
        .param("workers", WORKERS as f64)
        .param("sort_panic_per_mille", 100.0)
        .param("fatal_panic_per_mille", 10.0)
        .param("stall_per_mille", 50.0)
        .param("shed_per_mille", 40.0);
    r.mark("no_wedged_submitters", if no_wedge { "true" } else { "false" });
    r.mark("accounting_exact", if accounting_exact { "true" } else { "false" });
    r.mark("breaker_recovers", if breaker_ok { "true" } else { "false" });
    r.metric(
        "completion_rate",
        report::round_dp(completion_rate, 3),
        "ratio",
        Better::Higher,
    );
    let context = [
        ("resolved_ok", ok),
        ("resolved_err", err),
        ("gave_up_after_retries", gave_up),
        ("panics_contained", m.panics_contained),
        ("workers_respawned", m.workers_respawned),
        ("quarantined", m.quarantined),
        ("deadline_expired", m.deadline_expired),
        ("failed", m.failed),
    ];
    for (what, value) in context {
        r.metric(what, value as f64, "count", Better::Info);
    }
    report::write_report(&r, "NEONMS_BENCH_OUT", "../BENCH_chaos_soak.json");

    assert!(no_wedge && accounting_exact && breaker_ok, "structural marks must hold");
}
