//! Bench: aggressor-vs-victim fairness — how much of its isolated
//! throughput a well-behaved tenant keeps while a greedy one floods
//! the service, under global FIFO versus weighted fair-share QoS.
//!
//! Setup (both tenants weight 1, so the fair split is 50/50 and the
//! victim's demand is far below its half — the victim is
//! latency-bound, like an interactive tenant, while the aggressor is
//! throughput-bound):
//!
//! * **victim** — closed loop, `VICTIM_WINDOW` (= 1) request
//!   outstanding, `JOB_LEN`-element jobs; its completed-jobs/s is
//!   the metric. Its isolated throughput uses a fraction of the
//!   `WORKERS`-way service, well under its fair half.
//! * **aggressor** — `AGGRESSOR_FACTOR × WORKERS` requests held
//!   outstanding continuously (the "8× offered load": eight times
//!   the worker parallelism), same job size, submitting through
//!   `try_submit` and retrying immediately on shed with a tiny yield
//!   — a saturating flood against a deliberately small
//!   `queue_capacity`, so admission pressure (sheds, evictions) is
//!   real, not just dequeue ordering. Its burst allowance is small,
//!   so its backlog counts as over-share; the victim's is generous,
//!   so the victim is never over-share.
//!
//! Three measurements per run: the victim alone (isolated baseline),
//! then victim + aggressor under [`QosPolicy::Fifo`], then under
//! [`QosPolicy::FairShare`]. The headline number is **retention** =
//! contended / isolated victim throughput; the fair-share acceptance
//! bar is ≥ 0.8 while FIFO collapses (the aggressor owns the queues
//! and the victim is shed like anyone else). Results are written as
//! JSON (`BENCH_qos_fairness.json` at the repo root by default) with
//! a `source` provenance field, like the width-sweep and
//! routing-adaptive artifacts.
//!
//! Env knobs:
//! * `NEONMS_BENCH_SMOKE=1` — CI smoke mode (shorter runs).
//! * `NEONMS_BENCH_JOBS` — victim jobs per measurement.
//! * `NEONMS_BENCH_OUT` — artifact path (default
//!   `../BENCH_qos_fairness.json`, the repo root when run via
//!   `cargo bench` from `rust/`).

use neonms::bench::report::{self, BenchReport, Better, SourceKind};
use neonms::coordinator::{
    BusyReason, ClientConfig, CoordinatorConfig, QosPolicy, SortService, TenantSnapshot,
};
use neonms::testutil::Rng;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

const JOB_LEN: usize = 2048;
const VICTIM_WINDOW: usize = 1;
const AGGRESSOR_FACTOR: usize = 8;
const WORKERS: usize = 4;

fn service(qos: QosPolicy) -> SortService {
    let cfg = CoordinatorConfig {
        workers: WORKERS,
        shards: 2,
        queue_capacity: 16,
        qos,
        ..Default::default()
    };
    SortService::start(cfg, None).expect("service start")
}

fn victim_client(svc: &SortService) -> neonms::coordinator::SortClient {
    // Generous burst (bytes): the victim's whole window fits inside
    // it, so it is never the over-share tenant.
    svc.client_with("victim", ClientConfig { weight: 1, burst: 4 << 20, ..Default::default() })
}

/// Closed-loop victim: keep `VICTIM_WINDOW` requests outstanding
/// until `jobs` complete; returns jobs/s of wall time. Sheds retry
/// after the service's own hint (QoS-aware client behavior); evicted
/// handles are counted and resubmitted — under fair-share with a
/// within-burst victim neither ever fires.
fn run_victim(svc: &SortService, jobs: usize, seed: u64) -> f64 {
    let client = victim_client(svc);
    let mut rng = Rng::new(seed);
    let mut pending = Vec::new();
    let mut done = 0usize;
    let mut submitted = 0usize;
    let t0 = Instant::now();
    while done < jobs {
        while submitted < jobs && pending.len() < VICTIM_WINDOW {
            match client.try_submit(rng.vec_u32(JOB_LEN)) {
                Ok(h) => {
                    pending.push(h);
                    submitted += 1;
                }
                Err(busy) => {
                    let backoff = match busy.reason {
                        BusyReason::OverShare { retry_after_hint } => retry_after_hint,
                        _ => std::time::Duration::from_micros(100),
                    };
                    std::thread::sleep(backoff);
                }
            }
        }
        // Count only successful completions toward the throughput;
        // an evicted request must be redone.
        let mut completed_now = 0usize;
        pending.retain_mut(|h| match h.try_take() {
            Some(Ok(_)) => {
                completed_now += 1;
                false
            }
            Some(Err(_)) => {
                submitted -= 1;
                false
            }
            None => true,
        });
        done += completed_now;
        if completed_now == 0 {
            std::thread::yield_now();
        }
    }
    jobs as f64 / t0.elapsed().as_secs_f64()
}

/// Saturating aggressor: `AGGRESSOR_FACTOR × WORKERS` outstanding,
/// immediate resubmit on shed, until `stop`.
fn run_aggressor(svc: &SortService, stop: &AtomicBool, seed: u64) {
    let client =
        // Small burst (bytes): four u32 jobs' worth, so the flood's
        // backlog counts as over-share almost immediately.
        svc.client_with(
            "aggressor",
            ClientConfig { weight: 1, burst: 4 * JOB_LEN * 4, ..Default::default() },
        );
    let mut rng = Rng::new(seed);
    let mut pending = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        while pending.len() < AGGRESSOR_FACTOR * WORKERS {
            match client.try_submit(rng.vec_u32(JOB_LEN)) {
                Ok(h) => pending.push(h),
                Err(_) => {
                    std::thread::yield_now();
                    break;
                }
            }
        }
        // Drain whatever resolved (results and eviction errors alike).
        pending.retain_mut(|h| h.try_take().is_none());
    }
}

struct Contended {
    victim_jobs_per_s: f64,
    victim: TenantSnapshot,
    aggressor: TenantSnapshot,
    evictions: u64,
}

fn run_contended(qos: QosPolicy, jobs: usize) -> Contended {
    let svc = service(qos);
    let stop = AtomicBool::new(false);
    let rate = std::thread::scope(|s| {
        let svc = &svc;
        let stop = &stop;
        s.spawn(move || run_aggressor(svc, stop, 7));
        let rate = run_victim(svc, jobs, 11);
        stop.store(true, Ordering::Relaxed);
        rate
    });
    let m = svc.metrics();
    let tenant = |name: &str| {
        m.tenants.iter().find(|t| t.name == name).expect("tenant snapshot").clone()
    };
    let out = Contended {
        victim_jobs_per_s: rate,
        victim: tenant("victim"),
        aggressor: tenant("aggressor"),
        evictions: m.evicted,
    };
    svc.shutdown();
    out
}

fn main() {
    let smoke = report::smoke_from_env();
    let jobs: usize = std::env::var("NEONMS_BENCH_JOBS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke { 400 } else { 2000 });

    println!(
        "qos fairness: victim (window {VICTIM_WINDOW}) vs aggressor \
         ({AGGRESSOR_FACTOR}× offered load), {JOB_LEN}-element jobs, {jobs} victim jobs \
         (smoke={smoke})"
    );

    // Isolated baseline: the victim alone on a fair-share service.
    let svc = service(QosPolicy::FairShare);
    let isolated = run_victim(&svc, jobs, 11);
    svc.shutdown();
    println!("| victim isolated       | {isolated:10.0} jobs/s | retention 1.00 |");

    let mut rows = Vec::new();
    for qos in [QosPolicy::Fifo, QosPolicy::FairShare] {
        let c = run_contended(qos, jobs);
        let retention = c.victim_jobs_per_s / isolated;
        println!(
            "| victim vs aggressor ({:9}) | {:10.0} jobs/s | retention {:.2} | \
             victim shed {} | aggressor shed {} (over-share {}, evicted {})",
            format!("{qos:?}"),
            c.victim_jobs_per_s,
            retention,
            c.victim.shed,
            c.aggressor.shed,
            c.aggressor.shed_over_share,
            c.aggressor.evicted,
        );
        rows.push((qos, c, retention));
    }
    if let Some((_, c, r)) = rows.iter().find(|(q, _, _)| *q == QosPolicy::FairShare) {
        println!(
            "fair-share verdict: victim retained {:.0}% of isolated throughput \
             (acceptance bar 80%), victim sheds {}",
            r * 100.0,
            c.victim.shed
        );
    }

    let source = report::source_label(smoke);
    let mut r = BenchReport::new("qos_fairness", source, SourceKind::Native, smoke);
    r.param("job_len", JOB_LEN as f64)
        .param("victim_window", VICTIM_WINDOW as f64)
        .param("aggressor_factor", AGGRESSOR_FACTOR as f64)
        .param("victim_jobs", jobs as f64);
    r.metric("victim_isolated_jobs_per_s", report::round_dp(isolated, 1), "jobs/s", Better::Higher);
    for (qos, c, retention) in &rows {
        let p = format!("{qos:?}");
        // Only the fair-share victim numbers are gateable claims;
        // FIFO collapse depth and aggressor counters are context.
        let fair = *qos == QosPolicy::FairShare;
        let gate = |g: Better| if fair { g } else { Better::Info };
        r.metric(
            format!("victim_jobs_per_s/{p}"),
            report::round_dp(c.victim_jobs_per_s, 1),
            "jobs/s",
            gate(Better::Higher),
        );
        r.metric(
            format!("victim_retention/{p}"),
            report::round_dp(*retention, 3),
            "ratio",
            gate(Better::Higher),
        );
        r.metric(format!("victim_shed/{p}"), c.victim.shed as f64, "count", gate(Better::Lower));
        let context = [
            ("aggressor_completed", c.aggressor.completed),
            ("aggressor_shed", c.aggressor.shed),
            ("aggressor_shed_over_share", c.aggressor.shed_over_share),
            ("aggressor_evicted", c.aggressor.evicted),
            ("evictions_total", c.evictions),
        ];
        for (what, value) in context {
            r.metric(format!("{what}/{p}"), value as f64, "count", Better::Info);
        }
    }
    if let Some((_, c, _)) = rows.iter().find(|(q, _, _)| *q == QosPolicy::FairShare) {
        // The headline structural claim: fair share never sheds the
        // within-burst victim.
        let held = if c.victim.shed == 0 { "true" } else { "false" };
        r.mark("victim_shed_zero_under_fair_share", held);
    }
    report::write_report(&r, "NEONMS_BENCH_OUT", "../BENCH_qos_fairness.json");
}
