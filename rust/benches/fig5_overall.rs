//! Bench: paper Fig. 5 — end-to-end sorting rate (ME/s) by data size
//! and method, single-thread and parallel.
//! Run via `cargo bench --bench fig5_overall`.
//!
//! Size range: the paper sweeps 512K–128M on a 64-core FT2000+; this
//! single-core VM caps at 16M by default (override with
//! NEONMS_BENCH_MAXN). Speedup *ratios* are the reproduction target.
//!
//! Env knobs (shared bench conventions):
//! * `NEONMS_BENCH_SMOKE=1` — CI smoke mode: one 64K size, 2 reps,
//!   T=2 only.
//! * `NEONMS_BENCH_REPS` — repetitions per point (default 3, smoke 2).
//! * `NEONMS_BENCH_MAXN` — largest size in the sweep.
//! * `NEONMS_BENCH_OUT` — [`BenchReport`] artifact path (default
//!   `../BENCH_fig5_overall.json`, the repo root when run via
//!   `cargo bench` from `rust/`).

use neonms::bench::report::{self, slug, BenchReport, Better, SourceKind};

fn main() {
    let smoke = report::smoke_from_env();
    let max_n: usize = std::env::var("NEONMS_BENCH_MAXN")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke { 1 << 16 } else { 16 << 20 });
    let reps = report::reps_from_env(if smoke { 2 } else { 3 });
    let mut sizes = Vec::new();
    let mut n = if smoke { 1 << 16 } else { 512 * 1024 };
    while n <= max_n {
        sizes.push(n);
        n *= 2;
    }
    let threads: &[usize] = if smoke { &[2] } else { &[2, 4] };
    let (text, rows) = neonms::bench::tables::fig5(&sizes, threads, reps);
    print!("{text}");

    let source = report::source_label(smoke);
    let mut r = BenchReport::new("fig5_overall", source, SourceKind::Native, smoke);
    r.param("reps", reps as f64).param("max_n", *sizes.last().unwrap_or(&0) as f64);
    for (name, n, v) in &rows {
        let key = format!("me_per_s/{}/n{n}", slug(name));
        r.metric(key, report::round_dp(*v, 3), "ME/s", Better::Higher);
    }

    // Headline ratios (paper: 3.8× vs std::sort, 2.1× vs block_sort).
    println!("\nspeedup of NEON-MS (single-thread) per size:");
    for &n in &sizes {
        let get = |name: &str| {
            rows.iter()
                .find(|(m, nn, _)| m == name && *nn == n)
                .map(|(_, _, v)| *v)
                .unwrap_or(f64::NAN)
        };
        let vs_std = get("NEON-MS") / get("std::sort (introsort)");
        let vs_block = get("NEON-MS") / get("boost::block_sort");
        println!("  n={n:9}: {vs_std:.2}x vs std::sort, {vs_block:.2}x vs block_sort");
        // Ratios are host-shape facts, recorded but not rate-gated.
        if vs_std.is_finite() {
            let key = format!("speedup_vs_introsort/n{n}");
            r.metric(key, report::round_dp(vs_std, 3), "ratio", Better::Info);
        }
        if vs_block.is_finite() {
            let key = format!("speedup_vs_blocksort/n{n}");
            r.metric(key, report::round_dp(vs_block, 3), "ratio", Better::Info);
        }
    }
    report::write_report(&r, "NEONMS_BENCH_OUT", "../BENCH_fig5_overall.json");
}
