//! Bench: paper Fig. 5 — end-to-end sorting rate (ME/s) by data size
//! and method, single-thread and parallel.
//! Run via `cargo bench --bench fig5_overall`.
//!
//! Size range: the paper sweeps 512K–128M on a 64-core FT2000+; this
//! single-core VM caps at 16M by default (override with
//! NEONMS_BENCH_MAXN). Speedup *ratios* are the reproduction target.

fn main() {
    let max_n: usize = std::env::var("NEONMS_BENCH_MAXN")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(16 << 20);
    let reps = std::env::var("NEONMS_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);
    let mut sizes = Vec::new();
    let mut n = 512 * 1024;
    while n <= max_n {
        sizes.push(n);
        n *= 2;
    }
    let (text, rows) = neonms::bench::tables::fig5(&sizes, &[2, 4], reps);
    print!("{text}");
    // Headline ratios (paper: 3.8× vs std::sort, 2.1× vs block_sort).
    println!("\nspeedup of NEON-MS (single-thread) per size:");
    for &n in &sizes {
        let get = |name: &str| {
            rows.iter()
                .find(|(m, nn, _)| m == name && *nn == n)
                .map(|(_, _, v)| *v)
                .unwrap_or(f64::NAN)
        };
        println!(
            "  n={n:9}: {:.2}x vs std::sort, {:.2}x vs block_sort",
            get("NEON-MS") / get("std::sort (introsort)"),
            get("NEON-MS") / get("boost::block_sort"),
        );
    }
}
