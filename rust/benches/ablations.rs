//! Bench: ablations of the design choices DESIGN.md §5 calls out —
//! column-network family, merge-kernel width, input distribution, and
//! the cooperative merge-path strategy — plus two recorded sweeps:
//! the width × K × impl sweep (`BENCH_width_sweep.json`) and the
//! element-width sweep (u32 vs u64 vs `KeyValue` pairs at each
//! register width × K, `BENCH_elem_width.json`), so the perf
//! trajectory is comparable across PRs and element widths.
//! Run via `cargo bench --bench ablations`.
//!
//! Env knobs:
//! * `NEONMS_BENCH_REPS` — repetitions per point (default 10).
//! * `NEONMS_BENCH_SMOKE=1` — CI smoke mode: small n, 2 reps, the two
//!   recorded sweeps only (the artifacts still have every point).
//! * `NEONMS_BENCH_OUT` — where to write the width-sweep JSON
//!   (default `../BENCH_width_sweep.json`, i.e. the repo root when
//!   run via `cargo bench` from `rust/`).
//! * `NEONMS_BENCH_ELEM_OUT` — where to write the element-width JSON
//!   (default `../BENCH_elem_width.json`).

fn main() {
    let smoke = std::env::var("NEONMS_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let reps = std::env::var("NEONMS_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke { 2 } else { 10 });
    let n = if smoke { 1 << 16 } else { 1 << 20 };

    if !smoke {
        print!("{}", neonms::bench::tables::table1());
        println!();
        print!("{}", neonms::bench::tables::ablation_column_network(n, reps));
        println!();
        print!("{}", neonms::bench::tables::ablation_merge_width(n, reps));
        println!();
        print!("{}", neonms::bench::tables::ablation_workloads(n, reps));
        println!();
        print!("{}", neonms::bench::tables::ablation_parallel_merge(4 << 20, 4, reps.min(5)));
        println!();
    }

    let (table, points) = neonms::bench::tables::width_sweep(n, reps);
    print!("{table}");
    let source = if smoke { "cargo bench (smoke mode)" } else { "cargo bench" };
    let json = neonms::bench::tables::width_sweep_json(&points, n, reps, source);
    let out = std::env::var("NEONMS_BENCH_OUT")
        .unwrap_or_else(|_| "../BENCH_width_sweep.json".to_string());
    match std::fs::write(&out, &json) {
        Ok(()) => println!("width sweep recorded to {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }

    let (table, points) = neonms::bench::tables::elem_width_sweep(n, reps);
    print!("{table}");
    let json = neonms::bench::tables::elem_width_json(&points, n, reps, source);
    let out = std::env::var("NEONMS_BENCH_ELEM_OUT")
        .unwrap_or_else(|_| "../BENCH_elem_width.json".to_string());
    match std::fs::write(&out, &json) {
        Ok(()) => println!("element-width sweep recorded to {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
