//! Bench: ablations of the design choices DESIGN.md §5 calls out —
//! column-network family, merge-kernel width, input distribution, and
//! the cooperative merge-path strategy — plus two recorded sweeps:
//! the width × K × impl sweep (`BENCH_width_sweep.json`) and the
//! element-width sweep (u32 vs u64 vs `KeyValue` pairs at each
//! register width × K, `BENCH_elem_width.json`), so the perf
//! trajectory is comparable across PRs and element widths.
//! Run via `cargo bench --bench ablations`.
//!
//! Env knobs:
//! * `NEONMS_BENCH_REPS` — repetitions per point (default 10).
//! * `NEONMS_BENCH_SMOKE=1` — CI smoke mode: small n, 2 reps, the two
//!   recorded sweeps only (the artifacts still have every point).
//! * `NEONMS_BENCH_OUT` — where to write the width-sweep JSON
//!   (default `../BENCH_width_sweep.json`, i.e. the repo root when
//!   run via `cargo bench` from `rust/`).
//! * `NEONMS_BENCH_ELEM_OUT` — where to write the element-width JSON
//!   (default `../BENCH_elem_width.json`).

use neonms::bench::report;

fn main() {
    let smoke = report::smoke_from_env();
    let reps = report::reps_from_env(if smoke { 2 } else { 10 });
    let n = if smoke { 1 << 16 } else { 1 << 20 };

    if !smoke {
        print!("{}", neonms::bench::tables::table1());
        println!();
        print!("{}", neonms::bench::tables::ablation_column_network(n, reps));
        println!();
        print!("{}", neonms::bench::tables::ablation_merge_width(n, reps));
        println!();
        print!("{}", neonms::bench::tables::ablation_workloads(n, reps));
        println!();
        print!("{}", neonms::bench::tables::ablation_parallel_merge(4 << 20, 4, reps.min(5)));
        println!();
    }

    let source = report::source_label(smoke);
    let (table, points) = neonms::bench::tables::width_sweep(n, reps);
    print!("{table}");
    let sweep = neonms::bench::tables::width_sweep_report(&points, n, reps, source, smoke);
    report::write_report(&sweep, "NEONMS_BENCH_OUT", "../BENCH_width_sweep.json");

    let (table, points) = neonms::bench::tables::elem_width_sweep(n, reps);
    print!("{table}");
    let elem = neonms::bench::tables::elem_width_report(&points, n, reps, source, smoke);
    report::write_report(&elem, "NEONMS_BENCH_ELEM_OUT", "../BENCH_elem_width.json");
}
