//! Bench: ablations of the design choices DESIGN.md §5 calls out —
//! column-network family, merge-kernel width, input distribution, and
//! the cooperative merge-path strategy — plus the width × K × impl
//! sweep, whose results are recorded to `BENCH_width_sweep.json` so
//! the perf trajectory is comparable across PRs.
//! Run via `cargo bench --bench ablations`.
//!
//! Env knobs:
//! * `NEONMS_BENCH_REPS` — repetitions per point (default 10).
//! * `NEONMS_BENCH_SMOKE=1` — CI smoke mode: small n, 2 reps, width
//!   sweep only (the recorded artifact still has every point).
//! * `NEONMS_BENCH_OUT` — where to write the sweep JSON (default
//!   `../BENCH_width_sweep.json`, i.e. the repo root when run via
//!   `cargo bench` from `rust/`).

fn main() {
    let smoke = std::env::var("NEONMS_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let reps = std::env::var("NEONMS_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke { 2 } else { 10 });
    let n = if smoke { 1 << 16 } else { 1 << 20 };

    if !smoke {
        print!("{}", neonms::bench::tables::table1());
        println!();
        print!("{}", neonms::bench::tables::ablation_column_network(n, reps));
        println!();
        print!("{}", neonms::bench::tables::ablation_merge_width(n, reps));
        println!();
        print!("{}", neonms::bench::tables::ablation_workloads(n, reps));
        println!();
        print!("{}", neonms::bench::tables::ablation_parallel_merge(4 << 20, 4, reps.min(5)));
        println!();
    }

    let (table, points) = neonms::bench::tables::width_sweep(n, reps);
    print!("{table}");
    let source = if smoke { "cargo bench (smoke mode)" } else { "cargo bench" };
    let json = neonms::bench::tables::width_sweep_json(&points, n, reps, source);
    let out = std::env::var("NEONMS_BENCH_OUT")
        .unwrap_or_else(|_| "../BENCH_width_sweep.json".to_string());
    match std::fs::write(&out, &json) {
        Ok(()) => println!("width sweep recorded to {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
