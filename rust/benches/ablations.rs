//! Bench: ablations of the design choices DESIGN.md §5 calls out —
//! column-network family, merge-kernel width, input distribution, and
//! the cooperative merge-path strategy.
//! Run via `cargo bench --bench ablations`.

fn main() {
    let reps = std::env::var("NEONMS_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10);
    let n = 1 << 20;
    print!("{}", neonms::bench::tables::table1());
    println!();
    print!("{}", neonms::bench::tables::ablation_column_network(n, reps));
    println!();
    print!("{}", neonms::bench::tables::ablation_merge_width(n, reps));
    println!();
    print!("{}", neonms::bench::tables::ablation_workloads(n, reps));
    println!();
    print!("{}", neonms::bench::tables::ablation_parallel_merge(4 << 20, 4, reps.min(5)));
}
