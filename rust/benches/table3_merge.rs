//! Bench: paper Table 3 — merging speed (elements/µs) of the
//! vectorized vs hybrid bitonic mergers at 2×{8,16,32}.
//! Run via `cargo bench --bench table3_merge`.
//!
//! Env knobs (shared bench conventions):
//! * `NEONMS_BENCH_SMOKE=1` — CI smoke mode (5 reps).
//! * `NEONMS_BENCH_REPS` — repetitions (default 50, smoke 5).
//! * `NEONMS_BENCH_OUT` — `BenchReport` artifact path (default
//!   `../BENCH_table3_merge.json`, the repo root when run via
//!   `cargo bench` from `rust/`).

use neonms::bench::report::{self, slug, BenchReport, Better, SourceKind};

fn main() {
    let smoke = report::smoke_from_env();
    let reps = report::reps_from_env(if smoke { 5 } else { 50 });
    let (text, rows) = neonms::bench::tables::table3(reps);
    print!("{text}");

    let source = report::source_label(smoke);
    let mut r = BenchReport::new("table3_merge", source, SourceKind::Native, smoke);
    r.param("reps", reps as f64);
    for (name, k, v) in &rows {
        let key = format!("elems_per_us/{}/k{k}", slug(name));
        r.metric(key, report::round_dp(*v, 1), "elems/us", Better::Higher);
    }

    // Paper shape check: report the hybrid/vectorized ratio per width.
    println!("\nhybrid / vectorized speed ratio (paper: >1 at 8 and 16, <1 at 32):");
    for k in [8usize, 16, 32] {
        let get = |name: &str| {
            rows.iter().find(|(n, kk, _)| n == name && *kk == k).map(|(_, _, v)| *v).unwrap()
        };
        let ratio = get("Hybrid Bitonic") / get("Vectorized Bitonic");
        println!("  2x{k:2}: {ratio:.3}");
        // The sign of (ratio - 1) is the paper's claim; the magnitude
        // is host noise, so the ratio rides as info.
        r.metric(
            format!("hybrid_over_vectorized/k{k}"),
            report::round_dp(ratio, 3),
            "ratio",
            Better::Info,
        );
    }
    report::write_report(&r, "NEONMS_BENCH_OUT", "../BENCH_table3_merge.json");
}
