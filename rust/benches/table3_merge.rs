//! Bench: paper Table 3 — merging speed (elements/µs) of the
//! vectorized vs hybrid bitonic mergers at 2×{8,16,32}.
//! Run via `cargo bench --bench table3_merge`.

fn main() {
    let reps = std::env::var("NEONMS_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(50);
    let (text, rows) = neonms::bench::tables::table3(reps);
    print!("{text}");
    // Paper shape check: report the hybrid/vectorized ratio per width.
    println!("\nhybrid / vectorized speed ratio (paper: >1 at 8 and 16, <1 at 32):");
    for k in [8usize, 16, 32] {
        let get = |name: &str| {
            rows.iter().find(|(n, kk, _)| n == name && *kk == k).map(|(_, _, v)| *v).unwrap()
        };
        println!("  2x{k:2}: {:.3}", get("Hybrid Bitonic") / get("Vectorized Bitonic"));
    }
}
