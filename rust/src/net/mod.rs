//! Network ingress: the TCP wire protocol in front of
//! [`crate::coordinator::SortService`].
//!
//! Until PR 10 every request entered through an in-process
//! `SortService::client(name)` call; this module is the process
//! boundary the ROADMAP's "millions of users" goal needs. It has
//! three layers, each usable on its own:
//!
//! * [`codec`] — the pure, I/O-free frame grammar: length-prefixed
//!   binary frames (`HELLO`/`SUBMIT`/`POLL`/`CANCEL`/`METRICS`/
//!   `SHUTDOWN` and their responses), element-kind-tagged payloads
//!   for all three [`crate::coordinator::ElemKind`]s, hand-rolled
//!   with no new dependencies and hardened against adversarial
//!   bytes (bound-before-allocate, typed [`ProtocolError`]s, no
//!   panics).
//! * [`stream`] — frame ↔ byte-stream adaptation: [`FrameReader`]
//!   reassembles frames split across arbitrary read boundaries.
//! * [`server`] / [`client`] — the thread-per-connection
//!   [`NetServer`] mapping connections onto
//!   [`crate::coordinator::SortClient`]s (HELLO carries the tenant
//!   name + [`crate::coordinator::ClientConfig`] knobs), and the
//!   synchronous [`WireClient`] used by `neonms-loadgen` and the
//!   e2e tests.
//!
//! The design rule throughout: **backpressure is surfaced, never
//! dropped** — a shed submit crosses the wire as `RETRY_AFTER` with
//! the same reason and hint the in-process
//! [`crate::coordinator::BusyReason`] carries — and **every error
//! path resolves the handle or answers the frame**, so a protocol
//! error can never wedge a worker or leak a QoS charge (teardown
//! rides the coordinator's drop-to-cancel semantics).

pub mod codec;
pub mod stream;

mod client;
mod server;

pub use client::{NetError, PollOutcome, SubmitOutcome, WireClient};
pub use codec::{
    ProtocolError, Request, Response, WireBusyReason, WireMetrics, WireSortError, WireTenant,
    MAX_FRAME_BYTES,
};
pub use server::NetServer;
pub use stream::{FrameReader, NextFrame, StreamError};

#[cfg(test)]
mod tests;
