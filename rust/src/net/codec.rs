//! The wire frame codec: the length-prefixed binary grammar both ends
//! of the TCP protocol speak, hand-rolled byte-by-byte in the same
//! offline-friendly spirit as the [`crate::bench::report`] JSON layer
//! — no serde, no framing crate, every error a typed
//! [`ProtocolError`].
//!
//! # Frame grammar
//!
//! ```text
//! frame    := len:u32le body            (len = body byte count,
//!                                        bound: MAX_FRAME_BYTES)
//! body     := opcode:u8 fields...
//! str16    := n:u16le utf8[n]
//! payload  := kind:u8 count:u32le elem[count]
//!               kind 0 = u32 (4-byte le), 1 = u64 (8-byte le),
//!               2 = pair (packed key|payload u64, 8-byte le)
//! ```
//!
//! Requests (client → server): `HELLO(tenant:str16, weight:u32,
//! burst:u64)`, `SUBMIT(id:u64, payload)`, `POLL(id:u64)`,
//! `CANCEL(id:u64)`, `METRICS`, `SHUTDOWN`. Responses (server →
//! client): `HELLO_OK(weight, burst)`, `ACCEPTED(id)`,
//! `RETRY_AFTER(id, reason:u8, hint_us:u64)`, `PENDING(id)`,
//! `DONE(id, payload)`, `FAILED(id, code:u8)`, `CANCEL_OK(id)`,
//! `METRICS_OK(counters, tenants)`, `SHUTDOWN_OK`,
//! `PROTO_ERROR(msg:str16)`.
//!
//! # Hardening contract
//!
//! The decoder is written to face adversarial bytes (the vqsort
//! lesson applied to the wire): a declared length beyond
//! [`MAX_FRAME_BYTES`] is rejected from the 4-byte header alone —
//! before any body is buffered or allocated — and a payload count is
//! checked against the bytes actually present in the frame before the
//! element vector is reserved, so a forged `count` cannot make the
//! server allocate memory the frame never carried. Incomplete input
//! is never an error (decode returns `None` until the frame is whole,
//! which is what makes split-across-read delivery transparent);
//! malformed input is always an error and never a panic.

use crate::coordinator::{ElemBuf, ElemKind, SortError};
use crate::simd::KeyValue;
use std::time::Duration;

/// Hard bound on one frame's body, enforced on both encode and decode
/// (16 MiB — a 4 Mi-element `u32` sort; larger keysets belong to the
/// planned out-of-core tier, not a single wire frame).
pub const MAX_FRAME_BYTES: usize = 1 << 24;

// Request opcodes.
const OP_HELLO: u8 = 0x01;
const OP_SUBMIT: u8 = 0x02;
const OP_POLL: u8 = 0x03;
const OP_CANCEL: u8 = 0x04;
const OP_METRICS: u8 = 0x05;
const OP_SHUTDOWN: u8 = 0x06;

// Response opcodes (request opcode | 0x80 where one-to-one).
const OP_HELLO_OK: u8 = 0x81;
const OP_ACCEPTED: u8 = 0x82;
const OP_RETRY_AFTER: u8 = 0x83;
const OP_PENDING: u8 = 0x84;
const OP_DONE: u8 = 0x85;
const OP_FAILED: u8 = 0x86;
const OP_CANCEL_OK: u8 = 0x87;
const OP_METRICS_OK: u8 = 0x88;
const OP_SHUTDOWN_OK: u8 = 0x89;
const OP_PROTO_ERROR: u8 = 0x8A;

/// Why a byte sequence is not a valid frame. Every variant is a
/// *typed* decode (or encode-bound) failure — the codec never panics
/// on wire input and never reports malformed bytes as anything but
/// one of these.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ProtocolError {
    /// The header declared a body larger than [`MAX_FRAME_BYTES`].
    /// Raised from the 4 header bytes alone, before any body is
    /// buffered — the pre-allocation rejection rule.
    Oversized { declared: usize, max: usize },
    /// The body's first byte is not a known opcode.
    UnknownOpcode(u8),
    /// A payload carried an element-kind tag outside `0..=2`.
    UnknownElemKind(u8),
    /// A `RETRY_AFTER` carried a reason code outside `0..=2`.
    UnknownReason(u8),
    /// A `FAILED` carried an error code with no [`SortError`] mapping.
    UnknownErrorCode(u8),
    /// The body ended before the named field was complete.
    Truncated { what: &'static str },
    /// A payload declared more elements than the frame has bytes for
    /// (checked before allocating the element vector).
    PayloadTruncated { declared_elements: usize, available_bytes: usize },
    /// The body continued past the last field of its opcode.
    TrailingBytes { extra: usize },
    /// A `str16` field was not valid UTF-8.
    BadUtf8,
    /// Encode-side bound: a string or list exceeds its length-prefix
    /// range (or a payload exceeds [`MAX_FRAME_BYTES`]).
    TooLong { what: &'static str, len: usize },
    /// The peer closed the connection with a partial frame buffered
    /// (stream-level truncation, surfaced by the frame reader).
    ClosedMidFrame { buffered: usize },
}

impl std::fmt::Display for ProtocolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtocolError::Oversized { declared, max } => {
                write!(f, "frame declares {declared} body bytes, bound is {max}")
            }
            ProtocolError::UnknownOpcode(op) => write!(f, "unknown opcode 0x{op:02x}"),
            ProtocolError::UnknownElemKind(k) => write!(f, "unknown element kind {k}"),
            ProtocolError::UnknownReason(r) => write!(f, "unknown retry-after reason {r}"),
            ProtocolError::UnknownErrorCode(c) => write!(f, "unknown sort-error code {c}"),
            ProtocolError::Truncated { what } => {
                write!(f, "frame body ends inside field \"{what}\"")
            }
            ProtocolError::PayloadTruncated { declared_elements, available_bytes } => write!(
                f,
                "payload declares {declared_elements} elements but only \
                 {available_bytes} bytes follow"
            ),
            ProtocolError::TrailingBytes { extra } => {
                write!(f, "{extra} trailing bytes after the last field")
            }
            ProtocolError::BadUtf8 => f.write_str("string field is not valid UTF-8"),
            ProtocolError::TooLong { what, len } => {
                write!(f, "{what} of length {len} exceeds its wire bound")
            }
            ProtocolError::ClosedMidFrame { buffered } => {
                write!(f, "connection closed with {buffered} bytes of a partial frame")
            }
        }
    }
}

impl std::error::Error for ProtocolError {}

/// A client → server frame.
#[derive(Debug, PartialEq)]
pub enum Request {
    /// Connection handshake: names the tenant this connection accounts
    /// to and carries its fair-share [`crate::coordinator::ClientConfig`]
    /// knobs (weight + burst bytes). Must precede `Submit`.
    Hello { tenant: String, weight: u32, burst: u64 },
    /// Submit a payload under a connection-chosen request id.
    Submit { id: u64, data: ElemBuf },
    /// Ask whether request `id` has resolved (non-blocking on both
    /// ends; the server answers `Pending`, `Done`, or `Failed`).
    Poll { id: u64 },
    /// Drop request `id` — the wire form of dropping a
    /// [`crate::coordinator::SortHandle`] (drop-to-cancel).
    Cancel { id: u64 },
    /// Request a [`WireMetrics`] snapshot.
    Metrics,
    /// Ask the server process to stop accepting and drain.
    Shutdown,
}

/// Why a submit was shed — [`crate::coordinator::BusyReason`] with the
/// hint lifted out (it rides the `RETRY_AFTER` frame separately).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireBusyReason {
    /// Transient: every shard at capacity; retry after the hint.
    QueueFull,
    /// Transient, self-inflicted: this tenant is the most over its
    /// fair share; back off by the hint.
    OverShare,
    /// Permanent: the service shut down; stop retrying.
    Shutdown,
}

impl WireBusyReason {
    fn code(self) -> u8 {
        match self {
            WireBusyReason::QueueFull => 0,
            WireBusyReason::OverShare => 1,
            WireBusyReason::Shutdown => 2,
        }
    }

    fn from_code(code: u8) -> Result<WireBusyReason, ProtocolError> {
        match code {
            0 => Ok(WireBusyReason::QueueFull),
            1 => Ok(WireBusyReason::OverShare),
            2 => Ok(WireBusyReason::Shutdown),
            other => Err(ProtocolError::UnknownReason(other)),
        }
    }

    /// True for the reasons worth retrying (mirrors
    /// [`crate::coordinator::BusyReason::retry_after`] being `Some`).
    pub fn retryable(self) -> bool {
        !matches!(self, WireBusyReason::Shutdown)
    }
}

impl From<&crate::coordinator::BusyReason> for WireBusyReason {
    fn from(r: &crate::coordinator::BusyReason) -> WireBusyReason {
        use crate::coordinator::BusyReason;
        match r {
            BusyReason::QueueFull { .. } => WireBusyReason::QueueFull,
            BusyReason::OverShare { .. } => WireBusyReason::OverShare,
            BusyReason::Shutdown => WireBusyReason::Shutdown,
        }
    }
}

/// [`SortError`] as a stable one-byte wire code (a `FAILED` frame).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WireSortError(SortError);

impl WireSortError {
    fn code(self) -> u8 {
        match self.0 {
            SortError::Shutdown => 0,
            SortError::Evicted => 1,
            SortError::JobPanicked => 2,
            SortError::DeadlineExceeded => 3,
            SortError::Quarantined => 4,
            SortError::AlreadyTaken => 5,
        }
    }

    fn from_code(code: u8) -> Result<WireSortError, ProtocolError> {
        Ok(WireSortError(match code {
            0 => SortError::Shutdown,
            1 => SortError::Evicted,
            2 => SortError::JobPanicked,
            3 => SortError::DeadlineExceeded,
            4 => SortError::Quarantined,
            5 => SortError::AlreadyTaken,
            other => return Err(ProtocolError::UnknownErrorCode(other)),
        }))
    }

    /// The decoded [`SortError`] this code names.
    pub fn error(self) -> SortError {
        self.0
    }
}

impl From<SortError> for WireSortError {
    fn from(e: SortError) -> WireSortError {
        WireSortError(e)
    }
}

/// One tenant's row in a [`WireMetrics`] snapshot — the counters the
/// per-tenant accounting identity (`accepted == completed + cancelled
/// + failed`, `in_flight_bytes == 0` at quiesce) is checked from
/// across the wire.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WireTenant {
    pub name: String,
    pub accepted: u64,
    pub completed: u64,
    pub cancelled: u64,
    pub failed: u64,
    pub in_flight_bytes: u64,
    pub queued_jobs: u64,
}

/// The `METRICS_OK` body: the service-wide counters remote operators
/// and the load generator gate on, plus one [`WireTenant`] row per
/// registered tenant. A subset of
/// [`crate::coordinator::MetricsSnapshot`] — gauges that only make
/// sense in-process (shard depths, route observations) stay local.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct WireMetrics {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub cancelled: u64,
    pub failed: u64,
    pub quarantined: u64,
    /// Live wire connections (opened − closed).
    pub connections_open: u64,
    /// Wire connections accepted since startup.
    pub connections_opened: u64,
    /// Frames served (every decoded request, any opcode).
    pub net_frames: u64,
    /// `RETRY_AFTER` responses sent (backpressure surfaced, not
    /// connections dropped).
    pub net_retry_after: u64,
    /// Connections torn down for stream-level protocol errors.
    pub net_protocol_errors: u64,
    pub tenants: Vec<WireTenant>,
}

/// A server → client frame.
#[derive(Debug, PartialEq)]
pub enum Response {
    /// Handshake accepted; echoes the fair-share config now in force
    /// (the service clamps, e.g. weight 0 → 1).
    HelloOk { weight: u32, burst: u64 },
    /// Submit admitted; poll `id` for the result.
    Accepted { id: u64 },
    /// Submit shed with backpressure instead of a dropped connection:
    /// retry (or stop, on [`WireBusyReason::Shutdown`]) after `hint`.
    RetryAfter { id: u64, reason: WireBusyReason, hint: Duration },
    /// Request `id` is still in flight.
    Pending { id: u64 },
    /// Request `id` resolved: the sorted payload.
    Done { id: u64, data: ElemBuf },
    /// Request `id` resolved to a typed error.
    Failed { id: u64, error: WireSortError },
    /// Cancel acknowledged (idempotent — unknown ids ack too).
    CancelOk { id: u64 },
    /// The requested metrics snapshot.
    Metrics(WireMetrics),
    /// Server shutdown acknowledged; the connection closes next.
    ShutdownOk,
    /// The request could not be honored as protocol: either a
    /// semantic error answering one well-formed frame (`SUBMIT`
    /// before `HELLO`, reused id) or — when the byte stream itself
    /// desynced — the connection's parting diagnostic before close.
    ProtoError { message: String },
}

// ---------------------------------------------------------------- encode

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str16(out: &mut Vec<u8>, s: &str, what: &'static str) -> Result<(), ProtocolError> {
    let n = u16::try_from(s.len())
        .map_err(|_| ProtocolError::TooLong { what, len: s.len() })?;
    put_u16(out, n);
    out.extend_from_slice(s.as_bytes());
    Ok(())
}

fn kind_code(kind: ElemKind) -> u8 {
    match kind {
        ElemKind::U32 => 0,
        ElemKind::U64 => 1,
        ElemKind::Pair => 2,
    }
}

fn kind_from_code(code: u8) -> Result<ElemKind, ProtocolError> {
    match code {
        0 => Ok(ElemKind::U32),
        1 => Ok(ElemKind::U64),
        2 => Ok(ElemKind::Pair),
        other => Err(ProtocolError::UnknownElemKind(other)),
    }
}

fn put_payload(out: &mut Vec<u8>, data: &ElemBuf) -> Result<(), ProtocolError> {
    let count = u32::try_from(data.len())
        .map_err(|_| ProtocolError::TooLong { what: "element payload", len: data.len() })?;
    out.push(kind_code(data.kind()));
    put_u32(out, count);
    match data {
        ElemBuf::U32(v) => {
            for x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        ElemBuf::U64(v) => {
            for x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        ElemBuf::Pair(v) => {
            for x in v {
                out.extend_from_slice(&x.packed().to_le_bytes());
            }
        }
    }
    Ok(())
}

/// Prepend the length prefix, enforcing the frame bound symmetrically
/// with decode — an encoder cannot produce a frame its own decoder
/// would refuse.
fn seal(body: Vec<u8>) -> Result<Vec<u8>, ProtocolError> {
    if body.len() > MAX_FRAME_BYTES {
        return Err(ProtocolError::Oversized { declared: body.len(), max: MAX_FRAME_BYTES });
    }
    let mut out = Vec::with_capacity(4 + body.len());
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(&body);
    Ok(out)
}

/// Encode one request as a complete frame (length prefix included).
pub fn encode_request(req: &Request) -> Result<Vec<u8>, ProtocolError> {
    let mut b = Vec::new();
    match req {
        Request::Hello { tenant, weight, burst } => {
            b.push(OP_HELLO);
            put_str16(&mut b, tenant, "tenant name")?;
            put_u32(&mut b, *weight);
            put_u64(&mut b, *burst);
        }
        Request::Submit { id, data } => {
            b.push(OP_SUBMIT);
            put_u64(&mut b, *id);
            put_payload(&mut b, data)?;
        }
        Request::Poll { id } => {
            b.push(OP_POLL);
            put_u64(&mut b, *id);
        }
        Request::Cancel { id } => {
            b.push(OP_CANCEL);
            put_u64(&mut b, *id);
        }
        Request::Metrics => b.push(OP_METRICS),
        Request::Shutdown => b.push(OP_SHUTDOWN),
    }
    seal(b)
}

/// Encode one response as a complete frame (length prefix included).
pub fn encode_response(resp: &Response) -> Result<Vec<u8>, ProtocolError> {
    let mut b = Vec::new();
    match resp {
        Response::HelloOk { weight, burst } => {
            b.push(OP_HELLO_OK);
            put_u32(&mut b, *weight);
            put_u64(&mut b, *burst);
        }
        Response::Accepted { id } => {
            b.push(OP_ACCEPTED);
            put_u64(&mut b, *id);
        }
        Response::RetryAfter { id, reason, hint } => {
            b.push(OP_RETRY_AFTER);
            put_u64(&mut b, *id);
            b.push(reason.code());
            put_u64(&mut b, hint.as_micros().min(u128::from(u64::MAX)) as u64);
        }
        Response::Pending { id } => {
            b.push(OP_PENDING);
            put_u64(&mut b, *id);
        }
        Response::Done { id, data } => {
            b.push(OP_DONE);
            put_u64(&mut b, *id);
            put_payload(&mut b, data)?;
        }
        Response::Failed { id, error } => {
            b.push(OP_FAILED);
            put_u64(&mut b, *id);
            b.push(error.code());
        }
        Response::CancelOk { id } => {
            b.push(OP_CANCEL_OK);
            put_u64(&mut b, *id);
        }
        Response::Metrics(m) => {
            b.push(OP_METRICS_OK);
            for v in [
                m.submitted,
                m.completed,
                m.rejected,
                m.cancelled,
                m.failed,
                m.quarantined,
                m.connections_open,
                m.connections_opened,
                m.net_frames,
                m.net_retry_after,
                m.net_protocol_errors,
            ] {
                put_u64(&mut b, v);
            }
            let n = u16::try_from(m.tenants.len()).map_err(|_| ProtocolError::TooLong {
                what: "tenant list",
                len: m.tenants.len(),
            })?;
            put_u16(&mut b, n);
            for t in &m.tenants {
                put_str16(&mut b, &t.name, "tenant name")?;
                for v in [
                    t.accepted,
                    t.completed,
                    t.cancelled,
                    t.failed,
                    t.in_flight_bytes,
                    t.queued_jobs,
                ] {
                    put_u64(&mut b, v);
                }
            }
        }
        Response::ShutdownOk => b.push(OP_SHUTDOWN_OK),
        Response::ProtoError { message } => {
            b.push(OP_PROTO_ERROR);
            // Diagnostics are best-effort: clip (on a char boundary)
            // rather than fail the error path itself.
            let mut clipped = message.as_str();
            if clipped.len() > 512 {
                let mut end = 512;
                while !clipped.is_char_boundary(end) {
                    end -= 1;
                }
                clipped = &clipped[..end];
            }
            put_str16(&mut b, clipped, "error message")?;
        }
    }
    seal(b)
}

// ---------------------------------------------------------------- decode

/// Byte-indexed body reader; every short read names the field it died
/// in, mirroring the positioned errors of the bench-report parser.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], ProtocolError> {
        if self.remaining() < n {
            return Err(ProtocolError::Truncated { what });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &'static str) -> Result<u8, ProtocolError> {
        Ok(self.take(1, what)?[0])
    }

    fn u16(&mut self, what: &'static str) -> Result<u16, ProtocolError> {
        Ok(u16::from_le_bytes(self.take(2, what)?.try_into().expect("2-byte slice")))
    }

    fn u32(&mut self, what: &'static str) -> Result<u32, ProtocolError> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().expect("4-byte slice")))
    }

    fn u64(&mut self, what: &'static str) -> Result<u64, ProtocolError> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().expect("8-byte slice")))
    }

    fn str16(&mut self, what: &'static str) -> Result<String, ProtocolError> {
        let n = usize::from(self.u16(what)?);
        let bytes = self.take(n, what)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| ProtocolError::BadUtf8)
    }

    fn payload(&mut self) -> Result<ElemBuf, ProtocolError> {
        let kind = kind_from_code(self.u8("element kind")?)?;
        let count = self.u32("element count")? as usize;
        // Bound-before-allocate: the element vector is only reserved
        // once the frame demonstrably carries `count` elements.
        let need = count.checked_mul(kind.bytes()).unwrap_or(usize::MAX);
        if need > self.remaining() {
            return Err(ProtocolError::PayloadTruncated {
                declared_elements: count,
                available_bytes: self.remaining(),
            });
        }
        let bytes = self.take(need, "element payload")?;
        Ok(match kind {
            ElemKind::U32 => ElemBuf::U32(
                bytes
                    .chunks_exact(4)
                    .map(|c| u32::from_le_bytes(c.try_into().expect("4-byte chunk")))
                    .collect(),
            ),
            ElemKind::U64 => ElemBuf::U64(
                bytes
                    .chunks_exact(8)
                    .map(|c| u64::from_le_bytes(c.try_into().expect("8-byte chunk")))
                    .collect(),
            ),
            ElemKind::Pair => ElemBuf::Pair(
                bytes
                    .chunks_exact(8)
                    .map(|c| {
                        KeyValue::from_packed(u64::from_le_bytes(
                            c.try_into().expect("8-byte chunk"),
                        ))
                    })
                    .collect(),
            ),
        })
    }

    fn finish(self) -> Result<(), ProtocolError> {
        if self.remaining() > 0 {
            return Err(ProtocolError::TrailingBytes { extra: self.remaining() });
        }
        Ok(())
    }
}

/// Split the next frame's body off `buf`. `Ok(None)` means the bytes
/// so far are a valid *prefix* — read more. The oversize check fires
/// from the header alone, before the body exists anywhere.
fn frame_body(buf: &[u8]) -> Result<Option<(&[u8], usize)>, ProtocolError> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let len = u32::from_le_bytes(buf[..4].try_into().expect("4-byte slice")) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(ProtocolError::Oversized { declared: len, max: MAX_FRAME_BYTES });
    }
    if buf.len() < 4 + len {
        return Ok(None);
    }
    Ok(Some((&buf[4..4 + len], 4 + len)))
}

/// Decode one request from the front of `buf`. Returns the frame and
/// the bytes consumed, `Ok(None)` while the frame is still incomplete
/// (split-across-read tolerant), or a typed [`ProtocolError`].
pub fn decode_request(buf: &[u8]) -> Result<Option<(Request, usize)>, ProtocolError> {
    let Some((body, used)) = frame_body(buf)? else {
        return Ok(None);
    };
    let mut c = Cursor::new(body);
    let req = match c.u8("opcode")? {
        OP_HELLO => Request::Hello {
            tenant: c.str16("tenant name")?,
            weight: c.u32("weight")?,
            burst: c.u64("burst")?,
        },
        OP_SUBMIT => Request::Submit { id: c.u64("request id")?, data: c.payload()? },
        OP_POLL => Request::Poll { id: c.u64("request id")? },
        OP_CANCEL => Request::Cancel { id: c.u64("request id")? },
        OP_METRICS => Request::Metrics,
        OP_SHUTDOWN => Request::Shutdown,
        other => return Err(ProtocolError::UnknownOpcode(other)),
    };
    c.finish()?;
    Ok(Some((req, used)))
}

/// Decode one response from the front of `buf` (same contract as
/// [`decode_request`]).
pub fn decode_response(buf: &[u8]) -> Result<Option<(Response, usize)>, ProtocolError> {
    let Some((body, used)) = frame_body(buf)? else {
        return Ok(None);
    };
    let mut c = Cursor::new(body);
    let resp = match c.u8("opcode")? {
        OP_HELLO_OK => Response::HelloOk { weight: c.u32("weight")?, burst: c.u64("burst")? },
        OP_ACCEPTED => Response::Accepted { id: c.u64("request id")? },
        OP_RETRY_AFTER => Response::RetryAfter {
            id: c.u64("request id")?,
            reason: WireBusyReason::from_code(c.u8("reason")?)?,
            hint: Duration::from_micros(c.u64("retry-after hint")?),
        },
        OP_PENDING => Response::Pending { id: c.u64("request id")? },
        OP_DONE => Response::Done { id: c.u64("request id")?, data: c.payload()? },
        OP_FAILED => Response::Failed {
            id: c.u64("request id")?,
            error: WireSortError::from_code(c.u8("error code")?)?,
        },
        OP_CANCEL_OK => Response::CancelOk { id: c.u64("request id")? },
        OP_METRICS_OK => {
            let mut m = WireMetrics {
                submitted: c.u64("submitted")?,
                completed: c.u64("completed")?,
                rejected: c.u64("rejected")?,
                cancelled: c.u64("cancelled")?,
                failed: c.u64("failed")?,
                quarantined: c.u64("quarantined")?,
                connections_open: c.u64("connections_open")?,
                connections_opened: c.u64("connections_opened")?,
                net_frames: c.u64("net_frames")?,
                net_retry_after: c.u64("net_retry_after")?,
                net_protocol_errors: c.u64("net_protocol_errors")?,
                tenants: Vec::new(),
            };
            let n = usize::from(c.u16("tenant count")?);
            // Bound-before-allocate, list edition: 6 u64s + a str16
            // header per row is the floor, so a forged count beyond
            // the body's own bytes is refused without reserving.
            if n.saturating_mul(50) > c.remaining() {
                return Err(ProtocolError::PayloadTruncated {
                    declared_elements: n,
                    available_bytes: c.remaining(),
                });
            }
            m.tenants.reserve(n);
            for _ in 0..n {
                m.tenants.push(WireTenant {
                    name: c.str16("tenant name")?,
                    accepted: c.u64("accepted")?,
                    completed: c.u64("completed")?,
                    cancelled: c.u64("cancelled")?,
                    failed: c.u64("failed")?,
                    in_flight_bytes: c.u64("in_flight_bytes")?,
                    queued_jobs: c.u64("queued_jobs")?,
                });
            }
            Response::Metrics(m)
        }
        OP_SHUTDOWN_OK => Response::ShutdownOk,
        OP_PROTO_ERROR => Response::ProtoError { message: c.str16("error message")? },
        other => return Err(ProtocolError::UnknownOpcode(other)),
    };
    c.finish()?;
    Ok(Some((resp, used)))
}
