//! Frame transport over a byte stream: an accumulation buffer that
//! turns arbitrary `Read` chunking back into whole frames, and the
//! matching write helper.
//!
//! TCP does not respect frame boundaries — one frame may arrive split
//! across many reads, and one read may deliver several frames. The
//! [`FrameReader`] owns that impedance match: it buffers bytes until
//! the codec reports a complete frame, hands back exactly one frame
//! per call, and keeps any surplus for the next call. Errors stay
//! typed all the way up: a malformed byte sequence is a
//! [`ProtocolError`] (via [`StreamError::Protocol`]) and an I/O fault
//! is [`StreamError::Io`] — the caller never has to parse strings to
//! tell them apart.

use super::codec::{self, ProtocolError, Request, Response};
use std::io::{self, Read, Write};

/// Read chunk size; small enough to keep per-connection memory modest,
/// large enough that a 16 MiB max frame arrives in ~2k reads.
const READ_CHUNK: usize = 8 * 1024;

/// What one blocking read-next-frame call produced.
#[derive(Debug)]
pub enum NextFrame<T> {
    /// A complete, well-formed frame.
    Frame(T),
    /// The peer closed the stream on a frame boundary (clean EOF).
    Closed,
    /// The read timed out (the socket has a read timeout configured)
    /// with no complete frame yet; the caller can check its stop flag
    /// and come back.
    TimedOut,
}

/// A stream-level failure: either the bytes were wrong or the
/// transport was.
#[derive(Debug)]
pub enum StreamError {
    /// The byte stream is not a valid frame sequence. The connection
    /// is desynchronized and must be closed — frame boundaries cannot
    /// be recovered from arbitrary garbage.
    Protocol(ProtocolError),
    /// The transport failed underneath the protocol.
    Io(io::Error),
}

impl std::fmt::Display for StreamError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StreamError::Protocol(e) => write!(f, "protocol error: {e}"),
            StreamError::Io(e) => write!(f, "i/o error: {e}"),
        }
    }
}

impl std::error::Error for StreamError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            StreamError::Protocol(e) => Some(e),
            StreamError::Io(e) => Some(e),
        }
    }
}

impl From<ProtocolError> for StreamError {
    fn from(e: ProtocolError) -> StreamError {
        StreamError::Protocol(e)
    }
}

/// Reassembles whole frames from a split-at-arbitrary-boundaries byte
/// stream. One reader per connection, reused across frames; surplus
/// bytes from an over-delivering read are retained for the next call.
#[derive(Default)]
pub struct FrameReader {
    buf: Vec<u8>,
}

impl FrameReader {
    pub fn new() -> FrameReader {
        FrameReader::default()
    }

    /// Bytes buffered but not yet consumed as a frame.
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// Read until one whole request is decodable (server side).
    pub fn next_request(
        &mut self,
        src: &mut impl Read,
    ) -> Result<NextFrame<Request>, StreamError> {
        self.next_frame(src, codec::decode_request)
    }

    /// Read until one whole response is decodable (client side).
    pub fn next_response(
        &mut self,
        src: &mut impl Read,
    ) -> Result<NextFrame<Response>, StreamError> {
        self.next_frame(src, codec::decode_response)
    }

    fn next_frame<T>(
        &mut self,
        src: &mut impl Read,
        decode: fn(&[u8]) -> Result<Option<(T, usize)>, ProtocolError>,
    ) -> Result<NextFrame<T>, StreamError> {
        loop {
            // Drain before reading: a previous read may have delivered
            // more than one frame.
            if let Some((frame, used)) = decode(&self.buf)? {
                self.buf.drain(..used);
                return Ok(NextFrame::Frame(frame));
            }
            let mut chunk = [0u8; READ_CHUNK];
            match src.read(&mut chunk) {
                Ok(0) => {
                    // EOF inside a frame is a protocol violation, not
                    // a clean close — surface it as such so the caller
                    // counts it.
                    return if self.buf.is_empty() {
                        Ok(NextFrame::Closed)
                    } else {
                        Err(StreamError::Protocol(ProtocolError::ClosedMidFrame {
                            buffered: self.buf.len(),
                        }))
                    };
                }
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e)
                    if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) =>
                {
                    return Ok(NextFrame::TimedOut);
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => return Err(StreamError::Io(e)),
            }
        }
    }
}

/// Write one already-encoded frame and flush it (frames are
/// request/response units; latency beats batching here).
pub fn write_frame(dst: &mut impl Write, frame: &[u8]) -> io::Result<()> {
    dst.write_all(frame)?;
    dst.flush()
}
