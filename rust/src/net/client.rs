//! The client side of the wire protocol: a thin, synchronous,
//! one-request-at-a-time connection used by the load generator, the
//! integration tests, and any out-of-process caller.
//!
//! A [`WireClient`] is deliberately simpler than the in-process
//! [`crate::coordinator::SortClient`]: it speaks strict
//! request/response (no pipelining), assigns its own monotonically
//! increasing request ids, and leaves retry/backoff policy to the
//! caller — a `RETRY_AFTER` is returned as data
//! ([`SubmitOutcome::RetryAfter`]), not an error, because backpressure
//! is the protocol working as designed.

use super::codec::{
    self, ProtocolError, Request, Response, WireBusyReason, WireMetrics, WireSortError,
};
use super::stream::{write_frame, FrameReader, NextFrame, StreamError};
use crate::coordinator::ElemBuf;
use std::io;
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// A client-visible failure of the wire conversation itself (as
/// opposed to a sort job failing, which arrives as data).
#[derive(Debug)]
pub enum NetError {
    /// The transport failed.
    Io(io::Error),
    /// The server's bytes were not a valid frame sequence.
    Protocol(ProtocolError),
    /// The server answered `PROTO_ERROR` — this request broke the
    /// protocol's rules (as the server sees them).
    Remote(String),
    /// The server answered with a frame type this request cannot
    /// accept (a server bug or a desynchronized conversation).
    Unexpected(&'static str),
    /// The server closed the connection before answering.
    Closed,
}

impl std::fmt::Display for NetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetError::Io(e) => write!(f, "i/o error: {e}"),
            NetError::Protocol(e) => write!(f, "protocol error: {e}"),
            NetError::Remote(msg) => write!(f, "server rejected request: {msg}"),
            NetError::Unexpected(what) => write!(f, "unexpected response frame: {what}"),
            NetError::Closed => f.write_str("connection closed by server"),
        }
    }
}

impl std::error::Error for NetError {}

impl From<io::Error> for NetError {
    fn from(e: io::Error) -> NetError {
        NetError::Io(e)
    }
}

impl From<ProtocolError> for NetError {
    fn from(e: ProtocolError) -> NetError {
        NetError::Protocol(e)
    }
}

impl From<StreamError> for NetError {
    fn from(e: StreamError) -> NetError {
        match e {
            StreamError::Protocol(p) => NetError::Protocol(p),
            StreamError::Io(io) => NetError::Io(io),
        }
    }
}

/// How a submit landed.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitOutcome {
    /// Admitted; poll `id` for the result.
    Accepted { id: u64 },
    /// Shed with backpressure; the payload was not admitted. Retry
    /// after `hint` unless the reason is terminal
    /// ([`WireBusyReason::retryable`]).
    RetryAfter { reason: WireBusyReason, hint: Duration },
}

/// How a poll landed.
#[derive(Debug, PartialEq)]
pub enum PollOutcome {
    /// Still in flight.
    Pending,
    /// Resolved: the sorted payload.
    Done(ElemBuf),
    /// Resolved to a typed sort error.
    Failed(WireSortError),
}

/// One synchronous protocol connection.
pub struct WireClient {
    stream: TcpStream,
    reader: FrameReader,
    next_id: u64,
}

impl WireClient {
    /// Connect to a server; follow with [`WireClient::hello`] before
    /// submitting.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<WireClient, NetError> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(WireClient { stream, reader: FrameReader::new(), next_id: 0 })
    }

    /// Handshake: bind this connection to `tenant` with the given
    /// fair-share knobs. Returns the `(weight, burst)` actually in
    /// force after service-side clamping.
    pub fn hello(
        &mut self,
        tenant: &str,
        weight: u32,
        burst: u64,
    ) -> Result<(u32, u64), NetError> {
        let req = Request::Hello { tenant: tenant.to_string(), weight, burst };
        match self.rpc(&req)? {
            Response::HelloOk { weight, burst } => Ok((weight, burst)),
            Response::ProtoError { message } => Err(NetError::Remote(message)),
            _ => Err(NetError::Unexpected("HELLO expects HELLO_OK")),
        }
    }

    /// Submit a payload under a fresh request id.
    pub fn submit(&mut self, data: ElemBuf) -> Result<SubmitOutcome, NetError> {
        let id = self.next_id;
        self.next_id += 1;
        match self.rpc(&Request::Submit { id, data })? {
            Response::Accepted { id: rid } if rid == id => Ok(SubmitOutcome::Accepted { id }),
            Response::RetryAfter { id: rid, reason, hint } if rid == id => {
                Ok(SubmitOutcome::RetryAfter { reason, hint })
            }
            Response::ProtoError { message } => Err(NetError::Remote(message)),
            _ => Err(NetError::Unexpected("SUBMIT expects ACCEPTED or RETRY_AFTER")),
        }
    }

    /// Ask once whether request `id` resolved.
    pub fn poll(&mut self, id: u64) -> Result<PollOutcome, NetError> {
        match self.rpc(&Request::Poll { id })? {
            Response::Pending { id: rid } if rid == id => Ok(PollOutcome::Pending),
            Response::Done { id: rid, data } if rid == id => Ok(PollOutcome::Done(data)),
            Response::Failed { id: rid, error } if rid == id => Ok(PollOutcome::Failed(error)),
            Response::ProtoError { message } => Err(NetError::Remote(message)),
            _ => Err(NetError::Unexpected("POLL expects PENDING, DONE, or FAILED")),
        }
    }

    /// Poll `id` until it resolves, sleeping briefly between rounds.
    pub fn wait(&mut self, id: u64) -> Result<Result<ElemBuf, WireSortError>, NetError> {
        loop {
            match self.poll(id)? {
                PollOutcome::Pending => std::thread::sleep(Duration::from_micros(300)),
                PollOutcome::Done(data) => return Ok(Ok(data)),
                PollOutcome::Failed(e) => return Ok(Err(e)),
            }
        }
    }

    /// Cancel request `id` (idempotent; acks even if already resolved
    /// or unknown).
    pub fn cancel(&mut self, id: u64) -> Result<(), NetError> {
        match self.rpc(&Request::Cancel { id })? {
            Response::CancelOk { id: rid } if rid == id => Ok(()),
            Response::ProtoError { message } => Err(NetError::Remote(message)),
            _ => Err(NetError::Unexpected("CANCEL expects CANCEL_OK")),
        }
    }

    /// Fetch the server's metrics snapshot.
    pub fn metrics(&mut self) -> Result<WireMetrics, NetError> {
        match self.rpc(&Request::Metrics)? {
            Response::Metrics(m) => Ok(m),
            Response::ProtoError { message } => Err(NetError::Remote(message)),
            _ => Err(NetError::Unexpected("METRICS expects METRICS_OK")),
        }
    }

    /// Ask the server process to stop accepting and drain.
    pub fn shutdown_server(&mut self) -> Result<(), NetError> {
        match self.rpc(&Request::Shutdown)? {
            Response::ShutdownOk => Ok(()),
            Response::ProtoError { message } => Err(NetError::Remote(message)),
            _ => Err(NetError::Unexpected("SHUTDOWN expects SHUTDOWN_OK")),
        }
    }

    /// Send one raw (possibly malformed) frame — the hardening tests'
    /// hook for speaking garbage at a live server.
    pub fn send_raw(&mut self, frame: &[u8]) -> Result<(), NetError> {
        write_frame(&mut self.stream, frame)?;
        Ok(())
    }

    /// Read the next response frame, blocking until it arrives.
    pub fn recv(&mut self) -> Result<Response, NetError> {
        loop {
            match self.reader.next_response(&mut self.stream)? {
                NextFrame::Frame(resp) => return Ok(resp),
                NextFrame::TimedOut => {}
                NextFrame::Closed => return Err(NetError::Closed),
            }
        }
    }

    fn rpc(&mut self, req: &Request) -> Result<Response, NetError> {
        let frame = codec::encode_request(req)?;
        write_frame(&mut self.stream, &frame)?;
        self.recv()
    }
}
