//! The TCP ingress server: a thread-per-connection front end that
//! maps wire connections onto [`SortClient`]s.
//!
//! # Connection lifecycle
//!
//! An accept loop (one thread, owned by [`NetServer`]) hands each
//! connection to its own worker thread. The first useful frame must
//! be `HELLO`, which names the tenant and carries its
//! [`ClientConfig`] knobs — the server answers with the config
//! actually in force (the service clamps). From then on the
//! connection is a request/response loop over `SUBMIT` / `POLL` /
//! `CANCEL` / `METRICS` / `SHUTDOWN`.
//!
//! # Backpressure, not drops
//!
//! A shed submit ([`crate::coordinator::Busy`]) becomes a
//! `RETRY_AFTER` frame carrying the same reason and
//! `retry_after_hint` the in-process API exposes — the connection
//! stays open and the client decides when to come back. Overload
//! never closes sockets.
//!
//! # Error containment
//!
//! The two error classes get different treatment, and neither can
//! wedge a worker or leak a QoS charge:
//!
//! * **Semantic errors in well-formed frames** (`SUBMIT` before
//!   `HELLO`, a reused in-flight id, `POLL` for an unknown id) are
//!   answered with `PROTO_ERROR` and the connection continues — the
//!   frame was parseable, so the stream is still synchronized.
//! * **Stream desync** (malformed bytes, oversized declared length,
//!   EOF mid-frame) is answered with a final `PROTO_ERROR` and the
//!   connection closes: frame boundaries are unrecoverable.
//!
//! Either way — and equally on abrupt disconnect — closing drops the
//! connection's pending [`SortHandle`]s, and dropping an unresolved
//! handle *is* the coordinator's cancel path (PR 2's drop-to-cancel):
//! workers skip the job, the QoS charge is released, and the tenant
//! ledger counts it `cancelled`. The accounting identity holds across
//! the wire.

use super::codec::{self, Request, Response, WireBusyReason, WireMetrics, WireTenant};
use super::stream::{write_frame, FrameReader, NextFrame, StreamError};
use crate::coordinator::{
    Busy, ClientConfig, ElemBuf, Metrics, SortClient, SortElem, SortError, SortHandle, SortService,
};
use crate::simd::KeyValue;
use std::collections::HashMap;
use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::Duration;

/// How long a connection thread blocks in `read` before re-checking
/// the server stop flag.
const READ_TIMEOUT: Duration = Duration::from_millis(25);

/// A running TCP front end over one [`SortService`]. Dropping (or
/// calling [`NetServer::stop`]) stops accepting, wakes the accept
/// loop, and joins every connection thread; the underlying service is
/// left running for the owner to shut down.
pub struct NetServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an OS-assigned port) and
    /// start serving `svc` over it.
    pub fn bind(svc: Arc<SortService>, addr: &str) -> io::Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept = {
            let stop = Arc::clone(&stop);
            thread::Builder::new()
                .name("neonms-net-accept".into())
                .spawn(move || accept_loop(&svc, &listener, &stop, local))?
        };
        Ok(NetServer { addr: local, stop, accept: Some(accept) })
    }

    /// The bound address (with the real port when bound to port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// True once the server has stopped accepting — set by
    /// [`NetServer::stop`] or a `SHUTDOWN` frame.
    pub fn stopping(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// Block until the server stops (a `SHUTDOWN` frame arrives or
    /// another thread calls for a stop), then join every connection.
    pub fn wait(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Stop accepting and join all connection threads.
    pub fn stop(mut self) {
        self.shut();
    }

    fn shut(&mut self) {
        if let Some(h) = self.accept.take() {
            self.stop.store(true, Ordering::SeqCst);
            // The accept loop blocks in `accept`; a throwaway local
            // connection is the portable wakeup.
            let _ = TcpStream::connect(self.addr);
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shut();
    }
}

fn accept_loop(
    svc: &Arc<SortService>,
    listener: &TcpListener,
    stop: &Arc<AtomicBool>,
    local: SocketAddr,
) {
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                if stop.load(Ordering::SeqCst) {
                    // The wakeup connection from `stop`; not a client.
                    break;
                }
                let svc = Arc::clone(svc);
                let stop = Arc::clone(stop);
                let spawned = thread::Builder::new()
                    .name("neonms-net-conn".into())
                    .spawn(move || serve_connection(&svc, stream, &stop, local));
                match spawned {
                    Ok(h) => conns.push(h),
                    // Spawn failure: the stream drops here and the
                    // client sees a clean close with nothing pending.
                    Err(_) => {}
                }
            }
            Err(_) if stop.load(Ordering::SeqCst) => break,
            Err(_) => {}
        }
        conns.retain(|h| !h.is_finished());
    }
    for h in conns {
        let _ = h.join();
    }
}

/// A submitted-but-unresolved job of any element kind. Dropping it
/// drops the typed handle inside, which cancels the job — the single
/// mechanism behind `CANCEL` frames, protocol-error teardown, and
/// abrupt disconnects.
enum AnyHandle {
    U32(SortHandle<u32>),
    U64(SortHandle<u64>),
    Pair(SortHandle<KeyValue>),
}

impl AnyHandle {
    fn try_take(&mut self) -> Option<Result<ElemBuf, SortError>> {
        match self {
            AnyHandle::U32(h) => h.try_take().map(|r| r.map(<u32 as SortElem>::wrap)),
            AnyHandle::U64(h) => h.try_take().map(|r| r.map(<u64 as SortElem>::wrap)),
            AnyHandle::Pair(h) => h.try_take().map(|r| r.map(<KeyValue as SortElem>::wrap)),
        }
    }
}

/// Per-connection protocol state. Dropped on any exit path, which
/// resolves (cancels) everything still pending.
struct Conn {
    client: Option<SortClient>,
    pending: HashMap<u64, AnyHandle>,
}

fn serve_connection(
    svc: &Arc<SortService>,
    mut stream: TcpStream,
    stop: &AtomicBool,
    local: SocketAddr,
) {
    let m = svc.raw_metrics();
    m.connections_opened.fetch_add(1, Ordering::Relaxed);
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let _ = stream.set_nodelay(true);
    let mut reader = FrameReader::new();
    let mut conn = Conn { client: None, pending: HashMap::new() };
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let req = match reader.next_request(&mut stream) {
            Ok(NextFrame::Frame(req)) => req,
            Ok(NextFrame::TimedOut) => continue,
            Ok(NextFrame::Closed) => break,
            Err(e) => {
                // Desynchronized stream: send the diagnostic, then
                // close. `conn` drops below, cancelling every pending
                // handle, so no QoS charge outlives the connection.
                m.net_protocol_errors.fetch_add(1, Ordering::Relaxed);
                let message = match &e {
                    StreamError::Protocol(p) => p.to_string(),
                    StreamError::Io(io) => io.to_string(),
                };
                let _ = respond(&mut stream, m, &Response::ProtoError { message });
                break;
            }
        };
        m.net_frames.fetch_add(1, Ordering::Relaxed);
        match handle_request(svc, m, &mut conn, req) {
            Outcome::Reply(resp) => {
                if !respond(&mut stream, m, &resp) {
                    break;
                }
            }
            Outcome::Shutdown(resp) => {
                let _ = respond(&mut stream, m, &resp);
                stop.store(true, Ordering::SeqCst);
                // Wake the accept loop so it can drain and join.
                let _ = TcpStream::connect(local);
                break;
            }
        }
    }
    m.connections_closed.fetch_add(1, Ordering::Relaxed);
    // `conn` drops here: drop-to-cancel for everything unresolved.
}

enum Outcome {
    Reply(Response),
    Shutdown(Response),
}

fn handle_request(svc: &SortService, m: &Metrics, conn: &mut Conn, req: Request) -> Outcome {
    match req {
        Request::Hello { tenant, weight, burst } => {
            let cfg = ClientConfig {
                weight,
                burst: usize::try_from(burst).unwrap_or(usize::MAX),
                ..ClientConfig::default()
            };
            let client = svc.client_with(&tenant, cfg);
            let eff = client.config();
            conn.client = Some(client);
            Outcome::Reply(Response::HelloOk { weight: eff.weight, burst: eff.burst as u64 })
        }
        Request::Submit { id, data } => {
            let Some(client) = &conn.client else {
                return Outcome::Reply(Response::ProtoError {
                    message: "SUBMIT before HELLO".into(),
                });
            };
            if conn.pending.contains_key(&id) {
                return Outcome::Reply(Response::ProtoError {
                    message: format!("SUBMIT reuses in-flight id {id}"),
                });
            }
            match try_submit(client, data) {
                Ok(handle) => {
                    conn.pending.insert(id, handle);
                    Outcome::Reply(Response::Accepted { id })
                }
                Err((reason, hint)) => {
                    m.net_retry_after.fetch_add(1, Ordering::Relaxed);
                    Outcome::Reply(Response::RetryAfter { id, reason, hint })
                }
            }
        }
        Request::Poll { id } => match conn.pending.get_mut(&id) {
            None => Outcome::Reply(Response::ProtoError {
                message: format!("POLL for unknown id {id}"),
            }),
            Some(h) => match h.try_take() {
                None => Outcome::Reply(Response::Pending { id }),
                Some(Ok(data)) => {
                    conn.pending.remove(&id);
                    Outcome::Reply(Response::Done { id, data })
                }
                Some(Err(e)) => {
                    conn.pending.remove(&id);
                    Outcome::Reply(Response::Failed { id, error: e.into() })
                }
            },
        },
        Request::Cancel { id } => {
            // Removing drops the handle → the coordinator's cancel
            // path. Unknown ids ack too: cancel is idempotent and the
            // job may simply have resolved already.
            conn.pending.remove(&id);
            Outcome::Reply(Response::CancelOk { id })
        }
        Request::Metrics => Outcome::Reply(Response::Metrics(wire_metrics(svc))),
        Request::Shutdown => Outcome::Shutdown(Response::ShutdownOk),
    }
}

/// Non-blocking submit of a decoded payload; a shed becomes the
/// `(reason, hint)` pair for a `RETRY_AFTER` frame.
fn try_submit(
    client: &SortClient,
    data: ElemBuf,
) -> Result<AnyHandle, (WireBusyReason, Duration)> {
    match data {
        ElemBuf::U32(v) => client.try_submit(v).map(AnyHandle::U32).map_err(shed_info),
        ElemBuf::U64(v) => client.try_submit_u64(v).map(AnyHandle::U64).map_err(shed_info),
        ElemBuf::Pair(v) => client.try_submit_pairs(v).map(AnyHandle::Pair).map_err(shed_info),
    }
}

fn shed_info<T: SortElem>(busy: Busy<T>) -> (WireBusyReason, Duration) {
    let hint = busy.reason.retry_after().unwrap_or(Duration::ZERO);
    (WireBusyReason::from(&busy.reason), hint)
}

/// Project the in-process [`crate::coordinator::MetricsSnapshot`]
/// onto the wire subset.
fn wire_metrics(svc: &SortService) -> WireMetrics {
    let snap = svc.metrics();
    WireMetrics {
        submitted: snap.submitted,
        completed: snap.completed,
        rejected: snap.rejected,
        cancelled: snap.cancelled,
        failed: snap.failed,
        quarantined: snap.quarantined,
        connections_open: snap.connections_open,
        connections_opened: snap.connections_opened,
        net_frames: snap.net_frames,
        net_retry_after: snap.net_retry_after,
        net_protocol_errors: snap.net_protocol_errors,
        tenants: snap
            .tenants
            .iter()
            .map(|t| WireTenant {
                name: t.name.clone(),
                accepted: t.accepted,
                completed: t.completed,
                cancelled: t.cancelled,
                failed: t.failed,
                in_flight_bytes: t.in_flight_bytes,
                queued_jobs: t.queued_jobs,
            })
            .collect(),
    }
}

/// Encode and send one response. Returns false when the connection is
/// unusable (the caller closes; pending handles cancel on drop).
fn respond(stream: &mut TcpStream, m: &Metrics, resp: &Response) -> bool {
    let bytes = match codec::encode_response(resp) {
        Ok(b) => b,
        Err(e) => {
            // A response the codec bounds refuse (pathological tenant
            // list / message). Degrade to a diagnostic the peer can
            // always decode rather than silently dropping the answer.
            m.net_protocol_errors.fetch_add(1, Ordering::Relaxed);
            let fallback =
                Response::ProtoError { message: format!("response exceeded wire bounds: {e}") };
            match codec::encode_response(&fallback) {
                Ok(b) => b,
                Err(_) => return false,
            }
        }
    };
    write_frame(stream, &bytes).is_ok()
}
