//! Protocol-hardening suite for the wire codec and frame reader: the
//! vqsort adversarial-input lesson applied to the ingress boundary.
//! Round-trips every frame type (all three element kinds), then
//! attacks the decoder with truncation, oversized length prefixes,
//! garbage, split-across-read delivery, and seeded random bytes — all
//! of which must produce typed [`ProtocolError`]s, never a panic,
//! never an allocation beyond the frame bound.

use super::codec::{
    decode_request, decode_response, encode_request, encode_response, ProtocolError, Request,
    Response, WireBusyReason, WireMetrics, WireSortError, WireTenant, MAX_FRAME_BYTES,
};
use super::stream::{FrameReader, NextFrame, StreamError};
use crate::coordinator::{ElemBuf, SortError};
use crate::simd::KeyValue;
use crate::testutil::Rng;
use std::io::Read;
use std::time::Duration;

// ------------------------------------------------------------ fixtures

fn sample_bufs() -> Vec<ElemBuf> {
    vec![
        ElemBuf::U32(vec![]),
        ElemBuf::U32(vec![7, 3, u32::MAX, 0]),
        ElemBuf::U64(vec![u64::MAX, 1, 0x0123_4567_89AB_CDEF]),
        ElemBuf::Pair(vec![KeyValue::new(9, 100), KeyValue::new(0, u32::MAX)]),
    ]
}

fn sample_requests() -> Vec<Request> {
    let mut reqs = vec![
        Request::Hello { tenant: "tenant-α".into(), weight: 4, burst: 1 << 20 },
        Request::Hello { tenant: String::new(), weight: 0, burst: 0 },
        Request::Poll { id: 0 },
        Request::Poll { id: u64::MAX },
        Request::Cancel { id: 17 },
        Request::Metrics,
        Request::Shutdown,
    ];
    for (i, data) in sample_bufs().into_iter().enumerate() {
        reqs.push(Request::Submit { id: i as u64, data });
    }
    reqs
}

fn all_sort_errors() -> [SortError; 6] {
    [
        SortError::Shutdown,
        SortError::Evicted,
        SortError::JobPanicked,
        SortError::DeadlineExceeded,
        SortError::Quarantined,
        SortError::AlreadyTaken,
    ]
}

fn sample_responses() -> Vec<Response> {
    let mut resps = vec![
        Response::HelloOk { weight: 1, burst: 128 * 1024 },
        Response::Accepted { id: 3 },
        Response::RetryAfter {
            id: 4,
            reason: WireBusyReason::QueueFull,
            hint: Duration::from_micros(1000),
        },
        Response::RetryAfter {
            id: 5,
            reason: WireBusyReason::OverShare,
            hint: Duration::from_micros(50),
        },
        Response::RetryAfter { id: 6, reason: WireBusyReason::Shutdown, hint: Duration::ZERO },
        Response::Pending { id: 7 },
        Response::CancelOk { id: 8 },
        Response::Metrics(WireMetrics::default()),
        Response::Metrics(WireMetrics {
            submitted: 10,
            completed: 7,
            rejected: 1,
            cancelled: 1,
            failed: 1,
            quarantined: 1,
            connections_open: 2,
            connections_opened: 5,
            net_frames: 99,
            net_retry_after: 3,
            net_protocol_errors: 1,
            tenants: vec![
                WireTenant {
                    name: "gold".into(),
                    accepted: 6,
                    completed: 5,
                    cancelled: 1,
                    failed: 0,
                    in_flight_bytes: 0,
                    queued_jobs: 0,
                },
                WireTenant {
                    name: "bronze".into(),
                    accepted: 4,
                    completed: 2,
                    cancelled: 0,
                    failed: 1,
                    in_flight_bytes: 4096,
                    queued_jobs: 1,
                },
            ],
        }),
        Response::ShutdownOk,
        Response::ProtoError { message: "SUBMIT before HELLO".into() },
    ];
    for (i, data) in sample_bufs().into_iter().enumerate() {
        resps.push(Response::Done { id: 100 + i as u64, data });
    }
    for (i, e) in all_sort_errors().into_iter().enumerate() {
        resps.push(Response::Failed { id: 200 + i as u64, error: WireSortError::from(e) });
    }
    resps
}

/// A reader that hands out its bytes `chunk` at a time — the
/// split-across-read-boundary transport.
struct ChunkedReader {
    data: Vec<u8>,
    pos: usize,
    chunk: usize,
}

impl Read for ChunkedReader {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.chunk.min(buf.len()).min(self.data.len() - self.pos);
        buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

// ----------------------------------------------------------- round trip

#[test]
fn every_request_round_trips() {
    for req in sample_requests() {
        let frame = encode_request(&req).unwrap();
        let (back, used) = decode_request(&frame).unwrap().expect("complete frame");
        assert_eq!(used, frame.len(), "whole frame consumed: {req:?}");
        assert_eq!(back, req);
    }
}

#[test]
fn every_response_round_trips() {
    for resp in sample_responses() {
        let frame = encode_response(&resp).unwrap();
        let (back, used) = decode_response(&frame).unwrap().expect("complete frame");
        assert_eq!(used, frame.len(), "whole frame consumed: {resp:?}");
        assert_eq!(back, resp);
    }
}

#[test]
fn back_to_back_frames_decode_in_sequence() {
    let reqs = sample_requests();
    let mut wire = Vec::new();
    for req in &reqs {
        wire.extend_from_slice(&encode_request(req).unwrap());
    }
    let mut seen = Vec::new();
    while !wire.is_empty() {
        let (req, used) = decode_request(&wire).unwrap().expect("complete frame");
        seen.push(req);
        wire.drain(..used);
    }
    assert_eq!(seen, reqs);
}

// ----------------------------------------------- incomplete ≠ malformed

#[test]
fn every_strict_prefix_asks_for_more_bytes() {
    // A truncated-in-transit frame is *incomplete*, not an error:
    // decode must return None for every strict prefix of every valid
    // frame (this is what makes arbitrary TCP chunking transparent).
    for req in sample_requests() {
        let frame = encode_request(&req).unwrap();
        for cut in 0..frame.len() {
            assert_eq!(
                decode_request(&frame[..cut]).unwrap(),
                None,
                "prefix of {} bytes of {req:?}",
                cut
            );
        }
    }
    for resp in sample_responses() {
        let frame = encode_response(&resp).unwrap();
        for cut in 0..frame.len() {
            assert!(decode_response(&frame[..cut]).unwrap().is_none());
        }
    }
}

#[test]
fn frame_reader_reassembles_across_read_boundaries() {
    let reqs = sample_requests();
    let mut wire = Vec::new();
    for req in &reqs {
        wire.extend_from_slice(&encode_request(req).unwrap());
    }
    // One byte per read is the worst-case chunking; a couple of odd
    // sizes cover the straddle-the-length-prefix cases.
    for chunk in [1usize, 3, 7, 4096] {
        let mut src = ChunkedReader { data: wire.clone(), pos: 0, chunk };
        let mut reader = FrameReader::new();
        let mut seen = Vec::new();
        loop {
            match reader.next_request(&mut src).unwrap() {
                NextFrame::Frame(req) => seen.push(req),
                NextFrame::Closed => break,
                NextFrame::TimedOut => unreachable!("ChunkedReader never times out"),
            }
        }
        assert_eq!(seen, reqs, "chunk size {chunk}");
        assert_eq!(reader.buffered(), 0);
    }
}

#[test]
fn eof_mid_frame_is_a_typed_error() {
    let frame = encode_request(&Request::Poll { id: 9 }).unwrap();
    let mut src = ChunkedReader { data: frame[..frame.len() - 1].to_vec(), pos: 0, chunk: 64 };
    let mut reader = FrameReader::new();
    match reader.next_request(&mut src) {
        Err(StreamError::Protocol(ProtocolError::ClosedMidFrame { buffered })) => {
            assert_eq!(buffered, frame.len() - 1);
        }
        other => panic!("expected ClosedMidFrame, got {other:?}"),
    }
}

// ------------------------------------------------- adversarial frames

/// Wrap a raw body in a length prefix (bypassing the encoder's own
/// checks) — the attacker's frame-builder.
fn raw_frame(body: &[u8]) -> Vec<u8> {
    let mut f = (body.len() as u32).to_le_bytes().to_vec();
    f.extend_from_slice(body);
    f
}

#[test]
fn oversized_length_prefix_rejected_from_header_alone() {
    // Only the 4 header bytes exist; the decoder must reject before
    // waiting for (or allocating) the declared 4 GiB body.
    for declared in [MAX_FRAME_BYTES as u32 + 1, u32::MAX] {
        let header = declared.to_le_bytes();
        let err = decode_request(&header).unwrap_err();
        assert_eq!(
            err,
            ProtocolError::Oversized { declared: declared as usize, max: MAX_FRAME_BYTES }
        );
        assert!(decode_response(&header).is_err());
    }
    // The bound itself is fine (an all-padding body fails later, on
    // opcode grounds, proving the length check passed).
    let padding = vec![0u8; MAX_FRAME_BYTES];
    let at_bound = raw_frame(&padding);
    assert_eq!(decode_request(&at_bound).unwrap_err(), ProtocolError::UnknownOpcode(0));
}

#[test]
fn forged_element_count_rejected_before_allocating() {
    // SUBMIT declaring u32::MAX elements with a 4-byte payload: the
    // count × width bound check must fire against the bytes actually
    // present, not reserve 16 GiB.
    let mut body = vec![0x02]; // SUBMIT
    body.extend_from_slice(&7u64.to_le_bytes()); // id
    body.push(0); // kind u32
    body.extend_from_slice(&u32::MAX.to_le_bytes()); // forged count
    body.extend_from_slice(&[1, 2, 3, 4]); // 4 bytes of "payload"
    let err = decode_request(&raw_frame(&body)).unwrap_err();
    assert_eq!(
        err,
        ProtocolError::PayloadTruncated {
            declared_elements: u32::MAX as usize,
            available_bytes: 4
        }
    );
}

#[test]
fn forged_tenant_count_rejected_before_allocating() {
    let mut body = vec![0x88]; // METRICS_OK
    for _ in 0..11 {
        body.extend_from_slice(&0u64.to_le_bytes());
    }
    body.extend_from_slice(&u16::MAX.to_le_bytes()); // forged tenant count
    let err = decode_response(&raw_frame(&body)).unwrap_err();
    assert!(
        matches!(err, ProtocolError::PayloadTruncated { declared_elements: 65535, .. }),
        "got {err:?}"
    );
}

#[test]
fn garbage_bytes_yield_typed_errors_never_panics() {
    // Unknown opcode.
    assert_eq!(
        decode_request(&raw_frame(&[0x77])).unwrap_err(),
        ProtocolError::UnknownOpcode(0x77)
    );
    assert_eq!(
        decode_response(&raw_frame(&[0x01])).unwrap_err(),
        ProtocolError::UnknownOpcode(0x01),
        "request opcodes are not response opcodes"
    );
    // Unknown element kind in a SUBMIT.
    let mut body = vec![0x02];
    body.extend_from_slice(&1u64.to_le_bytes());
    body.push(9); // no such kind
    body.extend_from_slice(&0u32.to_le_bytes());
    assert_eq!(
        decode_request(&raw_frame(&body)).unwrap_err(),
        ProtocolError::UnknownElemKind(9)
    );
    // Unknown retry-after reason.
    let mut body = vec![0x83];
    body.extend_from_slice(&1u64.to_le_bytes());
    body.push(7);
    body.extend_from_slice(&0u64.to_le_bytes());
    assert_eq!(decode_response(&raw_frame(&body)).unwrap_err(), ProtocolError::UnknownReason(7));
    // Unknown sort-error code.
    let mut body = vec![0x86];
    body.extend_from_slice(&1u64.to_le_bytes());
    body.push(42);
    assert_eq!(
        decode_response(&raw_frame(&body)).unwrap_err(),
        ProtocolError::UnknownErrorCode(42)
    );
    // Non-UTF-8 tenant name in a HELLO.
    let mut body = vec![0x01];
    body.extend_from_slice(&2u16.to_le_bytes());
    body.extend_from_slice(&[0xFF, 0xFE]);
    body.extend_from_slice(&1u32.to_le_bytes());
    body.extend_from_slice(&0u64.to_le_bytes());
    assert_eq!(decode_request(&raw_frame(&body)).unwrap_err(), ProtocolError::BadUtf8);
    // Body truncated inside a field (POLL with a 4-byte id).
    let mut body = vec![0x03];
    body.extend_from_slice(&[1, 2, 3, 4]);
    assert_eq!(
        decode_request(&raw_frame(&body)).unwrap_err(),
        ProtocolError::Truncated { what: "request id" }
    );
    // Trailing bytes after a complete body.
    let mut body = vec![0x03];
    body.extend_from_slice(&1u64.to_le_bytes());
    body.extend_from_slice(&[0xAB, 0xCD]);
    assert_eq!(
        decode_request(&raw_frame(&body)).unwrap_err(),
        ProtocolError::TrailingBytes { extra: 2 }
    );
}

#[test]
fn encoder_refuses_frames_its_decoder_would() {
    // A payload beyond the frame bound must not encode (symmetric
    // bound: the encoder cannot produce an undecodable frame).
    let too_big = ElemBuf::U32(vec![0u32; MAX_FRAME_BYTES / 4 + 1]);
    let err = encode_request(&Request::Submit { id: 0, data: too_big }).unwrap_err();
    assert!(matches!(err, ProtocolError::Oversized { .. }), "got {err:?}");
}

#[test]
fn random_bytes_never_panic_the_decoder() {
    // Seeded fuzz: raw random buffers, and random bodies wrapped in
    // honest length prefixes so parsing gets past the header. Every
    // outcome must be Ok or a typed error — a panic fails the test by
    // crashing it.
    let mut rng = Rng::new(0xC0DEC);
    let reqs = sample_requests();
    for round in 0..2000 {
        let len = 1 + rng.below(95);
        let mut bytes = Vec::with_capacity(len + 4);
        for _ in 0..len {
            bytes.push(rng.below(256) as u8);
        }
        let _ = decode_request(&bytes);
        let _ = decode_response(&bytes);
        let framed = raw_frame(&bytes);
        let _ = decode_request(&framed);
        let _ = decode_response(&framed);
        // Bit-flip a valid frame: still no panic allowed.
        if round % 4 == 0 {
            let mut frame = encode_request(&reqs[round % reqs.len()]).unwrap();
            let idx = rng.below(frame.len());
            frame[idx] ^= 1u8 << rng.below(8);
            let _ = decode_request(&frame);
        }
    }
}

#[test]
fn hint_and_reason_survive_the_wire() {
    // The acceptance-criteria contract in miniature: the hint a
    // RETRY_AFTER carries decodes to the exact Duration the server
    // encoded (microsecond-resolution round trip).
    for (reason, us) in [
        (WireBusyReason::QueueFull, 1000u64),
        (WireBusyReason::OverShare, 50),
        (WireBusyReason::Shutdown, 0),
    ] {
        let frame = encode_response(&Response::RetryAfter {
            id: 1,
            reason,
            hint: Duration::from_micros(us),
        })
        .unwrap();
        match decode_response(&frame).unwrap().unwrap().0 {
            Response::RetryAfter { reason: r, hint, .. } => {
                assert_eq!(r, reason);
                assert_eq!(hint, Duration::from_micros(us));
                assert_eq!(r.retryable(), reason != WireBusyReason::Shutdown);
            }
            other => panic!("expected RetryAfter, got {other:?}"),
        }
    }
}

#[test]
fn proto_error_messages_are_clipped_not_refused() {
    let long = "x".repeat(100_000);
    let frame = encode_response(&Response::ProtoError { message: long }).unwrap();
    match decode_response(&frame).unwrap().unwrap().0 {
        Response::ProtoError { message } => {
            assert_eq!(message.len(), 512, "diagnostics clip to a bounded length");
        }
        other => panic!("expected ProtoError, got {other:?}"),
    }
}
