//! The 256-bit, 4-lane (64-bit element) vector register type: paired
//! `q`-registers at 8-byte lane width.
//!
//! The 64-bit sibling of [`super::V256`]: models SVE-256 / paired
//! NEON `q`-registers carrying `u64` keys or packed
//! [`super::KeyValue`] pairs, four lanes per logical register. On the
//! scalar and NEON backends every op lowers to exactly two [`V128D`]
//! ops, keeping the cost model honest at this width too; under AVX2
//! the comparators fuse into native ymm ops (see [`super::V256`]).

use super::backend;
use super::lane::Lane;
use super::v128d::{transpose2, V128D, W64};
use super::vector::{Lanes, Vector};

/// Four 64-bit lanes as a pair of [`V128D`] halves: lane `i` lives in
/// half `i / 2`, lane `i % 2`. Lane 0 is the lowest-addressed element
/// on load, matching the `V128D` convention.
#[derive(Clone, Copy, PartialEq, Debug)]
#[repr(C, align(32))]
pub struct V256D<T: Lane>(pub [V128D<T>; 2]);

impl<T: Lane> V256D<T> {
    /// Lanes per register.
    pub const LANES: usize = 2 * W64;

    /// Broadcast one scalar to all four lanes.
    #[inline(always)]
    pub fn splat(v: T) -> Self {
        V256D([V128D::splat(v), V128D::splat(v)])
    }

    /// Load four contiguous lanes from `src` (`vld1q_u64_x2` / SVE
    /// `ld1d`). Panics if `src.len() < 4`.
    #[inline(always)]
    pub fn load(src: &[T]) -> Self {
        V256D([V128D::load(&src[..W64]), V128D::load(&src[W64..2 * W64])])
    }

    /// Store four lanes to `dst`.
    #[inline(always)]
    pub fn store(self, dst: &mut [T]) {
        self.0[0].store(&mut dst[..W64]);
        self.0[1].store(&mut dst[W64..2 * W64]);
    }

    /// Materialize as a plain array.
    #[inline(always)]
    pub fn to_array(self) -> [T; 4] {
        let (a, b) = (self.0[0].to_array(), self.0[1].to_array());
        [a[0], a[1], b[0], b[1]]
    }
}

impl<T: Lane> Lanes for V256D<T> {
    const LANES: usize = 2 * W64;
    const LANE_BYTES: usize = 8;
}

impl<T: Lane> Vector<T> for V256D<T> {
    #[inline(always)]
    fn splat(v: T) -> Self {
        V256D::splat(v)
    }

    #[inline(always)]
    fn load(src: &[T]) -> Self {
        V256D::load(src)
    }

    #[inline(always)]
    fn store(self, dst: &mut [T]) {
        V256D::store(self, dst)
    }

    #[inline(always)]
    fn lane(self, i: usize) -> T {
        self.0[i / W64].lane(i % W64)
    }

    /// Two lane-wise mins on paired-register backends, one native ymm
    /// op under AVX2.
    #[inline(always)]
    fn min(self, o: Self) -> Self {
        backend::from_b256(T::min256(backend::to_b256(self), backend::to_b256(o)))
    }

    /// Two lane-wise maxes, or one ymm op under AVX2.
    #[inline(always)]
    fn max(self, o: Self) -> Self {
        backend::from_b256(T::max256(backend::to_b256(self), backend::to_b256(o)))
    }

    /// Reverse all four lanes: reverse each half and swap the pair.
    #[inline(always)]
    fn reverse(self) -> Self {
        V256D([self.0[1].reverse(), self.0[0].reverse()])
    }

    /// Two half-cleaner stages (distances 2, 1). The distance-2 stage
    /// is the pair boundary: one `cmpswap` *between* the two halves
    /// (no shuffle — the paired-register payoff); the distance-1
    /// stage is each half's single-comparator merge.
    #[inline(always)]
    fn bitonic_merge_lanes(self) -> Self {
        let (lo, hi) = self.0[0].cmpswap(self.0[1]);
        V256D([Vector::bitonic_merge_lanes(lo), Vector::bitonic_merge_lanes(hi)])
    }

    /// Sort both halves, reverse the upper to form a bitonic
    /// sequence, then merge — the 4-lane bitonic sorter.
    #[inline(always)]
    fn sort_lanes(self) -> Self {
        let lo = Vector::sort_lanes(self.0[0]);
        let hi = V128D::reverse(Vector::sort_lanes(self.0[1]));
        Vector::bitonic_merge_lanes(V256D([lo, hi]))
    }

    #[inline(always)]
    fn transpose_tile(tile: &mut [Self]) {
        assert_eq!(tile.len(), 2 * W64, "V256D tile is 4x4");
        let t = transpose4d([tile[0], tile[1], tile[2], tile[3]]);
        tile.copy_from_slice(&t);
    }
}

/// 4×4 in-register matrix transpose over [`V256D`] registers, built
/// from four 2×2 [`transpose2`] base transposes — the 2×2 block
/// decomposition `[[A, B], [C, D]]ᵀ = [[Aᵀ, Cᵀ], [Bᵀ, Dᵀ]]`, where
/// each letter is the 2×2 tile one `V128D` half-column contributes.
#[inline(always)]
pub fn transpose4d<T: Lane>(r: [V256D<T>; 4]) -> [V256D<T>; 4] {
    let a = transpose2([r[0].0[0], r[1].0[0]]);
    let b = transpose2([r[0].0[1], r[1].0[1]]);
    let c = transpose2([r[2].0[0], r[3].0[0]]);
    let d = transpose2([r[2].0[1], r[3].0[1]]);
    [
        V256D([a[0], c[0]]),
        V256D([a[1], c[1]]),
        V256D([b[0], d[0]]),
        V256D([b[1], d[1]]),
    ]
}
