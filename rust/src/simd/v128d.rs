//! The 128-bit, 2-lane (64-bit element) vector register type.

use super::backend::{self, B128};
use super::lane::Lane;
use super::vector::{Lanes, Vector};

/// Lanes per [`V128D`] register — the paper's `W` replayed at 64-bit
/// element width: a 128-bit register holds two 8-byte lanes.
pub const W64: usize = 2;

/// A NEON `q`-register stand-in at 64-bit element width: two lanes,
/// 16-byte aligned — the register the database `(key, rowid)` path
/// sorts on (`u64` keys, packed [`super::KeyValue`] pairs).
///
/// Same instruction vocabulary as [`super::V128`], one element size
/// up: the shuffles model the `_u64` forms (`vtrn1q_u64`,
/// `vzip1q_u64`, `vextq_u64 #8`). With only two lanes the shuffle
/// algebra collapses — `rev64`'s within-half reversal is the identity
/// at 64-bit granularity, so full reversal is the single `vextq`
/// half-swap, and the intra-register bitonic merge is one comparator
/// stage instead of [`super::V128`]'s two.
#[derive(Clone, Copy, PartialEq, Debug)]
#[repr(C, align(16))]
pub struct V128D<T: Lane>(pub [T; W64]);

impl<T: Lane> V128D<T> {
    /// The raw register bits, for backend dispatch.
    #[inline(always)]
    fn bits(self) -> B128 {
        backend::to_b128(self)
    }

    /// Rebuild from raw register bits.
    #[inline(always)]
    fn of(b: B128) -> Self {
        backend::from_b128(b)
    }

    /// Broadcast one scalar to both lanes (`vdupq_n_u64`).
    #[inline(always)]
    pub fn splat(v: T) -> Self {
        V128D([v; W64])
    }

    /// Load two contiguous lanes from `src` (`vld1q_u64`). Panics if
    /// `src.len() < 2` — kernels guarantee whole-vector access.
    #[inline(always)]
    pub fn load(src: &[T]) -> Self {
        V128D([src[0], src[1]])
    }

    /// Store both lanes to `dst` (`vst1q_u64`).
    #[inline(always)]
    pub fn store(self, dst: &mut [T]) {
        dst[..W64].copy_from_slice(&self.0);
    }

    /// Lane accessor (`vgetq_lane_u64`).
    #[inline(always)]
    pub fn lane(self, i: usize) -> T {
        self.0[i]
    }

    /// Lane-wise minimum — one half of a vector comparator. (AArch64
    /// has no `vminq_u64`; the NEON backend lowers this to `cmhi` +
    /// `bsl`, still branchless.)
    #[inline(always)]
    pub fn min(self, o: Self) -> Self {
        Self::of(T::min128(self.bits(), o.bits()))
    }

    /// Lane-wise maximum — the other half of a comparator.
    #[inline(always)]
    pub fn max(self, o: Self) -> Self {
        Self::of(T::max128(self.bits(), o.bits()))
    }

    /// Vector comparator: `(min, max)` lane-wise.
    #[inline(always)]
    pub fn cmpswap(self, o: Self) -> (Self, Self) {
        (self.min(o), self.max(o))
    }

    /// Transpose even lanes (`vtrn1q_u64` = `vzip1q_u64`): `[a0,b0]`.
    #[inline(always)]
    pub fn trn1(self, o: Self) -> Self {
        Self::of(backend::zip1_64(self.bits(), o.bits()))
    }

    /// Transpose odd lanes (`vtrn2q_u64` = `vzip2q_u64`): `[a1,b1]`.
    #[inline(always)]
    pub fn trn2(self, o: Self) -> Self {
        Self::of(backend::zip2_64(self.bits(), o.bits()))
    }

    /// Swap the two 64-bit lanes (`vextq_u64 #8`): `[a1,a0]` — at two
    /// lanes this *is* the full reversal.
    #[inline(always)]
    pub fn swap_halves(self) -> Self {
        Self::of(backend::swap64(self.bits()))
    }

    /// Full lane reversal `[a1,a0]`.
    #[inline(always)]
    pub fn reverse(self) -> Self {
        self.swap_halves()
    }

    /// Materialize as a plain array.
    #[inline(always)]
    pub fn to_array(self) -> [T; W64] {
        self.0
    }
}

impl<T: Lane> Lanes for V128D<T> {
    const LANES: usize = W64;
    const LANE_BYTES: usize = 8;
}

impl<T: Lane> Vector<T> for V128D<T> {
    #[inline(always)]
    fn splat(v: T) -> Self {
        V128D::splat(v)
    }

    #[inline(always)]
    fn load(src: &[T]) -> Self {
        V128D::load(src)
    }

    #[inline(always)]
    fn store(self, dst: &mut [T]) {
        V128D::store(self, dst)
    }

    #[inline(always)]
    fn lane(self, i: usize) -> T {
        V128D::lane(self, i)
    }

    #[inline(always)]
    fn min(self, o: Self) -> Self {
        V128D::min(self, o)
    }

    #[inline(always)]
    fn max(self, o: Self) -> Self {
        V128D::max(self, o)
    }

    #[inline(always)]
    fn reverse(self) -> Self {
        V128D::reverse(self)
    }

    /// `log2(2) = 1` half-cleaner stage: one comparator between the
    /// two lanes sorts any bitonic (here: any) 2-lane sequence —
    /// lane-swap, comparator, then keep min low / max high (the same
    /// `ext` + `cmhi`/`bsl` + blend sequence on every backend).
    #[inline(always)]
    fn bitonic_merge_lanes(self) -> Self {
        let s = self.swap_halves();
        Self::of(backend::blend64_lo_hi(
            self.min(s).bits(),
            self.max(s).bits(),
        ))
    }

    /// One comparator sorts two lanes — the degenerate bitonic sorter.
    #[inline(always)]
    fn sort_lanes(self) -> Self {
        self.bitonic_merge_lanes()
    }

    #[inline(always)]
    fn transpose_tile(tile: &mut [Self]) {
        assert_eq!(tile.len(), W64, "V128D tile is 2x2");
        let t = transpose2([tile[0], tile[1]]);
        tile.copy_from_slice(&t);
    }
}

/// 2×2 in-register matrix transpose — the base matrix transpose at
/// 64-bit element width: one `vtrn1q_u64` + one `vtrn2q_u64`, no
/// memory traffic. An `R×2` transpose decomposes into `R/2` of these,
/// exactly as the 32-bit path decomposes `R×4` into `transpose4`
/// tiles.
#[inline(always)]
pub fn transpose2<T: Lane>(r: [V128D<T>; 2]) -> [V128D<T>; 2] {
    [r[0].trn1(r[1]), r[0].trn2(r[1])]
}
