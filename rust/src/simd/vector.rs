//! The width-generic vector abstraction.
//!
//! The paper's kernels are written against one concrete register
//! shape (NEON `q`: 128 bits, `W = 4` lanes). The width sweep the
//! paper motivates (§2.2: throughput is governed by vector width ×
//! register budget) needs the *same* kernels at other widths, so the
//! kernel layer is generic over [`Vector`] instead of hard-wired to
//! [`super::V128`]. Four implementations exist, spanning both the
//! register-width and the element-width axis:
//!
//! * [`super::V128`] — `W = 4` × 32-bit, the paper's NEON `q`-register;
//! * [`super::V256`] — `W = 8` × 32-bit, modeling paired `q`-registers /
//!   SVE-256, lowering every op to two `V128` ops on this host;
//! * [`super::V128D`] — `W = 2` × 64-bit (NEON `vmovq_n_u64` geometry),
//!   carrying `u64` keys and packed [`super::KeyValue`] pairs;
//! * [`super::V256D`] — `W = 4` × 64-bit, the paired-register double
//!   of `V128D`.
//!
//! Only the operations the kernels actually consume are on the trait;
//! width-specific shuffles (`zip`/`uzp`/`trn`, `rev64`, the blends)
//! stay inherent to each register type — the trait exposes their
//! *compositions* ([`Vector::bitonic_merge_lanes`],
//! [`Vector::sort_lanes`], [`Vector::transpose_tile`]), which is what
//! keeps a width-generic kernel from paying width-specific shuffle
//! logic at every call site.

use super::lane::Lane;

/// Lane count of a vector register type, independent of the element
/// type. Split from [`Vector`] so const guards (e.g.
/// [`crate::kernels::hybrid::RegsFitMaxK`]) can name a register
/// type's width in a `const` context without dragging the `Lane`
/// parameter into const generics.
pub trait Lanes {
    /// Lanes per register — the paper's `W` (4/8 for 32-bit lanes at
    /// 128/256 bits, 2/4 for 64-bit lanes).
    const LANES: usize;
    /// Bytes per lane (4 or 8). `LANES * LANE_BYTES` is the register
    /// width in bytes, which is what the [`crate::kernels::hybrid::RegsFitMaxK`]
    /// budget is denominated in.
    const LANE_BYTES: usize;
}

/// A SIMD register of [`Lanes::LANES`] lanes over element type
/// `T` — everything the sort kernels need from a vector ISA.
///
/// Contract shared by all implementations:
///
/// * lane 0 is the lowest-addressed element on [`Vector::load`]
///   (NEON `vld1q` little-endian convention);
/// * [`Vector::min`]/[`Vector::max`] are lane-wise, so
///   [`Vector::cmpswap`] is the paper's two-instruction comparator;
/// * [`Vector::bitonic_merge_lanes`] sorts any *bitonic* lane
///   sequence ascending (the `log2(LANES)` intra-register
///   half-cleaner stages);
/// * [`Vector::transpose_tile`] transposes a `LANES × LANES` register
///   tile in place — the base transpose the in-register sort builds
///   its `R × W` transpose from (§2.3).
pub trait Vector<T: Lane>:
    Lanes + Copy + PartialEq + core::fmt::Debug + Send + Sync + 'static
{
    /// Broadcast one scalar to all lanes (`vdupq_n`).
    fn splat(v: T) -> Self;

    /// Load `LANES` contiguous elements from `src` (`vld1q`). Panics
    /// if `src.len() < LANES` — kernels guarantee whole-vector access.
    fn load(src: &[T]) -> Self;

    /// Store `LANES` lanes to `dst` (`vst1q`).
    fn store(self, dst: &mut [T]);

    /// Lane accessor (`vgetq_lane`).
    fn lane(self, i: usize) -> T;

    /// Lane-wise minimum (`vminq`) — one half of a vector comparator.
    fn min(self, o: Self) -> Self;

    /// Lane-wise maximum (`vmaxq`) — the other half.
    fn max(self, o: Self) -> Self;

    /// Vector comparator: `(min, max)` lane-wise — exactly two
    /// instructions, no branches, no shuffles (the paper's
    /// "Comparator" applied across registers in column sort).
    #[inline(always)]
    fn cmpswap(self, o: Self) -> (Self, Self) {
        (self.min(o), self.max(o))
    }

    /// Full lane reversal `[a(W-1), .., a0]` — forms the bitonic
    /// sequence before a merge network.
    fn reverse(self) -> Self;

    /// Bitonic merge of the lanes: input bitonic (ascending then
    /// descending), output sorted ascending. The `log2(LANES)`
    /// intra-register half-cleaner stages of Fig. 4.
    fn bitonic_merge_lanes(self) -> Self;

    /// Sort the lanes ascending (tiny bitonic sorter, used for the
    /// one-register base case of [`crate::kernels::bitonic::bitonic_sort_regs`]).
    fn sort_lanes(self) -> Self;

    /// Transpose a `LANES × LANES` register tile in place:
    /// `tile.len()` must equal `LANES`; afterwards output register
    /// `i` holds lane `i` of every input register, in register order.
    fn transpose_tile(tile: &mut [Self]);
}

/// Runtime selector for the register width a sort configuration uses
/// — the sweep axis the ROADMAP's "wider lanes" item asked for.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum VectorWidth {
    /// 128-bit, 4-lane [`super::V128`] (the paper's NEON geometry).
    V128,
    /// 256-bit, 8-lane [`super::V256`] (paired q-registers /
    /// SVE-256; lowers to two `V128` ops per op on this host).
    V256,
}

impl VectorWidth {
    /// Register width in bits.
    pub fn bits(self) -> usize {
        match self {
            VectorWidth::V128 => 128,
            VectorWidth::V256 => 256,
        }
    }

    /// Lanes per register at this width for 32-bit elements (the
    /// paper's `W`). Element-width-aware callers should use
    /// [`VectorWidth::lanes_for`].
    pub fn lanes(self) -> usize {
        match self {
            VectorWidth::V128 => 4,
            VectorWidth::V256 => 8,
        }
    }

    /// Lanes per register for element type `T`: `bits / (8 ·
    /// T::BYTES)` — 4-byte lanes get the paper's W = 4/8, 8-byte
    /// lanes (u64, [`super::KeyValue`]) get W = 2/4.
    pub fn lanes_for<T: Lane>(self) -> usize {
        self.bits() / (8 * T::BYTES)
    }

    /// Both widths, for sweeps.
    pub fn all() -> [VectorWidth; 2] {
        [VectorWidth::V128, VectorWidth::V256]
    }

    /// Display label (matches the type names).
    pub fn name(self) -> &'static str {
        match self {
            VectorWidth::V128 => "V128",
            VectorWidth::V256 => "V256",
        }
    }
}
