//! The 128-bit, 4-lane vector register type.

use super::backend::{self, B128};
use super::lane::Lane;
use super::vector::{Lanes, Vector};
use super::W;

/// A NEON `q`-register stand-in: four 32-bit lanes, 16-byte aligned.
///
/// Lane 0 is the lowest-addressed element on load (NEON `vld1q`
/// little-endian convention). All shuffle names follow the AArch64
/// instruction they model so kernels read like the paper's listings;
/// every op dispatches through [`super::backend`] to the active
/// lowering — the NEON instruction itself on `aarch64`, its xmm
/// equivalent on `x86_64`, or the scalar reference formula:
///
/// | method        | NEON instruction | x86 lowering            |
/// |---------------|------------------|-------------------------|
/// | [`V128::min`] | `vminq`          | `pminsd`/`pminud`/`minps` |
/// | [`V128::max`] | `vmaxq`          | `pmaxsd`/`pmaxud`/`maxps` |
/// | [`V128::zip1`]| `vzip1q`         | `punpckldq`             |
/// | [`V128::zip2`]| `vzip2q`         | `punpckhdq`             |
/// | [`V128::uzp1`]| `vuzp1q`         | `shufps 0x88`           |
/// | [`V128::uzp2`]| `vuzp2q`         | `shufps 0xDD`           |
/// | [`V128::trn1`]| `vtrn1q`         | `psllq` + `pblendw`     |
/// | [`V128::trn2`]| `vtrn2q`         | `psrlq` + `pblendw`     |
/// | [`V128::rev64`]| `vrev64q`       | `pshufd 0xB1`           |
/// | [`V128::reverse`]| `vrev64q`+`vextq` | `pshufd 0x1B`      |
///
/// Memory ops (`splat`/`load`/`store`/`lane`) stay direct array code
/// on every backend: each is a single guaranteed 16-byte move
/// (`ldr q` / `movups`) with no lane arithmetic to dispatch.
#[derive(Clone, Copy, PartialEq, Debug)]
#[repr(C, align(16))]
pub struct V128<T: Lane>(pub [T; W]);

impl<T: Lane> V128<T> {
    /// The raw register bits, for backend dispatch.
    #[inline(always)]
    fn bits(self) -> B128 {
        backend::to_b128(self)
    }

    /// Rebuild from raw register bits.
    #[inline(always)]
    fn of(b: B128) -> Self {
        backend::from_b128(b)
    }

    /// Broadcast one scalar to all lanes (`vdupq_n`).
    #[inline(always)]
    pub fn splat(v: T) -> Self {
        V128([v; W])
    }

    /// Load four contiguous lanes from `src` (`vld1q`). Panics if
    /// `src.len() < 4` — kernels guarantee whole-vector access.
    #[inline(always)]
    pub fn load(src: &[T]) -> Self {
        V128([src[0], src[1], src[2], src[3]])
    }

    /// Store four lanes to `dst` (`vst1q`).
    #[inline(always)]
    pub fn store(self, dst: &mut [T]) {
        dst[..W].copy_from_slice(&self.0);
    }

    /// Lane accessor (`vgetq_lane`).
    #[inline(always)]
    pub fn lane(self, i: usize) -> T {
        self.0[i]
    }

    /// Lane-wise minimum (`vminq`) — one half of a vector comparator.
    #[inline(always)]
    pub fn min(self, o: Self) -> Self {
        Self::of(T::min128(self.bits(), o.bits()))
    }

    /// Lane-wise maximum (`vmaxq`) — the other half of a comparator.
    #[inline(always)]
    pub fn max(self, o: Self) -> Self {
        Self::of(T::max128(self.bits(), o.bits()))
    }

    /// Vector comparator: returns `(min, max)` lane-wise. This is the
    /// paper's "Comparator" applied across R registers in column sort —
    /// exactly two instructions, no branches, no shuffles.
    #[inline(always)]
    pub fn cmpswap(self, o: Self) -> (Self, Self) {
        (self.min(o), self.max(o))
    }

    /// Interleave low halves (`vzip1q`): `[a0,b0,a1,b1]`.
    #[inline(always)]
    pub fn zip1(self, o: Self) -> Self {
        Self::of(backend::zip1_32(self.bits(), o.bits()))
    }

    /// Interleave high halves (`vzip2q`): `[a2,b2,a3,b3]`.
    #[inline(always)]
    pub fn zip2(self, o: Self) -> Self {
        Self::of(backend::zip2_32(self.bits(), o.bits()))
    }

    /// De-interleave even lanes (`vuzp1q`): `[a0,a2,b0,b2]`.
    #[inline(always)]
    pub fn uzp1(self, o: Self) -> Self {
        Self::of(backend::uzp1_32(self.bits(), o.bits()))
    }

    /// De-interleave odd lanes (`vuzp2q`): `[a1,a3,b1,b3]`.
    #[inline(always)]
    pub fn uzp2(self, o: Self) -> Self {
        Self::of(backend::uzp2_32(self.bits(), o.bits()))
    }

    /// Transpose even lanes (`vtrn1q`): `[a0,b0,a2,b2]`.
    #[inline(always)]
    pub fn trn1(self, o: Self) -> Self {
        Self::of(backend::trn1_32(self.bits(), o.bits()))
    }

    /// Transpose odd lanes (`vtrn2q`): `[a1,b1,a3,b3]`.
    #[inline(always)]
    pub fn trn2(self, o: Self) -> Self {
        Self::of(backend::trn2_32(self.bits(), o.bits()))
    }

    /// Reverse 32-bit lanes within each 64-bit half (`vrev64q_u32`):
    /// `[a1,a0,a3,a2]`.
    #[inline(always)]
    pub fn rev64(self) -> Self {
        Self::of(backend::rev64_32(self.bits()))
    }

    /// Swap the two 64-bit halves (`vextq #8`): `[a2,a3,a0,a1]`.
    #[inline(always)]
    pub fn swap_halves(self) -> Self {
        Self::of(backend::swap64(self.bits()))
    }

    /// Full lane reversal `[a3,a2,a1,a0]` — `vrev64q` + `vextq`, used to
    /// form the bitonic sequence before a merge network.
    #[inline(always)]
    pub fn reverse(self) -> Self {
        Self::of(backend::rev_32(self.bits()))
    }

    /// Materialize as a plain array.
    #[inline(always)]
    pub fn to_array(self) -> [T; W] {
        self.0
    }

    /// Blend low half of `lo` with high half of `hi`:
    /// `[lo0, lo1, hi2, hi3]` — one `blendps`/`vbslq`, used by the
    /// distance-2 stage of the in-register bitonic merge.
    #[inline(always)]
    pub fn blend_lo_hi(lo: Self, hi: Self) -> Self {
        Self::of(backend::blend64_lo_hi(lo.bits(), hi.bits()))
    }

    /// Blend even lanes of `ev` with odd lanes of `od`:
    /// `[ev0, od1, ev2, od3]` — the distance-1 stage blend.
    #[inline(always)]
    pub fn blend_even_odd(ev: Self, od: Self) -> Self {
        Self::of(backend::blend_even_odd_32(ev.bits(), od.bits()))
    }

    /// Blend outer lanes of `a` with inner lanes of `b`:
    /// `[a0, b1, b2, a3]` — the ascending/descending pair stage of
    /// the 4-lane sorter.
    #[inline(always)]
    pub fn blend_outer_inner(a: Self, b: Self) -> Self {
        Self::of(backend::blend_outer_32(a.bits(), b.bits()))
    }

    /// Interleave low 64-bit halves (`vzip1q_u64`): lanes
    /// `[a0, a1, b0, b1]` — the transpose stage-2 exchange.
    #[inline(always)]
    pub fn zip_lo64(self, o: Self) -> Self {
        Self::of(backend::zip1_64(self.bits(), o.bits()))
    }

    /// Interleave high 64-bit halves (`vzip2q_u64`): lanes
    /// `[a2, a3, b2, b3]`.
    #[inline(always)]
    pub fn zip_hi64(self, o: Self) -> Self {
        Self::of(backend::zip2_64(self.bits(), o.bits()))
    }
}

impl<T: Lane> Lanes for V128<T> {
    const LANES: usize = W;
    const LANE_BYTES: usize = 4;
}

impl<T: Lane> Vector<T> for V128<T> {
    #[inline(always)]
    fn splat(v: T) -> Self {
        V128::splat(v)
    }

    #[inline(always)]
    fn load(src: &[T]) -> Self {
        V128::load(src)
    }

    #[inline(always)]
    fn store(self, dst: &mut [T]) {
        V128::store(self, dst)
    }

    #[inline(always)]
    fn lane(self, i: usize) -> T {
        V128::lane(self, i)
    }

    #[inline(always)]
    fn min(self, o: Self) -> Self {
        V128::min(self, o)
    }

    #[inline(always)]
    fn max(self, o: Self) -> Self {
        V128::max(self, o)
    }

    #[inline(always)]
    fn reverse(self) -> Self {
        V128::reverse(self)
    }

    /// Distance-2 + distance-1 half-cleaners: 2 shuffles, 2 blends,
    /// 2 min, 2 max — the NEON `vrev64`/`vext` idiom.
    #[inline(always)]
    fn bitonic_merge_lanes(self) -> Self {
        let s = self.swap_halves();
        let r = V128::blend_lo_hi(self.min(s), self.max(s));
        let s = r.rev64();
        V128::blend_even_odd(r.min(s), r.max(s))
    }

    /// Tiny bitonic sorter: 3 stages, 6 comparator-lanes.
    #[inline(always)]
    fn sort_lanes(self) -> Self {
        // Stage 1: (0,1),(2,3) ascending/descending → bitonic pairs:
        // keep min in the outer lanes, max in the inner.
        let s = self.rev64();
        let mn = self.min(s);
        let mx = self.max(s);
        Vector::bitonic_merge_lanes(V128::blend_outer_inner(mn, mx))
    }

    #[inline(always)]
    fn transpose_tile(tile: &mut [Self]) {
        assert_eq!(tile.len(), W, "V128 tile is 4x4");
        let t = transpose4([tile[0], tile[1], tile[2], tile[3]]);
        tile.copy_from_slice(&t);
    }
}

/// 4×4 in-register matrix transpose — the paper's *base matrix
/// transpose* (§2.3): an `R×W` transpose decomposes into `R/W` of
/// these. Exactly the NEON `vtrnq` + 64-bit `vzip` idiom (8 shuffles,
/// no memory traffic).
#[inline(always)]
pub fn transpose4<T: Lane>(r: [V128<T>; 4]) -> [V128<T>; 4] {
    // Stage 1: 32-bit transpose pairs (vtrn1/vtrn2).
    let t0 = r[0].trn1(r[1]); // [a0 b0 a2 b2]
    let t1 = r[0].trn2(r[1]); // [a1 b1 a3 b3]
    let t2 = r[2].trn1(r[3]); // [c0 d0 c2 d2]
    let t3 = r[2].trn2(r[3]); // [c1 d1 c3 d3]
    // Stage 2: 64-bit element exchange (vzip1q_u64 / vzip2q_u64).
    let o0 = t0.zip_lo64(t2); // [a0 b0 c0 d0]
    let o1 = t1.zip_lo64(t3); // [a1 b1 c1 d1]
    let o2 = t0.zip_hi64(t2); // [a2 b2 c2 d2]
    let o3 = t1.zip_hi64(t3); // [a3 b3 c3 d3]
    [o0, o1, o2, o3]
}

/// Transpose an `R×4` register matrix (R a multiple of 4) in place,
/// viewing it as `R/4` stacked 4×4 tiles: tile (i,j) of the logical
/// `4×R` result is the transpose of tile (j,i) of the input. The result
/// is returned in row-major order of the `4×R` matrix flattened back
/// into `R` registers: output register `k` holds lanes
/// `[out_row, out_col..]` such that reading output registers
/// `j*stride..j*stride+stride` concatenates logical row `j`.
///
/// Concretely, for the in-register sort we need: after column-sorting
/// an `R×4` matrix, produce 4 sorted runs of length `R`, each run
/// contiguous across `R/4` registers. `transpose_rx4` delivers run `j`
/// in output registers `j*R/4 .. (j+1)*R/4`.
pub fn transpose_rx4<T: Lane>(regs: &mut [V128<T>]) {
    let r = regs.len();
    assert!(r % 4 == 0, "R must be a multiple of W=4");
    assert!(
        r <= super::NEON_REGISTER_FILE,
        "R={r} exceeds the architectural register file ({})",
        super::NEON_REGISTER_FILE
    );
    let tiles = r / 4;
    // Stack tile buffer bounded by the register-file size — this runs
    // inside the in-register pass, which must not touch the heap.
    let mut out = [V128::splat(T::MIN_VALUE); super::NEON_REGISTER_FILE];
    for t in 0..tiles {
        let tile = transpose4([regs[4 * t], regs[4 * t + 1], regs[4 * t + 2], regs[4 * t + 3]]);
        // Row j of this tile is the slice [4t .. 4t+4) of sorted run j;
        // place it at output register j*tiles + t.
        for (j, row) in tile.into_iter().enumerate() {
            out[j * tiles + t] = row;
        }
    }
    regs.copy_from_slice(&out[..r]);
}
