//! Cross-backend equivalence suite.
//!
//! Every backend compiled into this binary is property-tested against
//! the scalar reference model: raw geometry/comparator ops through the
//! `*_with` twins (no global state touched), then register-type ops,
//! transposes, run mergers, and full sorts under a forced global
//! backend (serialized by a lock). The forced-`scalar` test pins the
//! pre-backend semantics bit-for-bit.

use std::sync::Mutex;

use super::*;
use crate::kernels::runmerge::RunMerger;
use crate::kernels::{MergeImpl, MergeWidth};
use crate::simd::{transpose4, KeyValue, V128, V128D, Vector, VectorWidth};
use crate::sort::{NeonMergeSort, SortConfig};
use crate::testutil::Rng;

/// Serializes the tests that mutate the process-global backend. Every
/// backend sorts correctly, so concurrent tests elsewhere stay valid
/// whichever backend is active while they run; the lock only keeps
/// *these* tests from interleaving their force/restore pairs.
static FORCE_LOCK: Mutex<()> = Mutex::new(());

fn available() -> Vec<Backend> {
    Backend::all().into_iter().filter(|k| k.available()).collect()
}

/// Run `f` once per available backend with that backend forced
/// globally, restoring the previous selection afterwards.
fn with_each_backend(f: impl Fn(Backend)) {
    let guard = FORCE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = active();
    for k in available() {
        force(k).unwrap();
        f(k);
    }
    force(prev).unwrap();
    drop(guard);
}

fn pack32(v: [u32; 4]) -> B128 {
    let mut o = [0u8; 16];
    for (i, x) in v.iter().enumerate() {
        o[4 * i..4 * i + 4].copy_from_slice(&x.to_le_bytes());
    }
    B128(o)
}

fn unpack32(b: B128) -> [u32; 4] {
    let mut v = [0u32; 4];
    for (i, x) in v.iter_mut().enumerate() {
        let mut w = [0u8; 4];
        w.copy_from_slice(&b.0[4 * i..4 * i + 4]);
        *x = u32::from_le_bytes(w);
    }
    v
}

fn pack64(v: [u64; 2]) -> B128 {
    let mut o = [0u8; 16];
    o[..8].copy_from_slice(&v[0].to_le_bytes());
    o[8..].copy_from_slice(&v[1].to_le_bytes());
    B128(o)
}

fn rnd128(rng: &mut Rng) -> B128 {
    let mut o = [0u8; 16];
    o[..8].copy_from_slice(&rng.next_u64().to_le_bytes());
    o[8..].copy_from_slice(&rng.next_u64().to_le_bytes());
    B128(o)
}

fn rnd256(rng: &mut Rng) -> B256 {
    join128(rnd128(rng), rnd128(rng))
}

#[test]
fn backend_names_parse_round_trip() {
    for k in Backend::all() {
        assert_eq!(Backend::parse(k.name()), Some(k), "{}", k.name());
        assert_eq!(Backend::parse(&k.name().to_uppercase()), Some(k));
    }
    assert_eq!(Backend::parse("sse42"), Some(Backend::Sse42));
    assert_eq!(Backend::parse(" neon "), Some(Backend::Neon));
    assert_eq!(Backend::parse("avx512"), None);
    assert_eq!(Backend::parse("auto"), None, "auto is a policy, not a backend");
}

#[test]
fn scalar_is_always_available_and_detection_picks_available() {
    assert!(Backend::Scalar.available());
    assert!(detect().available());
    // The intrinsic backends are compile-time impossible off their
    // arch, whatever the CPU says.
    #[cfg(not(target_arch = "aarch64"))]
    assert!(!Backend::Neon.available());
    #[cfg(not(target_arch = "x86_64"))]
    {
        assert!(!Backend::Sse42.available());
        assert!(!Backend::Avx2.available());
    }
}

#[test]
fn env_resolution_policy() {
    assert_eq!(resolve_env(None).unwrap(), detect());
    assert_eq!(resolve_env(Some("")).unwrap(), detect());
    assert_eq!(resolve_env(Some("auto")).unwrap(), detect());
    assert_eq!(resolve_env(Some("AUTO")).unwrap(), detect());
    // Forcing scalar is honored on every machine.
    assert_eq!(resolve_env(Some("scalar")).unwrap(), Backend::Scalar);
    let err = resolve_env(Some("sse9")).unwrap_err();
    assert!(err.contains("unknown SIMD backend"), "{err}");
    // An explicitly requested but unavailable backend must error, not
    // silently fall back.
    if let Some(missing) = Backend::all().into_iter().find(|k| !k.available()) {
        let err = resolve_env(Some(missing.name())).unwrap_err();
        assert!(err.contains("not available"), "{err}");
    }
}

#[test]
fn active_backend_is_available_and_named() {
    let k = active();
    assert!(k.available());
    assert!(!k.name().is_empty());
}

#[test]
fn forcing_unavailable_backend_errors_and_leaves_selection() {
    let _guard = FORCE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = active();
    if let Some(missing) = Backend::all().into_iter().find(|k| !k.available()) {
        assert!(force(missing).is_err());
        assert_eq!(active(), prev, "failed force must not change the selection");
    }
}

type Op2 = fn(Backend, B128, B128) -> B128;
type Op1 = fn(Backend, B128) -> B128;

const OPS2: [(&str, Op2); 11] = [
    ("zip1_32", zip1_32_with),
    ("zip2_32", zip2_32_with),
    ("uzp1_32", uzp1_32_with),
    ("uzp2_32", uzp2_32_with),
    ("trn1_32", trn1_32_with),
    ("trn2_32", trn2_32_with),
    ("blend64_lo_hi", blend64_lo_hi_with),
    ("blend_even_odd_32", blend_even_odd_32_with),
    ("blend_outer_32", blend_outer_32_with),
    ("zip1_64", zip1_64_with),
    ("zip2_64", zip2_64_with),
];

const OPS1: [(&str, Op1); 3] =
    [("rev64_32", rev64_32_with), ("swap64", swap64_with), ("rev_32", rev_32_with)];

#[test]
fn geometry_ops_match_scalar_on_every_backend() {
    let mut rng = Rng::new(0x9e01);
    for _ in 0..256 {
        let (a, b) = (rnd128(&mut rng), rnd128(&mut rng));
        for k in available() {
            for (name, op) in OPS2 {
                assert_eq!(
                    op(k, a, b),
                    op(Backend::Scalar, a, b),
                    "{name} diverges on {k} for {a:?} {b:?}"
                );
            }
            for (name, op) in OPS1 {
                assert_eq!(
                    op(k, a),
                    op(Backend::Scalar, a),
                    "{name} diverges on {k} for {a:?}"
                );
            }
        }
    }
}

#[test]
fn scalar_geometry_is_the_reference_model() {
    // Pin the scalar lowering to the literal NEON lane formulas the
    // register types exposed before the backend refactor.
    let a = pack32([0, 1, 2, 3]);
    let b = pack32([10, 11, 12, 13]);
    let cases: [(&str, Op2, [u32; 4]); 11] = [
        ("zip1_32", zip1_32_with, [0, 10, 1, 11]),
        ("zip2_32", zip2_32_with, [2, 12, 3, 13]),
        ("uzp1_32", uzp1_32_with, [0, 2, 10, 12]),
        ("uzp2_32", uzp2_32_with, [1, 3, 11, 13]),
        ("trn1_32", trn1_32_with, [0, 10, 2, 12]),
        ("trn2_32", trn2_32_with, [1, 11, 3, 13]),
        ("blend64_lo_hi", blend64_lo_hi_with, [0, 1, 12, 13]),
        ("blend_even_odd_32", blend_even_odd_32_with, [0, 11, 2, 13]),
        ("blend_outer_32", blend_outer_32_with, [0, 11, 12, 3]),
        ("zip1_64", zip1_64_with, [0, 1, 10, 11]),
        ("zip2_64", zip2_64_with, [2, 3, 12, 13]),
    ];
    for (name, op, expect) in cases {
        assert_eq!(unpack32(op(Backend::Scalar, a, b)), expect, "{name}");
    }
    assert_eq!(unpack32(rev64_32_with(Backend::Scalar, a)), [1, 0, 3, 2]);
    assert_eq!(unpack32(swap64_with(Backend::Scalar, a)), [2, 3, 0, 1]);
    assert_eq!(unpack32(rev_32_with(Backend::Scalar, a)), [3, 2, 1, 0]);
}

#[test]
fn comparators_128_match_scalar_on_every_backend() {
    let mut rng = Rng::new(0x9e02);
    type MM = fn(Backend, B128, B128) -> B128;
    let int_ops: [(&str, MM); 6] = [
        ("min128_i32", min128_i32_with),
        ("max128_i32", max128_i32_with),
        ("min128_u32", min128_u32_with),
        ("max128_u32", max128_u32_with),
        ("min128_u64", min128_u64_with),
        ("max128_u64", max128_u64_with),
    ];
    for _ in 0..256 {
        let (a, b) = (rnd128(&mut rng), rnd128(&mut rng));
        for k in available() {
            for (name, op) in int_ops {
                assert_eq!(
                    op(k, a, b),
                    op(Backend::Scalar, a, b),
                    "{name} diverges on {k}"
                );
            }
        }
    }
    // u64 comparators must order across the sign bit (the sign-flip
    // trick's raison d'être).
    let hi = pack64([u64::MAX, 1 << 63]);
    let lo = pack64([0, (1 << 63) - 1]);
    for k in available() {
        assert_eq!(min128_u64_with(k, hi, lo), lo, "u64 min sign boundary on {k}");
        assert_eq!(max128_u64_with(k, hi, lo), hi, "u64 max sign boundary on {k}");
    }
}

#[test]
fn f32_comparators_match_scalar_on_every_backend() {
    // Finite floats, infinities, and both zero signs — every non-NaN
    // shape the sort contract admits. Ties must resolve to the same
    // *bits* on every backend (the ±0.0 cases pin operand order).
    let pool: [f32; 10] = [
        f32::NEG_INFINITY,
        -3.5,
        -1.0,
        -0.0,
        0.0,
        0.25,
        1.0,
        3.5,
        1e30,
        f32::INFINITY,
    ];
    let mut rng = Rng::new(0x9e03);
    let pick = |rng: &mut Rng| {
        let v: [f32; 4] = [
            pool[rng.below(pool.len())],
            pool[rng.below(pool.len())],
            pool[rng.below(pool.len())],
            pool[rng.below(pool.len())],
        ];
        pack32(v.map(f32::to_bits))
    };
    for _ in 0..512 {
        let (a, b) = (pick(&mut rng), pick(&mut rng));
        for k in available() {
            assert_eq!(
                min128_f32_with(k, a, b),
                min128_f32_with(Backend::Scalar, a, b),
                "min128_f32 diverges on {k}"
            );
            assert_eq!(
                max128_f32_with(k, a, b),
                max128_f32_with(Backend::Scalar, a, b),
                "max128_f32 diverges on {k}"
            );
        }
    }
}

#[test]
fn comparators_256_match_scalar_on_every_backend() {
    let mut rng = Rng::new(0x9e04);
    type MM = fn(Backend, B256, B256) -> B256;
    let ops: [(&str, MM); 6] = [
        ("min256_i32", min256_i32_with),
        ("max256_i32", max256_i32_with),
        ("min256_u32", min256_u32_with),
        ("max256_u32", max256_u32_with),
        ("min256_u64", min256_u64_with),
        ("max256_u64", max256_u64_with),
    ];
    for _ in 0..256 {
        let (a, b) = (rnd256(&mut rng), rnd256(&mut rng));
        for k in available() {
            for (name, op) in ops {
                assert_eq!(
                    op(k, a, b),
                    op(Backend::Scalar, a, b),
                    "{name} diverges on {k}"
                );
            }
        }
    }
    // f32 over the tie-pinning pool, splatted across halves.
    let x = pack32([(-0.0f32).to_bits(), 0.0f32.to_bits(), 1.5f32.to_bits(), (-1.5f32).to_bits()]);
    let y = pack32([0.0f32.to_bits(), (-0.0f32).to_bits(), (-1.5f32).to_bits(), 1.5f32.to_bits()]);
    let (a, b) = (join128(x, y), join128(y, x));
    for k in available() {
        assert_eq!(min256_f32_with(k, a, b), min256_f32_with(Backend::Scalar, a, b));
        assert_eq!(max256_f32_with(k, a, b), max256_f32_with(Backend::Scalar, a, b));
    }
}

#[test]
fn register_sort_and_transpose_match_oracle_under_every_backend() {
    with_each_backend(|k| {
        // Zero-one principle: all 16 four-lane 0/1 patterns sort.
        for pat in 0u32..16 {
            let v = V128([pat & 1, (pat >> 1) & 1, (pat >> 2) & 1, (pat >> 3) & 1]);
            let mut expect = v.to_array();
            expect.sort_unstable();
            assert_eq!(Vector::sort_lanes(v).to_array(), expect, "V128 0/1 {pat:04b} on {k}");
        }
        for pat in 0u64..4 {
            let v = V128D([pat & 1, (pat >> 1) & 1]);
            let mut expect = v.to_array();
            expect.sort_unstable();
            assert_eq!(Vector::sort_lanes(v).to_array(), expect, "V128D 0/1 {pat:02b} on {k}");
        }
        // Random lanes through sort_lanes and the 4×4 transpose.
        let mut rng = Rng::new(0x9e05 ^ k as u64);
        for _ in 0..64 {
            let v = V128([rng.next_u32(), rng.next_u32(), rng.next_u32(), rng.next_u32()]);
            let mut expect = v.to_array();
            expect.sort_unstable();
            assert_eq!(Vector::sort_lanes(v).to_array(), expect, "V128 sort_lanes on {k}");

            let m: [[u32; 4]; 4] = core::array::from_fn(|_| core::array::from_fn(|_| rng.next_u32()));
            let t = transpose4([V128(m[0]), V128(m[1]), V128(m[2]), V128(m[3])]);
            for (i, row) in t.iter().enumerate() {
                for j in 0..4 {
                    assert_eq!(row.lane(j), m[j][i], "transpose4[{i}][{j}] on {k}");
                }
            }
        }
    });
}

#[test]
fn run_mergers_match_oracle_on_every_backend() {
    with_each_backend(|k| {
        let mut rng = Rng::new(0x9e06 ^ k as u64);
        for vector in VectorWidth::all() {
            for width in MergeWidth::all() {
                for imp in [MergeImpl::Vectorized, MergeImpl::Hybrid, MergeImpl::Serial] {
                    let m = RunMerger { width, imp, vector };
                    // Random sorted runs (u32), including a ragged pair.
                    for (la, lb) in [(256usize, 256usize), (128, 320), (96, 7)] {
                        let mut a: Vec<u32> = (0..la).map(|_| rng.next_u32()).collect();
                        let mut b: Vec<u32> = (0..lb).map(|_| rng.next_u32()).collect();
                        a.sort_unstable();
                        b.sort_unstable();
                        let mut expect = [a.clone(), b.clone()].concat();
                        expect.sort_unstable();
                        let mut out = vec![0u32; la + lb];
                        m.merge(&a, &b, &mut out);
                        assert_eq!(out, expect, "u32 merge {la}+{lb} 2x{} {imp:?} {} on {k}", width.k(), vector.name());
                    }
                    // Zero-one sweep: every split of 0s/1s in two runs
                    // of 8 — the boundary cases of the merge network.
                    for i in 0..=8usize {
                        for j in 0..=8usize {
                            let a: Vec<u32> = (0..8).map(|x| u32::from(x >= i)).collect();
                            let b: Vec<u32> = (0..8).map(|x| u32::from(x >= j)).collect();
                            let mut expect = [a.clone(), b.clone()].concat();
                            expect.sort_unstable();
                            let mut out = vec![0u32; 16];
                            m.merge(&a, &b, &mut out);
                            assert_eq!(out, expect, "0/1 merge {i}/{j} 2x{} {imp:?} {} on {k}", width.k(), vector.name());
                        }
                    }
                    // 64-bit lanes ride the same merger.
                    let mut a: Vec<u64> = (0..200).map(|_| rng.next_u64()).collect();
                    let mut b: Vec<u64> = (0..120).map(|_| rng.next_u64()).collect();
                    a.sort_unstable();
                    b.sort_unstable();
                    let mut expect = [a.clone(), b.clone()].concat();
                    expect.sort_unstable();
                    let mut out = vec![0u64; 320];
                    m.merge(&a, &b, &mut out);
                    assert_eq!(out, expect, "u64 merge 2x{} {imp:?} {} on {k}", width.k(), vector.name());
                }
            }
        }
    });
}

#[test]
fn full_sorts_match_oracle_on_every_backend_and_combo() {
    with_each_backend(|k| {
        let mut rng = Rng::new(0x9e07 ^ k as u64);
        for vector in VectorWidth::all() {
            for width in [MergeWidth::K4, MergeWidth::K16, MergeWidth::K64] {
                let s = NeonMergeSort::new(SortConfig {
                    merge_width: width,
                    vector_width: vector,
                    ..Default::default()
                });
                let n = 2048 + rng.below(512);

                let data: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
                let mut expect = data.clone();
                expect.sort_unstable();
                assert_eq!(s.sorted(&data), expect, "u32 on {k} 2x{} {}", width.k(), vector.name());

                let data: Vec<i32> = (0..n).map(|_| rng.next_i32()).collect();
                let mut expect = data.clone();
                expect.sort_unstable();
                assert_eq!(s.sorted(&data), expect, "i32 on {k}");

                let data: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
                let mut expect = data.clone();
                expect.sort_unstable_by(|x, y| x.partial_cmp(y).unwrap());
                let got = s.sorted(&data);
                assert!(
                    got.iter().zip(&expect).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "f32 on {k}"
                );

                let data: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
                let mut expect = data.clone();
                expect.sort_unstable();
                assert_eq!(s.sorted(&data), expect, "u64 on {k}");

                let data: Vec<KeyValue> =
                    (0..n).map(|_| KeyValue::new(rng.next_u32() % 97, rng.next_u32())).collect();
                let mut expect = data.clone();
                expect.sort_unstable();
                assert_eq!(s.sorted(&data), expect, "KeyValue on {k}");

                // Zero-one array (many equal keys, all merge paths).
                let data: Vec<u32> = (0..n).map(|_| rng.next_u32() & 1).collect();
                let mut expect = data.clone();
                expect.sort_unstable();
                assert_eq!(s.sorted(&data), expect, "0/1 u32 on {k}");
            }
        }
    });
}

#[test]
fn forced_scalar_reproduces_reference_semantics_bit_for_bit() {
    let _guard = FORCE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = active();
    force(Backend::Scalar).unwrap();

    // The register-type ops under a forced scalar backend are the
    // pre-backend array formulas, verbatim.
    let a = V128([0u32, 1, 2, 3]);
    let b = V128([10u32, 11, 12, 13]);
    assert_eq!(a.zip1(b).to_array(), [0, 10, 1, 11]);
    assert_eq!(a.zip2(b).to_array(), [2, 12, 3, 13]);
    assert_eq!(a.uzp1(b).to_array(), [0, 2, 10, 12]);
    assert_eq!(a.uzp2(b).to_array(), [1, 3, 11, 13]);
    assert_eq!(a.trn1(b).to_array(), [0, 10, 2, 12]);
    assert_eq!(a.trn2(b).to_array(), [1, 11, 3, 13]);
    assert_eq!(a.rev64().to_array(), [1, 0, 3, 2]);
    assert_eq!(a.swap_halves().to_array(), [2, 3, 0, 1]);
    assert_eq!(a.reverse().to_array(), [3, 2, 1, 0]);
    assert_eq!(V128::blend_lo_hi(a, b).to_array(), [0, 1, 12, 13]);
    assert_eq!(V128::blend_even_odd(a, b).to_array(), [0, 11, 2, 13]);
    let d = V128D([7u64, 3]);
    let e = V128D([9u64, 5]);
    assert_eq!(d.trn1(e).to_array(), [7, 9]);
    assert_eq!(d.trn2(e).to_array(), [3, 5]);
    assert_eq!(d.reverse().to_array(), [3, 7]);
    assert_eq!(d.min(e).to_array(), [7, 3]);
    assert_eq!(d.max(e).to_array(), [9, 5]);
    assert_eq!(Vector::sort_lanes(V128([3u32, 1, 4, 1])).to_array(), [1, 1, 3, 4]);
    assert_eq!(Vector::sort_lanes(d).to_array(), [3, 7]);

    // A full sort under forced scalar is byte-identical to the
    // deterministic oracle — "today's results", pinned.
    let mut rng = Rng::new(20240908);
    let data: Vec<u32> = (0..100_000).map(|_| rng.next_u32()).collect();
    let mut expect = data.clone();
    expect.sort_unstable();
    let s = NeonMergeSort::new(SortConfig::default());
    assert_eq!(s.sorted(&data), expect);
    assert_eq!(active(), Backend::Scalar, "sort must not drift the forced selection");

    force(prev).unwrap();
}

#[test]
fn sort_config_backend_override_forces_process_backend() {
    let _guard = FORCE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = active();
    let s = NeonMergeSort::new(SortConfig { backend: Some(Backend::Scalar), ..Default::default() });
    assert_eq!(active(), Backend::Scalar);
    let mut data: Vec<u32> = (0..5000u32).rev().collect();
    s.sort(&mut data);
    assert_eq!(data, (0..5000).collect::<Vec<u32>>());
    force(prev).unwrap();
}
