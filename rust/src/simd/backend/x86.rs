//! SSE4.2 / AVX2 lowering of the register-model ops
//! (`x86_64` builds only).
//!
//! Every function here is `unsafe fn` gated on the features the
//! dispatcher verified at runtime (`#[target_feature]`); the
//! dispatchers in [`super`] are the only callers and only reach these
//! after `is_x86_feature_detected!` said yes.
//!
//! Lane-order note: the scalar model's lane `i` is byte offset `4*i`,
//! which is exactly the x86 "low lane first" convention, so NEON-named
//! ops map directly: `zip1` ↔ `punpckldq`, `uzp1` ↔ `shufps 0x88`,
//! `rev64` ↔ `pshufd 0xB1`, and so on. Each mapping is property-tested
//! against the scalar oracle in `backend::tests` and mirrored in
//! `tools/verify_backend_lowering.py`.

#![allow(unsafe_op_in_unsafe_fn)]

use core::arch::x86_64::*;

use super::{B128, B256};

#[inline(always)]
unsafe fn ld(a: B128) -> __m128i {
    // SSE2 is x86_64 baseline, so the unaligned load needs no gate
    // (B128 is 16-aligned anyway).
    _mm_loadu_si128(a.0.as_ptr() as *const __m128i)
}

#[inline(always)]
unsafe fn st(v: __m128i) -> B128 {
    let mut o = B128([0; 16]);
    _mm_storeu_si128(o.0.as_mut_ptr() as *mut __m128i, v);
    o
}

#[inline(always)]
unsafe fn ldf(a: B128) -> __m128 {
    _mm_castsi128_ps(ld(a))
}

#[inline(always)]
unsafe fn stf(v: __m128) -> B128 {
    st(_mm_castps_si128(v))
}

// -- geometry ---------------------------------------------------------

#[inline]
#[target_feature(enable = "sse4.1,sse4.2")]
pub(crate) unsafe fn zip1_32(a: B128, b: B128) -> B128 {
    st(_mm_unpacklo_epi32(ld(a), ld(b)))
}

#[inline]
#[target_feature(enable = "sse4.1,sse4.2")]
pub(crate) unsafe fn zip2_32(a: B128, b: B128) -> B128 {
    st(_mm_unpackhi_epi32(ld(a), ld(b)))
}

#[inline]
#[target_feature(enable = "sse4.1,sse4.2")]
pub(crate) unsafe fn uzp1_32(a: B128, b: B128) -> B128 {
    // shufps imm 0x88 = lanes (2,0) of b over (2,0) of a → [a0,a2,b0,b2].
    stf(_mm_shuffle_ps(ldf(a), ldf(b), 0x88))
}

#[inline]
#[target_feature(enable = "sse4.1,sse4.2")]
pub(crate) unsafe fn uzp2_32(a: B128, b: B128) -> B128 {
    // shufps imm 0xDD = lanes (3,1) / (3,1) → [a1,a3,b1,b3].
    stf(_mm_shuffle_ps(ldf(a), ldf(b), 0xDD))
}

#[inline]
#[target_feature(enable = "sse4.1,sse4.2")]
pub(crate) unsafe fn trn1_32(a: B128, b: B128) -> B128 {
    // [a0, b0, a2, b2]: even lanes of a, with b's even lanes shifted
    // up into the odd slots; pblendw mask 0xCC keeps a in lanes 0,2.
    st(_mm_blend_epi16(ld(a), _mm_slli_epi64(ld(b), 32), 0xCC))
}

#[inline]
#[target_feature(enable = "sse4.1,sse4.2")]
pub(crate) unsafe fn trn2_32(a: B128, b: B128) -> B128 {
    // [a1, b1, a3, b3]: a's odd lanes shifted down, b kept in 1,3.
    st(_mm_blend_epi16(_mm_srli_epi64(ld(a), 32), ld(b), 0xCC))
}

#[inline]
#[target_feature(enable = "sse4.1,sse4.2")]
pub(crate) unsafe fn rev64_32(a: B128) -> B128 {
    // pshufd imm 0xB1 = (2,3,0,1) → [a1,a0,a3,a2].
    st(_mm_shuffle_epi32(ld(a), 0xB1))
}

#[inline]
#[target_feature(enable = "sse4.1,sse4.2")]
pub(crate) unsafe fn swap64(a: B128) -> B128 {
    // pshufd imm 0x4E = (1,0,3,2) → [a2,a3,a0,a1].
    st(_mm_shuffle_epi32(ld(a), 0x4E))
}

#[inline]
#[target_feature(enable = "sse4.1,sse4.2")]
pub(crate) unsafe fn rev_32(a: B128) -> B128 {
    // pshufd imm 0x1B = (0,1,2,3) → [a3,a2,a1,a0].
    st(_mm_shuffle_epi32(ld(a), 0x1B))
}

#[inline]
#[target_feature(enable = "sse4.1,sse4.2")]
pub(crate) unsafe fn blend64_lo_hi(lo: B128, hi: B128) -> B128 {
    // pblendw mask 0xF0: low 4 words (64 bits) from lo, high from hi.
    st(_mm_blend_epi16(ld(lo), ld(hi), 0xF0))
}

#[inline]
#[target_feature(enable = "sse4.1,sse4.2")]
pub(crate) unsafe fn blend_even_odd_32(ev: B128, od: B128) -> B128 {
    // pblendw mask 0xCC: words 2,3,6,7 (= dword lanes 1,3) from od.
    st(_mm_blend_epi16(ld(ev), ld(od), 0xCC))
}

#[inline]
#[target_feature(enable = "sse4.1,sse4.2")]
pub(crate) unsafe fn blend_outer_32(a: B128, b: B128) -> B128 {
    // pblendw mask 0x3C: words 2..=5 (= dword lanes 1,2) from b.
    st(_mm_blend_epi16(ld(a), ld(b), 0x3C))
}

#[inline]
#[target_feature(enable = "sse4.1,sse4.2")]
pub(crate) unsafe fn zip1_64(a: B128, b: B128) -> B128 {
    st(_mm_unpacklo_epi64(ld(a), ld(b)))
}

#[inline]
#[target_feature(enable = "sse4.1,sse4.2")]
pub(crate) unsafe fn zip2_64(a: B128, b: B128) -> B128 {
    st(_mm_unpackhi_epi64(ld(a), ld(b)))
}

// -- comparators, 128-bit ---------------------------------------------

#[inline]
#[target_feature(enable = "sse4.1,sse4.2")]
pub(crate) unsafe fn min128_i32(a: B128, b: B128) -> B128 {
    st(_mm_min_epi32(ld(a), ld(b)))
}

#[inline]
#[target_feature(enable = "sse4.1,sse4.2")]
pub(crate) unsafe fn max128_i32(a: B128, b: B128) -> B128 {
    st(_mm_max_epi32(ld(a), ld(b)))
}

#[inline]
#[target_feature(enable = "sse4.1,sse4.2")]
pub(crate) unsafe fn min128_u32(a: B128, b: B128) -> B128 {
    st(_mm_min_epu32(ld(a), ld(b)))
}

#[inline]
#[target_feature(enable = "sse4.1,sse4.2")]
pub(crate) unsafe fn max128_u32(a: B128, b: B128) -> B128 {
    st(_mm_max_epu32(ld(a), ld(b)))
}

#[inline]
#[target_feature(enable = "sse4.1,sse4.2")]
pub(crate) unsafe fn min128_f32(a: B128, b: B128) -> B128 {
    // minps returns b on equal/zero ties, i.e. `a < b ? a : b` —
    // exactly the scalar model's select (NaN out of contract).
    stf(_mm_min_ps(ldf(a), ldf(b)))
}

#[inline]
#[target_feature(enable = "sse4.1,sse4.2")]
pub(crate) unsafe fn max128_f32(a: B128, b: B128) -> B128 {
    stf(_mm_max_ps(ldf(a), ldf(b)))
}

#[inline]
#[target_feature(enable = "sse4.1,sse4.2")]
pub(crate) unsafe fn min128_u64(a: B128, b: B128) -> B128 {
    // No pminuq below AVX-512: sign-flip to make pcmpgtq (SSE4.2)
    // order unsigned values, then blend the smaller on top.
    let (va, vb) = (ld(a), ld(b));
    let flip = _mm_set1_epi64x(i64::MIN);
    let a_gt_b = _mm_cmpgt_epi64(_mm_xor_si128(va, flip), _mm_xor_si128(vb, flip));
    st(_mm_blendv_epi8(va, vb, a_gt_b))
}

#[inline]
#[target_feature(enable = "sse4.1,sse4.2")]
pub(crate) unsafe fn max128_u64(a: B128, b: B128) -> B128 {
    let (va, vb) = (ld(a), ld(b));
    let flip = _mm_set1_epi64x(i64::MIN);
    let a_gt_b = _mm_cmpgt_epi64(_mm_xor_si128(va, flip), _mm_xor_si128(vb, flip));
    st(_mm_blendv_epi8(vb, va, a_gt_b))
}

// -- comparators, 256-bit (AVX2 only: native ymm) ---------------------

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn ld256(a: B256) -> __m256i {
    _mm256_loadu_si256(a.0.as_ptr() as *const __m256i)
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn st256(v: __m256i) -> B256 {
    let mut o = B256([0; 32]);
    _mm256_storeu_si256(o.0.as_mut_ptr() as *mut __m256i, v);
    o
}

#[inline]
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn min256_i32(a: B256, b: B256) -> B256 {
    st256(_mm256_min_epi32(ld256(a), ld256(b)))
}

#[inline]
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn max256_i32(a: B256, b: B256) -> B256 {
    st256(_mm256_max_epi32(ld256(a), ld256(b)))
}

#[inline]
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn min256_u32(a: B256, b: B256) -> B256 {
    st256(_mm256_min_epu32(ld256(a), ld256(b)))
}

#[inline]
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn max256_u32(a: B256, b: B256) -> B256 {
    st256(_mm256_max_epu32(ld256(a), ld256(b)))
}

#[inline]
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn min256_f32(a: B256, b: B256) -> B256 {
    st256(_mm256_castps_si256(_mm256_min_ps(
        _mm256_castsi256_ps(ld256(a)),
        _mm256_castsi256_ps(ld256(b)),
    )))
}

#[inline]
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn max256_f32(a: B256, b: B256) -> B256 {
    st256(_mm256_castps_si256(_mm256_max_ps(
        _mm256_castsi256_ps(ld256(a)),
        _mm256_castsi256_ps(ld256(b)),
    )))
}

#[inline]
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn min256_u64(a: B256, b: B256) -> B256 {
    let (va, vb) = (ld256(a), ld256(b));
    let flip = _mm256_set1_epi64x(i64::MIN);
    let a_gt_b = _mm256_cmpgt_epi64(_mm256_xor_si256(va, flip), _mm256_xor_si256(vb, flip));
    st256(_mm256_blendv_epi8(va, vb, a_gt_b))
}

#[inline]
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn max256_u64(a: B256, b: B256) -> B256 {
    let (va, vb) = (ld256(a), ld256(b));
    let flip = _mm256_set1_epi64x(i64::MIN);
    let a_gt_b = _mm256_cmpgt_epi64(_mm256_xor_si256(va, flip), _mm256_xor_si256(vb, flip));
    st256(_mm256_blendv_epi8(vb, va, a_gt_b))
}
