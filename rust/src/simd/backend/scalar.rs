//! Portable reference backend: every op as the plain array formula the
//! register model has used since PR 1.
//!
//! This is the oracle the intrinsic backends are property-tested
//! against, and the guaranteed fallback `NEONMS_SIMD_BACKEND=scalar`
//! selects on any machine. The formulas here must stay bit-for-bit
//! identical to the pre-backend register-type methods — the pinned
//! shuffle-semantics tests in `simd::tests` and the forced-scalar test
//! in `backend::tests` both enforce that.

use super::{B128, B256};
use crate::simd::Lane;

#[inline(always)]
fn u32x4(b: B128) -> [u32; 4] {
    // SAFETY: B128 is a repr(C, align(16)) wrapper over [u8; 16];
    // both types are 16 bytes with no invalid bit patterns.
    unsafe { core::mem::transmute(b) }
}

#[inline(always)]
fn b32(a: [u32; 4]) -> B128 {
    // SAFETY: as `u32x4`.
    unsafe { core::mem::transmute(a) }
}

#[inline(always)]
fn u64x2(b: B128) -> [u64; 2] {
    // SAFETY: as `u32x4` — 16 bytes either way.
    unsafe { core::mem::transmute(b) }
}

#[inline(always)]
fn b64(a: [u64; 2]) -> B128 {
    // SAFETY: as `u32x4`.
    unsafe { core::mem::transmute(a) }
}

// -- geometry ---------------------------------------------------------

pub(crate) fn zip1_32(a: B128, b: B128) -> B128 {
    let (x, y) = (u32x4(a), u32x4(b));
    b32([x[0], y[0], x[1], y[1]])
}

pub(crate) fn zip2_32(a: B128, b: B128) -> B128 {
    let (x, y) = (u32x4(a), u32x4(b));
    b32([x[2], y[2], x[3], y[3]])
}

pub(crate) fn uzp1_32(a: B128, b: B128) -> B128 {
    let (x, y) = (u32x4(a), u32x4(b));
    b32([x[0], x[2], y[0], y[2]])
}

pub(crate) fn uzp2_32(a: B128, b: B128) -> B128 {
    let (x, y) = (u32x4(a), u32x4(b));
    b32([x[1], x[3], y[1], y[3]])
}

pub(crate) fn trn1_32(a: B128, b: B128) -> B128 {
    let (x, y) = (u32x4(a), u32x4(b));
    b32([x[0], y[0], x[2], y[2]])
}

pub(crate) fn trn2_32(a: B128, b: B128) -> B128 {
    let (x, y) = (u32x4(a), u32x4(b));
    b32([x[1], y[1], x[3], y[3]])
}

pub(crate) fn rev64_32(a: B128) -> B128 {
    let x = u32x4(a);
    b32([x[1], x[0], x[3], x[2]])
}

pub(crate) fn swap64(a: B128) -> B128 {
    let x = u64x2(a);
    b64([x[1], x[0]])
}

pub(crate) fn rev_32(a: B128) -> B128 {
    let x = u32x4(a);
    b32([x[3], x[2], x[1], x[0]])
}

pub(crate) fn blend64_lo_hi(lo: B128, hi: B128) -> B128 {
    let (x, y) = (u64x2(lo), u64x2(hi));
    b64([x[0], y[1]])
}

pub(crate) fn blend_even_odd_32(ev: B128, od: B128) -> B128 {
    let (x, y) = (u32x4(ev), u32x4(od));
    b32([x[0], y[1], x[2], y[3]])
}

pub(crate) fn blend_outer_32(a: B128, b: B128) -> B128 {
    let (x, y) = (u32x4(a), u32x4(b));
    b32([x[0], y[1], y[2], x[3]])
}

pub(crate) fn zip1_64(a: B128, b: B128) -> B128 {
    let (x, y) = (u64x2(a), u64x2(b));
    b64([x[0], y[0]])
}

pub(crate) fn zip2_64(a: B128, b: B128) -> B128 {
    let (x, y) = (u64x2(a), u64x2(b));
    b64([x[1], y[1]])
}

// -- comparators ------------------------------------------------------

#[inline(always)]
fn lanewise128<L: Lane>(a: B128, b: B128, f: impl Fn(L, L) -> L) -> B128 {
    debug_assert_eq!(16 % core::mem::size_of::<L>(), 0);
    let n = 16 / core::mem::size_of::<L>();
    let mut out = B128([0; 16]);
    // SAFETY: B128 is 16-byte aligned and 16 bytes long; L is a plain
    // Copy scalar of size 4 or 8 dividing 16, so the n in-bounds
    // reads/writes below are aligned and valid for any bit pattern.
    unsafe {
        let pa = a.0.as_ptr() as *const L;
        let pb = b.0.as_ptr() as *const L;
        let po = out.0.as_mut_ptr() as *mut L;
        for i in 0..n {
            po.add(i).write(f(pa.add(i).read(), pb.add(i).read()));
        }
    }
    out
}

#[inline(always)]
fn lanewise256<L: Lane>(a: B256, b: B256, f: impl Fn(L, L) -> L) -> B256 {
    debug_assert_eq!(32 % core::mem::size_of::<L>(), 0);
    let n = 32 / core::mem::size_of::<L>();
    let mut out = B256([0; 32]);
    // SAFETY: as `lanewise128`, over 32 bytes.
    unsafe {
        let pa = a.0.as_ptr() as *const L;
        let pb = b.0.as_ptr() as *const L;
        let po = out.0.as_mut_ptr() as *mut L;
        for i in 0..n {
            po.add(i).write(f(pa.add(i).read(), pb.add(i).read()));
        }
    }
    out
}

/// Generic lane-wise minimum over the element's [`Lane::lane_min`] —
/// the reference semantics every intrinsic comparator must match.
pub(crate) fn min128<L: Lane>(a: B128, b: B128) -> B128 {
    lanewise128::<L>(a, b, L::lane_min)
}

/// Generic lane-wise maximum over [`Lane::lane_max`].
pub(crate) fn max128<L: Lane>(a: B128, b: B128) -> B128 {
    lanewise128::<L>(a, b, L::lane_max)
}

/// 256-bit generic lane-wise minimum.
pub(crate) fn min256<L: Lane>(a: B256, b: B256) -> B256 {
    lanewise256::<L>(a, b, L::lane_min)
}

/// 256-bit generic lane-wise maximum.
pub(crate) fn max256<L: Lane>(a: B256, b: B256) -> B256 {
    lanewise256::<L>(a, b, L::lane_max)
}

// Monomorphic names so the dispatch macro can route `min128_i32` etc.
// uniformly across backends.
pub(crate) fn min128_i32(a: B128, b: B128) -> B128 {
    min128::<i32>(a, b)
}
pub(crate) fn max128_i32(a: B128, b: B128) -> B128 {
    max128::<i32>(a, b)
}
pub(crate) fn min128_u32(a: B128, b: B128) -> B128 {
    min128::<u32>(a, b)
}
pub(crate) fn max128_u32(a: B128, b: B128) -> B128 {
    max128::<u32>(a, b)
}
pub(crate) fn min128_f32(a: B128, b: B128) -> B128 {
    min128::<f32>(a, b)
}
pub(crate) fn max128_f32(a: B128, b: B128) -> B128 {
    max128::<f32>(a, b)
}
pub(crate) fn min128_u64(a: B128, b: B128) -> B128 {
    min128::<u64>(a, b)
}
pub(crate) fn max128_u64(a: B128, b: B128) -> B128 {
    max128::<u64>(a, b)
}
