//! Runtime-selected SIMD backends behind the register model.
//!
//! The register types ([`super::V128`], [`super::V256`],
//! [`super::V128D`], [`super::V256D`]) keep their public shape — plain
//! `repr(C)` arrays with value semantics — but every data-movement and
//! comparator op now routes through this module, which lowers it on one
//! of three backends:
//!
//! * **`scalar`** — the original portable reference model, compiled on
//!   every target and always selectable. Bit-for-bit identical to the
//!   pre-backend code: each op is the same array formula the register
//!   types used to inline.
//! * **`neon`** (`aarch64` builds) — `core::arch::aarch64` intrinsics.
//!   `V128`/`V128D` map 1:1 onto q-register ops (`vminq_u32`,
//!   `vzip1q_u32`, `vextq_u64`, ...); `V256`/`V256D` lower as *pairs*
//!   of q-registers, matching the paper's modelling of 256-bit traffic
//!   on a 128-bit machine.
//! * **`sse4.2` / `avx2`** (`x86_64` builds) — `core::arch::x86_64`
//!   intrinsics. Under `sse4.2` everything is xmm pairs; under `avx2`
//!   the `V256`/`V256D` comparators additionally fuse into native
//!   256-bit ymm ops (`_mm256_min_epi32`, ...).
//!
//! # Dispatch happens once, at the trait-impl boundary
//!
//! `kernels/`, `sortnet::Network::apply_columns`, `sort/`, and the
//! coordinator are all generic over [`super::Vector`] and know nothing
//! about backends. The register-type impls translate each op into a
//! call here; the active backend is a process-global picked once by
//! [`active`] (runtime feature detection, overridable via the
//! `NEONMS_SIMD_BACKEND` environment variable or [`force`]) and read
//! with a single relaxed atomic load per op — which branch-predicts
//! perfectly and disappears entirely once LLVM hoists it out of the
//! sorting-network loops.
//!
//! Two kinds of ops exist:
//!
//! * **Geometry** (zips/unzips/transposes/reverses/blends) moves lanes
//!   without looking at them, so one lowering per *width* serves every
//!   element type. These are the free functions on [`B128`] below.
//! * **Comparators** (`min`/`max`) depend on the element's order, so
//!   they dispatch per element type through the `Lane::min128`-family
//!   hooks (see [`super::Lane`]), again landing in this module.
//!
//! Every dispatcher also has a `*_with(Backend, ...)` twin that takes
//! the backend explicitly. The cross-backend equivalence suite uses
//! those to compare lowerings without mutating process-global state.

use std::sync::atomic::{AtomicU8, Ordering};

pub(crate) mod scalar;

#[cfg(target_arch = "aarch64")]
pub(crate) mod neon;

#[cfg(target_arch = "x86_64")]
pub(crate) mod x86;

#[cfg(test)]
mod tests;

/// A SIMD lowering strategy for the register model.
///
/// All four variants exist on every target so that configs, CLI flags,
/// and bench artifacts can always *name* any backend; availability
/// ([`Backend::available`]) is what's target- and CPU-dependent.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Backend {
    /// Portable reference model — always available, on every target.
    Scalar = 0,
    /// ARM NEON q-register intrinsics (`aarch64` only).
    Neon = 1,
    /// SSE4.2 xmm intrinsics (`x86_64` with SSE4.1+SSE4.2).
    Sse42 = 2,
    /// AVX2 ymm intrinsics for 256-bit ops, xmm for 128-bit
    /// (`x86_64` with AVX2).
    Avx2 = 3,
}

impl Backend {
    /// All nameable backends, portable-first.
    pub fn all() -> [Backend; 4] {
        [Backend::Scalar, Backend::Neon, Backend::Sse42, Backend::Avx2]
    }

    /// Stable lower-case name, used by `NEONMS_SIMD_BACKEND`, the
    /// `--backend` CLI flag, `MetricsSnapshot`, and `BenchReport`.
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Neon => "neon",
            Backend::Sse42 => "sse4.2",
            Backend::Avx2 => "avx2",
        }
    }

    /// Parse a backend name as accepted by `NEONMS_SIMD_BACKEND` and
    /// `--backend`. Case-insensitive; `"sse42"` is accepted as an
    /// alias for `"sse4.2"`. `"auto"` is *not* a backend — callers
    /// handle it before parsing (it means "run detection").
    pub fn parse(s: &str) -> Option<Backend> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(Backend::Scalar),
            "neon" => Some(Backend::Neon),
            "sse4.2" | "sse42" => Some(Backend::Sse42),
            "avx2" => Some(Backend::Avx2),
            _ => None,
        }
    }

    /// Whether this backend can run on the current target *and* CPU.
    ///
    /// `Scalar` is available everywhere; the intrinsic backends
    /// require both the right `target_arch` (compile-time) and the
    /// right CPU features (runtime detection).
    pub fn available(self) -> bool {
        match self {
            Backend::Scalar => true,
            Backend::Neon => neon_available(),
            Backend::Sse42 => sse42_available(),
            Backend::Avx2 => avx2_available(),
        }
    }

    fn from_u8(v: u8) -> Option<Backend> {
        match v {
            0 => Some(Backend::Scalar),
            1 => Some(Backend::Neon),
            2 => Some(Backend::Sse42),
            3 => Some(Backend::Avx2),
            _ => None,
        }
    }
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(target_arch = "aarch64")]
fn neon_available() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}

#[cfg(not(target_arch = "aarch64"))]
fn neon_available() -> bool {
    false
}

#[cfg(target_arch = "x86_64")]
fn sse42_available() -> bool {
    is_x86_feature_detected!("sse4.1") && is_x86_feature_detected!("sse4.2")
}

#[cfg(not(target_arch = "x86_64"))]
fn sse42_available() -> bool {
    false
}

#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    sse42_available() && is_x86_feature_detected!("avx2")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_available() -> bool {
    false
}

/// Pick the best available backend for this machine: `avx2` >
/// `sse4.2` > `scalar` on x86_64, `neon` > `scalar` on aarch64,
/// `scalar` elsewhere.
pub fn detect() -> Backend {
    if Backend::Avx2.available() {
        Backend::Avx2
    } else if Backend::Sse42.available() {
        Backend::Sse42
    } else if Backend::Neon.available() {
        Backend::Neon
    } else {
        Backend::Scalar
    }
}

/// Resolve what the `NEONMS_SIMD_BACKEND` environment variable asks
/// for: unset/empty/`auto` means "detect", a backend name means "that
/// backend, or fail loudly if it can't run here".
///
/// Split out from the global-init path so the selection policy is unit
/// testable without touching process state.
fn resolve_env(var: Option<&str>) -> Result<Backend, String> {
    let v = match var {
        None => return Ok(detect()),
        Some(v) => v.trim(),
    };
    if v.is_empty() || v.eq_ignore_ascii_case("auto") {
        return Ok(detect());
    }
    let k = Backend::parse(v).ok_or_else(|| {
        format!(
            "unknown SIMD backend {:?}; valid values: scalar, neon, sse4.2, avx2, auto",
            v
        )
    })?;
    if !k.available() {
        return Err(format!(
            "SIMD backend `{}` is not available on this machine (target {}); \
             `scalar` always is",
            k.name(),
            std::env::consts::ARCH
        ));
    }
    Ok(k)
}

/// Sentinel meaning "not initialised yet" — outside the `Backend`
/// discriminant range.
const UNINIT: u8 = 0xFF;

static ACTIVE: AtomicU8 = AtomicU8::new(UNINIT);

#[cold]
fn init_slow() -> Backend {
    let resolved = match std::env::var("NEONMS_SIMD_BACKEND") {
        Ok(v) => resolve_env(Some(&v)),
        Err(_) => resolve_env(None),
    };
    let k = match resolved {
        Ok(k) => k,
        // An explicit-but-impossible request must not silently fall
        // back — wrong-backend numbers are worse than no numbers.
        Err(e) => panic!("NEONMS_SIMD_BACKEND: {e}"),
    };
    // Racing first-callers may each run detection; they all agree on
    // the result unless one raced a `force()`, in which case the
    // forced value wins (compare_exchange keeps whatever landed).
    let _ = ACTIVE.compare_exchange(UNINIT, k as u8, Ordering::Relaxed, Ordering::Relaxed);
    Backend::from_u8(ACTIVE.load(Ordering::Relaxed)).unwrap_or(Backend::Scalar)
}

/// The backend every dispatched op currently lowers on.
///
/// First call resolves `NEONMS_SIMD_BACKEND` (or runs detection);
/// subsequent calls are a single relaxed atomic load.
#[inline(always)]
pub fn active() -> Backend {
    match Backend::from_u8(ACTIVE.load(Ordering::Relaxed)) {
        Some(k) => k,
        None => init_slow(),
    }
}

/// Force the active backend for the whole process, overriding both
/// detection and the environment variable. Used by
/// [`crate::sort::SortConfig::backend`] and the CLI `--backend` flag.
///
/// Fails (leaving the current selection untouched) if the requested
/// backend is unavailable on this machine; forcing
/// [`Backend::Scalar`] always succeeds.
pub fn force(k: Backend) -> Result<Backend, String> {
    if !k.available() {
        return Err(format!(
            "SIMD backend `{}` is not available on this machine (target {}); \
             `scalar` always is",
            k.name(),
            std::env::consts::ARCH
        ));
    }
    ACTIVE.store(k as u8, Ordering::Relaxed);
    Ok(k)
}

// ---------------------------------------------------------------------
// Type-erased register bits
// ---------------------------------------------------------------------

/// The raw bits of one 128-bit register, independent of element type.
///
/// Geometry ops (zips, transposes, reverses, blends) move lanes
/// without interpreting them, so they operate on `B128` and serve
/// `V128<i32>`, `V128<u32>`, `V128<f32>`, and `V128D<u64>` alike —
/// exactly how the hardware ops they lower to behave.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(C, align(16))]
pub struct B128(pub [u8; 16]);

/// The raw bits of one 256-bit double-register ([`B128`] at 256 bits).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(C, align(32))]
pub struct B256(pub [u8; 32]);

/// Bit-cast a 16-byte register value to its raw bits.
#[inline(always)]
pub(crate) fn to_b128<R: Copy>(r: R) -> B128 {
    debug_assert_eq!(core::mem::size_of::<R>(), 16, "B128 requires a 16-byte register");
    debug_assert!(core::mem::align_of::<R>() <= 16);
    // SAFETY: size checked above; B128 has no invalid bit patterns.
    unsafe { core::ptr::read(&r as *const R as *const B128) }
}

/// Bit-cast raw bits back to a 16-byte register value.
#[inline(always)]
pub(crate) fn from_b128<R: Copy>(b: B128) -> R {
    debug_assert_eq!(core::mem::size_of::<R>(), 16, "B128 requires a 16-byte register");
    debug_assert!(core::mem::align_of::<R>() <= 16);
    // SAFETY: size checked above; register types are plain repr(C)
    // arrays of integers/floats, valid for every bit pattern the
    // backends produce.
    unsafe { core::ptr::read(&b as *const B128 as *const R) }
}

/// Bit-cast a 32-byte register value to its raw bits.
#[inline(always)]
pub(crate) fn to_b256<R: Copy>(r: R) -> B256 {
    debug_assert_eq!(core::mem::size_of::<R>(), 32, "B256 requires a 32-byte register");
    debug_assert!(core::mem::align_of::<R>() <= 32);
    // SAFETY: as `to_b128`.
    unsafe { core::ptr::read(&r as *const R as *const B256) }
}

/// Bit-cast raw bits back to a 32-byte register value.
#[inline(always)]
pub(crate) fn from_b256<R: Copy>(b: B256) -> R {
    debug_assert_eq!(core::mem::size_of::<R>(), 32, "B256 requires a 32-byte register");
    debug_assert!(core::mem::align_of::<R>() <= 32);
    // SAFETY: as `from_b128`.
    unsafe { core::ptr::read(&b as *const B256 as *const R) }
}

/// Low 128-bit half of a 256-bit double-register.
#[inline(always)]
pub(crate) fn lo128(b: B256) -> B128 {
    let mut o = [0u8; 16];
    o.copy_from_slice(&b.0[..16]);
    B128(o)
}

/// High 128-bit half of a 256-bit double-register.
#[inline(always)]
pub(crate) fn hi128(b: B256) -> B128 {
    let mut o = [0u8; 16];
    o.copy_from_slice(&b.0[16..]);
    B128(o)
}

/// Rejoin two 128-bit halves into a 256-bit double-register.
#[inline(always)]
pub(crate) fn join128(lo: B128, hi: B128) -> B256 {
    let mut o = [0u8; 32];
    o[..16].copy_from_slice(&lo.0);
    o[16..].copy_from_slice(&hi.0);
    B256(o)
}

// ---------------------------------------------------------------------
// Geometry dispatchers (element-type independent)
// ---------------------------------------------------------------------

macro_rules! geom2 {
    ($(#[$doc:meta])* $name:ident, $with:ident) => {
        $(#[$doc])*
        #[inline(always)]
        pub(crate) fn $name(a: B128, b: B128) -> B128 {
            $with(active(), a, b)
        }

        $(#[$doc])*
        ///
        /// Explicit-backend twin for the equivalence suite.
        #[inline]
        pub(crate) fn $with(k: Backend, a: B128, b: B128) -> B128 {
            match k {
                #[cfg(target_arch = "x86_64")]
                // SAFETY: Sse42/Avx2 only become active after runtime
                // detection confirmed SSE4.1+SSE4.2 on this CPU.
                Backend::Sse42 | Backend::Avx2 => unsafe { x86::$name(a, b) },
                #[cfg(target_arch = "aarch64")]
                // SAFETY: Neon only becomes active after runtime
                // detection confirmed NEON on this CPU.
                Backend::Neon => unsafe { neon::$name(a, b) },
                _ => scalar::$name(a, b),
            }
        }
    };
}

macro_rules! geom1 {
    ($(#[$doc:meta])* $name:ident, $with:ident) => {
        $(#[$doc])*
        #[inline(always)]
        pub(crate) fn $name(a: B128) -> B128 {
            $with(active(), a)
        }

        $(#[$doc])*
        ///
        /// Explicit-backend twin for the equivalence suite.
        #[inline]
        pub(crate) fn $with(k: Backend, a: B128) -> B128 {
            match k {
                #[cfg(target_arch = "x86_64")]
                // SAFETY: see the binary-geometry dispatcher.
                Backend::Sse42 | Backend::Avx2 => unsafe { x86::$name(a) },
                #[cfg(target_arch = "aarch64")]
                // SAFETY: see the binary-geometry dispatcher.
                Backend::Neon => unsafe { neon::$name(a) },
                _ => scalar::$name(a),
            }
        }
    };
}

geom2!(
    /// Interleave low 32-bit lanes: `[a0, b0, a1, b1]` (NEON `zip1`,
    /// SSE `punpckldq`).
    zip1_32,
    zip1_32_with
);
geom2!(
    /// Interleave high 32-bit lanes: `[a2, b2, a3, b3]` (NEON `zip2`,
    /// SSE `punpckhdq`).
    zip2_32,
    zip2_32_with
);
geom2!(
    /// Even 32-bit lanes of both: `[a0, a2, b0, b2]` (NEON `uzp1`,
    /// SSE `shufps 0x88`).
    uzp1_32,
    uzp1_32_with
);
geom2!(
    /// Odd 32-bit lanes of both: `[a1, a3, b1, b3]` (NEON `uzp2`,
    /// SSE `shufps 0xDD`).
    uzp2_32,
    uzp2_32_with
);
geom2!(
    /// Transpose-primary of 32-bit lanes: `[a0, b0, a2, b2]` (NEON
    /// `trn1`).
    trn1_32,
    trn1_32_with
);
geom2!(
    /// Transpose-secondary of 32-bit lanes: `[a1, b1, a3, b3]` (NEON
    /// `trn2`).
    trn2_32,
    trn2_32_with
);
geom1!(
    /// Reverse 32-bit lanes within each 64-bit half: `[a1, a0, a3,
    /// a2]` (NEON `rev64`, SSE `pshufd 0xB1`).
    rev64_32,
    rev64_32_with
);
geom1!(
    /// Swap the 64-bit halves: `[a2, a3, a0, a1]` (NEON `ext #8`, SSE
    /// `pshufd 0x4E`). Also serves the two-lane register's
    /// `reverse`/`swap_halves`.
    swap64,
    swap64_with
);
geom1!(
    /// Fully reverse the four 32-bit lanes: `[a3, a2, a1, a0]` (NEON
    /// `rev64` + `ext`, SSE `pshufd 0x1B`).
    rev_32,
    rev_32_with
);
geom2!(
    /// Low 64-bit half of `lo`, high 64-bit half of `hi` (SSE
    /// `pblendw 0xF0`, NEON `vcombine(low(lo), high(hi))`). Serves
    /// both the 4-lane `[lo0, lo1, hi2, hi3]` blend and the 2-lane
    /// `[lo0, hi1]` blend — same bit movement.
    blend64_lo_hi,
    blend64_lo_hi_with
);
geom2!(
    /// Even lanes from `ev`, odd lanes from `od`: `[ev0, od1, ev2,
    /// od3]` (SSE `pblendw 0xCC`, NEON `bsl`).
    blend_even_odd_32,
    blend_even_odd_32_with
);
geom2!(
    /// Outer lanes from `a`, inner lanes from `b`: `[a0, b1, b2, a3]`
    /// (SSE `pblendw 0x3C`, NEON `bsl`).
    blend_outer_32,
    blend_outer_32_with
);
geom2!(
    /// Interleave low 64-bit lanes: `[a0, b0]` (NEON `zip1.2d`, SSE
    /// `punpcklqdq`).
    zip1_64,
    zip1_64_with
);
geom2!(
    /// Interleave high 64-bit lanes: `[a1, b1]` (NEON `zip2.2d`, SSE
    /// `punpckhqdq`).
    zip2_64,
    zip2_64_with
);

// ---------------------------------------------------------------------
// Comparator dispatchers (element-type dependent)
// ---------------------------------------------------------------------

macro_rules! minmax128 {
    ($(#[$doc:meta])* $name:ident, $with:ident) => {
        $(#[$doc])*
        #[inline(always)]
        pub(crate) fn $name(a: B128, b: B128) -> B128 {
            $with(active(), a, b)
        }

        $(#[$doc])*
        ///
        /// Explicit-backend twin for the equivalence suite.
        #[inline]
        pub(crate) fn $with(k: Backend, a: B128, b: B128) -> B128 {
            match k {
                #[cfg(target_arch = "x86_64")]
                // SAFETY: Sse42/Avx2 only become active after runtime
                // detection confirmed SSE4.1+SSE4.2 on this CPU.
                Backend::Sse42 | Backend::Avx2 => unsafe { x86::$name(a, b) },
                #[cfg(target_arch = "aarch64")]
                // SAFETY: Neon only becomes active after runtime
                // detection confirmed NEON on this CPU.
                Backend::Neon => unsafe { neon::$name(a, b) },
                _ => scalar::$name(a, b),
            }
        }
    };
}

minmax128!(
    /// Lane-wise signed 32-bit minimum (NEON `smin`, SSE `pminsd`).
    min128_i32,
    min128_i32_with
);
minmax128!(
    /// Lane-wise signed 32-bit maximum (NEON `smax`, SSE `pmaxsd`).
    max128_i32,
    max128_i32_with
);
minmax128!(
    /// Lane-wise unsigned 32-bit minimum (NEON `umin`, SSE `pminud`).
    min128_u32,
    min128_u32_with
);
minmax128!(
    /// Lane-wise unsigned 32-bit maximum (NEON `umax`, SSE `pmaxud`).
    max128_u32,
    max128_u32_with
);
minmax128!(
    /// Lane-wise f32 minimum with `a < b ? a : b` semantics (NEON
    /// `fmin` differs on NaN, but NaN input is out of contract — see
    /// [`super::Lane`] on `f32`; SSE `minps` matches exactly).
    min128_f32,
    min128_f32_with
);
minmax128!(
    /// Lane-wise f32 maximum with `a < b ? b : a` semantics.
    max128_f32,
    max128_f32_with
);
minmax128!(
    /// Lane-wise unsigned 64-bit minimum (NEON `cmhi` + `bsl`; SSE4.2
    /// sign-flipped `pcmpgtq` + `pblendvb` — no native `pminuq` until
    /// AVX-512).
    min128_u64,
    min128_u64_with
);
minmax128!(
    /// Lane-wise unsigned 64-bit maximum (see [`min128_u64`]).
    max128_u64,
    max128_u64_with
);

macro_rules! minmax256 {
    ($(#[$doc:meta])* $name:ident, $with:ident, $op128_with:ident, $avx2:ident) => {
        $(#[$doc])*
        #[inline(always)]
        pub(crate) fn $name(a: B256, b: B256) -> B256 {
            $with(active(), a, b)
        }

        $(#[$doc])*
        ///
        /// Explicit-backend twin for the equivalence suite.
        #[inline]
        pub(crate) fn $with(k: Backend, a: B256, b: B256) -> B256 {
            match k {
                #[cfg(target_arch = "x86_64")]
                // SAFETY: Avx2 only becomes active after runtime
                // detection confirmed AVX2 on this CPU.
                Backend::Avx2 => unsafe { x86::$avx2(a, b) },
                // Everything below ymm width is the paired-register
                // lowering: two 128-bit ops on the halves (scalar,
                // NEON q-pairs, SSE xmm pairs alike).
                _ => join128(
                    $op128_with(k, lo128(a), lo128(b)),
                    $op128_with(k, hi128(a), hi128(b)),
                ),
            }
        }
    };
}

minmax256!(
    /// 256-bit signed 32-bit minimum (`vpminsd ymm` under AVX2,
    /// paired 128-bit ops otherwise).
    min256_i32,
    min256_i32_with,
    min128_i32_with,
    min256_i32
);
minmax256!(
    /// 256-bit signed 32-bit maximum.
    max256_i32,
    max256_i32_with,
    max128_i32_with,
    max256_i32
);
minmax256!(
    /// 256-bit unsigned 32-bit minimum.
    min256_u32,
    min256_u32_with,
    min128_u32_with,
    min256_u32
);
minmax256!(
    /// 256-bit unsigned 32-bit maximum.
    max256_u32,
    max256_u32_with,
    max128_u32_with,
    max256_u32
);
minmax256!(
    /// 256-bit f32 minimum (`vminps ymm` under AVX2).
    min256_f32,
    min256_f32_with,
    min128_f32_with,
    min256_f32
);
minmax256!(
    /// 256-bit f32 maximum.
    max256_f32,
    max256_f32_with,
    max128_f32_with,
    max256_f32
);
minmax256!(
    /// 256-bit unsigned 64-bit minimum (`vpcmpgtq` + `vpblendvb`
    /// under AVX2).
    min256_u64,
    min256_u64_with,
    min128_u64_with,
    min256_u64
);
minmax256!(
    /// 256-bit unsigned 64-bit maximum.
    max256_u64,
    max256_u64_with,
    max128_u64_with,
    max256_u64
);
