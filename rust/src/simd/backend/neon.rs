//! ARM NEON lowering of the register-model ops (`aarch64` builds
//! only).
//!
//! This is the backend the paper actually measures: `V128`/`V128D` map
//! 1:1 onto q-register ops, and `V256`/`V256D` lower as q-register
//! *pairs* (NEON has no 256-bit registers — the paired lowering is the
//! paper's own model of double-width traffic).
//!
//! The scalar model was written NEON-first, so the geometry ops here
//! are the eponymous intrinsics (`vzip1q_u32`, `vuzp1q_u32`,
//! `vrev64q_u32`, ...). Each lowering is property-tested against the
//! scalar oracle in `backend::tests` (which runs natively under the
//! CI `aarch64` cross-check once executed on arm hardware) and
//! mirrored in `tools/verify_backend_lowering.py`.

#![allow(unsafe_op_in_unsafe_fn)]

use core::arch::aarch64::*;

use super::B128;

#[inline(always)]
unsafe fn ld_u32(a: B128) -> uint32x4_t {
    // NEON is baseline on aarch64 linux targets; vld1q needs no gate.
    vld1q_u32(a.0.as_ptr() as *const u32)
}

#[inline(always)]
unsafe fn st_u32(v: uint32x4_t) -> B128 {
    let mut o = B128([0; 16]);
    vst1q_u32(o.0.as_mut_ptr() as *mut u32, v);
    o
}

#[inline(always)]
unsafe fn ld_u64(a: B128) -> uint64x2_t {
    vld1q_u64(a.0.as_ptr() as *const u64)
}

#[inline(always)]
unsafe fn st_u64(v: uint64x2_t) -> B128 {
    let mut o = B128([0; 16]);
    vst1q_u64(o.0.as_mut_ptr() as *mut u64, v);
    o
}

// -- geometry ---------------------------------------------------------

#[inline]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn zip1_32(a: B128, b: B128) -> B128 {
    st_u32(vzip1q_u32(ld_u32(a), ld_u32(b)))
}

#[inline]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn zip2_32(a: B128, b: B128) -> B128 {
    st_u32(vzip2q_u32(ld_u32(a), ld_u32(b)))
}

#[inline]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn uzp1_32(a: B128, b: B128) -> B128 {
    st_u32(vuzp1q_u32(ld_u32(a), ld_u32(b)))
}

#[inline]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn uzp2_32(a: B128, b: B128) -> B128 {
    st_u32(vuzp2q_u32(ld_u32(a), ld_u32(b)))
}

#[inline]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn trn1_32(a: B128, b: B128) -> B128 {
    st_u32(vtrn1q_u32(ld_u32(a), ld_u32(b)))
}

#[inline]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn trn2_32(a: B128, b: B128) -> B128 {
    st_u32(vtrn2q_u32(ld_u32(a), ld_u32(b)))
}

#[inline]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn rev64_32(a: B128) -> B128 {
    st_u32(vrev64q_u32(ld_u32(a)))
}

#[inline]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn swap64(a: B128) -> B128 {
    let v = ld_u64(a);
    st_u64(vextq_u64::<1>(v, v))
}

#[inline]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn rev_32(a: B128) -> B128 {
    // rev64 within halves, then swap the halves: full 4-lane reverse.
    let r = vreinterpretq_u64_u32(vrev64q_u32(ld_u32(a)));
    st_u64(vextq_u64::<1>(r, r))
}

#[inline]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn blend64_lo_hi(lo: B128, hi: B128) -> B128 {
    st_u64(vcombine_u64(
        vget_low_u64(ld_u64(lo)),
        vget_high_u64(ld_u64(hi)),
    ))
}

#[inline]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn blend_even_odd_32(ev: B128, od: B128) -> B128 {
    // bsl selects the second operand where the mask bits are set.
    let m = [u32::MAX, 0, u32::MAX, 0];
    st_u32(vbslq_u32(vld1q_u32(m.as_ptr()), ld_u32(ev), ld_u32(od)))
}

#[inline]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn blend_outer_32(a: B128, b: B128) -> B128 {
    let m = [u32::MAX, 0, 0, u32::MAX];
    st_u32(vbslq_u32(vld1q_u32(m.as_ptr()), ld_u32(a), ld_u32(b)))
}

#[inline]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn zip1_64(a: B128, b: B128) -> B128 {
    st_u64(vzip1q_u64(ld_u64(a), ld_u64(b)))
}

#[inline]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn zip2_64(a: B128, b: B128) -> B128 {
    st_u64(vzip2q_u64(ld_u64(a), ld_u64(b)))
}

// -- comparators ------------------------------------------------------

#[inline]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn min128_i32(a: B128, b: B128) -> B128 {
    let (va, vb) = (
        vreinterpretq_s32_u32(ld_u32(a)),
        vreinterpretq_s32_u32(ld_u32(b)),
    );
    st_u32(vreinterpretq_u32_s32(vminq_s32(va, vb)))
}

#[inline]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn max128_i32(a: B128, b: B128) -> B128 {
    let (va, vb) = (
        vreinterpretq_s32_u32(ld_u32(a)),
        vreinterpretq_s32_u32(ld_u32(b)),
    );
    st_u32(vreinterpretq_u32_s32(vmaxq_s32(va, vb)))
}

#[inline]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn min128_u32(a: B128, b: B128) -> B128 {
    st_u32(vminq_u32(ld_u32(a), ld_u32(b)))
}

#[inline]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn max128_u32(a: B128, b: B128) -> B128 {
    st_u32(vmaxq_u32(ld_u32(a), ld_u32(b)))
}

#[inline]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn min128_f32(a: B128, b: B128) -> B128 {
    // vbsl on vclt rather than vminq: the scalar model's `a < b ? a :
    // b` must also hold bit-for-bit for -0.0/+0.0 ties, where fmin
    // would canonicalise to -0.0.
    let (va, vb) = (
        vreinterpretq_f32_u32(ld_u32(a)),
        vreinterpretq_f32_u32(ld_u32(b)),
    );
    st_u32(vreinterpretq_u32_f32(vbslq_f32(vcltq_f32(va, vb), va, vb)))
}

#[inline]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn max128_f32(a: B128, b: B128) -> B128 {
    // `a > b ? a : b` — ties (incl. ±0.0) take the second operand,
    // matching both the scalar model and x86 `maxps`.
    let (va, vb) = (
        vreinterpretq_f32_u32(ld_u32(a)),
        vreinterpretq_f32_u32(ld_u32(b)),
    );
    st_u32(vreinterpretq_u32_f32(vbslq_f32(vcgtq_f32(va, vb), va, vb)))
}

#[inline]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn min128_u64(a: B128, b: B128) -> B128 {
    // No vminq for 64-bit lanes: compare-higher + bit-select.
    let (va, vb) = (ld_u64(a), ld_u64(b));
    st_u64(vbslq_u64(vcgtq_u64(va, vb), vb, va))
}

#[inline]
#[target_feature(enable = "neon")]
pub(crate) unsafe fn max128_u64(a: B128, b: B128) -> B128 {
    let (va, vb) = (ld_u64(a), ld_u64(b));
    st_u64(vbslq_u64(vcgtq_u64(va, vb), va, vb))
}
