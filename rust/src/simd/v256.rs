//! The 256-bit, 8-lane vector register type: paired `q`-registers.
//!
//! Models the wider geometries the paper's §2.2 width × register
//! budget tradeoff points at — ARM SVE at a 256-bit vector length, or
//! NEON `q`-register *pairs* scheduled as one logical register (the
//! `vld1q_u32_x2` / LD1 multi-register idiom). On the scalar and NEON
//! backends every op lowers to exactly two [`V128`] ops, so the cost
//! model stays honest: a `V256` comparator is two `vmin` + two `vmax`,
//! a `V256` shuffle is two 128-bit shuffles (plus, for stages that
//! cross the 128-bit boundary, the pair swap that SVE would express
//! as a single `tbl`/`ext`). Under the AVX2 backend the comparators —
//! the ops the kernels' inner loops are made of — fuse into native
//! 256-bit ymm instructions via [`Lane::min256`]/[`Lane::max256`];
//! the shuffle stages keep the per-half composition, which is also
//! what they cost on a paired-register machine. Kernels written
//! against [`Vector`] get this width for free; nothing in this module
//! is reachable from the `V128` paths.

use super::backend;
use super::lane::Lane;
use super::v128::{transpose4, V128};
use super::vector::{Lanes, Vector};
use super::W;

/// Eight 32-bit lanes as a pair of [`V128`] halves: lane `i` lives in
/// half `i / 4`, lane `i % 4`. Lane 0 is the lowest-addressed element
/// on load, matching the `V128` convention.
#[derive(Clone, Copy, PartialEq, Debug)]
#[repr(C, align(32))]
pub struct V256<T: Lane>(pub [V128<T>; 2]);

impl<T: Lane> V256<T> {
    /// Lanes per register.
    pub const LANES: usize = 2 * W;

    /// Broadcast one scalar to all eight lanes.
    #[inline(always)]
    pub fn splat(v: T) -> Self {
        V256([V128::splat(v), V128::splat(v)])
    }

    /// Load eight contiguous lanes from `src` (`vld1q_x2` / SVE
    /// `ld1w`). Panics if `src.len() < 8`.
    #[inline(always)]
    pub fn load(src: &[T]) -> Self {
        V256([V128::load(&src[..W]), V128::load(&src[W..2 * W])])
    }

    /// Store eight lanes to `dst`.
    #[inline(always)]
    pub fn store(self, dst: &mut [T]) {
        self.0[0].store(&mut dst[..W]);
        self.0[1].store(&mut dst[W..2 * W]);
    }

    /// Materialize as a plain array.
    #[inline(always)]
    pub fn to_array(self) -> [T; 8] {
        let (a, b) = (self.0[0].to_array(), self.0[1].to_array());
        [a[0], a[1], a[2], a[3], b[0], b[1], b[2], b[3]]
    }
}

impl<T: Lane> Lanes for V256<T> {
    const LANES: usize = 2 * W;
    const LANE_BYTES: usize = 4;
}

impl<T: Lane> Vector<T> for V256<T> {
    #[inline(always)]
    fn splat(v: T) -> Self {
        V256::splat(v)
    }

    #[inline(always)]
    fn load(src: &[T]) -> Self {
        V256::load(src)
    }

    #[inline(always)]
    fn store(self, dst: &mut [T]) {
        V256::store(self, dst)
    }

    #[inline(always)]
    fn lane(self, i: usize) -> T {
        self.0[i / W].lane(i % W)
    }

    /// Two `vminq` on paired-register backends, one `vpminsd ymm`
    /// under AVX2.
    #[inline(always)]
    fn min(self, o: Self) -> Self {
        backend::from_b256(T::min256(backend::to_b256(self), backend::to_b256(o)))
    }

    /// Two `vmaxq`, or one `vpmaxsd ymm` under AVX2.
    #[inline(always)]
    fn max(self, o: Self) -> Self {
        backend::from_b256(T::max256(backend::to_b256(self), backend::to_b256(o)))
    }

    /// Reverse all eight lanes: reverse each half and swap the pair.
    #[inline(always)]
    fn reverse(self) -> Self {
        V256([self.0[1].reverse(), self.0[0].reverse()])
    }

    /// Three half-cleaner stages (distances 4, 2, 1). The distance-4
    /// stage is the pair boundary: one `cmpswap` *between* the two
    /// halves (no shuffle at all — the paired-register payoff); the
    /// remaining stages are each half's own `V128` merge.
    #[inline(always)]
    fn bitonic_merge_lanes(self) -> Self {
        let (lo, hi) = self.0[0].cmpswap(self.0[1]);
        V256([Vector::bitonic_merge_lanes(lo), Vector::bitonic_merge_lanes(hi)])
    }

    /// Sort both halves, reverse the upper to form a bitonic
    /// sequence, then merge — the 8-lane bitonic sorter.
    #[inline(always)]
    fn sort_lanes(self) -> Self {
        let lo = Vector::sort_lanes(self.0[0]);
        let hi = V128::reverse(Vector::sort_lanes(self.0[1]));
        Vector::bitonic_merge_lanes(V256([lo, hi]))
    }

    #[inline(always)]
    fn transpose_tile(tile: &mut [Self]) {
        assert_eq!(tile.len(), 2 * W, "V256 tile is 8x8");
        let t = transpose8([
            tile[0], tile[1], tile[2], tile[3], tile[4], tile[5], tile[6], tile[7],
        ]);
        tile.copy_from_slice(&t);
    }
}

/// 8×8 in-register matrix transpose over [`V256`] registers, built
/// from four 4×4 [`transpose4`] base transposes — the 2×2 block
/// decomposition: `[[A, B], [C, D]]ᵀ = [[Aᵀ, Cᵀ], [Bᵀ, Dᵀ]]`, where
/// each letter is the 4×4 tile one `V128` half-column contributes.
#[inline(always)]
pub fn transpose8<T: Lane>(r: [V256<T>; 8]) -> [V256<T>; 8] {
    let a = transpose4([r[0].0[0], r[1].0[0], r[2].0[0], r[3].0[0]]);
    let b = transpose4([r[0].0[1], r[1].0[1], r[2].0[1], r[3].0[1]]);
    let c = transpose4([r[4].0[0], r[5].0[0], r[6].0[0], r[7].0[0]]);
    let d = transpose4([r[4].0[1], r[5].0[1], r[6].0[1], r[7].0[1]]);
    [
        V256([a[0], c[0]]),
        V256([a[1], c[1]]),
        V256([a[2], c[2]]),
        V256([a[3], c[3]]),
        V256([b[0], d[0]]),
        V256([b[1], d[1]]),
        V256([b[2], d[2]]),
        V256([b[3], d[3]]),
    ]
}
