//! Lane-element trait: the 32-bit scalar types the paper sorts.

/// A 32-bit scalar that can live in one lane of a [`super::V128`].
///
/// The paper evaluates 32-bit integers; we additionally support `u32`
/// and `f32` (NEON's `vminq_f32`/`vmaxq_f32` exist and the algorithm is
/// type-agnostic). All comparator logic is expressed through
/// [`Lane::lane_min`]/[`Lane::lane_max`] so that kernels stay branchless:
/// for integers these become `pminsd`/`pmaxsd`-class instructions, for
/// `f32` `minps`/`maxps`.
///
/// `f32` note: like NEON's `vminq_f32`, ordering is IEEE `<`; sorting
/// slices containing NaN is unsupported (same contract as
/// `std::sort` with `operator<` on floats in the paper's C++).
pub trait Lane: Copy + PartialOrd + core::fmt::Debug + Send + Sync + 'static {
    /// Smallest representable value (identity for `max`, used for padding).
    const MIN_VALUE: Self;
    /// Largest representable value (identity for `min`, used for padding).
    const MAX_VALUE: Self;

    /// Branchless minimum of two lanes.
    fn lane_min(self, other: Self) -> Self;
    /// Branchless maximum of two lanes.
    fn lane_max(self, other: Self) -> Self;

    /// Branchless compare-select: `if self <= other { a } else { b }`.
    ///
    /// Mirrors the paper's Fig. 3b `csel` comparator: on x86-64 this
    /// compiles to `cmp` + `cmov`, on AArch64 to `cmp` + `csel` — no
    /// branch, so no misprediction penalty in the serial merge path.
    #[inline(always)]
    fn select_le<T: Copy>(self, other: Self, a: T, b: T) -> T {
        // `PartialOrd` on the three concrete Lane types is total for
        // the values we admit (no NaN), and LLVM turns this into cmov.
        if self <= other {
            a
        } else {
            b
        }
    }
}

impl Lane for i32 {
    const MIN_VALUE: Self = i32::MIN;
    const MAX_VALUE: Self = i32::MAX;
    #[inline(always)]
    fn lane_min(self, other: Self) -> Self {
        Ord::min(self, other)
    }
    #[inline(always)]
    fn lane_max(self, other: Self) -> Self {
        Ord::max(self, other)
    }
}

impl Lane for u32 {
    const MIN_VALUE: Self = u32::MIN;
    const MAX_VALUE: Self = u32::MAX;
    #[inline(always)]
    fn lane_min(self, other: Self) -> Self {
        Ord::min(self, other)
    }
    #[inline(always)]
    fn lane_max(self, other: Self) -> Self {
        Ord::max(self, other)
    }
}

impl Lane for f32 {
    const MIN_VALUE: Self = f32::NEG_INFINITY;
    const MAX_VALUE: Self = f32::INFINITY;
    #[inline(always)]
    fn lane_min(self, other: Self) -> Self {
        // NEON vminq_f32 semantics for non-NaN inputs; branchless minps.
        if self < other {
            self
        } else {
            other
        }
    }
    #[inline(always)]
    fn lane_max(self, other: Self) -> Self {
        if self > other {
            self
        } else {
            other
        }
    }
}

/// Sort key packing for the (key, payload) examples: pack a `u32` key
/// and a `u32` row id into one `u64` so the SIMD path sorts pairs too
/// (the paper's database-retrieval motivation, examples/database_keys).
#[inline(always)]
pub fn pack_key_rowid(key: u32, rowid: u32) -> u64 {
    ((key as u64) << 32) | rowid as u64
}

/// Inverse of [`pack_key_rowid`].
#[inline(always)]
pub fn unpack_key_rowid(packed: u64) -> (u32, u32) {
    ((packed >> 32) as u32, packed as u32)
}
