//! Lane-element trait: the scalar types the paper sorts — 32-bit
//! lanes (`u32`/`i32`/`f32`) and, since the element-width refactor,
//! 64-bit lanes (`u64`) and packed key–payload pairs ([`KeyValue`]).
//!
//! Every `Lane` knows its byte width ([`Lane::BYTES`]) and names the
//! concrete 128/256-bit register types that carry it
//! ([`Lane::Reg128`] / [`Lane::Reg256`]): 4-byte lanes ride
//! [`super::V128`]/[`super::V256`] (W = 4/8), 8-byte lanes ride
//! [`super::V128D`]/[`super::V256D`] (W = 2/4). Kernels dispatch on
//! these associated types, so the same comparator networks, bitonic
//! mergers, and K-flight run merges serve every element width.

use super::backend::{self, B128, B256};
use super::v128::V128;
use super::v128d::V128D;
use super::v256::V256;
use super::v256d::V256D;
use super::vector::Vector;

/// A scalar that can live in one lane of a SIMD register.
///
/// The paper evaluates 32-bit integers; we additionally support `u32`
/// and `f32` (NEON's `vminq_f32`/`vmaxq_f32` exist and the algorithm is
/// type-agnostic), plus 8-byte lanes — `u64` and [`KeyValue`] — for
/// the database `(key, rowid)` scenario. All comparator logic is
/// expressed through [`Lane::lane_min`]/[`Lane::lane_max`] so that
/// kernels stay branchless: for integers these become
/// `pminsd`/`pmaxsd`-class instructions, for `f32` `minps`/`maxps`.
///
/// `f32` note: like NEON's `vminq_f32`, ordering is IEEE `<`; sorting
/// slices containing NaN is unsupported (same contract as
/// `std::sort` with `operator<` on floats in the paper's C++).
pub trait Lane: Copy + PartialOrd + core::fmt::Debug + Send + Sync + 'static {
    /// Smallest representable value (identity for `max`, used for padding).
    const MIN_VALUE: Self;
    /// Largest representable value (identity for `min`, used for padding).
    const MAX_VALUE: Self;
    /// Lane width in bytes (4 or 8). Lanes-per-register follows as
    /// `register_bits / (8 * BYTES)`: a 128-bit register holds four
    /// 4-byte lanes or two 8-byte lanes.
    const BYTES: usize;
    /// The 128-bit register type carrying this element width
    /// ([`super::V128`] for 4-byte lanes, [`super::V128D`] for 8-byte).
    type Reg128: Vector<Self>;
    /// The 256-bit register type carrying this element width
    /// ([`super::V256`] for 4-byte lanes, [`super::V256D`] for 8-byte).
    type Reg256: Vector<Self>;

    /// Branchless minimum of two lanes.
    fn lane_min(self, other: Self) -> Self;
    /// Branchless maximum of two lanes.
    fn lane_max(self, other: Self) -> Self;

    /// Lane-wise minimum over the raw bits of a 128-bit register of
    /// this element type — the hook the register types route their
    /// `min` through so the active [`super::backend`] supplies the
    /// intrinsic. Geometry ops don't need a per-type hook (they move
    /// bits without interpreting them); comparators do, because lane
    /// order depends on the element.
    ///
    /// The default is the always-correct scalar reference lowering;
    /// the built-in lanes override it with backend dispatch.
    #[inline(always)]
    fn min128(a: B128, b: B128) -> B128 {
        backend::scalar::min128::<Self>(a, b)
    }

    /// Lane-wise maximum over 128-bit register bits (see
    /// [`Lane::min128`]).
    #[inline(always)]
    fn max128(a: B128, b: B128) -> B128 {
        backend::scalar::max128::<Self>(a, b)
    }

    /// Lane-wise minimum over 256-bit double-register bits. Native
    /// ymm under AVX2, a pair of 128-bit ops everywhere else.
    #[inline(always)]
    fn min256(a: B256, b: B256) -> B256 {
        backend::scalar::min256::<Self>(a, b)
    }

    /// Lane-wise maximum over 256-bit double-register bits (see
    /// [`Lane::min256`]).
    #[inline(always)]
    fn max256(a: B256, b: B256) -> B256 {
        backend::scalar::max256::<Self>(a, b)
    }

    /// Branchless compare-select: `if self <= other { a } else { b }`.
    ///
    /// Mirrors the paper's Fig. 3b `csel` comparator: on x86-64 this
    /// compiles to `cmp` + `cmov`, on AArch64 to `cmp` + `csel` — no
    /// branch, so no misprediction penalty in the serial merge path.
    #[inline(always)]
    fn select_le<T: Copy>(self, other: Self, a: T, b: T) -> T {
        // `PartialOrd` on the concrete Lane types is total for the
        // values we admit (no NaN), and LLVM turns this into cmov.
        if self <= other {
            a
        } else {
            b
        }
    }
}

impl Lane for i32 {
    const MIN_VALUE: Self = i32::MIN;
    const MAX_VALUE: Self = i32::MAX;
    const BYTES: usize = 4;
    type Reg128 = V128<i32>;
    type Reg256 = V256<i32>;
    #[inline(always)]
    fn lane_min(self, other: Self) -> Self {
        Ord::min(self, other)
    }
    #[inline(always)]
    fn lane_max(self, other: Self) -> Self {
        Ord::max(self, other)
    }
    #[inline(always)]
    fn min128(a: B128, b: B128) -> B128 {
        backend::min128_i32(a, b)
    }
    #[inline(always)]
    fn max128(a: B128, b: B128) -> B128 {
        backend::max128_i32(a, b)
    }
    #[inline(always)]
    fn min256(a: B256, b: B256) -> B256 {
        backend::min256_i32(a, b)
    }
    #[inline(always)]
    fn max256(a: B256, b: B256) -> B256 {
        backend::max256_i32(a, b)
    }
}

impl Lane for u32 {
    const MIN_VALUE: Self = u32::MIN;
    const MAX_VALUE: Self = u32::MAX;
    const BYTES: usize = 4;
    type Reg128 = V128<u32>;
    type Reg256 = V256<u32>;
    #[inline(always)]
    fn lane_min(self, other: Self) -> Self {
        Ord::min(self, other)
    }
    #[inline(always)]
    fn lane_max(self, other: Self) -> Self {
        Ord::max(self, other)
    }
    #[inline(always)]
    fn min128(a: B128, b: B128) -> B128 {
        backend::min128_u32(a, b)
    }
    #[inline(always)]
    fn max128(a: B128, b: B128) -> B128 {
        backend::max128_u32(a, b)
    }
    #[inline(always)]
    fn min256(a: B256, b: B256) -> B256 {
        backend::min256_u32(a, b)
    }
    #[inline(always)]
    fn max256(a: B256, b: B256) -> B256 {
        backend::max256_u32(a, b)
    }
}

impl Lane for f32 {
    const MIN_VALUE: Self = f32::NEG_INFINITY;
    const MAX_VALUE: Self = f32::INFINITY;
    const BYTES: usize = 4;
    type Reg128 = V128<f32>;
    type Reg256 = V256<f32>;
    #[inline(always)]
    fn lane_min(self, other: Self) -> Self {
        // NEON vminq_f32 semantics for non-NaN inputs; branchless minps.
        if self < other {
            self
        } else {
            other
        }
    }
    #[inline(always)]
    fn lane_max(self, other: Self) -> Self {
        if self > other {
            self
        } else {
            other
        }
    }
    #[inline(always)]
    fn min128(a: B128, b: B128) -> B128 {
        backend::min128_f32(a, b)
    }
    #[inline(always)]
    fn max128(a: B128, b: B128) -> B128 {
        backend::max128_f32(a, b)
    }
    #[inline(always)]
    fn min256(a: B256, b: B256) -> B256 {
        backend::min256_f32(a, b)
    }
    #[inline(always)]
    fn max256(a: B256, b: B256) -> B256 {
        backend::max256_f32(a, b)
    }
}

impl Lane for u64 {
    const MIN_VALUE: Self = u64::MIN;
    const MAX_VALUE: Self = u64::MAX;
    const BYTES: usize = 8;
    type Reg128 = V128D<u64>;
    type Reg256 = V256D<u64>;
    #[inline(always)]
    fn lane_min(self, other: Self) -> Self {
        Ord::min(self, other)
    }
    #[inline(always)]
    fn lane_max(self, other: Self) -> Self {
        Ord::max(self, other)
    }
    #[inline(always)]
    fn min128(a: B128, b: B128) -> B128 {
        backend::min128_u64(a, b)
    }
    #[inline(always)]
    fn max128(a: B128, b: B128) -> B128 {
        backend::max128_u64(a, b)
    }
    #[inline(always)]
    fn min256(a: B256, b: B256) -> B256 {
        backend::min256_u64(a, b)
    }
    #[inline(always)]
    fn max256(a: B256, b: B256) -> B256 {
        backend::max256_u64(a, b)
    }
}

/// A packed `(key, payload)` pair — the paper's database motivation
/// (§1: retrieving `(key, rowid)` tuples) as a first-class lane type.
///
/// The pair is one `u64` lane: key in the high 32 bits, payload in the
/// low 32 (the [`pack_key_rowid`] layout). A single unsigned 64-bit
/// comparison therefore orders by key first, with the payload breaking
/// key ties deterministically (ascending payload) — so every kernel
/// from the comparator networks to the K-flight run merge sorts pairs
/// without knowing they are pairs, and equal-key runs come out in a
/// pinned, reproducible payload order.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug, Hash)]
#[repr(transparent)]
pub struct KeyValue(u64);

impl KeyValue {
    /// Pack a key and payload into one lane.
    #[inline(always)]
    pub fn new(key: u32, payload: u32) -> Self {
        KeyValue(pack_key_rowid(key, payload))
    }

    /// The sort key (high 32 bits).
    #[inline(always)]
    pub fn key(self) -> u32 {
        (self.0 >> 32) as u32
    }

    /// The carried payload (low 32 bits).
    #[inline(always)]
    pub fn payload(self) -> u32 {
        self.0 as u32
    }

    /// The raw packed representation.
    #[inline(always)]
    pub fn packed(self) -> u64 {
        self.0
    }

    /// Wrap an already-packed `u64` (inverse of [`KeyValue::packed`]).
    #[inline(always)]
    pub fn from_packed(p: u64) -> Self {
        KeyValue(p)
    }
}

impl Lane for KeyValue {
    const MIN_VALUE: Self = KeyValue(u64::MIN);
    const MAX_VALUE: Self = KeyValue(u64::MAX);
    const BYTES: usize = 8;
    type Reg128 = V128D<KeyValue>;
    type Reg256 = V256D<KeyValue>;
    #[inline(always)]
    fn lane_min(self, other: Self) -> Self {
        Ord::min(self, other)
    }
    #[inline(always)]
    fn lane_max(self, other: Self) -> Self {
        Ord::max(self, other)
    }
    // The packed order *is* unsigned 64-bit order (key-major, payload
    // tie-break), so pairs ride the u64 comparators unchanged.
    #[inline(always)]
    fn min128(a: B128, b: B128) -> B128 {
        backend::min128_u64(a, b)
    }
    #[inline(always)]
    fn max128(a: B128, b: B128) -> B128 {
        backend::max128_u64(a, b)
    }
    #[inline(always)]
    fn min256(a: B256, b: B256) -> B256 {
        backend::min256_u64(a, b)
    }
    #[inline(always)]
    fn max256(a: B256, b: B256) -> B256 {
        backend::max256_u64(a, b)
    }
}

/// Pack a `(key, rowid)` pair into one sortable `u64` — the paper's
/// database-retrieval representation (§1). Sorting the packed values
/// orders by key with rowid as a deterministic tie-break, and the
/// SIMD path sorts them natively: `u64` (and the typed [`KeyValue`]
/// wrapper) are `Lane`s carried two-per-register by
/// [`super::V128D`].
#[inline(always)]
pub fn pack_key_rowid(key: u32, rowid: u32) -> u64 {
    ((key as u64) << 32) | rowid as u64
}

/// Inverse of [`pack_key_rowid`].
#[inline(always)]
pub fn unpack_key_rowid(packed: u64) -> (u32, u32) {
    ((packed >> 32) as u32, packed as u32)
}
