use super::*;
use crate::simd::lane::{pack_key_rowid, unpack_key_rowid};

fn v(a: i32, b: i32, c: i32, d: i32) -> V128<i32> {
    V128([a, b, c, d])
}

#[test]
fn splat_load_store_roundtrip() {
    let x = V128::<u32>::splat(7);
    assert_eq!(x.to_array(), [7, 7, 7, 7]);
    let src = [1u32, 2, 3, 4, 5];
    let r = V128::load(&src);
    let mut dst = [0u32; 4];
    r.store(&mut dst);
    assert_eq!(dst, [1, 2, 3, 4]);
    assert_eq!(r.lane(2), 3);
}

#[test]
fn min_max_cmpswap_lanewise() {
    let a = v(1, 9, -3, 4);
    let b = v(2, 5, -7, 4);
    assert_eq!(a.min(b).to_array(), [1, 5, -7, 4]);
    assert_eq!(a.max(b).to_array(), [2, 9, -3, 4]);
    let (lo, hi) = a.cmpswap(b);
    assert_eq!(lo, a.min(b));
    assert_eq!(hi, a.max(b));
}

#[test]
fn float_min_max() {
    let a = V128([1.0f32, -2.5, 0.0, 3.5]);
    let b = V128([0.5f32, -2.0, 1.0, 3.5]);
    assert_eq!(a.min(b).to_array(), [0.5, -2.5, 0.0, 3.5]);
    assert_eq!(a.max(b).to_array(), [1.0, -2.0, 1.0, 3.5]);
}

#[test]
fn shuffles_match_neon_semantics() {
    let a = v(0, 1, 2, 3);
    let b = v(10, 11, 12, 13);
    assert_eq!(a.zip1(b).to_array(), [0, 10, 1, 11]);
    assert_eq!(a.zip2(b).to_array(), [2, 12, 3, 13]);
    assert_eq!(a.uzp1(b).to_array(), [0, 2, 10, 12]);
    assert_eq!(a.uzp2(b).to_array(), [1, 3, 11, 13]);
    assert_eq!(a.trn1(b).to_array(), [0, 10, 2, 12]);
    assert_eq!(a.trn2(b).to_array(), [1, 11, 3, 13]);
    assert_eq!(a.rev64().to_array(), [1, 0, 3, 2]);
    assert_eq!(a.swap_halves().to_array(), [2, 3, 0, 1]);
    assert_eq!(a.reverse().to_array(), [3, 2, 1, 0]);
}

#[test]
fn zip_uzp_inverse() {
    // uzp(zip(a,b)) == (a,b): the pair round-trips.
    let a = v(4, 8, 15, 16);
    let b = v(23, 42, -1, 0);
    let lo = a.zip1(b);
    let hi = a.zip2(b);
    assert_eq!(lo.uzp1(hi), a);
    assert_eq!(lo.uzp2(hi), b);
}

#[test]
fn transpose4_is_matrix_transpose() {
    let m = [v(0, 1, 2, 3), v(10, 11, 12, 13), v(20, 21, 22, 23), v(30, 31, 32, 33)];
    let t = transpose4(m);
    for i in 0..4 {
        for j in 0..4 {
            assert_eq!(t[i].lane(j), m[j].lane(i), "t[{i}][{j}]");
        }
    }
    // Involution: transpose twice is identity.
    assert_eq!(transpose4(t), m);
}

#[test]
fn transpose_rx4_produces_contiguous_runs() {
    // 8x4 matrix whose columns are 0..8, 100..108, 200..208, 300..308.
    // After transpose, run j (length 8) must be contiguous in output
    // registers j*2 and j*2+1.
    let mut regs: Vec<V128<i32>> = (0..8)
        .map(|i| V128([i, 100 + i, 200 + i, 300 + i]))
        .collect();
    transpose_rx4(&mut regs);
    let flat: Vec<i32> = regs.iter().flat_map(|r| r.to_array()).collect();
    let expect: Vec<i32> = (0..8).chain(100..108).chain(200..208).chain(300..308).collect();
    assert_eq!(flat, expect);
}

#[test]
fn transpose_16x4_runs() {
    let mut regs: Vec<V128<i32>> = (0..16)
        .map(|i| V128([i, 1000 + i, 2000 + i, 3000 + i]))
        .collect();
    transpose_rx4(&mut regs);
    let flat: Vec<i32> = regs.iter().flat_map(|r| r.to_array()).collect();
    let expect: Vec<i32> = (0..16).chain(1000..1016).chain(2000..2016).chain(3000..3016).collect();
    assert_eq!(flat, expect);
}

#[test]
fn transpose_4x4_via_rx4_matches_transpose4() {
    let m = [v(0, 1, 2, 3), v(10, 11, 12, 13), v(20, 21, 22, 23), v(30, 31, 32, 33)];
    let mut regs = m.to_vec();
    transpose_rx4(&mut regs);
    assert_eq!(regs.as_slice(), &transpose4(m)[..]);
}

#[test]
#[should_panic(expected = "multiple of W")]
fn transpose_rejects_non_multiple() {
    let mut regs = vec![V128::<u32>::splat(0); 6];
    transpose_rx4(&mut regs);
}

#[test]
fn key_rowid_pack_roundtrip_preserves_key_order() {
    let a = pack_key_rowid(5, 999);
    let b = pack_key_rowid(6, 0);
    assert!(a < b, "key dominates rowid in packed order");
    assert_eq!(unpack_key_rowid(a), (5, 999));
    assert_eq!(unpack_key_rowid(b), (6, 0));
}

#[test]
fn lane_select_le_is_branchless_semantics() {
    use crate::simd::Lane;
    assert_eq!(3i32.select_le(5, "a", "b"), "a");
    assert_eq!(5i32.select_le(3, "a", "b"), "b");
    assert_eq!(4u32.select_le(4, 1, 2), 1);
}
