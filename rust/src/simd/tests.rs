use super::*;
use crate::simd::lane::{pack_key_rowid, unpack_key_rowid};

fn v(a: i32, b: i32, c: i32, d: i32) -> V128<i32> {
    V128([a, b, c, d])
}

#[test]
fn splat_load_store_roundtrip() {
    let x = V128::<u32>::splat(7);
    assert_eq!(x.to_array(), [7, 7, 7, 7]);
    let src = [1u32, 2, 3, 4, 5];
    let r = V128::load(&src);
    let mut dst = [0u32; 4];
    r.store(&mut dst);
    assert_eq!(dst, [1, 2, 3, 4]);
    assert_eq!(r.lane(2), 3);
}

#[test]
fn min_max_cmpswap_lanewise() {
    let a = v(1, 9, -3, 4);
    let b = v(2, 5, -7, 4);
    assert_eq!(a.min(b).to_array(), [1, 5, -7, 4]);
    assert_eq!(a.max(b).to_array(), [2, 9, -3, 4]);
    let (lo, hi) = a.cmpswap(b);
    assert_eq!(lo, a.min(b));
    assert_eq!(hi, a.max(b));
}

#[test]
fn float_min_max() {
    let a = V128([1.0f32, -2.5, 0.0, 3.5]);
    let b = V128([0.5f32, -2.0, 1.0, 3.5]);
    assert_eq!(a.min(b).to_array(), [0.5, -2.5, 0.0, 3.5]);
    assert_eq!(a.max(b).to_array(), [1.0, -2.0, 1.0, 3.5]);
}

#[test]
fn shuffles_match_neon_semantics() {
    let a = v(0, 1, 2, 3);
    let b = v(10, 11, 12, 13);
    assert_eq!(a.zip1(b).to_array(), [0, 10, 1, 11]);
    assert_eq!(a.zip2(b).to_array(), [2, 12, 3, 13]);
    assert_eq!(a.uzp1(b).to_array(), [0, 2, 10, 12]);
    assert_eq!(a.uzp2(b).to_array(), [1, 3, 11, 13]);
    assert_eq!(a.trn1(b).to_array(), [0, 10, 2, 12]);
    assert_eq!(a.trn2(b).to_array(), [1, 11, 3, 13]);
    assert_eq!(a.rev64().to_array(), [1, 0, 3, 2]);
    assert_eq!(a.swap_halves().to_array(), [2, 3, 0, 1]);
    assert_eq!(a.reverse().to_array(), [3, 2, 1, 0]);
}

#[test]
fn zip_uzp_inverse() {
    // uzp(zip(a,b)) == (a,b): the pair round-trips.
    let a = v(4, 8, 15, 16);
    let b = v(23, 42, -1, 0);
    let lo = a.zip1(b);
    let hi = a.zip2(b);
    assert_eq!(lo.uzp1(hi), a);
    assert_eq!(lo.uzp2(hi), b);
}

#[test]
fn transpose4_is_matrix_transpose() {
    let m = [v(0, 1, 2, 3), v(10, 11, 12, 13), v(20, 21, 22, 23), v(30, 31, 32, 33)];
    let t = transpose4(m);
    for i in 0..4 {
        for j in 0..4 {
            assert_eq!(t[i].lane(j), m[j].lane(i), "t[{i}][{j}]");
        }
    }
    // Involution: transpose twice is identity.
    assert_eq!(transpose4(t), m);
}

#[test]
fn transpose_rx4_produces_contiguous_runs() {
    // 8x4 matrix whose columns are 0..8, 100..108, 200..208, 300..308.
    // After transpose, run j (length 8) must be contiguous in output
    // registers j*2 and j*2+1.
    let mut regs: Vec<V128<i32>> = (0..8)
        .map(|i| V128([i, 100 + i, 200 + i, 300 + i]))
        .collect();
    transpose_rx4(&mut regs);
    let flat: Vec<i32> = regs.iter().flat_map(|r| r.to_array()).collect();
    let expect: Vec<i32> = (0..8).chain(100..108).chain(200..208).chain(300..308).collect();
    assert_eq!(flat, expect);
}

#[test]
fn transpose_16x4_runs() {
    let mut regs: Vec<V128<i32>> = (0..16)
        .map(|i| V128([i, 1000 + i, 2000 + i, 3000 + i]))
        .collect();
    transpose_rx4(&mut regs);
    let flat: Vec<i32> = regs.iter().flat_map(|r| r.to_array()).collect();
    let expect: Vec<i32> = (0..16).chain(1000..1016).chain(2000..2016).chain(3000..3016).collect();
    assert_eq!(flat, expect);
}

#[test]
fn transpose_4x4_via_rx4_matches_transpose4() {
    let m = [v(0, 1, 2, 3), v(10, 11, 12, 13), v(20, 21, 22, 23), v(30, 31, 32, 33)];
    let mut regs = m.to_vec();
    transpose_rx4(&mut regs);
    assert_eq!(regs.as_slice(), &transpose4(m)[..]);
}

#[test]
#[should_panic(expected = "multiple of W")]
fn transpose_rejects_non_multiple() {
    let mut regs = vec![V128::<u32>::splat(0); 6];
    transpose_rx4(&mut regs);
}

#[test]
fn key_rowid_pack_roundtrip_preserves_key_order() {
    let a = pack_key_rowid(5, 999);
    let b = pack_key_rowid(6, 0);
    assert!(a < b, "key dominates rowid in packed order");
    assert_eq!(unpack_key_rowid(a), (5, 999));
    assert_eq!(unpack_key_rowid(b), (6, 0));
}

#[test]
fn lane_select_le_is_branchless_semantics() {
    use crate::simd::Lane;
    assert_eq!(3i32.select_le(5, "a", "b"), "a");
    assert_eq!(5i32.select_le(3, "a", "b"), "b");
    assert_eq!(4u32.select_le(4, 1, 2), 1);
}

// ---- V256: the paired-q-register width ----

fn v8(vals: [i32; 8]) -> V256<i32> {
    V256::load(&vals)
}

#[test]
fn v256_splat_load_store_lane_roundtrip() {
    let x = V256::<u32>::splat(9);
    assert_eq!(x.to_array(), [9; 8]);
    let src: Vec<u32> = (1..=10).collect();
    let r = V256::load(&src);
    assert_eq!(r.to_array(), [1, 2, 3, 4, 5, 6, 7, 8]);
    let mut dst = [0u32; 9];
    Vector::store(r, &mut dst);
    assert_eq!(&dst[..8], &[1, 2, 3, 4, 5, 6, 7, 8]);
    assert_eq!(dst[8], 0, "store writes exactly LANES elements");
    for i in 0..8 {
        assert_eq!(Vector::lane(r, i), (i + 1) as u32);
    }
}

#[test]
fn v256_min_max_reverse_lower_to_v128_pairs() {
    let a = v8([1, 9, -3, 4, 7, -8, 0, 2]);
    let b = v8([2, 5, -7, 4, -1, 6, 0, 3]);
    // Trait results equal the explicit two-half lowering.
    assert_eq!(Vector::min(a, b).0[0], a.0[0].min(b.0[0]));
    assert_eq!(Vector::min(a, b).0[1], a.0[1].min(b.0[1]));
    assert_eq!(Vector::max(a, b).0[0], a.0[0].max(b.0[0]));
    assert_eq!(Vector::max(a, b).0[1], a.0[1].max(b.0[1]));
    assert_eq!(Vector::min(a, b).to_array(), [1, 5, -7, 4, -1, -8, 0, 2]);
    assert_eq!(Vector::max(a, b).to_array(), [2, 9, -3, 4, 7, 6, 0, 3]);
    assert_eq!(Vector::reverse(v8([0, 1, 2, 3, 4, 5, 6, 7])).to_array(), [7, 6, 5, 4, 3, 2, 1, 0]);
}

#[test]
fn v256_bitonic_merge_lanes_sorts_all_bitonic_01() {
    // Zero-one principle over every ascending⌢descending 0/1 pattern
    // of 8 lanes: rise point × fall point exhaustively.
    for rise in 0..=8usize {
        for fall in rise..=8 {
            let mut arr = [0i32; 8];
            for v in arr.iter_mut().take(fall).skip(rise) {
                *v = 1;
            }
            let mut expect = arr;
            expect.sort_unstable();
            let got = Vector::bitonic_merge_lanes(v8(arr)).to_array();
            assert_eq!(got, expect, "rise={rise} fall={fall}");
        }
    }
}

#[test]
fn v256_sort_lanes_random_and_dups() {
    let mut rng = crate::testutil::Rng::new(21);
    for _ in 0..500 {
        let mut vals = [0i32; 8];
        for v in vals.iter_mut() {
            *v = (rng.next_u32() % 8) as i32 - 4; // heavy duplicates
        }
        let mut expect = vals;
        expect.sort_unstable();
        assert_eq!(Vector::sort_lanes(v8(vals)).to_array(), expect, "{vals:?}");
    }
}

#[test]
fn transpose8_is_matrix_transpose() {
    let m: Vec<V256<i32>> =
        (0..8).map(|i| v8(std::array::from_fn(|j| 10 * i + j as i32))).collect();
    let t = transpose8([m[0], m[1], m[2], m[3], m[4], m[5], m[6], m[7]]);
    for i in 0..8 {
        for j in 0..8 {
            assert_eq!(Vector::lane(t[i], j), Vector::lane(m[j], i), "t[{i}][{j}]");
        }
    }
    // Involution.
    let tt = transpose8(t);
    for (a, b) in tt.iter().zip(&m) {
        assert_eq!(a, b);
    }
}

#[test]
fn v256_transpose_tile_matches_transpose8() {
    let m: Vec<V256<i32>> =
        (0..8).map(|i| v8(std::array::from_fn(|j| 100 * i + j as i32))).collect();
    let mut tile = m.clone();
    V256::transpose_tile(&mut tile);
    let t = transpose8([m[0], m[1], m[2], m[3], m[4], m[5], m[6], m[7]]);
    assert_eq!(tile.as_slice(), &t[..]);
}

#[test]
fn v128_trait_matches_inherent_ops() {
    // The Vector impl must agree with the inherent V128 methods the
    // V128-only helpers still use.
    let a = v(3, -1, 7, 2);
    let b = v(0, 5, 7, -9);
    assert_eq!(Vector::min(a, b), a.min(b));
    assert_eq!(Vector::max(a, b), a.max(b));
    assert_eq!(Vector::reverse(a), a.reverse());
    assert_eq!(<V128<i32> as Lanes>::LANES, 4);
    assert_eq!(<V256<i32> as Lanes>::LANES, 8);
}

#[test]
fn v128_sort_and_merge_lanes_via_trait() {
    // 4-lane trait paths (shared with the kernels' generic code).
    let mut rng = crate::testutil::Rng::new(5);
    for _ in 0..200 {
        let vals = [rng.next_i32(), rng.next_i32(), rng.next_i32(), rng.next_i32()];
        let mut expect = vals;
        expect.sort_unstable();
        assert_eq!(Vector::sort_lanes(V128(vals)).to_array(), expect);
    }
}

#[test]
fn vector_width_lanes_and_names() {
    assert_eq!(VectorWidth::V128.lanes(), 4);
    assert_eq!(VectorWidth::V256.lanes(), 8);
    assert_eq!(VectorWidth::all().map(|w| w.name()), ["V128", "V256"]);
}

#[test]
fn vector_width_lanes_for_element_bytes() {
    assert_eq!(VectorWidth::V128.lanes_for::<u32>(), 4);
    assert_eq!(VectorWidth::V256.lanes_for::<u32>(), 8);
    assert_eq!(VectorWidth::V128.lanes_for::<u64>(), 2);
    assert_eq!(VectorWidth::V256.lanes_for::<u64>(), 4);
    assert_eq!(VectorWidth::V128.lanes_for::<KeyValue>(), 2);
    assert_eq!(VectorWidth::V256.lanes_for::<KeyValue>(), 4);
}

// ---- V128D / V256D: the 64-bit-lane register types ----

fn d(a: u64, b: u64) -> V128D<u64> {
    V128D([a, b])
}

#[test]
fn v128d_splat_load_store_lane_roundtrip() {
    let x = V128D::<u64>::splat(7);
    assert_eq!(x.to_array(), [7, 7]);
    // Values above u32::MAX: the lanes are genuinely 64-bit.
    let src = [u64::MAX - 1, 1 << 40, 3];
    let r = V128D::load(&src);
    let mut dst = [0u64; 2];
    r.store(&mut dst);
    assert_eq!(dst, [u64::MAX - 1, 1 << 40]);
    assert_eq!(r.lane(1), 1 << 40);
    assert_eq!(<V128D<u64> as Lanes>::LANES, 2);
    assert_eq!(<V128D<u64> as Lanes>::LANE_BYTES, 8);
}

#[test]
fn v128d_min_max_cmpswap_shuffles() {
    let a = d(1 << 35, 2);
    let b = d(5, u64::MAX);
    assert_eq!(a.min(b).to_array(), [5, 2]);
    assert_eq!(a.max(b).to_array(), [1 << 35, u64::MAX]);
    let (lo, hi) = a.cmpswap(b);
    assert_eq!(lo, a.min(b));
    assert_eq!(hi, a.max(b));
    assert_eq!(a.trn1(b).to_array(), [1 << 35, 5]);
    assert_eq!(a.trn2(b).to_array(), [2, u64::MAX]);
    assert_eq!(a.swap_halves().to_array(), [2, 1 << 35]);
    // At two 64-bit lanes the half-swap IS the full reversal.
    assert_eq!(a.reverse(), a.swap_halves());
}

#[test]
fn v128d_sort_and_merge_lanes_exhaustive() {
    // Two lanes: every ordering is bitonic, so both the sorter and the
    // single-stage merge must sort every input.
    for vals in [[0u64, 1], [1, 0], [3, 3], [u64::MAX, 0]] {
        let mut expect = vals;
        expect.sort_unstable();
        assert_eq!(Vector::sort_lanes(V128D(vals)).to_array(), expect, "{vals:?}");
        assert_eq!(Vector::bitonic_merge_lanes(V128D(vals)).to_array(), expect);
    }
}

#[test]
fn transpose2_is_matrix_transpose() {
    let m = [d(0, 1), d(10, 11)];
    let t = transpose2(m);
    for i in 0..2 {
        for j in 0..2 {
            assert_eq!(t[i].lane(j), m[j].lane(i), "t[{i}][{j}]");
        }
    }
    assert_eq!(transpose2(t), m); // involution
    let mut tile = m.to_vec();
    V128D::transpose_tile(&mut tile);
    assert_eq!(tile.as_slice(), &t[..]);
}

fn d4(vals: [u64; 4]) -> V256D<u64> {
    V256D::load(&vals)
}

#[test]
fn v256d_splat_load_store_lane_roundtrip() {
    let x = V256D::<u64>::splat(9);
    assert_eq!(x.to_array(), [9; 4]);
    let src: Vec<u64> = (1..=6).map(|i| i << 33).collect();
    let r = V256D::load(&src);
    assert_eq!(r.to_array(), [1 << 33, 2 << 33, 3 << 33, 4 << 33]);
    let mut dst = [0u64; 5];
    Vector::store(r, &mut dst);
    assert_eq!(&dst[..4], &[1 << 33, 2 << 33, 3 << 33, 4 << 33]);
    assert_eq!(dst[4], 0, "store writes exactly LANES elements");
    for i in 0..4 {
        assert_eq!(Vector::lane(r, i), ((i + 1) as u64) << 33);
    }
    assert_eq!(<V256D<u64> as Lanes>::LANES, 4);
    assert_eq!(<V256D<u64> as Lanes>::LANE_BYTES, 8);
}

#[test]
fn v256d_min_max_reverse_lower_to_v128d_pairs() {
    let a = d4([1, 9 << 40, 3, 4]);
    let b = d4([2, 5, 7 << 40, 4]);
    assert_eq!(Vector::min(a, b).0[0], a.0[0].min(b.0[0]));
    assert_eq!(Vector::min(a, b).0[1], a.0[1].min(b.0[1]));
    assert_eq!(Vector::max(a, b).0[0], a.0[0].max(b.0[0]));
    assert_eq!(Vector::max(a, b).0[1], a.0[1].max(b.0[1]));
    assert_eq!(Vector::min(a, b).to_array(), [1, 5, 3, 4]);
    assert_eq!(Vector::max(a, b).to_array(), [2, 9 << 40, 7 << 40, 4]);
    assert_eq!(Vector::reverse(d4([0, 1, 2, 3])).to_array(), [3, 2, 1, 0]);
}

#[test]
fn v256d_bitonic_merge_lanes_sorts_all_bitonic_01() {
    // Zero-one principle over every ascending⌢descending 0/1 pattern
    // of 4 lanes.
    for rise in 0..=4usize {
        for fall in rise..=4 {
            let mut arr = [0u64; 4];
            for v in arr.iter_mut().take(fall).skip(rise) {
                *v = 1;
            }
            let mut expect = arr;
            expect.sort_unstable();
            let got = Vector::bitonic_merge_lanes(d4(arr)).to_array();
            assert_eq!(got, expect, "rise={rise} fall={fall}");
        }
    }
}

#[test]
fn v256d_sort_lanes_random_and_dups() {
    let mut rng = crate::testutil::Rng::new(23);
    for _ in 0..500 {
        let mut vals = [0u64; 4];
        for v in vals.iter_mut() {
            // Heavy duplicates, high bits set: both comparison halves
            // of the 64-bit lane matter.
            *v = (rng.next_u64() % 4) << 40 | rng.next_u64() % 4;
        }
        let mut expect = vals;
        expect.sort_unstable();
        assert_eq!(Vector::sort_lanes(d4(vals)).to_array(), expect, "{vals:?}");
    }
}

#[test]
fn transpose4d_is_matrix_transpose() {
    let m: Vec<V256D<u64>> =
        (0..4).map(|i| d4(std::array::from_fn(|j| (10 * i + j) as u64))).collect();
    let t = transpose4d([m[0], m[1], m[2], m[3]]);
    for i in 0..4 {
        for j in 0..4 {
            assert_eq!(Vector::lane(t[i], j), Vector::lane(m[j], i), "t[{i}][{j}]");
        }
    }
    // Involution.
    let tt = transpose4d(t);
    for (a, b) in tt.iter().zip(&m) {
        assert_eq!(a, b);
    }
    // The Vector trait tile entry point agrees.
    let mut tile = m.clone();
    V256D::transpose_tile(&mut tile);
    assert_eq!(tile.as_slice(), &t[..]);
}

// ---- KeyValue: the packed key–payload pair ----

#[test]
fn keyvalue_accessors_and_packed_roundtrip() {
    let kv = KeyValue::new(0xDEAD_BEEF, 42);
    assert_eq!(kv.key(), 0xDEAD_BEEF);
    assert_eq!(kv.payload(), 42);
    assert_eq!(KeyValue::from_packed(kv.packed()), kv);
    // Same layout as the scalar baseline's packing helper.
    assert_eq!(kv.packed(), pack_key_rowid(0xDEAD_BEEF, 42));
}

#[test]
fn keyvalue_order_is_key_major_payload_tiebreak() {
    let lo_key = KeyValue::new(5, u32::MAX);
    let hi_key = KeyValue::new(6, 0);
    assert!(lo_key < hi_key, "key dominates payload");
    let tie_a = KeyValue::new(7, 1);
    let tie_b = KeyValue::new(7, 2);
    assert!(tie_a < tie_b, "equal keys break ties by payload");
    // Derived Ord == packed u64 order, exhaustively sampled.
    let mut rng = crate::testutil::Rng::new(29);
    for _ in 0..1000 {
        let a = KeyValue::new(rng.next_u32() % 8, rng.next_u32() % 8);
        let b = KeyValue::new(rng.next_u32() % 8, rng.next_u32() % 8);
        assert_eq!(a.cmp(&b), a.packed().cmp(&b.packed()), "{a:?} vs {b:?}");
    }
}

#[test]
fn keyvalue_is_a_lane() {
    assert_eq!(KeyValue::BYTES, 8);
    assert_eq!(KeyValue::MIN_VALUE, KeyValue::new(0, 0));
    assert_eq!(KeyValue::MAX_VALUE, KeyValue::new(u32::MAX, u32::MAX));
    let a = KeyValue::new(3, 9);
    let b = KeyValue::new(3, 1);
    assert_eq!(a.lane_min(b), b);
    assert_eq!(a.lane_max(b), a);
    // Pairs ride the 64-bit registers.
    let r = V128D::load(&[KeyValue::new(2, 0), KeyValue::new(1, 5)]);
    assert_eq!(
        Vector::sort_lanes(r).to_array(),
        [KeyValue::new(1, 5), KeyValue::new(2, 0)]
    );
}
