//! NEON-like 128-bit SIMD substrate.
//!
//! The paper's kernels are written against ARM NEON's `q` registers:
//! 128 bits, four 32-bit lanes, with `vminq`/`vmaxq` comparators and
//! `vzipq`/`vuzpq`/`vrev64q`/`vtrnq` shuffles. This testbed is x86-64,
//! so we substitute a portable [`V128`] type with exactly NEON's lane
//! semantics. Every method is a thin, `#[inline(always)]` array
//! operation that LLVM lowers to the SSE2/SSE4.1 equivalent of the
//! corresponding NEON instruction (`pminsd`/`pmaxsd`, `punpckl/hdq`,
//! `pshufd`, ...), preserving the paper's cost structure: one
//! comparator = one `vmin` + one `vmax`, one shuffle = one port-5 op.
//!
//! See DESIGN.md §Hardware-Adaptation.

mod lane;
mod v128;

pub use lane::{pack_key_rowid, unpack_key_rowid, Lane};
pub use v128::{transpose4, transpose_rx4, V128};

/// Number of 32-bit lanes per vector register — the paper's `W`.
pub const W: usize = 4;

/// Number of architectural vector registers on ARM NEON (AArch64):
/// `v0..v31`. The paper's §2.2 argues the *usable* count for an
/// in-register sort is 16 once shuffle temporaries and loop-carried
/// state are excluded.
pub const NEON_REGISTER_FILE: usize = 32;

#[cfg(test)]
mod tests;
