//! NEON-like SIMD substrate: width-generic since PR 3, and lowered
//! through pluggable runtime-dispatched backends since PR 9.
//!
//! The paper's kernels are written against ARM NEON's `q` registers:
//! 128 bits, four 32-bit lanes, with `vminq`/`vmaxq` comparators and
//! `vzipq`/`vuzpq`/`vrev64q`/`vtrnq` shuffles. The register types here
//! keep exactly NEON's lane semantics, but each op now dispatches — at
//! the trait-impl boundary, never inside the algorithms — to one of
//! the [`backend`] lowerings: the portable scalar reference model
//! (always available), real NEON intrinsics on `aarch64`, or
//! SSE4.2/AVX2 intrinsics on `x86_64`. The backend is picked once per
//! process by runtime feature detection and can be forced via
//! `NEONMS_SIMD_BACKEND`, [`crate::sort::SortConfig::backend`], or the
//! CLI `--backend` flag; `scalar` is always a valid choice. The cost
//! structure the paper counts is preserved on every backend: one
//! comparator = one `vmin` + one `vmax`, one shuffle = one port-5 op.
//!
//! Since the width sweep (§2.2's vector width × register budget
//! tradeoff) needs the same kernels at more than one width, the
//! kernel-facing surface is the [`Vector`] trait rather than a
//! concrete type:
//!
//! * [`V128`] — `W = 4`, the paper's geometry (and the default);
//! * [`V256`] — `W = 8`, paired q-registers / SVE-256, each op
//!   lowering to two `V128` ops on this host (see `v256.rs` for the
//!   exact cost accounting);
//! * [`V128D`] / [`V256D`] — the same two register widths at 64-bit
//!   element width (`W = 2` / `W = 4`), carrying `u64` keys and
//!   packed [`KeyValue`] pairs for the database `(key, rowid)` path.
//!
//! Element width is a first-class axis: every [`Lane`] names its byte
//! width and its concrete register types ([`Lane::BYTES`],
//! [`Lane::Reg128`], [`Lane::Reg256`]), and kernels dispatch through
//! those instead of hard-wiring `V128`/`V256`.
//!
//! [`VectorWidth`] is the runtime selector configs carry;
//! [`Lanes`] is the `Lane`-free width marker const guards use.
//!
//! See DESIGN.md §Hardware-Adaptation.

pub mod backend;
mod lane;
mod v128;
mod v128d;
mod v256;
mod v256d;
mod vector;

pub use backend::Backend;
pub use lane::{pack_key_rowid, unpack_key_rowid, KeyValue, Lane};
pub use v128::{transpose4, transpose_rx4, V128};
pub use v128d::{transpose2, V128D};
pub use v256::{transpose8, V256};
pub use v256d::{transpose4d, V256D};
pub use vector::{Lanes, Vector, VectorWidth};

/// Number of 32-bit lanes per 128-bit base register — the paper's `W`
/// at the paper's width. Width-generic code must use
/// [`Lanes::LANES`]/[`VectorWidth::lanes`] instead; this constant
/// remains for the V128-only helpers and the NEON cost discussions.
pub const W: usize = 4;

/// Number of architectural vector registers on ARM NEON (AArch64):
/// `v0..v31`. The paper's §2.2 argues the *usable* count for an
/// in-register sort is 16 once shuffle temporaries and loop-carried
/// state are excluded. A `V256` occupies two of these (a q-register
/// pair), which is why the wider configurations halve the usable `R`.
pub const NEON_REGISTER_FILE: usize = 32;

#[cfg(test)]
mod tests;
