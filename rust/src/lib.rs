//! # NEON-MS: A Hybrid Vectorized Merge Sort
//!
//! Reproduction of *"A Hybrid Vectorized Merge Sort on ARM NEON"*
//! (Zhou, Zhang, Zhang, Xiao, Ma, Gong — CS.DC 2024) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the sorting *framework*: the NEON-MS
//!   algorithm itself (in-register sort, hybrid bitonic mergers,
//!   merge-path multi-thread parallel merge), the baselines it is
//!   evaluated against, a sort-service coordinator, and the benchmark
//!   harness that regenerates every table and figure of the paper.
//! * **Layer 2 (python/compile/model.py)** — the same block-sort compute
//!   graph in JAX, AOT-lowered to HLO text artifacts.
//! * **Layer 1 (python/compile/kernels/)** — the in-register sort +
//!   bitonic merge as a Pallas kernel (interpret mode), validated
//!   against a pure-jnp oracle.
//!
//! The paper targets ARM NEON on an FT2000+. The NEON register model
//! is reproduced by the width-generic [`simd::Vector`] layer:
//! [`simd::V128`] — a 128-bit, 4-lane vector type whose operations
//! map 1:1 onto the NEON intrinsics the paper uses (`vminq_s32`,
//! `vmaxq_s32`, `vzipq`, ...) — and [`simd::V256`], its 8-lane
//! sibling modeling paired q-registers / SVE-256. Each operation
//! lowers through a pluggable [`simd::backend`]: real `core::arch`
//! NEON intrinsics on aarch64, SSE4.2/AVX2 on x86-64, and a portable
//! scalar reference model everywhere, selected once per process by
//! runtime feature detection (override: `NEONMS_SIMD_BACKEND`,
//! [`sort::SortConfig::backend`], or `--backend`). The kernels are
//! generic over the vector type, so the §2.2 width × register budget
//! sweep is a [`sort::SortConfig`] knob (`vector_width`/
//! `merge_width`), recorded in `BENCH_width_sweep.json`.
//! Register-pressure effects (the paper's Table 2 R-sweep) are
//! additionally modeled by [`regmachine`], an abstract register-file
//! simulator with an explicit spill cost model. See DESIGN.md
//! §Hardware-Adaptation.
//!
//! # Paper → code map
//!
//! The full map, with the figure/table cross-references, lives in
//! `docs/ARCHITECTURE.md`; the short version:
//!
//! | Paper concept | Module |
//! |---|---|
//! | §2.3 / Table 1 column-sort networks (incl. the asymmetric `16*`) | [`sortnet`] |
//! | §2.3 / Fig. 2 in-register sort (load, sort, transpose, merge) | [`kernels::inregister`] |
//! | §2.4 / Fig. 4 vectorized bitonic merger | [`kernels::bitonic`] |
//! | §2.4 / Fig. 3b serial branchless (`csel`) merge | [`kernels::serial`] |
//! | §2.4 hybrid merger + the `MAX_K` register budget | [`kernels::hybrid`] |
//! | §2.1 streaming merge of sorted runs | [`kernels::runmerge`] |
//! | §2.1/§3.2 merge-path partitioning | [`mergepath`] |
//! | §2.1 single-/multi-thread NEON-MS | [`sort`] |
//! | Tables/figures regeneration | [`bench`], `benches/` |
//!
//! # The service layer
//!
//! [`coordinator`] serves the sorter to many in-process tenants:
//! [`coordinator::SortService`] owns sharded bounded queues, workers
//! and the dynamic batcher; each tenant holds a clonable
//! [`coordinator::SortClient`] whose submits return non-blocking
//! [`coordinator::SortHandle`]s (poll, `.await`, or park), with
//! per-tenant shed/latency accounting in
//! [`coordinator::MetricsSnapshot`]. Contended capacity is split by
//! weighted fair-share QoS ([`coordinator::ClientConfig`] weights;
//! the most-over-share tenant is shed first), and routing cutoffs
//! can be learned online ([`coordinator::AdaptivePolicy`]).
//! Out-of-process tenants enter through [`net`]: a hand-rolled,
//! length-prefixed TCP wire protocol ([`net::codec`]) served by
//! [`net::NetServer`] (`neonms-serve`), with backpressure surfaced
//! as `RETRY_AFTER` frames and a load-generator binary
//! (`neonms-loadgen`) that turns the QoS/chaos benches into
//! end-to-end soak tests.
//!
//! # Quickstart
//!
//! ```
//! use neonms::sort::NeonMergeSort;
//!
//! let mut data = vec![170u32, 45, 75, 90, 802, 24, 2, 66];
//! NeonMergeSort::paper_default().sort(&mut data);
//! assert_eq!(data, [2, 24, 45, 66, 75, 90, 170, 802]);
//! ```

pub mod simd;
pub mod sortnet;
pub mod kernels;
pub mod sort;
pub mod mergepath;
pub mod baselines;
pub mod regmachine;
pub mod coordinator;
pub mod net;
pub mod runtime;
pub mod bench;
pub mod testutil;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
