//! The paper's compute kernels (§2.2–§2.4).
//!
//! * [`inregister`] — the in-register sort: load R vector registers,
//!   column-sort them with a sorting network, transpose, and row-merge
//!   to sorted runs of `X ∈ {R, 2R, 4R}` (Fig. 2, Table 2).
//! * [`bitonic`] — fully *vectorized* bitonic merging networks over
//!   registers (the paper's first merger implementation, Fig. 4).
//! * [`serial`] — branchless scalar (`csel`-style) merge primitives
//!   (Fig. 3b) and the streaming two-pointer merge.
//! * [`hybrid`] — the paper's contribution: the **hybrid bitonic
//!   merger** that runs one symmetric half of the merging network
//!   vectorized and the other half serial-branchless so the two
//!   independent instruction streams interleave in the pipeline.
//! * [`runmerge`] — streaming merge of two arbitrary-length sorted
//!   runs built on any of the register merge kernels (AA-sort style),
//!   the workhorse of the full sort's merge passes.

pub mod bitonic;
pub mod hybrid;
pub mod inregister;
pub mod runmerge;
pub mod serial;

/// Which register-merge kernel a streaming run merge uses — the
/// Table 3 comparison axis.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum MergeImpl {
    /// Fully vectorized bitonic network (compare + shuffle).
    Vectorized,
    /// Hybrid: vector half + serial branchless half, interleaved.
    Hybrid,
    /// Pure branchless scalar two-pointer merge (no SIMD) — baseline
    /// and tail path.
    Serial,
}

/// Width (elements per side) of the register merge kernel: 2×K → 2K.
/// The paper evaluates K ∈ {8, 16, 32} (Table 3); this reproduction
/// additionally sweeps 2×4 below and 2×64 above (the
/// [`hybrid::MAX_K`] = 64 budget), at both register widths
/// ([`crate::simd::VectorWidth`]).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum MergeWidth {
    K4 = 4,
    K8 = 8,
    K16 = 16,
    K32 = 32,
    K64 = 64,
}

impl MergeWidth {
    /// Elements per side.
    pub fn k(self) -> usize {
        self as usize
    }
    /// Vector registers per side at width `vector` (K / lanes) — the
    /// kernel dispatch's N/2.
    pub fn regs_at(self, vector: crate::simd::VectorWidth) -> usize {
        self.k() / vector.lanes()
    }
    /// The widest kernel this width folds to for a lane of `bytes`
    /// bytes: the [`hybrid::MAX_K_BYTES`] budget caps 8-byte elements
    /// (u64, `KeyValue`) at K = 32, so `K64` folds to `K32` there —
    /// the same fold the runtime dispatch applies, exposed so configs
    /// and sweeps can reason about the effective width.
    pub fn clamp_for_bytes(self, bytes: usize) -> MergeWidth {
        let cap = hybrid::MAX_K_BYTES / bytes.max(1);
        if self.k() <= cap {
            return self;
        }
        let mut best = MergeWidth::K4;
        for w in MergeWidth::all() {
            if w.k() <= cap && w.k() > best.k() {
                best = w;
            }
        }
        best
    }
    /// All widths, for sweeps.
    pub fn all() -> [MergeWidth; 5] {
        [MergeWidth::K4, MergeWidth::K8, MergeWidth::K16, MergeWidth::K32, MergeWidth::K64]
    }
}

#[cfg(test)]
mod tests;
