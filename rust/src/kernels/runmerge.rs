//! Streaming vectorized merge of two sorted runs of arbitrary length
//! (the paper's "vectorized merge", §2.1/§2.4, after AA-sort [6]).
//!
//! The kernel keeps a K-element *in-flight block* in registers. Each
//! iteration merges it against the next K elements of whichever input
//! run currently has the smaller head (decided by one scalar compare —
//! the only branch, highly predictable on long runs), emits the lower
//! K elements to the output, and keeps the upper K in flight. The
//! 2×K register merge is either the fully vectorized or the hybrid
//! bitonic network — Table 3's comparison — instantiated at either
//! register width ([`VectorWidth`]): the same K uses half the
//! registers at `V256`, trading shuffle structure for register
//! pressure exactly along the paper's §2.2 axis.
//!
//! # Invariants
//!
//! * Everything already emitted ≤ everything in flight ≤ nothing —
//!   i.e. ≤ every element not yet consumed from either run; the
//!   in-flight block and both input tails are each sorted at every
//!   iteration.
//! * The refill always takes from the run with the **smaller head**
//!   (one scalar compare, the loop's only data-dependent decision);
//!   when that run cannot supply a full K-block the vectorized loop
//!   must stop — its short head must not be overtaken — and the
//!   serial 3-way drain finishes (tails shorter than K never enter
//!   the register kernel).
//! * The flight/staging buffers are sized by
//!   [`super::hybrid::MAX_K`] and guarded by the
//!   [`RegsFitMaxK`] monomorphization-time assertion, so every
//!   [`MergeWidth`] × [`VectorWidth`] this type accepts provably fits
//!   them.

use super::bitonic::merge_sorted_regs;
use super::hybrid::{hybrid_merge_sorted_regs, RegsFitMaxK, MAX_K};
use super::serial::merge_scalar;
use super::{MergeImpl, MergeWidth};
use crate::simd::{Lane, Vector, VectorWidth};

/// Alloc-free 3-way merge of sorted `x`, `y`, `z` into `out` — the
/// streaming merge's drain step (flight block + both input tails).
/// Branchy, but runs once per pair-merge on the leftovers only.
fn drain3<T: Lane>(x: &[T], y: &[T], z: &[T], out: &mut [T]) {
    debug_assert_eq!(out.len(), x.len() + y.len() + z.len());
    let (mut i, mut j, mut l) = (0usize, 0usize, 0usize);
    for slot in out.iter_mut() {
        // Pick the smallest available head; ties x → y → z.
        let mut src = 3u8;
        let mut best = T::MIN_VALUE;
        if i < x.len() {
            src = 0;
            best = x[i];
        }
        if j < y.len() && (src == 3 || y[j] < best) {
            src = 1;
            best = y[j];
        }
        if l < z.len() && (src == 3 || z[l] < best) {
            src = 2;
            best = z[l];
        }
        *slot = best;
        match src {
            0 => i += 1,
            1 => j += 1,
            _ => l += 1,
        }
    }
}

/// Streaming merge configuration.
#[derive(Clone, Copy, Debug)]
pub struct RunMerger {
    /// Elements per side of the register kernel (K).
    pub width: MergeWidth,
    /// Register-kernel implementation.
    pub imp: MergeImpl,
    /// Register width the kernel is instantiated at. `K4` always runs
    /// at `V128` (one `V256` cannot hold two 4-element runs — see
    /// [`RunMerger::effective_vector`]).
    pub vector: VectorWidth,
}

impl RunMerger {
    /// Default: hybrid 2×16 on `V128` — the recorded sweep's
    /// full-sort winner (`BENCH_width_sweep.json` `best_fullsort`),
    /// matching the paper's Table 3 finding that the hybrid merger is
    /// fastest at 2×{8,16}. See README §Benchmarks to re-tune.
    pub fn paper_default() -> Self {
        RunMerger { width: MergeWidth::K16, imp: MergeImpl::Hybrid, vector: VectorWidth::V128 }
    }

    /// The register width this merger actually instantiates kernels
    /// at for 32-bit lanes: the configured [`RunMerger::vector`],
    /// except that `K4` needs registers of at most 4 lanes and
    /// therefore always runs at [`VectorWidth::V128`]. The per-element
    /// generalization is [`RunMerger::effective_vector_for`].
    pub fn effective_vector(&self) -> VectorWidth {
        self.effective_vector_for::<u32>()
    }

    /// The register width kernels are instantiated at for lane type
    /// `T`: the configured [`RunMerger::vector`], folded down to
    /// [`VectorWidth::V128`] whenever the (byte-clamped) K is smaller
    /// than one wide register's lane count — a register must never
    /// hold more than one K-run per side.
    pub fn effective_vector_for<T: Lane>(&self) -> VectorWidth {
        if self.width.clamp_for_bytes(T::BYTES).k() < self.vector.lanes_for::<T>() {
            VectorWidth::V128
        } else {
            self.vector
        }
    }

    /// Merge sorted `a` and `b` into `out` (`out.len() = a.len() +
    /// b.len()`). Dispatches to the serial path when either run is
    /// shorter than one kernel block. The configured K is clamped to
    /// the [`super::hybrid::MAX_K_BYTES`] budget for `T`'s byte width
    /// (`K64` folds to `K32` for 8-byte lanes) before dispatch, so one
    /// `RunMerger` serves every element type.
    pub fn merge<T: Lane>(&self, a: &[T], b: &[T], out: &mut [T]) {
        assert_eq!(out.len(), a.len() + b.len());
        if self.imp == MergeImpl::Serial {
            return merge_scalar(a, b, out);
        }
        let k = self.width.clamp_for_bytes(T::BYTES).k();
        if a.len() < k || b.len() < k {
            return merge_scalar(a, b, out);
        }
        // Monomorphize on (vector type, register count N = 2K/W) so
        // every kernel loop bound is a compile-time constant and
        // unrolls (§Perf iteration 2: runtime-length kernel loops
        // left ~3× on the table vs the Table 3 microbenches). The
        // dispatch is on the *register count* N, not MergeWidth, so
        // the same arms serve 4- and 8-byte lanes; every arm below is
        // provably inside the byte budget for every `Lane` type
        // (`RegsFitMaxK` fires at monomorphization, so an over-budget
        // arm would break the build even if unreachable at runtime).
        let eff = self.effective_vector_for::<T>();
        let n = 2 * k / eff.lanes_for::<T>();
        match (eff, n) {
            (VectorWidth::V128, 2) => self.merge_vectorized::<T, T::Reg128, 2>(a, b, out, k),
            (VectorWidth::V128, 4) => self.merge_vectorized::<T, T::Reg128, 4>(a, b, out, k),
            (VectorWidth::V128, 8) => self.merge_vectorized::<T, T::Reg128, 8>(a, b, out, k),
            (VectorWidth::V128, 16) => self.merge_vectorized::<T, T::Reg128, 16>(a, b, out, k),
            (VectorWidth::V128, 32) => self.merge_vectorized::<T, T::Reg128, 32>(a, b, out, k),
            (VectorWidth::V256, 2) => self.merge_vectorized::<T, T::Reg256, 2>(a, b, out, k),
            (VectorWidth::V256, 4) => self.merge_vectorized::<T, T::Reg256, 4>(a, b, out, k),
            (VectorWidth::V256, 8) => self.merge_vectorized::<T, T::Reg256, 8>(a, b, out, k),
            (VectorWidth::V256, 16) => self.merge_vectorized::<T, T::Reg256, 16>(a, b, out, k),
            _ => unreachable!("clamped K {k} at {eff:?} yields no kernel ({n} registers)"),
        }
    }

    fn merge_vectorized<T: Lane, V: Vector<T>, const N: usize>(
        &self,
        a: &[T],
        b: &[T],
        out: &mut [T],
        k: usize,
    ) {
        // Monomorphization-time proof that K = N·W/2 fits the MAX_K
        // flight buffer below — a future K sweep that widens
        // MergeWidth without growing MAX_K fails to compile instead of
        // silently overflowing.
        let () = RegsFitMaxK::<V, N>::OK;
        let w = V::LANES;
        let kr = N / 2;
        debug_assert_eq!(kr * w, k);
        debug_assert!(k <= MAX_K, "K={k} exceeds MAX_K={MAX_K}");
        // In-flight block: 2K elements in N registers; lower K is
        // emitted each round, upper K stays. Stack-resident — the
        // merge-pass hot loop must not allocate (§Perf iteration 1).
        let mut regs = [V::splat(T::MIN_VALUE); N];
        for (v, c) in regs
            .iter_mut()
            .zip(a[..k].chunks_exact(w).chain(b[..k].chunks_exact(w)))
        {
            *v = V::load(c);
        }
        let (mut i, mut j) = (k, k); // consumed from a / b
        let mut o = 0usize; // emitted
        // Fast loop: while BOTH runs can supply a full block, the
        // refill source is chosen with a branchless pointer select
        // (§Perf iteration 5: the data-dependent refill branch
        // mispredicted once per K outputs on random keys).
        while i + k <= a.len() && j + k <= b.len() {
            self.kernel(&mut regs);
            for (c, v) in out[o..o + k].chunks_exact_mut(w).zip(&regs[..kr]) {
                v.store(c);
            }
            o += k;
            let take_a = a[i] <= b[j];
            // SAFETY: both indices verified in the loop condition; the
            // select compiles to cmov and the loads read k elements
            // from whichever run was chosen.
            unsafe {
                let src = if take_a { a.as_ptr().add(i) } else { b.as_ptr().add(j) };
                for (t, r) in regs[..kr].iter_mut().enumerate() {
                    *r = V::load(std::slice::from_raw_parts(src.add(t * w), w));
                }
            }
            i += k * take_a as usize;
            j += k * !take_a as usize;
        }
        loop {
            self.kernel(&mut regs);
            for (c, v) in out[o..o + k].chunks_exact_mut(w).zip(&regs[..kr]) {
                v.store(c);
            }
            o += k;
            // Refill the lower half from the run with the smaller
            // head. Correctness requires following the head rule
            // strictly: if the chosen run cannot supply a full block,
            // the vector loop must STOP (its small head elements must
            // not be overtaken by the other run's blocks) and the
            // serial drain takes over.
            let a_has = i < a.len();
            let b_has = j < b.len();
            let choose_a = a_has && (!b_has || a[i] <= b[j]);
            if choose_a {
                if i + k > a.len() {
                    break;
                }
                for (r, c) in regs[..kr].iter_mut().zip(a[i..i + k].chunks_exact(w)) {
                    *r = V::load(c);
                }
                i += k;
            } else if b_has {
                if j + k > b.len() {
                    break;
                }
                for (r, c) in regs[..kr].iter_mut().zip(b[j..j + k].chunks_exact(w)) {
                    *r = V::load(c);
                }
                j += k;
            } else {
                break;
            }
        }
        // Drain: in-flight upper K (sorted) + both tails, all ≥
        // everything emitted. Alloc-free: flight lives on the stack
        // and the 3-way merge goes through one stack staging buffer
        // sized by the kernel family's MAX_K (guarded above).
        let mut flight = [T::MIN_VALUE; MAX_K];
        for (c, v) in flight[..k].chunks_exact_mut(w).zip(&regs[kr..]) {
            v.store(c);
        }
        drain3(&flight[..k], &a[i..], &b[j..], &mut out[o..]);
    }

    #[inline(always)]
    fn kernel<T: Lane, V: Vector<T>, const N: usize>(&self, regs: &mut [V; N]) {
        // On entry: regs[..kr] sorted (new block), regs[kr..] sorted
        // (in-flight). Passing the whole fixed-size array keeps every
        // stage loop fully unrolled after inlining.
        match self.imp {
            MergeImpl::Vectorized => merge_sorted_regs(&mut regs[..]),
            MergeImpl::Hybrid => hybrid_merge_sorted_regs(&mut regs[..]),
            MergeImpl::Serial => unreachable!("dispatched earlier"),
        }
    }
}

/// Table 3 rows: the two register-kernel implementations.
pub fn table3_impls() -> [(&'static str, MergeImpl); 2] {
    [("Vectorized Bitonic", MergeImpl::Vectorized), ("Hybrid Bitonic", MergeImpl::Hybrid)]
}
