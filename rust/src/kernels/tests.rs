use super::bitonic::{self, merge4_in_reg, sort4_in_reg};
use super::hybrid;
use super::inregister::{table2_configs, ColumnNetwork, InRegisterSorter};
use super::runmerge::RunMerger;
use super::serial;
use super::{MergeImpl, MergeWidth};
use crate::simd::{VectorWidth, V128, V256};
use crate::testutil::{assert_permutation, assert_sorted, forall, forall_indexed, Rng};

fn sorted_pair(rng: &mut Rng, k: usize, modv: u32) -> (Vec<u32>, Vec<u32>) {
    let mut a: Vec<u32> = (0..k).map(|_| rng.next_u32() % modv).collect();
    let mut b: Vec<u32> = (0..k).map(|_| rng.next_u32() % modv).collect();
    a.sort_unstable();
    b.sort_unstable();
    (a, b)
}

#[test]
fn sort4_in_reg_all_permutations() {
    // Exhaustive over all 4! orders of distinct values + dup patterns.
    let vals = [3i32, 1, 4, 1]; // with duplicates
    // Enumerate all 256 index tuples (covers all perms + dup patterns).
    for a in 0..4 {
        for b in 0..4 {
            for c in 0..4 {
                for d in 0..4 {
                    let idx = [a, b, c, d];
                    let input = V128([vals[idx[0]], vals[idx[1]], vals[idx[2]], vals[idx[3]]]);
                    let mut expect = input.to_array();
                    expect.sort_unstable();
                    assert_eq!(sort4_in_reg(input).to_array(), expect);
                }
            }
        }
    }
}

#[test]
fn merge4_in_reg_sorts_bitonic() {
    // All 0/1 bitonic patterns of the asc⌢desc form.
    for ones_start in 0..=4usize {
        for ones_end in ones_start..=4 {
            let mut arr = [0i32; 4];
            for v in arr.iter_mut().take(ones_end).skip(ones_start) {
                *v = 1;
            }
            let mut expect = arr;
            expect.sort_unstable();
            assert_eq!(merge4_in_reg(V128(arr)).to_array(), expect);
        }
    }
}

#[test]
fn merge_2x4_merges() {
    forall(200, |rng| {
        let (a, b) = sorted_pair(rng, 4, 50);
        let (lo, hi) = bitonic::merge_2x4(V128::load(&a), V128::load(&b));
        let got: Vec<u32> = lo.to_array().iter().chain(hi.to_array().iter()).copied().collect();
        let mut expect = [a, b].concat();
        expect.sort_unstable();
        assert_eq!(got, expect);
    });
}

#[test]
fn vectorized_merge_slices_all_widths() {
    forall(300, |rng| {
        for k in [4usize, 8, 16, 32, 64] {
            let (a, b) = sorted_pair(rng, k, 1000);
            let mut out = vec![0u32; 2 * k];
            bitonic::merge_slices(&a, &b, &mut out);
            let mut expect = [a, b].concat();
            expect.sort_unstable();
            assert_eq!(out, expect, "vectorized 2x{k}");
        }
    });
}

#[test]
fn hybrid_merge_slices_all_widths() {
    forall(300, |rng| {
        for k in [4usize, 8, 16, 32, 64] {
            let (a, b) = sorted_pair(rng, k, 1000);
            let mut out = vec![0u32; 2 * k];
            hybrid::merge_slices(&a, &b, &mut out);
            let mut expect = [a, b].concat();
            expect.sort_unstable();
            assert_eq!(out, expect, "hybrid 2x{k}");
        }
    });
}

#[test]
fn hybrid_equals_vectorized_equals_scalar() {
    // The paper's three merger implementations are interchangeable —
    // same output for the same input (DESIGN.md invariant 3).
    forall(200, |rng| {
        let k = [4usize, 8, 16, 32, 64][rng.below(5)];
        let (a, b) = sorted_pair(rng, k, 200);
        let mut o1 = vec![0u32; 2 * k];
        let mut o2 = vec![0u32; 2 * k];
        let mut o3 = vec![0u32; 2 * k];
        bitonic::merge_slices(&a, &b, &mut o1);
        hybrid::merge_slices(&a, &b, &mut o2);
        serial::merge_scalar(&a, &b, &mut o3);
        assert_eq!(o1, o2);
        assert_eq!(o2, o3);
    });
}

#[test]
fn bitonic_sort_regs_sorts_anything() {
    forall(200, |rng| {
        let r = [1usize, 2, 4, 8, 16][rng.below(5)];
        let mut regs: Vec<V128<u32>> = (0..r)
            .map(|_| V128([rng.next_u32(), rng.next_u32(), rng.next_u32(), rng.next_u32()]))
            .collect();
        let mut expect: Vec<u32> = regs.iter().flat_map(|v| v.to_array()).collect();
        expect.sort_unstable();
        bitonic::bitonic_sort_regs(&mut regs);
        let got: Vec<u32> = regs.iter().flat_map(|v| v.to_array()).collect();
        assert_eq!(got, expect);
    });
}

fn v256_from(rng: &mut Rng, modv: u32) -> V256<u32> {
    let mut vals = [0u32; 8];
    for v in vals.iter_mut() {
        *v = rng.next_u32() % modv;
    }
    V256::load(&vals)
}

#[test]
fn bitonic_sort_regs_sorts_v256() {
    // The width-generic register sorter at 8 lanes, incl. dup-heavy.
    forall(200, |rng| {
        let r = [1usize, 2, 4, 8, 16][rng.below(5)];
        let modv = if rng.below(2) == 0 { 5 } else { 100_000 };
        let mut regs: Vec<V256<u32>> = (0..r).map(|_| v256_from(rng, modv)).collect();
        let mut expect: Vec<u32> = regs.iter().flat_map(|v| v.to_array()).collect();
        expect.sort_unstable();
        bitonic::bitonic_sort_regs(&mut regs);
        let got: Vec<u32> = regs.iter().flat_map(|v| v.to_array()).collect();
        assert_eq!(got, expect, "V256 R={r} mod={modv}");
    });
}

#[test]
fn merge_sorted_regs_v256_vectorized_and_hybrid() {
    // Both register mergers at W=8, every register count up to the
    // MAX_K=64 budget (16 V256 regs = 2×64), vs the sorted oracle.
    forall(150, |rng| {
        for r in [2usize, 4, 8, 16] {
            let k = r * 8 / 2;
            let (a, b) = sorted_pair(rng, k, 500);
            let load = |x: &[u32], y: &[u32]| -> Vec<V256<u32>> {
                x.chunks_exact(8).chain(y.chunks_exact(8)).map(V256::load).collect()
            };
            let mut expect = [a.clone(), b.clone()].concat();
            expect.sort_unstable();
            let mut regs = load(&a, &b);
            bitonic::merge_sorted_regs(&mut regs[..]);
            let got: Vec<u32> = regs.iter().flat_map(|v| v.to_array()).collect();
            assert_eq!(got, expect, "vectorized V256 2x{k}");
            let mut regs = load(&a, &b);
            hybrid::hybrid_merge_sorted_regs(&mut regs[..]);
            let got: Vec<u32> = regs.iter().flat_map(|v| v.to_array()).collect();
            assert_eq!(got, expect, "hybrid V256 2x{k}");
        }
    });
}

#[test]
fn hybrid_merge_sorted_regs_v128_full_budget() {
    // The raised MAX_K=64 budget end-to-end at W=4: 32 V128 registers.
    forall(150, |rng| {
        let (a, b) = sorted_pair(rng, 64, 1000);
        let mut regs: Vec<V128<u32>> =
            a.chunks_exact(4).chain(b.chunks_exact(4)).map(V128::load).collect();
        assert_eq!(regs.len(), 32);
        hybrid::hybrid_merge_sorted_regs(&mut regs[..]);
        let got: Vec<u32> = regs.iter().flat_map(|v| v.to_array()).collect();
        let mut expect = [a, b].concat();
        expect.sort_unstable();
        assert_eq!(got, expect);
    });
}

#[test]
fn serial_merge_arbitrary_lengths() {
    forall_indexed(300, |case, rng| {
        let la = case % 17;
        let lb = rng.below(23);
        let mut a = rng.vec_u32(la);
        let mut b = rng.vec_u32(lb);
        a.sort_unstable();
        b.sort_unstable();
        let mut out = vec![0u32; la + lb];
        serial::merge_scalar(&a, &b, &mut out);
        let mut expect = [a, b].concat();
        expect.sort_unstable();
        assert_eq!(out, expect);
    });
}

#[test]
fn merge3_scalar_correct() {
    forall(100, |rng| {
        let (la, lb, lc) = (rng.below(10) + 1, rng.below(10), rng.below(10) + 3);
        let mut a = rng.vec_u32(la);
        let mut b = rng.vec_u32(lb);
        let mut c = rng.vec_u32(lc);
        a.sort_unstable();
        b.sort_unstable();
        c.sort_unstable();
        let mut out = vec![0u32; a.len() + b.len() + c.len()];
        serial::merge3_scalar(&a, &b, &c, &mut out);
        let mut expect = [a, b, c].concat();
        expect.sort_unstable();
        assert_eq!(out, expect);
    });
}

#[test]
fn insertion_sort_small() {
    forall(200, |rng| {
        let len = rng.below(64);
        let mut v = rng.vec_i32(len);
        let mut expect = v.clone();
        expect.sort_unstable();
        serial::insertion_sort(&mut v);
        assert_eq!(v, expect);
    });
}

#[test]
fn inregister_sort_block_full_all_configs() {
    for (label, sorter) in table2_configs() {
        forall(50, |rng| {
            let mut block = rng.vec_u32(sorter.block_len());
            let orig = block.clone();
            sorter.sort_block(&mut block);
            assert_sorted(&block, &label);
            assert_permutation(&block, &orig, &label);
        });
    }
}

#[test]
fn inregister_sort_to_runs_x_sweep() {
    // Table 2 semantics: X ∈ {R, 2R, 4R} produces sorted runs of X.
    for (label, sorter) in table2_configs() {
        let r = sorter.r();
        for x in [r, 2 * r, 4 * r] {
            forall(30, |rng| {
                let mut block = rng.vec_u32(sorter.block_len());
                let orig = block.clone();
                sorter.sort_block_to_runs(&mut block, x);
                assert_permutation(&block, &orig, &label);
                for (ri, run) in block.chunks(x).enumerate() {
                    assert_sorted(run, &format!("{label} X={x} run {ri}"));
                }
            });
        }
    }
}

#[test]
fn inregister_vectorized_vs_hybrid_same_result() {
    forall(50, |rng| {
        let block = rng.vec_u32(64);
        let mut b1 = block.clone();
        let mut b2 = block;
        InRegisterSorter::new(16, ColumnNetwork::Best)
            .with_merge_impl(MergeImpl::Vectorized)
            .sort_block(&mut b1);
        InRegisterSorter::new(16, ColumnNetwork::Best)
            .with_merge_impl(MergeImpl::Hybrid)
            .sort_block(&mut b2);
        assert_eq!(b1, b2);
    });
}

#[test]
fn inregister_sort_runs_with_tail() {
    let sorter = InRegisterSorter::paper_default();
    forall_indexed(100, |case, rng| {
        let len = case * 3 + rng.below(7); // exercises 0..306 incl. tails
        let mut data = rng.vec_u32(len);
        let orig = data.clone();
        let run = sorter.sort_runs(&mut data);
        assert_eq!(run, 64);
        assert_permutation(&data, &orig, "sort_runs");
        for (ri, chunk) in data.chunks(run).enumerate() {
            assert_sorted(chunk, &format!("run {ri} len {len}"));
        }
    });
}

#[test]
fn inregister_v256_block_and_x_sweep() {
    // The width-generic in-register sort at 8 lanes: every supported
    // R × network family, every run-length target X = R·2^j up to 8R.
    for r in [8usize, 16, 32] {
        for fam in [ColumnNetwork::Bitonic, ColumnNetwork::OddEven, ColumnNetwork::Best] {
            let sorter = InRegisterSorter::new(r, fam).with_vector(VectorWidth::V256);
            assert_eq!(sorter.block_len(), 8 * r);
            for x in [r, 2 * r, 4 * r, 8 * r] {
                forall(20, |rng| {
                    let mut block = rng.vec_u32(sorter.block_len());
                    let orig = block.clone();
                    sorter.sort_block_to_runs(&mut block, x);
                    assert_permutation(&block, &orig, &format!("V256 R={r} {fam:?} X={x}"));
                    for (ri, run) in block.chunks(x).enumerate() {
                        assert_sorted(run, &format!("V256 R={r} {fam:?} X={x} run {ri}"));
                    }
                });
            }
        }
    }
}

#[test]
fn inregister_v256_merge_impls_agree() {
    forall(50, |rng| {
        let block = rng.vec_u32(128);
        let mut b1 = block.clone();
        let mut b2 = block;
        InRegisterSorter::new(16, ColumnNetwork::Best)
            .with_vector(VectorWidth::V256)
            .with_merge_impl(MergeImpl::Vectorized)
            .sort_block(&mut b1);
        InRegisterSorter::new(16, ColumnNetwork::Best)
            .with_vector(VectorWidth::V256)
            .with_merge_impl(MergeImpl::Hybrid)
            .sort_block(&mut b2);
        assert_eq!(b1, b2);
    });
}

#[test]
fn inregister_v256_sort_runs_with_tail() {
    let sorter = InRegisterSorter::paper_default().with_vector(VectorWidth::V256);
    forall_indexed(60, |case, rng| {
        let len = case * 7 + rng.below(11); // 0..430 incl. sub-vector tails
        let mut data = rng.vec_u32(len);
        let orig = data.clone();
        let run = sorter.sort_runs(&mut data);
        assert_eq!(run, 128);
        assert_permutation(&data, &orig, "V256 sort_runs");
        for (ri, chunk) in data.chunks(run).enumerate() {
            assert_sorted(chunk, &format!("V256 run {ri} len {len}"));
        }
    });
}

#[test]
#[should_panic(expected = "multiple of the 8-lane width")]
fn inregister_v256_rejects_r4() {
    let _ = InRegisterSorter::new(4, ColumnNetwork::OddEven).with_vector(VectorWidth::V256);
}

#[test]
fn inregister_f32_and_i32() {
    let sorter = InRegisterSorter::paper_default();
    let mut rng = Rng::new(99);
    let mut fblock: Vec<f32> = (0..64).map(|_| rng.next_f32() * 100.0 - 50.0).collect();
    sorter.sort_block(&mut fblock);
    assert_sorted(&fblock, "f32 block");
    let mut iblock: Vec<i32> = (0..64).map(|_| rng.next_i32()).collect();
    sorter.sort_block(&mut iblock);
    assert_sorted(&iblock, "i32 block");
}

#[test]
fn runmerge_all_kernels_and_widths() {
    for vector in VectorWidth::all() {
        for (_, imp) in super::runmerge::table3_impls() {
            for width in MergeWidth::all() {
                let m = RunMerger { width, imp, vector };
                forall(60, |rng| {
                    let la = rng.below(300) + 1;
                    let lb = rng.below(300) + 1;
                    let mut a = rng.vec_u32(la);
                    let mut b = rng.vec_u32(lb);
                    a.sort_unstable();
                    b.sort_unstable();
                    let mut out = vec![0u32; la + lb];
                    m.merge(&a, &b, &mut out);
                    let mut expect = [a, b].concat();
                    expect.sort_unstable();
                    assert_eq!(out, expect, "{} {imp:?} 2x{}", vector.name(), width.k());
                });
            }
        }
    }
}

#[test]
fn runmerge_k4_v256_folds_to_v128() {
    let m = RunMerger { width: MergeWidth::K4, imp: MergeImpl::Hybrid, vector: VectorWidth::V256 };
    assert_eq!(m.effective_vector(), VectorWidth::V128);
    let a: Vec<u32> = (0..32).collect();
    let b: Vec<u32> = (16..48).collect();
    let mut out = vec![0u32; 64];
    m.merge(&a, &b, &mut out);
    let mut expect = [a, b].concat();
    expect.sort_unstable();
    assert_eq!(out, expect);
}

#[test]
fn runmerge_adversarial_interleavings() {
    // One run entirely below the other, strict interleave, heavy dups.
    let m = RunMerger::paper_default();
    let k = 16;
    assert_eq!(m.effective_vector(), VectorWidth::V128);
    let cases: Vec<(Vec<u32>, Vec<u32>)> = vec![
        ((0..64).collect(), (64..128).collect()),
        ((64..128).collect(), (0..64).collect()),
        ((0..64).map(|x| x * 2).collect(), (0..64).map(|x| x * 2 + 1).collect()),
        (vec![5; 64], vec![5; 64]),
        (vec![0; 64], (0..64).collect()),
        ((0..k as u32).collect(), (0..200).collect()),
    ];
    for (a, b) in cases {
        let mut out = vec![0u32; a.len() + b.len()];
        m.merge(&a, &b, &mut out);
        let mut expect = [a.clone(), b.clone()].concat();
        expect.sort_unstable();
        assert_eq!(out, expect, "a={a:?} b={b:?}");
    }
}

#[test]
fn runmerge_property_all_combos_match_scalar_oracle() {
    // Edge-shape property sweep over every MergeWidth × MergeImpl ×
    // VectorWidth, each case checked against merge_scalar: lengths
    // that are not a multiple of W, one run shorter than K (serial
    // dispatch), exact-K runs, and dup-heavy alphabets driving the
    // drain3 tie-breaks.
    for vector in VectorWidth::all() {
        let w = vector.lanes();
        for imp in [MergeImpl::Vectorized, MergeImpl::Hybrid, MergeImpl::Serial] {
            for width in MergeWidth::all() {
                let m = RunMerger { width, imp, vector };
                let k = width.k();
                forall_indexed(150, |case, rng| {
                    let (la, lb) = match case % 6 {
                        // One run shorter than K → serial fallback path.
                        0 => (rng.below(k), k + rng.below(3 * k)),
                        1 => (k + rng.below(3 * k), rng.below(k)),
                        // Lengths deliberately not a multiple of W.
                        2 => (
                            k * (1 + rng.below(4)) + 1 + rng.below(w - 1),
                            k * (1 + rng.below(4)) + 1 + rng.below(w - 1),
                        ),
                        // Exactly one kernel block each (flight drains
                        // everything after a single round).
                        3 => (k, k),
                        // Tails shorter than one block on both sides.
                        4 => (k + rng.below(w), k + rng.below(w)),
                        // Long runs, vector fast loop dominant.
                        _ => (4 * k + rng.below(k), 4 * k + rng.below(k)),
                    };
                    // Dup-heavy alphabet half the time to force ties.
                    let modv = if case % 2 == 0 { 4 } else { 100_000 };
                    let mut a: Vec<u32> = (0..la).map(|_| rng.next_u32() % modv).collect();
                    let mut b: Vec<u32> = (0..lb).map(|_| rng.next_u32() % modv).collect();
                    a.sort_unstable();
                    b.sort_unstable();
                    let mut got = vec![0u32; la + lb];
                    m.merge(&a, &b, &mut got);
                    let mut expect = vec![0u32; la + lb];
                    serial::merge_scalar(&a, &b, &mut expect);
                    assert_eq!(
                        got,
                        expect,
                        "{} {imp:?} 2x{k} la={la} lb={lb} mod={modv}",
                        vector.name()
                    );
                });
                // All-duplicates, asymmetric lengths.
                let a = vec![7u32; 2 * k + 3];
                let b = vec![7u32; 5 * k + 1];
                let mut got = vec![0u32; a.len() + b.len()];
                m.merge(&a, &b, &mut got);
                assert_eq!(
                    got,
                    vec![7u32; a.len() + b.len()],
                    "{} {imp:?} 2x{k} all-dups",
                    vector.name()
                );
            }
        }
    }
}

#[test]
fn runmerge_zero_one_principle_all_combos() {
    // Zero-one principle for merging: a merge network is correct iff
    // it merges every pair of sorted 0/1 runs. Exhaustive over the
    // (ones_a, ones_b) grid for two 2K-length runs (two full kernel
    // blocks per side — flight refills from both runs), for every
    // vector × width × impl combination.
    for vector in VectorWidth::all() {
        for (_, imp) in super::runmerge::table3_impls() {
            for width in MergeWidth::all() {
                let m = RunMerger { width, imp, vector };
                let n = 2 * width.k();
                // Full grid at small K; strided (boundaries kept) at
                // large K so debug-mode test time stays bounded.
                let stride = if n > 32 { 5 } else { 1 };
                let mut marks: Vec<usize> = (0..=n).step_by(stride).collect();
                if *marks.last().unwrap() != n {
                    marks.push(n);
                }
                for &ones_a in &marks {
                    for &ones_b in &marks {
                        let a: Vec<u32> = (0..n).map(|i| u32::from(i >= n - ones_a)).collect();
                        let b: Vec<u32> = (0..n).map(|i| u32::from(i >= n - ones_b)).collect();
                        let mut got = vec![9u32; 2 * n];
                        m.merge(&a, &b, &mut got);
                        let mut expect = [a, b].concat();
                        expect.sort_unstable();
                        assert_eq!(
                            got,
                            expect,
                            "{} {imp:?} 2x{} ones=({ones_a},{ones_b})",
                            vector.name(),
                            width.k()
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn runmerge_short_runs_fall_back_to_serial() {
    let m =
        RunMerger { width: MergeWidth::K32, imp: MergeImpl::Hybrid, vector: VectorWidth::V128 };
    let a = vec![3u32, 9];
    let b = vec![1u32, 2, 4];
    let mut out = vec![0u32; 5];
    m.merge(&a, &b, &mut out);
    assert_eq!(out, vec![1, 2, 3, 4, 9]);
}

// ---- Element-generic kernels: u64 and KeyValue on the 64-bit regs ----

use crate::simd::{KeyValue, Lane};

fn sorted_pair_u64(rng: &mut Rng, k: usize, modv: u64) -> (Vec<u64>, Vec<u64>) {
    let mut a: Vec<u64> = (0..k).map(|_| rng.next_u64() % modv).collect();
    let mut b: Vec<u64> = (0..k).map(|_| rng.next_u64() % modv).collect();
    a.sort_unstable();
    b.sort_unstable();
    (a, b)
}

/// Key–payload pairs with dup-prone keys and *distinct* payloads, so
/// the payload half of the packed comparison decides ties and any
/// ordering divergence is observable.
fn kv_run(rng: &mut Rng, len: usize, key_mod: u32, tag: u32) -> Vec<KeyValue> {
    let mut v: Vec<KeyValue> =
        (0..len).map(|i| KeyValue::new(rng.next_u32() % key_mod, tag + i as u32)).collect();
    v.sort_unstable();
    v
}

#[test]
fn merge_width_clamps_to_byte_budget() {
    // The byte-denominated budget: 8-byte elements cap at K = 32.
    assert_eq!(MergeWidth::K64.clamp_for_bytes(8), MergeWidth::K32);
    assert_eq!(MergeWidth::K32.clamp_for_bytes(8), MergeWidth::K32);
    assert_eq!(MergeWidth::K64.clamp_for_bytes(4), MergeWidth::K64);
    for w in MergeWidth::all() {
        assert_eq!(w.clamp_for_bytes(4), w, "4-byte lanes never clamp");
    }
}

#[test]
fn effective_vector_is_per_element_width() {
    // K4 folds to V128 for u32 (4 < 8 lanes) but NOT for u64 (a V256D
    // holds exactly one 4-element run per side), and the K64 → K32
    // clamp happens before the fold decision.
    let m = RunMerger { width: MergeWidth::K4, imp: MergeImpl::Hybrid, vector: VectorWidth::V256 };
    assert_eq!(m.effective_vector_for::<u32>(), VectorWidth::V128);
    assert_eq!(m.effective_vector_for::<u64>(), VectorWidth::V256);
    assert_eq!(m.effective_vector_for::<KeyValue>(), VectorWidth::V256);
    let m = RunMerger { width: MergeWidth::K64, imp: MergeImpl::Hybrid, vector: VectorWidth::V256 };
    assert_eq!(m.effective_vector_for::<u64>(), VectorWidth::V256);
}

#[test]
fn merge_slices_u64_all_budgeted_widths() {
    // Both register kernels on V128D, every K inside the 256-byte
    // budget (2 × 32 u64 = the full budget; K=64 would not compile).
    forall(200, |rng| {
        for k in [2usize, 4, 8, 16, 32] {
            let (a, b) = sorted_pair_u64(rng, k, 1 << 40);
            let mut expect = [a.clone(), b.clone()].concat();
            expect.sort_unstable();
            let mut out = vec![0u64; 2 * k];
            bitonic::merge_slices(&a, &b, &mut out);
            assert_eq!(out, expect, "vectorized u64 2x{k}");
            let mut out = vec![0u64; 2 * k];
            hybrid::merge_slices(&a, &b, &mut out);
            assert_eq!(out, expect, "hybrid u64 2x{k}");
        }
    });
}

#[test]
fn merge_slices_zero_one_u64_exhaustive() {
    // Zero-one principle on the 2-lane register kernels: every
    // (ones_a, ones_b) grid point for both impls at every budgeted K.
    for k in [2usize, 4, 8, 16, 32] {
        for ones_a in 0..=k {
            for ones_b in 0..=k {
                let a: Vec<u64> = (0..k).map(|i| u64::from(i >= k - ones_a)).collect();
                let b: Vec<u64> = (0..k).map(|i| u64::from(i >= k - ones_b)).collect();
                let mut expect = [a.clone(), b.clone()].concat();
                expect.sort_unstable();
                let mut out = vec![9u64; 2 * k];
                bitonic::merge_slices(&a, &b, &mut out);
                assert_eq!(out, expect, "vectorized 2x{k} ones=({ones_a},{ones_b})");
                let mut out = vec![9u64; 2 * k];
                hybrid::merge_slices(&a, &b, &mut out);
                assert_eq!(out, expect, "hybrid 2x{k} ones=({ones_a},{ones_b})");
            }
        }
    }
}

#[test]
fn hybrid_equals_vectorized_equals_scalar_u64_and_pairs() {
    forall(200, |rng| {
        let k = [2usize, 4, 8, 16, 32][rng.below(5)];
        let (a, b) = sorted_pair_u64(rng, k, 64); // dup-heavy
        let mut o1 = vec![0u64; 2 * k];
        let mut o2 = vec![0u64; 2 * k];
        let mut o3 = vec![0u64; 2 * k];
        bitonic::merge_slices(&a, &b, &mut o1);
        hybrid::merge_slices(&a, &b, &mut o2);
        serial::merge_scalar(&a, &b, &mut o3);
        assert_eq!(o1, o2);
        assert_eq!(o2, o3);
        let (a, b) = (kv_run(rng, k, 4, 0), kv_run(rng, k, 4, 1000));
        let mut o1 = vec![KeyValue::MIN_VALUE; 2 * k];
        let mut o2 = vec![KeyValue::MIN_VALUE; 2 * k];
        let mut o3 = vec![KeyValue::MIN_VALUE; 2 * k];
        bitonic::merge_slices(&a, &b, &mut o1);
        hybrid::merge_slices(&a, &b, &mut o2);
        serial::merge_scalar(&a, &b, &mut o3);
        assert_eq!(o1, o2);
        assert_eq!(o2, o3);
    });
}

#[test]
fn inregister_block_sort_u64_both_widths() {
    // The generic in-register sort at W=2 (V128D) and W=4 (V256D):
    // every Table 2 config at V128D; R ∈ {8,16,32} at V256D.
    for (label, sorter) in table2_configs() {
        forall(40, |rng| {
            let mut block = rng.vec_u64(sorter.block_len_for::<u64>());
            let mut expect = block.clone();
            expect.sort_unstable();
            sorter.sort_block(&mut block);
            assert_eq!(block, expect, "{label} u64 V128D");
        });
    }
    for r in [8usize, 16, 32] {
        let sorter = InRegisterSorter::new(r, ColumnNetwork::OddEven)
            .with_vector(VectorWidth::V256);
        assert_eq!(sorter.block_len_for::<u64>(), 4 * r);
        forall(40, |rng| {
            let mut block = rng.vec_u64(sorter.block_len_for::<u64>());
            let mut expect = block.clone();
            expect.sort_unstable();
            sorter.sort_block(&mut block);
            assert_eq!(block, expect, "R={r} u64 V256D");
        });
    }
}

#[test]
fn inregister_block_sort_u64_zero_one_sampled() {
    // Zero-one sampling for the full W=2 block pipeline (column sort +
    // transpose2 tiles + row merges): random 0/1 blocks, high volume.
    let sorter = InRegisterSorter::paper_default();
    let bl = sorter.block_len_for::<u64>();
    assert_eq!(bl, 32, "R=16 × 2 lanes");
    forall(500, |rng| {
        let mut block: Vec<u64> = (0..bl).map(|_| rng.next_u64() & 1).collect();
        let ones: usize = block.iter().map(|&b| b as usize).sum();
        sorter.sort_block(&mut block);
        let expect: Vec<u64> = (0..bl).map(|i| u64::from(i >= bl - ones)).collect();
        assert_eq!(block, expect);
    });
}

#[test]
fn inregister_runs_and_tail_u64_pairs() {
    // sort_runs at 8-byte widths: runs are block_len_for::<T> (32 at
    // V128D, 64 at V256D), tails pad with MAX_VALUE and come back.
    for (vector, want_run) in [(VectorWidth::V128, 32usize), (VectorWidth::V256, 64)] {
        let sorter = InRegisterSorter::paper_default().with_vector(vector);
        forall_indexed(60, |case, rng| {
            let len = case * 5 + rng.below(9);
            let mut data: Vec<KeyValue> = (0..len)
                .map(|i| KeyValue::new(rng.next_u32() % 50, i as u32))
                .collect();
            let mut expect = data.clone();
            expect.sort_unstable();
            let run = sorter.sort_runs(&mut data);
            assert_eq!(run, want_run);
            for (ri, chunk) in data.chunks(run).enumerate() {
                assert_sorted(chunk, &format!("{vector:?} pair run {ri} len {len}"));
            }
            data.sort_unstable();
            assert_eq!(data, expect, "{vector:?} len {len}: multiset changed");
        });
    }
}

#[test]
fn inregister_x_sweep_u64_v256d() {
    // Run-length targets at W=4 on 8-byte lanes: X ∈ {R, 2R, 4R}.
    let sorter = InRegisterSorter::new(16, ColumnNetwork::Best).with_vector(VectorWidth::V256);
    for x in [16usize, 32, 64] {
        forall(30, |rng| {
            let mut block = rng.vec_u64(sorter.block_len_for::<u64>());
            let mut expect = block.clone();
            expect.sort_unstable();
            sorter.sort_block_to_runs(&mut block, x);
            for (ri, run) in block.chunks(x).enumerate() {
                assert_sorted(run, &format!("u64 V256D X={x} run {ri}"));
            }
            block.sort_unstable();
            assert_eq!(block, expect, "X={x}: multiset changed");
        });
    }
}

#[test]
fn runmerge_u64_property_all_combos_match_scalar_oracle() {
    // Every MergeWidth × MergeImpl × VectorWidth on u64 runs, same
    // edge shapes as the u32 sweep, vs merge_scalar. K64 exercises the
    // clamp-to-K32 dispatch at both vector widths.
    for vector in VectorWidth::all() {
        let w = vector.lanes_for::<u64>();
        for imp in [MergeImpl::Vectorized, MergeImpl::Hybrid, MergeImpl::Serial] {
            for width in MergeWidth::all() {
                let m = RunMerger { width, imp, vector };
                let k = width.clamp_for_bytes(8).k();
                forall_indexed(80, |case, rng| {
                    let (la, lb) = match case % 5 {
                        0 => (rng.below(k), k + rng.below(3 * k)),
                        1 => (
                            k * (1 + rng.below(4)) + 1 + rng.below(w.max(2) - 1),
                            k * (1 + rng.below(4)) + 1 + rng.below(w.max(2) - 1),
                        ),
                        2 => (k, k),
                        3 => (k + rng.below(w), k + rng.below(w)),
                        _ => (4 * k + rng.below(k), 4 * k + rng.below(k)),
                    };
                    let modv = if case % 2 == 0 { 4 } else { 1 << 45 };
                    let mut a: Vec<u64> = (0..la).map(|_| rng.next_u64() % modv).collect();
                    let mut b: Vec<u64> = (0..lb).map(|_| rng.next_u64() % modv).collect();
                    a.sort_unstable();
                    b.sort_unstable();
                    let mut got = vec![0u64; la + lb];
                    m.merge(&a, &b, &mut got);
                    let mut expect = vec![0u64; la + lb];
                    serial::merge_scalar(&a, &b, &mut expect);
                    assert_eq!(
                        got,
                        expect,
                        "{} {imp:?} 2x{} u64 la={la} lb={lb} mod={modv}",
                        vector.name(),
                        width.k()
                    );
                });
            }
        }
    }
}

#[test]
fn runmerge_zero_one_u64_all_combos() {
    // Zero-one for the streaming merge at 8-byte lanes, every
    // vector × width × impl, two kernel blocks per side.
    for vector in VectorWidth::all() {
        for (_, imp) in super::runmerge::table3_impls() {
            for width in MergeWidth::all() {
                let m = RunMerger { width, imp, vector };
                let n = 2 * width.clamp_for_bytes(8).k();
                let stride = if n > 32 { 5 } else { 1 };
                let mut marks: Vec<usize> = (0..=n).step_by(stride).collect();
                if *marks.last().unwrap() != n {
                    marks.push(n);
                }
                for &ones_a in &marks {
                    for &ones_b in &marks {
                        let a: Vec<u64> = (0..n).map(|i| u64::from(i >= n - ones_a)).collect();
                        let b: Vec<u64> = (0..n).map(|i| u64::from(i >= n - ones_b)).collect();
                        let mut got = vec![9u64; 2 * n];
                        m.merge(&a, &b, &mut got);
                        let mut expect = [a, b].concat();
                        expect.sort_unstable();
                        assert_eq!(
                            got,
                            expect,
                            "{} {imp:?} 2x{} u64 ones=({ones_a},{ones_b})",
                            vector.name(),
                            width.k()
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn runmerge_pairs_tie_break_pinned() {
    // Tie-break determinism, pinned: equal keys throughout, payloads
    // distinct. KeyValue's order is total (key, then payload), so
    // every merger implementation must produce the *identical*
    // payload-ascending sequence within each key group — the property
    // the database index build (rowid order within key) relies on.
    let a: Vec<KeyValue> = (0..32).map(|i| KeyValue::new(i / 8, 2 * i)).collect();
    let b: Vec<KeyValue> = (0..32).map(|i| KeyValue::new(i / 8, 2 * i + 1)).collect();
    let mut expect = [a.clone(), b.clone()].concat();
    expect.sort_unstable();
    // Pin the shape: within each of the 4 key groups, payloads strictly
    // ascend and interleave a (even) with b (odd).
    for group in expect.chunks(16) {
        assert!(group.windows(2).all(|w| w[0].key() == w[1].key()));
        assert!(group.windows(2).all(|w| w[0].payload() < w[1].payload()));
    }
    for vector in VectorWidth::all() {
        for imp in [MergeImpl::Vectorized, MergeImpl::Hybrid, MergeImpl::Serial] {
            for width in MergeWidth::all() {
                let m = RunMerger { width, imp, vector };
                let mut got = vec![KeyValue::MIN_VALUE; 64];
                m.merge(&a, &b, &mut got);
                assert_eq!(
                    got,
                    expect,
                    "{} {imp:?} 2x{}: tie-break order diverged",
                    vector.name(),
                    width.k()
                );
            }
        }
    }
}

#[test]
fn runmerge_pairs_property_vs_scalar() {
    // Random key–payload runs through every combo vs merge_scalar.
    for vector in VectorWidth::all() {
        for (_, imp) in super::runmerge::table3_impls() {
            for width in [MergeWidth::K4, MergeWidth::K16, MergeWidth::K64] {
                let m = RunMerger { width, imp, vector };
                forall(60, |rng| {
                    let la = rng.below(200) + 1;
                    let lb = rng.below(200) + 1;
                    let a = kv_run(rng, la, 8, 0);
                    let b = kv_run(rng, lb, 8, 100_000);
                    let mut got = vec![KeyValue::MIN_VALUE; la + lb];
                    m.merge(&a, &b, &mut got);
                    let mut expect = vec![KeyValue::MIN_VALUE; la + lb];
                    serial::merge_scalar(&a, &b, &mut expect);
                    assert_eq!(got, expect, "{} {imp:?} 2x{}", vector.name(), width.k());
                });
            }
        }
    }
}
