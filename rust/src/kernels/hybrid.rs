//! The **hybrid bitonic merger** (paper §2.4) — the core contribution.
//!
//! A bitonic merging network over 2K elements decomposes, after its
//! first half-cleaner stage, into two *independent* K-element halves
//! (the black and blue rectangles of Fig. 4). The hybrid merger runs
//! the lower half fully vectorized (compare + shuffle, as in
//! [`super::bitonic`]) and the upper half with *serial branchless*
//! comparators (Fig. 3b `csel`/`cmov`), interleaving the two stage
//! streams in source order. Because the halves share no data, the two
//! dependency chains overlap in the out-of-order pipeline: the serial
//! half's `cmov` latency hides under the vector half's shuffle traffic
//! and vice versa, and the upper half needs *no* cross-register
//! shuffles at all.
//!
//! The paper's Table 3 finds this wins at K ∈ {8, 16} and loses at
//! K = 32, where the serial half's 32 temporaries exceed the register
//! file and spill to the stack — we reproduce exactly that mechanism:
//! the scalar buffer below *is* a stack spill once K is large.
//!
//! # Invariants
//!
//! * [`hybrid_merge_sorted_regs`] has the same contract as the
//!   symmetric merger: both register halves sorted ascending on
//!   entry, whole array sorted on exit; `regs.len()` a power of two
//!   in `2..=2·MAX_K/W` for the instantiated width `W`.
//! * After the first half-cleaner the two K-element halves are
//!   **data-independent** — the property the whole kernel rests on:
//!   the serial and vector halves may execute in any interleaving,
//!   and the out-of-order core exploits exactly that.
//! * Every fixed-size scalar/flight buffer in this module and in
//!   [`super::runmerge`] holds at most [`MAX_K`] elements. That bound
//!   is *proved at monomorphization time*: each kernel instantiated
//!   over `N` registers of width `V` evaluates
//!   [`RegsFitMaxK::OK`] (`RegsFitMaxK::<V, N>::OK`), a const
//!   assertion of `N·W/2·lane_bytes ≤ MAX_K_BYTES`. Widening
//!   [`super::MergeWidth`] past the byte budget — at any vector width
//!   or element width — without growing [`MAX_K_BYTES`] therefore
//!   fails to *compile*: the register budget can never silently
//!   become a buffer overflow. Because the budget is in bytes, an
//!   8-byte element (u64, `KeyValue`) gets half the K of a 4-byte
//!   one for the same register count.

use super::bitonic::{bitonic_merge_regs, reverse_regs};
use crate::simd::{Lane, Lanes, Vector};

/// Maximum K (elements per side) the register-merge kernels support
/// for 4-byte lanes: 2×64, i.e. 32 `V128` or 16 `V256` registers in
/// flight. Every fixed-size flight/spill buffer in this module and in
/// [`super::runmerge`] is sized by this constant — 8-byte lanes use
/// at most half of it, since the true budget is [`MAX_K_BYTES`].
///
/// PR 3 raised this from 32 to 64 to open the 2×64 row of the width
/// sweep (see `BENCH_width_sweep.json`); the compile-time
/// [`RegsFitMaxK`] guard is what makes such a raise a conscious,
/// single-point change.
pub const MAX_K: usize = 64;

/// The per-side register-merge budget in **bytes** (`MAX_K` 4-byte
/// lanes). Denominating the budget in bytes is what makes the
/// element-width axis safe: the same 32-register `V128` flight that
/// carries K = 64 `u32` elements carries K = 32 `u64`/`KeyValue`
/// elements, and both sit exactly at this bound.
pub const MAX_K_BYTES: usize = MAX_K * 4;

/// Monomorphization-time guard: referencing [`RegsFitMaxK::OK`] in a
/// kernel monomorphized over `N` registers of vector type `V` proves
/// `N` registers (K = N·W/2 lanes per side, `W = V::LANES`, each lane
/// `V::LANE_BYTES` wide) fit the [`MAX_K_BYTES`] budget — and hence
/// the `MAX_K`-element stack buffers — so a K sweep beyond the budget
/// becomes a compile error rather than a silent buffer overflow.
///
/// A configuration inside the budget compiles and runs. The bound is
/// per *byte*, so the 64-bit register types reach it at half the
/// element count:
///
/// ```
/// use neonms::kernels::hybrid::RegsFitMaxK;
/// use neonms::simd::{V128, V128D, V256, V256D, KeyValue};
///
/// let () = RegsFitMaxK::<V128<u32>, 32>::OK; // K = 64 — at the bound
/// let () = RegsFitMaxK::<V256<u32>, 16>::OK; // K = 64 via 8 lanes
/// let () = RegsFitMaxK::<V128D<u64>, 32>::OK; // K = 32 — same bytes
/// let () = RegsFitMaxK::<V256D<KeyValue>, 16>::OK; // K = 32 via 4 lanes
/// ```
///
/// One register past the budget fails to *compile* (the const
/// assertion fires during monomorphization):
///
/// ```compile_fail
/// use neonms::kernels::hybrid::RegsFitMaxK;
/// use neonms::simd::V128;
///
/// let () = RegsFitMaxK::<V128<u32>, 64>::OK; // K = 128 > 64 u32 budget
/// ```
///
/// ```compile_fail
/// use neonms::kernels::hybrid::RegsFitMaxK;
/// use neonms::simd::V256;
///
/// let () = RegsFitMaxK::<V256<u32>, 32>::OK; // K = 128 > 64 u32 budget
/// ```
///
/// The byte denomination halves the register budget for 8-byte
/// elements: 64 two-lane registers is exactly the 2×64 configuration
/// that *fits* for `u32` (`V128<u32>, 32` above), but must be
/// rejected for `u64`:
///
/// ```compile_fail
/// use neonms::kernels::hybrid::RegsFitMaxK;
/// use neonms::simd::V128D;
///
/// let () = RegsFitMaxK::<V128D<u64>, 64>::OK; // K = 64 × 8 B > MAX_K_BYTES
/// ```
pub struct RegsFitMaxK<V, const N: usize>(core::marker::PhantomData<V>);

impl<V: Lanes, const N: usize> RegsFitMaxK<V, N> {
    /// Evaluates (at compile time) the `N·W/2·lane_bytes ≤
    /// MAX_K_BYTES` bound.
    pub const OK: () = assert!(
        N * V::LANES / 2 * V::LANE_BYTES <= MAX_K_BYTES,
        "register count implies K over the MAX_K_BYTES budget: widen it before sweeping wider kernels"
    );
}

/// Hybrid-merge two sorted runs held in `regs` in place: on entry
/// `regs[..h]` and `regs[h..]` (`h = regs.len()/2`) are each sorted
/// ascending; on exit all of `regs` is sorted. `regs.len()` must be a
/// power of two ≥ 2 with at most `MAX_K` elements per side.
#[inline(always)]
pub fn hybrid_merge_sorted_regs<T: Lane, V: Vector<T>>(regs: &mut [V]) {
    let w = V::LANES;
    let r = regs.len();
    debug_assert!(r.is_power_of_two() && (2..=2 * MAX_K / w).contains(&r));
    let h = r / 2;
    let k = h * w; // elements per half after the first stage

    // Form the bitonic sequence and run the first half-cleaner
    // (element distance K): one register-level cmpswap per pair.
    reverse_regs(&mut regs[h..]);
    for i in 0..h {
        let (lo, hi) = regs[i].cmpswap(regs[i + h]);
        regs[i] = lo;
        regs[i + h] = hi;
    }

    debug_assert!(k <= MAX_K, "K={k} exceeds the MAX_K={MAX_K} spill buffer");
    // The two halves are now independent K-element bitonic merges.
    // LOWER half → scalar stack buffer (the serial side). Choosing
    // the *lower* half for the serial implementation keeps the serial
    // store/reload latency off the streaming merge's critical path:
    // the lower K is emitted to memory immediately, while the upper K
    // — which the next kernel invocation depends on — stays in the
    // vector pipeline (§Perf iteration 7).
    let mut buf = [T::MIN_VALUE; MAX_K];
    for (i, v) in regs[..h].iter().enumerate() {
        v.store(&mut buf[i * w..]);
    }

    // Both halves inline to straight-line code with *no data
    // dependence* between them, so the out-of-order scheduler
    // interleaves the vector half's shuffle/min/max stream with the
    // serial half's cmp/cmov stream — the paper expressed the same
    // interleaving at the source level for GCC's in-order-friendly
    // scheduling; on an OoO x86 core the hardware does it (§Perf
    // iteration 3: the source-level stage state machine blocked loop
    // unrolling and cost ~2×).
    serial_bitonic_merge(&mut buf[..k]); // serial half (lower K)
    bitonic_merge_regs(&mut regs[h..]); // vector half (upper K)

    // Reload the serial half into registers.
    for (i, v) in regs[..h].iter_mut().enumerate() {
        *v = V::load(&buf[i * w..i * w + w]);
    }
}

/// Branchless scalar bitonic merge (Fig. 3b comparators): sorts a
/// bitonic buffer with `cmp`+`cmov` pairs, no shuffles, no branches.
/// Fully unrolls when the caller's length is a compile-time constant.
#[inline(always)]
fn serial_bitonic_merge<T: Lane>(buf: &mut [T]) {
    let k = buf.len();
    let mut ds = k / 2;
    while ds >= 1 {
        let mut base = 0;
        while base < k {
            for i in base..base + ds {
                let (a, b) = (buf[i], buf[i + ds]);
                buf[i] = a.lane_min(b);
                buf[i + ds] = a.lane_max(b);
            }
            base += 2 * ds;
        }
        ds /= 2;
    }
}

/// Convenience: hybrid merge of two equal-length sorted slices into
/// `out` through the element's 128-bit register kernel
/// ([`Lane::Reg128`] — `V128` for 4-byte lanes, `V128D` for 8-byte).
/// Same contract as [`super::bitonic::merge_slices`].
pub fn merge_slices<T: Lane>(a: &[T], b: &[T], out: &mut [T]) {
    let w = <T::Reg128 as Lanes>::LANES;
    assert_eq!(a.len(), b.len());
    assert!((2 * a.len()).is_power_of_two() && a.len() % w == 0);
    assert!(
        a.len() * T::BYTES <= MAX_K_BYTES,
        "hybrid kernel supports up to 2x{} bytes per side",
        MAX_K_BYTES
    );
    assert_eq!(out.len(), a.len() * 2);
    // Monomorphize on the register count so both the vector stages and
    // the serial half's comparator loops unroll to straight-line code.
    match 2 * a.len() / w {
        2 => merge_slices_impl::<T, 2>(a, b, out),
        4 => merge_slices_impl::<T, 4>(a, b, out),
        8 => merge_slices_impl::<T, 8>(a, b, out),
        16 => merge_slices_impl::<T, 16>(a, b, out),
        32 => merge_slices_impl::<T, 32>(a, b, out),
        _ => unreachable!(),
    }
}

#[inline(always)]
fn merge_slices_impl<T: Lane, const N: usize>(a: &[T], b: &[T], out: &mut [T]) {
    let () = RegsFitMaxK::<T::Reg128, N>::OK;
    let w = <T::Reg128 as Lanes>::LANES;
    let mut regs = [T::Reg128::splat(T::MIN_VALUE); N];
    for (v, c) in regs.iter_mut().zip(a.chunks_exact(w).chain(b.chunks_exact(w))) {
        *v = T::Reg128::load(c);
    }
    hybrid_merge_sorted_regs(&mut regs[..]);
    for (c, v) in out.chunks_exact_mut(w).zip(&regs) {
        v.store(c);
    }
}
