//! The in-register sort (paper §2.1–2.3, Fig. 2, Table 2).
//!
//! Four steps over a block of `R·W` contiguous elements:
//!
//! 1. **load** — `R` vector registers, register `i` ← elements
//!    `[4i, 4i+4)`;
//! 2. **column sort** — a sorting network over the `R` registers,
//!    executed lane-wise: each comparator is one `vmin`+`vmax`, so all
//!    `W = 4` columns sort simultaneously. The network choice is the
//!    Table 2 axis: bitonic / odd-even / *best* (asymmetric, `16*`);
//! 3. **transpose** — `R×4 → 4×R` via `R/4` base 4×4 transposes
//!    (§2.3), leaving 4 sorted runs of length `R`, each contiguous in
//!    `R/4` registers;
//! 4. **row merge** — 0, 1, or 2 rounds of in-register bitonic merges
//!    growing runs `R → 2R → 4R`; the produced run length is the
//!    paper's `X`.

use super::bitonic::merge_sorted_regs;
use super::hybrid::hybrid_merge_sorted_regs;
use super::serial::insertion_sort;
use super::MergeImpl;
use crate::simd::{Lane, V128, W};
use crate::sortnet::{gen, Network};

/// Which column-sort network an [`InRegisterSorter`] uses — Table 2's
/// register-count rows, including the starred `16*` best network.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ColumnNetwork {
    /// Symmetric bitonic sorter.
    Bitonic,
    /// Batcher odd-even sorter (the unstarred Table 2 rows).
    OddEven,
    /// Best-known asymmetric network (`16*` — Green's 60-comparator
    /// network at R = 16).
    Best,
}

/// Configuration + precomputed network for the in-register sort.
#[derive(Clone, Debug)]
pub struct InRegisterSorter {
    r: usize,
    net: Network,
    family: ColumnNetwork,
    merge_impl: MergeImpl,
}

impl InRegisterSorter {
    /// Build a sorter using `r` vector registers (power of two, 4–32)
    /// and the given column-network family.
    pub fn new(r: usize, family: ColumnNetwork) -> Self {
        assert!(r.is_power_of_two() && (4..=32).contains(&r), "R must be 4|8|16|32");
        let net = match family {
            ColumnNetwork::Bitonic => gen::bitonic_sort(r),
            ColumnNetwork::OddEven => gen::odd_even_sort(r),
            ColumnNetwork::Best => gen::best(r),
        };
        InRegisterSorter { r, net, family, merge_impl: MergeImpl::Hybrid }
    }

    /// The paper's configuration: `R = 16` with the best (`16*`)
    /// column network and hybrid row merges.
    pub fn paper_default() -> Self {
        InRegisterSorter::new(16, ColumnNetwork::Best)
    }

    /// Select the row-merge implementation (vectorized / hybrid).
    pub fn with_merge_impl(mut self, mi: MergeImpl) -> Self {
        assert_ne!(mi, MergeImpl::Serial, "row merge is an in-register kernel");
        self.merge_impl = mi;
        self
    }

    /// Registers used (paper's `R`).
    pub fn r(&self) -> usize {
        self.r
    }

    /// Elements per block: `R · W`.
    pub fn block_len(&self) -> usize {
        self.r * W
    }

    /// The column-sort network in use.
    pub fn network(&self) -> &Network {
        &self.net
    }

    /// Sort one `R·W`-element block to sorted runs of length `x`,
    /// where `x ∈ {R, 2R, 4R}` (Table 2's `X`). `x = 4R` fully sorts
    /// the block.
    pub fn sort_block_to_runs<T: Lane>(&self, block: &mut [T], x: usize) {
        assert_eq!(block.len(), self.block_len());
        assert!(
            x == self.r || x == 2 * self.r || x == 4 * self.r,
            "X must be R, 2R or 4R (got {x} for R={})",
            self.r
        );
        // Monomorphized stack-register paths per R (§Perf iteration 1:
        // the former Vec-based path allocated twice per 64-element
        // block and dominated the profile).
        match self.r {
            4 => self.sort_block_impl::<T, 4>(block, x),
            8 => self.sort_block_impl::<T, 8>(block, x),
            16 => self.sort_block_impl::<T, 16>(block, x),
            32 => self.sort_block_impl::<T, 32>(block, x),
            _ => unreachable!("constructor enforces R ∈ {{4,8,16,32}}"),
        }
    }

    fn sort_block_impl<T: Lane, const R: usize>(&self, block: &mut [T], x: usize) {
        // 1. load: R stack registers.
        let mut regs = [V128::splat(T::MIN_VALUE); R];
        for (v, c) in regs.iter_mut().zip(block.chunks_exact(W)) {
            *v = V128::load(c);
        }
        // 2. column sort (lane-wise network application). The paper
        //    configuration (R=16, best network) takes a straight-line
        //    compiled path: 60 comparators on 16 named locals the
        //    compiler keeps in architectural registers (§Perf
        //    iteration 8 — the table-driven loop round-tripped every
        //    comparator through the stack, ~4 cyc/elem extra).
        if R == 16 && self.family == ColumnNetwork::Best {
            column_sort_best16(&mut regs);
        } else {
            for c in self.net.comparators() {
                let (i, j) = (c.i as usize, c.j as usize);
                let (lo, hi) = regs[i].cmpswap(regs[j]);
                regs[i] = lo;
                regs[j] = hi;
            }
        }
        // 3. transpose to 4 contiguous sorted runs of length R
        //    (R/4 base 4×4 transposes, stack scratch).
        let mut out = [V128::splat(T::MIN_VALUE); R];
        let tiles = R / W;
        for t in 0..tiles {
            let tile = crate::simd::transpose4([
                regs[4 * t],
                regs[4 * t + 1],
                regs[4 * t + 2],
                regs[4 * t + 3],
            ]);
            for (j, row) in tile.into_iter().enumerate() {
                out[j * tiles + t] = row;
            }
        }
        let mut regs = out;
        // 4. row merge rounds: R -> 2R -> 4R.
        if x >= 2 * self.r {
            for half in regs.chunks_exact_mut(2 * tiles) {
                self.reg_merge(half);
            }
        }
        if x == 4 * self.r {
            self.reg_merge(&mut regs);
        }
        // store
        for (c, v) in block.chunks_exact_mut(W).zip(&regs) {
            v.store(c);
        }
    }

    #[inline(always)]
    fn reg_merge<T: Lane>(&self, regs: &mut [V128<T>]) {
        let hybrid_max_regs = 2 * super::hybrid::MAX_K / W;
        match self.merge_impl {
            MergeImpl::Vectorized => merge_sorted_regs(regs),
            // Beyond 2×32 the hybrid kernel's serial half would spill
            // (the paper's own Table 3 finding) — use the vector path.
            MergeImpl::Hybrid if regs.len() <= hybrid_max_regs => {
                hybrid_merge_sorted_regs(regs)
            }
            MergeImpl::Hybrid => merge_sorted_regs(regs),
            MergeImpl::Serial => unreachable!(),
        }
    }

    /// Fully sort one block (`x = 4R`).
    pub fn sort_block<T: Lane>(&self, block: &mut [T]) {
        self.sort_block_to_runs(block, 4 * self.r);
    }

    /// First pass of the full sort: partition `data` into blocks and
    /// sort each one; the tail (< one block) is padded into a stack
    /// buffer and sorted with the same kernel (falling back to
    /// insertion sort below one vector). Returns the run length
    /// (`block_len`) for the merge passes.
    pub fn sort_runs<T: Lane>(&self, data: &mut [T]) -> usize {
        let bl = self.block_len();
        let whole = data.len() / bl * bl;
        let mut iter = data[..whole].chunks_exact_mut(bl);
        for block in &mut iter {
            self.sort_block(block);
        }
        let tail = &mut data[whole..];
        if !tail.is_empty() {
            if tail.len() >= W {
                // Pad to a full block with MAX so the padded suffix
                // stays at the top and is discarded on copy-back.
                let mut buf = vec![T::MAX_VALUE; bl];
                buf[..tail.len()].copy_from_slice(tail);
                self.sort_block(&mut buf);
                tail.copy_from_slice(&buf[..tail.len()]);
            } else {
                insertion_sort(tail);
            }
        }
        bl
    }
}

/// Table 2 row labels: the five configurations the paper sweeps.
pub fn table2_configs() -> Vec<(String, InRegisterSorter)> {
    vec![
        ("R=4".into(), InRegisterSorter::new(4, ColumnNetwork::OddEven)),
        ("R=8".into(), InRegisterSorter::new(8, ColumnNetwork::OddEven)),
        ("R=16".into(), InRegisterSorter::new(16, ColumnNetwork::OddEven)),
        ("R=16*".into(), InRegisterSorter::new(16, ColumnNetwork::Best)),
        ("R=32".into(), InRegisterSorter::new(32, ColumnNetwork::OddEven)),
    ]
}

/// Green's best-16 network compiled to straight-line code over 16
/// named locals — the compiler allocates them to architectural
/// vector registers, exactly like the paper's hand-scheduled NEON
/// kernel. Generated from [`crate::sortnet::gen::best`]\(16\)'s table
/// and cross-checked against it in this module's tests.
#[inline(always)]
fn column_sort_best16<T: Lane>(regs: &mut [V128<T>]) {
    debug_assert_eq!(regs.len(), 16);
    let [mut v0, mut v1, mut v2, mut v3, mut v4, mut v5, mut v6, mut v7, mut v8, mut v9, mut v10, mut v11, mut v12, mut v13, mut v14, mut v15] =
        [regs[0], regs[1], regs[2], regs[3], regs[4], regs[5], regs[6], regs[7], regs[8], regs[9], regs[10], regs[11], regs[12], regs[13], regs[14], regs[15]];
    macro_rules! cs {
        ($a:ident, $b:ident) => {{
            let (lo, hi) = $a.cmpswap($b);
            $a = lo;
            $b = hi;
        }};
    }
    cs!(v0, v1);
    cs!(v2, v3);
    cs!(v4, v5);
    cs!(v6, v7);
    cs!(v8, v9);
    cs!(v10, v11);
    cs!(v12, v13);
    cs!(v14, v15);
    cs!(v0, v2);
    cs!(v4, v6);
    cs!(v8, v10);
    cs!(v12, v14);
    cs!(v1, v3);
    cs!(v5, v7);
    cs!(v9, v11);
    cs!(v13, v15);
    cs!(v0, v4);
    cs!(v8, v12);
    cs!(v1, v5);
    cs!(v9, v13);
    cs!(v2, v6);
    cs!(v10, v14);
    cs!(v3, v7);
    cs!(v11, v15);
    cs!(v0, v8);
    cs!(v1, v9);
    cs!(v2, v10);
    cs!(v3, v11);
    cs!(v4, v12);
    cs!(v5, v13);
    cs!(v6, v14);
    cs!(v7, v15);
    cs!(v5, v10);
    cs!(v6, v9);
    cs!(v3, v12);
    cs!(v13, v14);
    cs!(v7, v11);
    cs!(v1, v2);
    cs!(v4, v8);
    cs!(v1, v4);
    cs!(v7, v13);
    cs!(v2, v8);
    cs!(v11, v14);
    cs!(v5, v6);
    cs!(v9, v10);
    cs!(v2, v4);
    cs!(v11, v13);
    cs!(v3, v8);
    cs!(v7, v12);
    cs!(v6, v8);
    cs!(v10, v12);
    cs!(v3, v5);
    cs!(v7, v9);
    cs!(v3, v4);
    cs!(v5, v6);
    cs!(v7, v8);
    cs!(v9, v10);
    cs!(v11, v12);
    cs!(v6, v7);
    cs!(v8, v9);
    regs.copy_from_slice(&[v0, v1, v2, v3, v4, v5, v6, v7, v8, v9, v10, v11, v12, v13, v14, v15]);
}
