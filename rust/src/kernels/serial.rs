//! Branchless scalar merge primitives (paper Fig. 3b).
//!
//! The paper's serial comparator uses AArch64 `csel` instead of a
//! branch; on x86-64 the same source shape compiles to `cmov`. These
//! primitives are the "serial half" of the hybrid merger and the tail
//! path of the streaming run merge.

use crate::simd::Lane;
use crate::sortnet::Network;

/// Branchless compare-exchange on a scalar slice: after the call,
/// `data[i] = min`, `data[j] = max`. This is exactly Fig. 3b.
#[inline(always)]
pub fn cmpswap_scalar<T: Lane>(data: &mut [T], i: usize, j: usize) {
    let (a, b) = (data[i], data[j]);
    data[i] = a.lane_min(b);
    data[j] = a.lane_max(b);
}

/// Run one parallel layer of a merging network serially with
/// branchless comparators — the unit the hybrid merger interleaves
/// with vector stages.
#[inline]
pub fn apply_layer_scalar<T: Lane>(data: &mut [T], layer: &[crate::sortnet::Comparator]) {
    for c in layer {
        cmpswap_scalar(data, c.i as usize, c.j as usize);
    }
}

/// Apply a whole network serially (branchless). Equivalent to
/// [`Network::apply_slice`]; re-exported here so kernel code reads
/// symmetrically with the vector path.
#[inline]
pub fn apply_network_scalar<T: Lane>(data: &mut [T], net: &Network) {
    net.apply_slice(data);
}

/// Branchless streaming two-pointer merge of two sorted slices into
/// `out` (`out.len() == a.len() + b.len()`).
///
/// The hot loop advances exactly one input per iteration with
/// `cmov`-style index updates — no data-dependent branch, so no
/// misprediction cost on random keys (the paper's motivation for
/// `csel`). Tails are bulk-copied.
pub fn merge_scalar<T: Lane>(a: &[T], b: &[T], out: &mut [T]) {
    assert_eq!(out.len(), a.len() + b.len());
    let (mut i, mut j, mut k) = (0usize, 0usize, 0usize);
    while i < a.len() && j < b.len() {
        let va = a[i];
        let vb = b[j];
        let take_a = va <= vb;
        // Both arms computed, select with cmov — branchless.
        out[k] = if take_a { va } else { vb };
        i += take_a as usize;
        j += !take_a as usize;
        k += 1;
    }
    if i < a.len() {
        out[k..].copy_from_slice(&a[i..]);
    } else {
        out[k..].copy_from_slice(&b[j..]);
    }
}

/// Three-way serial merge — used by the streaming run merge to drain
/// its in-flight register block together with both input tails.
pub fn merge3_scalar<T: Lane>(a: &[T], b: &[T], c: &[T], out: &mut [T]) {
    assert_eq!(out.len(), a.len() + b.len() + c.len());
    let mut tmp = vec![T::MIN_VALUE; a.len() + b.len()];
    merge_scalar(a, b, &mut tmp);
    merge_scalar(&tmp, c, out);
}

/// Binary-insertion sort for tiny tails (< one vector block). Branchy
/// but only ever run on < 64 elements.
pub fn insertion_sort<T: Lane>(data: &mut [T]) {
    for i in 1..data.len() {
        let v = data[i];
        let pos = data[..i].partition_point(|x| *x <= v);
        data.copy_within(pos..i, pos + 1);
        data[pos] = v;
    }
}
