//! Fully vectorized bitonic merging networks over vector registers,
//! generic over the register width ([`Vector`]).
//!
//! A bitonic merge of `n = W·R` elements held in `R` registers of `W`
//! lanes runs `log(n)` half-cleaner stages (Fig. 4): stages with
//! element distance ≥ W are *register-level* — one `vmin`+`vmax` pair
//! per register pair, no shuffles; the last `log(W)` stages are
//! *intra-register* ([`Vector::bitonic_merge_lanes`]) and each cost
//! one shuffle + min + max + blend. This is the paper's "vectorized
//! bitonic" merger (Table 3 row 1) — the fully *symmetric*
//! implementation the hybrid merger ([`super::hybrid`]) is the
//! asymmetric counterpoint to: here the whole network is vectorized
//! uniformly, which is exactly what makes its structural regularity
//! pay (every half-cleaner stage is the same two-op pattern over
//! register pairs).
//!
//! # Invariants
//!
//! * [`bitonic_merge_regs`] requires the concatenation of all lanes
//!   (register order, then lane order) to be **bitonic** (ascending
//!   then descending) and `regs.len()` to be a power of two; it
//!   leaves the concatenation sorted ascending.
//! * [`merge_sorted_regs`] requires `regs[..h]` and `regs[h..]`
//!   (`h = len/2`) each sorted ascending; [`reverse_regs`] on the
//!   upper half forms the bitonic input. Stages never move data
//!   between the two halves of a half-cleaner except through
//!   `min`/`max`, so the merge is oblivious — same instruction stream
//!   for every input, no branches to mispredict.
//! * Every function here is width-generic: the register-level stages
//!   only use [`Vector::cmpswap`], and the intra-register tail is the
//!   implementation's own `log(W)`-stage merge, so instantiating at
//!   [`crate::simd::V256`] yields the same network shape with half
//!   the register count per K.

use crate::simd::{Lane, Lanes, Vector, V128};

/// Distance-2 + distance-1 bitonic stages within one `V128`: sorts
/// any 4-element bitonic sequence ascending. 2 shuffles, 2 blends,
/// 2 min, 2 max — the NEON `vrev64`/`vext` idiom. The width-generic
/// spelling is [`Vector::bitonic_merge_lanes`].
#[inline(always)]
pub fn merge4_in_reg<T: Lane>(r: V128<T>) -> V128<T> {
    Vector::bitonic_merge_lanes(r)
}

/// Bitonic-merge `regs` in place: the concatenation of all lanes must
/// form a bitonic sequence (ascending then descending). `regs.len()`
/// must be a power of two. After return the concatenation is sorted
/// ascending.
#[inline(always)]
pub fn bitonic_merge_regs<T: Lane, V: Vector<T>>(regs: &mut [V]) {
    let r = regs.len();
    debug_assert!(r.is_power_of_two() || r == 1);
    // Register-level half-cleaner stages: element distance W·d.
    let mut d = r / 2;
    while d >= 1 {
        let mut base = 0;
        while base < r {
            for i in base..base + d {
                let (lo, hi) = regs[i].cmpswap(regs[i + d]);
                regs[i] = lo;
                regs[i + d] = hi;
            }
            base += 2 * d;
        }
        d /= 2;
    }
    // Intra-register stages (log W of them).
    for v in regs.iter_mut() {
        *v = v.bitonic_merge_lanes();
    }
}

/// Reverse a sorted run held in registers (register order + lanes), so
/// `a ⌢ reverse(b)` forms the bitonic input a merge stage needs.
#[inline(always)]
pub fn reverse_regs<T: Lane, V: Vector<T>>(regs: &mut [V]) {
    regs.reverse();
    for v in regs.iter_mut() {
        *v = v.reverse();
    }
}

/// Merge two sorted 4-element registers into a sorted 8-element pair
/// `(lo, hi)` — the innermost 2×4 kernel.
#[inline(always)]
pub fn merge_2x4<T: Lane>(a: V128<T>, b: V128<T>) -> (V128<T>, V128<T>) {
    let b = b.reverse();
    let (lo, hi) = a.cmpswap(b);
    (merge4_in_reg(lo), merge4_in_reg(hi))
}

/// Merge two sorted register runs of equal length in place:
/// on entry `regs[..h]` and `regs[h..]` (h = `regs.len()/2`) each hold
/// a sorted run; on exit the whole of `regs` is sorted. Fully
/// vectorized (Table 3 "Vectorized Bitonic").
#[inline(always)]
pub fn merge_sorted_regs<T: Lane, V: Vector<T>>(regs: &mut [V]) {
    let h = regs.len() / 2;
    debug_assert_eq!(h * 2, regs.len());
    reverse_regs(&mut regs[h..]);
    bitonic_merge_regs(regs);
}

/// Convenience: vectorized merge of two equal-length sorted slices
/// (lengths equal, multiple of the lane count, power-of-two total)
/// into `out`, through the element's 128-bit register kernel
/// ([`Lane::Reg128`] — `V128` for 4-byte lanes, `V128D` for 8-byte).
/// Used by tests and the regmachine cross-check; the streaming path
/// for arbitrary lengths is [`super::runmerge`].
pub fn merge_slices<T: Lane>(a: &[T], b: &[T], out: &mut [T]) {
    let w = <T::Reg128 as Lanes>::LANES;
    assert_eq!(a.len(), b.len());
    assert!((2 * a.len()).is_power_of_two() && a.len() % w == 0);
    assert!(
        a.len() * T::BYTES <= super::hybrid::MAX_K_BYTES,
        "register kernel supports up to 2x{} bytes per side",
        super::hybrid::MAX_K_BYTES
    );
    assert_eq!(out.len(), a.len() * 2);
    // Monomorphize on the register count so the stage loops unroll.
    match 2 * a.len() / w {
        2 => merge_slices_impl::<T, 2>(a, b, out),
        4 => merge_slices_impl::<T, 4>(a, b, out),
        8 => merge_slices_impl::<T, 8>(a, b, out),
        16 => merge_slices_impl::<T, 16>(a, b, out),
        32 => merge_slices_impl::<T, 32>(a, b, out),
        _ => unreachable!(),
    }
}

#[inline(always)]
fn merge_slices_impl<T: Lane, const N: usize>(a: &[T], b: &[T], out: &mut [T]) {
    let () = super::hybrid::RegsFitMaxK::<T::Reg128, N>::OK;
    let w = <T::Reg128 as Lanes>::LANES;
    let mut regs = [T::Reg128::splat(T::MIN_VALUE); N];
    for (v, c) in regs.iter_mut().zip(a.chunks_exact(w).chain(b.chunks_exact(w))) {
        *v = T::Reg128::load(c);
    }
    merge_sorted_regs(&mut regs[..]);
    for (c, v) in out.chunks_exact_mut(w).zip(&regs) {
        v.store(c);
    }
}

/// Fully sort `regs` (arbitrary contents) with an in-register bitonic
/// *sorter*: sort runs of one register with [`Vector::sort_lanes`],
/// then double run length with [`merge_sorted_regs`] on sub-slices.
/// Used as an oracle and by the R=32 Table 2 variant's row stage.
pub fn bitonic_sort_regs<T: Lane, V: Vector<T>>(regs: &mut [V]) {
    debug_assert!(regs.len().is_power_of_two());
    for v in regs.iter_mut() {
        *v = v.sort_lanes();
    }
    let mut run = 1;
    while run < regs.len() {
        let mut base = 0;
        while base < regs.len() {
            merge_sorted_regs(&mut regs[base..base + 2 * run]);
            base += 2 * run;
        }
        run *= 2;
    }
}

/// Sort the four lanes of one `V128` ascending (tiny bitonic sorter:
/// 3 stages, 6 comparator-lanes — the n=4 column of Table 1's bitonic
/// family, executed horizontally). Width-generic spelling:
/// [`Vector::sort_lanes`].
#[inline(always)]
pub fn sort4_in_reg<T: Lane>(r: V128<T>) -> V128<T> {
    Vector::sort_lanes(r)
}
