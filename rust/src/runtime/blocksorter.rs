//! The XLA-offload sort path: blocks through the compiled L2 graph,
//! cross-block merging in rust (hybrid kernels).

use super::pjrt::{Executable, PjrtRuntime};
use super::registry::ArtifactRegistry;
use crate::kernels::runmerge::RunMerger;
use crate::simd::Lane;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Sorts arbitrary-length `i32`/`u32` slices by dispatching fixed-size
/// blocks to the AOT-compiled XLA block-sort and merging the sorted
/// blocks with the rust hybrid merger — the L3↔L2 composition.
pub struct BlockSorter {
    runtime: Arc<PjrtRuntime>,
    execs: BTreeMap<usize, Executable>,
    f32_execs: BTreeMap<usize, Executable>,
    /// Batched dispatch program, if a `block_sort_batchN` artifact
    /// exists: (batch rows, block length, executable).
    batched: Option<(usize, usize, Executable)>,
    merger: RunMerger,
}

impl BlockSorter {
    /// Compile every artifact in `registry` (once, eagerly — the
    /// coordinator constructs this at startup, off the request path).
    pub fn new(runtime: Arc<PjrtRuntime>, registry: &ArtifactRegistry) -> Result<Self> {
        let mut execs = BTreeMap::new();
        let mut f32_execs = BTreeMap::new();
        let mut batched = None;
        for v in registry.variants() {
            let exe = runtime
                .load_hlo_text(&v.path)
                .with_context(|| format!("loading {}", v.path.display()))?;
            if v.batch > 1 {
                batched = Some((v.batch, v.block, exe));
            } else if v.dtype == "float32" {
                f32_execs.insert(v.block, exe);
            } else {
                execs.insert(v.block, exe);
            }
        }
        anyhow::ensure!(!execs.is_empty(), "no int32 artifacts to compile");
        Ok(BlockSorter { runtime, execs, f32_execs, batched, merger: RunMerger::paper_default() })
    }

    /// Compiled block sizes, ascending.
    pub fn block_sizes(&self) -> Vec<usize> {
        self.execs.keys().copied().collect()
    }

    /// Backend platform (for logs).
    pub fn platform(&self) -> String {
        self.runtime.platform()
    }

    /// Largest compiled block ≤ `len`, else the smallest compiled.
    fn pick_block(&self, len: usize) -> usize {
        *self
            .execs
            .range(..=len)
            .next_back()
            .map(|(k, _)| k)
            .unwrap_or_else(|| self.execs.keys().next().expect("non-empty"))
    }

    /// Sort `data` ascending via XLA block dispatch + rust merge.
    pub fn sort_i32(&self, data: &mut [i32]) -> Result<()> {
        let n = data.len();
        if n <= 1 {
            return Ok(());
        }
        let block = self.pick_block(n);
        let exe = &self.execs[&block];
        // Phase 1: sorted runs of `block` via the XLA executable
        // (tail padded with i32::MAX inside a scratch buffer).
        let mut base = 0;
        while base < n {
            let end = (base + block).min(n);
            if end - base == block {
                let sorted = exe.run_i32(&data[base..end])?;
                data[base..end].copy_from_slice(&sorted);
            } else {
                let mut pad = vec![i32::MAX; block];
                pad[..end - base].copy_from_slice(&data[base..end]);
                let sorted = exe.run_i32(&pad)?;
                data[base..end].copy_from_slice(&sorted[..end - base]);
            }
            base = end;
        }
        // Phase 2: rust merge passes over the sorted runs.
        merge_runs(data, block, &self.merger);
        Ok(())
    }

    /// Batched-dispatch geometry, if a batched artifact was compiled:
    /// `(batch rows, block length)`.
    pub fn batch_geometry(&self) -> Option<(usize, usize)> {
        self.batched.as_ref().map(|(b, n, _)| (*b, *n))
    }

    /// Sort up to `batch` requests of ≤ `block` elements each in ONE
    /// PJRT dispatch (the coordinator's dynamic batching). Rows are
    /// padded with `i32::MAX`; each row comes back fully sorted.
    /// Returns `Err` if no batched artifact is compiled or any row
    /// exceeds the block length.
    pub fn sort_batch_i32(&self, rows: &mut [&mut [i32]]) -> Result<()> {
        let Some((batch, block, exe)) = self.batched.as_ref() else {
            anyhow::bail!("no batched artifact compiled");
        };
        anyhow::ensure!(rows.len() <= *batch, "too many rows for batch {batch}");
        for r in rows.iter() {
            anyhow::ensure!(r.len() <= *block, "row exceeds block {block}");
        }
        let mut staging = vec![i32::MAX; batch * block];
        for (i, r) in rows.iter().enumerate() {
            staging[i * block..i * block + r.len()].copy_from_slice(r);
        }
        let sorted = exe.run_i32_batched(&staging, *batch, *block)?;
        for (i, r) in rows.iter_mut().enumerate() {
            let len = r.len();
            r.copy_from_slice(&sorted[i * block..i * block + len]);
        }
        Ok(())
    }

    /// [`BlockSorter::sort_batch_i32`] for `u32` rows (order-preserving
    /// XOR mapping, as in [`BlockSorter::sort_u32`]).
    pub fn sort_batch_u32(&self, rows: &mut [&mut [u32]]) -> Result<()> {
        for r in rows.iter_mut() {
            for v in r.iter_mut() {
                *v ^= 0x8000_0000;
            }
        }
        let res = {
            // SAFETY: identical layout; XOR maps unsigned order onto
            // signed order.
            let mut cast: Vec<&mut [i32]> = rows
                .iter_mut()
                .map(|r| unsafe {
                    std::slice::from_raw_parts_mut(r.as_mut_ptr() as *mut i32, r.len())
                })
                .collect();
            self.sort_batch_i32(&mut cast)
        };
        for r in rows.iter_mut() {
            for v in r.iter_mut() {
                *v ^= 0x8000_0000;
            }
        }
        res
    }

    /// Sort `f32` data (no NaNs — same contract as the CPU path) via
    /// the float32 artifacts; errors if none were compiled.
    pub fn sort_f32(&self, data: &mut [f32]) -> Result<()> {
        let n = data.len();
        if n <= 1 {
            return Ok(());
        }
        anyhow::ensure!(
            !self.f32_execs.is_empty(),
            "no float32 artifacts — run `make artifacts` (aot.py emits both dtypes)"
        );
        let block = *self
            .f32_execs
            .range(..=n)
            .next_back()
            .map(|(k, _)| k)
            .unwrap_or_else(|| self.f32_execs.keys().next().expect("non-empty"));
        let exe = &self.f32_execs[&block];
        let mut base = 0;
        while base < n {
            let end = (base + block).min(n);
            if end - base == block {
                let sorted = exe.run_f32(&data[base..end])?;
                data[base..end].copy_from_slice(&sorted);
            } else {
                let mut pad = vec![f32::INFINITY; block];
                pad[..end - base].copy_from_slice(&data[base..end]);
                let sorted = exe.run_f32(&pad)?;
                data[base..end].copy_from_slice(&sorted[..end - base]);
            }
            base = end;
        }
        merge_runs(data, block, &self.merger);
        Ok(())
    }

    /// Sort `u32` data via the order-preserving i32 mapping
    /// (`x ^ 0x8000_0000`): the int32 artifact serves both types.
    pub fn sort_u32(&self, data: &mut [u32]) -> Result<()> {
        for v in data.iter_mut() {
            *v ^= 0x8000_0000;
        }
        // SAFETY: u32 and i32 have identical layout; the XOR above
        // makes unsigned order match signed order.
        let as_i32 =
            unsafe { std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut i32, data.len()) };
        let res = self.sort_i32(as_i32);
        for v in data.iter_mut() {
            *v ^= 0x8000_0000;
        }
        res
    }
}

/// Ping-pong merge passes growing runs of `run` to the full length.
pub(crate) fn merge_runs<T: Lane>(data: &mut [T], mut run: usize, merger: &RunMerger) {
    let n = data.len();
    if run >= n {
        return;
    }
    let mut aux: Vec<T> = vec![T::MIN_VALUE; n];
    let mut src_is_data = true;
    while run < n {
        {
            let (src, dst): (&[T], &mut [T]) =
                if src_is_data { (&*data, &mut aux[..]) } else { (&aux[..], data) };
            let mut base = 0;
            while base < n {
                let mid = (base + run).min(n);
                let end = (base + 2 * run).min(n);
                if mid < end {
                    merger.merge(&src[base..mid], &src[mid..end], &mut dst[base..end]);
                } else {
                    dst[base..end].copy_from_slice(&src[base..end]);
                }
                base = end;
            }
        }
        src_is_data = !src_is_data;
        run *= 2;
    }
    if !src_is_data {
        data.copy_from_slice(&aux);
    }
}
