//! Thin, safe wrapper over the `xla` crate's PJRT CPU client.

use anyhow::{bail, Context, Result};
use std::path::Path;

/// Process-wide PJRT client. Construction is expensive (plugin init);
/// share one per process (the coordinator holds it in an `Arc`).
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime { client })
    }

    /// Backend platform name (e.g. `"cpu"`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO **text** artifact and compile it.
    ///
    /// Text is the interchange format by design: jax ≥ 0.5 emits
    /// protos with 64-bit instruction ids that xla_extension 0.5.1
    /// rejects; the text parser reassigns ids (see aot.py).
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe, name: path.display().to_string() })
    }
}

/// One compiled XLA program (e.g. `block_sort_int32_4096`).
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Executable {
    /// Artifact path this executable was compiled from.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute on one `i32` vector; the program must map
    /// `s32[n] -> (s32[n],)` (the aot.py export contract).
    pub fn run_i32(&self, input: &[i32]) -> Result<Vec<i32>> {
        self.run_vec(input)
    }

    /// Execute on one `f32` vector (`f32[n] -> (f32[n],)` programs).
    pub fn run_f32(&self, input: &[f32]) -> Result<Vec<f32>> {
        self.run_vec(input)
    }

    /// Execute a batched program (`s32[batch, block] -> (same,)`) on a
    /// row-major flattened input of `batch · block` elements.
    pub fn run_i32_batched(&self, input: &[i32], batch: usize, block: usize) -> Result<Vec<i32>> {
        anyhow::ensure!(input.len() == batch * block, "batched input shape mismatch");
        let lit = xla::Literal::vec1(input)
            .reshape(&[batch as i64, block as i64])
            .context("reshaping batched input")?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[lit])
            .with_context(|| format!("executing {}", self.name))?;
        let Some(buf) = result.first().and_then(|d| d.first()) else {
            bail!("{}: empty result", self.name);
        };
        let out = buf
            .to_literal_sync()
            .context("device->host transfer")?
            .to_tuple1()
            .context("unwrapping 1-tuple result")?;
        Ok(out.to_vec::<i32>()?)
    }

    fn run_vec<T: xla::NativeType + xla::ArrayElement>(&self, input: &[T]) -> Result<Vec<T>> {
        let lit = xla::Literal::vec1(input);
        let result = self
            .exe
            .execute::<xla::Literal>(&[lit])
            .with_context(|| format!("executing {}", self.name))?;
        let Some(buf) = result.first().and_then(|d| d.first()) else {
            bail!("{}: empty result", self.name);
        };
        let out = buf
            .to_literal_sync()
            .context("device->host transfer")?
            .to_tuple1()
            .context("unwrapping 1-tuple result")?;
        Ok(out.to_vec::<T>()?)
    }
}
