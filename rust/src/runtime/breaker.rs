//! Circuit breaker for the XLA executor: after a run of consecutive
//! dispatch failures the breaker trips **open** and the executor
//! stops paying for doomed PJRT calls — every job takes the CPU
//! fallback immediately. After a cool-off period the breaker lets
//! exactly one probe through (**half-open**); a success closes it, a
//! failure re-opens it for another cool-off.
//!
//! The breaker is owned by the single executor thread, so it is plain
//! mutable state — no atomics, no locks. Time is injected
//! ([`CircuitBreaker::allow_at`] / [`CircuitBreaker::record_failure_at`])
//! so the open → half-open transition is unit-testable without
//! sleeping; the executor uses the `Instant::now()` convenience
//! wrappers. The executor mirrors [`CircuitBreaker::state_code`] and
//! [`CircuitBreaker::trips`] into the service metrics after every
//! transition, which is how `MetricsSnapshot::breaker_state` stays
//! a lock-free gauge.

use std::time::{Duration, Instant};

/// The three classic breaker states.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    /// Normal operation: dispatches flow, consecutive failures are
    /// counted.
    Closed,
    /// Tripped: no dispatches until `until`; callers take the
    /// fallback path without paying for the doomed call.
    Open {
        /// When the cool-off ends and a half-open probe is allowed.
        until: Instant,
    },
    /// Cool-off expired: one probe is in flight; its outcome decides
    /// between [`BreakerState::Closed`] and another open period.
    HalfOpen,
}

/// Consecutive-failure circuit breaker (see module docs).
#[derive(Debug)]
pub struct CircuitBreaker {
    /// Consecutive failures that trip the breaker open.
    threshold: u32,
    /// How long an open period lasts before a half-open probe.
    cooloff: Duration,
    /// Consecutive failures observed while closed.
    consecutive: u32,
    state: BreakerState,
    /// Times the breaker has tripped closed → open (re-opens from
    /// half-open count too: every trip is a distinct degradation
    /// event worth counting).
    trips: u64,
}

impl CircuitBreaker {
    /// A closed breaker tripping after `threshold` consecutive
    /// failures and probing again `cooloff` after each trip.
    /// `threshold` is clamped to ≥ 1.
    pub fn new(threshold: u32, cooloff: Duration) -> Self {
        CircuitBreaker {
            threshold: threshold.max(1),
            cooloff,
            consecutive: 0,
            state: BreakerState::Closed,
            trips: 0,
        }
    }

    /// Whether a dispatch may proceed at time `now`. Open → false
    /// until the cool-off elapses, at which point the breaker moves
    /// to half-open and admits exactly this caller as the probe.
    pub fn allow_at(&mut self, now: Instant) -> bool {
        match self.state {
            BreakerState::Closed | BreakerState::HalfOpen => true,
            BreakerState::Open { until } => {
                if now >= until {
                    self.state = BreakerState::HalfOpen;
                    true
                } else {
                    false
                }
            }
        }
    }

    /// [`CircuitBreaker::allow_at`] at `Instant::now()`.
    pub fn allow(&mut self) -> bool {
        self.allow_at(Instant::now())
    }

    /// Record a successful dispatch: closes the breaker (half-open
    /// probe succeeded) and clears the failure run.
    pub fn record_success(&mut self) {
        self.consecutive = 0;
        self.state = BreakerState::Closed;
    }

    /// Record a failed dispatch at time `now`: extends the failure
    /// run and trips open (for `cooloff` from `now`) when the run
    /// reaches the threshold — immediately, when the failure was a
    /// half-open probe.
    pub fn record_failure_at(&mut self, now: Instant) {
        match self.state {
            BreakerState::HalfOpen => {
                // Probe failed: straight back to open, no grace run.
                self.state = BreakerState::Open { until: now + self.cooloff };
                self.trips += 1;
            }
            BreakerState::Closed => {
                self.consecutive += 1;
                if self.consecutive >= self.threshold {
                    self.consecutive = 0;
                    self.state = BreakerState::Open { until: now + self.cooloff };
                    self.trips += 1;
                }
            }
            // Failures reported while open (e.g. a forced-fault roll
            // on a job that never dispatched) don't extend the
            // cool-off: the breaker is already doing its job.
            BreakerState::Open { .. } => {}
        }
    }

    /// [`CircuitBreaker::record_failure_at`] at `Instant::now()`.
    pub fn record_failure(&mut self) {
        self.record_failure_at(Instant::now())
    }

    /// The current state (open periods are *not* auto-expired here;
    /// expiry happens on the next [`CircuitBreaker::allow_at`]).
    pub fn state(&self) -> BreakerState {
        self.state
    }

    /// Dense code for the metrics gauge: 0 closed, 1 open,
    /// 2 half-open. Matches `MetricsSnapshot::breaker_state`'s
    /// decoding.
    pub fn state_code(&self) -> u64 {
        match self.state {
            BreakerState::Closed => 0,
            BreakerState::Open { .. } => 1,
            BreakerState::HalfOpen => 2,
        }
    }

    /// Closed/half-open → open transitions so far.
    pub fn trips(&self) -> u64 {
        self.trips
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t0() -> Instant {
        Instant::now()
    }

    #[test]
    fn trips_after_threshold_consecutive_failures() {
        let now = t0();
        let mut b = CircuitBreaker::new(3, Duration::from_millis(100));
        assert!(b.allow_at(now));
        b.record_failure_at(now);
        b.record_failure_at(now);
        assert!(b.allow_at(now), "below threshold: still closed");
        b.record_failure_at(now);
        assert_eq!(b.trips(), 1);
        assert!(!b.allow_at(now), "third consecutive failure trips open");
        assert_eq!(b.state_code(), 1);
    }

    #[test]
    fn success_resets_the_failure_run() {
        let now = t0();
        let mut b = CircuitBreaker::new(2, Duration::from_millis(100));
        b.record_failure_at(now);
        b.record_success();
        b.record_failure_at(now);
        assert!(b.allow_at(now), "run was reset; one failure is below threshold");
        assert_eq!(b.trips(), 0);
    }

    #[test]
    fn half_open_probe_success_closes() {
        let now = t0();
        let cooloff = Duration::from_millis(50);
        let mut b = CircuitBreaker::new(1, cooloff);
        b.record_failure_at(now);
        assert!(!b.allow_at(now), "open");
        assert!(!b.allow_at(now + cooloff / 2), "still cooling off");
        assert!(b.allow_at(now + cooloff), "cool-off elapsed: probe admitted");
        assert_eq!(b.state_code(), 2, "half-open while the probe is out");
        b.record_success();
        assert_eq!(b.state_code(), 0, "probe success closes");
        assert!(b.allow_at(now + cooloff));
    }

    #[test]
    fn half_open_probe_failure_reopens() {
        let now = t0();
        let cooloff = Duration::from_millis(50);
        let mut b = CircuitBreaker::new(1, cooloff);
        b.record_failure_at(now);
        assert!(b.allow_at(now + cooloff));
        b.record_failure_at(now + cooloff);
        assert_eq!(b.trips(), 2, "probe failure is a second trip");
        assert!(!b.allow_at(now + cooloff + cooloff / 2), "re-opened for a fresh cool-off");
        assert!(b.allow_at(now + cooloff + cooloff), "…then probes again");
    }

    #[test]
    fn failures_while_open_do_not_extend_the_cooloff() {
        let now = t0();
        let cooloff = Duration::from_millis(50);
        let mut b = CircuitBreaker::new(1, cooloff);
        b.record_failure_at(now);
        // Forced-fault rolls keep reporting while open; the probe
        // time must not creep.
        b.record_failure_at(now + Duration::from_millis(40));
        assert!(b.allow_at(now + cooloff), "original cool-off still governs");
        assert_eq!(b.trips(), 1);
    }

    #[test]
    fn zero_threshold_clamps_to_one() {
        let now = t0();
        let mut b = CircuitBreaker::new(0, Duration::from_millis(10));
        b.record_failure_at(now);
        assert!(!b.allow_at(now), "clamped threshold 1 trips on the first failure");
    }
}
