//! Artifact discovery: scan `artifacts/` for `block_sort_<dtype>_<n>.hlo.txt`
//! files (the aot.py naming contract) and select variants by request
//! size. Filename-based rather than manifest-based so the registry has
//! no JSON dependency and tolerates partial artifact sets.

use anyhow::Result;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One discovered artifact.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactVariant {
    /// Block length in elements (power of two).
    pub block: usize,
    /// Rows per dispatch: 1 for the plain variants, >1 for the
    /// `block_sort_batchN_*` artifacts (coordinator dynamic batching).
    pub batch: usize,
    /// Element dtype as named by aot.py (`int32` / `float32`).
    pub dtype: String,
    /// Path to the HLO text file.
    pub path: PathBuf,
}

/// Registry of available block-sort artifacts, keyed by
/// (dtype, block size).
#[derive(Clone, Debug, Default)]
pub struct ArtifactRegistry {
    variants: BTreeMap<(String, usize, usize), ArtifactVariant>,
}

impl ArtifactRegistry {
    /// Scan a directory. Unrecognized files are ignored; an empty or
    /// missing directory yields an empty registry (callers decide
    /// whether XLA offload is mandatory).
    pub fn scan(dir: impl AsRef<Path>) -> Self {
        let mut variants = BTreeMap::new();
        let Ok(entries) = std::fs::read_dir(dir.as_ref()) else {
            return ArtifactRegistry { variants };
        };
        for entry in entries.flatten() {
            let path = entry.path();
            let Some(name) = path.file_name().and_then(|s| s.to_str()) else {
                continue;
            };
            if let Some(v) = Self::parse_name(name, &path) {
                variants.insert((v.dtype.clone(), v.block, v.batch), v);
            }
        }
        ArtifactRegistry { variants }
    }

    fn parse_name(name: &str, path: &Path) -> Option<ArtifactVariant> {
        let stem = name.strip_suffix(".hlo.txt")?;
        let mut rest = stem.strip_prefix("block_sort_")?;
        let mut batch = 1usize;
        if let Some(tail) = rest.strip_prefix("batch") {
            let (b, r) = tail.split_once('_')?;
            batch = b.parse().ok()?;
            rest = r;
        }
        let (dtype, block) = rest.rsplit_once('_')?;
        if dtype != "int32" && dtype != "float32" {
            return None;
        }
        let block: usize = block.parse().ok()?;
        Some(ArtifactVariant {
            block,
            batch,
            dtype: dtype.to_string(),
            path: path.to_path_buf(),
        })
    }

    /// All variants, ascending by (dtype, block size).
    pub fn variants(&self) -> impl Iterator<Item = &ArtifactVariant> {
        self.variants.values()
    }

    /// Unbatched variants of one dtype, ascending by block size.
    pub fn variants_of(&self, dtype: &str) -> impl Iterator<Item = &ArtifactVariant> + '_ {
        let key = dtype.to_string();
        self.variants
            .range((key.clone(), 0, 0)..=(key, usize::MAX, usize::MAX))
            .map(|(_, v)| v)
            .filter(|v| v.batch == 1)
    }

    /// Batched variants (batch > 1), any dtype.
    pub fn batched_variants(&self) -> impl Iterator<Item = &ArtifactVariant> {
        self.variants.values().filter(|v| v.batch > 1)
    }

    /// Number of variants.
    pub fn len(&self) -> usize {
        self.variants.len()
    }

    /// True if no artifacts were found.
    pub fn is_empty(&self) -> bool {
        self.variants.is_empty()
    }

    /// Pick the best block size for an `int32` request of `len`
    /// elements: the largest block ≤ `len`, else the smallest
    /// available (the tail is padded).
    pub fn pick(&self, len: usize) -> Result<&ArtifactVariant> {
        self.pick_of("int32", len)
    }

    /// [`ArtifactRegistry::pick`] for an explicit dtype.
    pub fn pick_of(&self, dtype: &str, len: usize) -> Result<&ArtifactVariant> {
        let mut best: Option<&ArtifactVariant> = None;
        for v in self.variants_of(dtype) {
            if best.is_none() || v.block <= len {
                best = Some(v);
            }
        }
        best.ok_or_else(|| {
            anyhow::anyhow!("no {dtype} block_sort artifacts found — run `make artifacts`")
        })
    }
}
