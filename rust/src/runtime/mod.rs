//! PJRT runtime: load the AOT artifacts produced by `make artifacts`
//! (`python/compile/aot.py`) and execute them from the rust hot path.
//!
//! One [`PjrtRuntime`] per process wraps the CPU PJRT client; each HLO
//! text artifact compiles once into an [`Executable`]. The
//! [`BlockSorter`] composes them into the L3 sort path: XLA sorts
//! fixed-size blocks (the L2 graph = Pallas tile sort + merge passes),
//! rust merges across blocks with the hybrid kernels — mirroring the
//! paper's split between in-register sort and the outer merge.

mod blocksorter;
mod breaker;
mod pjrt;
mod registry;

pub use blocksorter::BlockSorter;
pub use breaker::{BreakerState, CircuitBreaker};
pub use pjrt::{Executable, PjrtRuntime};
pub use registry::{ArtifactRegistry, ArtifactVariant};

/// Re-export of the run-merging pass for benches (the ablation
/// harness compares parallel-merge strategies against it).
pub fn merge_runs_for_bench<T: crate::simd::Lane>(
    data: &mut [T],
    run: usize,
    merger: &crate::kernels::runmerge::RunMerger,
) {
    blocksorter::merge_runs(data, run, merger)
}

#[cfg(test)]
mod tests;
