//! Runtime tests. Registry tests are hermetic; executable tests need
//! `artifacts/` (built by `make artifacts`) and are skipped with a
//! note when absent so `cargo test` works pre-AOT.

use super::*;
use crate::testutil::{assert_sorted, Rng};
use std::sync::Arc;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn registry() -> ArtifactRegistry {
    ArtifactRegistry::scan(artifacts_dir())
}

macro_rules! require_artifacts {
    ($reg:expr) => {
        if $reg.is_empty() {
            eprintln!("SKIP: no artifacts — run `make artifacts` first");
            return;
        }
    };
}

#[test]
fn registry_parses_filenames() {
    let dir = std::env::temp_dir().join(format!("neonms_reg_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    for name in [
        "block_sort_int32_1024.hlo.txt",
        "block_sort_int32_4096.hlo.txt",
        "block_sort_float32_1024.hlo.txt",
        "block_sort_bf16_1024.hlo.txt", // unknown dtype — ignored
        "manifest.json",                // ignored
        "junk.txt",                     // ignored
    ] {
        std::fs::write(dir.join(name), "x").unwrap();
    }
    std::fs::write(dir.join("block_sort_batch8_int32_1024.hlo.txt"), "x").unwrap();
    let reg = ArtifactRegistry::scan(&dir);
    assert_eq!(reg.len(), 4);
    let batched: Vec<_> = reg.batched_variants().collect();
    assert_eq!(batched.len(), 1);
    assert_eq!((batched[0].batch, batched[0].block), (8, 1024));
    // Batched variants never serve the unbatched pick path.
    assert!(reg.variants_of("int32").all(|v| v.batch == 1));
    assert_eq!(reg.pick(100).unwrap().block, 1024, "below smallest → smallest");
    assert_eq!(reg.pick(2000).unwrap().block, 1024);
    assert_eq!(reg.pick(4096).unwrap().block, 4096);
    assert_eq!(reg.pick(1 << 20).unwrap().block, 4096);
    assert_eq!(reg.pick_of("float32", 1 << 20).unwrap().block, 1024);
    assert!(reg.pick_of("bf16", 10).is_err(), "unknown dtype rejected");
    assert_eq!(reg.variants_of("int32").count(), 2);
    assert_eq!(reg.variants_of("float32").count(), 1);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn registry_missing_dir_is_empty() {
    let reg = ArtifactRegistry::scan("/nonexistent/path");
    assert!(reg.is_empty());
    assert!(reg.pick(100).is_err());
}

#[test]
fn executable_sorts_one_block() {
    let reg = registry();
    require_artifacts!(reg);
    let rt = Arc::new(PjrtRuntime::cpu().unwrap());
    let v = reg.pick(0).unwrap();
    let exe = rt.load_hlo_text(&v.path).unwrap();
    let mut rng = Rng::new(1);
    let input: Vec<i32> = (0..v.block).map(|_| rng.next_i32()).collect();
    let out = exe.run_i32(&input).unwrap();
    let mut expect = input.clone();
    expect.sort_unstable();
    assert_eq!(out, expect, "XLA block sort vs oracle");
}

#[test]
fn blocksorter_sorts_multi_block_and_tail() {
    let reg = registry();
    require_artifacts!(reg);
    let rt = Arc::new(PjrtRuntime::cpu().unwrap());
    let bs = BlockSorter::new(rt, &reg).unwrap();
    let mut rng = Rng::new(2);
    for len in [1usize, 100, 1024, 5000, 20_000] {
        let mut data: Vec<i32> = (0..len).map(|_| rng.next_i32()).collect();
        let mut expect = data.clone();
        expect.sort_unstable();
        bs.sort_i32(&mut data).unwrap();
        assert_eq!(data, expect, "len {len}");
    }
}

#[test]
fn blocksorter_batched_dispatch() {
    let reg = registry();
    require_artifacts!(reg);
    let Some(v) = reg.batched_variants().next() else {
        eprintln!("SKIP: no batched artifact — rerun `make artifacts`");
        return;
    };
    let (batch, block) = (v.batch, v.block);
    let rt = Arc::new(PjrtRuntime::cpu().unwrap());
    let bs = BlockSorter::new(rt, &reg).unwrap();
    assert_eq!(bs.batch_geometry(), Some((batch, block)));
    let mut rng = Rng::new(21);
    // Mixed row lengths, including empty and full-block.
    let mut rows: Vec<Vec<u32>> = (0..batch)
        .map(|i| rng.vec_u32([0, 7, block / 2, block][i % 4]))
        .collect();
    let expect: Vec<Vec<u32>> = rows
        .iter()
        .map(|r| {
            let mut e = r.clone();
            e.sort_unstable();
            e
        })
        .collect();
    let mut views: Vec<&mut [u32]> = rows.iter_mut().map(|r| r.as_mut_slice()).collect();
    bs.sort_batch_u32(&mut views).unwrap();
    assert_eq!(rows, expect, "all rows sorted in one dispatch");
    // Oversized row rejected.
    let mut too_big = vec![0u32; block + 1];
    let mut views: Vec<&mut [u32]> = vec![too_big.as_mut_slice()];
    assert!(bs.sort_batch_u32(&mut views).is_err());
}

#[test]
fn blocksorter_f32_path() {
    let reg = registry();
    require_artifacts!(reg);
    if reg.variants_of("float32").count() == 0 {
        eprintln!("SKIP: no float32 artifacts");
        return;
    }
    let rt = Arc::new(PjrtRuntime::cpu().unwrap());
    let bs = BlockSorter::new(rt, &reg).unwrap();
    let mut rng = Rng::new(9);
    let mut data: Vec<f32> = (0..5000).map(|_| rng.next_f32() * 2e6 - 1e6).collect();
    let mut expect = data.clone();
    expect.sort_by(|a, b| a.partial_cmp(b).unwrap());
    bs.sort_f32(&mut data).unwrap();
    assert_eq!(data, expect);
}

#[test]
fn blocksorter_u32_mapping() {
    let reg = registry();
    require_artifacts!(reg);
    let rt = Arc::new(PjrtRuntime::cpu().unwrap());
    let bs = BlockSorter::new(rt, &reg).unwrap();
    let mut rng = Rng::new(3);
    // Values spanning the sign boundary of the i32 mapping.
    let mut data: Vec<u32> = (0..6000).map(|_| rng.next_u32()).collect();
    data.extend([0u32, u32::MAX, 0x7FFF_FFFF, 0x8000_0000]);
    let mut expect = data.clone();
    expect.sort_unstable();
    bs.sort_u32(&mut data).unwrap();
    assert_eq!(data, expect);
}

#[test]
fn merge_runs_unit() {
    use crate::kernels::runmerge::RunMerger;
    let mut rng = Rng::new(4);
    for len in [64usize, 100, 257, 4096] {
        let mut data = rng.vec_u32(len);
        for chunk in data.chunks_mut(64) {
            chunk.sort_unstable();
        }
        super::blocksorter::merge_runs(&mut data, 64, &RunMerger::paper_default());
        assert_sorted(&data, &format!("merge_runs len {len}"));
    }
}
