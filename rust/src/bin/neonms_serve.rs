//! `neonms-serve` — the TCP front end: one [`SortService`] served
//! over the wire protocol (`neonms::net`) until a `SHUTDOWN` frame
//! arrives.
//!
//! ```text
//! neonms-serve [--addr HOST:PORT] [--workers W] [--shards S]
//!              [--queue-capacity C] [--batch-max B] [--qos fair|fifo]
//!              [--backend auto|scalar|neon|sse4.2|avx2]
//! ```
//!
//! Defaults: `--addr 127.0.0.1:7071`, coordinator knobs from
//! [`CoordinatorConfig::default`]. Prints `listening on <addr>` once
//! accepting (the line CI's smoke job and scripts wait for), serves
//! until a client sends `SHUTDOWN`, then drains the service and
//! prints the final counter summary. Overload never drops
//! connections — saturated tenants receive `RETRY_AFTER` frames (see
//! docs/OPERATIONS.md, "Reading a RETRY-AFTER").

use neonms::coordinator::{CoordinatorConfig, QosPolicy, SortService};
use neonms::net::NetServer;
use std::process::ExitCode;
use std::sync::Arc;

const USAGE: &str = "usage: neonms-serve [--addr HOST:PORT] [--workers W] [--shards S] \
                     [--queue-capacity C] [--batch-max B] [--qos fair|fifo] \
                     [--backend auto|scalar|neon|sse4.2|avx2]";

/// Minimal flag parser (`--key value` pairs), same shape as the main
/// CLI's — binaries are separate crates, so the few lines are local.
struct Flags(Vec<(String, Option<String>)>);

impl Flags {
    fn parse(args: &[String]) -> Flags {
        let mut out = Vec::new();
        let mut i = 0;
        while i < args.len() {
            if let Some(key) = args[i].strip_prefix("--") {
                let val = args.get(i + 1).filter(|v| !v.starts_with("--")).cloned();
                if val.is_some() {
                    i += 1;
                }
                out.push((key.to_string(), val));
            }
            i += 1;
        }
        Flags(out)
    }

    fn get_usize(&self, key: &str, default: usize) -> usize {
        self.0
            .iter()
            .find(|(k, _)| k == key)
            .and_then(|(_, v)| v.as_ref())
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    fn get_str(&self, key: &str, default: &str) -> String {
        self.0
            .iter()
            .find(|(k, _)| k == key)
            .and_then(|(_, v)| v.clone())
            .unwrap_or_else(|| default.to_string())
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let flags = Flags::parse(&args);

    let defaults = CoordinatorConfig::default();
    let qos = match flags.get_str("qos", "fair").as_str() {
        "fair" => QosPolicy::FairShare,
        "fifo" => QosPolicy::Fifo,
        other => {
            eprintln!("--qos {other}: expected fair|fifo\n{USAGE}");
            return ExitCode::from(2);
        }
    };
    let backend_name = flags.get_str("backend", "auto");
    let backend = if backend_name.trim().eq_ignore_ascii_case("auto") {
        None
    } else {
        match neonms::simd::Backend::parse(&backend_name) {
            Some(b) if b.available() => Some(b),
            Some(b) => {
                eprintln!(
                    "--backend {backend_name}: `{}` is not available on this machine; \
                     `scalar` always is\n{USAGE}",
                    b.name()
                );
                return ExitCode::from(2);
            }
            None => {
                eprintln!("--backend {backend_name}: unknown backend\n{USAGE}");
                return ExitCode::from(2);
            }
        }
    };
    let cfg = CoordinatorConfig {
        workers: flags.get_usize("workers", defaults.workers),
        shards: flags.get_usize("shards", defaults.shards),
        queue_capacity: flags.get_usize("queue-capacity", defaults.queue_capacity),
        batch_max: flags.get_usize("batch-max", defaults.batch_max),
        qos,
        sort: neonms::sort::SortConfig { backend, ..defaults.sort.clone() },
        ..defaults
    };

    let svc = match SortService::start(cfg, None) {
        Ok(svc) => Arc::new(svc),
        Err(e) => {
            eprintln!("neonms-serve: failed to start sort service: {e}");
            return ExitCode::FAILURE;
        }
    };

    let addr = flags.get_str("addr", "127.0.0.1:7071");
    let server = match NetServer::bind(Arc::clone(&svc), &addr) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("neonms-serve: failed to bind {addr}: {e}");
            return ExitCode::FAILURE;
        }
    };
    println!("listening on {}", server.local_addr());
    println!("simd backend: {}", svc.metrics().simd_backend);

    // Blocks until a SHUTDOWN frame stops the accept loop and every
    // connection thread has joined (their pending handles resolved).
    server.wait();

    match Arc::try_unwrap(svc) {
        Ok(svc) => {
            let snap = svc.metrics();
            svc.shutdown();
            println!(
                "shutdown: {} submitted, {} completed, {} cancelled, {} failed, \
                 {} rejected, {} quarantined",
                snap.submitted,
                snap.completed,
                snap.cancelled,
                snap.failed,
                snap.rejected,
                snap.quarantined
            );
            println!(
                "wire: {} connections, {} frames, {} retry-after, {} protocol errors",
                snap.connections_opened,
                snap.net_frames,
                snap.net_retry_after,
                snap.net_protocol_errors
            );
            ExitCode::SUCCESS
        }
        Err(_) => {
            // Unreachable once wait() joined every holder; refuse to
            // exit pretending the drain happened.
            eprintln!("neonms-serve: service still referenced after server stop");
            ExitCode::FAILURE
        }
    }
}
