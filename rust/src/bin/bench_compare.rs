//! `bench-compare` — validate a bench artifact and gate it against a
//! committed baseline.
//!
//! ```text
//! bench_compare --candidate fresh.json                     # validate only
//! bench_compare --baseline BENCH_x.json --candidate fresh.json
//! bench_compare --baseline BENCH_x.json --candidate fresh.json --tol 0.1
//! bench_compare --baseline BENCH_x.json --candidate fresh.json --refresh
//! ```
//!
//! Exit codes: `0` pass (or refresh written), `1` regression /
//! structural break, `2` bad usage, unreadable file, or schema error.
//!
//! `--refresh` rewrites the baseline path with the candidate report,
//! stamped with `refreshed_unix` — the workflow for recording a new
//! native baseline once a host with cargo has run the bench (see
//! OPERATIONS.md "Benchmark gates").

use neonms::bench::compare::{compare, CompareConfig};
use neonms::bench::report::{BenchReport, SourceKind};
use std::process::ExitCode;

const USAGE: &str = "usage: bench_compare --candidate <report.json> \
                     [--baseline <report.json>] [--tol <rel>] [--refresh]";

struct Args {
    baseline: Option<String>,
    candidate: Option<String>,
    tol: Option<f64>,
    refresh: bool,
}

fn parse_args() -> Result<Args, String> {
    let mut args = Args { baseline: None, candidate: None, tol: None, refresh: false };
    let mut it = std::env::args().skip(1);
    while let Some(flag) = it.next() {
        match flag.as_str() {
            "--baseline" => args.baseline = Some(it.next().ok_or("--baseline needs a path")?),
            "--candidate" => args.candidate = Some(it.next().ok_or("--candidate needs a path")?),
            "--tol" => {
                let raw = it.next().ok_or("--tol needs a value")?;
                let v: f64 = raw.parse().map_err(|_| format!("bad --tol \"{raw}\""))?;
                if !v.is_finite() || v <= 0.0 {
                    return Err(format!("--tol must be positive, got {raw}"));
                }
                args.tol = Some(v);
            }
            "--refresh" => args.refresh = true,
            "--help" | "-h" => return Err(USAGE.to_string()),
            other => return Err(format!("unknown flag \"{other}\"\n{USAGE}")),
        }
    }
    if args.candidate.is_none() {
        return Err(format!("--candidate is required\n{USAGE}"));
    }
    if args.refresh && args.baseline.is_none() {
        return Err("--refresh needs --baseline (the path to rewrite)".to_string());
    }
    Ok(args)
}

fn load(path: &str) -> Result<BenchReport, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("cannot read {path}: {e}"))?;
    BenchReport::from_json(&text).map_err(|e| format!("{path}: {e}"))
}

fn main() -> ExitCode {
    let args = match parse_args() {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            return ExitCode::from(2);
        }
    };

    let cand_path = args.candidate.as_deref().expect("checked in parse_args");
    let cand = match load(cand_path) {
        Ok(r) => r,
        Err(msg) => {
            eprintln!("{msg}");
            return ExitCode::from(2);
        }
    };
    println!(
        "candidate {cand_path}: bench \"{}\", {} on {}, {} metric(s), {} mark(s)",
        cand.bench,
        cand.source_kind.name(),
        cand.arch,
        cand.metrics.len(),
        cand.marks.len()
    );

    let Some(base_path) = args.baseline.as_deref() else {
        println!("no --baseline: schema validation only, PASS");
        return ExitCode::SUCCESS;
    };
    let base = match load(base_path) {
        Ok(r) => r,
        Err(msg) => {
            eprintln!("{msg}");
            return ExitCode::from(2);
        }
    };

    let mut cfg = CompareConfig::default();
    if let Some(t) = args.tol {
        cfg.default_tol = t;
    }
    let cmp = compare(&base, &cand, &cfg);
    print!("{}", cmp.render());

    if args.refresh {
        let mut refreshed = cand.clone();
        refreshed.refreshed_unix = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .ok()
            .map(|d| d.as_secs());
        if refreshed.source_kind == SourceKind::Surrogate {
            eprintln!(
                "warning: refreshing {base_path} from a SURROGATE candidate \
                 (rates will stay structural-only)"
            );
        }
        return match std::fs::write(base_path, refreshed.to_json()) {
            Ok(()) => {
                println!(
                    "baseline {base_path} refreshed from {cand_path} \
                     (source_kind {}, arch {})",
                    refreshed.source_kind.name(),
                    refreshed.arch
                );
                ExitCode::SUCCESS
            }
            Err(e) => {
                eprintln!("cannot write {base_path}: {e}");
                ExitCode::from(2)
            }
        };
    }

    if cmp.passed() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
