//! `neonms-loadgen` — the open-loop wire load generator: drives a
//! running `neonms-serve` through the full protocol (HELLO / SUBMIT /
//! POLL / CANCEL / METRICS) from multiple weighted tenants over
//! multiple connections, then checks the coordinator's accounting
//! identity *across the wire* and emits a schema-v1 `BenchReport`
//! (`BENCH_net_soak.json`) for the `bench_compare` gate.
//!
//! ```text
//! neonms-loadgen [--addr HOST:PORT] [--tenants T] [--conns C]
//!                [--requests N] [--rate HZ] [--seed S]
//!                [--shutdown-server]
//! ```
//!
//! Arrival model is **open loop**: each connection schedules submit
//! `i` at `t0 + i/rate` regardless of completions (polling pending
//! work while it waits), so server backpressure shows up as
//! `RETRY_AFTER` responses — which are retried with the server's own
//! hint — rather than as a silently self-throttling client. Payloads
//! mix all three element kinds and a spread of sizes per tenant,
//! deterministically from `--seed`. Every 17th accepted request is
//! cancelled over the wire to exercise drop-to-cancel remotely.
//!
//! `NEONMS_BENCH_SMOKE=1` shrinks the run for CI; `NEONMS_BENCH_OUT`
//! redirects the report. With `--shutdown-server` the final act is a
//! `SHUTDOWN` frame, letting one CI step own the whole
//! server-then-gate lifecycle.

use neonms::bench::report::{self, BenchReport, Better, SourceKind};
use neonms::coordinator::ElemBuf;
use neonms::net::{NetError, PollOutcome, SubmitOutcome, WireClient};
use neonms::simd::KeyValue;
use neonms::testutil::Rng;
use std::process::ExitCode;
use std::time::{Duration, Instant};

const USAGE: &str = "usage: neonms-loadgen [--addr HOST:PORT] [--tenants T] [--conns C] \
                     [--requests N] [--rate HZ] [--seed S] [--shutdown-server]";

/// Give up on one submit after this many RETRY_AFTER rounds: the
/// open-loop schedule must not stall forever behind one hot spot.
const MAX_SUBMIT_ATTEMPTS: u32 = 8;
/// Cap on honoring the server's retry hint, so a pathological hint
/// cannot stall the arrival schedule.
const MAX_RETRY_SLEEP: Duration = Duration::from_millis(2);
/// Quiesce deadline: how long the control connection waits for the
/// server's per-tenant gauges to drain before declaring a wedge.
const QUIESCE_TIMEOUT: Duration = Duration::from_secs(15);

struct Flags(Vec<(String, Option<String>)>);

impl Flags {
    fn parse(args: &[String]) -> Flags {
        let mut out = Vec::new();
        let mut i = 0;
        while i < args.len() {
            if let Some(key) = args[i].strip_prefix("--") {
                let val = args.get(i + 1).filter(|v| !v.starts_with("--")).cloned();
                if val.is_some() {
                    i += 1;
                }
                out.push((key.to_string(), val));
            }
            i += 1;
        }
        Flags(out)
    }

    fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get_str_opt(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get_str_opt(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get_str_opt(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn get_str(&self, key: &str, default: &str) -> String {
        self.get_str_opt(key).unwrap_or_else(|| default.to_string())
    }

    fn get_str_opt(&self, key: &str) -> Option<String> {
        self.0.iter().find(|(k, _)| k == key).and_then(|(_, v)| v.clone())
    }

    fn has(&self, key: &str) -> bool {
        self.0.iter().any(|(k, _)| k == key)
    }
}

/// What one connection observed, summed into the report.
#[derive(Default)]
struct ConnStats {
    accepted: u64,
    completed: u64,
    cancelled: u64,
    failed: u64,
    retry_after: u64,
    gave_up: u64,
    unsorted: u64,
    net_errors: u64,
}

impl ConnStats {
    fn absorb(&mut self, other: &ConnStats) {
        self.accepted += other.accepted;
        self.completed += other.completed;
        self.cancelled += other.cancelled;
        self.failed += other.failed;
        self.retry_after += other.retry_after;
        self.gave_up += other.gave_up;
        self.unsorted += other.unsorted;
        self.net_errors += other.net_errors;
    }
}

fn gen_payload(rng: &mut Rng, tenant: usize, i: usize) -> ElemBuf {
    let len = [16usize, 64, 256, 1024][i % 4] + rng.below(32);
    match (tenant + i) % 3 {
        0 => ElemBuf::U32(rng.vec_u32(len)),
        1 => ElemBuf::U64(rng.vec_u64(len)),
        _ => ElemBuf::Pair((0..len).map(|j| KeyValue::new(rng.next_u32(), j as u32)).collect()),
    }
}

fn is_sorted(buf: &ElemBuf) -> bool {
    match buf {
        ElemBuf::U32(v) => v.windows(2).all(|w| w[0] <= w[1]),
        ElemBuf::U64(v) => v.windows(2).all(|w| w[0] <= w[1]),
        ElemBuf::Pair(v) => v.windows(2).all(|w| w[0] <= w[1]),
    }
}

/// Poll one outstanding request; drop it from the list if resolved.
fn poll_one(
    c: &mut WireClient,
    outstanding: &mut Vec<u64>,
    stats: &mut ConnStats,
) -> Result<(), NetError> {
    let Some(&id) = outstanding.first() else {
        return Ok(());
    };
    match c.poll(id)? {
        PollOutcome::Pending => {}
        PollOutcome::Done(data) => {
            if !is_sorted(&data) {
                stats.unsorted += 1;
            }
            stats.completed += 1;
            outstanding.remove(0);
        }
        PollOutcome::Failed(_) => {
            stats.failed += 1;
            outstanding.remove(0);
        }
    }
    Ok(())
}

/// One connection's whole life: handshake, open-loop submits with
/// hint-driven retries and interleaved polling, wire cancels, drain.
fn run_conn(
    addr: &str,
    tenant: usize,
    conn: usize,
    requests: usize,
    rate_hz: f64,
    seed: u64,
) -> Result<ConnStats, NetError> {
    let mut stats = ConnStats::default();
    let mut rng = Rng::new(seed ^ (tenant as u64).wrapping_mul(0x9E37_79B9) ^ conn as u64);
    let mut c = WireClient::connect(addr)?;
    // Weight scales with tenant index so the fair-share ledger has
    // something to arbitrate; burst stays at 1 MiB.
    c.hello(&format!("load-{tenant}"), 1 + tenant as u32, 1 << 20)?;
    let t0 = Instant::now();
    let mut outstanding: Vec<u64> = Vec::new();
    for i in 0..requests {
        // Open loop: submit i is due at t0 + i/rate, completions or
        // not. The wait is spent polling pending work.
        let due = t0 + Duration::from_secs_f64(i as f64 / rate_hz);
        while Instant::now() < due {
            poll_one(&mut c, &mut outstanding, &mut stats)?;
            std::thread::sleep(Duration::from_micros(100));
        }
        let data = gen_payload(&mut rng, tenant, i);
        let mut attempts = 0;
        let accepted_id = loop {
            attempts += 1;
            match c.submit(data.clone())? {
                SubmitOutcome::Accepted { id } => break Some(id),
                SubmitOutcome::RetryAfter { reason, hint } => {
                    stats.retry_after += 1;
                    if !reason.retryable() || attempts >= MAX_SUBMIT_ATTEMPTS {
                        stats.gave_up += 1;
                        break None;
                    }
                    std::thread::sleep(hint.min(MAX_RETRY_SLEEP));
                }
            }
        };
        if let Some(id) = accepted_id {
            stats.accepted += 1;
            if i % 17 == 13 {
                // Exercise drop-to-cancel over the wire. The server
                // acks regardless; whether the ledger lands on
                // `cancelled` or `completed` depends on the race with
                // the workers — both keep the identity balanced.
                c.cancel(id)?;
                stats.cancelled += 1;
            } else {
                outstanding.push(id);
            }
        }
    }
    // Drain: every outstanding request resolves one way or another.
    while let Some(&id) = outstanding.first() {
        match c.wait(id)? {
            Ok(data) => {
                if !is_sorted(&data) {
                    stats.unsorted += 1;
                }
                stats.completed += 1;
            }
            Err(_) => stats.failed += 1,
        }
        outstanding.remove(0);
    }
    Ok(stats)
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let flags = Flags::parse(&args);
    let smoke = report::smoke_from_env();

    let addr = flags.get_str("addr", "127.0.0.1:7071");
    let tenants = flags.get_usize("tenants", 3).max(1);
    let conns = flags.get_usize("conns", 2).max(1);
    let requests = flags.get_usize("requests", if smoke { 40 } else { 400 });
    let rate_hz = flags.get_f64("rate", 2000.0).max(1.0);
    let seed = flags.get_u64("seed", 0x10AD);
    if flags.has("help") {
        eprintln!("{USAGE}");
        return ExitCode::from(2);
    }

    println!(
        "loadgen: {tenants} tenants x {conns} conns x {requests} reqs \
         at {rate_hz}/s per conn against {addr} (seed {seed:#x}, smoke {smoke})"
    );

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for t in 0..tenants {
        for cx in 0..conns {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                run_conn(&addr, t, cx, requests, rate_hz, seed)
            }));
        }
    }
    let mut total = ConnStats::default();
    let mut conns_failed = 0u64;
    for h in handles {
        match h.join() {
            Ok(Ok(stats)) => total.absorb(&stats),
            Ok(Err(e)) => {
                eprintln!("loadgen: connection failed: {e}");
                total.net_errors += 1;
                conns_failed += 1;
            }
            Err(_) => {
                eprintln!("loadgen: connection thread panicked");
                conns_failed += 1;
            }
        }
    }
    let elapsed = t0.elapsed();

    // Control connection: wait for the server's per-tenant gauges to
    // drain, then pull the final snapshot the identity is checked on.
    let mut control = match WireClient::connect(addr.as_str()) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("loadgen: control connection failed: {e}");
            return ExitCode::FAILURE;
        }
    };
    let quiesce_start = Instant::now();
    let metrics = loop {
        let m = match control.metrics() {
            Ok(m) => m,
            Err(e) => {
                eprintln!("loadgen: METRICS failed: {e}");
                return ExitCode::FAILURE;
            }
        };
        let drained = m
            .tenants
            .iter()
            .filter(|t| t.name.starts_with("load-"))
            .all(|t| t.in_flight_bytes == 0 && t.queued_jobs == 0);
        if drained {
            break m;
        }
        if quiesce_start.elapsed() > QUIESCE_TIMEOUT {
            eprintln!("loadgen: server did not quiesce within {QUIESCE_TIMEOUT:?}");
            break m;
        }
        std::thread::sleep(Duration::from_millis(5));
    };

    // The PR 8 invariant, observed across the wire per tenant.
    let mut accounting_exact = true;
    let (mut acc, mut comp, mut canc, mut fail) = (0u64, 0u64, 0u64, 0u64);
    for t in metrics.tenants.iter().filter(|t| t.name.starts_with("load-")) {
        let balanced = t.accepted == t.completed + t.cancelled + t.failed
            && t.in_flight_bytes == 0
            && t.queued_jobs == 0;
        if !balanced {
            accounting_exact = false;
            eprintln!(
                "loadgen: tenant {} unbalanced: accepted {} vs {}+{}+{}, in-flight {} B, \
                 queued {}",
                t.name,
                t.accepted,
                t.completed,
                t.cancelled,
                t.failed,
                t.in_flight_bytes,
                t.queued_jobs
            );
        }
        acc += t.accepted;
        comp += t.completed;
        canc += t.cancelled;
        fail += t.failed;
    }
    let all_sorted = total.unsorted == 0;
    let no_wedged = conns_failed == 0 && total.net_errors == 0;
    let zero_proto_errors = metrics.net_protocol_errors == 0;
    let completion_rate = if acc > 0 { comp as f64 / acc as f64 } else { 0.0 };
    let jobs_per_s = comp as f64 / elapsed.as_secs_f64().max(1e-9);

    let source = if smoke {
        "neonms-loadgen over loopback TCP (smoke mode)"
    } else {
        "neonms-loadgen over loopback TCP"
    };
    let mut r = BenchReport::new("net_soak", source, SourceKind::Native, smoke);
    r.param("tenants", tenants as f64)
        .param("conns_per_tenant", conns as f64)
        .param("requests_per_conn", requests as f64)
        .param("rate_hz", rate_hz)
        .param("seed", seed as f64)
        .mark("accounting_exact", if accounting_exact { "true" } else { "false" })
        .mark("all_results_sorted", if all_sorted { "true" } else { "false" })
        .mark("no_wedged_connections", if no_wedged { "true" } else { "false" })
        .mark("zero_protocol_errors", if zero_proto_errors { "true" } else { "false" })
        .metric("completion_rate", report::round_dp(completion_rate, 4), "ratio", Better::Higher)
        .metric("jobs_per_s", report::round_dp(jobs_per_s, 1), "jobs/s", Better::Info)
        .note(
            "Open-loop wire soak: per-tenant accounting identity checked across the wire \
             (accepted == completed + cancelled + failed, zero residual in-flight bytes).",
        );
    for (what, value) in [
        ("accepted_total", acc),
        ("completed_total", comp),
        ("cancelled_total", canc),
        ("failed_total", fail),
        ("retry_after_responses", metrics.net_retry_after),
        ("frames_total", metrics.net_frames),
        ("submit_give_ups", total.gave_up),
    ] {
        r.metric(what, value as f64, "count", Better::Info);
    }
    report::write_report(&r, "NEONMS_BENCH_OUT", "../BENCH_net_soak.json");

    if flags.has("shutdown-server") {
        if let Err(e) = control.shutdown_server() {
            eprintln!("loadgen: SHUTDOWN failed: {e}");
            return ExitCode::FAILURE;
        }
        println!("loadgen: server acknowledged shutdown");
    }

    println!(
        "loadgen: {} accepted, {} completed, {} cancelled, {} failed over the wire; \
         {} retry-after responses, completion rate {:.3}",
        acc, comp, canc, fail, metrics.net_retry_after, completion_rate
    );
    if accounting_exact && all_sorted && no_wedged && zero_proto_errors {
        ExitCode::SUCCESS
    } else {
        eprintln!(
            "loadgen: FAILED marks: accounting_exact={accounting_exact} \
             all_results_sorted={all_sorted} no_wedged_connections={no_wedged} \
             zero_protocol_errors={zero_proto_errors}"
        );
        ExitCode::FAILURE
    }
}
