//! Timing core: warmup, N timed repetitions, robust statistics.

use std::time::{Duration, Instant};

/// Summary statistics over repetition times (seconds).
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub mean: f64,
    pub median: f64,
    pub min: f64,
    pub p95: f64,
    pub reps: usize,
}

impl Stats {
    /// Compute from raw per-rep durations.
    pub fn from_times(mut secs: Vec<f64>) -> Stats {
        assert!(!secs.is_empty());
        secs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = secs.len();
        let mean = secs.iter().sum::<f64>() / n as f64;
        Stats {
            mean,
            median: secs[n / 2],
            min: secs[0],
            p95: secs[(n * 95 / 100).min(n - 1)],
            reps: n,
        }
    }
}

/// One named measurement.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub stats: Stats,
    /// Elements processed per repetition (for rate units).
    pub elements: usize,
}

impl BenchResult {
    /// Million elements per second (Fig. 5's unit), from the median.
    pub fn me_per_sec(&self) -> f64 {
        self.elements as f64 / self.stats.median / 1e6
    }

    /// Elements per microsecond (Table 3's unit), from the median.
    pub fn elems_per_us(&self) -> f64 {
        self.elements as f64 / (self.stats.median * 1e6)
    }

    /// Median microseconds (Table 2's unit).
    pub fn median_us(&self) -> f64 {
        self.stats.median * 1e6
    }

    /// Items per second from the median — for service benches whose
    /// unit is a *request* rather than an element (`elements` then
    /// counts requests per repetition).
    pub fn per_sec(&self) -> f64 {
        self.elements as f64 / self.stats.median
    }
}

/// Run `f` `reps` times (after `warmup` untimed runs), timing each
/// repetition. `f` receives the repetition index and must do its own
/// per-rep setup *outside* the timed region via `setup`.
pub fn bench<S, F>(
    name: impl Into<String>,
    elements: usize,
    warmup: usize,
    reps: usize,
    mut setup: impl FnMut(usize) -> S,
    mut f: F,
) -> BenchResult
where
    F: FnMut(S),
{
    for w in 0..warmup {
        f(setup(w));
    }
    let mut times = Vec::with_capacity(reps);
    for r in 0..reps {
        let input = setup(r);
        let t0 = Instant::now();
        f(input);
        times.push(t0.elapsed().as_secs_f64());
    }
    BenchResult { name: name.into(), stats: Stats::from_times(times), elements }
}

/// Time a single closure once (coarse measurements).
pub fn time_once(f: impl FnOnce()) -> Duration {
    let t0 = Instant::now();
    f();
    t0.elapsed()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = Stats::from_times(vec![3.0, 1.0, 2.0, 10.0]);
        assert_eq!(s.min, 1.0);
        assert!(s.median <= s.p95);
        assert_eq!(s.reps, 4);
        assert!((s.mean - 4.0).abs() < 1e-9);
    }

    #[test]
    fn bench_counts_reps() {
        let mut calls = 0;
        let r = bench("t", 100, 2, 5, |_| (), |_| calls += 1);
        assert_eq!(calls, 7, "warmup + reps");
        assert_eq!(r.stats.reps, 5);
        assert!(r.me_per_sec() > 0.0);
    }

    #[test]
    fn units_consistent() {
        let r = BenchResult {
            name: "u".into(),
            stats: Stats::from_times(vec![0.001]), // 1 ms
            elements: 1000,
        };
        assert!((r.elems_per_us() - 1.0).abs() < 1e-9);
        assert!((r.me_per_sec() - 1.0).abs() < 1e-9);
        assert!((r.median_us() - 1000.0).abs() < 1e-9);
    }
}
