//! Seeded workload generators (the paper uses uniform random 32-bit
//! integers; we add the standard adversarial distributions for the
//! ablation benches).

use crate::testutil::Rng;

/// Named input distribution.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Workload {
    /// Uniform random u32 — the paper's §3 workload.
    Uniform,
    /// Keys drawn from a small alphabet (heavy duplicates).
    FewDups,
    /// Already sorted ascending.
    Presorted,
    /// Sorted descending.
    Reverse,
    /// Piecewise-ascending sawtooth (pre-existing runs).
    Sawtooth,
    /// Gaussian-ish (sum of uniforms) — clustered values.
    Clustered,
}

impl Workload {
    /// All distributions, for sweeps.
    pub fn all() -> [Workload; 6] {
        [
            Workload::Uniform,
            Workload::FewDups,
            Workload::Presorted,
            Workload::Reverse,
            Workload::Sawtooth,
            Workload::Clustered,
        ]
    }

    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            Workload::Uniform => "uniform",
            Workload::FewDups => "few-dups",
            Workload::Presorted => "presorted",
            Workload::Reverse => "reverse",
            Workload::Sawtooth => "sawtooth",
            Workload::Clustered => "clustered",
        }
    }

    /// Generate `n` elements with a fixed `seed`.
    pub fn generate(self, n: usize, seed: u64) -> Vec<u32> {
        let mut rng = Rng::new(seed);
        match self {
            Workload::Uniform => rng.vec_u32(n),
            Workload::FewDups => (0..n).map(|_| rng.next_u32() % 100).collect(),
            Workload::Presorted => (0..n as u32).collect(),
            Workload::Reverse => (0..n as u32).rev().collect(),
            Workload::Sawtooth => (0..n).map(|i| (i % 1024) as u32).collect(),
            Workload::Clustered => (0..n)
                .map(|_| {
                    (0..8).map(|_| rng.next_u32() >> 6).fold(0u32, u32::wrapping_add)
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        for w in Workload::all() {
            assert_eq!(w.generate(100, 7), w.generate(100, 7), "{}", w.name());
        }
    }

    #[test]
    fn lengths_and_shapes() {
        assert_eq!(Workload::Uniform.generate(1000, 1).len(), 1000);
        let pre = Workload::Presorted.generate(100, 1);
        assert!(pre.windows(2).all(|w| w[0] <= w[1]));
        let rev = Workload::Reverse.generate(100, 1);
        assert!(rev.windows(2).all(|w| w[0] >= w[1]));
        let dups = Workload::FewDups.generate(1000, 1);
        assert!(dups.iter().all(|&x| x < 100));
    }
}
