//! Table/figure builders: every evaluation artifact of the paper,
//! regenerated on this host. Shared by `cargo bench` targets and the
//! `neonms bench` CLI. Each function returns the formatted table and
//! the raw numbers so EXPERIMENTS.md can quote both.

use super::harness::{bench, BenchResult};
use super::report::{round_dp, BenchReport, Better, SourceKind};
use super::workloads::Workload;
use crate::baselines::{blocksort, introsort};
use crate::kernels::inregister::{table2_configs, ColumnNetwork, InRegisterSorter};
use crate::kernels::runmerge::{table3_impls, RunMerger};
use crate::kernels::{bitonic, hybrid, MergeImpl, MergeWidth};
use crate::regmachine;
use crate::simd::{KeyValue, Lane, VectorWidth};
use crate::sort::{NeonMergeSort, ParallelNeonMergeSort, SortConfig};
use crate::sortnet::gen;
use crate::testutil::Rng;

/// Paper §3 protocol for Table 2: 64K integers per repetition.
pub const TABLE2_N: usize = 64 * 1024;

/// Table 1: comparator counts per family and size (exact, no timing).
pub fn table1() -> String {
    let mut out = String::from(
        "Table 1: comparators per sorting network (paper: bitonic 6/24/80/240, \
         odd-even 5/19/63/191, asymmetric 5/19/55~60/135~185)\n",
    );
    out.push_str("|  n | Bitonic | Odd-even | Asymmetric (ours) | depth b/o/a |\n");
    for n in [4usize, 8, 16, 32] {
        let b = gen::bitonic_sort(n);
        let o = gen::odd_even_sort(n);
        let a = gen::best(n);
        out.push_str(&format!(
            "| {n:2} | {:7} | {:8} | {:17} | {}/{}/{} |\n",
            b.size(),
            o.size(),
            a.size(),
            b.depth(),
            o.depth(),
            a.depth()
        ));
    }
    out
}

/// Table 2 (measured): µs to bring every X elements of a 64K buffer
/// into sorted runs, per register configuration. `reps` ≈ the paper's
/// 100 iterations.
pub fn table2_measured(reps: usize) -> (String, Vec<(String, usize, f64)>) {
    let mut rows = Vec::new();
    let mut out = String::from(
        "Table 2: running time (µs) sorting every X elements of 64K u32 \
         (paper FT2000+: R=16* best at 65/121/183µs)\n| config | X | µs (median) |\n",
    );
    for (label, sorter) in table2_configs() {
        let r = sorter.r();
        for x in [r, 2 * r, 4 * r] {
            let res = bench_inregister(&sorter, x, reps);
            out.push_str(&format!("| {label:5} | {x:3} | {:9.1} |\n", res.median_us()));
            rows.push((label.clone(), x, res.median_us()));
        }
    }
    (out, rows)
}

fn bench_inregister(sorter: &InRegisterSorter, x: usize, reps: usize) -> BenchResult {
    let bl = sorter.block_len();
    let n = TABLE2_N / bl * bl;
    let base = Workload::Uniform.generate(n, 42);
    bench(
        format!("inreg R={} X={x}", sorter.r()),
        n,
        2,
        reps,
        |_| base.clone(),
        |mut data| {
            for block in data.chunks_exact_mut(bl) {
                sorter.sort_block_to_runs(block, x);
            }
            std::hint::black_box(&data);
        },
    )
}

/// Table 2 (modeled): the regmachine cycle model on the NEON geometry
/// (F=32) — reproduces the paper's *mechanism* including the R=32
/// spill cliff that x86's 16-register file shifts in the measured run.
pub fn table2_model() -> String {
    let mut out = String::from(
        "Table 2 (cost model, NEON F=32): cycles per 64-element-normalized \
         block; spills show the R=32 cliff\n| config | X | cycles | cycles/elem | spills |\n",
    );
    for (label, x, rep) in regmachine::model_table2(32) {
        out.push_str(&format!(
            "| {label:5} | {x:3} | {:6} | {:11.2} | {:6} |\n",
            rep.cycles,
            rep.cycles as f64 / x as f64, // per element at run length X… see EXPERIMENTS.md
            rep.spills
        ));
    }
    out
}

/// Table 3: merge speed (elements/µs) for 2×{8,16,32} merges,
/// vectorized vs hybrid (paper: hybrid wins at 8/16, loses at 32).
pub fn table3(reps: usize) -> (String, Vec<(String, usize, f64)>) {
    let mut rows = Vec::new();
    let mut out = String::from(
        "Table 3: merging speeds (elements/µs) — paper: vectorized 873.81/1024/897.75, \
         hybrid 1057.03/1092.27/840.21\n| impl | 2xK | elems/µs |\n",
    );
    // A large buffer of pre-sorted run pairs, merged pair by pair.
    for (name, imp) in table3_impls() {
        for k in [8usize, 16, 32] {
            let res = bench_merge_kernel(imp, k, reps);
            out.push_str(&format!("| {name:18} | {k:3} | {:8.1} |\n", res.elems_per_us()));
            rows.push((name.to_string(), k, res.elems_per_us()));
        }
    }
    // Streaming context: the same kernels inside the RunMerger loop
    // (two 128K-element runs) — the setting the full sort actually
    // runs them in, where the hybrid's off-critical-path serial half
    // pays off (EXPERIMENTS.md §Table 3 discussion).
    out.push_str("| --- streaming (two 128K runs) --- |\n");
    for (name, imp) in table3_impls() {
        for width in [MergeWidth::K8, MergeWidth::K16, MergeWidth::K32] {
            let k = width.k();
            let res = bench_merge_streaming(imp, width, reps);
            let label = format!("{name} (stream)");
            out.push_str(&format!("| {label:18} | {k:3} | {:8.1} |\n", res.elems_per_us()));
            rows.push((label, k, res.elems_per_us()));
        }
    }
    (out, rows)
}

fn bench_merge_streaming(imp: MergeImpl, width: MergeWidth, reps: usize) -> BenchResult {
    bench_merge_streaming_at(VectorWidth::V128, imp, width, 128 * 1024, reps)
}

fn bench_merge_streaming_at(
    vector: VectorWidth,
    imp: MergeImpl,
    width: MergeWidth,
    half: usize,
    reps: usize,
) -> BenchResult {
    let mut a = Workload::Uniform.generate(half, 11);
    let mut b = Workload::Uniform.generate(half, 12);
    a.sort_unstable();
    b.sort_unstable();
    let merger = RunMerger { width, imp, vector };
    let mut out_buf = vec![0u32; 2 * half];
    bench(
        format!("stream {} {imp:?} 2x{}", vector.name(), width.k()),
        2 * half,
        2,
        reps,
        |_| (),
        move |()| {
            merger.merge(&a, &b, &mut out_buf);
            std::hint::black_box(&out_buf);
        },
    )
}

fn bench_merge_kernel(imp: MergeImpl, k: usize, reps: usize) -> BenchResult {
    let pairs = (256 * 1024) / (2 * k); // ~256K elements per rep
    let n = pairs * 2 * k;
    // Pre-sort each K-run.
    let mut base = Workload::Uniform.generate(n, 7);
    for run in base.chunks_exact_mut(k) {
        run.sort_unstable();
    }
    let mut out_buf = vec![0u32; n];
    bench(
        format!("{imp:?} 2x{k}"),
        n,
        2,
        reps,
        move |_| base.clone(),
        move |data| {
            for (pair, out) in data.chunks_exact(2 * k).zip(out_buf.chunks_exact_mut(2 * k)) {
                let (a, b) = pair.split_at(k);
                match imp {
                    MergeImpl::Vectorized => bitonic::merge_slices(a, b, out),
                    MergeImpl::Hybrid => hybrid::merge_slices(a, b, out),
                    MergeImpl::Serial => crate::kernels::serial::merge_scalar(a, b, out),
                }
            }
            std::hint::black_box(&out_buf);
        },
    )
}

/// Fig. 5: sorting rate (ME/s) by size and method, single-thread and
/// parallel. `sizes` in elements; `reps` per point.
pub fn fig5(sizes: &[usize], threads: &[usize], reps: usize) -> (String, Vec<(String, usize, f64)>) {
    let mut rows = Vec::new();
    let mut out = String::from(
        "Fig. 5: sorting rate (ME/s), uniform u32 (paper: NEON-MS 23.5–70 ME/s, \
         3.8× std::sort, 2.1× block_sort; parallel 1.25× parallel block_sort)\n\
         | method | n | ME/s |\n",
    );
    for &n in sizes {
        let mut push = |name: String, res: BenchResult| {
            out.push_str(&format!("| {name:22} | {n:9} | {:7.2} |\n", res.me_per_sec()));
            rows.push((name, n, res.me_per_sec()));
        };
        let base = Workload::Uniform.generate(n, 99);
        let nms = NeonMergeSort::paper_default();
        push(
            "NEON-MS".into(),
            bench("neon-ms", n, 1, reps, |_| base.clone(), |mut d| nms.sort(&mut d)),
        );
        push(
            "std::sort (introsort)".into(),
            bench("introsort", n, 1, reps, |_| base.clone(), |mut d| introsort::sort(&mut d)),
        );
        push(
            "boost::block_sort".into(),
            bench("blocksort", n, 1, reps, |_| base.clone(), |mut d| blocksort::sort(&mut d)),
        );
        for &t in threads {
            if t <= 1 {
                continue;
            }
            let pnms = ParallelNeonMergeSort::with_threads(t);
            push(
                format!("NEON-MS T={t}"),
                bench("p-neon-ms", n, 1, reps, |_| base.clone(), |mut d| pnms.sort(&mut d)),
            );
            push(
                format!("block_sort T={t}"),
                bench("p-blocksort", n, 1, reps, |_| base.clone(), |mut d| {
                    blocksort::parallel_sort(&mut d, t)
                }),
            );
        }
    }
    (out, rows)
}

/// Ablation: merge-kernel width sweep on the full sort (2×4..2×32).
pub fn ablation_merge_width(n: usize, reps: usize) -> String {
    let mut out = String::from("Ablation: full-sort rate by merge width (hybrid)\n");
    let base = Workload::Uniform.generate(n, 5);
    for width in MergeWidth::all() {
        let s = NeonMergeSort::new(SortConfig { merge_width: width, ..Default::default() });
        let res = bench("w", n, 1, reps, |_| base.clone(), |mut d| s.sort(&mut d));
        out.push_str(&format!("| 2x{:2} | {:7.2} ME/s |\n", width.k(), res.me_per_sec()));
    }
    out
}

/// Ablation: column-network family on the full sort (Table 1 → end-to-end).
pub fn ablation_column_network(n: usize, reps: usize) -> String {
    let mut out = String::from("Ablation: full-sort rate by column network (R=16)\n");
    let base = Workload::Uniform.generate(n, 6);
    for (name, fam) in [
        ("bitonic", ColumnNetwork::Bitonic),
        ("odd-even", ColumnNetwork::OddEven),
        ("best(16*)", ColumnNetwork::Best),
    ] {
        let s = NeonMergeSort::new(SortConfig { column_network: fam, ..Default::default() });
        let res = bench("c", n, 1, reps, |_| base.clone(), |mut d| s.sort(&mut d));
        out.push_str(&format!("| {name:9} | {:7.2} ME/s |\n", res.me_per_sec()));
    }
    out
}

/// Ablation: workload distributions through the paper-default sort.
pub fn ablation_workloads(n: usize, reps: usize) -> String {
    let mut out = String::from("Ablation: full-sort rate by input distribution\n");
    let s = NeonMergeSort::paper_default();
    for w in Workload::all() {
        let base = w.generate(n, 8);
        let res = bench("d", n, 1, reps, |_| base.clone(), |mut d| s.sort(&mut d));
        out.push_str(&format!("| {:9} | {:7.2} ME/s |\n", w.name(), res.me_per_sec()));
    }
    out
}

/// One measured point of the width × K × impl sweep.
#[derive(Clone, Debug)]
pub struct WidthSweepPoint {
    /// Register width label (`"V128"` / `"V256"`).
    pub vector: &'static str,
    /// Elements per kernel side (K).
    pub k: usize,
    /// Kernel implementation label (`"Hybrid"` / `"Vectorized"`).
    pub imp: &'static str,
    /// Streaming 2-run merge rate, elements/µs (Table 3's unit).
    pub stream_elems_per_us: f64,
    /// Full-sort rate, ME/s (Fig. 5's unit).
    pub fullsort_me_per_s: f64,
}

/// The width sweep the ROADMAP's "wider lanes" item asked for:
/// every [`VectorWidth`] × [`MergeWidth`] × register-kernel
/// [`MergeImpl`], each measured two ways — the streaming 2-run merge
/// kernel in isolation and the full sort end-to-end. `K4 × V256` is
/// skipped (one 8-lane register cannot hold two 4-element runs; the
/// merger folds it to `V128`, which the sweep measures anyway).
pub fn width_sweep(n: usize, reps: usize) -> (String, Vec<WidthSweepPoint>) {
    let mut rows = Vec::new();
    let mut out = String::from(
        "Width sweep: register width × K × impl — streaming merge (elements/µs) \
         and full sort (ME/s)\n| vector | 2xK | impl | stream e/µs | sort ME/s |\n",
    );
    let base = Workload::Uniform.generate(n, 13);
    for vector in VectorWidth::all() {
        for width in MergeWidth::all() {
            if width.k() < vector.lanes() {
                continue; // K4 × V256 folds to V128 (measured above)
            }
            let impls = [("Hybrid", MergeImpl::Hybrid), ("Vectorized", MergeImpl::Vectorized)];
            for (label, imp) in impls {
                let stream = bench_merge_streaming_at(vector, imp, width, n / 2, reps);
                let s = NeonMergeSort::new(SortConfig {
                    merge_width: width,
                    merge_impl: imp,
                    vector_width: vector,
                    ..Default::default()
                });
                let full = bench("ws", n, 1, reps, |_| base.clone(), |mut d| s.sort(&mut d));
                out.push_str(&format!(
                    "| {:6} | {:3} | {label:10} | {:11.1} | {:9.2} |\n",
                    vector.name(),
                    width.k(),
                    stream.elems_per_us(),
                    full.me_per_sec()
                ));
                rows.push(WidthSweepPoint {
                    vector: vector.name(),
                    k: width.k(),
                    imp: label,
                    stream_elems_per_us: stream.elems_per_us(),
                    fullsort_me_per_s: full.me_per_sec(),
                });
            }
        }
    }
    (out, rows)
}

/// Build the `BENCH_width_sweep.json` [`BenchReport`]: every sweep
/// point as two metrics (streaming merge in elements/µs, full sort in
/// ME/s) plus the `best_fullsort` structural mark the docs quote.
/// Native runs stamp [`SourceKind::Native`]; the committed surrogate
/// baseline carries `Surrogate` and is compared structurally.
pub fn width_sweep_report(
    points: &[WidthSweepPoint],
    n: usize,
    reps: usize,
    source: &str,
    smoke: bool,
) -> BenchReport {
    let mut r = BenchReport::new("width_sweep", source, SourceKind::Native, smoke);
    r.param("n", n as f64).param("reps", reps as f64);
    let best = points
        .iter()
        .max_by(|a, b| a.fullsort_me_per_s.partial_cmp(&b.fullsort_me_per_s).unwrap());
    if let Some(b) = best {
        r.mark("best_fullsort", format!("{}/k{}/{}", b.vector, b.k, b.imp));
    }
    for p in points {
        let key = format!("{}/k{}/{}", p.vector, p.k, p.imp);
        r.metric(
            format!("stream_elems_per_us/{key}"),
            round_dp(p.stream_elems_per_us, 2),
            "elems/us",
            Better::Higher,
        );
        r.metric(
            format!("fullsort_me_per_s/{key}"),
            round_dp(p.fullsort_me_per_s, 3),
            "ME/s",
            Better::Higher,
        );
    }
    r
}

/// One measured point of the element-width sweep (element type ×
/// register width × K).
#[derive(Clone, Debug)]
pub struct ElemWidthPoint {
    /// Register width label (`"V128"` / `"V256"`); 8-byte elements run
    /// on the D-suffixed register types of the same physical width.
    pub vector: &'static str,
    /// Elements per kernel side (K).
    pub k: usize,
    /// Element label (`"u32"` / `"u64"` / `"pair"`).
    pub elem: &'static str,
    /// Bytes per element (4 or 8).
    pub elem_bytes: usize,
    /// Full-sort rate, millions of elements per second (Fig. 5's
    /// unit — halves mechanically when elements double in size).
    pub fullsort_me_per_s: f64,
    /// Full-sort rate in MB/s — the cross-width comparable unit.
    pub fullsort_mb_per_s: f64,
}

fn elem_sweep_rows<T: Lane>(
    elem: &'static str,
    base: &[T],
    reps: usize,
    out: &mut String,
    rows: &mut Vec<ElemWidthPoint>,
) {
    let n = base.len();
    for vector in VectorWidth::all() {
        for width in MergeWidth::all() {
            if width.clamp_for_bytes(T::BYTES) != width {
                continue; // over the register byte budget; runs as the clamped K
            }
            if width.k() < vector.lanes_for::<T>() {
                continue; // one register holds both runs; folds to the narrower width
            }
            let s = NeonMergeSort::new(SortConfig {
                merge_width: width,
                vector_width: vector,
                ..Default::default()
            });
            let full = bench("es", n, 1, reps, |_| base.to_vec(), |mut d| s.sort(&mut d));
            let me = full.me_per_sec();
            let mb = me * T::BYTES as f64;
            out.push_str(&format!(
                "| {:6} | {elem:4} | {:3} | {me:8.2} | {mb:8.1} |\n",
                vector.name(),
                width.k(),
            ));
            rows.push(ElemWidthPoint {
                vector: vector.name(),
                k: width.k(),
                elem,
                elem_bytes: T::BYTES,
                fullsort_me_per_s: me,
                fullsort_mb_per_s: mb,
            });
        }
    }
}

/// Element-width sweep: the full sort across element types — plain
/// `u32`, 64-bit `u64` keys, and packed [`KeyValue`] pairs — at every
/// register width × K the byte budget admits (K64 is 4-byte-only; its
/// 8-byte dispatch folds to K32, measured as such). All points use
/// the hybrid kernel (the paper default; the impl dimension is
/// [`width_sweep`]'s job). ME/s halves mechanically when elements
/// double, so the MB/s column is the one comparable across widths.
pub fn elem_width_sweep(n: usize, reps: usize) -> (String, Vec<ElemWidthPoint>) {
    let mut rows = Vec::new();
    let mut out = String::from(
        "Element-width sweep: element type × register width × K — full sort (hybrid)\n\
         | vector | elem | 2xK | ME/s | MB/s |\n",
    );
    let u32s = Workload::Uniform.generate(n, 21);
    let mut rng = Rng::new(22);
    let u64s = rng.vec_u64(n);
    // Pair keys use 24 bits so duplicate keys occur and the payload
    // tie-break half of the comparison is actually exercised.
    let pairs: Vec<KeyValue> =
        (0..n).map(|i| KeyValue::new(rng.next_u32() >> 8, i as u32)).collect();
    elem_sweep_rows("u32", &u32s, reps, &mut out, &mut rows);
    elem_sweep_rows("u64", &u64s, reps, &mut out, &mut rows);
    elem_sweep_rows("pair", &pairs, reps, &mut out, &mut rows);
    (out, rows)
}

/// Build the `BENCH_elem_width.json` [`BenchReport`]: per point the
/// ME/s and cross-width-comparable MB/s full-sort rates, plus
/// per-element `best_{elem}` / `best_{elem}_vector` marks — the
/// latter is the structural claim the docs' element-width story
/// rests on (wider registers win for 8-byte elements).
pub fn elem_width_report(
    points: &[ElemWidthPoint],
    n: usize,
    reps: usize,
    source: &str,
    smoke: bool,
) -> BenchReport {
    let mut r = BenchReport::new("elem_width", source, SourceKind::Native, smoke);
    r.param("n", n as f64).param("reps", reps as f64);
    for elem in ["u32", "u64", "pair"] {
        if let Some(b) = points
            .iter()
            .filter(|p| p.elem == elem)
            .max_by(|a, b| a.fullsort_mb_per_s.partial_cmp(&b.fullsort_mb_per_s).unwrap())
        {
            r.mark(format!("best_{elem}"), format!("{}/k{}", b.vector, b.k));
            r.mark(format!("best_{elem}_vector"), b.vector);
        }
    }
    for p in points {
        let key = format!("{}/{}/k{}", p.vector, p.elem, p.k);
        r.metric(
            format!("fullsort_me_per_s/{key}"),
            round_dp(p.fullsort_me_per_s, 3),
            "ME/s",
            Better::Higher,
        );
        r.metric(
            format!("fullsort_mb_per_s/{key}"),
            round_dp(p.fullsort_mb_per_s, 2),
            "MB/s",
            Better::Higher,
        );
    }
    r
}

/// Ablation: merge-path cooperative parallel merge vs one-thread-per-
/// pair (what the paper's load-balancing §3.2 claim is about).
pub fn ablation_parallel_merge(n: usize, t: usize, reps: usize) -> String {
    let mut out =
        String::from("Ablation: parallel merge strategy (cooperative merge-path vs pair-per-thread)\n");
    let base = Workload::Uniform.generate(n, 9);
    let coop = ParallelNeonMergeSort::with_threads(t);
    let res = bench("coop", n, 1, reps, |_| base.clone(), |mut d| coop.sort(&mut d));
    out.push_str(&format!("| merge-path coop T={t} | {:7.2} ME/s |\n", res.me_per_sec()));
    // Pair-per-thread: emulate with blocksort's parallel merge tree
    // (each pair merged by one thread) over NEON-MS-sorted chunks.
    let res2 = bench("pair", n, 1, reps, |_| base.clone(), |mut d| {
        let merger = RunMerger::paper_default();
        let chunk = n.div_ceil(t).next_multiple_of(64);
        let single = NeonMergeSort::paper_default();
        let chunks: Vec<&mut [u32]> = d.chunks_mut(chunk).collect();
        std::thread::scope(|s| {
            for c in chunks {
                s.spawn(|| single.sort(c));
            }
        });
        crate::runtime::merge_runs_for_bench(&mut d, chunk, &merger);
    });
    out.push_str(&format!("| pair-per-thread T={t} | {:7.2} ME/s |\n", res2.me_per_sec()));
    out
}
