//! Unified benchmark report schema — the one JSON shape every bench
//! target emits and every committed `BENCH_*.json` baseline uses.
//!
//! Before this module each bench hand-rolled its own JSON (three
//! different writers, and the paper-table benches wrote none), so the
//! artifacts CI uploaded could not be *compared* to anything. A
//! [`BenchReport`] normalizes all of them: provenance (`source`,
//! [`SourceKind`], `arch`, `smoke`), the run parameters that make two
//! reports comparable, gateable `metrics` with units and a
//! better-direction, string-valued `marks` for structural claims
//! ("the best full-sort config is hybrid 2×16"), and free-form
//! `notes` that are preserved but never gated (decision traces,
//! per-tier route tallies).
//!
//! serde is not in the offline vendor set, so the module carries its
//! own minimal JSON reader/writer ([`Json`]). The writer emits the
//! exact subset the reader accepts, and committed baselines are
//! round-tripped by a tier-1 test, so a truncated or hand-mangled
//! baseline fails `cargo test`, not just the CI gate.

use std::collections::HashSet;
use std::fmt::Write as _;

/// Version stamp for the on-disk schema; bump only with a migration
/// note in OPERATIONS.md.
pub const SCHEMA_VERSION: u64 = 1;

// ---------------------------------------------------------------------------
// Minimal JSON value + parser (no serde offline).
// ---------------------------------------------------------------------------

/// A parsed JSON value. Object fields keep their file order so a
/// parse → serialize round trip is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always held as `f64`).
    Num(f64),
    /// A string, with escapes decoded.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object; field order preserved.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters after the JSON document"));
        }
        Ok(v)
    }

    /// Object field lookup (`None` for non-objects or missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// The boolean payload, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// The field slice, if this is an object.
    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(fields) => Some(fields),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn err(&self, msg: &str) -> String {
        format!("{msg} at byte {}", self.pos)
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(fields)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        // Committed baselines carry non-ASCII (em
                        // dashes) as \uXXXX, and surrogate pairs are
                        // legal JSON — decode both.
                        let code = if (0xD800..0xDC00).contains(&hi) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired UTF-16 surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        match char::from_u32(code) {
                            Some(c) => out.push(c),
                            None => return Err(self.err("invalid \\u escape")),
                        }
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("unescaped control character")),
                Some(_) => {
                    // Plain run: copy whole UTF-8 sequences untouched.
                    // The scan only stops at ASCII bytes, which never
                    // occur inside a multi-byte sequence.
                    let start = self.pos - 1;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' || c < 0x20 {
                            break;
                        }
                        self.pos += 1;
                    }
                    let run = std::str::from_utf8(&self.b[start..self.pos])
                        .expect("input &str slice split at ASCII boundaries");
                    out.push_str(run);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).expect("ascii number bytes");
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("invalid number"))
    }
}

/// Escape a string into `out` as JSON string *contents* (no quotes).
pub fn escape_json(s: &str, out: &mut String) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
}

/// Format an `f64` the way the schema stores it: `Display` (shortest
/// round-trip), which never loses precision on re-parse.
fn fmt_num(v: f64) -> String {
    format!("{v}")
}

/// Round to `dp` decimal places — for report builders that want the
/// committed-artifact readability of the old writers (the comparator
/// works on any precision).
pub fn round_dp(v: f64, dp: i32) -> f64 {
    let m = 10f64.powi(dp);
    (v * m).round() / m
}

// ---------------------------------------------------------------------------
// The report schema.
// ---------------------------------------------------------------------------

/// How a report's numbers were produced — the provenance axis the
/// comparator keys on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SourceKind {
    /// Measured by the Rust benches on real hardware; rates are
    /// comparable to other native runs on the same `arch`/params.
    Native,
    /// Produced by a structural mirror or model (e.g. the Python
    /// ports the committed baselines come from); only structure and
    /// ordering are meaningful, never absolute rates.
    Surrogate,
}

impl SourceKind {
    /// The on-disk spelling.
    pub fn name(self) -> &'static str {
        match self {
            SourceKind::Native => "native",
            SourceKind::Surrogate => "surrogate",
        }
    }

    /// Parse the on-disk spelling.
    pub fn parse(s: &str) -> Result<SourceKind, String> {
        match s {
            "native" => Ok(SourceKind::Native),
            "surrogate" => Ok(SourceKind::Surrogate),
            other => Err(format!("unknown source_kind \"{other}\" (native|surrogate)")),
        }
    }
}

/// Which direction is an improvement for a metric.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Better {
    /// Bigger is better (rates); regression = drop beyond tolerance.
    Higher,
    /// Smaller is better (latency); regression = rise beyond tolerance.
    Lower,
    /// Informational only — recorded and structure-checked, never
    /// rate-gated (counts, ratios whose "good" direction is contextual).
    Info,
}

impl Better {
    /// The on-disk spelling.
    pub fn name(self) -> &'static str {
        match self {
            Better::Higher => "higher",
            Better::Lower => "lower",
            Better::Info => "info",
        }
    }

    /// Parse the on-disk spelling.
    pub fn parse(s: &str) -> Result<Better, String> {
        match s {
            "higher" => Ok(Better::Higher),
            "lower" => Ok(Better::Lower),
            "info" => Ok(Better::Info),
            other => Err(format!("unknown better \"{other}\" (higher|lower|info)")),
        }
    }
}

/// One gateable measurement.
#[derive(Clone, Debug, PartialEq)]
pub struct Metric {
    /// Stable identity across runs, e.g. `fullsort_me_per_s/V128/k16/Hybrid`.
    pub name: String,
    /// The measured value (finite).
    pub value: f64,
    /// Unit label; a unit change across runs is a schema break.
    pub unit: String,
    /// Gate direction.
    pub better: Better,
    /// Optional per-metric relative tolerance overriding the
    /// comparator default (e.g. `0.05` = ±5%).
    pub tol: Option<f64>,
}

/// The unified bench artifact: provenance + params + metrics + marks.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchReport {
    /// Bench identity (`width_sweep`, `fig5_overall`, ...).
    pub bench: String,
    /// `std::env::consts::ARCH` of the producing host.
    pub arch: String,
    /// The active SIMD backend the run's kernels lowered on
    /// (`"scalar"` / `"neon"` / `"sse4.2"` / `"avx2"`), stamped
    /// automatically by [`BenchReport::new`]. `None` only for
    /// pre-backend artifacts; the comparator treats two reports from
    /// different backends as rate-incomparable (see
    /// [`super::compare`]).
    pub backend: Option<String>,
    /// Free-text provenance (how/where the numbers were produced).
    pub source: String,
    /// Machine-readable provenance class.
    pub source_kind: SourceKind,
    /// Whether the run used CI smoke-mode workloads.
    pub smoke: bool,
    /// Unix seconds of the last `bench-compare --refresh`, if any.
    pub refreshed_unix: Option<u64>,
    /// Run parameters that must match for rates to be comparable
    /// (n, reps, job counts, ...). Order preserved.
    pub params: Vec<(String, f64)>,
    /// Structural claims as strings. A baseline mark may be a
    /// `|`-separated set of acceptable values ("up|hold"); candidates
    /// emit a single value.
    pub marks: Vec<(String, String)>,
    /// The gateable measurements.
    pub metrics: Vec<Metric>,
    /// Free-form context lines (decision traces, route tallies) —
    /// preserved, surfaced, never compared.
    pub notes: Vec<String>,
}

impl BenchReport {
    /// A new report for `bench` on this host's arch.
    pub fn new(bench: &str, source: &str, source_kind: SourceKind, smoke: bool) -> BenchReport {
        BenchReport {
            bench: bench.to_string(),
            arch: std::env::consts::ARCH.to_string(),
            backend: Some(crate::simd::backend::active().name().to_string()),
            source: source.to_string(),
            source_kind,
            smoke,
            refreshed_unix: None,
            params: Vec::new(),
            marks: Vec::new(),
            metrics: Vec::new(),
            notes: Vec::new(),
        }
    }

    /// Record a comparability parameter.
    pub fn param(&mut self, name: impl Into<String>, value: f64) -> &mut BenchReport {
        self.params.push((name.into(), value));
        self
    }

    /// Record a structural mark.
    pub fn mark(&mut self, name: impl Into<String>, value: impl Into<String>) -> &mut BenchReport {
        self.marks.push((name.into(), value.into()));
        self
    }

    /// Record a metric with the comparator's default tolerance.
    pub fn metric(
        &mut self,
        name: impl Into<String>,
        value: f64,
        unit: &str,
        better: Better,
    ) -> &mut BenchReport {
        self.metrics.push(Metric {
            name: name.into(),
            value,
            unit: unit.to_string(),
            better,
            tol: None,
        });
        self
    }

    /// Record a metric with a per-metric relative tolerance.
    pub fn metric_tol(
        &mut self,
        name: impl Into<String>,
        value: f64,
        unit: &str,
        better: Better,
        tol: f64,
    ) -> &mut BenchReport {
        self.metrics.push(Metric {
            name: name.into(),
            value,
            unit: unit.to_string(),
            better,
            tol: Some(tol),
        });
        self
    }

    /// Record a free-form note line.
    pub fn note(&mut self, line: impl Into<String>) -> &mut BenchReport {
        self.notes.push(line.into());
        self
    }

    /// Look up a metric by name.
    pub fn get_metric(&self, name: &str) -> Option<&Metric> {
        self.metrics.iter().find(|m| m.name == name)
    }

    /// Look up a mark by name.
    pub fn get_mark(&self, name: &str) -> Option<&str> {
        self.marks.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    /// Look up a param by name.
    pub fn get_param(&self, name: &str) -> Option<f64> {
        self.params.iter().find(|(k, _)| k == name).map(|(_, v)| *v)
    }

    /// Schema invariants the gate (and tier-1) rely on.
    pub fn validate(&self) -> Result<(), String> {
        if self.bench.is_empty() {
            return Err("empty bench name".into());
        }
        if self.arch.is_empty() {
            return Err("empty arch".into());
        }
        if let Some(b) = &self.backend {
            if b.is_empty() {
                return Err("empty backend (omit the field instead)".into());
            }
        }
        if self.source.is_empty() {
            return Err("empty source provenance".into());
        }
        let mut names = HashSet::new();
        for m in &self.metrics {
            if m.name.is_empty() {
                return Err("metric with an empty name".into());
            }
            if !names.insert(m.name.as_str()) {
                return Err(format!("duplicate metric name \"{}\"", m.name));
            }
            if !m.value.is_finite() {
                return Err(format!("metric \"{}\" has a non-finite value", m.name));
            }
            if let Some(t) = m.tol {
                if !t.is_finite() || t <= 0.0 {
                    return Err(format!("metric \"{}\" has a non-positive tolerance", m.name));
                }
            }
        }
        let mut keys = HashSet::new();
        for (k, v) in &self.params {
            if k.is_empty() || !keys.insert(k.as_str()) {
                return Err(format!("empty or duplicate param name \"{k}\""));
            }
            if !v.is_finite() {
                return Err(format!("param \"{k}\" has a non-finite value"));
            }
        }
        let mut keys = HashSet::new();
        for (k, v) in &self.marks {
            if k.is_empty() || !keys.insert(k.as_str()) {
                return Err(format!("empty or duplicate mark name \"{k}\""));
            }
            if v.is_empty() {
                return Err(format!("mark \"{k}\" has an empty value"));
            }
        }
        Ok(())
    }

    /// Serialize to the on-disk schema (2-space indent, field order
    /// fixed, metrics/params/marks in insertion order).
    pub fn to_json(&self) -> String {
        let mut o = String::from("{\n");
        let _ = writeln!(o, "  \"schema_version\": {SCHEMA_VERSION},");
        push_str_field(&mut o, "bench", &self.bench);
        push_str_field(&mut o, "arch", &self.arch);
        if let Some(b) = &self.backend {
            push_str_field(&mut o, "backend", b);
        }
        push_str_field(&mut o, "source", &self.source);
        push_str_field(&mut o, "source_kind", self.source_kind.name());
        let _ = writeln!(o, "  \"smoke\": {},", self.smoke);
        if let Some(t) = self.refreshed_unix {
            let _ = writeln!(o, "  \"refreshed_unix\": {t},");
        }
        o.push_str("  \"params\": {");
        for (i, (k, v)) in self.params.iter().enumerate() {
            let sep = if i == 0 { "" } else { ", " };
            let _ = write!(o, "{sep}\"{}\": {}", escaped(k), fmt_num(*v));
        }
        o.push_str("},\n");
        o.push_str("  \"marks\": {");
        for (i, (k, v)) in self.marks.iter().enumerate() {
            let sep = if i == 0 { "" } else { ", " };
            let _ = write!(o, "{sep}\"{}\": \"{}\"", escaped(k), escaped(v));
        }
        o.push_str("},\n");
        o.push_str("  \"metrics\": [");
        for (i, m) in self.metrics.iter().enumerate() {
            o.push_str(if i == 0 { "\n" } else { ",\n" });
            let _ = write!(
                o,
                "    {{\"name\": \"{}\", \"value\": {}, \"unit\": \"{}\", \"better\": \"{}\"",
                escaped(&m.name),
                fmt_num(m.value),
                escaped(&m.unit),
                m.better.name()
            );
            if let Some(t) = m.tol {
                let _ = write!(o, ", \"tol\": {}", fmt_num(t));
            }
            o.push('}');
        }
        o.push_str(if self.metrics.is_empty() { "],\n" } else { "\n  ],\n" });
        o.push_str("  \"notes\": [");
        for (i, n) in self.notes.iter().enumerate() {
            o.push_str(if i == 0 { "\n" } else { ",\n" });
            let _ = write!(o, "    \"{}\"", escaped(n));
        }
        o.push_str(if self.notes.is_empty() { "]\n" } else { "\n  ]\n" });
        o.push_str("}\n");
        o
    }

    /// Parse and validate a report from its on-disk form.
    pub fn from_json(text: &str) -> Result<BenchReport, String> {
        let root = Json::parse(text)?;
        if root.as_obj().is_none() {
            return Err("report root must be a JSON object".into());
        }
        let version = req(&root, "schema_version")?
            .as_f64()
            .ok_or("schema_version must be a number")?;
        if version != SCHEMA_VERSION as f64 {
            return Err(format!("unsupported schema_version {version} (want {SCHEMA_VERSION})"));
        }
        let report = BenchReport {
            bench: req_str(&root, "bench")?,
            arch: req_str(&root, "arch")?,
            backend: match root.get("backend") {
                None | Some(Json::Null) => None,
                Some(v) => {
                    Some(v.as_str().ok_or("backend must be a string")?.to_string())
                }
            },
            source: req_str(&root, "source")?,
            source_kind: SourceKind::parse(&req_str(&root, "source_kind")?)?,
            smoke: req(&root, "smoke")?.as_bool().ok_or("smoke must be a boolean")?,
            refreshed_unix: match root.get("refreshed_unix") {
                None | Some(Json::Null) => None,
                Some(v) => Some(v.as_f64().ok_or("refreshed_unix must be a number")? as u64),
            },
            params: req(&root, "params")?
                .as_obj()
                .ok_or("params must be an object")?
                .iter()
                .map(|(k, v)| {
                    let v =
                        v.as_f64().ok_or_else(|| format!("param \"{k}\" must be a number"))?;
                    Ok((k.clone(), v))
                })
                .collect::<Result<_, String>>()?,
            marks: req(&root, "marks")?
                .as_obj()
                .ok_or("marks must be an object")?
                .iter()
                .map(|(k, v)| {
                    let v =
                        v.as_str().ok_or_else(|| format!("mark \"{k}\" must be a string"))?;
                    Ok((k.clone(), v.to_string()))
                })
                .collect::<Result<_, String>>()?,
            metrics: req(&root, "metrics")?
                .as_arr()
                .ok_or("metrics must be an array")?
                .iter()
                .map(parse_metric)
                .collect::<Result<_, String>>()?,
            notes: match root.get("notes") {
                None => Vec::new(),
                Some(v) => v
                    .as_arr()
                    .ok_or("notes must be an array")?
                    .iter()
                    .map(|n| {
                        n.as_str()
                            .map(str::to_string)
                            .ok_or_else(|| "notes must be strings".to_string())
                    })
                    .collect::<Result<_, String>>()?,
            },
        };
        report.validate()?;
        Ok(report)
    }
}

fn escaped(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    escape_json(s, &mut out);
    out
}

fn push_str_field(o: &mut String, key: &str, val: &str) {
    let _ = writeln!(o, "  \"{key}\": \"{}\",", escaped(val));
}

fn req<'a>(root: &'a Json, key: &str) -> Result<&'a Json, String> {
    root.get(key).ok_or_else(|| format!("missing required field \"{key}\""))
}

fn req_str(root: &Json, key: &str) -> Result<String, String> {
    req(root, key)?
        .as_str()
        .map(str::to_string)
        .ok_or_else(|| format!("field \"{key}\" must be a string"))
}

fn parse_metric(v: &Json) -> Result<Metric, String> {
    let name = req_str(v, "name")?;
    let value = req(v, "value")?
        .as_f64()
        .ok_or_else(|| format!("metric \"{name}\" value must be a number"))?;
    let unit = req_str(v, "unit")?;
    let better = Better::parse(&req_str(v, "better")?)?;
    let tol = match v.get("tol") {
        None | Some(Json::Null) => None,
        Some(t) => {
            Some(t.as_f64().ok_or_else(|| format!("metric \"{name}\" tol must be a number"))?)
        }
    };
    Ok(Metric { name, value, unit, better, tol })
}

// ---------------------------------------------------------------------------
// Shared bench-binary conventions (env knobs, artifact writing).
// ---------------------------------------------------------------------------

/// The shared `NEONMS_BENCH_SMOKE=1` convention.
pub fn smoke_from_env() -> bool {
    std::env::var("NEONMS_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// The shared `NEONMS_BENCH_REPS` convention.
pub fn reps_from_env(default: usize) -> usize {
    std::env::var("NEONMS_BENCH_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// The `source` string every native bench run stamps.
pub fn source_label(smoke: bool) -> &'static str {
    if smoke {
        "cargo bench (smoke mode)"
    } else {
        "cargo bench"
    }
}

/// Metric-name slug: lowercase alphanumerics, runs of everything else
/// collapsed to a single `_` (`"NEON-MS T=2"` → `"neon_ms_t_2"`).
pub fn slug(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut gap = false;
    for c in s.chars() {
        if c.is_ascii_alphanumeric() {
            if gap && !out.is_empty() {
                out.push('_');
            }
            gap = false;
            out.push(c.to_ascii_lowercase());
        } else {
            gap = true;
        }
    }
    out
}

/// Write a validated report to `$env_var` (or `default_path`), with
/// the writers' shared stdout/stderr conventions. Panics on an
/// invalid report (a bench-builder bug, not an I/O condition).
pub fn write_report(report: &BenchReport, env_var: &str, default_path: &str) {
    if let Err(e) = report.validate() {
        panic!("bench {} built an invalid report: {e}", report.bench);
    }
    let out = std::env::var(env_var).unwrap_or_else(|_| default_path.to_string());
    match std::fs::write(&out, report.to_json()) {
        Ok(()) => println!("{} report recorded to {out}", report.bench),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rich_report() -> BenchReport {
        let source = "unit-test fixture \u{2014} em-dash provenance";
        let mut r = BenchReport::new("demo_bench", source, SourceKind::Native, true);
        r.param("n", 16384.0).param("reps", 2.0);
        r.mark("best_fullsort", "V128/k16/Hybrid");
        r.mark("direction", "up|hold");
        r.metric("rate/a", 123.25, "ME/s", Better::Higher);
        r.metric_tol("lat/\"quoted\"", 0.125, "us", Better::Lower, 0.05);
        r.metric("count/x", 42.0, "count", Better::Info);
        r.note("line one\nline two\ttabbed");
        r.refreshed_unix = Some(1_754_000_000);
        r
    }

    #[test]
    fn round_trip_preserves_everything() {
        let r = rich_report();
        let text = r.to_json();
        let back = BenchReport::from_json(&text).expect("round trip");
        assert_eq!(r, back);
        // And the serialization itself is stable.
        assert_eq!(text, back.to_json());
    }

    #[test]
    fn backend_stamp_round_trips_and_absent_field_stays_none() {
        let r = rich_report();
        // `new` stamps the process's active SIMD backend.
        let name = crate::simd::backend::active().name();
        assert_eq!(r.backend.as_deref(), Some(name));
        let text = r.to_json();
        let line = format!("  \"backend\": \"{name}\",\n");
        assert!(text.contains(&line), "backend line missing from:\n{text}");
        assert_eq!(BenchReport::from_json(&text).unwrap().backend, r.backend);

        // Pre-backend artifacts omit the field: parses to None and
        // re-serialization keeps it omitted (no round-trip drift).
        let legacy = text.replace(&line, "");
        let back = BenchReport::from_json(&legacy).unwrap();
        assert_eq!(back.backend, None);
        assert!(!back.to_json().contains("\"backend\""));

        // An explicit null means the same as absent.
        let nulled = text.replace(&line, "  \"backend\": null,\n");
        assert_eq!(BenchReport::from_json(&nulled).unwrap().backend, None);

        // Present-but-empty is a schema break, as is a non-string.
        let mut r = rich_report();
        r.backend = Some(String::new());
        assert!(r.validate().unwrap_err().contains("backend"));
        let bad = text.replace(&line, "  \"backend\": 7,\n");
        assert!(BenchReport::from_json(&bad).unwrap_err().contains("backend"));
    }

    #[test]
    fn parser_decodes_unicode_escapes_and_surrogate_pairs() {
        // \uXXXX escape (how committed baselines spell their em dash).
        let v = Json::parse(r#""a \u2014 b""#).unwrap();
        assert_eq!(v.as_str(), Some("a \u{2014} b"));
        // Literal multi-byte UTF-8 passes through untouched.
        let v = Json::parse("\"a \u{2014} b\"").unwrap();
        assert_eq!(v.as_str(), Some("a \u{2014} b"));
        // Surrogate pair escape decodes to one astral char.
        let v = Json::parse(r#""\uD83D\uDE00""#).unwrap();
        assert_eq!(v.as_str(), Some("\u{1F600}"));
        assert!(Json::parse(r#""\uD83D""#).is_err()); // unpaired high surrogate
        let v = Json::parse(r#""q\"w\\e\n\t""#).unwrap();
        assert_eq!(v.as_str(), Some("q\"w\\e\n\t"));
    }

    #[test]
    fn parser_handles_numbers_and_structure() {
        let v = Json::parse(r#"{"a": [1, -2.5, 1e3, 2.5E-2], "b": {"c": true, "d": null}}"#)
            .unwrap();
        let a = v.get("a").and_then(Json::as_arr).unwrap();
        let vals: Vec<f64> = a.iter().map(|x| x.as_f64().unwrap()).collect();
        assert_eq!(vals, vec![1.0, -2.5, 1000.0, 0.025]);
        assert_eq!(v.get("b").and_then(|b| b.get("c")).and_then(Json::as_bool), Some(true));
    }

    #[test]
    fn truncated_and_trailing_input_fail() {
        assert!(Json::parse("{\"a\": 1").is_err());
        assert!(Json::parse("{\"a\": 1} trailing").is_err());
        assert!(Json::parse("").is_err());
        let full = rich_report().to_json();
        let cut = &full[..full.len() / 2];
        assert!(BenchReport::from_json(cut).is_err());
    }

    #[test]
    fn validate_rejects_schema_breaks() {
        let mut r = rich_report();
        r.metric("rate/a", 1.0, "ME/s", Better::Higher); // duplicate name
        assert!(r.validate().unwrap_err().contains("duplicate metric"));

        let mut r = rich_report();
        r.metrics[0].value = f64::NAN;
        assert!(r.validate().unwrap_err().contains("non-finite"));

        let mut r = rich_report();
        r.source.clear();
        assert!(r.validate().unwrap_err().contains("source"));

        let text = rich_report().to_json().replace("\"schema_version\": 1", "\"schema_version\": 9");
        assert!(BenchReport::from_json(&text).unwrap_err().contains("schema_version"));

        let text = rich_report().to_json().replace("\"better\": \"higher\"", "\"better\": \"up\"");
        assert!(BenchReport::from_json(&text).unwrap_err().contains("better"));

        let text = rich_report().to_json().replace("  \"source_kind\": \"native\",\n", "");
        assert!(BenchReport::from_json(&text).unwrap_err().contains("source_kind"));
    }

    #[test]
    fn slug_flattens_labels() {
        assert_eq!(slug("NEON-MS T=2"), "neon_ms_t_2");
        assert_eq!(slug("unbatched (batch_max=1)"), "unbatched_batch_max_1");
        assert_eq!(slug("Hybrid Bitonic (stream)"), "hybrid_bitonic_stream");
        assert_eq!(slug("std::sort (introsort)"), "std_sort_introsort");
    }

    /// The committed baselines at the repo root must parse, validate,
    /// and round-trip through this reader — a hand-edited or
    /// truncated baseline fails tier-1, not just the CI gate.
    #[test]
    fn committed_baselines_parse_validate_and_round_trip() {
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
        let mut seen = Vec::new();
        for entry in std::fs::read_dir(&root).expect("repo root") {
            let path = entry.expect("dir entry").path();
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("").to_string();
            if !name.starts_with("BENCH_") || !name.ends_with(".json") {
                continue;
            }
            let text = std::fs::read_to_string(&path).expect("baseline readable");
            let report = BenchReport::from_json(&text)
                .unwrap_or_else(|e| panic!("{name} is not a valid BenchReport: {e}"));
            assert!(!report.metrics.is_empty(), "{name} has no metrics");
            let back = BenchReport::from_json(&report.to_json())
                .unwrap_or_else(|e| panic!("{name} does not round-trip: {e}"));
            assert_eq!(report, back, "{name} round-trip drift");
            seen.push(name);
        }
        for required in [
            "BENCH_width_sweep.json",
            "BENCH_elem_width.json",
            "BENCH_routing_adaptive.json",
            "BENCH_qos_fairness.json",
            "BENCH_net_soak.json",
        ] {
            assert!(seen.iter().any(|n| n == required), "missing committed baseline {required}");
        }
    }
}
