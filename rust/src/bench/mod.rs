//! Benchmark harness (criterion is not available offline, so we ship
//! our own): timing with warmup + repetition statistics, seeded
//! workload generators matching the paper's §3 protocol, and table
//! builders that print every table/figure of the evaluation in the
//! paper's own units — shared by `cargo bench` targets and the CLI.
//! The [`report`] module is the unified artifact schema every bench
//! emits, and [`compare`] is the tolerance-band regression gate the
//! `bench-compare` binary and CI run over those artifacts.

pub mod compare;
pub mod harness;
pub mod report;
pub mod tables;
pub mod workloads;

pub use harness::{bench, BenchResult, Stats};
pub use report::{BenchReport, Better, SourceKind};
pub use workloads::Workload;
