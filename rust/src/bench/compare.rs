//! Baseline comparison for [`BenchReport`] artifacts — the logic
//! behind the `bench-compare` binary and the CI regression gates.
//!
//! Two comparison modes, chosen from provenance:
//!
//! * **Rates** — both reports are [`SourceKind::Native`], same
//!   `arch`, same SIMD `backend` stamp, same `smoke` flag, and every
//!   baseline param matches.
//!   Gateable metrics get a relative tolerance band around the
//!   baseline value (per-metric `tol` or the configured default);
//!   [`Better::Higher`] metrics fail on drops below the band,
//!   [`Better::Lower`] on rises above it, [`Better::Info`] never.
//! * **Structural** — anything else (the committed Python-surrogate
//!   baselines, cross-arch runs, param mismatches). Absolute rates
//!   mean nothing across those boundaries, so only structure is
//!   gated: every baseline metric must exist in the candidate with
//!   the same unit, and every baseline mark must hold (a baseline
//!   mark may be a `|`-separated set of acceptable values —
//!   `"up|hold"` — and a candidate value must be the full set or a
//!   member of it).
//!
//! Structural checks also run in Rates mode; a rate band on a metric
//! the candidate no longer emits would otherwise vacuously pass.

use super::report::{BenchReport, Better, SourceKind};
use std::fmt::Write as _;

/// Comparator knobs.
#[derive(Clone, Debug)]
pub struct CompareConfig {
    /// Relative tolerance for metrics without their own `tol` —
    /// 0.20 means a Higher-is-better metric fails below 80% of the
    /// baseline. Wide by default: smoke-mode VMs are noisy, and the
    /// gate is for real regressions (≥30%), not jitter.
    pub default_tol: f64,
}

impl Default for CompareConfig {
    fn default() -> CompareConfig {
        CompareConfig { default_tol: 0.20 }
    }
}

/// Which comparison the provenance admitted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Native vs native, comparable run: tolerance-band rate gating.
    Rates,
    /// Structure and ordering only.
    Structural,
}

/// How much a finding matters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Severity {
    /// Gate failure (nonzero exit).
    Fail,
    /// Surprising but not gating (e.g. a mode downgrade).
    Warn,
    /// Context (skipped zero baselines, large improvements).
    Note,
}

/// One comparison observation.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Gate impact.
    pub severity: Severity,
    /// Operator-readable description.
    pub message: String,
}

/// The full result of one baseline/candidate diff.
#[derive(Clone, Debug)]
pub struct Comparison {
    /// The mode provenance admitted.
    pub mode: Mode,
    /// Everything observed, in check order.
    pub findings: Vec<Finding>,
    /// Metrics that got a tolerance band applied.
    pub rate_checked: usize,
    /// Structural presence/unit/mark checks performed.
    pub structural_checked: usize,
}

impl Comparison {
    /// Number of gate failures.
    pub fn failures(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Fail).count()
    }

    /// True when the candidate passes the gate.
    pub fn passed(&self) -> bool {
        self.failures() == 0
    }

    /// Multi-line operator summary (what `bench-compare` prints).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "mode: {} ({} rate-banded, {} structural checks)",
            match self.mode {
                Mode::Rates => "rates",
                Mode::Structural => "structural",
            },
            self.rate_checked,
            self.structural_checked
        );
        for f in &self.findings {
            let tag = match f.severity {
                Severity::Fail => "FAIL",
                Severity::Warn => "warn",
                Severity::Note => "note",
            };
            let _ = writeln!(out, "  {tag}: {}", f.message);
        }
        let _ = writeln!(
            out,
            "verdict: {}",
            if self.passed() {
                "PASS".to_string()
            } else {
                format!("FAIL ({} finding(s))", self.failures())
            }
        );
        out
    }
}

fn finding(severity: Severity, message: String) -> Finding {
    Finding { severity, message }
}

/// Does a candidate mark satisfy a baseline mark spec? The spec may
/// be a `|`-separated alternation; identity always satisfies (so a
/// baseline compared against itself passes).
fn mark_ok(spec: &str, value: &str) -> bool {
    spec == value || spec.split('|').any(|alt| alt == value)
}

/// Diff `cand` against `base`. Never panics; the result carries the
/// gate verdict.
pub fn compare(base: &BenchReport, cand: &BenchReport, cfg: &CompareConfig) -> Comparison {
    let mut findings = Vec::new();
    if base.bench != cand.bench {
        findings.push(finding(
            Severity::Fail,
            format!(
                "bench mismatch: baseline is \"{}\", candidate is \"{}\"",
                base.bench, cand.bench
            ),
        ));
        return Comparison {
            mode: Mode::Structural,
            findings,
            rate_checked: 0,
            structural_checked: 0,
        };
    }

    // Provenance → mode.
    let mut mode = Mode::Rates;
    if base.source_kind != SourceKind::Native || cand.source_kind != SourceKind::Native {
        mode = Mode::Structural;
        findings.push(finding(
            Severity::Note,
            format!(
                "provenance {}/{} (baseline/candidate): comparing structure only, not rates",
                base.source_kind.name(),
                cand.source_kind.name()
            ),
        ));
    } else {
        if base.arch != cand.arch {
            mode = Mode::Structural;
            findings.push(finding(
                Severity::Warn,
                format!(
                    "arch mismatch ({} vs {}): rates not comparable, structural mode",
                    base.arch, cand.arch
                ),
            ));
        }
        if base.backend != cand.backend {
            // A scalar run vs an avx2 run on the same host differ by
            // integer factors; rates across that line mean nothing.
            // An unrecorded side (pre-backend artifact) is treated as
            // unknown, which is also not "known equal".
            mode = Mode::Structural;
            findings.push(finding(
                Severity::Warn,
                format!(
                    "SIMD backend mismatch ({} vs {}): rates not comparable, structural mode",
                    base.backend.as_deref().unwrap_or("unrecorded"),
                    cand.backend.as_deref().unwrap_or("unrecorded")
                ),
            ));
        }
        if base.smoke != cand.smoke {
            mode = Mode::Structural;
            findings.push(finding(
                Severity::Warn,
                format!(
                    "smoke mismatch (baseline {} vs candidate {}): structural mode",
                    base.smoke, cand.smoke
                ),
            ));
        }
        for (name, bval) in &base.params {
            match cand.get_param(name) {
                Some(cval) if cval == *bval => {}
                Some(cval) => {
                    mode = Mode::Structural;
                    findings.push(finding(
                        Severity::Warn,
                        format!("param \"{name}\" differs ({bval} vs {cval}): structural mode"),
                    ));
                }
                None => {
                    mode = Mode::Structural;
                    findings.push(finding(
                        Severity::Warn,
                        format!("param \"{name}\" missing from candidate: structural mode"),
                    ));
                }
            }
        }
    }

    // Structural checks (both modes): baseline metrics must survive
    // with their units, baseline marks must hold.
    let mut structural_checked = 0;
    for m in &base.metrics {
        structural_checked += 1;
        match cand.get_metric(&m.name) {
            None => findings.push(finding(
                Severity::Fail,
                format!("metric \"{}\" missing from candidate", m.name),
            )),
            Some(c) if c.unit != m.unit => findings.push(finding(
                Severity::Fail,
                format!("metric \"{}\": unit changed \"{}\" -> \"{}\"", m.name, m.unit, c.unit),
            )),
            Some(_) => {}
        }
    }
    for (name, spec) in &base.marks {
        structural_checked += 1;
        match cand.get_mark(name) {
            None => findings.push(finding(
                Severity::Fail,
                format!("mark \"{name}\" missing from candidate"),
            )),
            Some(v) if !mark_ok(spec, v) => findings.push(finding(
                Severity::Fail,
                format!("mark \"{name}\": candidate \"{v}\" not in baseline's set \"{spec}\""),
            )),
            Some(_) => {}
        }
    }

    // Rate bands (Rates mode only).
    let mut rate_checked = 0;
    if mode == Mode::Rates {
        for m in &base.metrics {
            if m.better == Better::Info {
                continue;
            }
            let Some(c) = cand.get_metric(&m.name) else {
                continue; // already a structural failure
            };
            if m.value == 0.0 {
                findings.push(finding(
                    Severity::Note,
                    format!("metric \"{}\": baseline is 0, no relative band", m.name),
                ));
                continue;
            }
            rate_checked += 1;
            let tol = m.tol.unwrap_or(cfg.default_tol);
            let rel = (c.value - m.value) / m.value.abs();
            let regressed = match m.better {
                Better::Higher => rel < -tol,
                Better::Lower => rel > tol,
                Better::Info => false,
            };
            if regressed {
                findings.push(finding(
                    Severity::Fail,
                    format!(
                        "{}: {} -> {} {} ({:+.1}% vs the {:.0}% band, {})",
                        m.name,
                        m.value,
                        c.value,
                        m.unit,
                        rel * 100.0,
                        tol * 100.0,
                        match m.better {
                            Better::Higher => "higher is better",
                            _ => "lower is better",
                        }
                    ),
                ));
            } else if rel.abs() > tol {
                findings.push(finding(
                    Severity::Note,
                    format!(
                        "{}: improved {:+.1}% ({} -> {} {})",
                        m.name,
                        rel * 100.0,
                        m.value,
                        c.value,
                        m.unit
                    ),
                ));
            }
        }
    }

    Comparison { mode, findings, rate_checked, structural_checked }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::report::{BenchReport, Better, SourceKind};

    fn native(bench: &str) -> BenchReport {
        let mut r = BenchReport::new(bench, "unit-test native run", SourceKind::Native, true);
        r.param("n", 16384.0).param("reps", 2.0);
        r
    }

    fn cfg() -> CompareConfig {
        CompareConfig::default()
    }

    #[test]
    fn self_comparison_passes_in_rates_mode() {
        let mut r = native("demo");
        r.metric("rate/a", 100.0, "ME/s", Better::Higher);
        r.metric("lat/b", 50.0, "us", Better::Lower);
        r.mark("best", "V128/k16/Hybrid");
        let cmp = compare(&r, &r.clone(), &cfg());
        assert_eq!(cmp.mode, Mode::Rates);
        assert!(cmp.passed(), "{}", cmp.render());
        assert_eq!(cmp.rate_checked, 2);
    }

    #[test]
    fn thirty_percent_regression_fails_both_directions() {
        let mut base = native("demo");
        base.metric("rate/a", 100.0, "ME/s", Better::Higher);
        base.metric("lat/b", 100.0, "us", Better::Lower);

        let mut cand = native("demo");
        cand.metric("rate/a", 70.0, "ME/s", Better::Higher); // -30% on higher-is-better
        cand.metric("lat/b", 100.0, "us", Better::Lower);
        let cmp = compare(&base, &cand, &cfg());
        assert_eq!(cmp.failures(), 1, "{}", cmp.render());

        let mut cand = native("demo");
        cand.metric("rate/a", 100.0, "ME/s", Better::Higher);
        cand.metric("lat/b", 130.0, "us", Better::Lower); // +30% on lower-is-better
        let cmp = compare(&base, &cand, &cfg());
        assert_eq!(cmp.failures(), 1, "{}", cmp.render());
    }

    #[test]
    fn within_band_jitter_passes() {
        let mut base = native("demo");
        base.metric("rate/a", 100.0, "ME/s", Better::Higher);
        base.metric("lat/b", 100.0, "us", Better::Lower);
        let mut cand = native("demo");
        cand.metric("rate/a", 95.0, "ME/s", Better::Higher); // -5%
        cand.metric("lat/b", 105.0, "us", Better::Lower); // +5%
        let cmp = compare(&base, &cand, &cfg());
        assert!(cmp.passed(), "{}", cmp.render());
    }

    #[test]
    fn improvements_pass_with_a_note() {
        let mut base = native("demo");
        base.metric("rate/a", 100.0, "ME/s", Better::Higher);
        let mut cand = native("demo");
        cand.metric("rate/a", 150.0, "ME/s", Better::Higher);
        let cmp = compare(&base, &cand, &cfg());
        assert!(cmp.passed());
        assert!(cmp.findings.iter().any(|f| f.message.contains("improved")));
    }

    #[test]
    fn per_metric_tolerance_overrides_default() {
        // Tight band: 5% jitter fails at tol 0.01.
        let mut base = native("demo");
        base.metric_tol("rate/a", 100.0, "ME/s", Better::Higher, 0.01);
        let mut cand = native("demo");
        cand.metric("rate/a", 95.0, "ME/s", Better::Higher);
        assert_eq!(compare(&base, &cand, &cfg()).failures(), 1);

        // Loose band: a 30% drop passes at tol 0.5.
        let mut base = native("demo");
        base.metric_tol("rate/a", 100.0, "ME/s", Better::Higher, 0.5);
        let mut cand = native("demo");
        cand.metric("rate/a", 70.0, "ME/s", Better::Higher);
        assert!(compare(&base, &cand, &cfg()).passed());
    }

    #[test]
    fn info_metrics_never_gate() {
        let mut base = native("demo");
        base.metric("decisions", 10.0, "count", Better::Info);
        let mut cand = native("demo");
        cand.metric("decisions", 1.0, "count", Better::Info);
        let cmp = compare(&base, &cand, &cfg());
        assert!(cmp.passed(), "{}", cmp.render());
        assert_eq!(cmp.rate_checked, 0);
    }

    #[test]
    fn surrogate_baseline_downgrades_to_structural() {
        // The committed-baseline shape: Python-surrogate numbers vs a
        // native candidate 10× off — ordering is checked, rates are not.
        let mut base =
            BenchReport::new("demo", "python structural-port", SourceKind::Surrogate, false);
        base.metric("rate/a", 0.016, "ME/s", Better::Higher);
        base.mark("best_fullsort", "V128/k8/Hybrid|V128/k16/Hybrid");

        let mut cand = native("demo");
        cand.metric("rate/a", 45.0, "ME/s", Better::Higher); // ~2800× the surrogate
        cand.mark("best_fullsort", "V128/k16/Hybrid");
        let cmp = compare(&base, &cand, &cfg());
        assert_eq!(cmp.mode, Mode::Structural);
        assert!(cmp.passed(), "{}", cmp.render());

        // Structure still gates: a dropped metric fails...
        let mut missing = native("demo");
        missing.mark("best_fullsort", "V128/k16/Hybrid");
        assert!(!compare(&base, &missing, &cfg()).passed());

        // ...and a mark outside the alternation set fails.
        let mut wrong = native("demo");
        wrong.metric("rate/a", 45.0, "ME/s", Better::Higher);
        wrong.mark("best_fullsort", "V256/k32/Vectorized");
        assert!(!compare(&base, &wrong, &cfg()).passed());
    }

    #[test]
    fn surrogate_baseline_self_comparison_passes() {
        // `bench-compare --baseline X --candidate X` on a committed
        // surrogate, including an alternation-set mark: the candidate
        // carries the full set, which satisfies by identity.
        let mut base =
            BenchReport::new("demo", "python structural-port", SourceKind::Surrogate, false);
        base.metric("rate/a", 0.016, "ME/s", Better::Higher);
        base.mark("direction", "up|hold");
        let cmp = compare(&base, &base.clone(), &cfg());
        assert_eq!(cmp.mode, Mode::Structural);
        assert!(cmp.passed(), "{}", cmp.render());
    }

    #[test]
    fn native_arch_or_param_mismatch_downgrades() {
        let mut base = native("demo");
        base.metric("rate/a", 100.0, "ME/s", Better::Higher);

        let mut cand = native("demo");
        cand.arch = "fictional_isa".to_string();
        cand.metric("rate/a", 10.0, "ME/s", Better::Higher); // -90%, but cross-arch
        let cmp = compare(&base, &cand, &cfg());
        assert_eq!(cmp.mode, Mode::Structural);
        assert!(cmp.passed(), "{}", cmp.render());

        let mut cand = native("demo");
        cand.params[0].1 = 32768.0; // different n
        cand.metric("rate/a", 10.0, "ME/s", Better::Higher);
        let cmp = compare(&base, &cand, &cfg());
        assert_eq!(cmp.mode, Mode::Structural);
        assert!(cmp.passed(), "{}", cmp.render());
    }

    #[test]
    fn backend_mismatch_downgrades_to_structural_both_ways() {
        // A scalar baseline must never be rate-compared against a
        // SIMD candidate — a 4× "regression" would just be the lane
        // count — and vice versa.
        let mut base = native("demo");
        base.backend = Some("scalar".to_string());
        base.metric("rate/a", 100.0, "ME/s", Better::Higher);

        let mut cand = native("demo");
        cand.backend = Some("avx2".to_string());
        cand.metric("rate/a", 10.0, "ME/s", Better::Higher); // -90%, cross-backend
        let cmp = compare(&base, &cand, &cfg());
        assert_eq!(cmp.mode, Mode::Structural);
        assert_eq!(cmp.rate_checked, 0);
        assert!(cmp.passed(), "{}", cmp.render());
        assert!(cmp.findings.iter().any(|f| f.message.contains("backend mismatch")));

        // The reverse direction downgrades identically (an avx2
        // baseline against a scalar candidate is not a regression).
        let cmp = compare(&cand, &base, &cfg());
        assert_eq!(cmp.mode, Mode::Structural);
        assert!(cmp.passed(), "{}", cmp.render());

        // Same stamp on both sides stays in Rates mode and gates.
        let mut cand2 = native("demo");
        cand2.backend = Some("scalar".to_string());
        cand2.metric("rate/a", 10.0, "ME/s", Better::Higher);
        let cmp = compare(&base, &cand2, &cfg());
        assert_eq!(cmp.mode, Mode::Rates);
        assert_eq!(cmp.failures(), 1, "{}", cmp.render());
    }

    #[test]
    fn unrecorded_backend_is_not_known_equal() {
        // Pre-backend artifact vs a stamped run: unknown is not
        // "known same backend", so rates are off the table...
        let mut base = native("demo");
        base.backend = None;
        base.metric("rate/a", 100.0, "ME/s", Better::Higher);
        let mut cand = native("demo");
        cand.backend = Some("neon".to_string());
        cand.metric("rate/a", 10.0, "ME/s", Better::Higher);
        let cmp = compare(&base, &cand, &cfg());
        assert_eq!(cmp.mode, Mode::Structural);
        assert!(cmp.findings.iter().any(|f| f.message.contains("unrecorded")));

        // ...but two pre-backend artifacts compare as before.
        let mut cand = native("demo");
        cand.backend = None;
        cand.metric("rate/a", 95.0, "ME/s", Better::Higher);
        let cmp = compare(&base, &cand, &cfg());
        assert_eq!(cmp.mode, Mode::Rates);
        assert!(cmp.passed(), "{}", cmp.render());
    }

    #[test]
    fn bench_name_mismatch_fails_immediately() {
        let base = native("demo");
        let cand = native("other");
        let cmp = compare(&base, &cand, &cfg());
        assert!(!cmp.passed());
        assert_eq!(cmp.structural_checked, 0);
    }

    #[test]
    fn unit_change_fails_even_in_rates_mode() {
        let mut base = native("demo");
        base.metric("rate/a", 100.0, "ME/s", Better::Higher);
        let mut cand = native("demo");
        cand.metric("rate/a", 100.0, "MB/s", Better::Higher);
        assert!(!compare(&base, &cand, &cfg()).passed());
    }

    #[test]
    fn zero_baseline_is_skipped_with_a_note() {
        let mut base = native("demo");
        base.metric("rate/a", 0.0, "ME/s", Better::Higher);
        let mut cand = native("demo");
        cand.metric("rate/a", 5.0, "ME/s", Better::Higher);
        let cmp = compare(&base, &cand, &cfg());
        assert!(cmp.passed());
        assert_eq!(cmp.rate_checked, 0);
        assert!(cmp.findings.iter().any(|f| f.message.contains("baseline is 0")));
    }

    #[test]
    fn candidate_may_emit_extra_metrics_and_marks() {
        let mut base = native("demo");
        base.metric("rate/a", 100.0, "ME/s", Better::Higher);
        let mut cand = native("demo");
        cand.metric("rate/a", 100.0, "ME/s", Better::Higher);
        cand.metric("rate/new", 7.0, "ME/s", Better::Higher);
        cand.mark("extra", "whatever");
        assert!(compare(&base, &cand, &cfg()).passed());
    }
}
