//! `neonms` CLI — the leader entrypoint.
//!
//! Subcommands (no clap offline; hand-rolled parsing):
//!
//! ```text
//! neonms sort [--n N] [--threads T] [--workload W]
//!             [--impl hybrid|vectorized|serial] [--width 4|8|16|32|64]
//!             [--vector 128|256] [--backend auto|scalar|neon|sse4.2|avx2]
//! neonms bench <table1|table2|table3|fig5|ablations|all> [--reps R] [--max-n N]
//! neonms verify-networks
//! neonms regmachine [--phys F]
//! neonms serve-demo [--requests N] [--tenants T] [--workers W]
//!                   [--shards S] [--batch-max B] [--fuse-cutoff F]
//!                   [--xla] [--adaptive] [--epoch J]
//!                   [--tenant-weights W1,W2,...] [--qos fair|fifo]
//!                   [--backend auto|scalar|neon|sse4.2|avx2]
//! ```
//!
//! `--backend` pins the SIMD backend the kernels lower on (`auto`,
//! the default, runs feature detection; `scalar` always works). The
//! `NEONMS_SIMD_BACKEND` environment variable is the process-wide
//! equivalent; the flag wins when both are set because it forces the
//! selection explicitly.
//!
//! `--adaptive` turns on online routing: the service re-derives the
//! tiny/fuse/parallel cutoffs and `batch_max` from live per-tier
//! throughput every `--epoch` completed jobs (default 256) and the
//! demo prints the decision trace and per-route observations.
//!
//! `--tenant-weights` assigns fair-share weights to the demo tenants
//! (CSV, cycled when shorter than `--tenants`; default all 1), and
//! `--qos fifo` switches admission/dequeue back to the pre-QoS global
//! FIFO baseline — the per-tenant table prints the share/credit
//! gauges and shed breakdown either way.

use neonms::bench::tables;
use neonms::bench::Workload;
use neonms::coordinator::{
    AdaptivePolicy, ClientConfig, CoordinatorConfig, QosPolicy, RoutingBounds, SortService,
};
use neonms::regmachine;
use neonms::sort::{NeonMergeSort, ParallelNeonMergeSort};
use neonms::sortnet::gen;
use std::time::Instant;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = Flags::parse(&args[args.len().min(1)..]);
    match cmd {
        "sort" => cmd_sort(&flags),
        "bench" => cmd_bench(args.get(1).map(String::as_str).unwrap_or("all"), &flags),
        "verify-networks" => cmd_verify(),
        "regmachine" => cmd_regmachine(&flags),
        "serve-demo" => cmd_serve(&flags),
        _ => {
            eprintln!(
                "usage: neonms <sort|bench|verify-networks|regmachine|serve-demo> [flags]\n\
                 see rust/src/main.rs header for flags"
            );
        }
    }
}

/// Minimal flag parser: `--key value` pairs and boolean `--key`.
struct Flags(Vec<(String, Option<String>)>);

impl Flags {
    fn parse(args: &[String]) -> Self {
        let mut out = Vec::new();
        let mut i = 0;
        while i < args.len() {
            if let Some(key) = args[i].strip_prefix("--") {
                let val = args.get(i + 1).filter(|v| !v.starts_with("--")).cloned();
                if val.is_some() {
                    i += 1;
                }
                out.push((key.to_string(), val));
            }
            i += 1;
        }
        Flags(out)
    }

    fn get_usize(&self, key: &str, default: usize) -> usize {
        self.0
            .iter()
            .find(|(k, _)| k == key)
            .and_then(|(_, v)| v.as_ref())
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    fn get_str(&self, key: &str, default: &str) -> String {
        self.0
            .iter()
            .find(|(k, _)| k == key)
            .and_then(|(_, v)| v.clone())
            .unwrap_or_else(|| default.to_string())
    }

    fn has(&self, key: &str) -> bool {
        self.0.iter().any(|(k, _)| k == key)
    }
}

/// `--backend` → [`SortConfig::backend`]. `auto` (the default) defers
/// to detection / `NEONMS_SIMD_BACKEND`; a named backend must parse
/// and be available on this CPU or the command exits with usage.
fn backend_flag(flags: &Flags) -> Option<neonms::simd::Backend> {
    let s = flags.get_str("backend", "auto");
    if s.trim().eq_ignore_ascii_case("auto") {
        return None;
    }
    match neonms::simd::Backend::parse(&s) {
        Some(b) if b.available() => Some(b),
        Some(b) => {
            eprintln!(
                "--backend {s}: `{}` is not available on this machine (target {}); \
                 `scalar` always is",
                b.name(),
                std::env::consts::ARCH
            );
            std::process::exit(2);
        }
        None => {
            eprintln!("--backend {s}: unknown SIMD backend (want auto|scalar|neon|sse4.2|avx2)");
            std::process::exit(2);
        }
    }
}

fn cmd_sort(flags: &Flags) {
    use neonms::kernels::{MergeImpl, MergeWidth};
    use neonms::simd::VectorWidth;
    use neonms::sort::SortConfig;
    let n = flags.get_usize("n", 1 << 20);
    let threads = flags.get_usize("threads", 1);
    let wname = flags.get_str("workload", "uniform");
    let workload = Workload::all()
        .into_iter()
        .find(|w| w.name() == wname)
        .unwrap_or(Workload::Uniform);
    let imp = match flags.get_str("impl", "hybrid").as_str() {
        "vectorized" => MergeImpl::Vectorized,
        "serial" => MergeImpl::Serial,
        _ => MergeImpl::Hybrid,
    };
    let width = match flags.get_usize("width", 8) {
        4 => MergeWidth::K4,
        16 => MergeWidth::K16,
        32 => MergeWidth::K32,
        64 => MergeWidth::K64,
        _ => MergeWidth::K8,
    };
    let vector = match flags.get_usize("vector", 128) {
        256 => VectorWidth::V256,
        _ => VectorWidth::V128,
    };
    let cfg = SortConfig {
        merge_impl: imp,
        merge_width: width,
        vector_width: vector,
        backend: backend_flag(flags),
        ..Default::default()
    };
    let mut data = workload.generate(n, 42);
    let t0 = Instant::now();
    if threads > 1 {
        ParallelNeonMergeSort::new(NeonMergeSort::new(cfg), threads).sort(&mut data);
    } else {
        NeonMergeSort::new(cfg).sort(&mut data);
    }
    let dt = t0.elapsed();
    assert!(data.windows(2).all(|w| w[0] <= w[1]), "output not sorted!");
    println!(
        "sorted {n} {} u32 in {:.3}s ({:.2} ME/s, T={threads}, backend={})",
        workload.name(),
        dt.as_secs_f64(),
        n as f64 / dt.as_secs_f64() / 1e6,
        neonms::simd::backend::active().name()
    );
}

fn cmd_bench(which: &str, flags: &Flags) {
    let reps = flags.get_usize("reps", 20);
    let max_n = flags.get_usize("max-n", 8 << 20);
    match which {
        "table1" => print!("{}", tables::table1()),
        "table2" => {
            print!("{}", tables::table2_measured(reps).0);
            print!("{}", tables::table2_model());
        }
        "table3" => print!("{}", tables::table3(reps).0),
        "fig5" => {
            let sizes = fig5_sizes(max_n);
            print!("{}", tables::fig5(&sizes, &[2, 4], reps.min(5)).0);
        }
        "ablations" => {
            print!("{}", tables::ablation_column_network(1 << 20, reps.min(10)));
            print!("{}", tables::ablation_merge_width(1 << 20, reps.min(10)));
            print!("{}", tables::ablation_workloads(1 << 20, reps.min(10)));
            print!("{}", tables::ablation_parallel_merge(4 << 20, 4, reps.min(5)));
        }
        "all" => {
            for t in ["table1", "table2", "table3", "fig5", "ablations"] {
                cmd_bench(t, flags);
                println!();
            }
        }
        other => eprintln!("unknown bench target {other}"),
    }
}

fn fig5_sizes(max_n: usize) -> Vec<usize> {
    let mut sizes = Vec::new();
    let mut n = 512 * 1024; // paper starts at 512K
    while n <= max_n {
        sizes.push(n);
        n *= 2;
    }
    sizes
}

fn cmd_verify() {
    for n in [2usize, 4, 8, 16] {
        for net in [gen::bitonic_sort(n), gen::odd_even_sort(n), gen::best(n)] {
            let ok = net.verify_zero_one();
            println!("{net}: zero-one {}", if ok { "OK" } else { "FAILED" });
            assert!(ok);
        }
    }
    for n in [4usize, 8, 16, 32, 64] {
        let m = gen::bitonic_merge(n);
        println!("{m}: bitonic-merge {}", if m.verify_bitonic_merge() { "OK" } else { "FAILED" });
    }
    println!("all networks verified");
}

fn cmd_regmachine(flags: &Flags) {
    let f = flags.get_usize("phys", 32);
    println!("register-file cost model, F={f} physical vector registers");
    println!("| config | X | cycles | spills | cmpswaps | shuffles |");
    for (label, x, rep) in regmachine::model_table2(f) {
        println!(
            "| {label:5} | {x:3} | {:6} | {:6} | {:8} | {:8} |",
            rep.cycles, rep.spills, rep.cmpswaps, rep.shuffles
        );
    }
}

fn cmd_serve(flags: &Flags) {
    let n_requests = flags.get_usize("requests", 200);
    let tenants = flags.get_usize("tenants", 4).max(1);
    let artifacts = flags
        .has("xla")
        .then(|| std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    let defaults = CoordinatorConfig::default();
    let adaptive = if flags.has("adaptive") {
        AdaptivePolicy::Adaptive {
            epoch_jobs: flags.get_usize("epoch", 256).max(1) as u64,
            bounds: RoutingBounds::default(),
        }
    } else {
        AdaptivePolicy::Off
    };
    // Fair-share weights, one per tenant (CSV cycled; default 1).
    let weights: Vec<u32> = flags
        .get_str("tenant-weights", "1")
        .split(',')
        .map(|w| w.trim().parse().unwrap_or(1).max(1))
        .collect();
    let qos = match flags.get_str("qos", "fair").as_str() {
        "fifo" => QosPolicy::Fifo,
        _ => QosPolicy::FairShare,
    };
    let cfg = CoordinatorConfig {
        workers: flags.get_usize("workers", defaults.workers),
        shards: flags.get_usize("shards", defaults.shards),
        batch_max: flags.get_usize("batch-max", defaults.batch_max),
        fuse_cutoff: flags.get_usize("fuse-cutoff", defaults.fuse_cutoff),
        xla_cutoff: flags.has("xla").then_some(4096),
        adaptive,
        qos,
        sort: neonms::sort::SortConfig {
            backend: backend_flag(flags),
            ..defaults.sort.clone()
        },
        ..defaults
    };
    let svc = SortService::start(cfg.clone(), artifacts).expect("service start");
    let initial_routing = svc.routing();
    println!(
        "service up ({} workers, {} shards, batch_max={}, xla={}, {} tenants, adaptive={}, \
         qos={:?}, backend={})",
        cfg.workers,
        cfg.shards,
        cfg.batch_max,
        svc.xla_enabled(),
        tenants,
        cfg.adaptive.is_on(),
        cfg.qos,
        svc.metrics().simd_backend
    );
    // One client per tenant, each submitting from its own thread
    // through the non-blocking handle API.
    let t0 = Instant::now();
    let total: usize = std::thread::scope(|s| {
        let joins: Vec<_> = (0..tenants)
            .map(|t| {
                let client = svc.client_with(
                    &format!("tenant-{t}"),
                    ClientConfig {
                        weight: weights[t % weights.len()],
                        ..Default::default()
                    },
                );
                let share = n_requests / tenants + usize::from(t < n_requests % tenants);
                s.spawn(move || {
                    let mut rng = neonms::testutil::Rng::new(7 + t as u64);
                    let handles: Vec<_> = (0..share)
                        .map(|i| {
                            let len = [32usize, 1000, 8192, 100_000][i % 4] + rng.below(64);
                            client.submit(rng.vec_u32(len))
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| h.wait().expect("response").len())
                        .sum::<usize>()
                })
            })
            .collect();
        joins.into_iter().map(|j| j.join().expect("tenant thread")).sum()
    });
    let dt = t0.elapsed();
    let m = svc.metrics();
    println!(
        "{n_requests} requests, {total} elements in {:.3}s ({:.2} ME/s)\n\
         routes: tiny={} single={} parallel={} xla={}\n\
         batching: batches={} batched_jobs={} occupancy={:.1} | steals={}\n\
         latency: mean {:.0}µs p50 {}µs p99 {}µs",
        dt.as_secs_f64(),
        total as f64 / dt.as_secs_f64() / 1e6,
        m.route_tiny,
        m.route_single,
        m.route_parallel,
        m.route_xla,
        m.batches,
        m.batched_jobs,
        m.batch_occupancy,
        m.steals,
        m.mean_latency_us,
        m.p50_us,
        m.p99_us
    );
    println!("per-tenant (share = weight fraction; credit > 0 = under fair share):");
    for t in &m.tenants {
        println!(
            "  {:10} w={:<2} share={:.2} credit={:<6} accepted={:<5} shed={:<4} \
             (over-share {} evicted {}) completed={:<5} p50 {}µs p99 {}µs",
            t.name,
            t.weight,
            t.share,
            t.credit_bytes,
            t.accepted,
            t.shed,
            t.shed_over_share,
            t.evicted,
            t.completed,
            t.p50_us,
            t.p99_us
        );
    }
    println!("per-route (service time):");
    for r in &m.routes {
        if r.jobs > 0 {
            println!(
                "  {:8} jobs={:<6} elements={:<9} {:8.1} e/µs p50 {}µs p99 {}µs",
                r.tier, r.jobs, r.elements, r.elems_per_us, r.p50_us, r.p99_us
            );
        }
    }
    if cfg.adaptive.is_on() {
        let fin = svc.routing();
        println!(
            "adaptive routing: tiny {}→{} fuse {}→{} parallel {}→{} batch_max {}→{}",
            initial_routing.tiny_cutoff,
            fin.tiny_cutoff,
            initial_routing.fuse_cutoff,
            fin.fuse_cutoff,
            initial_routing.parallel_cutoff,
            fin.parallel_cutoff,
            initial_routing.batch_max,
            fin.batch_max
        );
        let decisions = svc.decisions();
        if decisions.is_empty() {
            println!("  no confirmed cutoff moves (short run, or tiers already balanced)");
        }
        for d in decisions {
            println!(
                "  epoch {:3}: {} {} -> {} (lower tier {:.1} e/µs vs upper {:.1} e/µs)",
                d.epoch, d.param, d.from, d.to, d.lo_elems_per_us, d.hi_elems_per_us
            );
        }
    }
    svc.shutdown();
}
