//! Minimal property-testing and deterministic-random substrate.
//!
//! The offline vendor set has no `proptest`/`quickcheck`/`rand`, so we
//! provide our own: a fast splitmix/xorshift-style PRNG with fixed
//! seeding (tests are reproducible by construction) and a [`forall`]
//! runner that executes a property over many generated cases. Workload
//! generators for benches live in [`crate::bench::workloads`] and
//! build on the same [`Rng`].

/// SplitMix64-seeded xorshift128+ PRNG. Deterministic, fast, and good
/// enough for test-case generation and benchmark workloads (not
/// cryptographic).
#[derive(Clone, Debug)]
pub struct Rng {
    s0: u64,
    s1: u64,
}

impl Rng {
    /// Create from a seed; equal seeds give equal streams.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to expand the seed into two non-zero words.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s0 = next();
        let mut s1 = next();
        if s0 == 0 && s1 == 0 {
            s1 = 1;
        }
        Rng { s0, s1 }
    }

    /// Next raw 64-bit value (xorshift128+).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.s0;
        let y = self.s1;
        self.s0 = y;
        x ^= x << 23;
        self.s1 = x ^ y ^ (x >> 17) ^ (y >> 26);
        self.s1.wrapping_add(y)
    }

    /// Next 32-bit value.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Next signed 32-bit value.
    #[inline]
    pub fn next_i32(&mut self) -> i32 {
        self.next_u32() as i32
    }

    /// Next `f32` uniform in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 / (1u32 << 24) as f32
    }

    /// Uniform index in `[0, bound)`. `bound` must be non-zero.
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        (self.next_u64() % bound as u64) as usize
    }

    /// Uniform value in `[lo, hi)`.
    #[inline]
    pub fn range_u32(&mut self, lo: u32, hi: u32) -> u32 {
        debug_assert!(lo < hi);
        lo + (self.next_u64() % (hi - lo) as u64) as u32
    }

    /// A vector of `len` uniform `u32`s.
    pub fn vec_u32(&mut self, len: usize) -> Vec<u32> {
        (0..len).map(|_| self.next_u32()).collect()
    }

    /// A vector of `len` uniform `i32`s.
    pub fn vec_i32(&mut self, len: usize) -> Vec<i32> {
        (0..len).map(|_| self.next_i32()).collect()
    }

    /// A vector of `len` uniform `u64`s (full 64-bit range, so the
    /// 8-byte sort paths see high and low halves both varying).
    pub fn vec_u64(&mut self, len: usize) -> Vec<u64> {
        (0..len).map(|_| self.next_u64()).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, data: &mut [T]) {
        for i in (1..data.len()).rev() {
            data.swap(i, self.below(i + 1));
        }
    }
}

/// Run `prop` over `cases` generated cases with a per-case seeded RNG.
/// Failures are reproducible: case `k` uses `Rng::new(0xC0FFEE ^ k)`.
pub fn forall(cases: usize, mut prop: impl FnMut(&mut Rng)) {
    for k in 0..cases {
        let mut rng = Rng::new(0xC0FFEE ^ k as u64);
        prop(&mut rng);
    }
}

/// Like [`forall`] but the property receives the case index too —
/// handy for size ramps (`len = k`).
pub fn forall_indexed(cases: usize, mut prop: impl FnMut(usize, &mut Rng)) {
    for k in 0..cases {
        let mut rng = Rng::new(0xC0FFEE ^ k as u64);
        prop(k, &mut rng);
    }
}

/// Assert a slice is sorted (non-decreasing), with a useful message.
pub fn assert_sorted<T: PartialOrd + core::fmt::Debug>(data: &[T], ctx: &str) {
    for w in 0..data.len().saturating_sub(1) {
        assert!(
            data[w] <= data[w + 1],
            "{ctx}: not sorted at {w}: {:?} > {:?}",
            data[w],
            data[w + 1]
        );
    }
}

/// Assert `got` is a permutation of `want` (multiset equality) — the
/// "no element lost or invented" half of sorting correctness.
pub fn assert_permutation(got: &[u32], want: &[u32], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length changed");
    let mut a = got.to_vec();
    let mut b = want.to_vec();
    a.sort_unstable();
    b.sort_unstable();
    assert_eq!(a, b, "{ctx}: multiset differs");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_streams_differ_by_seed() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range() {
        let mut rng = Rng::new(7);
        for _ in 0..1000 {
            assert!(rng.below(10) < 10);
            let v = rng.range_u32(5, 8);
            assert!((5..8).contains(&v));
        }
    }

    #[test]
    fn next_f32_in_unit_interval() {
        let mut rng = Rng::new(9);
        for _ in 0..1000 {
            let f = rng.next_f32();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        assert_permutation(&v, &(0..100).collect::<Vec<_>>(), "shuffle");
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle moved something");
    }

    #[test]
    #[should_panic(expected = "not sorted")]
    fn assert_sorted_catches() {
        assert_sorted(&[1, 3, 2], "t");
    }

    #[test]
    #[should_panic(expected = "multiset differs")]
    fn assert_permutation_catches() {
        assert_permutation(&[1, 2, 2], &[1, 2, 3], "t");
    }
}
