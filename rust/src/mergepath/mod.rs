//! Merge Path partitioning (Odeh, Green, Mwassi et al. [10]) —
//! the substrate of the paper's multi-thread parallel merge (§2.1).
//!
//! Merging sorted `A` (len m) and `B` (len n) traces a monotone path
//! through an `m×n` grid. Cutting the path where it crosses the
//! diagonals `i + j = d_k` splits the merge into `p` pieces of *equal
//! output size*, each an independent sequential merge — perfect load
//! balance with no inter-thread communication ("each available thread
//! remains active", §3.2). The crossing point on each diagonal is
//! found by binary search on the *co-rank* condition, O(log min(m,n))
//! per cut.

use crate::simd::Lane;

/// One partition piece: merge `a[a_lo..a_hi]` with `b[b_lo..b_hi]`
/// into `out[out_lo..out_lo + out_len()]`.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct Segment {
    pub a_lo: usize,
    pub a_hi: usize,
    pub b_lo: usize,
    pub b_hi: usize,
    pub out_lo: usize,
}

impl Segment {
    /// Output elements this segment produces.
    pub fn out_len(&self) -> usize {
        (self.a_hi - self.a_lo) + (self.b_hi - self.b_lo)
    }
}

/// Co-rank: the split `(i, j)` with `i + j = d` such that merging
/// `a[..i]` and `b[..j]` yields exactly the first `d` output elements
/// of the stable merge (ties go to `A`). Binary search on `i` over the
/// feasible window.
pub fn corank<T: Lane>(d: usize, a: &[T], b: &[T]) -> (usize, usize) {
    debug_assert!(d <= a.len() + b.len());
    // Smallest i with ¬P(i), P(i) ≡ b[d-i-1] ≥ a[i] ("the stable path
    // still wants more of A"). P is monotone non-increasing in i, so
    // the answer is unique — and, being the stable-merge co-rank, it
    // is monotone in d (each extra output element extends exactly one
    // side).
    let mut lo = d.saturating_sub(b.len());
    let mut hi = d.min(a.len());
    while lo < hi {
        let i = lo + (hi - lo) / 2; // i < hi ≤ a.len(), so a[i] is valid
        let j = d - i;
        if j > 0 && b[j - 1] >= a[i] {
            lo = i + 1;
        } else {
            hi = i;
        }
    }
    (lo, d - lo)
}

/// Partition the merge of `a` and `b` into `p` segments of equal (±1)
/// output length. Returns exactly `p` segments covering the output
/// contiguously and the inputs disjointly.
pub fn partition<T: Lane>(a: &[T], b: &[T], p: usize) -> Vec<Segment> {
    assert!(p >= 1);
    let total = a.len() + b.len();
    let mut segs = Vec::with_capacity(p);
    let mut prev = (0usize, 0usize);
    let mut prev_d = 0usize;
    for k in 1..=p {
        let d = total * k / p;
        let cut = if k == p { (a.len(), b.len()) } else { corank(d, a, b) };
        segs.push(Segment {
            a_lo: prev.0,
            a_hi: cut.0,
            b_lo: prev.1,
            b_hi: cut.1,
            out_lo: prev_d,
        });
        prev = cut;
        prev_d = d;
    }
    segs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{forall, Rng};

    fn sorted(rng: &mut Rng, len: usize, modv: u32) -> Vec<u32> {
        let mut v: Vec<u32> = (0..len).map(|_| rng.next_u32() % modv).collect();
        v.sort_unstable();
        v
    }

    #[test]
    fn corank_prefix_property() {
        forall(300, |rng| {
            let (la, lb) = (rng.below(50), rng.below(50));
            let a = sorted(rng, la, 40);
            let b = sorted(rng, lb, 40);
            let total = a.len() + b.len();
            if total == 0 {
                return;
            }
            let d = rng.below(total + 1);
            let (i, j) = corank(d, &a, &b);
            assert_eq!(i + j, d);
            // Everything taken must be <= everything left behind.
            if i > 0 && j < b.len() {
                assert!(a[i - 1] <= b[j], "a[{}]={} > b[{}]={}", i - 1, a[i - 1], j, b[j]);
            }
            if j > 0 && i < a.len() {
                assert!(b[j - 1] <= a[i]);
            }
        });
    }

    #[test]
    fn corank_is_monotone_in_d() {
        forall(100, |rng| {
            let a = sorted(rng, 30, 20);
            let b = sorted(rng, 40, 20);
            let mut last = (0, 0);
            for d in 0..=70 {
                let c = corank(d, &a, &b);
                assert!(c.0 >= last.0 && c.1 >= last.1, "co-rank must be monotone");
                last = c;
            }
        });
    }

    #[test]
    fn partition_covers_disjoint_balanced() {
        forall(200, |rng| {
            let (la, lb) = (rng.below(200), rng.below(200));
            let a = sorted(rng, la, 50);
            let b = sorted(rng, lb, 50);
            let p = rng.below(8) + 1;
            let segs = partition(&a, &b, p);
            assert_eq!(segs.len(), p);
            let total = a.len() + b.len();
            let (mut ai, mut bi, mut oi) = (0, 0, 0);
            for s in &segs {
                assert_eq!(s.a_lo, ai);
                assert_eq!(s.b_lo, bi);
                assert_eq!(s.out_lo, oi);
                ai = s.a_hi;
                bi = s.b_hi;
                oi += s.out_len();
            }
            assert_eq!(ai, a.len());
            assert_eq!(bi, b.len());
            assert_eq!(oi, total);
            let (lo, hi) = (total / p, total.div_ceil(p));
            for s in &segs {
                assert!(
                    (lo..=hi).contains(&s.out_len()),
                    "segment {} unbalanced ({total}/{p})",
                    s.out_len()
                );
            }
        });
    }

    #[test]
    fn partitioned_merge_equals_full_merge() {
        use crate::kernels::serial::merge_scalar;
        forall(200, |rng| {
            let (la, lb) = (rng.below(300), rng.below(300));
            let a = sorted(rng, la, 64);
            let b = sorted(rng, lb, 64);
            let p = rng.below(6) + 1;
            let mut expect = vec![0u32; a.len() + b.len()];
            merge_scalar(&a, &b, &mut expect);
            let mut got = vec![0u32; a.len() + b.len()];
            for s in partition(&a, &b, p) {
                let end = s.out_lo + s.out_len();
                merge_scalar(&a[s.a_lo..s.a_hi], &b[s.b_lo..s.b_hi], &mut got[s.out_lo..end]);
            }
            assert_eq!(got, expect);
        });
    }

    #[test]
    fn partition_empty_inputs() {
        let a: Vec<u32> = vec![];
        let b: Vec<u32> = vec![];
        let segs = partition(&a, &b, 4);
        assert_eq!(segs.len(), 4);
        assert!(segs.iter().all(|s| s.out_len() == 0));
    }

    #[test]
    fn partition_heavy_duplicates() {
        let a = vec![7u32; 100];
        let b = vec![7u32; 100];
        for p in 1..9 {
            let segs = partition(&a, &b, p);
            let covered: usize = segs.iter().map(Segment::out_len).sum();
            assert_eq!(covered, 200);
        }
    }

    #[test]
    fn partition_extreme_skew() {
        // A entirely below B and vice versa.
        let a: Vec<u32> = (0..100).collect();
        let b: Vec<u32> = (1000..1100).collect();
        for p in [1usize, 3, 7] {
            for (x, y) in [(&a, &b), (&b, &a)] {
                let segs = partition(x, y, p);
                let covered: usize = segs.iter().map(Segment::out_len).sum();
                assert_eq!(covered, 200);
            }
        }
    }
}
