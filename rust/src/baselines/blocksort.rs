//! `boost::sort::block_indirect_sort`-style baseline (paper §3):
//! a merge sort whose auxiliary memory is bounded by
//! `block_size × threads` instead of `n` — the property the paper
//! credits for boost's strong small-scale parallel performance.
//!
//! Simplifications vs boost (documented in DESIGN.md): we keep the
//! bounded-buffer guarantee with a SymMerge (Kim–Kutzner) rotation
//! merge for runs larger than the buffer, rather than boost's block
//! permutation indirection; the asymptotics and memory profile match
//! (O(block_size) aux per worker, O(n·log²n) worst-case moves).

use super::introsort;
use crate::kernels::serial::merge_scalar;
use crate::simd::Lane;

/// Default block size (boost's default is ~1024 elements for 4-byte
/// keys).
pub const DEFAULT_BLOCK: usize = 1024;

/// Single-thread block sort with `block_size` elements of auxiliary
/// memory.
pub fn sort<T: Lane>(data: &mut [T]) {
    sort_with_block(data, DEFAULT_BLOCK);
}

/// Single-thread block sort, explicit block size.
pub fn sort_with_block<T: Lane>(data: &mut [T], block: usize) {
    assert!(block >= 2);
    let n = data.len();
    if n <= 1 {
        return;
    }
    // Phase 1: introsort each block.
    for chunk in data.chunks_mut(block) {
        introsort::sort(chunk);
    }
    // Phase 2: bottom-up merge with a bounded buffer.
    let mut aux: Vec<T> = vec![T::MIN_VALUE; block];
    let mut run = block;
    while run < n {
        let mut base = 0;
        while base + run < n {
            let end = (base + 2 * run).min(n);
            bounded_merge(&mut data[base..end], run, &mut aux);
            base = end;
        }
        run *= 2;
    }
}

/// Merge `data[..mid]` with `data[mid..]` in place using at most
/// `aux.len()` auxiliary elements.
fn bounded_merge<T: Lane>(data: &mut [T], mid: usize, aux: &mut [T]) {
    sym_merge(data, 0, mid, data.len(), aux);
}

/// Kim–Kutzner SymMerge with a buffered base case: when either side
/// fits in `aux`, finish with a plain buffered merge.
fn sym_merge<T: Lane>(data: &mut [T], a: usize, m: usize, b: usize, aux: &mut [T]) {
    if a >= m || m >= b {
        return;
    }
    let (left, right) = (m - a, b - m);
    if left <= aux.len() {
        return buffered_merge_left(&mut data[a..b], left, aux);
    }
    if right <= aux.len() {
        return buffered_merge_right(&mut data[a..b], left, aux);
    }
    let mid = (a + b) / 2;
    let n = mid + m;
    let (mut start, mut r) = if m > mid { (n - b, mid) } else { (a, m) };
    let p = n - 1;
    while start < r {
        let c = (start + r) / 2;
        if data[p - c] >= data[c] {
            start = c + 1;
        } else {
            r = c;
        }
    }
    let end = n - start;
    if start < m && m < end {
        rotate(&mut data[start..end], m - start);
    }
    sym_merge(data, a, start, mid, aux);
    sym_merge(data, mid, end, b, aux);
}

/// Copy the left run (≤ aux) out, then standard merge forward.
fn buffered_merge_left<T: Lane>(data: &mut [T], mid: usize, aux: &mut [T]) {
    let aux = &mut aux[..mid];
    aux.copy_from_slice(&data[..mid]);
    let (mut i, mut j, mut k) = (0usize, mid, 0usize);
    while i < mid && j < data.len() {
        if aux[i] <= data[j] {
            data[k] = aux[i];
            i += 1;
        } else {
            data[k] = data[j];
            j += 1;
        }
        k += 1;
    }
    while i < mid {
        data[k] = aux[i];
        i += 1;
        k += 1;
    }
}

/// Copy the right run (≤ aux) out, then merge backward.
fn buffered_merge_right<T: Lane>(data: &mut [T], mid: usize, aux: &mut [T]) {
    let rlen = data.len() - mid;
    let aux = &mut aux[..rlen];
    aux.copy_from_slice(&data[mid..]);
    let (mut i, mut j, mut k) = (mid, rlen, data.len());
    while i > 0 && j > 0 {
        k -= 1;
        if aux[j - 1] >= data[i - 1] {
            data[k] = aux[j - 1];
            j -= 1;
        } else {
            data[k] = data[i - 1];
            i -= 1;
        }
    }
    while j > 0 {
        k -= 1;
        j -= 1;
        data[k] = aux[j];
    }
}

/// Rotate left by `k` via triple reversal.
fn rotate<T: Lane>(data: &mut [T], k: usize) {
    data[..k].reverse();
    data[k..].reverse();
    data.reverse();
}

/// Parallel block sort: phase-1 block sorts and phase-2 pair merges
/// distributed over `threads` scoped threads, each with its own
/// `block`-element buffer (total aux = `block × threads`, boost's
/// memory profile).
pub fn parallel_sort<T: Lane>(data: &mut [T], threads: usize) {
    parallel_sort_with_block(data, threads, DEFAULT_BLOCK)
}

/// Parallel block sort with explicit block size.
pub fn parallel_sort_with_block<T: Lane>(data: &mut [T], threads: usize, block: usize) {
    let n = data.len();
    if threads <= 1 || n <= 2 * block {
        return sort_with_block(data, block);
    }
    // Phase 1: parallel block introsorts (per-thread stripes of
    // contiguous blocks — no shared state needed).
    {
        let nblocks = n.div_ceil(block);
        let per_stripe = nblocks.div_ceil(threads) * block;
        let stripes: Vec<&mut [T]> = data.chunks_mut(per_stripe).collect();
        std::thread::scope(|sc| {
            for stripe in stripes {
                sc.spawn(move || {
                    for b in stripe.chunks_mut(block) {
                        introsort::sort(b);
                    }
                });
            }
        });
    }
    // Phase 2: merge tree, one thread per pair, bounded aux each.
    let mut run = block;
    while run < n {
        let ranges: Vec<(usize, usize, usize)> = {
            let mut v = Vec::new();
            let mut base = 0;
            while base + run < n {
                let end = (base + 2 * run).min(n);
                v.push((base, base + run, end));
                base = end;
            }
            v
        };
        // Hand out disjoint slices.
        let mut rest: &mut [T] = data;
        let mut offset = 0usize;
        let mut jobs: Vec<(&mut [T], usize)> = Vec::new();
        for &(lo, mid, hi) in &ranges {
            let (skip, tail) = rest.split_at_mut(lo - offset);
            let _ = skip;
            let (seg, tail) = tail.split_at_mut(hi - lo);
            jobs.push((seg, mid - lo));
            rest = tail;
            offset = hi;
        }
        let per_chunk = jobs.len().div_ceil(threads).max(1);
        std::thread::scope(|sc| {
            for chunk in jobs.chunks_mut(per_chunk) {
                sc.spawn(move || {
                    let mut aux: Vec<T> = vec![T::MIN_VALUE; block];
                    for (seg, mid) in chunk.iter_mut() {
                        bounded_merge(seg, *mid, &mut aux);
                    }
                });
            }
        });
        run *= 2;
    }
}

/// Reference: unbounded-aux merge used in tests to cross-check the
/// bounded merges.
pub fn reference_merge<T: Lane>(a: &[T], b: &[T]) -> Vec<T> {
    let mut out = vec![T::MIN_VALUE; a.len() + b.len()];
    merge_scalar(a, b, &mut out);
    out
}
