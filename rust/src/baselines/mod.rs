//! The comparison systems of the paper's §3 evaluation, built from
//! scratch (no external crates):
//!
//! * [`introsort`] — a faithful reimplementation of libstdc++
//!   `std::sort`: median-of-3 quicksort with a `2·log2(n)` depth limit
//!   falling back to heapsort, insertion sort below 16 elements.
//! * [`blocksort`] — a boost `block_indirect_sort`-style merge sort
//!   with *bounded auxiliary memory* (`block_size` elements per
//!   worker) using rotation-based in-place merging when a run exceeds
//!   the buffer, plus a parallel version (`block_size × threads` aux —
//!   the paper's §3.2 note on boost's small-footprint advantage).
//! * [`RustStdSort`] — thin wrappers over `slice::sort_unstable`
//!   (pdqsort) as a sanity reference for the harness.

pub mod blocksort;
pub mod introsort;

/// Reference wrapper: rust's own pdqsort, used to sanity-check the
/// harness numbers (not a paper baseline).
pub struct RustStdSort;

impl RustStdSort {
    /// Sort via `slice::sort_unstable`.
    pub fn sort<T: Ord>(data: &mut [T]) {
        data.sort_unstable();
    }
}

#[cfg(test)]
mod tests;
