//! `std::sort` baseline: introsort as shipped in libstdc++ (the
//! paper's single-thread comparison, compiled with GCC 9.3 -O3).
//!
//! Structure mirrors `std::__sort`: quicksort with median-of-3 pivot
//! and a depth limit of `2·⌊log2(n)⌋`; on limit exhaustion the
//! partition falls back to heapsort; partitions below
//! [`INSERTION_THRESHOLD`] are left unsorted and fixed by one final
//! insertion-sort pass (libstdc++'s `__final_insertion_sort`).

use crate::simd::Lane;

/// libstdc++ `_S_threshold`.
pub const INSERTION_THRESHOLD: usize = 16;

/// Sort ascending, in place — the `std::sort` stand-in.
pub fn sort<T: Lane>(data: &mut [T]) {
    let n = data.len();
    if n <= 1 {
        return;
    }
    let depth_limit = 2 * (usize::BITS - 1 - n.leading_zeros()) as usize;
    introsort_loop(data, depth_limit);
    final_insertion_sort(data);
}

fn introsort_loop<T: Lane>(data: &mut [T], mut depth: usize) {
    let mut slice = data;
    while slice.len() > INSERTION_THRESHOLD {
        if depth == 0 {
            heapsort(slice);
            return;
        }
        depth -= 1;
        let p = partition_median3(slice);
        // Recurse into the smaller side, loop on the larger (bounded
        // stack, as libstdc++ does by recursing on [cut, last)).
        let (lo, hi) = slice.split_at_mut(p);
        let hi = &mut hi[1..];
        if lo.len() < hi.len() {
            introsort_loop(lo, depth);
            slice = hi;
        } else {
            introsort_loop(hi, depth);
            slice = lo;
        }
    }
}

/// Median-of-3 pivot selection + Hoare-style partition; returns the
/// pivot's final index.
fn partition_median3<T: Lane>(data: &mut [T]) -> usize {
    let n = data.len();
    let mid = n / 2;
    // Order first/mid/last, then use mid as pivot (moved to n-2).
    if data[mid] < data[0] {
        data.swap(mid, 0);
    }
    if data[n - 1] < data[0] {
        data.swap(n - 1, 0);
    }
    if data[n - 1] < data[mid] {
        data.swap(n - 1, mid);
    }
    data.swap(mid, n - 2);
    let pivot = data[n - 2];
    let (mut i, mut j) = (0usize, n - 2);
    loop {
        i += 1;
        while data[i] < pivot {
            i += 1;
        }
        j -= 1;
        while pivot < data[j] {
            j -= 1;
        }
        if i >= j {
            break;
        }
        data.swap(i, j);
    }
    data.swap(i, n - 2);
    i
}

/// Bottom-up heapsort (libstdc++ `__heap_select` + `__sort_heap`
/// equivalent).
pub fn heapsort<T: Lane>(data: &mut [T]) {
    let n = data.len();
    for i in (0..n / 2).rev() {
        sift_down(data, i, n);
    }
    for end in (1..n).rev() {
        data.swap(0, end);
        sift_down(data, 0, end);
    }
}

fn sift_down<T: Lane>(data: &mut [T], mut root: usize, end: usize) {
    loop {
        let mut child = 2 * root + 1;
        if child >= end {
            return;
        }
        if child + 1 < end && data[child] < data[child + 1] {
            child += 1;
        }
        if data[root] >= data[child] {
            return;
        }
        data.swap(root, child);
        root = child;
    }
}

/// One pass of insertion sort over the whole slice — cheap because
/// every element is within `INSERTION_THRESHOLD` of its final place.
fn final_insertion_sort<T: Lane>(data: &mut [T]) {
    for i in 1..data.len() {
        let v = data[i];
        let mut j = i;
        while j > 0 && data[j - 1] > v {
            data[j] = data[j - 1];
            j -= 1;
        }
        data[j] = v;
    }
}
