use super::{blocksort, introsort, RustStdSort};
use crate::testutil::{assert_permutation, assert_sorted, forall, forall_indexed, Rng};

fn oracle(data: &[u32]) -> Vec<u32> {
    let mut v = data.to_vec();
    v.sort_unstable();
    v
}

#[test]
fn introsort_random() {
    forall_indexed(100, |case, rng| {
        let len = case * 37 + rng.below(11);
        let data = rng.vec_u32(len);
        let mut v = data.clone();
        introsort::sort(&mut v);
        assert_eq!(v, oracle(&data), "len {len}");
    });
}

#[test]
fn introsort_adversarial() {
    let n = 20_000u32;
    let patterns: Vec<Vec<u32>> = vec![
        (0..n).collect(),
        (0..n).rev().collect(),
        vec![1; n as usize],
        (0..n).map(|x| x % 2).collect(),
        (0..n).map(|x| x % 1000).collect(),
        // Median-of-3 killer-ish: organ pipe.
        (0..n / 2).chain((0..n / 2).rev()).collect(),
    ];
    for data in patterns {
        let mut v = data.clone();
        introsort::sort(&mut v);
        assert_eq!(v, oracle(&data));
    }
}

#[test]
fn introsort_depth_limit_triggers_heapsort() {
    // A pattern engineered to produce bad pivots repeatedly still
    // sorts (heapsort fallback): many equal keys with a skew tail.
    let mut data: Vec<u32> = vec![0; 50_000];
    for (i, v) in data.iter_mut().enumerate() {
        *v = (i % 3) as u32;
    }
    data.extend(0..50_000u32);
    let mut v = data.clone();
    introsort::sort(&mut v);
    assert_eq!(v, oracle(&data));
}

#[test]
fn heapsort_direct() {
    forall(50, |rng| {
        let len = rng.below(2000);
        let data = rng.vec_u32(len);
        let mut v = data.clone();
        introsort::heapsort(&mut v);
        assert_eq!(v, oracle(&data));
    });
}

#[test]
fn introsort_floats() {
    let mut rng = Rng::new(2);
    let mut v: Vec<f32> = (0..10_000).map(|_| rng.next_f32() * 1e6 - 5e5).collect();
    introsort::sort(&mut v);
    assert_sorted(&v, "introsort f32");
}

#[test]
fn blocksort_random_various_blocks() {
    forall(60, |rng| {
        let len = rng.below(30_000);
        let block = [16usize, 64, 256, 1024][rng.below(4)];
        let data = rng.vec_u32(len);
        let mut v = data.clone();
        blocksort::sort_with_block(&mut v, block);
        assert_eq!(v, oracle(&data), "len {len} block {block}");
    });
}

#[test]
fn blocksort_exercises_symmerge_path() {
    // Runs much larger than the aux buffer force the rotation merge.
    let mut rng = Rng::new(77);
    let data = rng.vec_u32(40_000);
    let mut v = data.clone();
    blocksort::sort_with_block(&mut v, 16); // tiny buffer, deep symmerge
    assert_eq!(v, oracle(&data));
}

#[test]
fn blocksort_adversarial() {
    let n = 30_000u32;
    for data in [
        (0..n).rev().collect::<Vec<_>>(),
        vec![9; n as usize],
        (0..n).map(|x| x % 7).collect(),
    ] {
        let mut v = data.clone();
        blocksort::sort(&mut v);
        assert_eq!(v, oracle(&data));
    }
}

#[test]
fn blocksort_parallel_matches_serial() {
    forall(15, |rng| {
        let len = 3000 + rng.below(60_000);
        let data = rng.vec_u32(len);
        let mut expect = data.clone();
        blocksort::sort(&mut expect);
        for t in [2usize, 4, 7] {
            let mut v = data.clone();
            blocksort::parallel_sort(&mut v, t);
            assert_eq!(v, expect, "T={t} len={len}");
        }
    });
}

#[test]
fn blocksort_parallel_small_falls_back() {
    let mut rng = Rng::new(4);
    let data = rng.vec_u32(500);
    let mut v = data.clone();
    blocksort::parallel_sort(&mut v, 8);
    assert_eq!(v, oracle(&data));
}

#[test]
fn rust_std_sort_wrapper() {
    let mut rng = Rng::new(5);
    let data = rng.vec_u32(1000);
    let mut v = data.clone();
    RustStdSort::sort(&mut v);
    assert_eq!(v, oracle(&data));
    assert_permutation(&v, &data, "std");
}

#[test]
fn all_baselines_agree_with_neon_ms() {
    use crate::sort::NeonMergeSort;
    forall(20, |rng| {
        let data = rng.vec_u32(10_000);
        let expect = oracle(&data);
        let mut a = data.clone();
        introsort::sort(&mut a);
        let mut b = data.clone();
        blocksort::sort(&mut b);
        let mut c = data.clone();
        NeonMergeSort::paper_default().sort(&mut c);
        assert_eq!(a, expect);
        assert_eq!(b, expect);
        assert_eq!(c, expect);
    });
}
