//! The abstract machine: F physical vector registers, LRU spilling,
//! per-op-class cycle costs.

use super::program::{InRegisterProgram, Op};

/// Cycle costs per op class (latency-weighted throughput model).
#[derive(Clone, Copy, Debug)]
pub struct OpCosts {
    /// One vector comparator = vmin + vmax.
    pub cmpswap: u64,
    /// One permute-class op.
    pub shuffle: u64,
    /// Architectural load/store (program-mandated).
    pub mem: u64,
    /// Spill store + reload pair is `2 × spill` cycles.
    pub spill: u64,
}

impl OpCosts {
    /// FT2000+/NEON-flavored weights: min/max 2-cycle pair, shuffles 1,
    /// L1 access 4.
    pub fn neon_like() -> Self {
        OpCosts { cmpswap: 2, shuffle: 1, mem: 4, spill: 4 }
    }
}

/// Result of running a program on the machine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CostReport {
    /// Architectural (program) loads+stores.
    pub mem_ops: usize,
    /// Vector comparators executed.
    pub cmpswaps: usize,
    /// Shuffles executed.
    pub shuffles: usize,
    /// Spill events (each = one store + one later reload).
    pub spills: usize,
    /// Modeled total cycles.
    pub cycles: u64,
}

/// LRU register allocator over `f` physical registers.
pub struct Machine {
    f: usize,
    costs: OpCosts,
}

impl Machine {
    /// A machine with `f` physical vector registers.
    pub fn new(f: usize, costs: OpCosts) -> Self {
        assert!(f >= 4, "need at least 4 physical registers");
        Machine { f, costs }
    }

    /// Execute the trace, counting spills an LRU allocator would take.
    pub fn run(&self, prog: &InRegisterProgram) -> CostReport {
        let mut report =
            CostReport { mem_ops: 0, cmpswaps: 0, shuffles: 0, spills: 0, cycles: 0 };
        // resident[v] = Some(tick of last use); LRU by tick.
        let mut resident: Vec<Option<u64>> = vec![None; prog.vregs];
        let mut tick = 0u64;
        let mut live = 0usize;
        let mut touch = |v: usize,
                         resident: &mut Vec<Option<u64>>,
                         live: &mut usize,
                         report: &mut CostReport| {
            tick += 1;
            if resident[v].is_some() {
                resident[v] = Some(tick);
                return;
            }
            if *live == self.f {
                // Evict LRU (spill: store now, the victim reloads later).
                let victim = resident
                    .iter()
                    .enumerate()
                    .filter_map(|(i, t)| t.map(|t| (t, i)))
                    .min()
                    .map(|(_, i)| i)
                    .expect("live > 0");
                resident[victim] = None;
                *live -= 1;
                report.spills += 1;
                report.cycles += 2 * self.costs.spill;
            }
            resident[v] = Some(tick);
            *live += 1;
        };
        for op in &prog.ops {
            match *op {
                Op::Load(v) => {
                    touch(v as usize, &mut resident, &mut live, &mut report);
                    report.mem_ops += 1;
                    report.cycles += self.costs.mem;
                }
                Op::Store(v) => {
                    touch(v as usize, &mut resident, &mut live, &mut report);
                    report.mem_ops += 1;
                    report.cycles += self.costs.mem;
                }
                Op::CmpSwap(a, b) => {
                    touch(a as usize, &mut resident, &mut live, &mut report);
                    touch(b as usize, &mut resident, &mut live, &mut report);
                    report.cmpswaps += 1;
                    report.cycles += self.costs.cmpswap;
                }
                Op::Shuffle { dst, a, b } => {
                    touch(a as usize, &mut resident, &mut live, &mut report);
                    touch(b as usize, &mut resident, &mut live, &mut report);
                    touch(dst as usize, &mut resident, &mut live, &mut report);
                    report.shuffles += 1;
                    report.cycles += self.costs.shuffle;
                }
            }
        }
        report
    }
}
