use super::*;
use crate::kernels::inregister::ColumnNetwork;

#[test]
fn program_op_counts_match_structure() {
    // R=16 best, X=16 (column sort only): 16 loads, 16 stores,
    // 60 comparators, 4 tiles × 8 transpose shuffles.
    let p = InRegisterProgram::build(16, ColumnNetwork::Best, 16);
    let (l, s, c, sh) = p.op_counts();
    assert_eq!((l, s), (16, 16));
    assert_eq!(c, 60, "best-16 = 60 comparators");
    assert_eq!(sh, 32, "4 tiles × 8 shuffles");
    assert_eq!(p.vregs, 18);
}

#[test]
fn odd_even_vs_best_comparator_gap() {
    // The 16 vs 16* Table 2 gap is exactly the 63→60 comparator save.
    let oe = InRegisterProgram::build(16, ColumnNetwork::OddEven, 16);
    let best = InRegisterProgram::build(16, ColumnNetwork::Best, 16);
    assert_eq!(oe.op_counts().2 - best.op_counts().2, 3);
}

#[test]
fn row_merges_add_ops_with_x() {
    let x16 = InRegisterProgram::build(16, ColumnNetwork::Best, 16);
    let x32 = InRegisterProgram::build(16, ColumnNetwork::Best, 32);
    let x64 = InRegisterProgram::build(16, ColumnNetwork::Best, 64);
    assert!(x32.ops.len() > x16.ops.len());
    assert!(x64.ops.len() > x32.ops.len());
}

#[test]
fn no_spills_when_registers_fit() {
    // R=16 + 2 temps = 18 vregs fits F=32 (NEON) with zero spills.
    let rep = model_table2_cell(16, ColumnNetwork::Best, 64, 32);
    assert_eq!(rep.spills, 0, "paper's R=16 claim: no register-to-memory traffic");
    // R=8 on F=16 also fits.
    assert_eq!(model_table2_cell(8, ColumnNetwork::OddEven, 32, 16).spills, 0);
}

#[test]
fn r32_spills_on_neon_geometry() {
    // R=32 + temps = 34 vregs > 32 physical: the paper's "complexity"
    // cliff — spills appear exactly here.
    let rep = model_table2_cell(32, ColumnNetwork::OddEven, 128, 32);
    assert!(rep.spills > 0, "R=32 must spill on a 32-register file");
    // And R=16 on the x86 geometry (F=16) also spills a little,
    // which is why the measured Table 2 on this host shows the cliff
    // one row earlier than the paper's.
    let rep16 = model_table2_cell(16, ColumnNetwork::Best, 64, 16);
    assert!(rep16.spills > 0);
}

#[test]
fn cycles_monotone_in_pressure() {
    // Fewer physical registers never makes the model faster.
    let c32 = model_table2_cell(32, ColumnNetwork::OddEven, 128, 32).cycles;
    let c16 = model_table2_cell(32, ColumnNetwork::OddEven, 128, 16).cycles;
    let c8 = model_table2_cell(32, ColumnNetwork::OddEven, 128, 8).cycles;
    assert!(c32 <= c16 && c16 <= c8);
}

#[test]
fn table2_model_shape_matches_paper() {
    // The paper's key qualitative claims on the NEON geometry:
    let rows = model_table2(32);
    let get = |label: &str, x: usize| {
        rows.iter()
            .find(|(l, xx, _)| l == label && *xx == x)
            .map(|(_, _, r)| *r)
            .unwrap()
    };
    // (1) 16* beats 16 at every X (fewer comparators, same spills).
    for x in [16, 32, 64] {
        assert!(get("R=16*", x).cycles < get("R=16", x).cycles, "16* wins at X={x}");
    }
    // (2) bigger R sorts the same X cheaper per element *until* the
    // spill cliff: R=16 X=32 beats R=8 X=32 per-block… compare via
    // cycles per element sorted-to-X.
    let per_elem = |label: &str, r: usize, x: usize| {
        get(label, x).cycles as f64 / (4 * r) as f64
    };
    assert!(per_elem("R=16", 16, 32) < per_elem("R=8", 8, 32));
    // (3) R=32 pays spills; R=16* has none.
    assert!(get("R=32", 128).spills > 0);
    assert_eq!(get("R=16*", 64).spills, 0);
}

#[test]
fn machine_lru_is_deterministic() {
    let p = InRegisterProgram::build(32, ColumnNetwork::OddEven, 128);
    let m = Machine::new(16, OpCosts::neon_like());
    assert_eq!(m.run(&p), m.run(&p));
}

#[test]
#[should_panic(expected = "at least 4")]
fn machine_rejects_tiny_register_file() {
    Machine::new(2, OpCosts::neon_like());
}
