//! Lowering the in-register sort to a virtual-register op trace.

use crate::kernels::inregister::ColumnNetwork;
use crate::sortnet::gen;

/// One abstract vector op over virtual register ids.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Op {
    /// Load a vector from memory into `v`.
    Load(u16),
    /// Store `v` back to memory.
    Store(u16),
    /// Vector comparator: reads and writes both (one vmin + one vmax).
    CmpSwap(u16, u16),
    /// Shuffle reading `a`,`b`, writing `dst` (zip/uzp/trn/rev class).
    Shuffle { dst: u16, a: u16, b: u16 },
}

/// A lowered in-register sort: `R` data registers + shuffle temps.
#[derive(Clone, Debug)]
pub struct InRegisterProgram {
    /// Virtual registers used (R data + temps).
    pub vregs: usize,
    /// Op trace in execution order.
    pub ops: Vec<Op>,
    /// The paper's parameters, for reporting.
    pub r: usize,
    pub x: usize,
}

impl InRegisterProgram {
    /// Lower the four in-register phases (Fig. 2) for `r` registers,
    /// column network `family`, target run length `x ∈ {r, 2r, 4r}`.
    ///
    /// The op trace mirrors `kernels::inregister` exactly: same
    /// comparator sequence, same 4×4-tile transpose (8 shuffles + 2
    /// temps per tile), same bitonic row-merge structure (reversal
    /// shuffles, register-level cmpswaps, 2 intra-register stages of
    /// shuffle+cmpswap per register).
    pub fn build(r: usize, family: ColumnNetwork, x: usize) -> Self {
        assert!(r % 4 == 0 && (x == r || x == 2 * r || x == 4 * r));
        let net = match family {
            ColumnNetwork::Bitonic => gen::bitonic_sort(r),
            ColumnNetwork::OddEven => gen::odd_even_sort(r),
            ColumnNetwork::Best => gen::best(r),
        };
        let t0 = r as u16; // shuffle temps
        let t1 = r as u16 + 1;
        let mut ops = Vec::new();
        // 1. load
        for v in 0..r as u16 {
            ops.push(Op::Load(v));
        }
        // 2. column sort: one CmpSwap per comparator.
        for c in net.comparators() {
            ops.push(Op::CmpSwap(c.i, c.j));
        }
        // 3. transpose: R/4 base 4×4 transposes, 8 shuffles each
        //    (4 trn-stage + 4 zip-stage), two temps live throughout.
        for tile in 0..(r / 4) as u16 {
            let base = tile * 4;
            for k in 0..4u16 {
                // trn stage writes through t0/t1 alternately.
                let dst = if k % 2 == 0 { t0 } else { t1 };
                ops.push(Op::Shuffle { dst, a: base + (k / 2) * 2, b: base + (k / 2) * 2 + 1 });
            }
            for k in 0..4u16 {
                ops.push(Op::Shuffle { dst: base + k, a: t0, b: t1 });
            }
        }
        // 4. row merges: runs of r double until x.
        let per_run = r / 4; // registers per length-r run
        let mut run_regs = per_run;
        let mut run_len = r;
        while run_len < x {
            let mut base = 0u16;
            while (base as usize) < r {
                Self::emit_bitonic_merge(&mut ops, base, 2 * run_regs as u16, t0);
                base += 2 * run_regs as u16;
            }
            run_regs *= 2;
            run_len *= 2;
        }
        // 5. store
        for v in 0..r as u16 {
            ops.push(Op::Store(v));
        }
        InRegisterProgram { vregs: r + 2, ops, r, x }
    }

    /// Bitonic merge over `n` registers starting at `base` (second
    /// half pre-sorted ascending → reversal shuffles first), mirroring
    /// `kernels::bitonic::merge_sorted_regs`.
    fn emit_bitonic_merge(ops: &mut Vec<Op>, base: u16, n: u16, tmp: u16) {
        // Reverse second half: one rev-shuffle per register.
        for v in base + n / 2..base + n {
            ops.push(Op::Shuffle { dst: v, a: v, b: v });
        }
        // Register-level half-cleaner stages.
        let mut d = n / 2;
        while d >= 1 {
            let mut blk = base;
            while blk < base + n {
                for i in blk..blk + d {
                    ops.push(Op::CmpSwap(i, i + d));
                }
                blk += 2 * d;
            }
            d /= 2;
        }
        // Intra-register stages: 2 × (shuffle into tmp + cmpswap +
        // blend-shuffle) per register.
        for v in base..base + n {
            for _ in 0..2 {
                ops.push(Op::Shuffle { dst: tmp, a: v, b: v });
                ops.push(Op::CmpSwap(v, tmp));
                ops.push(Op::Shuffle { dst: v, a: v, b: tmp });
            }
        }
    }

    /// Count ops by class: `(loads, stores, cmpswaps, shuffles)`.
    pub fn op_counts(&self) -> (usize, usize, usize, usize) {
        let mut c = (0, 0, 0, 0);
        for op in &self.ops {
            match op {
                Op::Load(_) => c.0 += 1,
                Op::Store(_) => c.1 += 1,
                Op::CmpSwap(..) => c.2 += 1,
                Op::Shuffle { .. } => c.3 += 1,
            }
        }
        c
    }
}
