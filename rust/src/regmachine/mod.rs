//! Abstract register-file cost model (paper §2.2, Table 2's mechanism).
//!
//! The paper's R-sweep argument is about *register pressure*: an
//! in-register sort over R vector registers plus its shuffle/merge
//! temporaries must fit the architectural register file (32 on NEON)
//! or intermediate values spill to memory, and "the register-to-memory
//! access is always a big computation bottleneck". Our testbed is
//! x86-64 (16 XMM registers), so absolute spill points differ from
//! FT2000+; this module reproduces the paper's accounting analytically:
//! lower the in-register sort to a virtual-register program
//! ([`InRegisterProgram`]), execute it on an abstract machine with
//! `F` physical registers and an LRU allocator ([`Machine`]), and
//! report vector ops, shuffles, spills, and modeled cycles for any
//! (R, network, X, F) point — including the NEON F=32 geometry we
//! cannot measure.

mod machine;
mod program;

pub use machine::{CostReport, Machine, OpCosts};
pub use program::{InRegisterProgram, Op};

use crate::kernels::inregister::ColumnNetwork;

/// Model one Table 2 cell: in-register sort with `r` registers and
/// network `family` producing runs of `x`, on a machine with `f`
/// physical vector registers.
pub fn model_table2_cell(r: usize, family: ColumnNetwork, x: usize, f: usize) -> CostReport {
    let prog = InRegisterProgram::build(r, family, x);
    Machine::new(f, OpCosts::neon_like()).run(&prog)
}

/// The full Table 2 analog: rows (R, family) × columns X, on the NEON
/// geometry (F = 32). Returns (label, X, report) triples.
pub fn model_table2(f: usize) -> Vec<(String, usize, CostReport)> {
    let rows: [(&str, usize, ColumnNetwork); 5] = [
        ("R=4", 4, ColumnNetwork::OddEven),
        ("R=8", 8, ColumnNetwork::OddEven),
        ("R=16", 16, ColumnNetwork::OddEven),
        ("R=16*", 16, ColumnNetwork::Best),
        ("R=32", 32, ColumnNetwork::OddEven),
    ];
    let mut out = Vec::new();
    for (label, r, family) in rows {
        for x in [r, 2 * r, 4 * r] {
            out.push((label.to_string(), x, model_table2_cell(r, family, x, f)));
        }
    }
    out
}

#[cfg(test)]
mod tests;
