//! Lock-free service metrics: request counters per route and a
//! log-bucketed latency histogram (no external deps — atomics only).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Power-of-two latency buckets from 1 µs to ~67 s.
const BUCKETS: usize = 27;

/// Log-bucketed latency histogram.
#[derive(Default)]
pub struct LatencyHistogram {
    counts: [AtomicU64; BUCKETS],
    sum_us: AtomicU64,
    n: AtomicU64,
}

impl LatencyHistogram {
    /// Record one latency sample.
    pub fn record(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let bucket = (63 - us.leading_zeros() as usize).min(BUCKETS - 1);
        self.counts[bucket].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.n.fetch_add(1, Ordering::Relaxed);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.n.load(Ordering::Relaxed)
    }

    /// Mean latency in µs.
    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Approximate quantile (upper bucket bound), q in [0, 1].
    pub fn quantile_us(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = ((n as f64) * q).ceil() as u64;
        let mut acc = 0u64;
        for (b, c) in self.counts.iter().enumerate() {
            acc += c.load(Ordering::Relaxed);
            if acc >= target {
                return 1u64 << (b + 1);
            }
        }
        1u64 << BUCKETS
    }
}

/// All coordinator counters (shared via `Arc`).
#[derive(Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub elements: AtomicU64,
    pub route_tiny: AtomicU64,
    pub route_single: AtomicU64,
    pub route_parallel: AtomicU64,
    pub route_xla: AtomicU64,
    pub batches: AtomicU64,
    pub latency: LatencyHistogram,
}

/// Point-in-time copy for reporting.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub elements: u64,
    pub route_tiny: u64,
    pub route_single: u64,
    pub route_parallel: u64,
    pub route_xla: u64,
    pub batches: u64,
    pub mean_latency_us: f64,
    pub p50_us: u64,
    pub p99_us: u64,
}

impl Metrics {
    /// Capture a snapshot.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            elements: self.elements.load(Ordering::Relaxed),
            route_tiny: self.route_tiny.load(Ordering::Relaxed),
            route_single: self.route_single.load(Ordering::Relaxed),
            route_parallel: self.route_parallel.load(Ordering::Relaxed),
            route_xla: self.route_xla.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            mean_latency_us: self.latency.mean_us(),
            p50_us: self.latency.quantile_us(0.5),
            p99_us: self.latency.quantile_us(0.99),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = LatencyHistogram::default();
        for us in [1u64, 2, 4, 100, 1000, 10_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 6);
        assert!(h.mean_us() > 0.0);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.99));
        assert!(h.quantile_us(1.0) >= 10_000);
    }

    #[test]
    fn zero_samples() {
        let h = LatencyHistogram::default();
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.quantile_us(0.99), 0);
    }

    #[test]
    fn snapshot_roundtrip() {
        let m = Metrics::default();
        m.submitted.fetch_add(3, Ordering::Relaxed);
        m.completed.fetch_add(2, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.submitted, 3);
        assert_eq!(s.completed, 2);
    }
}
