//! Lock-free service metrics: request counters per route, per-tenant
//! accepted/shed/completed accounting plus the fair-share QoS gauges
//! (weight, share, credit, in-flight/queued occupancy), log-bucketed
//! latency histograms, and the per-tier observation grid the adaptive
//! router learns from (no external deps — atomics only).

use super::qos::{ClientConfig, QosState};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Power-of-two latency buckets from 1 µs to ~67 s.
const BUCKETS: usize = 27;

/// Log-bucketed latency histogram.
#[derive(Default)]
pub struct LatencyHistogram {
    counts: [AtomicU64; BUCKETS],
    sum_us: AtomicU64,
    n: AtomicU64,
}

impl LatencyHistogram {
    /// Record one latency sample.
    pub fn record(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let bucket = (63 - us.leading_zeros() as usize).min(BUCKETS - 1);
        self.counts[bucket].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.n.fetch_add(1, Ordering::Relaxed);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.n.load(Ordering::Relaxed)
    }

    /// Mean latency in µs.
    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Approximate quantile (upper bucket bound), q in [0, 1].
    ///
    /// Bucket `b` covers `[2^b, 2^(b+1))` µs; the top bucket collects
    /// every sample ≥ ~67 s (`2^26` µs) and has no finite upper edge,
    /// so the returned bound is clamped to that ceiling — this never
    /// reports more than `1 << 26` µs.
    pub fn quantile_us(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = ((n as f64) * q).ceil() as u64;
        let mut acc = 0u64;
        for (b, c) in self.counts.iter().enumerate() {
            acc += c.load(Ordering::Relaxed);
            if acc >= target {
                return 1u64 << (b + 1).min(BUCKETS - 1);
            }
        }
        // Counters may lag `n` under concurrent recording; fall back
        // to the top bucket's clamped bound rather than overshooting.
        1u64 << (BUCKETS - 1)
    }
}

/// Execution tiers the router can place a request on — the adaptive
/// tuner's observation axes. `Fused` is not a routing decision of its
/// own: it is where dynamically-batched Tiny/SingleThread jobs land,
/// observed separately so the tuner can compare fused against solo
/// execution when deriving `fuse_cutoff`/`batch_max`.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Tier {
    /// Branchless insertion sort (`Route::Tiny`).
    Tiny,
    /// Single-thread NEON-MS (`Route::SingleThread`).
    Single,
    /// Merge-path parallel NEON-MS (`Route::Parallel`).
    Parallel,
    /// XLA offload executor (`Route::Xla`), CPU fallback included.
    Xla,
    /// Fused dynamic batch (multiple small jobs, one sort pass).
    Fused,
}

/// Number of [`Tier`] variants (array sizing).
pub const TIER_COUNT: usize = 5;

impl Tier {
    /// Dense index for per-tier arrays.
    pub fn index(self) -> usize {
        self as usize
    }

    /// Stable lowercase label used in snapshots and bench JSON.
    pub fn name(self) -> &'static str {
        match self {
            Tier::Tiny => "tiny",
            Tier::Single => "single",
            Tier::Parallel => "parallel",
            Tier::Xla => "xla",
            Tier::Fused => "fused",
        }
    }

    /// All tiers in index order.
    pub fn all() -> [Tier; TIER_COUNT] {
        [Tier::Tiny, Tier::Single, Tier::Parallel, Tier::Xla, Tier::Fused]
    }
}

/// Power-of-two request-size classes the per-tier observations are
/// bucketed by: class `c` holds lengths in `[2^c, 2^(c+1))`, with the
/// top class collecting everything ≥ `2^27` (~134M elements).
pub const SIZE_CLASSES: usize = 28;

/// Size class of a request length (`floor(log2(len))`, clamped).
pub fn size_class(len: usize) -> usize {
    (len.max(1).ilog2() as usize).min(SIZE_CLASSES - 1)
}

/// The throughput gauge formula — elements per microsecond of busy
/// nanoseconds, `0.0` when nothing was measured. One implementation
/// for both the reported [`RouteSnapshot::elems_per_us`] and the
/// tuner's verdicts, so the two can never silently diverge.
pub fn throughput_elems_per_us(elements: u64, busy_ns: u64) -> f64 {
    if busy_ns == 0 {
        0.0
    } else {
        elements as f64 * 1e3 / busy_ns as f64
    }
}

/// One size class's running totals inside a [`RouteObs`].
#[derive(Default)]
struct ClassObs {
    jobs: AtomicU64,
    elements: AtomicU64,
    busy_ns: AtomicU64,
}

/// Per-tier observation: how many jobs/elements this tier executed,
/// how long it was busy doing so (service time, not queue latency — a
/// tier's *throughput* is what routing decisions trade on), a latency
/// histogram of per-sort service times, and the same totals bucketed
/// by request size class so the tuner can compare tiers *near a
/// cutoff* instead of on incomparable aggregates.
#[derive(Default)]
pub struct RouteObs {
    jobs: AtomicU64,
    elements: AtomicU64,
    busy_ns: AtomicU64,
    /// Service-time (sort duration) histogram for this tier.
    pub latency: LatencyHistogram,
    classes: [ClassObs; SIZE_CLASSES],
}

impl RouteObs {
    /// Record one solo sort of `len` elements that took `busy`.
    /// Durations are accumulated in nanoseconds: tiny-tier sorts run
    /// well under a microsecond, and the throughput gauge must not
    /// round them to zero.
    pub fn record(&self, len: usize, busy: Duration) {
        let ns = (busy.as_nanos().max(1)).min(u64::MAX as u128) as u64;
        self.jobs.fetch_add(1, Ordering::Relaxed);
        self.elements.fetch_add(len as u64, Ordering::Relaxed);
        self.busy_ns.fetch_add(ns, Ordering::Relaxed);
        self.latency.record(busy);
        let c = &self.classes[size_class(len)];
        c.jobs.fetch_add(1, Ordering::Relaxed);
        c.elements.fetch_add(len as u64, Ordering::Relaxed);
        c.busy_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Record one fused batch sort: `bounds` is the fused buffer's
    /// offset table (`bounds[i]..bounds[i+1]` = segment `i`), `busy`
    /// the duration of the whole batched pass. Each segment is charged
    /// its proportional share of the batch time — in its size class
    /// *and* as its own latency-histogram sample — so both the
    /// per-class throughput and the service-time quantiles stay
    /// comparable with the solo tiers' per-sort observations (one
    /// batch-level sample against a `jobs += segments` count would
    /// overstate per-job service time by the batch width).
    pub fn record_segments(&self, bounds: &[usize], busy: Duration) {
        let total = *bounds.last().unwrap_or(&0);
        if bounds.len() < 2 || total == 0 {
            return;
        }
        let ns = (busy.as_nanos().max(1)).min(u64::MAX as u128) as u64;
        let jobs = (bounds.len() - 1) as u64;
        self.jobs.fetch_add(jobs, Ordering::Relaxed);
        self.elements.fetch_add(total as u64, Ordering::Relaxed);
        self.busy_ns.fetch_add(ns, Ordering::Relaxed);
        for w in bounds.windows(2) {
            let len = w[1] - w[0];
            let share = (((ns as u128 * len as u128) / total as u128) as u64).max(1);
            let c = &self.classes[size_class(len)];
            c.jobs.fetch_add(1, Ordering::Relaxed);
            c.elements.fetch_add(len as u64, Ordering::Relaxed);
            c.busy_ns.fetch_add(share, Ordering::Relaxed);
            self.latency.record(Duration::from_nanos(share));
        }
    }

    /// Jobs observed on this tier.
    pub fn jobs(&self) -> u64 {
        self.jobs.load(Ordering::Relaxed)
    }

    /// Elements sorted on this tier.
    pub fn elements(&self) -> u64 {
        self.elements.load(Ordering::Relaxed)
    }

    /// Cumulative busy time in nanoseconds.
    pub fn busy_ns(&self) -> u64 {
        self.busy_ns.load(Ordering::Relaxed)
    }

    /// Element-throughput gauge: elements per microsecond of busy
    /// time, `0.0` before the first observation.
    pub fn elems_per_us(&self) -> f64 {
        throughput_elems_per_us(self.elements(), self.busy_ns())
    }

    /// Cumulative `(jobs, elements, busy_ns)` of one size class — the
    /// tuner diffs these across epochs.
    pub fn class_totals(&self, class: usize) -> (u64, u64, u64) {
        let c = &self.classes[class];
        (
            c.jobs.load(Ordering::Relaxed),
            c.elements.load(Ordering::Relaxed),
            c.busy_ns.load(Ordering::Relaxed),
        )
    }

    fn snapshot(&self, tier: Tier) -> RouteSnapshot {
        RouteSnapshot {
            tier: tier.name(),
            jobs: self.jobs(),
            elements: self.elements(),
            busy_us: self.busy_ns() / 1_000,
            elems_per_us: self.elems_per_us(),
            p50_us: self.latency.quantile_us(0.5),
            p99_us: self.latency.quantile_us(0.99),
        }
    }
}

/// All five per-tier observations, indexed by [`Tier`].
#[derive(Default)]
pub struct RouteSet {
    obs: [RouteObs; TIER_COUNT],
}

impl RouteSet {
    /// The observation cell for `tier`.
    pub fn get(&self, tier: Tier) -> &RouteObs {
        &self.obs[tier.index()]
    }

    /// Snapshots of every tier, in [`Tier::all`] order.
    pub fn snapshots(&self) -> Vec<RouteSnapshot> {
        Tier::all().iter().map(|&t| self.get(t).snapshot(t)).collect()
    }
}

/// Point-in-time copy of one tier's observation, reported inside
/// [`MetricsSnapshot::routes`].
#[derive(Clone, Debug, PartialEq)]
pub struct RouteSnapshot {
    /// [`Tier::name`] label.
    pub tier: &'static str,
    pub jobs: u64,
    pub elements: u64,
    /// Cumulative busy (service) time, µs.
    pub busy_us: u64,
    /// Element-throughput gauge (elements/µs of busy time).
    pub elems_per_us: f64,
    /// Service-time (not queue-latency) quantiles.
    pub p50_us: u64,
    pub p99_us: u64,
}

/// Per-tenant counters, owned by one registered tenant (shared
/// between every [`super::SortClient`] clone for that tenant and the
/// service) and snapshotted into [`TenantSnapshot`].
pub struct TenantMetrics {
    name: String,
    /// Requests admitted into a shard queue for this tenant.
    pub accepted: AtomicU64,
    /// Requests shed without a result: `try_submit` refused at
    /// admission (queue full, over share, or shutdown), any submit
    /// after shutdown, or a queued request evicted under fair-share
    /// pressure.
    pub shed: AtomicU64,
    /// The subset of `shed` caused by this tenant exceeding its fair
    /// share under pressure (`OverShare` refusals + evictions) —
    /// distinguishes "the service was full" from "*you* were the
    /// overload".
    pub shed_over_share: AtomicU64,
    /// The subset of `shed` that was already queued when it was shed:
    /// fair-share admission displaced it to make room for a tenant
    /// further under its share (the evicted handle resolves to an
    /// error).
    pub evicted: AtomicU64,
    /// Requests completed with a result delivered to the slot.
    pub completed: AtomicU64,
    /// Requests that were admitted but never sorted: the handle was
    /// dropped before a worker started them, or they were still
    /// queued when the service shut down. Together with `failed` this
    /// closes the admission ledger: always
    /// `accepted == completed + cancelled + failed` once the service
    /// is quiet.
    pub cancelled: AtomicU64,
    /// Requests that were admitted but resolved to a
    /// [`super::SortError`] instead of a result: contained panics,
    /// expired deadlines, and quarantines.
    pub failed: AtomicU64,
    /// The subset of `failed` reaped because the request's deadline
    /// expired before a worker started it (the QoS charge was
    /// refunded).
    pub deadline_expired: AtomicU64,
    /// Queue-to-completion latency, this tenant's requests only.
    pub latency: LatencyHistogram,
    /// Live fair-share scheduling state (weight/burst config plus the
    /// in-flight / queued / virtual-time counters); its atomics
    /// double as the snapshot's QoS gauges.
    pub(super) qos: QosState,
}

impl TenantMetrics {
    pub(super) fn new(name: &str) -> Self {
        TenantMetrics {
            name: name.to_string(),
            accepted: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            shed_over_share: AtomicU64::new(0),
            evicted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            deadline_expired: AtomicU64::new(0),
            latency: LatencyHistogram::default(),
            qos: QosState::new(ClientConfig::default()),
        }
    }

    /// The tenant's registered name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Point-in-time copy of this tenant's counters. The relative
    /// gauges (`share`, `credit_bytes`) need service-wide totals and
    /// are zero here; [`TenantSnapshot::with_share`] fills them —
    /// `SortService::metrics` and `SortClient::tenant_metrics` both
    /// do.
    pub fn snapshot(&self) -> TenantSnapshot {
        let cfg = self.qos.config();
        TenantSnapshot {
            name: self.name.clone(),
            accepted: self.accepted.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            shed_over_share: self.shed_over_share.load(Ordering::Relaxed),
            evicted: self.evicted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            cancelled: self.cancelled.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            deadline_expired: self.deadline_expired.load(Ordering::Relaxed),
            weight: cfg.weight,
            burst: cfg.burst as u64,
            in_flight_bytes: self.qos.in_flight(),
            queued_jobs: self.qos.queued(),
            share: 0.0,
            credit_bytes: 0,
            mean_latency_us: self.latency.mean_us(),
            p50_us: self.latency.quantile_us(0.5),
            p99_us: self.latency.quantile_us(0.99),
        }
    }
}

/// Point-in-time copy of one tenant's counters, reported inside
/// [`MetricsSnapshot::tenants`].
#[derive(Clone, Debug, PartialEq)]
pub struct TenantSnapshot {
    pub name: String,
    pub accepted: u64,
    pub shed: u64,
    /// `shed` subset caused by this tenant exceeding its fair share
    /// (`BusyReason::OverShare` refusals + evictions).
    pub shed_over_share: u64,
    /// `shed` subset displaced from a queue after admission (the
    /// evicted handle resolves to an error).
    pub evicted: u64,
    pub completed: u64,
    pub cancelled: u64,
    /// Requests resolved to a [`super::SortError`] (contained panic,
    /// deadline, quarantine). The quiet-service ledger reads
    /// `accepted == completed + cancelled + failed`.
    pub failed: u64,
    /// `failed` subset reaped for deadline expiry (charge refunded).
    pub deadline_expired: u64,
    /// Fair-share weight in force ([`super::ClientConfig::weight`]).
    pub weight: u32,
    /// Burst allowance in bytes ([`super::ClientConfig::burst`]).
    pub burst: u64,
    /// Occupancy gauge: admission cost (payload bytes, floored at
    /// 1 KiB per job so queue-slot hogs register) admitted and not
    /// yet completed/cancelled/evicted (queued + executing). Byte
    /// denomination makes the gauge comparable across element widths.
    pub in_flight_bytes: u64,
    /// Jobs currently sitting in a shard queue.
    pub queued_jobs: u64,
    /// Share gauge: this tenant's weight over the total registered
    /// weight, in `(0, 1]` (filled against the live registry totals
    /// by `SortService::metrics` / `SortClient::tenant_metrics`).
    pub share: f64,
    /// Credit gauge: `share × total in-flight bytes −` this tenant's
    /// in-flight bytes. Positive = running under its fair share of
    /// the current load (has credit); negative = over.
    pub credit_bytes: i64,
    pub mean_latency_us: f64,
    pub p50_us: u64,
    pub p99_us: u64,
}

impl TenantSnapshot {
    /// Fill the relative gauges from service-wide totals: `share`
    /// from the registered-weight sum, `credit_bytes` against the
    /// total in-flight byte count.
    pub(super) fn with_share(mut self, total_weight: u64, total_in_flight: u64) -> Self {
        if total_weight > 0 {
            self.share = self.weight as f64 / total_weight as f64;
        }
        self.credit_bytes =
            (self.share * total_in_flight as f64) as i64 - self.in_flight_bytes as i64;
        self
    }
}

/// All service-wide coordinator counters (shared via `Arc`).
#[derive(Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    /// The subset of `rejected` that was displaced from a queue by
    /// fair-share admission after having been accepted (summed over
    /// tenants; the evicted handles resolve to errors).
    pub evicted: AtomicU64,
    /// Requests admitted but never sorted: their [`super::SortHandle`]
    /// was dropped before a worker reached them, or they were still
    /// queued at shutdown.
    pub cancelled: AtomicU64,
    /// Requests admitted but resolved to a [`super::SortError`]
    /// (contained panic, expired deadline, quarantine) — summed over
    /// tenants.
    pub failed: AtomicU64,
    /// `failed` subset reaped for deadline expiry.
    pub deadline_expired: AtomicU64,
    /// Panics caught by the per-job `catch_unwind` envelope (the
    /// worker survived; only the panicking request failed).
    pub panics_contained: AtomicU64,
    /// Worker threads the supervisor respawned after a fatal
    /// (uncontained) panic killed them.
    pub workers_respawned: AtomicU64,
    /// Jobs quarantined after killing a worker twice (resolved
    /// [`super::SortError::Quarantined`] instead of a third retry).
    pub quarantined: AtomicU64,
    /// XLA circuit-breaker state gauge, mirrored by the executor after
    /// every dispatch: 0 closed, 1 open, 2 half-open
    /// ([`crate::runtime::CircuitBreaker::state_code`]).
    pub breaker_state: AtomicU64,
    /// Times the XLA circuit breaker tripped open.
    pub breaker_trips: AtomicU64,
    /// Wire connections accepted by the network ingress since
    /// startup (`rust/src/net`); 0 when no server is running.
    pub connections_opened: AtomicU64,
    /// Wire connections fully torn down (clean close, abrupt
    /// disconnect, or protocol-error teardown alike).
    pub connections_closed: AtomicU64,
    /// Request frames decoded and served, any opcode.
    pub net_frames: AtomicU64,
    /// `RETRY_AFTER` responses sent — sheds surfaced as backpressure
    /// over the wire instead of dropped connections.
    pub net_retry_after: AtomicU64,
    /// Connections closed because the byte stream desynchronized
    /// (malformed frame, oversized length prefix, EOF mid-frame).
    pub net_protocol_errors: AtomicU64,
    pub elements: AtomicU64,
    pub route_tiny: AtomicU64,
    pub route_single: AtomicU64,
    pub route_parallel: AtomicU64,
    pub route_xla: AtomicU64,
    /// Accelerator-side batches (XLA executor coalescing). CPU fused
    /// batches are counted per shard in [`ShardMetrics::batches`].
    pub batches: AtomicU64,
    pub latency: LatencyHistogram,
    /// Per-tier service-time observations (jobs, elements, busy time,
    /// size-class grid) — the adaptive tuner's input signal, recorded
    /// by the workers / XLA executor as each sort completes.
    pub routes: RouteSet,
}

/// Per-shard counters, owned by one shard and aggregated into the
/// service-wide [`MetricsSnapshot`].
#[derive(Default)]
pub struct ShardMetrics {
    /// Current queue depth (updated on push/pop; also drives the
    /// power-of-two-choices submit routing).
    pub depth: AtomicU64,
    /// Fused CPU batches formed from this shard's queue.
    pub batches: AtomicU64,
    /// Jobs that left this shard's queue inside a multi-job batch.
    pub batched_jobs: AtomicU64,
    /// Batches this shard's home worker stole from other shards.
    pub steals: AtomicU64,
}

/// Point-in-time copy for reporting.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    /// Requests refused or shed: admission-time sheds (queue full,
    /// over share, shutdown) plus fair-share evictions.
    pub rejected: u64,
    /// The subset of `rejected` displaced from a queue after
    /// admission by fair-share QoS (see [`TenantSnapshot::evicted`]).
    pub evicted: u64,
    /// Requests admitted but never sorted (handle dropped, or still
    /// queued at shutdown).
    pub cancelled: u64,
    /// Requests resolved to a [`super::SortError`] (contained panic,
    /// expired deadline, quarantine); the quiet-service ledger is
    /// `Σ tenants.accepted == completed + cancelled + failed`.
    pub failed: u64,
    /// `failed` subset reaped for deadline expiry (charge refunded).
    pub deadline_expired: u64,
    /// Panics contained by the per-job envelope (worker survived).
    pub panics_contained: u64,
    /// Workers the supervisor respawned after fatal panics.
    pub workers_respawned: u64,
    /// Jobs quarantined after killing a worker twice.
    pub quarantined: u64,
    /// XLA circuit-breaker state at snapshot time: `"closed"`,
    /// `"open"`, or `"half-open"` (always `"closed"` when no XLA
    /// executor is running).
    pub breaker_state: &'static str,
    /// The process-wide active SIMD backend every CPU sort lowers on
    /// ([`crate::simd::backend::active`]): `"scalar"`, `"neon"`,
    /// `"sse4.2"`, or `"avx2"`.
    pub simd_backend: &'static str,
    /// Times the XLA circuit breaker tripped open.
    pub breaker_trips: u64,
    /// Wire connections currently open (opened − closed); 0 when no
    /// network server fronts this service.
    pub connections_open: u64,
    /// Wire connections accepted since startup.
    pub connections_opened: u64,
    /// Request frames decoded and served over the wire.
    pub net_frames: u64,
    /// `RETRY_AFTER` responses sent (wire-surfaced backpressure).
    pub net_retry_after: u64,
    /// Connections torn down for stream-level protocol errors.
    pub net_protocol_errors: u64,
    pub elements: u64,
    pub route_tiny: u64,
    pub route_single: u64,
    pub route_parallel: u64,
    pub route_xla: u64,
    /// Total batches: CPU fused batches (all shards) + XLA batches.
    pub batches: u64,
    /// Jobs completed inside fused CPU batches.
    pub batched_jobs: u64,
    /// Mean jobs per fused CPU batch (0 when no batch formed) — the
    /// batch-occupancy gauge.
    pub batch_occupancy: f64,
    /// Cross-shard steals, summed over workers.
    pub steals: u64,
    /// Queue depth per shard at snapshot time.
    pub shard_depths: Vec<u64>,
    pub mean_latency_us: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    /// Per-tenant accepted/shed/completed counters and latency
    /// quantiles, sorted by tenant name. Empty when no tenant client
    /// was ever created.
    pub tenants: Vec<TenantSnapshot>,
    /// Per-tier observations (throughput gauge + service-time
    /// quantiles), in [`Tier::all`] order — always `TIER_COUNT` rows.
    pub routes: Vec<RouteSnapshot>,
}

/// Decode the breaker gauge code mirrored by the XLA executor
/// ([`crate::runtime::CircuitBreaker::state_code`]).
fn breaker_state_label(code: u64) -> &'static str {
    match code {
        1 => "open",
        2 => "half-open",
        _ => "closed",
    }
}

impl Metrics {
    /// Capture a service-wide snapshot (no shard data; see
    /// [`Metrics::snapshot_with_shards`]).
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            evicted: self.evicted.load(Ordering::Relaxed),
            cancelled: self.cancelled.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            deadline_expired: self.deadline_expired.load(Ordering::Relaxed),
            panics_contained: self.panics_contained.load(Ordering::Relaxed),
            workers_respawned: self.workers_respawned.load(Ordering::Relaxed),
            quarantined: self.quarantined.load(Ordering::Relaxed),
            breaker_state: breaker_state_label(self.breaker_state.load(Ordering::Relaxed)),
            simd_backend: crate::simd::backend::active().name(),
            breaker_trips: self.breaker_trips.load(Ordering::Relaxed),
            connections_open: self
                .connections_opened
                .load(Ordering::Relaxed)
                .saturating_sub(self.connections_closed.load(Ordering::Relaxed)),
            connections_opened: self.connections_opened.load(Ordering::Relaxed),
            net_frames: self.net_frames.load(Ordering::Relaxed),
            net_retry_after: self.net_retry_after.load(Ordering::Relaxed),
            net_protocol_errors: self.net_protocol_errors.load(Ordering::Relaxed),
            elements: self.elements.load(Ordering::Relaxed),
            route_tiny: self.route_tiny.load(Ordering::Relaxed),
            route_single: self.route_single.load(Ordering::Relaxed),
            route_parallel: self.route_parallel.load(Ordering::Relaxed),
            route_xla: self.route_xla.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_jobs: 0,
            batch_occupancy: 0.0,
            steals: 0,
            shard_depths: Vec::new(),
            mean_latency_us: self.latency.mean_us(),
            p50_us: self.latency.quantile_us(0.5),
            p99_us: self.latency.quantile_us(0.99),
            tenants: Vec::new(),
            routes: self.routes.snapshots(),
        }
    }

    /// Capture a snapshot with per-shard counters folded in: fused
    /// batches add to `batches`, and occupancy/steals/depths are
    /// aggregated across shards.
    pub fn snapshot_with_shards<'a>(
        &self,
        shards: impl Iterator<Item = &'a ShardMetrics>,
    ) -> MetricsSnapshot {
        let mut snap = self.snapshot();
        let mut fused_batches = 0u64;
        for s in shards {
            snap.shard_depths.push(s.depth.load(Ordering::Relaxed));
            fused_batches += s.batches.load(Ordering::Relaxed);
            snap.batched_jobs += s.batched_jobs.load(Ordering::Relaxed);
            snap.steals += s.steals.load(Ordering::Relaxed);
        }
        snap.batches += fused_batches;
        if fused_batches > 0 {
            snap.batch_occupancy = snap.batched_jobs as f64 / fused_batches as f64;
        }
        snap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = LatencyHistogram::default();
        for us in [1u64, 2, 4, 100, 1000, 10_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 6);
        assert!(h.mean_us() > 0.0);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.99));
        assert!(h.quantile_us(1.0) >= 10_000);
    }

    #[test]
    fn zero_samples() {
        let h = LatencyHistogram::default();
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.quantile_us(0.99), 0);
    }

    #[test]
    fn snapshot_roundtrip() {
        let m = Metrics::default();
        m.submitted.fetch_add(3, Ordering::Relaxed);
        m.completed.fetch_add(2, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.submitted, 3);
        assert_eq!(s.completed, 2);
        assert!(s.shard_depths.is_empty());
    }

    #[test]
    fn shard_aggregation_and_occupancy() {
        let m = Metrics::default();
        m.batches.fetch_add(1, Ordering::Relaxed); // one XLA batch
        let shards: Vec<ShardMetrics> = (0..3).map(|_| ShardMetrics::default()).collect();
        shards[0].depth.store(5, Ordering::Relaxed);
        shards[0].batches.fetch_add(2, Ordering::Relaxed);
        shards[0].batched_jobs.fetch_add(12, Ordering::Relaxed);
        shards[1].batches.fetch_add(1, Ordering::Relaxed);
        shards[1].batched_jobs.fetch_add(3, Ordering::Relaxed);
        shards[2].steals.fetch_add(4, Ordering::Relaxed);
        let s = m.snapshot_with_shards(shards.iter());
        assert_eq!(s.shard_depths, vec![5, 0, 0]);
        assert_eq!(s.batches, 1 + 3, "xla + fused");
        assert_eq!(s.batched_jobs, 15);
        assert_eq!(s.steals, 4);
        assert!((s.batch_occupancy - 5.0).abs() < 1e-9, "15 jobs / 3 fused batches");
    }

    #[test]
    fn tenant_snapshot_roundtrip() {
        let t = TenantMetrics::new("acme");
        t.accepted.fetch_add(3, Ordering::Relaxed);
        t.shed.fetch_add(1, Ordering::Relaxed);
        t.completed.fetch_add(2, Ordering::Relaxed);
        t.latency.record(Duration::from_micros(10));
        let s = t.snapshot();
        assert_eq!(s.name, "acme");
        assert_eq!((s.accepted, s.shed, s.completed, s.cancelled), (3, 1, 2, 0));
        assert_eq!((s.shed_over_share, s.evicted), (0, 0));
        assert_eq!(s.weight, 1, "default ClientConfig weight");
        assert!(s.mean_latency_us > 0.0);
        assert_eq!(t.name(), "acme");
    }

    #[test]
    fn tenant_share_and_credit_gauges() {
        let t = TenantMetrics::new("gold");
        t.qos.configure(ClientConfig { weight: 4, burst: 0, ..Default::default() });
        let gv = AtomicU64::new(0);
        t.qos.charge(100, &gv);
        // Bare snapshot: relative gauges unset.
        let bare = t.snapshot();
        assert_eq!(bare.share, 0.0);
        assert_eq!(bare.credit_bytes, 0);
        assert_eq!(bare.in_flight_bytes, 100);
        // Against totals: weight 4 of 5 → share 0.8; fair in-flight
        // at 500 total is 400, so 300 bytes of credit remain.
        let s = t.snapshot().with_share(5, 500);
        assert!((s.share - 0.8).abs() < 1e-9);
        assert_eq!(s.credit_bytes, 300);
        // An over-share tenant's credit goes negative.
        t.qos.charge(900, &gv);
        let s = t.snapshot().with_share(5, 1000);
        assert_eq!(s.credit_bytes, -200);
    }

    #[test]
    fn quantiles_monotone_and_clamped_to_top_bucket() {
        // Mixed sample set, including one far past the ~67 s bucket
        // ceiling: quantiles must be nondecreasing in q and never
        // exceed the clamped top-bucket bound of 2^26 µs.
        let h = LatencyHistogram::default();
        let mut us = 1u64;
        for i in 0..200u64 {
            h.record(Duration::from_micros(us));
            us = us.wrapping_mul(3).wrapping_add(i) % 50_000_000 + 1;
        }
        h.record(Duration::from_secs(1000)); // 1e9 µs ≫ 2^26
        let mut prev = 0u64;
        for step in 0..=20 {
            let q = step as f64 / 20.0;
            let v = h.quantile_us(q);
            assert!(v >= prev, "quantile must be monotone: q={q} gave {v} < {prev}");
            assert!(v <= 1 << 26, "quantile {v} exceeds the ~67 s bucket ceiling");
            prev = v;
        }
        assert_eq!(h.quantile_us(1.0), 1 << 26, "top sample lands in the clamped bucket");
    }

    #[test]
    fn size_classes_cover_and_clamp() {
        assert_eq!(size_class(0), 0);
        assert_eq!(size_class(1), 0);
        assert_eq!(size_class(2), 1);
        assert_eq!(size_class(1023), 9);
        assert_eq!(size_class(1024), 10);
        assert_eq!(size_class(usize::MAX), SIZE_CLASSES - 1);
    }

    #[test]
    fn route_obs_gauge_and_classes() {
        let m = Metrics::default();
        let tiny = m.routes.get(Tier::Tiny);
        tiny.record(32, Duration::from_nanos(500));
        tiny.record(40, Duration::from_nanos(700));
        assert_eq!(tiny.jobs(), 2);
        assert_eq!(tiny.elements(), 72);
        assert!(tiny.elems_per_us() > 0.0);
        let (jobs, elems, ns) = tiny.class_totals(5); // 32..63
        assert_eq!((jobs, elems), (2, 72));
        assert!(ns >= 1200);
        // Sub-µs observations must not round the gauge to zero.
        assert!(tiny.elems_per_us() > 1.0, "72 elems in 1.2µs ≈ 60 e/µs");
        let snap = m.snapshot();
        assert_eq!(snap.routes.len(), TIER_COUNT);
        assert_eq!(snap.routes[Tier::Tiny.index()].tier, "tiny");
        assert_eq!(snap.routes[Tier::Tiny.index()].jobs, 2);
        assert_eq!(snap.routes[Tier::Fused.index()].jobs, 0);
    }

    #[test]
    fn fused_observation_attributes_segments_proportionally() {
        let obs = RouteObs::default();
        // Three segments 100/100/200 sorted in one 4 µs batch pass.
        obs.record_segments(&[0, 100, 200, 400], Duration::from_micros(4));
        assert_eq!(obs.jobs(), 3);
        assert_eq!(obs.elements(), 400);
        let (j_small, e_small, ns_small) = obs.class_totals(size_class(100));
        assert_eq!((j_small, e_small), (2, 200));
        let (j_big, e_big, ns_big) = obs.class_totals(size_class(200));
        assert_eq!((j_big, e_big), (1, 200));
        // The 200-element segment gets ~half the batch time; the two
        // 100-element segments split the other half.
        assert!(ns_big >= ns_small / 2 && ns_big <= 2 * ns_small + 2);
        assert!(obs.elems_per_us() > 99.0 && obs.elems_per_us() < 101.0);
        // One latency sample per *segment* (its proportional share),
        // not one per batch — p50 must read as a per-job service
        // time comparable with the solo tiers' histograms.
        assert_eq!(obs.latency.count(), 3);
        assert!(obs.latency.quantile_us(0.99) <= 4, "2µs share → ≤4µs bucket bound");
        // Degenerate inputs are ignored, not divided by zero.
        obs.record_segments(&[0], Duration::from_micros(1));
        obs.record_segments(&[0, 0], Duration::from_micros(1));
        assert_eq!(obs.jobs(), 3);
    }

    #[test]
    fn failure_counters_round_trip_and_breaker_decodes() {
        let m = Metrics::default();
        assert_eq!(m.snapshot().breaker_state, "closed", "gauge defaults closed");
        m.failed.fetch_add(3, Ordering::Relaxed);
        m.deadline_expired.fetch_add(2, Ordering::Relaxed);
        m.panics_contained.fetch_add(1, Ordering::Relaxed);
        m.workers_respawned.fetch_add(4, Ordering::Relaxed);
        m.quarantined.fetch_add(1, Ordering::Relaxed);
        m.breaker_state.store(1, Ordering::Relaxed);
        m.breaker_trips.store(7, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!((s.failed, s.deadline_expired), (3, 2));
        assert_eq!((s.panics_contained, s.workers_respawned, s.quarantined), (1, 4, 1));
        assert_eq!((s.breaker_state, s.breaker_trips), ("open", 7));
        m.breaker_state.store(2, Ordering::Relaxed);
        assert_eq!(m.snapshot().breaker_state, "half-open");
        // Tenant side: failed/deadline_expired land in the snapshot.
        let t = TenantMetrics::new("acme");
        t.failed.fetch_add(2, Ordering::Relaxed);
        t.deadline_expired.fetch_add(1, Ordering::Relaxed);
        let ts = t.snapshot();
        assert_eq!((ts.failed, ts.deadline_expired), (2, 1));
    }

    #[test]
    fn occupancy_zero_without_batches() {
        let m = Metrics::default();
        let shards = [ShardMetrics::default()];
        let s = m.snapshot_with_shards(shards.iter());
        assert_eq!(s.batch_occupancy, 0.0);
        assert_eq!(s.shard_depths, vec![0]);
    }
}
