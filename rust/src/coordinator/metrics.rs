//! Lock-free service metrics: request counters per route, per-tenant
//! accepted/shed/completed accounting, and log-bucketed latency
//! histograms (no external deps — atomics only).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Power-of-two latency buckets from 1 µs to ~67 s.
const BUCKETS: usize = 27;

/// Log-bucketed latency histogram.
#[derive(Default)]
pub struct LatencyHistogram {
    counts: [AtomicU64; BUCKETS],
    sum_us: AtomicU64,
    n: AtomicU64,
}

impl LatencyHistogram {
    /// Record one latency sample.
    pub fn record(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let bucket = (63 - us.leading_zeros() as usize).min(BUCKETS - 1);
        self.counts[bucket].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.n.fetch_add(1, Ordering::Relaxed);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.n.load(Ordering::Relaxed)
    }

    /// Mean latency in µs.
    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Approximate quantile (upper bucket bound), q in [0, 1].
    pub fn quantile_us(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = ((n as f64) * q).ceil() as u64;
        let mut acc = 0u64;
        for (b, c) in self.counts.iter().enumerate() {
            acc += c.load(Ordering::Relaxed);
            if acc >= target {
                return 1u64 << (b + 1);
            }
        }
        1u64 << BUCKETS
    }
}

/// Per-tenant counters, owned by one registered tenant (shared
/// between every [`super::SortClient`] clone for that tenant and the
/// service) and snapshotted into [`TenantSnapshot`].
pub struct TenantMetrics {
    name: String,
    /// Requests admitted into a shard queue for this tenant.
    pub accepted: AtomicU64,
    /// Requests shed at admission without being enqueued:
    /// `try_submit` while every queue was full, or any submit
    /// (including blocking `submit`) after shutdown.
    pub shed: AtomicU64,
    /// Requests completed with a result delivered to the slot.
    pub completed: AtomicU64,
    /// Requests that were admitted but never sorted: the handle was
    /// dropped before a worker started them, or they were still
    /// queued when the service shut down. Always
    /// `accepted == completed + cancelled` once the service is quiet.
    pub cancelled: AtomicU64,
    /// Queue-to-completion latency, this tenant's requests only.
    pub latency: LatencyHistogram,
}

impl TenantMetrics {
    pub(super) fn new(name: &str) -> Self {
        TenantMetrics {
            name: name.to_string(),
            accepted: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            latency: LatencyHistogram::default(),
        }
    }

    /// The tenant's registered name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Point-in-time copy of this tenant's counters.
    pub fn snapshot(&self) -> TenantSnapshot {
        TenantSnapshot {
            name: self.name.clone(),
            accepted: self.accepted.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            cancelled: self.cancelled.load(Ordering::Relaxed),
            mean_latency_us: self.latency.mean_us(),
            p50_us: self.latency.quantile_us(0.5),
            p99_us: self.latency.quantile_us(0.99),
        }
    }
}

/// Point-in-time copy of one tenant's counters, reported inside
/// [`MetricsSnapshot::tenants`].
#[derive(Clone, Debug, PartialEq)]
pub struct TenantSnapshot {
    pub name: String,
    pub accepted: u64,
    pub shed: u64,
    pub completed: u64,
    pub cancelled: u64,
    pub mean_latency_us: f64,
    pub p50_us: u64,
    pub p99_us: u64,
}

/// All service-wide coordinator counters (shared via `Arc`).
#[derive(Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    /// Requests admitted but never sorted: their [`super::SortHandle`]
    /// was dropped before a worker reached them, or they were still
    /// queued at shutdown.
    pub cancelled: AtomicU64,
    pub elements: AtomicU64,
    pub route_tiny: AtomicU64,
    pub route_single: AtomicU64,
    pub route_parallel: AtomicU64,
    pub route_xla: AtomicU64,
    /// Accelerator-side batches (XLA executor coalescing). CPU fused
    /// batches are counted per shard in [`ShardMetrics::batches`].
    pub batches: AtomicU64,
    pub latency: LatencyHistogram,
}

/// Per-shard counters, owned by one shard and aggregated into the
/// service-wide [`MetricsSnapshot`].
#[derive(Default)]
pub struct ShardMetrics {
    /// Current queue depth (updated on push/pop; also drives the
    /// power-of-two-choices submit routing).
    pub depth: AtomicU64,
    /// Fused CPU batches formed from this shard's queue.
    pub batches: AtomicU64,
    /// Jobs that left this shard's queue inside a multi-job batch.
    pub batched_jobs: AtomicU64,
    /// Batches this shard's home worker stole from other shards.
    pub steals: AtomicU64,
}

/// Point-in-time copy for reporting.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    /// Requests admitted but never sorted (handle dropped, or still
    /// queued at shutdown).
    pub cancelled: u64,
    pub elements: u64,
    pub route_tiny: u64,
    pub route_single: u64,
    pub route_parallel: u64,
    pub route_xla: u64,
    /// Total batches: CPU fused batches (all shards) + XLA batches.
    pub batches: u64,
    /// Jobs completed inside fused CPU batches.
    pub batched_jobs: u64,
    /// Mean jobs per fused CPU batch (0 when no batch formed) — the
    /// batch-occupancy gauge.
    pub batch_occupancy: f64,
    /// Cross-shard steals, summed over workers.
    pub steals: u64,
    /// Queue depth per shard at snapshot time.
    pub shard_depths: Vec<u64>,
    pub mean_latency_us: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    /// Per-tenant accepted/shed/completed counters and latency
    /// quantiles, sorted by tenant name. Empty when no tenant client
    /// was ever created.
    pub tenants: Vec<TenantSnapshot>,
}

impl Metrics {
    /// Capture a service-wide snapshot (no shard data; see
    /// [`Metrics::snapshot_with_shards`]).
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            cancelled: self.cancelled.load(Ordering::Relaxed),
            elements: self.elements.load(Ordering::Relaxed),
            route_tiny: self.route_tiny.load(Ordering::Relaxed),
            route_single: self.route_single.load(Ordering::Relaxed),
            route_parallel: self.route_parallel.load(Ordering::Relaxed),
            route_xla: self.route_xla.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_jobs: 0,
            batch_occupancy: 0.0,
            steals: 0,
            shard_depths: Vec::new(),
            mean_latency_us: self.latency.mean_us(),
            p50_us: self.latency.quantile_us(0.5),
            p99_us: self.latency.quantile_us(0.99),
            tenants: Vec::new(),
        }
    }

    /// Capture a snapshot with per-shard counters folded in: fused
    /// batches add to `batches`, and occupancy/steals/depths are
    /// aggregated across shards.
    pub fn snapshot_with_shards<'a>(
        &self,
        shards: impl Iterator<Item = &'a ShardMetrics>,
    ) -> MetricsSnapshot {
        let mut snap = self.snapshot();
        let mut fused_batches = 0u64;
        for s in shards {
            snap.shard_depths.push(s.depth.load(Ordering::Relaxed));
            fused_batches += s.batches.load(Ordering::Relaxed);
            snap.batched_jobs += s.batched_jobs.load(Ordering::Relaxed);
            snap.steals += s.steals.load(Ordering::Relaxed);
        }
        snap.batches += fused_batches;
        if fused_batches > 0 {
            snap.batch_occupancy = snap.batched_jobs as f64 / fused_batches as f64;
        }
        snap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = LatencyHistogram::default();
        for us in [1u64, 2, 4, 100, 1000, 10_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 6);
        assert!(h.mean_us() > 0.0);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.99));
        assert!(h.quantile_us(1.0) >= 10_000);
    }

    #[test]
    fn zero_samples() {
        let h = LatencyHistogram::default();
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.quantile_us(0.99), 0);
    }

    #[test]
    fn snapshot_roundtrip() {
        let m = Metrics::default();
        m.submitted.fetch_add(3, Ordering::Relaxed);
        m.completed.fetch_add(2, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.submitted, 3);
        assert_eq!(s.completed, 2);
        assert!(s.shard_depths.is_empty());
    }

    #[test]
    fn shard_aggregation_and_occupancy() {
        let m = Metrics::default();
        m.batches.fetch_add(1, Ordering::Relaxed); // one XLA batch
        let shards: Vec<ShardMetrics> = (0..3).map(|_| ShardMetrics::default()).collect();
        shards[0].depth.store(5, Ordering::Relaxed);
        shards[0].batches.fetch_add(2, Ordering::Relaxed);
        shards[0].batched_jobs.fetch_add(12, Ordering::Relaxed);
        shards[1].batches.fetch_add(1, Ordering::Relaxed);
        shards[1].batched_jobs.fetch_add(3, Ordering::Relaxed);
        shards[2].steals.fetch_add(4, Ordering::Relaxed);
        let s = m.snapshot_with_shards(shards.iter());
        assert_eq!(s.shard_depths, vec![5, 0, 0]);
        assert_eq!(s.batches, 1 + 3, "xla + fused");
        assert_eq!(s.batched_jobs, 15);
        assert_eq!(s.steals, 4);
        assert!((s.batch_occupancy - 5.0).abs() < 1e-9, "15 jobs / 3 fused batches");
    }

    #[test]
    fn tenant_snapshot_roundtrip() {
        let t = TenantMetrics::new("acme");
        t.accepted.fetch_add(3, Ordering::Relaxed);
        t.shed.fetch_add(1, Ordering::Relaxed);
        t.completed.fetch_add(2, Ordering::Relaxed);
        t.latency.record(Duration::from_micros(10));
        let s = t.snapshot();
        assert_eq!(s.name, "acme");
        assert_eq!((s.accepted, s.shed, s.completed, s.cancelled), (3, 1, 2, 0));
        assert!(s.mean_latency_us > 0.0);
        assert_eq!(t.name(), "acme");
    }

    #[test]
    fn occupancy_zero_without_batches() {
        let m = Metrics::default();
        let shards = [ShardMetrics::default()];
        let s = m.snapshot_with_shards(shards.iter());
        assert_eq!(s.batch_occupancy, 0.0);
        assert_eq!(s.shard_depths, vec![0]);
    }
}
