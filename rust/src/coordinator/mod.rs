//! L3 sort-service coordinator.
//!
//! The paper delivers an algorithm; this module delivers it as a
//! *service* the way a framework would ship it: sharded bounded
//! request queues with backpressure (power-of-two-choices admission +
//! cross-shard work stealing), a router that classifies requests by
//! size (tiny → branchless scalar, small → in-register path, medium →
//! single-thread NEON-MS, large → merge-path parallel, optional XLA
//! offload for power-of-two-friendly blocks), a dynamic batcher that
//! fuses bursts of small requests into one buffer sorted by a single
//! parallel pass, and latency/throughput/occupancy metrics. The
//! threading model is documented at the top of `service.rs`.
//!
//! Python never appears here: the XLA path executes AOT artifacts via
//! [`crate::runtime`].

mod config;
mod metrics;
mod service;

pub use config::{CoordinatorConfig, Route};
pub use metrics::{LatencyHistogram, MetricsSnapshot, ShardMetrics};
pub use service::{SortHandle, SortService};

#[cfg(test)]
mod tests;
