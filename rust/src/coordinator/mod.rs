//! L3 sort-service coordinator.
//!
//! The paper delivers an algorithm; this module delivers it as a
//! *service* the way a framework would ship it: a bounded request
//! queue with backpressure, a router that classifies requests by size
//! (tiny → branchless scalar, small → in-register path, medium →
//! single-thread NEON-MS, large → merge-path parallel, optional XLA
//! offload for power-of-two-friendly blocks), a small dynamic batcher
//! that drains bursts of tiny requests in one worker wakeup, and
//! latency/throughput metrics.
//!
//! Python never appears here: the XLA path executes AOT artifacts via
//! [`crate::runtime`].

mod config;
mod metrics;
mod service;

pub use config::{CoordinatorConfig, Route};
pub use metrics::{LatencyHistogram, MetricsSnapshot};
pub use service::{SortHandle, SortService};

#[cfg(test)]
mod tests;
