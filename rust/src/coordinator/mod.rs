//! L3 sort-service coordinator.
//!
//! The paper delivers an algorithm; this module delivers it as a
//! *service* the way a framework would ship it: sharded bounded
//! request queues with backpressure (power-of-two-choices admission +
//! cross-shard work stealing), a router that classifies requests by
//! size (tiny → branchless scalar, small → in-register path, medium →
//! single-thread NEON-MS, large → merge-path parallel, optional XLA
//! offload for power-of-two-friendly blocks), a dynamic batcher that
//! fuses bursts of small requests into one buffer sorted by a single
//! parallel pass, and latency/throughput/occupancy metrics. The
//! threading model is documented at the top of `service.rs`.
//!
//! Work enters through a **multi-tenant client layer**: each
//! in-process tenant holds a cheaply clonable [`SortClient`] bound to
//! one shared [`SortService`], and every submit returns a
//! non-blocking [`SortHandle`] that can be polled, `.await`ed, or
//! parked on — completion is signaled by the shard workers through a
//! per-request waker/parker slot, never a blocking join.
//! [`SortClient::try_submit`] sheds with [`Busy`] instead of parking,
//! and [`MetricsSnapshot::tenants`] reports accepted/shed/completed/
//! cancelled counts and latency quantiles per tenant.
//!
//! Submission is **element-typed** end to end: `u32` keys
//! ([`SortClient::submit`]), `u64` keys ([`SortClient::submit_u64`]),
//! and packed key–payload pairs ([`SortClient::submit_pairs`],
//! [`crate::simd::KeyValue`]) each ride the vectorized kernels on
//! their width's register types and resolve to a matching typed
//! handle. Jobs of different element kinds share queues and workers
//! but are never fused into one batch, and only `u32` jobs are
//! eligible for XLA offload — see [`ElemKind`] / [`ElemBuf`] /
//! [`SortElem`].
//!
//! Contended capacity is arbitrated by **weighted fair-share QoS**
//! ([`QosPolicy::FairShare`], the default): each tenant carries a
//! [`ClientConfig`] weight and burst allowance
//! ([`SortService::client_with`]), admission tracks per-tenant
//! in-flight cost in *bytes* (width-honest: an 8-byte element costs
//! twice a 4-byte one), shard dequeue orders jobs by
//! per-tenant virtual time, and when every queue is full the tenant
//! most over its share is shed first — [`BusyReason::OverShare`]
//! with a retry-after hint for the offender's own arrivals, eviction
//! of its newest queued job when a less-loaded tenant needs the
//! slot. Share/credit/occupancy gauges land in
//! [`MetricsSnapshot::tenants`]; [`QosPolicy::Fifo`] restores the
//! pre-QoS global FIFO behavior.
//!
//! **Failure domains are hardened**: every solo sort runs inside a
//! panic-containment envelope (a panicking job resolves its handle to
//! [`SortError::JobPanicked`]; the worker survives), a supervisor
//! respawns workers killed by uncontained panics and quarantines jobs
//! that kill twice, requests may carry deadlines
//! ([`ClientConfig::default_deadline`] /
//! [`SortClient::submit_with_deadline`]) reaped lazily as
//! [`SortError::DeadlineExceeded`], the XLA executor degrades through
//! a circuit breaker to the CPU fallback, clients can wrap submits in
//! a deterministic [`RetryPolicy`] backoff, and a seeded [`FaultPlan`]
//! ([`CoordinatorConfig::faults`]) injects all of it reproducibly in
//! tests — see the "Failure domains" section in `service.rs`.
//!
//! Quarantined inputs additionally leave a **dead letter**: a
//! bounded, byte-capped copy of the poisonous payload retained in a
//! ring operators can pull through [`SortService::quarantined`] —
//! the input survives its failed handle for offline reproduction.
//!
//! Out-of-process tenants reach all of this over TCP through
//! [`crate::net`]: the `HELLO` handshake maps a connection onto
//! [`SortService::client_with`] (tenant name + [`ClientConfig`]
//! knobs on the wire), and admission sheds cross the wire as
//! `RETRY_AFTER` frames carrying the same [`BusyReason`] hint the
//! in-process API returns.
//!
//! The routing cutoffs can be **learned online**: with
//! [`AdaptivePolicy::Adaptive`] the service observes each tier's
//! throughput per request-size class ([`MetricsSnapshot::routes`])
//! and re-derives `tiny`/`fuse`/`parallel`/`batch_max` every epoch,
//! within hard safety bounds — see `tuner.rs` for the
//! observe → decide → publish loop.
//!
//! Python never appears here: the XLA path executes AOT artifacts via
//! [`crate::runtime`].

mod client;
mod config;
mod elem;
mod faults;
mod metrics;
mod qos;
mod service;
mod tuner;

pub use client::{Busy, BusyReason, RetryPolicy, SortError, SortHandle};
pub use faults::{FaultDecision, FaultPlan};
pub use config::{CoordinatorConfig, QosPolicy, Route};
pub use elem::{ElemBuf, ElemKind, SortElem};
pub use metrics::{
    LatencyHistogram, MetricsSnapshot, RouteSnapshot, ShardMetrics, TenantSnapshot, Tier,
};
pub(crate) use metrics::Metrics;
pub use qos::ClientConfig;
pub use service::{DeadLetter, SortClient, SortService};
pub use tuner::{AdaptivePolicy, Decision, RoutingBounds, RoutingSnapshot};

#[cfg(test)]
mod tests;
