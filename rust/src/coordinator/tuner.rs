//! Online adaptive routing: learn the `tiny`/`fuse`/`parallel`
//! cutoffs and `batch_max` from live per-tier throughput instead of
//! freezing them in a constants file.
//!
//! The paper's hybrid design wins by picking the right mechanism per
//! scale (Fig. 5): insertion sort below vector-setup cost, then
//! single-thread NEON-MS, then merge-path parallel. *Where* those
//! boundaries sit depends on the host — the width sweep proved the
//! best kernel config varies per machine, and the same is true of the
//! routing cutoffs. This module closes the loop at runtime:
//!
//! ```text
//! observe                  decide                    publish
//! ───────                  ──────                    ───────
//! workers record per-tier  every `epoch_jobs`        RoutingState
//! (len, sort-time) into    completions, one worker   (plain atomics)
//! Metrics::routes — incl.  diffs the observation     read by route()/
//! cross-boundary *probe*   grid since the last       fuse_eligible()
//! jobs (1 in 8 near a      epoch and compares the    on the worker
//! cutoff runs on the       two tiers' elements/µs    hot path — no
//! neighbor tier)           in the classes around     locks, no deps
//!                          each cutoff
//! ```
//!
//! # Why probing
//!
//! Under a static cutoff every request size is only ever executed by
//! one tier, so the telemetry alone can never say whether the *other*
//! tier would have been faster — the counterfactual is unobserved.
//! The router therefore sends a small deterministic fraction
//! (1/[`PROBE_PERIOD`]) of jobs whose length falls within one octave
//! of a cutoff to the neighboring tier. Probes are real requests,
//! sorted correctly either way; they differ only in which mechanism
//! runs, and their measurements populate the otherwise-dark side of
//! the boundary. Probes stay inside the `[cutoff/2, 2·cutoff)`
//! window, so a down-probe can cost at most one sort of `< 2·cutoff`
//! elements on the slower tier — bounded by the cutoff's own hard
//! upper bound below, never a 1M-element insertion sort.
//!
//! The comparison is **paired per size class**: only classes where
//! both tiers were observed this epoch count, because pooling
//! unpaired classes would reward whichever tier happened to run the
//! larger jobs (per-sort overhead amortizes with size), not the
//! faster mechanism at equal size.
//!
//! # Safety: hysteresis, min-sample floors, hard bounds
//!
//! Three guards keep a noisy epoch from wrecking routing:
//!
//! * **Min-sample floor** — a boundary is only judged when *both*
//!   tiers have ≥ [`MIN_SAMPLES`] jobs observed near it this epoch.
//! * **Hysteresis** — the faster side must win by ≥ [`HYSTERESIS`]
//!   (25%), and the same verdict must repeat for [`CONFIRM`]
//!   consecutive epochs, before a cutoff moves — one step (×2 or ÷2)
//!   per move, so alternating verdicts produce *no* movement instead
//!   of flapping.
//! * **Hard bounds** — every published value is clamped to
//!   [`RoutingBounds`], and the ordering invariant `tiny_cutoff ≤
//!   fuse_cutoff ≤ parallel_cutoff` is re-imposed on publish. However
//!   wrong the observations, a 1M-element job can never route to
//!   insertion sort because `bounds.tiny.1` caps `tiny_cutoff` (4096
//!   by default).
//!
//! All shared state is plain atomics ([`RoutingState`]) — the hot
//! path pays a handful of relaxed loads; the epoch tick runs under a
//! `try_lock` so exactly one worker pays for the decision.

use super::config::{CoordinatorConfig, Route};
use super::metrics::{
    size_class, throughput_elems_per_us as elems_per_us, Metrics, Tier, SIZE_CLASSES, TIER_COUNT,
};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// One probe per this many boundary-window jobs (per boundary side).
pub const PROBE_PERIOD: usize = 8;

/// Relative throughput advantage a tier must show before a cutoff
/// moves toward it (25%).
pub const HYSTERESIS: f64 = 0.25;

/// Minimum jobs observed on *each* side of a boundary, per epoch,
/// before the boundary is judged at all.
pub const MIN_SAMPLES: u64 = 8;

/// Consecutive epochs the same verdict must repeat before a move.
pub const CONFIRM: u8 = 2;

/// Hard per-parameter bounds `(min, max)` the tuner can never leave,
/// however lopsided the observations — the "safety rails" of the
/// adaptive policy. Defaults keep every tier in its sane regime:
/// `tiny` can never exceed 4096 (no large insertion sorts), `parallel`
/// can never drop below 64K (no thread-scope setup for small jobs).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RoutingBounds {
    /// `tiny_cutoff` range.
    pub tiny: (usize, usize),
    /// `fuse_cutoff` range.
    pub fuse: (usize, usize),
    /// `parallel_cutoff` range.
    pub parallel: (usize, usize),
    /// `batch_max` range (min ≥ 1; `1` disables fusing).
    pub batch: (usize, usize),
}

impl Default for RoutingBounds {
    fn default() -> Self {
        RoutingBounds {
            tiny: (8, 4096),
            fuse: (64, 1 << 16),
            parallel: (1 << 16, 1 << 22),
            batch: (1, 256),
        }
    }
}

impl RoutingBounds {
    /// `Ok(())` when every range is non-empty and `batch.0 ≥ 1`.
    pub(super) fn validate(&self) -> Result<(), String> {
        for (name, (lo, hi)) in [
            ("tiny", self.tiny),
            ("fuse", self.fuse),
            ("parallel", self.parallel),
            ("batch", self.batch),
        ] {
            if lo > hi {
                return Err(format!("adaptive bounds: {name} range ({lo}, {hi}) is empty"));
            }
        }
        if self.batch.0 == 0 {
            return Err("adaptive bounds: batch_max min must be ≥ 1".to_string());
        }
        // Order-compatibility: publish re-imposes tiny ≤ fuse ≤
        // parallel by raising the larger cutoffs, so each upper bound
        // must dominate the previous one or the raise could push a
        // value past its own bounds — the "clamped to bounds"
        // guarantee would silently break.
        if self.tiny.1 > self.fuse.1 || self.fuse.1 > self.parallel.1 {
            return Err(format!(
                "adaptive bounds: upper bounds must order tiny ({}) <= fuse ({}) <= parallel ({})",
                self.tiny.1, self.fuse.1, self.parallel.1
            ));
        }
        Ok(())
    }
}

/// Whether the service re-derives its routing cutoffs online.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub enum AdaptivePolicy {
    /// Static routing: the `CoordinatorConfig` cutoffs are used as-is
    /// for the life of the service (the pre-PR-4 behavior).
    #[default]
    Off,
    /// Epoch-based online tuning: every `epoch_jobs` completed
    /// requests, re-derive the cutoffs from the per-tier observations,
    /// clamped to `bounds`.
    Adaptive {
        /// Completed jobs per tuning epoch (≥ 1; default 256).
        epoch_jobs: u64,
        /// Hard safety bounds on every tunable.
        bounds: RoutingBounds,
    },
}

impl AdaptivePolicy {
    /// Adaptive with default epoch length and bounds.
    pub fn adaptive() -> Self {
        AdaptivePolicy::Adaptive { epoch_jobs: 256, bounds: RoutingBounds::default() }
    }

    /// True when tuning is enabled.
    pub fn is_on(&self) -> bool {
        matches!(self, AdaptivePolicy::Adaptive { .. })
    }
}

/// Point-in-time copy of the published routing parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RoutingSnapshot {
    pub tiny_cutoff: usize,
    pub fuse_cutoff: usize,
    pub parallel_cutoff: usize,
    pub batch_max: usize,
}

impl RoutingSnapshot {
    /// The tier ladder — the single shared implementation behind both
    /// [`CoordinatorConfig::route`] (static config values) and the
    /// live `RoutingState` (published atomics): below `tiny_cutoff` →
    /// insertion sort; `[xla_cutoff, parallel_cutoff)` with an
    /// executor available → XLA; at or above `parallel_cutoff` →
    /// merge-path parallel; otherwise single-thread NEON-MS.
    pub fn route(&self, len: usize, xla_available: bool, xla_cutoff: Option<usize>) -> Route {
        if len < self.tiny_cutoff {
            return Route::Tiny;
        }
        if let Some(x) = xla_cutoff {
            if xla_available && len >= x && len < self.parallel_cutoff {
                return Route::Xla;
            }
        }
        if len >= self.parallel_cutoff {
            Route::Parallel
        } else {
            Route::SingleThread
        }
    }

    /// True when a request of `len` may join a fused dynamic batch:
    /// batching on, small enough, and routed to a CPU tier the fused
    /// sort covers.
    pub fn fuse_eligible(
        &self,
        len: usize,
        xla_available: bool,
        xla_cutoff: Option<usize>,
    ) -> bool {
        self.batch_max > 1
            && len <= self.fuse_cutoff
            && matches!(
                self.route(len, xla_available, xla_cutoff),
                Route::Tiny | Route::SingleThread
            )
    }
}

/// One cutoff change the tuner committed, with the measurements that
/// drove it — the decision trace `serve-demo --adaptive` prints and
/// `benches/routing_adaptive.rs` records to JSON.
#[derive(Clone, Debug, PartialEq)]
pub struct Decision {
    /// Tuning epoch (1-based) the change was committed in.
    pub epoch: u64,
    /// `"tiny_cutoff"` | `"fuse_cutoff"` | `"parallel_cutoff"` |
    /// `"batch_max"`.
    pub param: &'static str,
    pub from: usize,
    pub to: usize,
    /// Observed elements/µs of the boundary's lower tier (smaller
    /// sizes / solo execution) this epoch.
    pub lo_elems_per_us: f64,
    /// Observed elements/µs of the boundary's upper tier (larger
    /// sizes / fused execution) this epoch.
    pub hi_elems_per_us: f64,
}

/// The live routing parameters, published by the tuner and read by
/// the worker hot path — plain atomics, no locks, no dependencies.
/// When the policy is [`AdaptivePolicy::Off`] the values are seeded
/// from the config and never change, so static routing behaves
/// exactly as before.
pub(super) struct RoutingState {
    tiny: AtomicUsize,
    fuse: AtomicUsize,
    parallel: AtomicUsize,
    batch_max: AtomicUsize,
    adaptive: bool,
    /// False when XLA offload is configured: the tuner then freezes
    /// the single/parallel boundary (see [`Tuner::new`]), so paying a
    /// single-threaded sort for a multi-megabyte down-probe would buy
    /// telemetry nobody reads — those probe arms are gated off.
    probe_parallel: bool,
    /// Deterministic clocks driving the 1/[`PROBE_PERIOD`] probes,
    /// one per boundary *side* (tiny-up, tiny-down, parallel-up,
    /// parallel-down) plus one for solo-execution probes of fused
    /// batch candidates, so one side's traffic pattern can never
    /// phase-lock another side out of probing. Each clock only
    /// advances for jobs inside its own window.
    probe_clocks: [AtomicUsize; PROBE_SLOTS],
}

/// [`RoutingState::probe_clocks`] slots.
const PROBE_TINY_UP: usize = 0;
const PROBE_TINY_DOWN: usize = 1;
const PROBE_PAR_UP: usize = 2;
const PROBE_PAR_DOWN: usize = 3;
/// Solo-execution probe for fused-batch candidates (see
/// [`RoutingState::solo_probe`]).
const PROBE_SOLO: usize = 4;
const PROBE_SLOTS: usize = 5;

impl RoutingState {
    /// `xla_configured` mirrors the tuner's frozen single/parallel
    /// boundary: when true, the parallel-side probe arms never fire.
    pub(super) fn new(cfg: &CoordinatorConfig, xla_configured: bool) -> Self {
        let (adaptive, seed) = match &cfg.adaptive {
            AdaptivePolicy::Off => (false, cfg.routing_snapshot()),
            // Clamp the config seeds into the bounds so the
            // invariants hold from the first request on.
            AdaptivePolicy::Adaptive { bounds, .. } => {
                (true, constrain(cfg.routing_snapshot(), bounds))
            }
        };
        RoutingState {
            tiny: AtomicUsize::new(seed.tiny_cutoff),
            fuse: AtomicUsize::new(seed.fuse_cutoff),
            parallel: AtomicUsize::new(seed.parallel_cutoff),
            batch_max: AtomicUsize::new(seed.batch_max),
            adaptive,
            probe_parallel: adaptive && !xla_configured,
            probe_clocks: Default::default(),
        }
    }

    pub(super) fn snapshot(&self) -> RoutingSnapshot {
        RoutingSnapshot {
            tiny_cutoff: self.tiny.load(Ordering::Relaxed),
            fuse_cutoff: self.fuse.load(Ordering::Relaxed),
            parallel_cutoff: self.parallel.load(Ordering::Relaxed),
            batch_max: self.batch_max.load(Ordering::Relaxed),
        }
    }

    pub(super) fn batch_max(&self) -> usize {
        self.batch_max.load(Ordering::Relaxed)
    }

    fn publish(&self, s: RoutingSnapshot) {
        self.tiny.store(s.tiny_cutoff, Ordering::Relaxed);
        self.fuse.store(s.fuse_cutoff, Ordering::Relaxed);
        self.parallel.store(s.parallel_cutoff, Ordering::Relaxed);
        self.batch_max.store(s.batch_max, Ordering::Relaxed);
    }

    /// Route a request of `len` elements against the *live* cutoffs —
    /// [`RoutingSnapshot::route`] over the published atomics.
    pub(super) fn route(
        &self,
        len: usize,
        xla_available: bool,
        xla_cutoff: Option<usize>,
    ) -> Route {
        self.snapshot().route(len, xla_available, xla_cutoff)
    }

    /// [`RoutingState::route`], plus boundary probing when adaptive:
    /// 1 in [`PROBE_PERIOD`] jobs whose length falls within one octave
    /// of the tiny or parallel cutoff executes on the neighboring tier
    /// so the tuner observes both sides of the boundary. Never probes
    /// outside the `[cutoff/2, 2·cutoff)` window, so the extra cost is
    /// bounded by the cutoff's hard upper bound.
    pub(super) fn route_probed(
        &self,
        len: usize,
        xla_available: bool,
        xla_cutoff: Option<usize>,
    ) -> Route {
        let natural = self.route(len, xla_available, xla_cutoff);
        if !self.adaptive {
            return natural;
        }
        let tiny = self.tiny.load(Ordering::Relaxed);
        let parallel = self.parallel.load(Ordering::Relaxed);
        match natural {
            // Up-probe: top half of the tiny range → vector tier.
            Route::Tiny if 2 * len >= tiny && self.probe(PROBE_TINY_UP) => Route::SingleThread,
            Route::SingleThread => {
                if len < 2 * tiny && self.probe(PROBE_TINY_DOWN) {
                    // Down-probe: first octave above tiny → insertion
                    // sort (≤ 2·bounds.tiny.1 elements, bounded).
                    Route::Tiny
                } else if self.probe_parallel && 2 * len >= parallel && self.probe(PROBE_PAR_UP)
                {
                    // Up-probe: top octave below parallel → threads.
                    Route::Parallel
                } else {
                    natural
                }
            }
            // Down-probe: first octave above parallel → single thread.
            Route::Parallel
                if self.probe_parallel && len < 2 * parallel && self.probe(PROBE_PAR_DOWN) =>
            {
                Route::SingleThread
            }
            _ => natural,
        }
    }

    fn probe(&self, side: usize) -> bool {
        self.probe_clocks[side].fetch_add(1, Ordering::Relaxed) % PROBE_PERIOD == 0
    }

    /// Solo-execution probe: when adaptive, 1 in [`PROBE_PERIOD`]
    /// fused-batch candidates is pulled out of the batch and executed
    /// solo instead. Under sustained load the batcher would otherwise
    /// fuse *every* small job, starving the Tiny/Single observation
    /// classes — and with them both the boundary verdicts and the
    /// solo side of the fused-vs-solo comparison — exactly when there
    /// is the most signal to learn from. Always `false` when the
    /// policy is off (static batching untouched).
    pub(super) fn solo_probe(&self) -> bool {
        self.adaptive && self.probe(PROBE_SOLO)
    }

    /// Live-cutoff version of [`CoordinatorConfig::fuse_eligible`]
    /// ([`RoutingSnapshot::fuse_eligible`] over the atomics).
    pub(super) fn fuse_eligible(
        &self,
        len: usize,
        xla_available: bool,
        xla_cutoff: Option<usize>,
    ) -> bool {
        self.snapshot().fuse_eligible(len, xla_available, xla_cutoff)
    }
}

/// Clamp a candidate parameter set to `bounds` and re-impose the
/// tier-ordering invariant `tiny ≤ fuse ≤ parallel`.
fn constrain(mut s: RoutingSnapshot, b: &RoutingBounds) -> RoutingSnapshot {
    s.tiny_cutoff = s.tiny_cutoff.clamp(b.tiny.0, b.tiny.1);
    s.fuse_cutoff = s.fuse_cutoff.clamp(b.fuse.0, b.fuse.1).max(s.tiny_cutoff);
    s.parallel_cutoff = s.parallel_cutoff.clamp(b.parallel.0, b.parallel.1).max(s.fuse_cutoff);
    s.batch_max = s.batch_max.clamp(b.batch.0, b.batch.1);
    s
}

/// A `(jobs, elements, busy_ns)` grid per `[tier][size class]` — one
/// shape for both roles the tick needs: the cumulative totals as of
/// the last tick, and the per-epoch deltas [`TunerCore::step`]
/// consumes ([`ObsGrid::absorb`] turns the former into the latter).
struct ObsGrid {
    jobs: [[u64; SIZE_CLASSES]; TIER_COUNT],
    elements: [[u64; SIZE_CLASSES]; TIER_COUNT],
    busy_ns: [[u64; SIZE_CLASSES]; TIER_COUNT],
}

impl ObsGrid {
    fn zero() -> Self {
        ObsGrid {
            jobs: [[0; SIZE_CLASSES]; TIER_COUNT],
            elements: [[0; SIZE_CLASSES]; TIER_COUNT],
            busy_ns: [[0; SIZE_CLASSES]; TIER_COUNT],
        }
    }

    /// Read the live cumulative totals out of `m`, returning the
    /// delta against `self` (the totals at the previous absorb) and
    /// updating `self` to the new totals — one call per epoch tick.
    fn absorb(&mut self, m: &Metrics) -> ObsGrid {
        let mut delta = ObsGrid::zero();
        for tier in Tier::all() {
            let route = m.routes.get(tier);
            let t = tier.index();
            for c in 0..SIZE_CLASSES {
                let (j, e, n) = route.class_totals(c);
                delta.jobs[t][c] = j.saturating_sub(self.jobs[t][c]);
                delta.elements[t][c] = e.saturating_sub(self.elements[t][c]);
                delta.busy_ns[t][c] = n.saturating_sub(self.busy_ns[t][c]);
                self.jobs[t][c] = j;
                self.elements[t][c] = e;
                self.busy_ns[t][c] = n;
            }
        }
        delta
    }

    /// Class totals of one tier at one class.
    fn at(&self, tier: Tier, c: usize) -> (u64, u64, u64) {
        let t = tier.index();
        (self.jobs[t][c], self.elements[t][c], self.busy_ns[t][c])
    }

    /// Pool two tiers over `[lo, hi]`, including only the classes
    /// where **both** tiers executed at least one job this epoch.
    ///
    /// Pooling unpaired classes would compare different size mixes:
    /// elements/µs grows with request size as fixed per-sort overhead
    /// amortizes, so the tier running the larger jobs would win the
    /// aggregate regardless of which mechanism is actually faster at
    /// equal size. Near a cutoff the natural traffic of the two tiers
    /// sits on *opposite* sides of it; the probes exist precisely to
    /// give each tier samples in the other's classes, and this
    /// pairing restricts the comparison to those shared classes.
    fn paired(
        &self,
        lo_tier: Tier,
        hi_tier: Tier,
        lo: usize,
        hi: usize,
    ) -> ((u64, u64, u64), (u64, u64, u64)) {
        let (mut l, mut h) = ((0, 0, 0), (0, 0, 0));
        for c in lo..=hi.min(SIZE_CLASSES - 1) {
            let lc = self.at(lo_tier, c);
            let hc = self.at(hi_tier, c);
            if lc.0 > 0 && hc.0 > 0 {
                l = (l.0 + lc.0, l.1 + lc.1, l.2 + lc.2);
                h = (h.0 + hc.0, h.1 + hc.1, h.2 + hc.2);
            }
        }
        (l, h)
    }
}

/// Which way a boundary verdict points: `-1` = lower the cutoff (the
/// upper tier measured faster near the boundary), `+1` = raise it.
type Verdict = Option<(i8, f64, f64)>;

/// The shared verdict rule: given two pooled `(jobs, elements,
/// busy_ns)` sides, apply the [`MIN_SAMPLES`] floor, then require a
/// [`HYSTERESIS`] throughput lead. `-1` = the `hi` side won.
fn verdict_from(lo: (u64, u64, u64), hi: (u64, u64, u64)) -> Verdict {
    if lo.0 < MIN_SAMPLES || hi.0 < MIN_SAMPLES {
        return None;
    }
    let lo_eu = elems_per_us(lo.1, lo.2);
    let hi_eu = elems_per_us(hi.1, hi.2);
    if hi_eu > lo_eu * (1.0 + HYSTERESIS) {
        Some((-1, lo_eu, hi_eu))
    } else if lo_eu > hi_eu * (1.0 + HYSTERESIS) {
        Some((1, lo_eu, hi_eu))
    } else {
        None
    }
}

/// Confirmation memory for one tunable parameter.
#[derive(Clone, Copy, Default)]
struct ParamMemory {
    /// Direction of the current verdict streak (0 = none).
    dir: i8,
    /// Consecutive epochs the verdict has pointed in `dir`.
    streak: u8,
}

/// The decision engine: pure state machine over epoch observations —
/// no clocks, no atomics — so convergence, hysteresis, and clamping
/// are unit-testable without a running service.
struct TunerCore {
    bounds: RoutingBounds,
    /// False while XLA offload is configured: jobs below
    /// `parallel_cutoff` then route to the accelerator, so the
    /// Single-vs-Parallel verdict would re-partition traffic between
    /// Xla and Parallel based on a tier (Single) that carries almost
    /// none of it — hold that boundary instead. (Learning
    /// `xla_cutoff` itself is a ROADMAP follow-on.)
    tune_parallel: bool,
    epoch: u64,
    tiny_mem: ParamMemory,
    parallel_mem: ParamMemory,
    fuse_mem: ParamMemory,
}

impl TunerCore {
    fn new(bounds: RoutingBounds, tune_parallel: bool) -> Self {
        TunerCore {
            bounds,
            tune_parallel,
            epoch: 0,
            tiny_mem: ParamMemory::default(),
            parallel_mem: ParamMemory::default(),
            fuse_mem: ParamMemory::default(),
        }
    }

    /// Judge one boundary: compare the two tiers' throughput over the
    /// classes within one octave of `cutoff`, restricted to classes
    /// both tiers were observed in ([`ObsGrid::paired`] — unpaired
    /// pooling would reward whichever tier ran the larger jobs).
    /// `None` when either side lacks [`MIN_SAMPLES`] or neither wins
    /// by [`HYSTERESIS`].
    fn boundary_verdict(obs: &ObsGrid, lo_tier: Tier, hi_tier: Tier, cutoff: usize) -> Verdict {
        let c = size_class(cutoff);
        let (lo, hi) = obs.paired(lo_tier, hi_tier, c.saturating_sub(1), c + 1);
        verdict_from(lo, hi)
    }

    /// Fold a verdict into a parameter's confirmation memory; returns
    /// the confirmed direction once the same verdict has repeated
    /// [`CONFIRM`] epochs in a row (then resets, so the *next* move
    /// needs fresh confirmation too).
    fn confirm(mem: &mut ParamMemory, verdict: Verdict) -> Option<(i8, f64, f64)> {
        match verdict {
            None => {
                *mem = ParamMemory::default();
                None
            }
            Some((dir, lo, hi)) => {
                if mem.dir == dir {
                    mem.streak += 1;
                } else {
                    mem.dir = dir;
                    mem.streak = 1;
                }
                if mem.streak >= CONFIRM {
                    *mem = ParamMemory::default();
                    Some((dir, lo, hi))
                } else {
                    None
                }
            }
        }
    }

    /// One ×2/÷2 step of `value` in `dir`, clamped to `(min, max)`.
    fn step_value(value: usize, dir: i8, (min, max): (usize, usize)) -> usize {
        if dir < 0 {
            (value / 2).clamp(min, max)
        } else {
            value.saturating_mul(2).clamp(min, max)
        }
    }

    /// One tuning epoch: consume the observation deltas, return the
    /// next parameter set (bounds-clamped, ordering-constrained) and
    /// the decision records for every parameter that moved.
    fn step(&mut self, obs: &ObsGrid, cur: RoutingSnapshot) -> (RoutingSnapshot, Vec<Decision>) {
        self.epoch += 1;
        let mut next = cur;

        // Boundary 1: insertion sort vs single-thread vector sort.
        let tiny_v = Self::confirm(
            &mut self.tiny_mem,
            Self::boundary_verdict(obs, Tier::Tiny, Tier::Single, cur.tiny_cutoff),
        );
        if let Some((dir, _, _)) = tiny_v {
            next.tiny_cutoff = Self::step_value(cur.tiny_cutoff, dir, self.bounds.tiny);
        }

        // Boundary 2: single-thread vs merge-path parallel. Held when
        // XLA offload is configured (see `tune_parallel`).
        let parallel_v = if self.tune_parallel {
            Self::confirm(
                &mut self.parallel_mem,
                Self::boundary_verdict(obs, Tier::Single, Tier::Parallel, cur.parallel_cutoff),
            )
        } else {
            None
        };
        if let Some((dir, _, _)) = parallel_v {
            next.parallel_cutoff = Self::step_value(cur.parallel_cutoff, dir, self.bounds.parallel);
        }

        // Fusing: fused-batch execution vs solo (tiny + single) over
        // the classes at or below the fuse cutoff — paired per class
        // like the boundaries (only classes where both fused and solo
        // execution were observed count). Fused faster → fuse more
        // (raise fuse_cutoff, grow batch_max); solo faster → fuse
        // less. dir < 0 means "the fused side won", mirroring the
        // boundary verdicts' "upper tier won" sense.
        let fc = size_class(cur.fuse_cutoff);
        let (mut solo, mut fused) = ((0u64, 0u64, 0u64), (0u64, 0u64, 0u64));
        for c in 0..=fc.min(SIZE_CLASSES - 1) {
            let t = obs.at(Tier::Tiny, c);
            let s = obs.at(Tier::Single, c);
            let f = obs.at(Tier::Fused, c);
            if t.0 + s.0 > 0 && f.0 > 0 {
                solo = (solo.0 + t.0 + s.0, solo.1 + t.1 + s.1, solo.2 + t.2 + s.2);
                fused = (fused.0 + f.0, fused.1 + f.1, fused.2 + f.2);
            }
        }
        let fuse_v = Self::confirm(&mut self.fuse_mem, verdict_from(solo, fused));
        if let Some((dir, _, _)) = fuse_v {
            // dir < 0 (fused won): more fusing; dir > 0: less.
            next.fuse_cutoff = Self::step_value(cur.fuse_cutoff, -dir, self.bounds.fuse);
            let mut bm = Self::step_value(cur.batch_max, -dir, self.bounds.batch);
            // Never self-disable fusing: at batch_max = 1 nothing
            // fuses, the Fused tier stops producing observations, and
            // the min-sample floor would lock this verdict to `None`
            // forever — an unrecoverable ratchet. The tuner throttles
            // to 2 at most; only explicit config/bounds can turn
            // fusing off outright.
            if dir > 0 && bm < 2 {
                bm = 2usize.clamp(self.bounds.batch.0, self.bounds.batch.1);
            }
            next.batch_max = bm;
        }

        let next = constrain(next, &self.bounds);
        // A param may also move without its own verdict when the
        // ordering constraint drags it along; record 0.0 gauges then.
        let measured = |v: Option<(i8, f64, f64)>| match v {
            Some((_, lo, hi)) => (lo, hi),
            None => (0.0, 0.0),
        };
        let mut decisions = Vec::new();
        for (param, from, to, v) in [
            ("tiny_cutoff", cur.tiny_cutoff, next.tiny_cutoff, tiny_v),
            ("fuse_cutoff", cur.fuse_cutoff, next.fuse_cutoff, fuse_v),
            ("parallel_cutoff", cur.parallel_cutoff, next.parallel_cutoff, parallel_v),
            ("batch_max", cur.batch_max, next.batch_max, fuse_v),
        ] {
            if from != to {
                let (lo, hi) = measured(v);
                decisions.push(Decision {
                    epoch: self.epoch,
                    param,
                    from,
                    to,
                    lo_elems_per_us: lo,
                    hi_elems_per_us: hi,
                });
            }
        }
        (next, decisions)
    }
}

/// The epoch controller: owns the decision engine and the last-tick
/// snapshot behind a mutex (contended only by the losing `try_lock`
/// callers, who simply skip), plus the append-only decision trace.
pub(super) struct Tuner {
    epoch_jobs: u64,
    inner: Mutex<TunerInner>,
    decisions: Mutex<Vec<Decision>>,
}

struct TunerInner {
    core: TunerCore,
    /// Cumulative totals as of the last tick ([`ObsGrid::absorb`]).
    last: ObsGrid,
    last_completed: u64,
}

/// Cap on the retained decision trace (the tuner keeps deciding past
/// it; only the record stops growing).
const MAX_DECISIONS: usize = 1024;

impl Tuner {
    /// `tune_parallel: false` freezes the single/parallel boundary —
    /// used when XLA offload is active, since the traffic below
    /// `parallel_cutoff` then runs on the accelerator and the
    /// Single-vs-Parallel comparison would not describe it.
    pub(super) fn new(epoch_jobs: u64, bounds: RoutingBounds, tune_parallel: bool) -> Self {
        Tuner {
            epoch_jobs: epoch_jobs.max(1),
            inner: Mutex::new(TunerInner {
                core: TunerCore::new(bounds, tune_parallel),
                last: ObsGrid::zero(),
                last_completed: 0,
            }),
            decisions: Mutex::new(Vec::new()),
        }
    }

    /// Worker-wakeup hook: if an epoch's worth of jobs has completed
    /// since the last tick, diff the observation grid, run one
    /// decision step, and publish the result. `try_lock` keeps this
    /// off the hot path — at most one worker pays per epoch, the rest
    /// skip in a few nanoseconds.
    pub(super) fn maybe_tick(&self, m: &Metrics, routing: &RoutingState) {
        let completed = m.completed.load(Ordering::Relaxed);
        let Ok(mut inner) = self.inner.try_lock() else {
            return;
        };
        if completed.saturating_sub(inner.last_completed) < self.epoch_jobs {
            return;
        }
        inner.last_completed = completed;
        let obs = inner.last.absorb(m);
        let (next, decisions) = inner.core.step(&obs, routing.snapshot());
        routing.publish(next);
        drop(inner);
        if !decisions.is_empty() {
            let mut log = self.decisions.lock().unwrap();
            let room = MAX_DECISIONS.saturating_sub(log.len());
            log.extend(decisions.into_iter().take(room));
        }
    }

    /// The committed decision trace so far.
    pub(super) fn decisions(&self) -> Vec<Decision> {
        self.decisions.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build an ObsGrid where `tier` executed `jobs` jobs of
    /// `len`-element requests at `eu` elements/µs.
    fn obs_point(obs: &mut ObsGrid, tier: Tier, len: usize, jobs: u64, eu: f64) {
        let elements = jobs * len as u64;
        let busy_ns = (elements as f64 * 1e3 / eu) as u64;
        let c = size_class(len);
        // Accumulate (set adds per class; combine with any prior).
        let t = tier.index();
        obs.jobs[t][c] += jobs;
        obs.elements[t][c] += elements;
        obs.busy_ns[t][c] += busy_ns;
    }

    fn snap(tiny: usize, fuse: usize, parallel: usize, batch: usize) -> RoutingSnapshot {
        RoutingSnapshot {
            tiny_cutoff: tiny,
            fuse_cutoff: fuse,
            parallel_cutoff: parallel,
            batch_max: batch,
        }
    }

    /// Epoch where the single-thread tier clearly beats insertion
    /// sort around the tiny boundary.
    fn single_wins_at(cur: RoutingSnapshot) -> ObsGrid {
        let mut o = ObsGrid::zero();
        obs_point(&mut o, Tier::Tiny, cur.tiny_cutoff / 2, 20, 10.0);
        obs_point(&mut o, Tier::Single, cur.tiny_cutoff / 2, 20, 40.0);
        o
    }

    #[test]
    fn converges_toward_better_tier_and_clamps_at_bounds() {
        let bounds = RoutingBounds::default();
        let mut core = TunerCore::new(bounds.clone(), true);
        let mut cur = snap(256, 4096, 1 << 20, 32);
        let mut moved = 0;
        for _ in 0..32 {
            let obs = single_wins_at(cur);
            let (next, ds) = core.step(&obs, cur);
            if next.tiny_cutoff != cur.tiny_cutoff {
                assert!(next.tiny_cutoff < cur.tiny_cutoff, "must move toward the faster tier");
                assert_eq!(ds.len(), 1);
                assert_eq!(ds[0].param, "tiny_cutoff");
                assert!(ds[0].hi_elems_per_us > ds[0].lo_elems_per_us);
                moved += 1;
            }
            cur = next;
        }
        assert!(moved >= 2, "a persistent signal must move the cutoff, got {moved} moves");
        assert_eq!(
            cur.tiny_cutoff, bounds.tiny.0,
            "persistent signal converges to the hard lower bound, never past it"
        );
    }

    #[test]
    fn confirmation_requires_consecutive_epochs() {
        // One winning epoch is not enough (CONFIRM = 2): the first
        // verdict arms the streak, the second commits the move.
        let mut core = TunerCore::new(RoutingBounds::default(), true);
        let cur = snap(256, 4096, 1 << 20, 32);
        let (next, ds) = core.step(&single_wins_at(cur), cur);
        assert_eq!(next, cur, "first verdict must not move anything");
        assert!(ds.is_empty());
        let (next, _) = core.step(&single_wins_at(cur), cur);
        assert_eq!(next.tiny_cutoff, cur.tiny_cutoff / 2, "second consecutive verdict commits");
    }

    #[test]
    fn hysteresis_no_flapping_on_alternating_workloads() {
        // Verdicts that alternate direction every epoch never reach
        // CONFIRM consecutive agreements, so the cutoff never moves —
        // the no-flap property.
        let mut core = TunerCore::new(RoutingBounds::default(), true);
        let cur = snap(256, 4096, 1 << 20, 32);
        for i in 0..16 {
            let mut o = ObsGrid::zero();
            let (tiny_eu, single_eu) = if i % 2 == 0 { (10.0, 40.0) } else { (40.0, 10.0) };
            obs_point(&mut o, Tier::Tiny, 128, 20, tiny_eu);
            obs_point(&mut o, Tier::Single, 128, 20, single_eu);
            let (next, ds) = core.step(&o, cur);
            assert_eq!(next, cur, "alternating verdicts must not move cutoffs (epoch {i})");
            assert!(ds.is_empty());
        }
    }

    #[test]
    fn within_hysteresis_band_is_a_hold() {
        // A 10% advantage is inside the 25% band: no verdict, and the
        // streak resets so it can't slow-walk into a move either.
        let mut core = TunerCore::new(RoutingBounds::default(), true);
        let cur = snap(256, 4096, 1 << 20, 32);
        for _ in 0..8 {
            let mut o = ObsGrid::zero();
            obs_point(&mut o, Tier::Tiny, 128, 50, 10.0);
            obs_point(&mut o, Tier::Single, 128, 50, 11.0);
            let (next, ds) = core.step(&o, cur);
            assert_eq!(next, cur);
            assert!(ds.is_empty());
        }
    }

    #[test]
    fn min_sample_floor_blocks_noisy_epochs() {
        // Huge measured advantage but too few samples: hold.
        let mut core = TunerCore::new(RoutingBounds::default(), true);
        let cur = snap(256, 4096, 1 << 20, 32);
        for _ in 0..8 {
            let mut o = ObsGrid::zero();
            obs_point(&mut o, Tier::Tiny, 128, MIN_SAMPLES - 1, 1.0);
            obs_point(&mut o, Tier::Single, 128, 100, 100.0);
            let (next, ds) = core.step(&o, cur);
            assert_eq!(next, cur, "min-sample floor must gate the verdict");
            assert!(ds.is_empty());
        }
    }

    #[test]
    fn bounds_and_ordering_invariant_always_hold() {
        // Drive every boundary hard in both directions with extreme
        // observations; whatever happens, published values stay inside
        // bounds and tiny ≤ fuse ≤ parallel.
        let bounds = RoutingBounds {
            tiny: (16, 128),
            fuse: (32, 1024),
            parallel: (2048, 1 << 18),
            batch: (1, 64),
        };
        let mut core = TunerCore::new(bounds.clone(), true);
        let mut cur = constrain(snap(64, 512, 4096, 16), &bounds);
        for round in 0..64 {
            let mut o = ObsGrid::zero();
            let flip = round % 4 < 2;
            let (a, b) = if flip { (1.0, 1000.0) } else { (1000.0, 1.0) };
            obs_point(&mut o, Tier::Tiny, cur.tiny_cutoff.max(2) / 2, 50, a);
            obs_point(&mut o, Tier::Single, cur.tiny_cutoff.max(2) / 2, 50, b);
            obs_point(&mut o, Tier::Single, cur.parallel_cutoff / 2, 50, a);
            obs_point(&mut o, Tier::Parallel, cur.parallel_cutoff / 2, 50, b);
            obs_point(&mut o, Tier::Fused, cur.fuse_cutoff / 2, 50, b);
            let (next, _) = core.step(&o, cur);
            assert!(next.tiny_cutoff >= bounds.tiny.0 && next.tiny_cutoff <= bounds.tiny.1);
            assert!(next.fuse_cutoff >= bounds.fuse.0 && next.fuse_cutoff <= bounds.fuse.1);
            assert!(
                next.parallel_cutoff >= bounds.parallel.0
                    && next.parallel_cutoff <= bounds.parallel.1
            );
            assert!(next.batch_max >= bounds.batch.0 && next.batch_max <= bounds.batch.1);
            assert!(next.tiny_cutoff <= next.fuse_cutoff);
            assert!(next.fuse_cutoff <= next.parallel_cutoff);
            cur = next;
        }
    }

    #[test]
    fn parallel_boundary_frozen_while_xla_offload_is_active() {
        // tune_parallel = false (XLA configured): even a persistent,
        // decisive Single-vs-Parallel signal must not move
        // parallel_cutoff — the traffic below it routes to the
        // accelerator, which this comparison says nothing about. The
        // tiny boundary keeps tuning normally.
        let mut core = TunerCore::new(RoutingBounds::default(), false);
        let mut cur = snap(256, 4096, 1 << 20, 32);
        for _ in 0..8 {
            let mut o = ObsGrid::zero();
            obs_point(&mut o, Tier::Single, 1 << 19, 50, 10.0);
            obs_point(&mut o, Tier::Parallel, 1 << 19, 50, 100.0);
            obs_point(&mut o, Tier::Tiny, 128, 20, 10.0);
            obs_point(&mut o, Tier::Single, 128, 20, 40.0);
            let (next, _) = core.step(&o, cur);
            assert_eq!(next.parallel_cutoff, cur.parallel_cutoff, "parallel boundary held");
            cur = next;
        }
        assert!(cur.tiny_cutoff < 256, "tiny boundary still tunes while parallel is frozen");
    }

    #[test]
    fn unpaired_size_classes_never_drive_a_verdict() {
        // The boundary comparison must not reward a tier for running
        // bigger jobs: here every Tiny sample sits in the class below
        // the cutoff and every Single sample in the class above, with
        // Single's aggregate elements/µs far higher purely because its
        // jobs are larger. No shared class → no verdict → no move.
        let mut core = TunerCore::new(RoutingBounds::default(), true);
        let cur = snap(256, 4096, 1 << 20, 32);
        for _ in 0..8 {
            let mut o = ObsGrid::zero();
            obs_point(&mut o, Tier::Tiny, 140, 50, 10.0); // class 7, below 256
            obs_point(&mut o, Tier::Single, 300, 50, 80.0); // class 8, above 256
            let (next, ds) = core.step(&o, cur);
            assert_eq!(next, cur, "size-mix bias must not move the cutoff");
            assert!(ds.is_empty());
        }
        // With probe samples pairing the below-cutoff class, the
        // within-class comparison decides — here Tiny genuinely wins
        // at its own sizes despite Single's bigger-job aggregate.
        let mut core = TunerCore::new(RoutingBounds::default(), true);
        let mut cur = snap(256, 4096, 1 << 20, 32);
        for _ in 0..2 {
            let mut o = ObsGrid::zero();
            obs_point(&mut o, Tier::Tiny, 140, 50, 30.0);
            obs_point(&mut o, Tier::Single, 140, 10, 10.0); // probes, same class
            obs_point(&mut o, Tier::Single, 300, 50, 80.0); // unpaired: ignored
            let (next, _) = core.step(&o, cur);
            cur = next;
        }
        assert_eq!(cur.tiny_cutoff, 512, "paired comparison raises toward the real winner");
    }

    #[test]
    fn batch_max_never_ratchets_to_one() {
        // Persistent solo-wins verdicts shrink batching, but the tuner
        // must stop at 2: batch_max = 1 would end Fused observations
        // and the min-sample floor would lock fusing off forever.
        let mut core = TunerCore::new(RoutingBounds::default(), true);
        let mut cur = snap(8, 1024, 1 << 20, 8);
        for _ in 0..12 {
            let mut o = ObsGrid::zero();
            // len 40 (class 5) stays inside the fuse window even once
            // fuse_cutoff has shrunk to its 64-element lower bound.
            obs_point(&mut o, Tier::Single, 40, 30, 50.0);
            obs_point(&mut o, Tier::Fused, 40, 30, 10.0);
            let (next, _) = core.step(&o, cur);
            cur = next;
        }
        assert_eq!(cur.batch_max, 2, "tuner throttles fusing but never disables it");
        assert_eq!(cur.fuse_cutoff, RoutingBounds::default().fuse.0);
    }

    #[test]
    fn fused_advantage_grows_batching_solo_advantage_shrinks_it() {
        let mut core = TunerCore::new(RoutingBounds::default(), true);
        let mut cur = snap(64, 1024, 1 << 20, 8);
        // Fused clearly faster for two consecutive epochs → both
        // fuse_cutoff and batch_max grow one step.
        for _ in 0..2 {
            let mut o = ObsGrid::zero();
            obs_point(&mut o, Tier::Single, 512, 30, 10.0);
            obs_point(&mut o, Tier::Fused, 512, 30, 30.0);
            let (next, _) = core.step(&o, cur);
            cur = next;
        }
        assert_eq!(cur.fuse_cutoff, 2048, "fused won → fuse more");
        assert_eq!(cur.batch_max, 16, "fused won → bigger batches");
        // Now solo clearly faster → both shrink again.
        for _ in 0..2 {
            let mut o = ObsGrid::zero();
            obs_point(&mut o, Tier::Single, 512, 30, 50.0);
            obs_point(&mut o, Tier::Fused, 512, 30, 10.0);
            let (next, _) = core.step(&o, cur);
            cur = next;
        }
        assert_eq!(cur.fuse_cutoff, 1024);
        assert_eq!(cur.batch_max, 8);
    }

    #[test]
    fn routing_state_probes_only_inside_the_window() {
        let cfg = CoordinatorConfig {
            tiny_cutoff: 64,
            parallel_cutoff: 1 << 20,
            adaptive: AdaptivePolicy::adaptive(),
            ..Default::default()
        };
        let state = RoutingState::new(&cfg, false);
        // Far outside any boundary window: never probed, whatever the
        // probe clock says.
        for _ in 0..64 {
            assert_eq!(state.route_probed(8, false, None), Route::Tiny);
            assert_eq!(state.route_probed(4096, false, None), Route::SingleThread);
            assert_eq!(state.route_probed(1 << 23, false, None), Route::Parallel);
        }
        // Inside the tiny window: exactly 1 in PROBE_PERIOD goes to
        // the neighbor tier.
        let mut probed = 0;
        for _ in 0..(PROBE_PERIOD * 8) {
            if state.route_probed(48, false, None) == Route::SingleThread {
                probed += 1;
            }
        }
        assert_eq!(probed, 8, "1/{PROBE_PERIOD} of boundary-window jobs probe");
    }

    #[test]
    fn parallel_probes_gated_off_while_xla_configured() {
        // With XLA configured the tuner freezes the single/parallel
        // boundary, so its probes must not fire either — a down-probe
        // would pay a single-threaded multi-megabyte sort for
        // telemetry nobody reads. The tiny boundary keeps probing.
        let cfg = CoordinatorConfig {
            tiny_cutoff: 64,
            parallel_cutoff: 1 << 20,
            adaptive: AdaptivePolicy::adaptive(),
            ..Default::default()
        };
        let state = RoutingState::new(&cfg, true);
        for _ in 0..(PROBE_PERIOD * 8) {
            assert_eq!(
                state.route_probed((1 << 20) + 1, false, None),
                Route::Parallel,
                "no down-probes while the parallel boundary is frozen"
            );
            assert_eq!(
                state.route_probed((1 << 19) + 1, false, None),
                Route::SingleThread,
                "no up-probes while the parallel boundary is frozen"
            );
        }
        let mut tiny_probes = 0;
        for _ in 0..(PROBE_PERIOD * 8) {
            if state.route_probed(48, false, None) == Route::SingleThread {
                tiny_probes += 1;
            }
        }
        assert_eq!(tiny_probes, 8, "tiny boundary probing unaffected");
    }

    #[test]
    fn routing_state_static_when_policy_off() {
        let cfg = CoordinatorConfig::default();
        let state = RoutingState::new(&cfg, false);
        for _ in 0..64 {
            assert_eq!(state.route_probed(63, false, None), Route::Tiny, "no probes when off");
        }
        let s = state.snapshot();
        assert_eq!(s.tiny_cutoff, cfg.tiny_cutoff);
        assert_eq!(s.fuse_cutoff, cfg.fuse_cutoff);
        assert_eq!(s.parallel_cutoff, cfg.parallel_cutoff);
        assert_eq!(s.batch_max, cfg.batch_max);
    }

    #[test]
    fn adaptive_seed_is_clamped_into_bounds() {
        let cfg = CoordinatorConfig {
            tiny_cutoff: 1 << 20, // absurd seed
            adaptive: AdaptivePolicy::adaptive(),
            ..Default::default()
        };
        let s = RoutingState::new(&cfg, false).snapshot();
        assert_eq!(s.tiny_cutoff, RoutingBounds::default().tiny.1);
        assert!(s.tiny_cutoff <= s.fuse_cutoff && s.fuse_cutoff <= s.parallel_cutoff);
    }

    #[test]
    fn bounds_validation() {
        assert!(RoutingBounds::default().validate().is_ok());
        let empty = RoutingBounds { tiny: (64, 8), ..Default::default() };
        assert!(empty.validate().is_err());
        let zero_batch = RoutingBounds { batch: (0, 4), ..Default::default() };
        assert!(zero_batch.validate().is_err());
        // Order-incompatible upper bounds: the ordering constraint
        // could push parallel above its own max — must be rejected so
        // the "clamped to bounds" guarantee holds unconditionally.
        let crossed = RoutingBounds {
            fuse: (1 << 20, 1 << 21),
            parallel: (1 << 16, 1 << 18),
            ..Default::default()
        };
        assert!(crossed.validate().is_err());
    }

    #[test]
    fn probe_clocks_are_independent_per_boundary_side() {
        // Strictly alternating tiny-window / parallel-window traffic:
        // with one shared clock, one boundary could phase-lock the
        // other out of probing entirely. Each side owns its clock, so
        // both boundaries probe at the full 1/PROBE_PERIOD rate.
        let cfg = CoordinatorConfig {
            tiny_cutoff: 64,
            parallel_cutoff: 1 << 20,
            adaptive: AdaptivePolicy::adaptive(),
            ..Default::default()
        };
        let state = RoutingState::new(&cfg, false);
        let (mut tiny_probes, mut par_probes) = (0, 0);
        for _ in 0..(PROBE_PERIOD * 8) {
            if state.route_probed(48, false, None) == Route::SingleThread {
                tiny_probes += 1;
            }
            if state.route_probed((1 << 19) + 1, false, None) == Route::Parallel {
                par_probes += 1;
            }
        }
        assert_eq!(tiny_probes, 8, "tiny boundary probes at full rate");
        assert_eq!(par_probes, 8, "parallel boundary probes at full rate despite interleaving");
    }
}
