//! Deterministic fault injection for the failure-domain tests and
//! benches: a seeded [`FaultPlan`] threaded through
//! [`super::CoordinatorConfig::faults`] that decides, per admitted
//! job, whether to inject a contained sort panic, a fatal (worker-
//! killing) panic, a sort stall, a forced XLA error, or a forced
//! admission shed.
//!
//! The plan is **deterministic**: every admitted job draws a
//! monotonically increasing sequence number, and
//! [`FaultPlan::decide`] hashes `seed ⊕ seq` through splitmix64 —
//! identical seeds therefore produce identical injection schedules
//! regardless of thread interleaving, which is what makes chaos
//! tests replayable and the chaos bench comparable across runs. No
//! wall clock, no global RNG state.
//!
//! Injection sites (all no-ops when the plan is absent or a rate is
//! zero):
//!
//! * [`FaultDecision::SortPanic`] — the worker panics *inside* the
//!   `catch_unwind` envelope around the sort, exercising panic
//!   containment (`SortError::JobPanicked`, `panics_contained`).
//! * [`FaultDecision::FatalPanic`] — the worker parks every job it
//!   holds and panics *outside* per-job containment, killing the
//!   thread: exercises the supervisor (respawn, requeue,
//!   `workers_respawned`) and double-kill quarantine
//!   (`SortError::Quarantined`).
//! * [`FaultDecision::Stall`] — the worker sleeps before sorting,
//!   exercising deadline reaping (`SortError::DeadlineExceeded`).
//! * [`FaultDecision::XlaError`] — the XLA executor records a
//!   dispatch failure without touching PJRT, exercising the circuit
//!   breaker and CPU fallback.
//! * [`FaultDecision::Shed`] — `try_submit` refuses the request as
//!   if every shard were full, exercising retry/backoff paths.
//!
//! This module is wired for tests and benches only: production
//! configurations leave [`super::CoordinatorConfig::faults`] at
//! `None`, which costs one `Option` check per admission.

use std::time::Duration;

/// SplitMix64: the finalizer used both for fault rolls and for
/// [`super::RetryPolicy`]'s deterministic jitter. Full-period,
/// stateless, and good enough avalanche that consecutive sequence
/// numbers produce uncorrelated rolls.
pub(super) fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// A seeded, per-mille-rated fault schedule. All rates are
/// **per-mille** (0..=1000) and drawn from disjoint bands of one
/// roll, so their sum must stay ≤ 1000 — [`FaultPlan::decide`]
/// saturates gracefully (later bands are squeezed out) but tests
/// should keep the sum in range for the rates to mean what they say.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultPlan {
    /// Seed for the deterministic roll stream. Two plans with equal
    /// seeds and rates produce identical injection schedules.
    pub seed: u64,
    /// Per-mille of admitted jobs whose sort panics inside the
    /// containment envelope (`SortError::JobPanicked`).
    pub sort_panic_per_mille: u16,
    /// Per-mille of admitted jobs that kill their worker thread
    /// outright (supervisor respawn; second kill → quarantine).
    pub fatal_panic_per_mille: u16,
    /// Per-mille of admitted jobs stalled by [`FaultPlan::stall`]
    /// before sorting (drives deadline expiry).
    pub stall_per_mille: u16,
    /// How long a stalled job sleeps.
    pub stall: Duration,
    /// Per-mille of XLA-routed jobs whose dispatch is failed without
    /// touching PJRT (drives the circuit breaker).
    pub xla_error_per_mille: u16,
    /// Per-mille of `try_submit` admissions refused as if the queues
    /// were full (`BusyReason::QueueFull`).
    pub shed_per_mille: u16,
}

impl Default for FaultPlan {
    /// All rates zero — an inert plan (useful as a `..Default::default()`
    /// base). `stall` defaults to 1 ms so enabling `stall_per_mille`
    /// alone already produces an observable delay.
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            sort_panic_per_mille: 0,
            fatal_panic_per_mille: 0,
            stall_per_mille: 0,
            stall: Duration::from_millis(1),
            xla_error_per_mille: 0,
            shed_per_mille: 0,
        }
    }
}

/// What, if anything, to inject for one job. Stamped onto the job at
/// admission ([`FaultPlan::decide`]) and honored at the matching
/// site; decisions that never reach their site (e.g. `XlaError` on a
/// job the router sends to a CPU tier) are inert.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultDecision {
    /// No fault for this job (always the case without a plan).
    None,
    /// Panic inside the sort's containment envelope.
    SortPanic,
    /// Kill the worker thread processing this job.
    FatalPanic,
    /// Sleep this long before sorting.
    Stall(Duration),
    /// Fail the XLA dispatch without calling PJRT.
    XlaError,
    /// Refuse this `try_submit` as if every shard were full.
    Shed,
}

impl FaultPlan {
    /// The deterministic decision for admission sequence number
    /// `seq`: one splitmix64 roll in `0..1000`, carved into disjoint
    /// bands in a fixed order (shed, sort panic, fatal panic, stall,
    /// XLA error). Pure — same `(plan, seq)` always returns the same
    /// decision.
    pub fn decide(&self, seq: u64) -> FaultDecision {
        let roll = (splitmix64(self.seed ^ seq.wrapping_mul(0xA24B_AED4_963E_E407)) % 1000) as u16;
        let mut edge = self.shed_per_mille;
        if roll < edge {
            return FaultDecision::Shed;
        }
        edge = edge.saturating_add(self.sort_panic_per_mille);
        if roll < edge {
            return FaultDecision::SortPanic;
        }
        edge = edge.saturating_add(self.fatal_panic_per_mille);
        if roll < edge {
            return FaultDecision::FatalPanic;
        }
        edge = edge.saturating_add(self.stall_per_mille);
        if roll < edge {
            return FaultDecision::Stall(self.stall);
        }
        edge = edge.saturating_add(self.xla_error_per_mille);
        if roll < edge {
            return FaultDecision::XlaError;
        }
        FaultDecision::None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_seeds_produce_identical_schedules() {
        let a = FaultPlan {
            seed: 42,
            sort_panic_per_mille: 100,
            fatal_panic_per_mille: 50,
            stall_per_mille: 75,
            xla_error_per_mille: 25,
            shed_per_mille: 125,
            ..Default::default()
        };
        let b = a;
        let schedule_a: Vec<FaultDecision> = (0..4096).map(|s| a.decide(s)).collect();
        let schedule_b: Vec<FaultDecision> = (0..4096).map(|s| b.decide(s)).collect();
        assert_eq!(schedule_a, schedule_b, "same seed+rates ⇒ same schedule");
        // And re-evaluating the same plan is stable (pure function).
        assert_eq!(schedule_a, (0..4096).map(|s| a.decide(s)).collect::<Vec<_>>());
    }

    #[test]
    fn different_seeds_diverge() {
        let mk = |seed| FaultPlan { seed, sort_panic_per_mille: 500, ..Default::default() };
        let a: Vec<FaultDecision> = (0..256).map(|s| mk(1).decide(s)).collect();
        let b: Vec<FaultDecision> = (0..256).map(|s| mk(2).decide(s)).collect();
        assert_ne!(a, b, "256 draws at 50% should not collide across seeds");
    }

    #[test]
    fn rates_are_approximately_honored() {
        let plan = FaultPlan {
            seed: 7,
            sort_panic_per_mille: 200,
            shed_per_mille: 100,
            ..Default::default()
        };
        let n = 100_000u64;
        let mut panics = 0u64;
        let mut sheds = 0u64;
        let mut none = 0u64;
        for s in 0..n {
            match plan.decide(s) {
                FaultDecision::SortPanic => panics += 1,
                FaultDecision::Shed => sheds += 1,
                FaultDecision::None => none += 1,
                other => panic!("rate-zero decision {other:?} injected"),
            }
        }
        // 20% ± 1.5pp and 10% ± 1.5pp over 100k draws.
        assert!((panics as i64 - 20_000).unsigned_abs() < 1_500, "panics={panics}");
        assert!((sheds as i64 - 10_000).unsigned_abs() < 1_500, "sheds={sheds}");
        assert_eq!(none, n - panics - sheds);
    }

    #[test]
    fn inert_plan_never_injects() {
        let plan = FaultPlan::default();
        assert!((0..10_000).all(|s| plan.decide(s) == FaultDecision::None));
    }

    #[test]
    fn stall_decision_carries_the_configured_duration() {
        let plan = FaultPlan {
            seed: 3,
            stall_per_mille: 1000,
            stall: Duration::from_millis(7),
            ..Default::default()
        };
        assert_eq!(plan.decide(0), FaultDecision::Stall(Duration::from_millis(7)));
    }

    #[test]
    fn splitmix_is_not_identity_and_spreads_low_bits() {
        // Sanity on the mixer: consecutive inputs land in different
        // per-mille buckets often enough to be usable as rolls.
        let buckets: std::collections::HashSet<u64> =
            (0..1000u64).map(|x| splitmix64(x) % 1000).collect();
        assert!(buckets.len() > 600, "only {} distinct rolls in 1000", buckets.len());
    }
}
