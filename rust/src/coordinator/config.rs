//! Coordinator configuration and routing policy.

use super::tuner::{AdaptivePolicy, RoutingSnapshot};
use crate::sort::SortConfig;

/// Where a request executes — chosen by [`CoordinatorConfig::route`].
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Route {
    /// `< tiny_cutoff`: branchless insertion sort, cheaper than any
    /// vector setup (paper Fig. 5's small-scale observation).
    Tiny,
    /// Single-thread NEON-MS.
    SingleThread,
    /// Merge-path parallel NEON-MS.
    Parallel,
    /// XLA block-sort offload + rust cross-block merge.
    Xla,
}

/// How admission and dequeue arbitrate between tenants when the
/// service is contended.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum QosPolicy {
    /// Global FIFO (the pre-QoS behavior): shards pop in arrival
    /// order, and when every shard is full the *arriving* request is
    /// the one shed — whoever submitted first owns the queues,
    /// whatever their tenant's weight. Kept as the baseline
    /// `benches/qos_fairness.rs` contrasts against, and for
    /// single-tenant deployments that want strict arrival order.
    Fifo,
    /// Weighted fair share (the default): dequeue orders jobs by
    /// per-tenant virtual time (completed elements converge to the
    /// [`super::ClientConfig::weight`] ratios under contention), and
    /// when every shard is full the tenant *most over its share* is
    /// shed first — the arrival with [`super::BusyReason::OverShare`]
    /// when it is the worst offender, else by evicting the worst
    /// offender's newest queued job to make room. Admission stays
    /// work-conserving: while any shard has room, everyone is
    /// admitted regardless of share.
    #[default]
    FairShare,
}

/// Tunables for [`super::SortService`].
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Worker threads draining the shard queues. Worker `w` homes on
    /// shard `w % shards` and steals from the others when idle.
    pub workers: usize,
    /// Queue shards. Each shard has its own bounded queue and lock;
    /// submits route by power-of-two-choices over shard depths, so no
    /// single mutex serializes admission. Must be ≥ 1.
    pub shards: usize,
    /// Bounded *total* queue capacity (requests), split evenly across
    /// shards. Beyond it, backpressuring submits
    /// ([`super::SortClient::submit`]) park until a shard pops, and
    /// shedding submits ([`super::SortClient::try_submit`]) hand the
    /// input straight back — bounded memory either way.
    pub queue_capacity: usize,
    /// Max requests fused into one dynamic batch by a single worker
    /// wakeup. `1` disables batching.
    pub batch_max: usize,
    /// Requests at or below this length are eligible for the dynamic
    /// batcher's fused sort (only Tiny/SingleThread-routed requests
    /// fuse; Parallel- and Xla-routed ones never do).
    pub fuse_cutoff: usize,
    /// Below this, route Tiny.
    pub tiny_cutoff: usize,
    /// Above this, route Parallel.
    pub parallel_cutoff: usize,
    /// Threads for one Parallel-routed request and for one fused
    /// batch sort.
    pub threads_per_parallel_sort: usize,
    /// Offload to XLA when a request's length is ≥ this and an
    /// artifact set is loaded. `None` disables offload.
    pub xla_cutoff: Option<usize>,
    /// Kernel configuration every CPU tier runs — register width
    /// ([`crate::simd::VectorWidth`]), merge width/impl, column
    /// network. Each shard worker builds its sorters from this once
    /// at startup, so e.g. a `V256` 2×64 service is one config away
    /// (the width sweep's service-level knob).
    pub sort: SortConfig,
    /// Online routing policy. With [`AdaptivePolicy::Adaptive`] the
    /// cutoffs above are only *seeds*: the service re-derives
    /// `tiny_cutoff` / `fuse_cutoff` / `parallel_cutoff` / `batch_max`
    /// every epoch from the measured per-tier throughput, within the
    /// policy's hard bounds. [`AdaptivePolicy::Off`] (the default)
    /// keeps them static for the service's lifetime.
    pub adaptive: AdaptivePolicy,
    /// Multi-tenant arbitration under contention:
    /// [`QosPolicy::FairShare`] (the default) or the pre-QoS
    /// [`QosPolicy::Fifo`] baseline. Per-tenant weights and burst
    /// allowances ride on [`super::ClientConfig`] via
    /// [`super::SortService::client_with`].
    pub qos: QosPolicy,
    /// Deterministic fault injection for tests and benches
    /// ([`super::FaultPlan`]): seeded per-job decisions to panic,
    /// stall, fail XLA dispatches, or shed at admission. `None` (the
    /// default, and the only sane production setting) costs one
    /// `Option` check per admission.
    pub faults: Option<super::faults::FaultPlan>,
    /// Consecutive PJRT dispatch failures that trip the XLA circuit
    /// breaker open (jobs then take the CPU fallback without paying
    /// for a doomed dispatch). Must be ≥ 1; the default of 3 tolerates
    /// isolated transient errors without flapping.
    pub breaker_threshold: u32,
    /// How long the tripped XLA breaker stays open before admitting a
    /// half-open probe dispatch. Shorter recovers faster from
    /// transient accelerator faults; longer sheds less latency onto a
    /// persistently broken one.
    pub breaker_cooloff: std::time::Duration,
    /// Worker deaths a single fatally-flagged job may cause before the
    /// supervisor quarantines it ([`super::SortError::Quarantined`])
    /// instead of requeueing. Must be ≥ 1; the default of 2 gives a
    /// job one legitimate retry while still bounding how many workers
    /// a poison payload can take down.
    pub quarantine_deaths: u32,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 2,
            shards: 2,
            queue_capacity: 1024,
            batch_max: 32,
            fuse_cutoff: 4096,
            tiny_cutoff: 64,
            parallel_cutoff: 1 << 20,
            threads_per_parallel_sort: 4,
            xla_cutoff: None,
            sort: SortConfig::default(),
            adaptive: AdaptivePolicy::Off,
            qos: QosPolicy::default(),
            faults: None,
            breaker_threshold: 3,
            breaker_cooloff: std::time::Duration::from_millis(50),
            quarantine_deaths: 2,
        }
    }
}

impl CoordinatorConfig {
    /// The configured cutoffs as a [`RoutingSnapshot`] — the adaptive
    /// policy's seed, and the values [`CoordinatorConfig::route`]
    /// evaluates.
    pub fn routing_snapshot(&self) -> RoutingSnapshot {
        RoutingSnapshot {
            tiny_cutoff: self.tiny_cutoff,
            fuse_cutoff: self.fuse_cutoff,
            parallel_cutoff: self.parallel_cutoff,
            batch_max: self.batch_max,
        }
    }

    /// Route a request of `len` elements against the *configured*
    /// cutoffs ([`RoutingSnapshot::route`], the one shared tier
    /// ladder). When adaptive routing is on, the running service
    /// consults its live published state instead (same ladder,
    /// cutoffs re-derived each epoch); this method is the static
    /// policy and the adaptive seed.
    pub fn route(&self, len: usize, xla_available: bool) -> Route {
        self.routing_snapshot().route(len, xla_available, self.xla_cutoff)
    }

    /// True when a request of `len` may join a fused dynamic batch:
    /// small enough, and routed to a CPU tier the fused sort covers
    /// ([`RoutingSnapshot::fuse_eligible`] over the configured
    /// values).
    pub fn fuse_eligible(&self, len: usize, xla_available: bool) -> bool {
        self.routing_snapshot().fuse_eligible(len, xla_available, self.xla_cutoff)
    }

    /// Capacity of shard `s`: the total [`Self::queue_capacity`] split
    /// evenly, remainders to the lowest-indexed shards — the per-shard
    /// caps always sum to exactly the configured total.
    pub fn shard_capacity(&self, s: usize) -> usize {
        let base = self.queue_capacity / self.shards;
        base + usize::from(s < self.queue_capacity % self.shards)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_table() {
        let cfg = CoordinatorConfig { xla_cutoff: Some(4096), ..Default::default() };
        assert_eq!(cfg.route(10, true), Route::Tiny);
        assert_eq!(cfg.route(1000, true), Route::SingleThread);
        assert_eq!(cfg.route(1000, false), Route::SingleThread);
        assert_eq!(cfg.route(8192, true), Route::Xla);
        assert_eq!(cfg.route(8192, false), Route::SingleThread);
        assert_eq!(cfg.route(1 << 21, true), Route::Parallel);
    }

    #[test]
    fn xla_disabled_by_default() {
        let cfg = CoordinatorConfig::default();
        assert_eq!(cfg.route(1 << 14, true), Route::SingleThread);
    }

    #[test]
    fn shard_capacities_sum_to_total() {
        for (cap, shards) in [(1024usize, 2usize), (4, 2), (7, 3), (3, 8), (0, 4), (5, 1)] {
            let cfg = CoordinatorConfig { queue_capacity: cap, shards, ..Default::default() };
            let total: usize = (0..shards).map(|s| cfg.shard_capacity(s)).sum();
            assert_eq!(total, cap, "cap={cap} shards={shards}");
        }
    }

    #[test]
    fn failure_knob_defaults_preserve_hardwired_values() {
        // PR 8 shipped these as consts; the knobs must default to the
        // same values so existing deployments see no behavior change.
        let cfg = CoordinatorConfig::default();
        assert_eq!(cfg.breaker_threshold, 3);
        assert_eq!(cfg.breaker_cooloff, std::time::Duration::from_millis(50));
        assert_eq!(cfg.quarantine_deaths, 2);
    }

    #[test]
    fn fuse_eligibility_follows_routing() {
        let cfg = CoordinatorConfig {
            tiny_cutoff: 10,
            fuse_cutoff: 1000,
            parallel_cutoff: 2000,
            xla_cutoff: Some(500),
            ..Default::default()
        };
        assert!(cfg.fuse_eligible(5, false), "tiny fuses");
        assert!(cfg.fuse_eligible(500, false), "small single-thread fuses");
        assert!(!cfg.fuse_eligible(1500, false), "above fuse_cutoff never fuses");
        assert!(!cfg.fuse_eligible(500, true), "xla-routed jobs never fuse");
        assert!(!cfg.fuse_eligible(3000, false), "parallel-routed jobs never fuse");
        let unbatched = CoordinatorConfig { batch_max: 1, ..Default::default() };
        assert!(!unbatched.fuse_eligible(5, false), "batch_max=1 disables fusing");
    }
}
