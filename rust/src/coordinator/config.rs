//! Coordinator configuration and routing policy.

/// Where a request executes — chosen by [`CoordinatorConfig::route`].
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Route {
    /// `< tiny_cutoff`: branchless insertion sort, cheaper than any
    /// vector setup (paper Fig. 5's small-scale observation).
    Tiny,
    /// Single-thread NEON-MS.
    SingleThread,
    /// Merge-path parallel NEON-MS.
    Parallel,
    /// XLA block-sort offload + rust cross-block merge.
    Xla,
}

/// Tunables for [`super::SortService`].
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Worker threads draining the queue.
    pub workers: usize,
    /// Bounded queue capacity (requests); submits beyond it block —
    /// backpressure rather than unbounded memory growth.
    pub queue_capacity: usize,
    /// Max tiny requests drained by one worker wakeup (dynamic batch).
    pub batch_max: usize,
    /// Below this, route Tiny.
    pub tiny_cutoff: usize,
    /// Above this, route Parallel.
    pub parallel_cutoff: usize,
    /// Threads for one Parallel-routed request.
    pub threads_per_parallel_sort: usize,
    /// Offload to XLA when a request's length is ≥ this and an
    /// artifact set is loaded. `None` disables offload.
    pub xla_cutoff: Option<usize>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 2,
            queue_capacity: 1024,
            batch_max: 32,
            tiny_cutoff: 64,
            parallel_cutoff: 1 << 20,
            threads_per_parallel_sort: 4,
            xla_cutoff: None,
        }
    }
}

impl CoordinatorConfig {
    /// Route a request of `len` elements.
    pub fn route(&self, len: usize, xla_available: bool) -> Route {
        if len < self.tiny_cutoff {
            return Route::Tiny;
        }
        if let Some(x) = self.xla_cutoff {
            if xla_available && len >= x && len < self.parallel_cutoff {
                return Route::Xla;
            }
        }
        if len >= self.parallel_cutoff {
            Route::Parallel
        } else {
            Route::SingleThread
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_table() {
        let cfg = CoordinatorConfig { xla_cutoff: Some(4096), ..Default::default() };
        assert_eq!(cfg.route(10, true), Route::Tiny);
        assert_eq!(cfg.route(1000, true), Route::SingleThread);
        assert_eq!(cfg.route(1000, false), Route::SingleThread);
        assert_eq!(cfg.route(8192, true), Route::Xla);
        assert_eq!(cfg.route(8192, false), Route::SingleThread);
        assert_eq!(cfg.route(1 << 21, true), Route::Parallel);
    }

    #[test]
    fn xla_disabled_by_default() {
        let cfg = CoordinatorConfig::default();
        assert_eq!(cfg.route(1 << 14, true), Route::SingleThread);
    }
}
