//! The sort service: sharded bounded queues, a dynamic batcher that
//! fuses bursts of small jobs into one buffer, size-tiered routing,
//! cross-shard work stealing, and the confined XLA executor thread.
//!
//! # Threading model
//!
//! Admission and execution are **sharded**: the service owns
//! `cfg.shards` independent bounded FIFO queues, each behind its own
//! mutex, so no single lock serializes a heavy submit stream.
//! [`SortService::submit`] routes by **power-of-two-choices**: it
//! samples two shards from the submit clock and pushes to the
//! less-loaded one, falling back to a full scan so the aggregate
//! `queue_capacity` bound stays exact (a full sample never rejects a
//! request the service still has room for). Blocking submits sleep on
//! a shared wakeup hub until any shard pops.
//!
//! `cfg.workers` worker threads each *home* on shard `w % shards` but
//! **steal** from the other shards whenever their own queue is empty —
//! one hot shard can never idle the rest of the pool, the sharded
//! analog of the paper's §3.2 merge-path load-balancing claim ("each
//! available thread remains active").
//!
//! A take from a queue is a **dynamic batch**: after popping the head
//! job, the worker drains up to `batch_max - 1` further consecutive
//! fuse-eligible jobs (small, CPU-routed; see
//! [`CoordinatorConfig::fuse_eligible`]) in the same wakeup. A
//! multi-job batch is **fused**: the payloads are concatenated into
//! one contiguous buffer with recorded per-request offsets, sorted by
//! a single [`ParallelNeonMergeSort::sort_segments`] pass (one
//! thread-scope for the whole batch), and split back per request —
//! amortizing queue wakeups and thread-scope setup that previously
//! made tiny requests pay full pool cost. Batch occupancy, steals and
//! queue depths are tracked per shard ([`super::ShardMetrics`]) and
//! aggregated into one [`MetricsSnapshot`].
//!
//! Single jobs route by size tier ([`CoordinatorConfig::route`]):
//! insertion sort → single-thread NEON-MS → merge-path parallel →
//! XLA offload. The PJRT client is `Rc`-based (!Send), so XLA offload
//! runs on one dedicated executor thread owning the [`BlockSorter`];
//! workers forward Xla-routed jobs over an `mpsc` channel and move on
//! — the executor answers the requester directly.
//!
//! # Lock order and wakeups
//!
//! Only `hub → shard.queue` is ever held nested (submit retry and the
//! worker idle re-check). Push/pop wakeups lock the hub *after*
//! releasing the queue, which closes the lost-wakeup race: a sleeper
//! re-checks all queues while holding the hub, so any pop/push either
//! happens before that check (and is seen) or after (and its notify
//! lands on a registered waiter).
//!
//! The hub is kept off the hot path by parked-thread counters
//! (`idle_workers`, `blocked_submitters`): a push/pop only locks the
//! hub and notifies when someone is actually parked. The SeqCst pair
//! — sleeper: *increment counter, then re-check queues*; signaler:
//! *mutate queue, then load counter* — makes the skip safe: if the
//! signaler's load misses the increment, the sequentially-consistent
//! order puts the sleeper's re-check after the queue mutation, so the
//! sleeper sees the state change instead of sleeping through it.

use super::config::{CoordinatorConfig, Route};
use super::metrics::{Metrics, MetricsSnapshot, ShardMetrics};
use crate::kernels::serial::insertion_sort;
use crate::runtime::{ArtifactRegistry, BlockSorter, PjrtRuntime};
use crate::sort::{NeonMergeSort, ParallelNeonMergeSort};
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// One queued request.
struct Job {
    data: Vec<u32>,
    enqueued: Instant,
    reply: mpsc::Sender<Vec<u32>>,
}

/// Handle to a submitted request; [`SortHandle::wait`] blocks for the
/// sorted result.
pub struct SortHandle {
    rx: mpsc::Receiver<Vec<u32>>,
}

impl SortHandle {
    /// Block until the sorted vector arrives.
    pub fn wait(self) -> Result<Vec<u32>> {
        self.rx.recv().context("sort worker dropped the request")
    }
}

/// One queue shard. The mutex is held only for push/pop; sleeping
/// happens on the shared hub so cross-shard wakeups work.
struct Shard {
    queue: Mutex<VecDeque<Job>>,
    capacity: usize,
    metrics: ShardMetrics,
}

struct Shared {
    cfg: CoordinatorConfig,
    shards: Vec<Shard>,
    /// Wakeup hub: both condvars share this mutex (see module docs,
    /// "Lock order").
    hub: Mutex<()>,
    /// Signaled after any push (wakes idle workers).
    work_cv: Condvar,
    /// Signaled after any pop (wakes blocked submitters).
    space_cv: Condvar,
    /// Submit clock driving the two-choice shard sampling.
    clock: AtomicUsize,
    /// Workers parked on `work_cv` (SeqCst; see module docs).
    idle_workers: AtomicUsize,
    /// Submitters parked on `space_cv` (SeqCst; see module docs).
    blocked_submitters: AtomicUsize,
    shutdown: AtomicBool,
    metrics: Arc<Metrics>,
    xla_tx: Option<mpsc::Sender<Job>>,
}

impl Shared {
    fn depth(&self, s: usize) -> u64 {
        self.shards[s].metrics.depth.load(Ordering::Relaxed)
    }

    /// Push to shard `s` if it has room. No wakeup here — callers
    /// signal after placement so the hub lock is never taken while a
    /// queue lock is held.
    fn push_to(&self, s: usize, job: Job) -> std::result::Result<(), Job> {
        let shard = &self.shards[s];
        let mut q = shard.queue.lock().unwrap();
        if q.len() >= shard.capacity {
            return Err(job);
        }
        q.push_back(job);
        shard.metrics.depth.store(q.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    /// Two-choice placement with full-scan fallback: sample two shards
    /// from the clock, try the less-loaded first, then the other, then
    /// every remaining shard — so rejection means *every* shard is at
    /// capacity and the aggregate bound stays exact.
    fn try_place(&self, job: Job) -> std::result::Result<(), Job> {
        let n = self.shards.len();
        let t = self.clock.fetch_add(1, Ordering::Relaxed);
        let s1 = t % n;
        let h = (t.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 33) as usize % n;
        let s2 = if h == s1 { (s1 + 1) % n } else { h };
        let (first, second) =
            if self.depth(s2) < self.depth(s1) { (s2, s1) } else { (s1, s2) };
        let job = match self.push_to(first, job) {
            Ok(()) => return Ok(()),
            Err(j) => j,
        };
        let mut job = if second == first {
            job
        } else {
            match self.push_to(second, job) {
                Ok(()) => return Ok(()),
                Err(j) => j,
            }
        };
        for s in 0..n {
            if s == first || s == second {
                continue;
            }
            job = match self.push_to(s, job) {
                Ok(()) => return Ok(()),
                Err(j) => j,
            };
        }
        Err(job)
    }

    /// Wake one parked worker. Fast path: nobody parked → no hub
    /// lock, no notify (safe per the SeqCst protocol in the module
    /// docs). Slow path: lock-then-notify so a sleeper's hub-guarded
    /// re-check can't miss the event.
    fn signal_work(&self) {
        if self.idle_workers.load(Ordering::SeqCst) == 0 {
            return;
        }
        drop(self.hub.lock().unwrap());
        self.work_cv.notify_one();
    }

    /// Wake all parked submitters; same fast path as [`Self::signal_work`].
    fn signal_space(&self) {
        if self.blocked_submitters.load(Ordering::SeqCst) == 0 {
            return;
        }
        drop(self.hub.lock().unwrap());
        self.space_cv.notify_all();
    }
}

/// The coordinator service.
pub struct SortService {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    xla_thread: Option<JoinHandle<()>>,
}

impl SortService {
    /// Start with `cfg`; if `artifacts_dir` is `Some` and contains
    /// artifacts, an XLA executor thread is started and Xla routing is
    /// enabled (subject to `cfg.xla_cutoff`).
    pub fn start(cfg: CoordinatorConfig, artifacts_dir: Option<PathBuf>) -> Result<Self> {
        anyhow::ensure!(cfg.shards >= 1, "coordinator needs at least one shard");
        let metrics = Arc::new(Metrics::default());
        let (xla_tx, xla_thread) = match artifacts_dir {
            Some(dir) => {
                let reg = ArtifactRegistry::scan(&dir);
                if reg.is_empty() {
                    (None, None)
                } else {
                    let (tx, rx) = mpsc::channel::<Job>();
                    // Handshake so startup failures surface in start().
                    let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
                    let xm = Arc::clone(&metrics);
                    let handle = std::thread::Builder::new()
                        .name("xla-executor".into())
                        .spawn(move || xla_executor(reg, rx, ready_tx, xm))
                        .context("spawning xla executor")?;
                    ready_rx.recv().context("xla executor died at startup")??;
                    (Some(tx), Some(handle))
                }
            }
            None => (None, None),
        };

        let shards = (0..cfg.shards)
            .map(|s| Shard {
                queue: Mutex::new(VecDeque::new()),
                capacity: cfg.shard_capacity(s),
                metrics: ShardMetrics::default(),
            })
            .collect();
        let shared = Arc::new(Shared {
            cfg: cfg.clone(),
            shards,
            hub: Mutex::new(()),
            work_cv: Condvar::new(),
            space_cv: Condvar::new(),
            clock: AtomicUsize::new(0),
            idle_workers: AtomicUsize::new(0),
            blocked_submitters: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            metrics,
            xla_tx,
        });

        let mut workers = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let shared = Arc::clone(&shared);
            let home = w % cfg.shards;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("sort-worker-{w}"))
                    .spawn(move || worker_loop(&shared, home))
                    .context("spawning worker")?,
            );
        }
        Ok(SortService { shared, workers, xla_thread })
    }

    /// Start with defaults and no XLA offload.
    pub fn start_default() -> Result<Self> {
        SortService::start(CoordinatorConfig::default(), None)
    }

    /// True if the XLA executor is running.
    pub fn xla_enabled(&self) -> bool {
        self.shared.xla_tx.is_some()
    }

    /// Submit a sort request, blocking while every shard is full
    /// (backpressure).
    pub fn submit(&self, data: Vec<u32>) -> SortHandle {
        let (reply, rx) = mpsc::channel();
        let mut job = Job { data, enqueued: Instant::now(), reply };
        // Count before the job becomes poppable so `submitted ≥
        // completed` holds at every instant (a worker can finish the
        // job before a post-placement increment would land).
        self.shared.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        loop {
            job = match self.shared.try_place(job) {
                Ok(()) => break,
                Err(j) => j,
            };
            // All shards full: sleep until a pop frees space. The
            // counter increment *before* the retry under the hub lock
            // pairs with signal_space's fast-path load (module docs);
            // the retry itself closes the race against pops between
            // the failed scan and the wait.
            let guard = self.shared.hub.lock().unwrap();
            self.shared.blocked_submitters.fetch_add(1, Ordering::SeqCst);
            job = match self.shared.try_place(job) {
                Ok(()) => {
                    self.shared.blocked_submitters.fetch_sub(1, Ordering::SeqCst);
                    drop(guard);
                    break;
                }
                Err(j) => {
                    let guard = self.shared.space_cv.wait(guard).unwrap();
                    self.shared.blocked_submitters.fetch_sub(1, Ordering::SeqCst);
                    drop(guard);
                    j
                }
            };
        }
        self.shared.signal_work();
        SortHandle { rx }
    }

    /// Non-blocking submit; `Err(data)` returns the input when every
    /// shard is full (caller decides to retry/shed).
    pub fn try_submit(&self, data: Vec<u32>) -> std::result::Result<SortHandle, Vec<u32>> {
        let (reply, rx) = mpsc::channel();
        // Pre-count (and roll back on rejection) so `submitted ≥
        // completed` holds at every instant — see submit().
        self.shared.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        match self.shared.try_place(Job { data, enqueued: Instant::now(), reply }) {
            Ok(()) => {
                self.shared.signal_work();
                Ok(SortHandle { rx })
            }
            Err(job) => {
                self.shared.metrics.submitted.fetch_sub(1, Ordering::Relaxed);
                self.shared.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(job.data)
            }
        }
    }

    /// Current metrics, with per-shard counters aggregated in.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared
            .metrics
            .snapshot_with_shards(self.shared.shards.iter().map(|s| &s.metrics))
    }

    /// Drain the queues and stop all threads. Consumes the service;
    /// outstanding handles still receive their results first.
    pub fn shutdown(self) {
        let SortService { shared, workers, xla_thread } = self;
        shared.shutdown.store(true, Ordering::SeqCst);
        drop(shared.hub.lock().unwrap());
        shared.work_cv.notify_all();
        for w in workers {
            let _ = w.join();
        }
        // Dropping the last Shared Arc drops the xla sender, which
        // disconnects the executor's channel and ends its loop.
        drop(shared);
        if let Some(t) = xla_thread {
            let _ = t.join();
        }
    }
}

/// Pop one dynamic batch from shard `s`: the head job, plus up to
/// `batch_max - 1` consecutive fuse-eligible followers in the same
/// wakeup. Returns `None` when the queue is empty.
fn take_batch(shared: &Shared, s: usize) -> Option<Vec<Job>> {
    let xla = shared.xla_tx.is_some();
    let shard = &shared.shards[s];
    let batch = {
        let mut q = shard.queue.lock().unwrap();
        let first = q.pop_front()?;
        let mut batch = vec![first];
        if shared.cfg.fuse_eligible(batch[0].data.len(), xla) {
            while batch.len() < shared.cfg.batch_max {
                match q.front() {
                    Some(j) if shared.cfg.fuse_eligible(j.data.len(), xla) => {
                        batch.push(q.pop_front().unwrap());
                    }
                    _ => break,
                }
            }
        }
        shard.metrics.depth.store(q.len() as u64, Ordering::Relaxed);
        batch
    };
    shared.signal_space();
    if batch.len() > 1 {
        shard.metrics.batches.fetch_add(1, Ordering::Relaxed);
        shard.metrics.batched_jobs.fetch_add(batch.len() as u64, Ordering::Relaxed);
    }
    Some(batch)
}

fn worker_loop(shared: &Shared, home: usize) {
    let n = shared.shards.len();
    loop {
        // Own shard first, then steal round-robin from the others.
        if let Some(batch) = take_batch(shared, home) {
            process_batch(shared, batch);
            continue;
        }
        let mut found = None;
        for off in 1..n {
            let victim = (home + off) % n;
            if let Some(batch) = take_batch(shared, victim) {
                shared.shards[home].metrics.steals.fetch_add(1, Ordering::Relaxed);
                found = Some(batch);
                break;
            }
        }
        if let Some(batch) = found {
            process_batch(shared, batch);
            continue;
        }
        // Nothing anywhere: advertise as idle, re-check under the
        // hub (the INC-then-re-check side of the SeqCst protocol in
        // the module docs), then sleep — or exit when shutting down
        // with all queues drained.
        let guard = shared.hub.lock().unwrap();
        shared.idle_workers.fetch_add(1, Ordering::SeqCst);
        let any_work =
            shared.shards.iter().any(|s| !s.queue.lock().unwrap().is_empty());
        if any_work {
            shared.idle_workers.fetch_sub(1, Ordering::SeqCst);
            continue;
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            shared.idle_workers.fetch_sub(1, Ordering::SeqCst);
            return;
        }
        let guard = shared.work_cv.wait(guard).unwrap();
        shared.idle_workers.fetch_sub(1, Ordering::SeqCst);
        drop(guard);
    }
}

/// Execute one dynamic batch: single jobs go through the size-tiered
/// router; multi-job batches take the fused path — concatenate into
/// one buffer with recorded offsets, sort all segments in a single
/// [`ParallelNeonMergeSort::sort_segments`] pass, split back.
fn process_batch(shared: &Shared, mut batch: Vec<Job>) {
    if batch.len() == 1 {
        return process(shared, batch.pop().expect("len checked"));
    }
    let m = &shared.metrics;
    let total: usize = batch.iter().map(|j| j.data.len()).sum();
    let mut fused = Vec::with_capacity(total);
    let mut bounds = Vec::with_capacity(batch.len() + 1);
    bounds.push(0);
    for job in &batch {
        fused.extend_from_slice(&job.data);
        bounds.push(fused.len());
        // Fused jobs still count under their size tier.
        if job.data.len() < shared.cfg.tiny_cutoff {
            m.route_tiny.fetch_add(1, Ordering::Relaxed);
        } else {
            m.route_single.fetch_add(1, Ordering::Relaxed);
        }
    }
    ParallelNeonMergeSort::with_threads(shared.cfg.threads_per_parallel_sort)
        .sort_segments(&mut fused, &bounds);
    for (i, mut job) in batch.into_iter().enumerate() {
        job.data.copy_from_slice(&fused[bounds[i]..bounds[i + 1]]);
        finish(m, job);
    }
}

fn process(shared: &Shared, mut job: Job) {
    let m = &shared.metrics;
    let route = shared.cfg.route(job.data.len(), shared.xla_tx.is_some());
    match route {
        Route::Tiny => {
            m.route_tiny.fetch_add(1, Ordering::Relaxed);
            insertion_sort(&mut job.data);
        }
        Route::SingleThread => {
            m.route_single.fetch_add(1, Ordering::Relaxed);
            // Thread-local sorter: construction is cheap (network
            // tables are small) and avoids sharing.
            thread_local! {
                static SORTER: NeonMergeSort = NeonMergeSort::paper_default();
            }
            SORTER.with(|s| s.sort(&mut job.data));
        }
        Route::Parallel => {
            m.route_parallel.fetch_add(1, Ordering::Relaxed);
            ParallelNeonMergeSort::with_threads(shared.cfg.threads_per_parallel_sort)
                .sort(&mut job.data);
        }
        Route::Xla => {
            m.route_xla.fetch_add(1, Ordering::Relaxed);
            // Forward; the executor thread completes the reply.
            if let Some(tx) = &shared.xla_tx {
                if tx.send(job).is_ok() {
                    return;
                }
            }
            unreachable!("route() returned Xla without an executor");
        }
    }
    finish(m, job);
}

fn finish(m: &Metrics, job: Job) {
    m.elements.fetch_add(job.data.len() as u64, Ordering::Relaxed);
    m.latency.record(job.enqueued.elapsed());
    m.completed.fetch_add(1, Ordering::Relaxed);
    // Receiver may have given up; that's fine.
    let _ = job.reply.send(job.data);
}

/// Dedicated thread owning the (!Send) PJRT client + executables.
fn xla_executor(
    reg: ArtifactRegistry,
    rx: mpsc::Receiver<Job>,
    ready: mpsc::Sender<Result<()>>,
    metrics: Arc<Metrics>,
) {
    let sorter = match PjrtRuntime::cpu()
        .map(Arc::new)
        .and_then(|rt| BlockSorter::new(rt, &reg))
    {
        Ok(s) => {
            let _ = ready.send(Ok(()));
            s
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let geometry = sorter.batch_geometry();
    while let Ok(mut job) = rx.recv() {
        // Opportunistic dynamic batching through the accelerator: if a
        // batched artifact is compiled and this job fits one row, pull
        // whatever fitting jobs are already queued (non-blocking) and
        // sort them all in a single PJRT dispatch.
        if let Some((batch, block)) = geometry {
            if job.data.len() <= block {
                let mut group = vec![job];
                let mut oversized = Vec::new();
                while group.len() < batch {
                    match rx.try_recv() {
                        Ok(j) if j.data.len() <= block => group.push(j),
                        Ok(j) => {
                            oversized.push(j);
                            break;
                        }
                        Err(_) => break,
                    }
                }
                if group.len() > 1 {
                    metrics.batches.fetch_add(1, Ordering::Relaxed);
                    let mut rows: Vec<&mut [u32]> =
                        group.iter_mut().map(|j| j.data.as_mut_slice()).collect();
                    if sorter.sort_batch_u32(&mut rows).is_err() {
                        for j in group.iter_mut() {
                            NeonMergeSort::paper_default().sort(&mut j.data);
                        }
                    }
                    for j in group {
                        finish(&metrics, j);
                    }
                } else {
                    for mut j in group {
                        if sorter.sort_u32(&mut j.data).is_err() {
                            NeonMergeSort::paper_default().sort(&mut j.data);
                        }
                        finish(&metrics, j);
                    }
                }
                for mut j in oversized {
                    if sorter.sort_u32(&mut j.data).is_err() {
                        NeonMergeSort::paper_default().sort(&mut j.data);
                    }
                    finish(&metrics, j);
                }
                continue;
            }
        }
        if sorter.sort_u32(&mut job.data).is_err() {
            // Fall back to the CPU path rather than dropping the job.
            NeonMergeSort::paper_default().sort(&mut job.data);
        }
        finish(&metrics, job);
    }
}
