//! The sort service: sharded bounded queues, a dynamic batcher that
//! fuses bursts of small jobs into one buffer, size-tiered routing,
//! cross-shard work stealing, a multi-tenant client layer, and the
//! confined XLA executor thread.
//!
//! # Request lifecycle (handle-based, non-blocking)
//!
//! Every request is a [`Job`] carrying a shared completion [`Slot`]
//! and (for client submits) its tenant's counters. Admission returns
//! a [`SortHandle`] immediately; nothing in the service ever blocks
//! on a per-request channel join. When a shard worker finishes the
//! sort it deposits the result in the slot and *signals* — waking a
//! parked `wait()` caller through the slot's condvar and any polling
//! async task through its registered waker. Callers choose their
//! style per request: poll ([`SortHandle::try_take`]), await (the
//! handle is a `Future`), or park ([`SortHandle::wait`]).
//!
//! # Element types
//!
//! A request's payload is typed ([`super::ElemBuf`]): `u32` keys
//! ([`SortClient::submit`]), `u64` keys ([`SortClient::submit_u64`]),
//! or packed key–payload pairs ([`SortClient::submit_pairs`]). The
//! handle a submit returns is typed to match, so every payload
//! round-trips as the `Vec` the caller handed in. Element width cuts
//! through three policy layers:
//!
//! * **Batch fusion is kind-segregated** — a fused buffer is one
//!   contiguous typed allocation, so `take_batch` only drains
//!   followers of the *same* element kind as the batch head; jobs of
//!   different widths never share a fused sort.
//! * **XLA offload is `u32`-only** (the AOT artifacts are compiled
//!   for 32-bit rows): wider jobs route through the CPU tiers at the
//!   same size cutoffs, and the executor defensively CPU-sorts any
//!   non-`u32` job that reaches it anyway.
//! * **QoS admission is costed in bytes** (see below), so switching
//!   to 8-byte elements halves the element count a burst allowance
//!   admits rather than doubling a tenant's effective share.
//!
//! Tenants enter through [`SortService::client`] (or
//! [`SortService::client_with`], which also sets the tenant's
//! fair-share [`ClientConfig`] weight and burst): a [`SortClient`] is
//! a cheaply clonable handle binding one tenant identity to the
//! service. [`SortClient::submit`] applies backpressure (parks only
//! while *every* shard is at capacity); [`SortClient::try_submit`]
//! never parks — it sheds with [`Busy`], handing the input back and
//! bumping the tenant's `shed` counter. Accepted/shed/completed/
//! cancelled counts, a latency histogram, and the QoS gauges
//! (share/credit/in-flight occupancy) are kept per tenant and
//! reported in [`MetricsSnapshot::tenants`].
//!
//! # Per-tenant QoS (weighted fair share)
//!
//! Under [`QosPolicy::FairShare`] (the default) capacity under
//! contention belongs to *weights*, not to arrival order:
//!
//! * Every admission is costed in **bytes** (`len × element size`,
//!   floored at `qos::MIN_JOB_COST` per job so a flood of tiny
//!   requests is policed for the queue *slots* it hogs, not just its
//!   bytes) and charged to its tenant: an in-flight gauge (admitted,
//!   not yet completed/cancelled) plus a start-time-fair-queueing
//!   virtual clock that advances by `cost / weight`. The byte
//!   denomination makes costs comparable across element widths — a
//!   million `u64`s is twice the work of a million `u32`s, and is
//!   charged as such. The job carries its virtual-time tag into the
//!   queue.
//! * **Dequeue is weight-aware**: a shard pops the lowest tag
//!   instead of the head, so backlogged tenants drain bytes in
//!   proportion to their weights (FIFO within a tenant — tags are
//!   strictly increasing per tenant). Everything else about the pop
//!   is unchanged: the capacity bounds, work stealing, the dynamic
//!   batcher (it drains further fuse-eligible jobs in tag order),
//!   and cancellation filtering.
//! * **Admission is work-conserving but fair under pressure**: while
//!   any shard has room, everyone is admitted. When every shard is
//!   full, the tenant *most over its share* (in-flight bytes
//!   beyond its [`ClientConfig::burst`], per unit weight) loses:
//!   an over-share arrival is shed with [`BusyReason::OverShare`]
//!   (carrying a retry-after hint), while an arrival from a tenant
//!   further under its share **evicts** the worst offender's newest
//!   queued job (its handle resolves to an error; counted `evicted`
//!   and `shed_over_share`) and takes its place. A tenant within its
//!   burst allowance is never shed for share reasons and never
//!   evicted.
//!
//! Tenant-less [`SortService::submit`] / [`SortService::try_submit`]
//! requests ride an internal anonymous bucket (weight 1): they get
//! virtual-time tags and over-share accounting like everyone else —
//! an over-burst anonymous flood gains no eviction privilege over
//! registered tenants — but the bucket is never an eviction *victim*
//! (it is not in the tenant registry) and its sheds surface exactly
//! as the legacy API always surfaced them (`Err(data)` / a parked
//! submit), never as per-tenant counters. [`QosPolicy::Fifo`]
//! restores arrival-order dequeue and shed-the-arrival admission
//! wholesale (the bench baseline).
//!
//! Dropping an unresolved [`SortHandle`] cancels the request: workers
//! check the slot's cancellation flag before sorting and skip the
//! job (counted under `cancelled`), so abandoned requests cost one
//! atomic load instead of a sort — and can never wedge a worker.
//!
//! # Threading model
//!
//! Admission and execution are **sharded**: the service owns
//! `cfg.shards` independent bounded FIFO queues, each behind its own
//! mutex, so no single lock serializes a heavy submit stream.
//! Placement routes by **power-of-two-choices**: it samples two
//! shards from the submit clock and pushes to the less-loaded one,
//! falling back to a full scan so the aggregate `queue_capacity`
//! bound stays exact (a full sample never rejects a request the
//! service still has room for). Backpressured submits sleep on a
//! shared wakeup hub until any shard pops; shedding submits never
//! touch the hub at all.
//!
//! `cfg.workers` worker threads each *home* on shard `w % shards` but
//! **steal** from the other shards whenever their own queue is empty —
//! one hot shard can never idle the rest of the pool, the sharded
//! analog of the paper's §3.2 merge-path load-balancing claim ("each
//! available thread remains active").
//!
//! A take from a queue is a **dynamic batch**: after popping the head
//! job, the worker drains up to `batch_max - 1` further consecutive
//! fuse-eligible jobs (small, CPU-routed; see
//! [`CoordinatorConfig::fuse_eligible`]) in the same wakeup. A
//! multi-job batch is **fused**: the payloads are concatenated into
//! one contiguous buffer with recorded per-request offsets, sorted by
//! a single [`ParallelNeonMergeSort::sort_segments_with`] pass (one
//! thread-scope for the whole batch), and each request's slot is
//! completed *as soon as its own segment is sorted* rather than when
//! the whole batch finishes — amortizing queue wakeups and
//! thread-scope setup without adding tail latency for the batch's
//! early finishers. Batch occupancy, steals and queue depths are
//! tracked per shard ([`ShardMetrics`]) and aggregated into one
//! [`MetricsSnapshot`].
//!
//! Single jobs route by size tier: insertion sort → single-thread
//! NEON-MS → merge-path parallel → XLA offload. The cutoffs live in a
//! lock-free `RoutingState` seeded from [`CoordinatorConfig`]; with
//! [`AdaptivePolicy::Adaptive`] the workers also record each sort's
//! `(size, duration)` into the per-tier observation grid, probe
//! boundary-window jobs onto the neighbor tier, and tick the epoch
//! tuner on wakeups — which re-derives the cutoffs from measured
//! throughput and publishes them through the same atomics (see
//! `tuner.rs`). The PJRT client is `Rc`-based (!Send), so XLA offload
//! runs on one dedicated executor thread owning the [`BlockSorter`];
//! workers forward Xla-routed jobs over an `mpsc` channel and move on
//! — the executor completes the requester's slot directly.
//!
//! # Lock order and wakeups
//!
//! Nested acquisition always starts from the hub: `hub → shard.queue`
//! (submit retry and the worker idle re-check) and `hub → tenants`
//! (the blocked submitter's fair-share victim scan). The tenants
//! registry and the shard queues are never held together — victim
//! selection releases the registry before `evict_and_place` takes a
//! queue lock (the victim may race away; the placement loop just
//! rescans) — and per-request slot mutexes are leaves. Push/pop
//! wakeups lock the hub *after* releasing the queue, which closes the
//! lost-wakeup race: a sleeper re-checks all queues while holding the
//! hub, so any pop/push either happens before that check (and is
//! seen) or after (and its notify lands on a registered waiter).
//!
//! The hub is kept off the hot path by parked-thread counters
//! (`idle_workers`, `blocked_submitters`): a push/pop only locks the
//! hub and notifies when someone is actually parked. The SeqCst pair
//! — sleeper: *increment counter, then re-check queues*; signaler:
//! *mutate queue, then load counter* — makes the skip safe: if the
//! signaler's load misses the increment, the sequentially-consistent
//! order puts the sleeper's re-check after the queue mutation, so the
//! sleeper sees the state change instead of sleeping through it.
//!
//! # Shutdown
//!
//! [`SortService::shutdown`] sets the shutdown flag, wakes every
//! parked worker and submitter, and joins the workers — which drain
//! their queues first, so already-admitted requests still complete.
//! Clients can outlive the service object: submits that observe the
//! flag are shed (blocking submits resolve their handle to an error,
//! `try_submit` returns [`Busy`]), and [`Shared::push_to`] re-checks
//! the flag under the queue lock so a submit racing the drain either
//! lands before it (and is dropped with its slot closed) or is
//! refused — never parked forever.
//!
//! # Failure domains
//!
//! Every admitted request ends in exactly one terminal ledger —
//! `completed`, `cancelled`, or `failed` — so the quiet-service
//! identity is `accepted == completed + cancelled + failed` per
//! tenant (admission-time sheds never count as accepted at all):
//!
//! * **Panic containment.** Each solo CPU sort runs inside a
//!   `catch_unwind` envelope: a panicking job resolves its handle to
//!   [`SortError::JobPanicked`] (counted `failed` +
//!   `panics_contained`) and the worker keeps serving. A fused batch
//!   that panics fails only the segments still unfinished — requests
//!   whose segments already completed keep their results.
//! * **Supervision.** Each worker owns a recovery cell; a worker
//!   about to die from an uncontained panic parks every job it holds
//!   there, and a supervisor thread joins the corpse, requeues the
//!   recovered jobs, and respawns the thread (`workers_respawned`).
//!   A job whose kills reach [`CoordinatorConfig::quarantine_deaths`]
//!   (default 2) is **quarantined** ([`SortError::Quarantined`],
//!   counted `quarantined`) instead of being retried forever.
//! * **Deadlines.** Requests carry an optional deadline
//!   ([`ClientConfig::default_deadline`], or per call via
//!   [`SortClient::submit_with_deadline`] /
//!   [`SortClient::try_submit_with_deadline`]); expired jobs are
//!   lazily reaped at dequeue and in the batcher — the handle
//!   resolves [`SortError::DeadlineExceeded`] and the QoS byte charge
//!   is *refunded* (uncharge, exactly like an eviction) so virtual
//!   time cannot drift from work that consumed no service.
//! * **Degradation.** The XLA executor guards every dispatch with a
//!   [`CircuitBreaker`]: [`CoordinatorConfig::breaker_threshold`]
//!   consecutive PJRT failures trip it open and jobs take the CPU
//!   fallback immediately (no doomed calls), with timed half-open
//!   probes after [`CoordinatorConfig::breaker_cooloff`] to recover. Its state and trip count are
//!   mirrored into [`MetricsSnapshot::breaker_state`] /
//!   `breaker_trips`.
//! * **Fault injection.** [`CoordinatorConfig::faults`] threads a
//!   seeded deterministic [`super::FaultPlan`] through admission for
//!   tests and benches: identical seeds produce identical injection
//!   schedules. Production leaves it `None` (one `Option` check per
//!   admission).

use super::client::{Busy, BusyReason, RetryPolicy, Slot, SortError, SortHandle};
use super::config::{CoordinatorConfig, QosPolicy, Route};
use super::elem::{ElemBuf, ElemKind, SortElem};
use super::faults::FaultDecision;
use super::metrics::{
    Metrics, MetricsSnapshot, ShardMetrics, TenantMetrics, TenantSnapshot, Tier,
};
use super::qos::{self, ClientConfig};
use super::tuner::{AdaptivePolicy, Decision, RoutingSnapshot, RoutingState, Tuner};
use crate::kernels::serial::insertion_sort;
use crate::runtime::{ArtifactRegistry, BlockSorter, CircuitBreaker, PjrtRuntime};
use crate::simd::KeyValue;
use crate::sort::{NeonMergeSort, ParallelNeonMergeSort, SortScratch};
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One queued request. The drop guard closes the completion slot, so
/// a job discarded anywhere (queue cleared at shutdown, channel to a
/// dead executor) resolves its handle to an error instead of leaving
/// a waiter parked forever.
struct Job {
    /// The typed payload. Workers dispatch on its [`ElemBuf::kind`]:
    /// fusion only groups same-kind jobs, and only `U32` payloads may
    /// reach the XLA executor.
    data: ElemBuf,
    /// Admission cost in bytes (`qos::job_cost(data.byte_len())` at
    /// submit — floored at `MIN_JOB_COST` so slot hogs are policed),
    /// kept so the tenant's in-flight gauge can be released after
    /// `data` has been moved out by completion.
    cost: u64,
    /// Virtual-time tag the fair-share dequeue orders by
    /// (`QosState::charge`; arrival order under `QosPolicy::Fifo`,
    /// where it is ignored).
    vtag: u64,
    /// The virtual-clock advance this job's charge added, refunded if
    /// the job is shed at admission or evicted (an evicted job
    /// consumed no service; keeping the charge would starve the
    /// evicted tenant under churn — see `QosState::release`).
    vdelta: u64,
    enqueued: Instant,
    /// Reap-by time: the per-call deadline, else the tenant's
    /// [`ClientConfig::default_deadline`], resolved to an absolute
    /// instant at admission. `None` = no deadline. Checked lazily at
    /// dequeue/batch time (`expired`), never by a timer thread.
    deadline: Option<Instant>,
    /// The fault-injection decision stamped at admission
    /// ([`CoordinatorConfig::faults`]); always
    /// [`FaultDecision::None`] without a plan.
    fault: FaultDecision,
    /// Workers this job's processing has killed so far (fatal
    /// injected panics). At [`CoordinatorConfig::quarantine_deaths`]
    /// the supervisor quarantines it instead of requeueing — the
    /// poison-job stop rule.
    deaths: u8,
    slot: Arc<Slot>,
    /// Tenant attribution for completion/cancellation accounting and
    /// QoS cost release. Service-level [`SortService::submit`]
    /// requests carry the internal anonymous bucket ([`Shared::anon`]
    /// — not registered, so invisible in snapshots and never an
    /// eviction victim, though its own load is policed at admission
    /// like any tenant's).
    tenant: Arc<TenantMetrics>,
}

impl Drop for Job {
    fn drop(&mut self) {
        // Idempotent: a no-op when `finish` already completed the slot.
        self.slot.close();
    }
}

/// One queue shard. The mutex is held only for push/pop; sleeping
/// happens on the shared hub so cross-shard wakeups work.
struct Shard {
    queue: Mutex<VecDeque<Job>>,
    capacity: usize,
    metrics: ShardMetrics,
}

/// Most recent quarantined payloads retained, newest last (ring of
/// [`DEAD_LETTER_MAX`]).
const DEAD_LETTER_MAX: usize = 32;
/// Per-letter payload byte cap: larger payloads keep only their
/// element prefix ([`DeadLetter::truncated`] set) so a flood of huge
/// poison jobs cannot turn the store into a memory leak.
const DEAD_LETTER_BYTE_CAP: usize = 64 * 1024;

/// One quarantined input, retained for operators: the payload that
/// killed [`CoordinatorConfig::quarantine_deaths`] workers, kept (up
/// to a byte cap) so the poisonous bytes can be pulled for offline
/// reproduction instead of vanishing with the failed handle. Read
/// through [`SortService::quarantined`].
#[derive(Clone, Debug)]
pub struct DeadLetter {
    /// Tenant the job was accounted to (`"(anonymous)"` for
    /// service-level submits).
    pub tenant: String,
    /// Element kind of the payload.
    pub kind: ElemKind,
    /// The poisonous payload — the whole input when it fits
    /// [`DEAD_LETTER_BYTE_CAP`] (64 KiB), else its element prefix.
    pub payload: ElemBuf,
    /// Original element count (exceeds `payload.len()` iff truncated).
    pub total_elements: usize,
    /// True when `payload` is a capped prefix of the original input.
    pub truncated: bool,
    /// Workers this job killed before the stop rule fired.
    pub deaths: u32,
}

struct Shared {
    cfg: CoordinatorConfig,
    shards: Vec<Shard>,
    /// Wakeup hub: both condvars share this mutex (see module docs,
    /// "Lock order").
    hub: Mutex<()>,
    /// Signaled after any push (wakes idle workers).
    work_cv: Condvar,
    /// Signaled after any pop (wakes blocked submitters).
    space_cv: Condvar,
    /// Submit clock driving the two-choice shard sampling.
    clock: AtomicUsize,
    /// Workers parked on `work_cv` (SeqCst; see module docs).
    idle_workers: AtomicUsize,
    /// Submitters parked on `space_cv` (SeqCst; see module docs).
    blocked_submitters: AtomicUsize,
    shutdown: AtomicBool,
    metrics: Arc<Metrics>,
    /// Global SFQ virtual clock: the largest virtual-time tag any
    /// shard has dequeued. New charges start at
    /// `max(tenant_vtime, vclock)` — the no-banked-credit rule.
    vclock: AtomicU64,
    /// QoS bucket for tenant-less submits (weight 1, never
    /// registered in `tenants`, never shed for share reasons or
    /// evicted — see the module docs).
    anon: Arc<TenantMetrics>,
    /// Live routing parameters the worker hot path reads (plain
    /// atomics). Seeded from `cfg`; static unless `tuner` is present.
    routing: RoutingState,
    /// Epoch controller re-deriving the routing parameters from the
    /// per-tier observations; `None` when [`AdaptivePolicy::Off`].
    tuner: Option<Tuner>,
    /// Registered tenants, looked up by name in [`SortService::client`].
    tenants: Mutex<Vec<Arc<TenantMetrics>>>,
    /// Channel to the XLA executor. Behind a mutex so
    /// [`SortService::shutdown`] can revoke it explicitly — clients
    /// may hold `Shared` alive past shutdown, so the executor's
    /// disconnect must not depend on the last `Arc` dropping.
    xla_tx: Mutex<Option<mpsc::Sender<Job>>>,
    /// Lock-free mirror of `xla_tx.is_some()` for the worker hot path
    /// (routing + batch eligibility check once per pop); cleared when
    /// shutdown revokes the sender.
    xla_on: AtomicBool,
    /// Monotone admission sequence feeding
    /// [`super::FaultPlan::decide`] — the per-job roll index that
    /// makes injection schedules independent of thread interleaving.
    fault_seq: AtomicU64,
    /// Dead-letter ring: the last [`DEAD_LETTER_MAX`] quarantined
    /// payloads (byte-capped copies), newest last. Written by the
    /// supervisor's recovery path, read by
    /// [`SortService::quarantined`].
    dead_letters: Mutex<VecDeque<DeadLetter>>,
}

impl Shared {
    fn depth(&self, s: usize) -> u64 {
        self.shards[s].metrics.depth.load(Ordering::Relaxed)
    }

    /// True while the XLA executor is reachable.
    fn xla_enabled(&self) -> bool {
        self.xla_on.load(Ordering::Relaxed)
    }

    /// Forward a job to the XLA executor; hands it back if the
    /// executor is unreachable (revoked at shutdown, or died).
    fn xla_send(&self, job: Job) -> std::result::Result<(), Job> {
        match &*self.xla_tx.lock().unwrap() {
            Some(tx) => tx.send(job).map_err(|e| e.0),
            None => Err(job),
        }
    }

    /// Park a bounded, byte-capped copy of a quarantined job's
    /// payload in the dead-letter ring so operators can pull the
    /// poisonous input ([`SortService::quarantined`]) after its
    /// handle has resolved to [`SortError::Quarantined`].
    fn retain_dead_letter(&self, job: &Job) {
        let kind = job.data.kind();
        let keep = (DEAD_LETTER_BYTE_CAP / kind.bytes()).min(job.data.len());
        let payload = match &job.data {
            ElemBuf::U32(v) => ElemBuf::U32(v[..keep].to_vec()),
            ElemBuf::U64(v) => ElemBuf::U64(v[..keep].to_vec()),
            ElemBuf::Pair(v) => ElemBuf::Pair(v[..keep].to_vec()),
        };
        let letter = DeadLetter {
            tenant: job.tenant.name().to_string(),
            kind,
            payload,
            total_elements: job.data.len(),
            truncated: keep < job.data.len(),
            deaths: u32::from(job.deaths),
        };
        let mut ring = self.dead_letters.lock().unwrap();
        while ring.len() >= DEAD_LETTER_MAX {
            ring.pop_front();
        }
        ring.push_back(letter);
    }

    /// Push to shard `s` if it has room and the service is still
    /// accepting. The shutdown re-check under the queue lock pairs
    /// with the post-join queue drain in [`SortService::shutdown`]: a
    /// push that acquires the lock after the drain released it also
    /// sees the flag, so no job can slip into an abandoned queue. No
    /// wakeup here — callers signal after placement so the hub lock
    /// is never taken while a queue lock is held.
    fn push_to(&self, s: usize, job: Job) -> std::result::Result<(), Job> {
        let shard = &self.shards[s];
        let mut q = shard.queue.lock().unwrap();
        if q.len() >= shard.capacity || self.shutdown.load(Ordering::SeqCst) {
            return Err(job);
        }
        job.tenant.qos.enqueued();
        q.push_back(job);
        shard.metrics.depth.store(q.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    /// Two-choice placement with full-scan fallback: sample two shards
    /// from the clock, try the less-loaded first, then the other, then
    /// every remaining shard — so rejection means *every* shard is at
    /// capacity (or the service is shutting down) and the aggregate
    /// bound stays exact.
    fn try_place(&self, job: Job) -> std::result::Result<(), Job> {
        let n = self.shards.len();
        let t = self.clock.fetch_add(1, Ordering::Relaxed);
        let s1 = t % n;
        let h = (t.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 33) as usize % n;
        let s2 = if h == s1 { (s1 + 1) % n } else { h };
        let (first, second) =
            if self.depth(s2) < self.depth(s1) { (s2, s1) } else { (s1, s2) };
        let job = match self.push_to(first, job) {
            Ok(()) => return Ok(()),
            Err(j) => j,
        };
        let mut job = if second == first {
            job
        } else {
            match self.push_to(second, job) {
                Ok(()) => return Ok(()),
                Err(j) => j,
            }
        };
        for s in 0..n {
            if s == first || s == second {
                continue;
            }
            job = match self.push_to(s, job) {
                Ok(()) => return Ok(()),
                Err(j) => j,
            };
        }
        Err(job)
    }

    /// Wake one parked worker. Fast path: nobody parked → no hub
    /// lock, no notify (safe per the SeqCst protocol in the module
    /// docs). Slow path: lock-then-notify so a sleeper's hub-guarded
    /// re-check can't miss the event.
    fn signal_work(&self) {
        if self.idle_workers.load(Ordering::SeqCst) == 0 {
            return;
        }
        drop(self.hub.lock().unwrap());
        self.work_cv.notify_one();
    }

    /// Wake all parked submitters; same fast path as [`Self::signal_work`].
    fn signal_space(&self) {
        if self.blocked_submitters.load(Ordering::SeqCst) == 0 {
            return;
        }
        drop(self.hub.lock().unwrap());
        self.space_cv.notify_all();
    }

    /// True when `t` is the internal anonymous bucket (tenant-less
    /// submits): counted service-wide but not per tenant. Its load is
    /// policed at admission like any tenant's, but it can never be an
    /// eviction victim (it is not in the registry).
    fn is_anon(&self, t: &Arc<TenantMetrics>) -> bool {
        Arc::ptr_eq(t, &self.anon)
    }

    /// Take the optimistic admission counts. Pre-counting *before*
    /// the job becomes poppable keeps `submitted ≥ completed` (and
    /// `accepted ≥ completed` per tenant) true at every instant — a
    /// worker can finish a job before any post-placement increment
    /// would land.
    fn count_admit(&self, tenant: &Arc<TenantMetrics>) {
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        if !self.is_anon(tenant) {
            tenant.accepted.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record a shed: roll back the optimistic admission counts if
    /// they were taken, bump the reject + tenant shed counters
    /// (`over_share` additionally marks the shed as share-caused).
    fn count_shed(&self, tenant: &Arc<TenantMetrics>, counted: bool, over_share: bool) {
        if counted {
            self.metrics.submitted.fetch_sub(1, Ordering::Relaxed);
            if !self.is_anon(tenant) {
                tenant.accepted.fetch_sub(1, Ordering::Relaxed);
            }
        }
        self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
        if !self.is_anon(tenant) {
            tenant.shed.fetch_add(1, Ordering::Relaxed);
            if over_share {
                tenant.shed_over_share.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Whether fair-share arbitration is in force.
    fn fair(&self) -> bool {
        self.cfg.qos == QosPolicy::FairShare
    }

    /// The most-over-share registered tenant with queued work,
    /// provided it is *strictly* more over share than `arrival_over`
    /// — the eviction victim. `exclude` (the arriving tenant) never
    /// picks itself: displacing your own job to place your own job is
    /// pure churn.
    fn most_over_share(
        &self,
        arrival_over: u64,
        exclude: &Arc<TenantMetrics>,
    ) -> Option<Arc<TenantMetrics>> {
        let reg = self.tenants.lock().unwrap();
        let candidates = reg.iter().map(|t| {
            if Arc::ptr_eq(t, exclude) {
                (0, false)
            } else {
                (t.qos.over_share(), t.qos.queued() > 0)
            }
        });
        qos::pick_victim(arrival_over, candidates).map(|i| Arc::clone(&reg[i]))
    }

    /// Scan the shards (newest job first within each) for one of
    /// `victim`'s queued jobs; on find, swap `job` into its place
    /// under the same queue lock, so the freed capacity cannot be
    /// stolen between eviction and placement. `Err(job)` when the
    /// victim's queued work raced away (or shutdown began).
    fn evict_and_place(
        &self,
        victim: &Arc<TenantMetrics>,
        job: Job,
    ) -> std::result::Result<Job, Job> {
        for shard in &self.shards {
            let mut q = shard.queue.lock().unwrap();
            if self.shutdown.load(Ordering::SeqCst) {
                return Err(job);
            }
            if let Some(idx) = q.iter().rposition(|j| Arc::ptr_eq(&j.tenant, victim)) {
                let evicted = q.remove(idx).expect("rposition returned a valid index");
                job.tenant.qos.enqueued();
                q.push_back(job);
                // Same length as before the swap; depth store keeps
                // the gauge coherent with the push path anyway.
                shard.metrics.depth.store(q.len() as u64, Ordering::Relaxed);
                return Ok(evicted);
            }
            drop(q);
        }
        Err(job)
    }

    /// Account one eviction: the displaced job was admitted, so roll
    /// its admission back and count it shed (share-caused) + evicted,
    /// refund its QoS charges (in-flight *and* virtual time — it
    /// consumed no service), and resolve its handle to an error that
    /// says why.
    fn count_eviction(&self, job: Job) {
        let t = Arc::clone(&job.tenant);
        t.qos.dequeued();
        t.qos.uncharge(job.cost, job.vdelta);
        self.count_shed(&t, true, true);
        self.metrics.evicted.fetch_add(1, Ordering::Relaxed);
        if !self.is_anon(&t) {
            t.evicted.fetch_add(1, Ordering::Relaxed);
        }
        job.slot.close_with(SortError::Evicted);
        // Job's drop guard would close anyway; the explicit close
        // above wins the race with it and records the reason.
    }

    /// Place `job`, arbitrating by fair share when every shard is
    /// full: evict the most-over-share tenant's newest queued job to
    /// make room, unless the arrival is itself the worst offender.
    /// `Err((job, over_share))` hands the job back with whether the
    /// shed is share-caused (drives [`BusyReason::OverShare`]).
    fn place(&self, job: Job) -> std::result::Result<(), (Job, bool)> {
        let mut job = job;
        // Bounded retries: each eviction frees exactly the slot we
        // then take under the same lock, so a second full pass only
        // happens when a victim's queued work raced away.
        for _ in 0..4 {
            job = match self.try_place(job) {
                Ok(()) => return Ok(()),
                Err(j) => j,
            };
            if !self.fair() {
                return Err((job, false));
            }
            // The anonymous bucket's own load counts too: a flooding
            // legacy-API submitter must not keep eviction privilege
            // over registered tenants just because it has no name.
            let arrival_over = job.tenant.qos.over_share();
            let Some(victim) = self.most_over_share(arrival_over, &job.tenant) else {
                return Err((job, arrival_over > 0));
            };
            job = match self.evict_and_place(&victim, job) {
                Ok(evicted) => {
                    self.count_eviction(evicted);
                    return Ok(());
                }
                Err(j) => j, // victim raced away; rescan from the top
            };
        }
        Err((job, false))
    }

    /// Build the job + handle pair and charge the tenant's QoS state
    /// for it (rolled back via `uncharge` if admission sheds — the
    /// job carries its own `vdelta` for that). The cost is the
    /// payload's **byte** size, so the charge is width-honest.
    /// `deadline` is the per-call override; absent, the tenant's
    /// [`ClientConfig::default_deadline`] applies. Both resolve to an
    /// absolute reap-by instant here, at admission.
    fn make_job<T: SortElem>(
        &self,
        tenant: &Arc<TenantMetrics>,
        data: Vec<T>,
        deadline: Option<Duration>,
    ) -> (Job, SortHandle<T>) {
        let slot = Slot::new();
        let handle = SortHandle::new(Arc::clone(&slot));
        let data = T::wrap(data);
        let cost = qos::job_cost(data.byte_len());
        let (vtag, vdelta) = tenant.qos.charge(cost, &self.vclock);
        let now = Instant::now();
        // checked_add: a deadline too far out to represent is no
        // deadline at all, not a panic.
        let deadline = deadline
            .or_else(|| tenant.qos.default_deadline())
            .and_then(|d| now.checked_add(d));
        let fault = match &self.cfg.faults {
            Some(plan) => plan.decide(self.fault_seq.fetch_add(1, Ordering::Relaxed)),
            None => FaultDecision::None,
        };
        let job = Job {
            data,
            cost,
            vtag,
            vdelta,
            enqueued: now,
            deadline,
            fault,
            deaths: 0,
            slot,
            tenant: Arc::clone(tenant),
        };
        (job, handle)
    }

    /// The back-off hint attached to both transient [`BusyReason`]s:
    /// roughly one median queue-to-completion latency — by then a
    /// queue slot has likely freed (QueueFull) or some of the
    /// tenant's in-flight cost has drained (OverShare). One
    /// derivation for both, so clients can back off uniformly.
    fn busy_hint(&self) -> Duration {
        qos::retry_after_hint(self.metrics.latency.quantile_us(0.5))
    }

    /// Backpressuring admission: park while every shard is full (and
    /// fair-share eviction finds no one worse-off to displace), shed
    /// (resolving the handle to an error) if the service shuts down
    /// first. Returns the handle in all cases — `submit` never
    /// fails, it just may resolve unsuccessfully.
    fn admit_blocking<T: SortElem>(
        &self,
        tenant: &Arc<TenantMetrics>,
        data: Vec<T>,
        deadline: Option<Duration>,
    ) -> SortHandle<T> {
        let (job, handle) = self.make_job(tenant, data, deadline);
        self.count_admit(tenant);
        let shed = |job: Job| {
            self.count_shed(tenant, true, false);
            tenant.qos.uncharge(job.cost, job.vdelta);
            drop(job); // drop guard closes the slot → handle errors
        };
        let mut job = job;
        loop {
            if self.shutdown.load(Ordering::SeqCst) {
                shed(job);
                return handle;
            }
            job = match self.place(job) {
                Ok(()) => break,
                Err((j, _)) => j, // blocking path parks instead of reporting why
            };
            // All shards full: sleep until a pop frees space. The
            // counter increment *before* the retry under the hub lock
            // pairs with signal_space's fast-path load (module docs);
            // the retry itself closes the race against pops between
            // the failed scan and the wait.
            let guard = self.hub.lock().unwrap();
            self.blocked_submitters.fetch_add(1, Ordering::SeqCst);
            job = match self.place(job) {
                Ok(()) => {
                    self.blocked_submitters.fetch_sub(1, Ordering::SeqCst);
                    drop(guard);
                    break;
                }
                Err((j, _)) => {
                    if self.shutdown.load(Ordering::SeqCst) {
                        self.blocked_submitters.fetch_sub(1, Ordering::SeqCst);
                        drop(guard);
                        shed(j);
                        return handle;
                    }
                    let guard = self.space_cv.wait(guard).unwrap();
                    self.blocked_submitters.fetch_sub(1, Ordering::SeqCst);
                    drop(guard);
                    j
                }
            };
        }
        self.signal_work();
        handle
    }

    /// Shedding admission: place or hand the input straight back,
    /// tagged with why ([`BusyReason`]) so callers know whether (and
    /// when) a retry can succeed.
    fn admit_try<T: SortElem>(
        &self,
        tenant: &Arc<TenantMetrics>,
        data: Vec<T>,
        deadline: Option<Duration>,
    ) -> std::result::Result<SortHandle<T>, Busy<T>> {
        if self.shutdown.load(Ordering::SeqCst) {
            self.count_shed(tenant, false, false);
            return Err(Busy { data, reason: BusyReason::Shutdown });
        }
        // Pre-count + pre-charge, rolled back on rejection (see
        // count_admit).
        let (mut job, handle) = self.make_job(tenant, data, deadline);
        self.count_admit(tenant);
        // Injected admission shed (tests/benches only): refuse as if
        // every shard were full, through the normal shed bookkeeping
        // so the forced path and the real one can never diverge.
        if job.fault == FaultDecision::Shed {
            self.count_shed(tenant, true, false);
            tenant.qos.uncharge(job.cost, job.vdelta);
            return Err(Busy {
                data: T::unwrap(std::mem::take(&mut job.data)),
                reason: BusyReason::QueueFull { retry_after_hint: self.busy_hint() },
            });
        }
        match self.place(job) {
            Ok(()) => {
                self.signal_work();
                Ok(handle)
            }
            Err((mut job, over_share)) => {
                self.count_shed(tenant, true, over_share);
                tenant.qos.uncharge(job.cost, job.vdelta);
                // push_to also refuses once the shutdown flag is up;
                // report that precisely so retry loops terminate.
                let reason = if self.shutdown.load(Ordering::SeqCst) {
                    BusyReason::Shutdown
                } else if over_share {
                    BusyReason::OverShare { retry_after_hint: self.busy_hint() }
                } else {
                    BusyReason::QueueFull { retry_after_hint: self.busy_hint() }
                };
                Err(Busy { data: T::unwrap(std::mem::take(&mut job.data)), reason })
            }
        }
    }

    /// Snapshots of every registered tenant with the relative QoS
    /// gauges (share/credit) filled against the registry totals,
    /// sorted by name.
    fn tenant_snapshots(&self) -> Vec<TenantSnapshot> {
        let reg = self.tenants.lock().unwrap();
        let total_weight: u64 = reg.iter().map(|t| t.qos.weight() as u64).sum();
        let total_in_flight: u64 = reg.iter().map(|t| t.qos.in_flight()).sum();
        let mut tenants: Vec<TenantSnapshot> = reg
            .iter()
            .map(|t| t.snapshot().with_share(total_weight, total_in_flight))
            .collect();
        drop(reg);
        tenants.sort_by(|a, b| a.name.cmp(&b.name));
        tenants
    }

    /// One tenant's snapshot with the relative gauges filled (see
    /// [`Shared::tenant_snapshots`]).
    fn tenant_snapshot_of(&self, tenant: &Arc<TenantMetrics>) -> TenantSnapshot {
        let reg = self.tenants.lock().unwrap();
        let total_weight: u64 = reg.iter().map(|t| t.qos.weight() as u64).sum();
        let total_in_flight: u64 = reg.iter().map(|t| t.qos.in_flight()).sum();
        drop(reg);
        tenant.snapshot().with_share(total_weight, total_in_flight)
    }
}

/// The coordinator service.
pub struct SortService {
    shared: Arc<Shared>,
    /// The supervisor owns the worker thread handles (it joins and
    /// respawns them); `None` when `cfg.workers == 0`.
    supervisor: Option<JoinHandle<()>>,
    xla_thread: Option<JoinHandle<()>>,
}

/// A cheaply clonable, tenant-scoped handle to one [`SortService`] —
/// the intended entry point for every in-process tenant sharing a
/// service instance. Cloning copies two `Arc`s; clones (and clones of
/// clones) all account to the same tenant, so a tenant can fan its
/// submit side out across threads freely.
///
/// # Examples
///
/// ```
/// use neonms::coordinator::SortService;
///
/// let svc = SortService::start_default().unwrap();
/// let client = svc.client("tenant-a");
///
/// // Non-blocking submit: the handle resolves once a shard worker
/// // completes the slot — poll it, await it, or park on it.
/// let handle = match client.try_submit(vec![3, 1, 2]) {
///     Ok(h) => h,
///     Err(busy) => panic!("fresh service shed {} elements", busy.data.len()),
/// };
/// assert_eq!(handle.wait().unwrap(), vec![1, 2, 3]);
///
/// let snap = svc.metrics();
/// assert_eq!(snap.tenants.len(), 1);
/// assert_eq!(snap.tenants[0].name, "tenant-a");
/// assert_eq!(snap.tenants[0].accepted, 1);
/// assert_eq!(snap.tenants[0].completed, 1);
/// svc.shutdown();
/// ```
#[derive(Clone)]
pub struct SortClient {
    shared: Arc<Shared>,
    tenant: Arc<TenantMetrics>,
}

impl SortClient {
    /// The tenant name this client accounts to.
    pub fn tenant(&self) -> &str {
        self.tenant.name()
    }

    /// The fair-share configuration currently in force for this
    /// tenant (the last explicit [`SortService::client_with`] wins;
    /// [`ClientConfig::default`] otherwise).
    pub fn config(&self) -> ClientConfig {
        self.tenant.qos.config()
    }

    /// Submit with backpressure: parks only while *every* shard is at
    /// capacity (and, under [`QosPolicy::FairShare`], no tenant
    /// further over its share than this one has queued work to
    /// displace), then returns a [`SortHandle`] that resolves when a
    /// shard worker completes the request.
    ///
    /// The handle resolves to a [`SortError`] instead of a result
    /// when the service gives up on the request: the service shut
    /// down first ([`SortError::Shutdown`]; counts as shed), the
    /// request was **evicted** under fair-share pressure
    /// ([`SortError::Evicted`]; counted
    /// `shed`/`shed_over_share`/`evicted`), the sort panicked
    /// ([`SortError::JobPanicked`]), a deadline expired
    /// ([`SortError::DeadlineExceeded`] — only possible when this
    /// tenant sets [`ClientConfig::default_deadline`] or the call
    /// came through [`SortClient::submit_with_deadline`]), or the job
    /// was quarantined after killing workers
    /// ([`SortError::Quarantined`]). A tenant operating within its
    /// [`ClientConfig::burst`] allowance, without deadlines, against
    /// a live service can only hit the panic cases, which is why
    /// `wait().unwrap()` stays sound for well-behaved tenants in
    /// tests; production callers should match on the variant.
    pub fn submit(&self, data: Vec<u32>) -> SortHandle {
        self.shared.admit_blocking(&self.tenant, data, None)
    }

    /// [`SortClient::submit`] with an explicit per-request deadline,
    /// overriding any [`ClientConfig::default_deadline`]: if no
    /// worker has *started* the request within `deadline` of
    /// admission it is reaped — the handle resolves
    /// [`SortError::DeadlineExceeded`], the tenant's QoS byte charge
    /// is refunded (the request consumed no service), and it counts
    /// under `failed`/`deadline_expired`. Reaping is lazy (checked at
    /// dequeue and in the batcher), so an expired job sitting in an
    /// idle queue resolves when a worker next looks, not on a timer.
    ///
    /// A deadline of [`Duration::ZERO`] expires immediately — useful
    /// in tests as a deterministic reap.
    pub fn submit_with_deadline(&self, data: Vec<u32>, deadline: Duration) -> SortHandle {
        self.shared.admit_blocking(&self.tenant, data, Some(deadline))
    }

    /// Non-blocking submit: returns [`Busy`] — handing the input
    /// back untouched and bumping this tenant's `shed` counter — when
    /// every shard is at capacity ([`BusyReason::QueueFull`], retry
    /// later; [`BusyReason::OverShare`] when this tenant is itself
    /// the most over its fair share, back off by the hint) or the
    /// service has shut down ([`BusyReason::Shutdown`], stop
    /// retrying). Never parks, never spins.
    pub fn try_submit(&self, data: Vec<u32>) -> std::result::Result<SortHandle, Busy> {
        self.shared.admit_try(&self.tenant, data, None)
    }

    /// [`SortClient::try_submit`] with an explicit per-request
    /// deadline (see [`SortClient::submit_with_deadline`] for the
    /// reaping semantics).
    pub fn try_submit_with_deadline(
        &self,
        data: Vec<u32>,
        deadline: Duration,
    ) -> std::result::Result<SortHandle, Busy> {
        self.shared.admit_try(&self.tenant, data, Some(deadline))
    }

    /// [`SortClient::try_submit`] wrapped in a [`RetryPolicy`]
    /// backoff loop: on a transient shed ([`BusyReason::QueueFull`] /
    /// [`BusyReason::OverShare`]) the calling thread sleeps the
    /// policy's jittered backoff — floored at the shed's
    /// `retry_after_hint` — and resubmits. Returns the final [`Busy`]
    /// when the policy's attempts are exhausted, or immediately on
    /// [`BusyReason::Shutdown`] (retrying a dead service cannot
    /// succeed). The backoff schedule is deterministic per policy
    /// seed; only the service's own hint varies with load.
    pub fn try_submit_with_retry(
        &self,
        data: Vec<u32>,
        policy: &RetryPolicy,
    ) -> std::result::Result<SortHandle, Busy> {
        let mut data = data;
        let mut attempt = 0u32;
        loop {
            match self.try_submit(data) {
                Ok(handle) => return Ok(handle),
                Err(busy) => match busy.reason.retry_after() {
                    Some(hint) => match policy.backoff(attempt, Some(hint)) {
                        Some(delay) => {
                            std::thread::sleep(delay);
                            attempt += 1;
                            data = busy.data;
                        }
                        None => return Err(busy), // policy exhausted
                    },
                    None => return Err(busy), // shutdown: permanent
                },
            }
        }
    }

    /// [`SortClient::submit`] for 8-byte keys: the request sorts on
    /// the 2-lane `V128D` / 4-lane `V256D` register types and resolves
    /// to the same `Vec<u64>`. Costed at 8 bytes per element for QoS,
    /// CPU-tier routed (never XLA-offloaded), and never fused with
    /// jobs of another element type.
    pub fn submit_u64(&self, data: Vec<u64>) -> SortHandle<u64> {
        self.shared.admit_blocking(&self.tenant, data, None)
    }

    /// [`SortClient::try_submit`] for 8-byte keys (see
    /// [`SortClient::submit_u64`]); sheds with `Busy<u64>`, handing
    /// the input back untouched.
    pub fn try_submit_u64(
        &self,
        data: Vec<u64>,
    ) -> std::result::Result<SortHandle<u64>, Busy<u64>> {
        self.shared.admit_try(&self.tenant, data, None)
    }

    /// [`SortClient::submit`] for packed key–payload pairs
    /// ([`KeyValue`]): sorted key-major with deterministic payload
    /// tie-break, on the 8-byte-lane register types. Same QoS/routing
    /// treatment as [`SortClient::submit_u64`].
    pub fn submit_pairs(&self, data: Vec<KeyValue>) -> SortHandle<KeyValue> {
        self.shared.admit_blocking(&self.tenant, data, None)
    }

    /// [`SortClient::try_submit`] for key–payload pairs (see
    /// [`SortClient::submit_pairs`]).
    pub fn try_submit_pairs(
        &self,
        data: Vec<KeyValue>,
    ) -> std::result::Result<SortHandle<KeyValue>, Busy<KeyValue>> {
        self.shared.admit_try(&self.tenant, data, None)
    }

    /// Point-in-time copy of this tenant's counters and QoS gauges
    /// (share/credit filled against the live registry totals).
    pub fn tenant_metrics(&self) -> TenantSnapshot {
        self.shared.tenant_snapshot_of(&self.tenant)
    }
}

impl SortService {
    /// Start with `cfg`; if `artifacts_dir` is `Some` and contains
    /// artifacts, an XLA executor thread is started and Xla routing is
    /// enabled (subject to `cfg.xla_cutoff`).
    pub fn start(cfg: CoordinatorConfig, artifacts_dir: Option<PathBuf>) -> Result<Self> {
        anyhow::ensure!(cfg.shards >= 1, "coordinator needs at least one shard");
        // Validate the kernel config here, mirroring the sorter
        // constructors' asserts: workers build their sorters from it
        // on their own threads, where a panic would not surface —
        // every submit would then park forever on slots no worker
        // completes. Startup failures must surface in start().
        anyhow::ensure!(
            cfg.sort.r.is_power_of_two() && (4..=32).contains(&cfg.sort.r),
            "sort config: R must be 4|8|16|32 (got {})",
            cfg.sort.r
        );
        anyhow::ensure!(
            cfg.sort.r % cfg.sort.vector_width.lanes() == 0,
            "sort config: R={} must be a multiple of the {}-lane vector width",
            cfg.sort.r,
            cfg.sort.vector_width.lanes()
        );
        // The sorter constructor panics on an unavailable backend; the
        // service pre-validates so misconfiguration surfaces as an
        // error here instead of a panic on a worker thread.
        if let Some(backend) = cfg.sort.backend {
            anyhow::ensure!(
                backend.available(),
                "sort config: SIMD backend `{backend}` is not available on this machine \
                 (target {}); `scalar` always is",
                std::env::consts::ARCH
            );
        }
        anyhow::ensure!(cfg.breaker_threshold >= 1, "breaker_threshold must be ≥ 1");
        anyhow::ensure!(cfg.quarantine_deaths >= 1, "quarantine_deaths must be ≥ 1");
        let adaptive_params = match &cfg.adaptive {
            AdaptivePolicy::Off => None,
            AdaptivePolicy::Adaptive { epoch_jobs, bounds } => {
                anyhow::ensure!(*epoch_jobs >= 1, "adaptive policy: epoch_jobs must be ≥ 1");
                if let Err(e) = bounds.validate() {
                    anyhow::bail!("{e}");
                }
                Some((*epoch_jobs, bounds.clone()))
            }
        };
        let metrics = Arc::new(Metrics::default());
        let (xla_tx, xla_thread) = match artifacts_dir {
            Some(dir) => {
                let reg = ArtifactRegistry::scan(&dir);
                if reg.is_empty() {
                    (None, None)
                } else {
                    let (tx, rx) = mpsc::channel::<Job>();
                    // Handshake so startup failures surface in start().
                    let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
                    let xla_cfg = cfg.clone();
                    let xm = Arc::clone(&metrics);
                    let handle = std::thread::Builder::new()
                        .name("xla-executor".into())
                        .spawn(move || xla_executor(reg, rx, ready_tx, xm, xla_cfg))
                        .context("spawning xla executor")?;
                    ready_rx.recv().context("xla executor died at startup")??;
                    (Some(tx), Some(handle))
                }
            }
            None => (None, None),
        };

        // Built after the XLA setup: with offload active the tuner
        // freezes the single/parallel boundary (its lower side then
        // routes to the accelerator; see Tuner::new).
        let tuner = adaptive_params
            .map(|(epoch_jobs, bounds)| Tuner::new(epoch_jobs, bounds, xla_tx.is_none()));
        let shards = (0..cfg.shards)
            .map(|s| Shard {
                queue: Mutex::new(VecDeque::new()),
                capacity: cfg.shard_capacity(s),
                metrics: ShardMetrics::default(),
            })
            .collect();
        let shared = Arc::new(Shared {
            routing: RoutingState::new(&cfg, xla_tx.is_some()),
            tuner,
            cfg: cfg.clone(),
            shards,
            hub: Mutex::new(()),
            work_cv: Condvar::new(),
            space_cv: Condvar::new(),
            clock: AtomicUsize::new(0),
            idle_workers: AtomicUsize::new(0),
            blocked_submitters: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            metrics,
            vclock: AtomicU64::new(0),
            anon: Arc::new(TenantMetrics::new("(anonymous)")),
            tenants: Mutex::new(Vec::new()),
            xla_on: AtomicBool::new(xla_tx.is_some()),
            xla_tx: Mutex::new(xla_tx),
            fault_seq: AtomicU64::new(0),
            dead_letters: Mutex::new(VecDeque::new()),
        });

        // Workers are owned by a supervisor thread, not the service
        // struct: the supervisor joins any worker that dies from an
        // uncontained panic, recovers the jobs it parked, and
        // respawns the thread (see supervisor_loop).
        let supervisor = if cfg.workers > 0 {
            let (notice_tx, notice_rx) = mpsc::channel::<WorkerNotice>();
            let mut workers = Vec::with_capacity(cfg.workers);
            let mut homes = Vec::with_capacity(cfg.workers);
            let mut cells = Vec::with_capacity(cfg.workers);
            for w in 0..cfg.workers {
                let home = w % cfg.shards;
                let cell: RecoveryCell = Arc::new(Mutex::new(Vec::new()));
                workers.push(Some(spawn_worker(
                    &shared,
                    w,
                    home,
                    Arc::clone(&cell),
                    notice_tx.clone(),
                )?));
                homes.push(home);
                cells.push(cell);
            }
            let sup = Arc::clone(&shared);
            Some(
                std::thread::Builder::new()
                    .name("sort-supervisor".into())
                    .spawn(move || {
                        supervisor_loop(&sup, workers, &homes, &cells, &notice_tx, &notice_rx)
                    })
                    .context("spawning supervisor")?,
            )
        } else {
            None
        };
        Ok(SortService { shared, supervisor, xla_thread })
    }

    /// Start with defaults and no XLA offload.
    pub fn start_default() -> Result<Self> {
        SortService::start(CoordinatorConfig::default(), None)
    }

    /// True if the XLA executor is running.
    pub fn xla_enabled(&self) -> bool {
        self.shared.xla_enabled()
    }

    /// Register (or look up) the named tenant and return a client
    /// bound to it, with fair-share defaults ([`ClientConfig`]:
    /// weight 1) for a new tenant — an existing tenant's
    /// configuration is left untouched, so a default client joining
    /// does not reset a weight set via
    /// [`SortService::client_with`]. Calling twice with the same name
    /// returns clients sharing one set of counters — a tenant is an
    /// identity, not a connection.
    pub fn client(&self, tenant: &str) -> SortClient {
        self.client_inner(tenant, None)
    }

    /// [`SortService::client`] with an explicit fair-share
    /// [`ClientConfig`] (weight + burst). Reconfigures an existing
    /// tenant — the last explicit configuration wins; jobs already
    /// queued keep the virtual-time tags they were charged under.
    pub fn client_with(&self, tenant: &str, cfg: ClientConfig) -> SortClient {
        self.client_inner(tenant, Some(cfg))
    }

    fn client_inner(&self, tenant: &str, cfg: Option<ClientConfig>) -> SortClient {
        let mut reg = self.shared.tenants.lock().unwrap();
        let tenant = match reg.iter().find(|t| t.name() == tenant) {
            Some(t) => Arc::clone(t),
            None => {
                let t = Arc::new(TenantMetrics::new(tenant));
                reg.push(Arc::clone(&t));
                t
            }
        };
        if let Some(cfg) = cfg {
            tenant.qos.configure(cfg);
        }
        SortClient { shared: Arc::clone(&self.shared), tenant }
    }

    /// Submit a sort request without tenant attribution, blocking
    /// while every shard is full (backpressure). Rides the internal
    /// anonymous QoS bucket (weight 1; policed at admission like any
    /// tenant, but never an eviction victim). Prefer
    /// [`SortService::client`] + [`SortClient::submit`] for anything
    /// multi-tenant.
    pub fn submit(&self, data: Vec<u32>) -> SortHandle {
        let anon = Arc::clone(&self.shared.anon);
        self.shared.admit_blocking(&anon, data, None)
    }

    /// Non-blocking submit without tenant attribution; `Err(data)`
    /// returns the input when every shard is full (caller decides to
    /// retry/shed). The tenant-aware [`SortClient::try_submit`]
    /// additionally reports *why* via [`Busy`].
    pub fn try_submit(&self, data: Vec<u32>) -> std::result::Result<SortHandle, Vec<u32>> {
        let anon = Arc::clone(&self.shared.anon);
        self.shared.admit_try(&anon, data, None).map_err(|b| b.data)
    }

    /// The routing parameters currently in force: the configured
    /// cutoffs when the policy is [`AdaptivePolicy::Off`], the live
    /// tuner-published values when adaptive.
    pub fn routing(&self) -> RoutingSnapshot {
        self.shared.routing.snapshot()
    }

    /// The adaptive tuner's committed cutoff changes so far, oldest
    /// first (empty when the policy is [`AdaptivePolicy::Off`] or no
    /// epoch has produced a confirmed move yet).
    pub fn decisions(&self) -> Vec<Decision> {
        self.shared.tuner.as_ref().map(Tuner::decisions).unwrap_or_default()
    }

    /// Current metrics, with per-shard counters aggregated in and
    /// per-tenant snapshots (sorted by name, share/credit gauges
    /// filled against the registry totals) attached.
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut snap = self
            .shared
            .metrics
            .snapshot_with_shards(self.shared.shards.iter().map(|s| &s.metrics));
        snap.tenants = self.shared.tenant_snapshots();
        snap
    }

    /// The raw service-wide counters, for in-process subsystems (the
    /// network ingress) that record events as they happen rather than
    /// through snapshots.
    pub(crate) fn raw_metrics(&self) -> &Metrics {
        &self.shared.metrics
    }

    /// The dead-letter view of quarantined inputs: byte-capped copies
    /// of the last [`DEAD_LETTER_MAX`] payloads whose processing
    /// killed [`CoordinatorConfig::quarantine_deaths`] workers,
    /// newest last. The handles already resolved to
    /// [`SortError::Quarantined`]; this is how an operator pulls the
    /// poisonous bytes for offline reproduction.
    pub fn quarantined(&self) -> Vec<DeadLetter> {
        self.shared.dead_letters.lock().unwrap().iter().cloned().collect()
    }

    /// Drain the queues and stop all threads. Consumes the service;
    /// outstanding handles still receive their results first.
    /// [`SortClient`]s may outlive the call: their submits are shed
    /// from then on (see the module docs, "Shutdown").
    pub fn shutdown(self) {
        let SortService { shared, supervisor, xla_thread } = self;
        shared.shutdown.store(true, Ordering::SeqCst);
        drop(shared.hub.lock().unwrap());
        shared.work_cv.notify_all();
        shared.space_cv.notify_all();
        // The supervisor joins every worker (draining queues first)
        // and exits once the last one is down.
        if let Some(s) = supervisor {
            let _ = s.join();
        }
        // Stragglers that raced the flag into a queue after the
        // workers drained it: abandon them now — counted like any
        // other never-started job, slots closed — so their waiters
        // error out instead of parking forever and the accounting
        // identity `accepted = completed + cancelled + failed` still
        // holds.
        for shard in &shared.shards {
            let drained: Vec<Job> = shard.queue.lock().unwrap().drain(..).collect();
            for job in drained {
                // These never went through take_batch, so drop them
                // from the queued gauge here before abandoning.
                job.tenant.qos.dequeued();
                abandon(&shared.metrics, job);
            }
        }
        // Revoke the xla sender explicitly: clients may keep `Shared`
        // alive past this call, so the executor's disconnect must not
        // wait for the last Arc. The executor drains already-forwarded
        // jobs, then its recv fails and the loop ends.
        shared.xla_on.store(false, Ordering::Relaxed);
        drop(shared.xla_tx.lock().unwrap().take());
        if let Some(t) = xla_thread {
            let _ = t.join();
        }
        drop(shared);
    }
}

/// Per-worker execution state, built once at worker startup from
/// [`CoordinatorConfig::sort`] and owned for the thread's lifetime:
/// the sorters (construction precomputes network tables; they are
/// element-generic, so one pair serves every kind) and every reusable
/// buffer the sort tiers need — an aux scratch and a fused batch
/// buffer *per element type* (a `Vec<u32>` cannot be reused as a
/// `Vec<u64>`, so each kind keeps its own steady-state allocation),
/// plus the shared offset table. After warmup the steady-state CPU
/// paths therefore do **zero** per-job heap allocation: tiny jobs
/// sort in place, single-thread and fused-batch jobs ping-pong
/// through their kind's scratch, and the fused concatenation reuses
/// the kind's `fused_*` buffer / `bounds` (`Vec::clear` keeps
/// capacity).
struct WorkerCtx {
    single: NeonMergeSort,
    parallel: ParallelNeonMergeSort,
    scratch_u32: SortScratch<u32>,
    scratch_u64: SortScratch<u64>,
    scratch_pair: SortScratch<KeyValue>,
    fused_u32: Vec<u32>,
    fused_u64: Vec<u64>,
    fused_pair: Vec<KeyValue>,
    bounds: Vec<usize>,
}

impl WorkerCtx {
    fn new(cfg: &CoordinatorConfig) -> Self {
        let single = NeonMergeSort::new(cfg.sort.clone());
        let parallel = ParallelNeonMergeSort::new(single.clone(), cfg.threads_per_parallel_sort);
        WorkerCtx {
            single,
            parallel,
            scratch_u32: SortScratch::new(),
            scratch_u64: SortScratch::new(),
            scratch_pair: SortScratch::new(),
            fused_u32: Vec::new(),
            fused_u64: Vec::new(),
            fused_pair: Vec::new(),
            bounds: Vec::new(),
        }
    }
}

/// Index of the next job to pop under fair-share dequeue: the lowest
/// virtual-time tag, first arrival winning ties (strict `<`), so the
/// scan is FIFO within a tenant and FIFO overall when tags tie.
///
/// Deliberately an O(depth) linear scan over the shard's `VecDeque`
/// (depth ≤ `queue_capacity / shards`, 512 at defaults) rather than
/// an ordered index: the queue structure stays the plain deque every
/// other path (capacity checks, newest-of-tenant eviction scan,
/// shutdown drain) already works on, and eviction would need
/// tombstones in any heap variant. If profiling ever shows this scan
/// on top under deep backlogs, a per-shard `BTreeMap<(vtag, seq)>`
/// index is the upgrade path (ROADMAP follow-on).
fn min_vtag_idx(q: &VecDeque<Job>) -> Option<usize> {
    let mut best: Option<(usize, u64)> = None;
    for (i, j) in q.iter().enumerate() {
        match best {
            Some((_, tag)) if j.vtag >= tag => {}
            _ => best = Some((i, j.vtag)),
        }
    }
    best.map(|(i, _)| i)
}

/// Pop one dynamic batch from shard `s`: the next job — the queue
/// head under [`QosPolicy::Fifo`], the lowest virtual-time tag under
/// [`QosPolicy::FairShare`] — plus up to `batch_max - 1` further
/// fuse-eligible followers in the same wakeup, drained in the same
/// order (`batch_max` and the fuse eligibility read the *live*
/// routing state, so an adaptive service re-shapes its batches as the
/// tuner publishes). Returns `None` when the queue is empty.
fn take_batch(shared: &Shared, s: usize) -> Option<Vec<Job>> {
    let xla = shared.xla_enabled();
    let xla_cut = shared.cfg.xla_cutoff;
    let batch_max = shared.routing.batch_max();
    let fair = shared.fair();
    let shard = &shared.shards[s];
    let batch = {
        let mut q = shard.queue.lock().unwrap();
        let first = if fair {
            let idx = min_vtag_idx(&q)?;
            q.remove(idx).expect("min_vtag_idx returned a valid index")
        } else {
            q.pop_front()?
        };
        // A fused batch is one contiguous typed buffer, so followers
        // must match the head's element kind — a mixed-width batch
        // would have nowhere coherent to concatenate.
        let kind = first.data.kind();
        let mut batch = vec![first];
        if shared.routing.fuse_eligible(batch[0].data.len(), xla, xla_cut) {
            while batch.len() < batch_max {
                // Next candidate in pop order: lowest remaining tag
                // when fair, the head when FIFO. Stop at the first
                // non-fusable candidate either way — the batch never
                // skips past the job that should run next.
                let idx = if fair {
                    match min_vtag_idx(&q) {
                        Some(i) => i,
                        None => break,
                    }
                } else {
                    0
                };
                match q.get(idx) {
                    Some(j)
                        if j.data.kind() == kind
                            && shared.routing.fuse_eligible(j.data.len(), xla, xla_cut) =>
                    {
                        batch.push(q.remove(idx).expect("checked index"));
                    }
                    _ => break,
                }
            }
        }
        shard.metrics.depth.store(q.len() as u64, Ordering::Relaxed);
        batch
    };
    // Dequeue bookkeeping outside the queue lock: advance the global
    // virtual clock to the largest tag served (the SFQ no-banking
    // anchor) and drop the jobs from their tenants' queued gauges.
    let mut max_tag = 0;
    for job in &batch {
        max_tag = max_tag.max(job.vtag);
        job.tenant.qos.dequeued();
    }
    shared.vclock.fetch_max(max_tag, Ordering::Relaxed);
    shared.signal_space();
    Some(batch)
}

/// One worker's job-recovery cell: where the worker parks every job
/// it holds when it is about to die from an (injected) fatal panic,
/// and where the supervisor recovers them from after joining the
/// corpse. Plain `Vec` under a mutex — touched only on the death
/// path, never per job.
type RecoveryCell = Arc<Mutex<Vec<Job>>>;

/// How a worker thread ended, reported to the supervisor.
enum WorkerNotice {
    /// Clean exit (shutdown drain finished).
    Exited(usize),
    /// Killed by an uncontained panic; its recovery cell may hold
    /// parked jobs.
    Died(usize),
}

/// Spawn worker `idx` homed on `home`. The top-level `catch_unwind`
/// is the death detector: a panic that escapes `worker_loop` (the
/// per-job containment never lets a *sort* panic out; this catches
/// injected fatal panics and genuine bugs) reports
/// [`WorkerNotice::Died`] so the supervisor can join + respawn
/// instead of the service silently losing a worker.
fn spawn_worker(
    shared: &Arc<Shared>,
    idx: usize,
    home: usize,
    cell: RecoveryCell,
    notice: mpsc::Sender<WorkerNotice>,
) -> Result<JoinHandle<()>> {
    let shared = Arc::clone(shared);
    std::thread::Builder::new()
        .name(format!("sort-worker-{idx}"))
        .spawn(move || {
            let outcome = catch_unwind(AssertUnwindSafe(|| worker_loop(&shared, home, &cell)));
            let _ = notice.send(match outcome {
                Ok(()) => WorkerNotice::Exited(idx),
                Err(_) => WorkerNotice::Died(idx),
            });
        })
        .context("spawning worker")
}

/// The supervisor: joins workers as they end, and for a death —
/// recover the jobs the worker parked, quarantine any that have now
/// killed two workers, requeue the rest, and respawn the thread
/// (unless the service is shutting down, in which case the pool is
/// allowed to drain). Exits when the last worker is down; the
/// service's `shutdown` joins *this* thread instead of the workers.
fn supervisor_loop(
    shared: &Arc<Shared>,
    mut workers: Vec<Option<JoinHandle<()>>>,
    homes: &[usize],
    cells: &[RecoveryCell],
    notice_tx: &mpsc::Sender<WorkerNotice>,
    notice_rx: &mpsc::Receiver<WorkerNotice>,
) {
    let mut live = workers.iter().filter(|w| w.is_some()).count();
    while live > 0 {
        let Ok(notice) = notice_rx.recv() else {
            return; // unreachable while we hold a sender; defensive
        };
        match notice {
            WorkerNotice::Exited(idx) => {
                if let Some(h) = workers[idx].take() {
                    let _ = h.join();
                }
                live -= 1;
            }
            WorkerNotice::Died(idx) => {
                if let Some(h) = workers[idx].take() {
                    let _ = h.join();
                }
                let held = std::mem::take(
                    // The dying worker may have poisoned its cell;
                    // the parked Vec is still intact.
                    &mut *cells[idx].lock().unwrap_or_else(|e| e.into_inner()),
                );
                recover_jobs(shared, held);
                if shared.shutdown.load(Ordering::SeqCst) {
                    live -= 1; // shutting down: let the pool drain
                } else {
                    shared.metrics.workers_respawned.fetch_add(1, Ordering::Relaxed);
                    match spawn_worker(
                        shared,
                        idx,
                        homes[idx],
                        Arc::clone(&cells[idx]),
                        notice_tx.clone(),
                    ) {
                        Ok(h) => workers[idx] = Some(h),
                        Err(_) => live -= 1, // spawn failed: degrade
                    }
                }
            }
        }
    }
}

/// Re-dispatch the jobs a dead worker parked: cancelled ones are
/// abandoned, a fatally-flagged job that has now killed two workers
/// is quarantined, everything else goes back into a queue untouched
/// (same tag, same charge — the requeue is invisible to QoS). A
/// requeue that fails (shutdown, or queues full) resolves the handle
/// to [`SortError::JobPanicked`] rather than leaving a waiter parked.
fn recover_jobs(shared: &Arc<Shared>, held: Vec<Job>) {
    let m = &shared.metrics;
    for mut job in held {
        if job.slot.is_cancelled() {
            abandon(m, job);
            continue;
        }
        if job.fault == FaultDecision::FatalPanic {
            job.deaths = job.deaths.saturating_add(1);
            if u32::from(job.deaths) >= shared.cfg.quarantine_deaths {
                m.quarantined.fetch_add(1, Ordering::Relaxed);
                // Retain the poisonous payload *before* failing the
                // handle — fail() is the last owner of `job`.
                shared.retain_dead_letter(&job);
                fail(m, job, SortError::Quarantined);
                continue;
            }
        }
        match shared.try_place(job) {
            Ok(()) => shared.signal_work(),
            Err(job) => fail(m, job, SortError::JobPanicked),
        }
    }
}

fn worker_loop(shared: &Shared, home: usize, cell: &RecoveryCell) {
    let n = shared.shards.len();
    // Sorters + reusable buffers, owned by this worker for its
    // lifetime (see WorkerCtx).
    let mut ctx = WorkerCtx::new(&shared.cfg);
    loop {
        // Own shard first, then steal round-robin from the others.
        if let Some(batch) = take_batch(shared, home) {
            process_batch(shared, home, batch, cell, &mut ctx);
            tick_tuner(shared);
            continue;
        }
        let mut found = None;
        for off in 1..n {
            let victim = (home + off) % n;
            if let Some(batch) = take_batch(shared, victim) {
                shared.shards[home].metrics.steals.fetch_add(1, Ordering::Relaxed);
                found = Some((victim, batch));
                break;
            }
        }
        if let Some((victim, batch)) = found {
            process_batch(shared, victim, batch, cell, &mut ctx);
            tick_tuner(shared);
            continue;
        }
        // Nothing anywhere: advertise as idle, re-check under the
        // hub (the INC-then-re-check side of the SeqCst protocol in
        // the module docs), then sleep — or exit when shutting down
        // with all queues drained.
        let guard = shared.hub.lock().unwrap();
        shared.idle_workers.fetch_add(1, Ordering::SeqCst);
        let any_work =
            shared.shards.iter().any(|s| !s.queue.lock().unwrap().is_empty());
        if any_work {
            shared.idle_workers.fetch_sub(1, Ordering::SeqCst);
            continue;
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            shared.idle_workers.fetch_sub(1, Ordering::SeqCst);
            return;
        }
        let guard = shared.work_cv.wait(guard).unwrap();
        shared.idle_workers.fetch_sub(1, Ordering::SeqCst);
        drop(guard);
    }
}

/// Worker-wakeup tuner hook: a no-op unless adaptive routing is on
/// and an epoch's worth of jobs has completed since the last tick.
fn tick_tuner(shared: &Shared) {
    if let Some(t) = &shared.tuner {
        t.maybe_tick(&shared.metrics, &shared.routing);
    }
}

/// Discard a job that will never be sorted — its handle was dropped
/// before a worker reached it, or it was still queued when the
/// service shut down: count the skip, release the tenant's in-flight
/// QoS cost, then let the job's drop guard close the slot. (For the
/// anonymous bucket the tenant-side counter is invisible — it is
/// never snapshotted — but the release keeps the gauge exact.)
fn abandon(m: &Metrics, job: Job) {
    m.cancelled.fetch_add(1, Ordering::Relaxed);
    job.tenant.cancelled.fetch_add(1, Ordering::Relaxed);
    job.tenant.qos.release(job.cost);
}

/// Fail a job the service gave up on (contained panic, quarantine,
/// failed requeue): count it `failed`, release the tenant's in-flight
/// cost — the charge is *spent*, not refunded, because a worker did
/// burn time on this job — and resolve the handle with `err`.
fn fail(m: &Metrics, job: Job, err: SortError) {
    m.failed.fetch_add(1, Ordering::Relaxed);
    job.tenant.failed.fetch_add(1, Ordering::Relaxed);
    job.tenant.qos.release(job.cost);
    job.slot.close_with(err);
}

/// Reap a deadline-expired job: `failed` + `deadline_expired`, QoS
/// charge *refunded* (uncharge — in-flight and virtual time, exactly
/// like an eviction: the request consumed no service, so its tenant
/// must not be penalized in the fair-share ordering for it), handle
/// resolved to [`SortError::DeadlineExceeded`].
fn reap(m: &Metrics, job: Job) {
    m.failed.fetch_add(1, Ordering::Relaxed);
    m.deadline_expired.fetch_add(1, Ordering::Relaxed);
    job.tenant.failed.fetch_add(1, Ordering::Relaxed);
    job.tenant.deadline_expired.fetch_add(1, Ordering::Relaxed);
    job.tenant.qos.uncharge(job.cost, job.vdelta);
    job.slot.close_with(SortError::DeadlineExceeded);
}

/// Whether a job's reap-by instant has passed. `>=`, not `>`, so a
/// [`Duration::ZERO`] deadline expires deterministically.
fn expired(job: &Job) -> bool {
    job.deadline.is_some_and(|d| Instant::now() >= d)
}

/// Execute one dynamic batch taken from shard `src`: single jobs go
/// through the size-tiered router; multi-job batches take the fused
/// path — concatenate into one buffer with recorded offsets, sort all
/// segments in a single [`ParallelNeonMergeSort::sort_segments_with`]
/// pass, and complete each request's slot the moment its own segment
/// is sorted.
fn process_batch(
    shared: &Shared,
    src: usize,
    batch: Vec<Job>,
    cell: &RecoveryCell,
    ctx: &mut WorkerCtx,
) {
    let m = &shared.metrics;
    // Injected *fatal* panic (tests only): park the whole batch in
    // the recovery cell first, then kill the worker. Parking before
    // panicking is the invariant that keeps the accounting identity
    // alive — an unwinding drop of these jobs would close their slots
    // as generic shutdowns with no terminal counter. The supervisor
    // drains the cell, quarantines the killer if it strikes twice,
    // and requeues the innocent bystanders.
    if shared.cfg.faults.is_some()
        && batch.iter().any(|j| j.fault == FaultDecision::FatalPanic)
    {
        cell.lock().unwrap_or_else(|e| e.into_inner()).extend(batch);
        panic!("injected fatal worker panic");
    }
    // Shed cancelled jobs and reap expired ones before paying for any
    // sorting; divert fault-flagged jobs to the solo router so the
    // fused path stays injection-free (a mid-batch panic would
    // otherwise fail innocent segments).
    let mut live: Vec<Job> = Vec::with_capacity(batch.len());
    for job in batch {
        if job.slot.is_cancelled() {
            abandon(m, job);
        } else if expired(&job) {
            reap(m, job);
        } else if job.fault != FaultDecision::None {
            process(shared, job, ctx);
        } else {
            live.push(job);
        }
    }
    // Solo probes (adaptive only): pull 1 in PROBE_PERIOD jobs out of
    // a would-be fused batch and run them through the solo router.
    // Under sustained load everything fuse-eligible fuses, which
    // would starve the Tiny/Single observation classes the tuner
    // compares — both at the boundaries and as the solo side of the
    // fused-vs-solo verdict.
    if shared.tuner.is_some() && live.len() > 1 {
        // In-place walk (swap_remove, no allocation): batch order is
        // irrelevant to correctness — every job completes through its
        // own slot/segment either way.
        let mut i = 0;
        while i < live.len() {
            if shared.routing.solo_probe() {
                let job = live.swap_remove(i);
                process(shared, job, ctx);
            } else {
                i += 1;
            }
        }
    }
    if live.len() <= 1 {
        if let Some(job) = live.pop() {
            process(shared, job, ctx);
        }
        return;
    }
    // Count the fused batch only now — after the cancellation filter —
    // so occupancy reflects jobs that actually went through a fused
    // sort, attributed to the shard the batch was taken from.
    let sm = &shared.shards[src].metrics;
    sm.batches.fetch_add(1, Ordering::Relaxed);
    sm.batched_jobs.fetch_add(live.len() as u64, Ordering::Relaxed);
    // take_batch only drains same-kind followers, so the whole batch
    // shares the head's element kind; dispatch once to the typed
    // fused path, handing it that kind's reusable buffers (disjoint
    // WorkerCtx field borrows keep this a plain function call).
    let kind = live[0].data.kind();
    debug_assert!(live.iter().all(|j| j.data.kind() == kind), "mixed-kind fused batch");
    match kind {
        ElemKind::U32 => fused_sort::<u32>(
            shared,
            live,
            &ctx.parallel,
            &mut ctx.fused_u32,
            &mut ctx.scratch_u32,
            &mut ctx.bounds,
        ),
        ElemKind::U64 => fused_sort::<u64>(
            shared,
            live,
            &ctx.parallel,
            &mut ctx.fused_u64,
            &mut ctx.scratch_u64,
            &mut ctx.bounds,
        ),
        ElemKind::Pair => fused_sort::<KeyValue>(
            shared,
            live,
            &ctx.parallel,
            &mut ctx.fused_pair,
            &mut ctx.scratch_pair,
            &mut ctx.bounds,
        ),
    }
}

/// The typed fused-batch sort: concatenate the (same-kind) batch into
/// the worker's reusable buffer for `T`, sort every segment in one
/// [`ParallelNeonMergeSort::sort_segments_with_scratch`] pass, and
/// complete each request's slot the moment its own segment is sorted.
fn fused_sort<T: SortElem>(
    shared: &Shared,
    live: Vec<Job>,
    parallel: &ParallelNeonMergeSort,
    fused: &mut Vec<T>,
    scratch: &mut SortScratch<T>,
    bounds: &mut Vec<usize>,
) {
    let m = &shared.metrics;
    let total: usize = live.iter().map(|j| j.data.len()).sum();
    // Concatenate into the worker's reusable fused buffer (clear
    // keeps capacity — steady-state batches don't allocate here).
    fused.clear();
    fused.reserve(total);
    bounds.clear();
    bounds.push(0);
    let tiny_cutoff = shared.routing.snapshot().tiny_cutoff;
    for job in &live {
        fused.extend_from_slice(T::slice(&job.data));
        bounds.push(fused.len());
        // Fused jobs still count under their size tier.
        if job.data.len() < tiny_cutoff {
            m.route_tiny.fetch_add(1, Ordering::Relaxed);
        } else {
            m.route_single.fetch_add(1, Ordering::Relaxed);
        }
    }
    // One cell per request; each is taken exactly once, by whichever
    // batch-sort thread finishes that segment (uncontended in
    // practice — the per-segment lock is the completion hand-off).
    let cells: Vec<Mutex<Option<Job>>> = live.into_iter().map(|j| Mutex::new(Some(j))).collect();
    let t0 = Instant::now();
    // Containment for the fused pass: a panic mid-batch fails only
    // the segments not yet completed — their cells are still `Some` —
    // while requests whose segments already finished keep their
    // results (their slots were completed inside the callback). The
    // per-segment lock uses poison recovery because a panic on one
    // batch-sort thread poisons the cells its unwinding touched.
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        parallel.sort_segments_with_scratch(fused, bounds, scratch, |k, seg: &[T]| {
            if let Some(mut job) = cells[k].lock().unwrap_or_else(|e| e.into_inner()).take() {
                T::slice_mut(&mut job.data).copy_from_slice(seg);
                finish(m, job);
            }
        });
    }));
    match outcome {
        Ok(()) => {
            // One fused observation for the whole pass; each segment's
            // size class is charged its proportional share (see
            // RouteObs), so the tuner can compare fused against solo
            // execution per class.
            m.routes.get(Tier::Fused).record_segments(bounds, t0.elapsed());
        }
        Err(_) => {
            m.panics_contained.fetch_add(1, Ordering::Relaxed);
            for cell in &cells {
                if let Some(job) = cell.lock().unwrap_or_else(|e| e.into_inner()).take() {
                    fail(m, job, SortError::JobPanicked);
                }
            }
        }
    }
}

fn process(shared: &Shared, mut job: Job, ctx: &mut WorkerCtx) {
    let m = &shared.metrics;
    if job.slot.is_cancelled() {
        return abandon(m, job);
    }
    if expired(&job) {
        return reap(m, job);
    }
    // Injected stall (tests only): burn wall-clock before sorting —
    // the deterministic way to drive a real deadline past expiry —
    // then re-check, since the stall may have consumed the budget.
    if let FaultDecision::Stall(d) = job.fault {
        std::thread::sleep(d);
        if expired(&job) {
            return reap(m, job);
        }
    }
    // Live routing state, with boundary probing when adaptive: a
    // small fraction of jobs near a cutoff run on the neighbor tier
    // so the tuner observes both sides of the boundary. The XLA tier
    // only exists for u32 payloads (the AOT artifacts are 32-bit), so
    // wider jobs route as if the accelerator were absent.
    let kind = job.data.kind();
    let xla_ok = shared.xla_enabled() && kind == ElemKind::U32;
    let mut route = shared.routing.route_probed(job.data.len(), xla_ok, shared.cfg.xla_cutoff);
    if route == Route::Xla {
        // Forward; the executor thread counts route_xla (after its
        // own cancellation check) and completes the slot. If it
        // became unreachable since routing (revoked or died), fall
        // back to the CPU route for this size — the arms below, so
        // the fallback can never drift from the normal tiers.
        match shared.xla_send(job) {
            Ok(()) => return,
            Err(j) => {
                job = j;
                route = shared.routing.route(job.data.len(), false, None);
            }
        }
    }
    match kind {
        ElemKind::U32 => process_cpu::<u32>(
            shared, job, route, &ctx.single, &ctx.parallel, &mut ctx.scratch_u32,
        ),
        ElemKind::U64 => process_cpu::<u64>(
            shared, job, route, &ctx.single, &ctx.parallel, &mut ctx.scratch_u64,
        ),
        ElemKind::Pair => process_cpu::<KeyValue>(
            shared, job, route, &ctx.single, &ctx.parallel, &mut ctx.scratch_pair,
        ),
    }
}

/// The typed CPU tiers for one solo job: insertion sort, single-thread
/// NEON-MS, or merge-path parallel, against the worker's per-kind
/// scratch. Each arm times the sort itself (not queueing) and records
/// it against the tier that actually ran — probes included, which is
/// the point: the observation grid is the tuner's input signal.
fn process_cpu<T: SortElem>(
    shared: &Shared,
    mut job: Job,
    route: Route,
    single: &NeonMergeSort,
    parallel: &ParallelNeonMergeSort,
    scratch: &mut SortScratch<T>,
) {
    let m = &shared.metrics;
    let len = job.data.len();
    let t0 = Instant::now();
    // Panic containment: the sort runs inside a `catch_unwind`
    // envelope, so a panicking kernel (or the injected SortPanic)
    // fails *this* job — handle resolved, counters bumped — and the
    // worker moves on. AssertUnwindSafe is sound here: on unwind the
    // job's payload is simply discarded along with the job, and the
    // worker scratch's only invariant is its length, which every sort
    // re-establishes on entry.
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        if job.fault == FaultDecision::SortPanic {
            panic!("injected sort panic");
        }
        match route {
            Route::Tiny => {
                m.route_tiny.fetch_add(1, Ordering::Relaxed);
                insertion_sort(T::slice_mut(&mut job.data));
                Tier::Tiny
            }
            Route::SingleThread => {
                m.route_single.fetch_add(1, Ordering::Relaxed);
                // Worker-owned sorter + scratch: zero allocation once the
                // scratch has grown to the tier's largest request.
                single.sort_with_scratch(T::slice_mut(&mut job.data), scratch);
                Tier::Single
            }
            Route::Parallel => {
                m.route_parallel.fetch_add(1, Ordering::Relaxed);
                parallel.sort_with_scratch(T::slice_mut(&mut job.data), scratch);
                Tier::Parallel
            }
            Route::Xla => unreachable!("route(len, xla_available=false) never returns Xla"),
        }
    }));
    match outcome {
        Ok(tier) => {
            m.routes.get(tier).record(len, t0.elapsed());
            finish(m, job);
        }
        Err(_) => {
            m.panics_contained.fetch_add(1, Ordering::Relaxed);
            fail(m, job, SortError::JobPanicked);
        }
    }
}

/// Complete one job: record the metrics and release the tenant's
/// in-flight QoS cost, then deposit the sorted data in the slot —
/// which wakes the parked waiter and/or registered async waker.
/// Counters (and the release) land before the completion signal so a
/// caller that observes the result also observes its own counts and
/// a drained in-flight gauge.
fn finish(m: &Metrics, mut job: Job) {
    let data = std::mem::take(&mut job.data);
    let latency = job.enqueued.elapsed();
    m.elements.fetch_add(data.len() as u64, Ordering::Relaxed);
    m.latency.record(latency);
    m.completed.fetch_add(1, Ordering::Relaxed);
    job.tenant.completed.fetch_add(1, Ordering::Relaxed);
    job.tenant.latency.record(latency);
    job.tenant.qos.release(job.cost);
    // Receiver may have given up; complete() discards in that case.
    job.slot.complete(data);
}

/// CPU-sort a payload of any kind on the XLA executor's fallback
/// sorter. Only non-`u32` payloads take the allocating `sort` arms —
/// routing never forwards one (see `process`), so those arms exist
/// purely as a defensive backstop against a routing bug; the `u32`
/// callers below use the scratch-reusing path directly.
fn wide_fallback(fallback: &NeonMergeSort, job: &mut Job) {
    match &mut job.data {
        ElemBuf::U32(v) => fallback.sort(v),
        ElemBuf::U64(v) => fallback.sort(v),
        ElemBuf::Pair(v) => fallback.sort(v),
    }
}

/// Mirror the executor-owned breaker into the lock-free metrics
/// gauges after every recorded outcome (the breaker itself is plain
/// mutable state on the executor thread; this is its only escape).
fn publish_breaker(m: &Metrics, b: &CircuitBreaker) {
    m.breaker_state.store(b.state_code(), Ordering::Relaxed);
    m.breaker_trips.store(b.trips(), Ordering::Relaxed);
}

/// One breaker-guarded accelerator dispatch. Returns whether the
/// accelerator sorted the payload; `false` — breaker open (the call
/// was never made), injected failure, or a real PJRT error — means
/// the caller must run the CPU fallback. `forced_fail` is the
/// [`FaultDecision::XlaError`] injection: counted as a failure
/// without paying for a dispatch, so tests can trip the breaker
/// deterministically.
fn xla_dispatch(
    breaker: &mut CircuitBreaker,
    metrics: &Metrics,
    forced_fail: bool,
    run: impl FnOnce() -> bool,
) -> bool {
    let ok = if !breaker.allow() {
        false
    } else if forced_fail {
        breaker.record_failure();
        false
    } else if run() {
        breaker.record_success();
        true
    } else {
        breaker.record_failure();
        false
    };
    publish_breaker(metrics, breaker);
    ok
}

/// Dedicated thread owning the (!Send) PJRT client + executables.
fn xla_executor(
    reg: ArtifactRegistry,
    rx: mpsc::Receiver<Job>,
    ready: mpsc::Sender<Result<()>>,
    metrics: Arc<Metrics>,
    cfg: CoordinatorConfig,
) {
    let sorter = match PjrtRuntime::cpu()
        .map(Arc::new)
        .and_then(|rt| BlockSorter::new(rt, &reg))
    {
        Ok(s) => {
            let _ = ready.send(Ok(()));
            s
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let geometry = sorter.batch_geometry();
    // CPU fallback sorter + scratch, built once from the service's
    // configured kernel (CoordinatorConfig::sort governs every CPU
    // tier, fallbacks included): PJRT failures must not pay a per-job
    // construction or aux allocation — nor silently switch kernels.
    let fallback = NeonMergeSort::new(cfg.sort.clone());
    let mut fb_scratch = SortScratch::new();
    // Degradation guard: consecutive PJRT failures trip this open and
    // every job takes the CPU fallback without paying for a doomed
    // dispatch; timed half-open probes recover (see runtime::breaker).
    // Threshold and cool-off are service knobs
    // (CoordinatorConfig::breaker_threshold / breaker_cooloff).
    let mut breaker = CircuitBreaker::new(cfg.breaker_threshold, cfg.breaker_cooloff);
    publish_breaker(&metrics, &breaker);
    while let Ok(mut job) = rx.recv() {
        if job.slot.is_cancelled() {
            abandon(&metrics, job);
            continue;
        }
        if expired(&job) {
            reap(&metrics, job);
            continue;
        }
        // Count the route here, after the cancellation check, so
        // route_xla only covers jobs the executor actually sorts —
        // mirroring how the CPU paths count after their filters.
        metrics.route_xla.fetch_add(1, Ordering::Relaxed);
        // Routing never forwards non-u32 jobs (the AOT artifacts are
        // compiled for 32-bit rows); if one arrives anyway, CPU-sort
        // it rather than dropping the request.
        if job.data.kind() != ElemKind::U32 {
            let t0 = Instant::now();
            wide_fallback(&fallback, &mut job);
            metrics.routes.get(Tier::Xla).record(job.data.len(), t0.elapsed());
            finish(&metrics, job);
            continue;
        }
        // Opportunistic dynamic batching through the accelerator: if a
        // batched artifact is compiled and this job fits one row, pull
        // whatever fitting jobs are already queued (non-blocking) and
        // sort them all in a single PJRT dispatch.
        if let Some((batch, block)) = geometry {
            if job.data.len() <= block {
                let mut group = vec![job];
                let mut oversized = Vec::new();
                while group.len() < batch {
                    match rx.try_recv() {
                        Ok(j) if j.slot.is_cancelled() => abandon(&metrics, j),
                        Ok(j) if expired(&j) => reap(&metrics, j),
                        // Same defensive non-u32 backstop as the
                        // outer loop: CPU-sort it, never batch it.
                        Ok(mut j) if j.data.kind() != ElemKind::U32 => {
                            metrics.route_xla.fetch_add(1, Ordering::Relaxed);
                            let t0 = Instant::now();
                            wide_fallback(&fallback, &mut j);
                            metrics.routes.get(Tier::Xla).record(j.data.len(), t0.elapsed());
                            finish(&metrics, j);
                        }
                        Ok(j) if j.data.len() <= block => {
                            metrics.route_xla.fetch_add(1, Ordering::Relaxed);
                            group.push(j);
                        }
                        // Oversized spill: sorted below, after its own
                        // cancellation re-check (which also counts the
                        // route then, mirroring the rule above).
                        Ok(j) => {
                            oversized.push(j);
                            break;
                        }
                        Err(_) => break,
                    }
                }
                if group.len() > 1 {
                    metrics.batches.fetch_add(1, Ordering::Relaxed);
                    // Offset table so the coalesced dispatch records
                    // like the CPU fused path: per-job size classes
                    // and proportional per-job latency samples, not
                    // one batch-total observation.
                    let mut offsets = Vec::with_capacity(group.len() + 1);
                    offsets.push(0);
                    for j in &group {
                        offsets.push(*offsets.last().unwrap() + j.data.len());
                    }
                    let t0 = Instant::now();
                    // One forced-fault roll anywhere in the group fails
                    // the whole dispatch — PJRT errors are per call,
                    // not per row.
                    let forced = group.iter().any(|j| j.fault == FaultDecision::XlaError);
                    let mut rows: Vec<&mut [u32]> =
                        group.iter_mut().map(|j| u32::slice_mut(&mut j.data)).collect();
                    if !xla_dispatch(&mut breaker, &metrics, forced, || {
                        sorter.sort_batch_u32(&mut rows).is_ok()
                    }) {
                        for j in group.iter_mut() {
                            fallback.sort_with_scratch(u32::slice_mut(&mut j.data), &mut fb_scratch);
                        }
                    }
                    metrics.routes.get(Tier::Xla).record_segments(&offsets, t0.elapsed());
                    for j in group {
                        finish(&metrics, j);
                    }
                } else {
                    for mut j in group {
                        let t0 = Instant::now();
                        let forced = j.fault == FaultDecision::XlaError;
                        if !xla_dispatch(&mut breaker, &metrics, forced, || {
                            sorter.sort_u32(u32::slice_mut(&mut j.data)).is_ok()
                        }) {
                            fallback.sort_with_scratch(u32::slice_mut(&mut j.data), &mut fb_scratch);
                        }
                        metrics.routes.get(Tier::Xla).record(j.data.len(), t0.elapsed());
                        finish(&metrics, j);
                    }
                }
                for mut j in oversized {
                    // The batching drain above parked this job; its
                    // handle may have been dropped in the meantime —
                    // re-check before paying for a full sort, so an
                    // abandoned oversized spill costs one atomic load
                    // and is counted `cancelled`, not sorted.
                    if j.slot.is_cancelled() {
                        abandon(&metrics, j);
                        continue;
                    }
                    if expired(&j) {
                        reap(&metrics, j);
                        continue;
                    }
                    metrics.route_xla.fetch_add(1, Ordering::Relaxed);
                    let t0 = Instant::now();
                    let forced = j.fault == FaultDecision::XlaError;
                    if !xla_dispatch(&mut breaker, &metrics, forced, || {
                        sorter.sort_u32(u32::slice_mut(&mut j.data)).is_ok()
                    }) {
                        fallback.sort_with_scratch(u32::slice_mut(&mut j.data), &mut fb_scratch);
                    }
                    metrics.routes.get(Tier::Xla).record(j.data.len(), t0.elapsed());
                    finish(&metrics, j);
                }
                continue;
            }
        }
        let t0 = Instant::now();
        let forced = job.fault == FaultDecision::XlaError;
        if !xla_dispatch(&mut breaker, &metrics, forced, || {
            sorter.sort_u32(u32::slice_mut(&mut job.data)).is_ok()
        }) {
            // Fall back to the CPU path rather than dropping the job.
            fallback.sort_with_scratch(u32::slice_mut(&mut job.data), &mut fb_scratch);
        }
        metrics.routes.get(Tier::Xla).record(job.data.len(), t0.elapsed());
        finish(&metrics, job);
    }
}
