//! The sort service: bounded queue, router, dynamic batcher, worker
//! pool, and the confined XLA executor thread.
//!
//! Threading model: `N` CPU workers drain a bounded `Mutex<VecDeque>`
//! + condvar queue (blocking `submit` = backpressure). The PJRT client
//! is `Rc`-based (!Send), so XLA offload runs on one dedicated
//! executor thread owning the [`BlockSorter`]; workers forward
//! Xla-routed jobs over an `mpsc` channel and move on — the executor
//! answers the requester directly.

use super::config::{CoordinatorConfig, Route};
use super::metrics::{Metrics, MetricsSnapshot};
use crate::kernels::serial::insertion_sort;
use crate::runtime::{ArtifactRegistry, BlockSorter, PjrtRuntime};
use crate::sort::{NeonMergeSort, ParallelNeonMergeSort};
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// One queued request.
struct Job {
    data: Vec<u32>,
    enqueued: Instant,
    reply: mpsc::Sender<Vec<u32>>,
}

/// Handle to a submitted request; [`SortHandle::wait`] blocks for the
/// sorted result.
pub struct SortHandle {
    rx: mpsc::Receiver<Vec<u32>>,
}

impl SortHandle {
    /// Block until the sorted vector arrives.
    pub fn wait(self) -> Result<Vec<u32>> {
        self.rx.recv().context("sort worker dropped the request")
    }
}

struct Shared {
    cfg: CoordinatorConfig,
    queue: Mutex<VecDeque<Job>>,
    not_empty: Condvar,
    not_full: Condvar,
    shutdown: AtomicBool,
    metrics: Arc<Metrics>,
    xla_tx: Option<mpsc::Sender<Job>>,
}

/// The coordinator service.
pub struct SortService {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    xla_thread: Option<JoinHandle<()>>,
}

impl SortService {
    /// Start with `cfg`; if `artifacts_dir` is `Some` and contains
    /// artifacts, an XLA executor thread is started and Xla routing is
    /// enabled (subject to `cfg.xla_cutoff`).
    pub fn start(cfg: CoordinatorConfig, artifacts_dir: Option<PathBuf>) -> Result<Self> {
        let metrics = Arc::new(Metrics::default());
        let (xla_tx, xla_thread) = match artifacts_dir {
            Some(dir) => {
                let reg = ArtifactRegistry::scan(&dir);
                if reg.is_empty() {
                    (None, None)
                } else {
                    let (tx, rx) = mpsc::channel::<Job>();
                    // Handshake so startup failures surface in start().
                    let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
                    let xm = Arc::clone(&metrics);
                    let handle = std::thread::Builder::new()
                        .name("xla-executor".into())
                        .spawn(move || xla_executor(reg, rx, ready_tx, xm))
                        .context("spawning xla executor")?;
                    ready_rx.recv().context("xla executor died at startup")??;
                    (Some(tx), Some(handle))
                }
            }
            None => (None, None),
        };

        let shared = Arc::new(Shared {
            cfg: cfg.clone(),
            queue: Mutex::new(VecDeque::new()),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            shutdown: AtomicBool::new(false),
            metrics,
            xla_tx,
        });

        let mut workers = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let shared = Arc::clone(&shared);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("sort-worker-{w}"))
                    .spawn(move || worker_loop(&shared))
                    .context("spawning worker")?,
            );
        }
        Ok(SortService { shared, workers, xla_thread })
    }

    /// Start with defaults and no XLA offload.
    pub fn start_default() -> Result<Self> {
        SortService::start(CoordinatorConfig::default(), None)
    }

    /// True if the XLA executor is running.
    pub fn xla_enabled(&self) -> bool {
        self.shared.xla_tx.is_some()
    }

    /// Submit a sort request, blocking while the queue is full
    /// (backpressure).
    pub fn submit(&self, data: Vec<u32>) -> SortHandle {
        let (reply, rx) = mpsc::channel();
        let job = Job { data, enqueued: Instant::now(), reply };
        let mut q = self.shared.queue.lock().unwrap();
        while q.len() >= self.shared.cfg.queue_capacity {
            q = self.shared.not_full.wait(q).unwrap();
        }
        q.push_back(job);
        self.shared.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        drop(q);
        self.shared.not_empty.notify_one();
        SortHandle { rx }
    }

    /// Non-blocking submit; `Err(data)` returns the input when the
    /// queue is full (caller decides to retry/shed).
    pub fn try_submit(&self, data: Vec<u32>) -> std::result::Result<SortHandle, Vec<u32>> {
        let mut q = self.shared.queue.lock().unwrap();
        if q.len() >= self.shared.cfg.queue_capacity {
            self.shared.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(data);
        }
        let (reply, rx) = mpsc::channel();
        q.push_back(Job { data, enqueued: Instant::now(), reply });
        self.shared.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        drop(q);
        self.shared.not_empty.notify_one();
        Ok(SortHandle { rx })
    }

    /// Current metrics.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    /// Drain the queue and stop all threads. Consumes the service;
    /// outstanding handles still receive their results first.
    pub fn shutdown(self) {
        let SortService { shared, workers, xla_thread } = self;
        shared.shutdown.store(true, Ordering::SeqCst);
        shared.not_empty.notify_all();
        for w in workers {
            let _ = w.join();
        }
        // Dropping the last Shared Arc drops the xla sender, which
        // disconnects the executor's channel and ends its loop.
        drop(shared);
        if let Some(t) = xla_thread {
            let _ = t.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        // Pop one job (plus a batch of tiny ones) or exit.
        let batch = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    let mut batch = vec![job];
                    // Dynamic batching: drain further *tiny* requests
                    // in the same wakeup to amortize scheduling.
                    if batch[0].data.len() < shared.cfg.tiny_cutoff {
                        while batch.len() < shared.cfg.batch_max {
                            match q.front() {
                                Some(j) if j.data.len() < shared.cfg.tiny_cutoff => {
                                    batch.push(q.pop_front().unwrap());
                                }
                                _ => break,
                            }
                        }
                        if batch.len() > 1 {
                            shared.metrics.batches.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    break batch;
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = shared.not_empty.wait(q).unwrap();
            }
        };
        shared.not_full.notify_all();
        for job in batch {
            process(shared, job);
        }
    }
}

fn process(shared: &Shared, mut job: Job) {
    let m = &shared.metrics;
    let route = shared.cfg.route(job.data.len(), shared.xla_tx.is_some());
    match route {
        Route::Tiny => {
            m.route_tiny.fetch_add(1, Ordering::Relaxed);
            insertion_sort(&mut job.data);
        }
        Route::SingleThread => {
            m.route_single.fetch_add(1, Ordering::Relaxed);
            // Thread-local sorter: construction is cheap (network
            // tables are small) and avoids sharing.
            thread_local! {
                static SORTER: NeonMergeSort = NeonMergeSort::paper_default();
            }
            SORTER.with(|s| s.sort(&mut job.data));
        }
        Route::Parallel => {
            m.route_parallel.fetch_add(1, Ordering::Relaxed);
            ParallelNeonMergeSort::with_threads(shared.cfg.threads_per_parallel_sort)
                .sort(&mut job.data);
        }
        Route::Xla => {
            m.route_xla.fetch_add(1, Ordering::Relaxed);
            // Forward; the executor thread completes the reply.
            if let Some(tx) = &shared.xla_tx {
                if tx.send(job).is_ok() {
                    return;
                }
            }
            unreachable!("route() returned Xla without an executor");
        }
    }
    finish(m, job);
}

fn finish(m: &Metrics, job: Job) {
    m.elements.fetch_add(job.data.len() as u64, Ordering::Relaxed);
    m.latency.record(job.enqueued.elapsed());
    m.completed.fetch_add(1, Ordering::Relaxed);
    // Receiver may have given up; that's fine.
    let _ = job.reply.send(job.data);
}

/// Dedicated thread owning the (!Send) PJRT client + executables.
fn xla_executor(
    reg: ArtifactRegistry,
    rx: mpsc::Receiver<Job>,
    ready: mpsc::Sender<Result<()>>,
    metrics: Arc<Metrics>,
) {
    let sorter = match PjrtRuntime::cpu()
        .map(Arc::new)
        .and_then(|rt| BlockSorter::new(rt, &reg))
    {
        Ok(s) => {
            let _ = ready.send(Ok(()));
            s
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let geometry = sorter.batch_geometry();
    while let Ok(mut job) = rx.recv() {
        // Opportunistic dynamic batching through the accelerator: if a
        // batched artifact is compiled and this job fits one row, pull
        // whatever fitting jobs are already queued (non-blocking) and
        // sort them all in a single PJRT dispatch.
        if let Some((batch, block)) = geometry {
            if job.data.len() <= block {
                let mut group = vec![job];
                let mut oversized = Vec::new();
                while group.len() < batch {
                    match rx.try_recv() {
                        Ok(j) if j.data.len() <= block => group.push(j),
                        Ok(j) => {
                            oversized.push(j);
                            break;
                        }
                        Err(_) => break,
                    }
                }
                if group.len() > 1 {
                    metrics.batches.fetch_add(1, Ordering::Relaxed);
                    let mut rows: Vec<&mut [u32]> =
                        group.iter_mut().map(|j| j.data.as_mut_slice()).collect();
                    if sorter.sort_batch_u32(&mut rows).is_err() {
                        for j in group.iter_mut() {
                            NeonMergeSort::paper_default().sort(&mut j.data);
                        }
                    }
                    for j in group {
                        finish(&metrics, j);
                    }
                } else {
                    for mut j in group {
                        if sorter.sort_u32(&mut j.data).is_err() {
                            NeonMergeSort::paper_default().sort(&mut j.data);
                        }
                        finish(&metrics, j);
                    }
                }
                for mut j in oversized {
                    if sorter.sort_u32(&mut j.data).is_err() {
                        NeonMergeSort::paper_default().sort(&mut j.data);
                    }
                    finish(&metrics, j);
                }
                continue;
            }
        }
        if sorter.sort_u32(&mut job.data).is_err() {
            // Fall back to the CPU path rather than dropping the job.
            NeonMergeSort::paper_default().sort(&mut job.data);
        }
        finish(&metrics, job);
    }
}
