//! Service-level tests: every request completes exactly once with the
//! oracle result; queue bounds hold under overload; shutdown drains;
//! tenant clients account their accepted/shed/completed/cancelled
//! requests; dropped handles cancel without wedging workers; and
//! fair-share QoS holds its two contracts — completed elements
//! converge to the weight ratios under saturation, and a within-burst
//! victim is never shed while an over-share tenant has queued work.

use super::*;
use crate::simd::KeyValue;
use crate::testutil::{assert_sorted, Rng};
use std::time::Duration;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
fn every_request_completes_with_oracle_result() {
    let svc = SortService::start_default().unwrap();
    let mut rng = Rng::new(1);
    let mut pending = Vec::new();
    for i in 0..60usize {
        let len = [3usize, 64, 1000, 5000][i % 4] + rng.below(10);
        let data = rng.vec_u32(len);
        let mut expect = data.clone();
        expect.sort_unstable();
        pending.push((svc.submit(data), expect));
    }
    for (h, expect) in pending {
        assert_eq!(h.wait().unwrap(), expect);
    }
    let m = svc.metrics();
    assert_eq!(m.submitted, 60);
    assert_eq!(m.completed, 60);
    assert_eq!(m.rejected, 0);
    assert!(m.route_tiny > 0 && m.route_single > 0);
    svc.shutdown();
}

#[test]
fn routes_match_config() {
    let cfg = CoordinatorConfig {
        tiny_cutoff: 10,
        parallel_cutoff: 2000,
        threads_per_parallel_sort: 2,
        ..Default::default()
    };
    let svc = SortService::start(cfg, None).unwrap();
    let mut rng = Rng::new(2);
    let tiny = svc.submit(rng.vec_u32(5));
    let single = svc.submit(rng.vec_u32(500));
    let par = svc.submit(rng.vec_u32(5000));
    for h in [tiny, single, par] {
        assert_sorted(&h.wait().unwrap(), "routed");
    }
    let m = svc.metrics();
    assert_eq!(m.route_tiny, 1);
    assert_eq!(m.route_single, 1);
    assert_eq!(m.route_parallel, 1);
    svc.shutdown();
}

#[test]
fn try_submit_sheds_on_overload() {
    // 0 workers → nothing drains → queue fills to capacity exactly.
    let cfg = CoordinatorConfig { workers: 0, queue_capacity: 4, ..Default::default() };
    let svc = SortService::start(cfg, None).unwrap();
    let mut handles = Vec::new();
    let mut rejected = 0;
    for _ in 0..10 {
        match svc.try_submit(vec![3, 1, 2]) {
            Ok(h) => handles.push(h),
            Err(data) => {
                assert_eq!(data, vec![3, 1, 2], "shed returns the input");
                rejected += 1;
            }
        }
    }
    assert_eq!(handles.len(), 4);
    assert_eq!(rejected, 6);
    assert_eq!(svc.metrics().rejected, 6);
    // shutdown drains the 4 queued jobs even with 0 steady workers?
    // No workers exist, so results never arrive — handles drop. This
    // documents the contract: workers=0 is a test-only configuration.
    drop(handles);
    svc.shutdown();
}

#[test]
fn dynamic_batching_counts_batches() {
    let cfg = CoordinatorConfig {
        workers: 1,
        tiny_cutoff: 64,
        batch_max: 16,
        ..Default::default()
    };
    let svc = SortService::start(cfg, None).unwrap();
    let mut rng = Rng::new(3);
    // Burst of tiny requests while the single worker is busy with a
    // big one → they coalesce into batches.
    let big = svc.submit(rng.vec_u32(2_000_000));
    let tiny: Vec<_> = (0..64).map(|_| svc.submit(rng.vec_u32(8))).collect();
    assert_sorted(&big.wait().unwrap(), "big");
    for h in tiny {
        assert_sorted(&h.wait().unwrap(), "tiny");
    }
    let m = svc.metrics();
    assert_eq!(m.completed, 65);
    assert!(m.batches >= 1, "burst should form ≥1 batch, got {}", m.batches);
    svc.shutdown();
}

#[test]
fn invalid_sort_config_fails_at_start_not_in_workers() {
    // Workers build their sorters from cfg.sort on their own threads;
    // a bad config must be an Err from start(), never a worker-thread
    // panic that leaves every submit parked forever.
    use crate::simd::VectorWidth;
    use crate::sort::SortConfig;
    let bad_r = CoordinatorConfig {
        sort: SortConfig { r: 12, ..Default::default() },
        ..Default::default()
    };
    assert!(SortService::start(bad_r, None).is_err(), "R=12 must be rejected");
    let bad_width = CoordinatorConfig {
        sort: SortConfig { r: 4, vector_width: VectorWidth::V256, ..Default::default() },
        ..Default::default()
    };
    assert!(SortService::start(bad_width, None).is_err(), "R=4 × V256 must be rejected");
}

#[test]
fn v256_wide_config_serves_all_tiers_and_fused_batches() {
    // Acceptance: the V256 / 2×64 configuration runs end-to-end
    // through the service — tiny, fused-batch, single-thread and
    // parallel tiers — with every result equal to the oracle.
    use crate::kernels::MergeWidth;
    use crate::simd::VectorWidth;
    use crate::sort::SortConfig;
    let cfg = CoordinatorConfig {
        workers: 2,
        shards: 2,
        batch_max: 16,
        parallel_cutoff: 40_000,
        sort: SortConfig {
            vector_width: VectorWidth::V256,
            merge_width: MergeWidth::K64,
            ..Default::default()
        },
        ..Default::default()
    };
    let svc = SortService::start(cfg, None).unwrap();
    let mut rng = Rng::new(77);
    let mut pending = Vec::new();
    // A large job first so the tiny burst behind it fuses.
    for i in 0..80usize {
        let len = [60_000usize, 8, 40, 700, 5000][i % 5] + rng.below(17);
        let data = rng.vec_u32(len);
        let mut expect = data.clone();
        expect.sort_unstable();
        pending.push((svc.submit(data), expect));
    }
    for (h, expect) in pending {
        assert_eq!(h.wait().unwrap(), expect, "V256-configured service");
    }
    let m = svc.metrics();
    assert_eq!(m.completed, 80);
    assert!(m.route_parallel > 0, "parallel tier exercised");
    svc.shutdown();
}

#[test]
fn sharded_concurrent_mixed_sizes_all_match_oracle() {
    // Acceptance: ≥ 64 mixed-size jobs across ≥ 2 shards, submitted
    // from several threads at once, every result equal to
    // sort_unstable.
    let cfg = CoordinatorConfig { workers: 4, shards: 4, ..Default::default() };
    let svc = std::sync::Arc::new(SortService::start(cfg, None).unwrap());
    let mut joins = Vec::new();
    for t in 0..4u64 {
        let svc = std::sync::Arc::clone(&svc);
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + t);
            for i in 0..24usize {
                let len = [3usize, 48, 700, 5000, 20_000, 120_000][i % 6] + rng.below(9);
                let data = rng.vec_u32(len);
                let mut expect = data.clone();
                expect.sort_unstable();
                assert_eq!(svc.submit(data).wait().unwrap(), expect, "len={len}");
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let m = svc.metrics();
    assert_eq!(m.submitted, 96);
    assert_eq!(m.completed, 96);
    assert_eq!(m.shard_depths.len(), 4, "per-shard metrics aggregated");
    std::sync::Arc::into_inner(svc).unwrap().shutdown();
}

#[test]
fn batcher_fuses_small_jobs_with_occupancy() {
    // One worker, two shards: a big job pins the worker while small
    // jobs pile up, so the drain must fuse ≥ 2 of them into one batch
    // — observable via the batch-occupancy metric.
    let cfg = CoordinatorConfig {
        workers: 1,
        shards: 2,
        batch_max: 16,
        ..Default::default()
    };
    let svc = SortService::start(cfg, None).unwrap();
    let mut rng = Rng::new(7);
    let big = svc.submit(rng.vec_u32(2_000_000));
    let mut small = Vec::new();
    for _ in 0..48 {
        let len = 100 + rng.below(400);
        let data = rng.vec_u32(len);
        let mut expect = data.clone();
        expect.sort_unstable();
        small.push((svc.submit(data), expect));
    }
    assert_sorted(&big.wait().unwrap(), "big");
    for (h, expect) in small {
        assert_eq!(h.wait().unwrap(), expect);
    }
    let m = svc.metrics();
    assert_eq!(m.completed, 49);
    assert!(m.batches >= 1, "burst should form ≥1 fused batch");
    assert!(m.batched_jobs >= 2, "≥2 jobs coalesced, got {}", m.batched_jobs);
    assert!(
        m.batch_occupancy >= 2.0,
        "fused batches must average ≥2 jobs, got {}",
        m.batch_occupancy
    );
    svc.shutdown();
}

#[test]
fn lone_worker_steals_from_other_shards() {
    // workers=1 homes on shard 0; two-choice admission spreads the
    // burst over all 4 shards, so the other shards' jobs can only
    // complete via stealing.
    let cfg = CoordinatorConfig { workers: 1, shards: 4, ..Default::default() };
    let svc = SortService::start(cfg, None).unwrap();
    let mut rng = Rng::new(8);
    let big = svc.submit(rng.vec_u32(1_500_000)); // pin the worker
    let pending: Vec<_> = (0..32)
        .map(|_| {
            let data = rng.vec_u32(3000);
            let mut expect = data.clone();
            expect.sort_unstable();
            (svc.submit(data), expect)
        })
        .collect();
    assert_sorted(&big.wait().unwrap(), "big");
    for (h, expect) in pending {
        assert_eq!(h.wait().unwrap(), expect);
    }
    let m = svc.metrics();
    assert_eq!(m.completed, 33);
    assert!(m.steals >= 1, "single worker must steal cross-shard, got {}", m.steals);
    svc.shutdown();
}

#[test]
fn single_shard_config_still_works() {
    let cfg = CoordinatorConfig { workers: 2, shards: 1, ..Default::default() };
    let svc = SortService::start(cfg, None).unwrap();
    let mut rng = Rng::new(9);
    let pending: Vec<_> = (0..20)
        .map(|_| {
            let data = rng.vec_u32(500);
            let mut expect = data.clone();
            expect.sort_unstable();
            (svc.submit(data), expect)
        })
        .collect();
    for (h, expect) in pending {
        assert_eq!(h.wait().unwrap(), expect);
    }
    let m = svc.metrics();
    assert_eq!(m.completed, 20);
    assert_eq!(m.shard_depths.len(), 1);
    assert_eq!(m.steals, 0, "nothing to steal with one shard");
    svc.shutdown();
}

#[test]
fn shutdown_drains_queue() {
    let svc = SortService::start(
        CoordinatorConfig { workers: 1, ..Default::default() },
        None,
    )
    .unwrap();
    let mut rng = Rng::new(4);
    let handles: Vec<_> = (0..20).map(|_| svc.submit(rng.vec_u32(3000))).collect();
    svc.shutdown(); // must drain, not drop
    for h in handles {
        assert_sorted(&h.wait().unwrap(), "drained");
    }
}

#[test]
fn duplicate_and_empty_requests() {
    let svc = SortService::start_default().unwrap();
    let empty = svc.submit(vec![]);
    let ones = svc.submit(vec![1; 100]);
    assert_eq!(empty.wait().unwrap(), Vec::<u32>::new());
    assert_eq!(ones.wait().unwrap(), vec![1; 100]);
    svc.shutdown();
}

#[test]
fn xla_batched_dispatch_under_burst() {
    let reg = crate::runtime::ArtifactRegistry::scan(artifacts_dir());
    if reg.batched_variants().next().is_none() {
        eprintln!("SKIP: no batched artifact — run `make artifacts` first");
        return;
    }
    // Route small-but-xla-eligible requests (≤ the batched block) and
    // burst them: the executor should coalesce into ≥1 XLA batch.
    let cfg = CoordinatorConfig {
        workers: 1,
        xla_cutoff: Some(256),
        ..Default::default()
    };
    let svc = SortService::start(cfg, Some(artifacts_dir())).unwrap();
    let mut rng = Rng::new(31);
    let mut pending = Vec::new();
    for _ in 0..24 {
        let data = rng.vec_u32(512);
        let mut expect = data.clone();
        expect.sort_unstable();
        pending.push((svc.submit(data), expect));
    }
    for (h, expect) in pending {
        assert_eq!(h.wait().unwrap(), expect);
    }
    let m = svc.metrics();
    assert_eq!(m.completed, 24);
    assert_eq!(m.route_xla, 24);
    assert!(m.batches >= 1, "burst should form ≥1 accelerator batch");
    svc.shutdown();
}

#[test]
fn xla_route_end_to_end() {
    let reg = crate::runtime::ArtifactRegistry::scan(artifacts_dir());
    if reg.is_empty() {
        eprintln!("SKIP: no artifacts — run `make artifacts` first");
        return;
    }
    let cfg = CoordinatorConfig { xla_cutoff: Some(1024), ..Default::default() };
    let svc = SortService::start(cfg, Some(artifacts_dir())).unwrap();
    assert!(svc.xla_enabled());
    let mut rng = Rng::new(5);
    let data = rng.vec_u32(8192);
    let mut expect = data.clone();
    expect.sort_unstable();
    let h = svc.submit(data);
    assert_eq!(h.wait().unwrap(), expect);
    let m = svc.metrics();
    assert_eq!(m.route_xla, 1, "should have routed via XLA");
    assert_eq!(m.completed, 1);
    svc.shutdown();
}

#[test]
fn concurrent_tenants_through_cloned_clients() {
    // Four tenants, each submitting from its own thread through a
    // cloned SortClient; per-tenant counters must attribute exactly.
    let cfg = CoordinatorConfig { workers: 4, shards: 4, ..Default::default() };
    let svc = SortService::start(cfg, None).unwrap();
    let mut joins = Vec::new();
    for t in 0..4u64 {
        let client = svc.client(&format!("tenant-{t}"));
        joins.push(std::thread::spawn(move || {
            let clone = client.clone(); // same tenant, shared counters
            let mut rng = Rng::new(500 + t);
            let mut pending = Vec::new();
            for i in 0..20usize {
                let len = [5usize, 80, 900, 6000][i % 4] + rng.below(7);
                let data = rng.vec_u32(len);
                let mut expect = data.clone();
                expect.sort_unstable();
                let c = if i % 2 == 0 { &client } else { &clone };
                pending.push((c.submit(data), expect));
            }
            for (h, expect) in pending {
                assert_eq!(h.wait().unwrap(), expect);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let m = svc.metrics();
    assert_eq!(m.completed, 80);
    assert_eq!(m.tenants.len(), 4, "one snapshot per registered tenant");
    for (i, t) in m.tenants.iter().enumerate() {
        assert_eq!(t.name, format!("tenant-{i}"), "tenants sorted by name");
        assert_eq!(t.accepted, 20);
        assert_eq!(t.completed, 20);
        assert_eq!(t.shed, 0);
        assert_eq!(t.cancelled, 0);
        assert!(t.p99_us >= t.p50_us);
        assert!(t.mean_latency_us > 0.0);
    }
    svc.shutdown();
}

#[test]
fn try_submit_sheds_per_tenant() {
    // 0 workers → nothing drains → queue fills to capacity exactly,
    // and every further try_submit is shed against its tenant.
    let cfg = CoordinatorConfig { workers: 0, queue_capacity: 4, ..Default::default() };
    let svc = SortService::start(cfg, None).unwrap();
    let greedy = svc.client("greedy");
    let idle = svc.client("idle");
    let mut handles = Vec::new();
    let mut shed = 0;
    for _ in 0..10 {
        match greedy.try_submit(vec![3, 1, 2]) {
            Ok(h) => handles.push(h),
            Err(busy) => {
                assert_eq!(busy.data, vec![3, 1, 2], "shed hands the input back");
                assert!(
                    matches!(busy.reason, BusyReason::QueueFull { .. }),
                    "overload, not shutdown: {:?}",
                    busy.reason
                );
                shed += 1;
            }
        }
    }
    assert_eq!(handles.len(), 4);
    assert_eq!(shed, 6);
    let m = svc.metrics();
    assert_eq!(m.rejected, 6);
    assert_eq!(m.tenants.len(), 2);
    assert_eq!(m.tenants[0].name, "greedy");
    assert_eq!(m.tenants[0].accepted, 4);
    assert_eq!(m.tenants[0].shed, 6);
    assert_eq!(m.tenants[0].completed, 0);
    assert_eq!(m.tenants[1].name, "idle");
    assert_eq!(m.tenants[1].accepted, 0);
    assert_eq!(m.tenants[1].shed, 0);
    assert_eq!(greedy.tenant_metrics().shed, 6, "client-side snapshot agrees");
    drop(idle);
    drop(handles);
    svc.shutdown();
}

#[test]
fn dropped_handle_cancellation_does_not_wedge_worker() {
    // One worker, one shard → strict FIFO: a big job pins the worker
    // while doomed jobs queue behind it; their handles are dropped
    // before the worker reaches them, so it must skip those sorts and
    // still serve the final probe.
    let cfg =
        CoordinatorConfig { workers: 1, shards: 1, batch_max: 1, ..Default::default() };
    let svc = SortService::start(cfg, None).unwrap();
    let client = svc.client("dropper");
    let mut rng = Rng::new(11);
    let big = svc.submit(rng.vec_u32(2_000_000));
    for _ in 0..16 {
        let h = client.submit(rng.vec_u32(50_000));
        drop(h); // cancel before the worker can start it
    }
    let probe = client.submit(rng.vec_u32(1000));
    assert_sorted(&big.wait().unwrap(), "big");
    assert_sorted(&probe.wait().unwrap(), "probe");
    let m = svc.metrics();
    assert_eq!(m.submitted, 18);
    assert_eq!(m.completed + m.cancelled, 18, "every job resolved exactly once");
    assert!(m.cancelled >= 1, "worker must skip dropped-handle jobs");
    let t = &m.tenants[0];
    assert_eq!(t.cancelled + t.completed, 17);
    svc.shutdown();
}

#[test]
fn cancelled_jobs_filtered_from_fused_batches() {
    // Same shape but with batching on: cancelled jobs must be shed
    // before the fused buffer is built, live ones still complete.
    let cfg =
        CoordinatorConfig { workers: 1, shards: 1, batch_max: 16, ..Default::default() };
    let svc = SortService::start(cfg, None).unwrap();
    let client = svc.client("mixed");
    let mut rng = Rng::new(12);
    let big = svc.submit(rng.vec_u32(2_000_000)); // pin the worker
    let mut keep = Vec::new();
    for i in 0..32 {
        let data = rng.vec_u32(200);
        let mut expect = data.clone();
        expect.sort_unstable();
        let h = client.submit(data);
        if i % 2 == 0 {
            keep.push((h, expect)); // odd-indexed handles drop right here
        }
    }
    // FIFO probe: once it completes, every earlier job has been
    // counted (abandons happen synchronously at batch pop).
    let probe = client.submit(rng.vec_u32(100));
    assert_sorted(&big.wait().unwrap(), "big");
    for (h, expect) in keep {
        assert_eq!(h.wait().unwrap(), expect);
    }
    assert_sorted(&probe.wait().unwrap(), "probe");
    let m = svc.metrics();
    assert_eq!(m.completed + m.cancelled, 34);
    assert!(m.completed >= 18, "big + the 16 kept jobs + probe");
    svc.shutdown();
}

#[test]
fn handle_poll_and_is_ready() {
    let svc = SortService::start_default().unwrap();
    let mut h = svc.submit(vec![4u32, 2, 3, 1]);
    // Poll to completion — never blocks.
    let result = loop {
        if let Some(r) = h.try_take() {
            break r.unwrap();
        }
        std::thread::yield_now();
    };
    assert_eq!(result, vec![1, 2, 3, 4]);
    let mut ready = svc.submit(vec![2u32, 1]);
    while !ready.is_ready() {
        std::thread::yield_now();
    }
    assert_eq!(ready.try_take().unwrap().unwrap(), vec![1, 2], "ready ⇒ take succeeds");
    svc.shutdown();
}

#[test]
fn handle_is_a_future() {
    // Minimal std-only executor: park the thread, wake via unpark.
    struct ThreadWaker(std::thread::Thread);
    impl std::task::Wake for ThreadWaker {
        fn wake(self: std::sync::Arc<Self>) {
            self.0.unpark();
        }
    }
    fn block_on<F: std::future::Future>(fut: F) -> F::Output {
        let waker = std::task::Waker::from(std::sync::Arc::new(ThreadWaker(
            std::thread::current(),
        )));
        let mut cx = std::task::Context::from_waker(&waker);
        let mut fut = std::pin::pin!(fut);
        loop {
            match fut.as_mut().poll(&mut cx) {
                std::task::Poll::Ready(v) => return v,
                std::task::Poll::Pending => std::thread::park(),
            }
        }
    }
    let svc = SortService::start_default().unwrap();
    let client = svc.client("async");
    let sorted = block_on(client.submit(vec![9u32, 5, 7])).unwrap();
    assert_eq!(sorted, vec![5, 7, 9]);
    assert_eq!(client.tenant_metrics().completed, 1);
    svc.shutdown();
}

#[test]
fn accounting_identity_under_cancellation_storm() {
    // Property: per tenant, accepted == completed + cancelled once the
    // service is quiet — under a storm of dropped handles racing the
    // dynamic batcher (cancellation can land before the pop, between
    // the pop and the fused filter, or after completion; every path
    // must count the job exactly once). Several seeds, several tenants
    // submitting concurrently.
    for seed in 0..4u64 {
        let cfg = CoordinatorConfig {
            workers: 2,
            shards: 2,
            batch_max: 8,
            queue_capacity: 64,
            ..Default::default()
        };
        let svc = SortService::start(cfg, None).unwrap();
        let clients: Vec<_> = (0..3).map(|t| svc.client(&format!("storm-{t}"))).collect();
        std::thread::scope(|s| {
            for (t, client) in clients.iter().enumerate() {
                s.spawn(move || {
                    let mut rng = Rng::new(1000 * seed + t as u64);
                    let mut kept = Vec::new();
                    for i in 0..80usize {
                        let len = 8 + rng.below(600);
                        match client.try_submit(rng.vec_u32(len)) {
                            // Keep ~half the handles; drop the rest on
                            // the floor immediately (the storm).
                            Ok(h) if i % 2 == 0 => kept.push(h),
                            Ok(h) => drop(h),
                            Err(_) => {} // shed at admission: not accepted
                        }
                    }
                    for h in kept {
                        let _ = h.wait();
                    }
                });
            }
        });
        // Quiesce: shutdown drains the queues and resolves (or counts
        // as cancelled) everything still in flight.
        svc.shutdown();
        for client in &clients {
            let t = client.tenant_metrics();
            assert_eq!(
                t.accepted,
                t.completed + t.cancelled,
                "seed {seed} tenant {}: accepted ({}) != completed ({}) + cancelled ({})",
                t.name,
                t.accepted,
                t.completed,
                t.cancelled
            );
        }
    }
}

#[test]
fn adaptive_service_sorts_correctly_and_stays_in_bounds() {
    // Adaptive routing on, short epochs, a workload spanning the tiny
    // boundary: every result must still match the oracle (probes are
    // real requests on a different tier, not a different answer), the
    // published cutoffs must stay inside the policy bounds, and the
    // per-route observations must be populated.
    let bounds = RoutingBounds::default();
    let cfg = CoordinatorConfig {
        workers: 2,
        shards: 2,
        batch_max: 1,
        adaptive: AdaptivePolicy::Adaptive { epoch_jobs: 32, bounds: bounds.clone() },
        ..Default::default()
    };
    let svc = SortService::start(cfg, None).unwrap();
    let client = svc.client("adaptive");
    let mut rng = Rng::new(21);
    let mut pending = Vec::new();
    for _ in 0..400usize {
        let len = 16 + rng.below(200);
        let data = rng.vec_u32(len);
        let mut expect = data.clone();
        expect.sort_unstable();
        pending.push((client.submit(data), expect));
    }
    for (h, expect) in pending {
        assert_eq!(h.wait().unwrap(), expect, "adaptive routing must not change results");
    }
    let r = svc.routing();
    assert!(r.tiny_cutoff >= bounds.tiny.0 && r.tiny_cutoff <= bounds.tiny.1);
    assert!(r.fuse_cutoff >= bounds.fuse.0 && r.fuse_cutoff <= bounds.fuse.1);
    assert!(r.parallel_cutoff >= bounds.parallel.0 && r.parallel_cutoff <= bounds.parallel.1);
    assert!(r.tiny_cutoff <= r.fuse_cutoff && r.fuse_cutoff <= r.parallel_cutoff);
    let m = svc.metrics();
    let observed: u64 = m.routes.iter().map(|r| r.jobs).sum();
    assert!(observed >= 400, "every sorted job lands in the observation grid");
    // Both boundary tiers saw work (probing guarantees the vector
    // tier gets samples even if every job is below the cutoff).
    let tiny = &m.routes[Tier::Tiny.index()];
    let single = &m.routes[Tier::Single.index()];
    assert!(tiny.jobs > 0, "tiny tier observed");
    assert!(single.jobs > 0, "probing must give the single tier samples too");
    // Decisions, if any epochs confirmed, must stay inside bounds.
    for d in svc.decisions() {
        assert!(d.from != d.to);
    }
    svc.shutdown();
}

#[test]
fn batched_adaptive_service_still_observes_solo_tiers() {
    // One worker pinned by a big job while fuse-eligible jobs pile
    // up: under pure fusing the solo tiers would record nothing and
    // the tuner would be blind under exactly the sustained load it
    // should learn from. Solo probes must pull ~1/PROBE_PERIOD of the
    // fused-batch candidates out to the solo router (the first
    // candidate deterministically, the probe clock starts at 0).
    let cfg = CoordinatorConfig {
        workers: 1,
        shards: 1,
        batch_max: 64,
        adaptive: AdaptivePolicy::Adaptive { epoch_jobs: 32, bounds: RoutingBounds::default() },
        ..Default::default()
    };
    let svc = SortService::start(cfg, None).unwrap();
    let mut rng = Rng::new(91);
    let big = svc.submit(rng.vec_u32(2_000_000)); // pin the worker
    let mut pending = Vec::new();
    for _ in 0..64 {
        let len = 100 + rng.below(400);
        let data = rng.vec_u32(len);
        let mut expect = data.clone();
        expect.sort_unstable();
        pending.push((svc.submit(data), expect));
    }
    assert_sorted(&big.wait().unwrap(), "big");
    for (h, expect) in pending {
        assert_eq!(h.wait().unwrap(), expect);
    }
    let m = svc.metrics();
    // ≥ 2: the pinning job contributes one solo observation (it sits
    // in the parallel down-probe window), so at least one more must
    // come from a solo-probed fused-batch candidate.
    let solo = m.routes[Tier::Tiny.index()].jobs + m.routes[Tier::Single.index()].jobs
        + m.routes[Tier::Parallel.index()].jobs;
    assert!(solo >= 2, "solo probes must keep the solo tiers observed under batching");
    assert!(m.routes[Tier::Fused.index()].jobs >= 1, "batching itself still fuses");
    svc.shutdown();
}

#[test]
fn static_service_routing_matches_config_and_never_probes() {
    let cfg = CoordinatorConfig { tiny_cutoff: 100, ..Default::default() };
    let svc = SortService::start(cfg.clone(), None).unwrap();
    let r = svc.routing();
    assert_eq!(r.tiny_cutoff, 100);
    assert_eq!(r.fuse_cutoff, cfg.fuse_cutoff);
    assert_eq!(r.parallel_cutoff, cfg.parallel_cutoff);
    assert_eq!(r.batch_max, cfg.batch_max);
    assert!(svc.decisions().is_empty());
    // With the policy off, a below-cutoff job always runs the tiny
    // tier — no probe can send it elsewhere.
    let mut rng = Rng::new(33);
    let pending: Vec<_> = (0..40).map(|_| svc.submit(rng.vec_u32(50))).collect();
    for h in pending {
        assert_sorted(&h.wait().unwrap(), "static tiny");
    }
    let m = svc.metrics();
    assert_eq!(m.routes[Tier::Single.index()].jobs, 0, "no probes when adaptive is off");
    svc.shutdown();
}

#[test]
fn invalid_adaptive_policy_fails_at_start() {
    let bad_epoch = CoordinatorConfig {
        adaptive: AdaptivePolicy::Adaptive { epoch_jobs: 0, bounds: RoutingBounds::default() },
        ..Default::default()
    };
    assert!(SortService::start(bad_epoch, None).is_err(), "epoch_jobs=0 must be rejected");
    let bad_bounds = CoordinatorConfig {
        adaptive: AdaptivePolicy::Adaptive {
            epoch_jobs: 64,
            bounds: RoutingBounds { tiny: (512, 8), ..Default::default() },
        },
        ..Default::default()
    };
    assert!(SortService::start(bad_bounds, None).is_err(), "empty bounds must be rejected");
}

#[test]
fn fair_share_completed_elements_converge_to_weights() {
    // Property (statistical form): three saturating tenants with
    // weights 4:2:1 and identical job sizes; once every tenant is
    // permanently backlogged, the weight-aware dequeue serves
    // completed elements in (roughly) the weight ratio. Tolerances
    // are generous — the first queue-capacity worth of admissions is
    // FIFO-raced before fairness bites — but a FIFO service would
    // measure ~1:1:1 here, far outside them.
    let cfg = CoordinatorConfig {
        workers: 1,
        shards: 1,
        queue_capacity: 48,
        batch_max: 8,
        ..Default::default()
    };
    let svc = SortService::start(cfg, None).unwrap();
    let weights = [4u32, 2, 1];
    let clients: Vec<SortClient> = weights
        .iter()
        .map(|&w| {
            svc.client_with(
                &format!("w{w}"),
                ClientConfig { weight: w, burst: 2048, ..Default::default() },
            )
        })
        .collect();
    // Pin the worker so the queue is deeply mixed across all three
    // tenants before the first tenant completion — the measured order
    // then reflects the scheduler, not submission racing. Wait until
    // the pin job has been *popped*: once queued jobs exist, the
    // fair dequeue would otherwise serve the (cheaper, lower-tag)
    // tenant jobs first and the pin would never pin.
    let mut pin_rng = Rng::new(39);
    let pin = svc.submit(pin_rng.vec_u32(2_000_000));
    while svc.metrics().shard_depths[0] > 0 {
        std::thread::yield_now();
    }
    let stop = std::sync::atomic::AtomicBool::new(false);
    let snap_at_stop = std::thread::scope(|s| {
        for (i, client) in clients.iter().enumerate() {
            let stop = &stop;
            s.spawn(move || {
                let mut rng = Rng::new(40 + i as u64);
                let mut pending: Vec<SortHandle> = Vec::new();
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    match client.try_submit(rng.vec_u32(4096)) {
                        Ok(h) => pending.push(h),
                        // Shed (queue full / over share): stay
                        // saturating, just give the queue a beat.
                        Err(_) => std::thread::sleep(std::time::Duration::from_micros(50)),
                    }
                    if pending.len() > 48 {
                        // Evicted handles resolve to errors; both
                        // outcomes just free the slot here.
                        pending.retain_mut(|h| h.try_take().is_none());
                    }
                }
                drop(pending); // cancels whatever is still queued
            });
        }
        loop {
            let m = svc.metrics();
            if m.completed >= 500 {
                stop.store(true, std::sync::atomic::Ordering::Relaxed);
                break m;
            }
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
    });
    let done: Vec<u64> = weights
        .iter()
        .map(|w| {
            snap_at_stop
                .tenants
                .iter()
                .find(|t| t.name == format!("w{w}"))
                .expect("tenant snapshot")
                .completed
        })
        .collect();
    assert!(
        done[0] > done[1] && done[1] > done[2],
        "service order must follow weights, got {done:?}"
    );
    let r42 = done[0] as f64 / done[1].max(1) as f64;
    let r21 = done[1] as f64 / done[2].max(1) as f64;
    assert!((1.3..=3.2).contains(&r42), "w4/w2 ratio {r42:.2} outside tolerance ({done:?})");
    assert!((1.3..=3.2).contains(&r21), "w2/w1 ratio {r21:.2} outside tolerance ({done:?})");
    assert_sorted(&pin.wait().unwrap(), "pin job");
    svc.shutdown();
}

#[test]
fn within_burst_victim_never_shed_while_aggressor_over_share() {
    // Property (deterministic form): queue full of an over-share
    // aggressor's jobs, worker pinned. A within-burst victim's
    // try_submit must *always* be admitted — each admission evicting
    // the aggressor's newest queued job — and the victim must never
    // appear in any shed counter.
    let cfg = CoordinatorConfig {
        workers: 1,
        shards: 1,
        queue_capacity: 8,
        batch_max: 1,
        ..Default::default()
    };
    let svc = SortService::start(cfg, None).unwrap();
    let aggressor = svc
        .client_with("aggressor", ClientConfig { weight: 1, burst: 1024, ..Default::default() });
    let victim = svc
        .client_with("victim", ClientConfig { weight: 1, burst: 1 << 16, ..Default::default() });
    let mut rng = Rng::new(55);
    // Pin the worker with a big anonymous job, then wait until it has
    // been popped so it does not occupy a queue slot.
    let big = svc.submit(rng.vec_u32(2_000_000));
    while svc.metrics().shard_depths[0] > 0 {
        std::thread::yield_now();
    }
    // Fill the queue with aggressor jobs until it sheds: every shed
    // proves the queue is full and the aggressor is the most
    // over-share tenant, so the reason must be OverShare with a hint.
    let mut agg_handles = Vec::new();
    let mut agg_refused = 0;
    while agg_refused < 4 {
        match aggressor.try_submit(rng.vec_u32(50_000)) {
            Ok(h) => agg_handles.push(h),
            Err(busy) => {
                match busy.reason {
                    BusyReason::OverShare { retry_after_hint } => {
                        assert!(retry_after_hint.as_micros() > 0);
                    }
                    other => panic!("over-share aggressor shed with {other:?}"),
                }
                agg_refused += 1;
            }
        }
    }
    assert_eq!(agg_handles.len(), 8, "queue capacity admitted exactly");
    // The victim displaces the aggressor: six submits, six evictions,
    // zero victim sheds.
    let mut victim_handles = Vec::new();
    for i in 0..6 {
        match victim.try_submit(rng.vec_u32(1000)) {
            Ok(h) => victim_handles.push(h),
            Err(busy) => panic!("victim shed on submit {i}: {:?}", busy.reason),
        }
    }
    let vt = victim.tenant_metrics();
    assert_eq!(vt.shed, 0, "victim never shed");
    assert_eq!(vt.evicted, 0, "victim never evicted");
    assert_eq!(vt.queued_jobs, 6);
    let at = aggressor.tenant_metrics();
    assert_eq!(at.evicted, 6, "one aggressor eviction per victim admission");
    assert_eq!(at.shed, agg_refused + 6);
    assert_eq!(at.shed_over_share, agg_refused + 6, "every aggressor shed was share-caused");
    assert_eq!(at.accepted, 2, "8 admitted − 6 evicted");
    assert!(at.in_flight_bytes >= 2 * 50_000 * 4, "evicted cost released, queued cost kept");
    // Evictions target the *newest* queued job first: the last six
    // admitted aggressor handles error out (with the reason), the
    // first two still complete.
    let evicted_handle = agg_handles.pop().unwrap();
    let err = evicted_handle.wait().expect_err("newest aggressor job was evicted");
    assert!(format!("{err}").contains("evicted"), "error names the eviction: {err}");
    assert_sorted(&big.wait().unwrap(), "pin job");
    for h in victim_handles {
        assert_sorted(&h.wait().unwrap(), "victim job");
    }
    // First two aggressor jobs were never evicted; they complete.
    for h in agg_handles.drain(..2) {
        assert_sorted(&h.wait().unwrap(), "surviving aggressor job");
    }
    drop(agg_handles); // remaining evicted handles resolve to errors on drop
    svc.shutdown();
    let at = aggressor.tenant_metrics();
    assert_eq!(
        at.accepted,
        at.completed + at.cancelled,
        "accounting identity holds through evictions"
    );
}

#[test]
fn tiny_job_flood_cannot_hog_queue_slots() {
    // Admission cost is floored per job (qos::MIN_JOB_COST = 1 KiB),
    // so a flood of tiny requests is policed for the queue *slots* it
    // occupies: with 256 slots the flood crosses the default 128 KiB
    // burst at ~128 queued jobs, and a victim's arrival still
    // displaces it even though the literal byte count of the hog's
    // backlog (256 × 32 bytes) is far below any burst.
    let cfg = CoordinatorConfig {
        workers: 0,
        shards: 1,
        queue_capacity: 256,
        ..Default::default()
    };
    let svc = SortService::start(cfg, None).unwrap();
    let hog = svc.client("hog"); // default ClientConfig: burst 128 KiB
    let victim = svc.client("victim");
    let mut handles = Vec::new();
    let refused = loop {
        match hog.try_submit(vec![3u32; 8]) {
            Ok(h) => handles.push(h),
            Err(busy) => break busy,
        }
    };
    assert_eq!(handles.len(), 256, "queue slots are the binding constraint");
    assert!(
        matches!(refused.reason, BusyReason::OverShare { .. }),
        "slot hog must be recognized as over share, got {:?}",
        refused.reason
    );
    // The victim's first-ever submit (in-flight 0, well within burst)
    // must displace the hog rather than be turned away.
    victim.try_submit(vec![2u32, 1]).expect("victim admitted by eviction");
    assert_eq!(victim.tenant_metrics().shed, 0);
    assert_eq!(hog.tenant_metrics().evicted, 1);
    drop(handles);
    svc.shutdown();
}

#[test]
fn fifo_policy_restores_legacy_shedding() {
    // Under QosPolicy::Fifo an over-share flood is shed with plain
    // QueueFull (never OverShare), nothing is ever evicted, and
    // dequeue stays strict arrival order.
    let cfg = CoordinatorConfig {
        workers: 0,
        queue_capacity: 4,
        qos: QosPolicy::Fifo,
        ..Default::default()
    };
    let svc = SortService::start(cfg, None).unwrap();
    let greedy =
        svc.client_with("greedy", ClientConfig { weight: 1, burst: 0, ..Default::default() });
    let mut handles = Vec::new();
    for _ in 0..10 {
        match greedy.try_submit(vec![3, 1, 2]) {
            Ok(h) => handles.push(h),
            Err(busy) => assert!(
                matches!(busy.reason, BusyReason::QueueFull { .. }),
                "FIFO never reports OverShare, got {:?}",
                busy.reason
            ),
        }
    }
    let t = greedy.tenant_metrics();
    assert_eq!(t.shed, 6);
    assert_eq!(t.shed_over_share, 0);
    assert_eq!(t.evicted, 0);
    assert_eq!(svc.metrics().evicted, 0);
    drop(handles);
    svc.shutdown();
}

#[test]
fn qos_gauges_track_occupancy_and_drain_at_shutdown() {
    let cfg = CoordinatorConfig { workers: 0, queue_capacity: 4, ..Default::default() };
    let svc = SortService::start(cfg, None).unwrap();
    let client =
        svc.client_with("gauged", ClientConfig { weight: 2, burst: 0, ..Default::default() });
    let handles: Vec<_> =
        (0..3).map(|_| client.try_submit(vec![7; 1000]).expect("room")).collect();
    let t = client.tenant_metrics();
    assert_eq!(t.weight, 2);
    assert_eq!(t.burst, 0);
    assert_eq!(t.in_flight_bytes, 12_000, "3 jobs × 1000 u32 × 4 bytes");
    assert_eq!(t.queued_jobs, 3);
    assert!((t.share - 1.0).abs() < 1e-9, "sole registered tenant owns the whole share");
    assert_eq!(t.credit_bytes, 0, "share × total in-flight equals own in-flight");
    drop(handles);
    svc.shutdown();
    let t = client.tenant_metrics();
    assert_eq!(t.in_flight_bytes, 0, "shutdown drain releases in-flight cost");
    assert_eq!(t.queued_jobs, 0);
    assert_eq!(t.accepted, t.completed + t.cancelled);
}

#[test]
fn client_with_reconfigures_but_plain_client_does_not() {
    let svc = SortService::start_default().unwrap();
    let a =
        svc.client_with("acme", ClientConfig { weight: 8, burst: 64, ..Default::default() });
    assert_eq!(a.config(), ClientConfig { weight: 8, burst: 64, ..Default::default() });
    // A default client joining the same tenant must not reset it.
    let b = svc.client("acme");
    assert_eq!(b.config().weight, 8, "client() preserves the explicit config");
    // The last explicit configuration wins.
    let c =
        svc.client_with("acme", ClientConfig { weight: 3, burst: 128, ..Default::default() });
    assert_eq!(a.config().weight, 3, "clones observe the reconfiguration");
    drop((b, c));
    svc.shutdown();
}

#[test]
fn submits_after_shutdown_resolve_to_errors() {
    // Clients may outlive the service: submits are shed, handles
    // resolve to errors, nothing parks forever.
    let svc = SortService::start_default().unwrap();
    let client = svc.client("late");
    svc.shutdown();
    match client.try_submit(vec![1, 2]) {
        Err(busy) => {
            assert_eq!(busy.reason, BusyReason::Shutdown, "permanent shed, stop retrying");
            assert_eq!(busy.data, vec![1, 2]);
        }
        Ok(_) => panic!("try_submit must shed after shutdown"),
    }
    let h = client.submit(vec![2, 1]);
    assert!(h.wait().is_err(), "blocking submit resolves to an error after shutdown");
    let snap = client.tenant_metrics();
    assert_eq!(snap.shed, 2);
    assert_eq!(snap.accepted, 0);
}

#[test]
fn mixed_element_types_from_concurrent_tenants_complete_exactly_once() {
    // E2E for the element-generic stack: three tenants concurrently
    // push u32, u64, and key–payload jobs (sizes spanning the tiny /
    // fused / single tiers) through one service. Every handle must
    // resolve to its own submission's oracle result — a fused batch
    // that mixed element kinds would either corrupt payloads or panic
    // on a kind mismatch in the typed concatenation — and the
    // per-tenant identity accepted == completed + cancelled must hold
    // for every kind.
    let cfg = CoordinatorConfig { workers: 2, shards: 2, batch_max: 8, ..Default::default() };
    let svc = SortService::start(cfg, None).unwrap();
    const JOBS: usize = 40;
    const LENS: [usize; 4] = [5, 40, 900, 4000];
    std::thread::scope(|s| {
        let svc = &svc;
        s.spawn(move || {
            let client = svc.client("alpha-u32");
            let mut rng = Rng::new(71);
            for i in 0..JOBS {
                let data = rng.vec_u32(LENS[i % LENS.len()]);
                let mut expect = data.clone();
                expect.sort_unstable();
                assert_eq!(client.submit(data).wait().unwrap(), expect, "u32 job {i}");
            }
        });
        s.spawn(move || {
            let client = svc.client("bravo-u64");
            let mut rng = Rng::new(72);
            for i in 0..JOBS {
                let data = rng.vec_u64(LENS[i % LENS.len()]);
                let mut expect = data.clone();
                expect.sort_unstable();
                assert_eq!(client.submit_u64(data).wait().unwrap(), expect, "u64 job {i}");
            }
        });
        s.spawn(move || {
            let client = svc.client("carol-pair");
            let mut rng = Rng::new(73);
            for i in 0..JOBS {
                // Heavy key duplication (mod 97) so equal-key runs
                // exercise the deterministic payload tie-break.
                let data: Vec<KeyValue> = (0..LENS[i % LENS.len()])
                    .map(|j| KeyValue::new(rng.next_u32() % 97, j as u32))
                    .collect();
                let mut expect = data.clone();
                expect.sort_unstable();
                assert_eq!(client.submit_pairs(data).wait().unwrap(), expect, "pair job {i}");
            }
        });
    });
    let m = svc.metrics();
    assert_eq!(m.submitted, 3 * JOBS as u64);
    assert_eq!(m.completed, 3 * JOBS as u64);
    assert_eq!(m.rejected, 0);
    for t in &m.tenants {
        assert_eq!(t.accepted, 40, "{} accepted all its jobs", t.name);
        assert_eq!(t.accepted, t.completed + t.cancelled, "{} accounting identity", t.name);
    }
    svc.shutdown();
}

#[test]
fn typed_try_submits_shed_with_typed_payloads() {
    // The non-blocking typed submits hand the exact input back on
    // shed, at the submitted type — and QoS costs 8-byte elements
    // twice as much, so the same element count fills a byte budget
    // twice as fast.
    let cfg = CoordinatorConfig { workers: 0, queue_capacity: 2, ..Default::default() };
    let svc = SortService::start(cfg, None).unwrap();
    let client = svc.client("typed");
    let h64 = client.try_submit_u64(vec![9u64, 3]).expect("room");
    let hp = client
        .try_submit_pairs(vec![KeyValue::new(2, 0), KeyValue::new(1, 1)])
        .expect("room");
    // Queue full now (capacity 2): both typed sheds round-trip.
    let busy = client.try_submit_u64(vec![u64::MAX, 0]).expect_err("queue full");
    assert_eq!(busy.data, vec![u64::MAX, 0]);
    let busy = client
        .try_submit_pairs(vec![KeyValue::new(7, 7)])
        .expect_err("queue full");
    assert_eq!(busy.data, vec![KeyValue::new(7, 7)]);
    // Two queued jobs of 2 × 8 bytes each, floored at MIN_JOB_COST
    // (1 KiB) per job.
    assert_eq!(client.tenant_metrics().in_flight_bytes, 2 * 1024);
    drop((h64, hp));
    svc.shutdown();
}

#[test]
fn mixed_kind_storm_accounting_survives_shutdown_race() {
    // The PR-6 element-kind axis meets the PR-4/5 invariants: a
    // randomized storm of u32 / u64 / key-value submits races dropped
    // handles, fair-share eviction (tiny queue, tiny bursts, uneven
    // weights), and a shutdown() issued from the main thread while
    // the submitters are still running. Per tenant, once quiet:
    // accepted == completed + cancelled, and the QoS occupancy gauges
    // (in-flight bytes, queued jobs) drain to exactly zero — no
    // element kind may leak accounting on any cancellation path.
    for seed in 0..3u64 {
        let cfg = CoordinatorConfig {
            workers: 2,
            shards: 2,
            batch_max: 8,
            queue_capacity: 8, // small: sheds and evictions are real
            qos: QosPolicy::FairShare,
            ..Default::default()
        };
        let svc = SortService::start(cfg, None).unwrap();
        // Uneven weights and small bursts so over-share shedding and
        // eviction both fire during the storm.
        let clients: Vec<_> = (0..3)
            .map(|t| {
                let cfg = ClientConfig {
                    weight: 1 + t as u32,
                    burst: (4 + t as usize) << 10,
                    ..Default::default()
                };
                svc.client_with(&format!("storm-{t}"), cfg)
            })
            .collect();
        let joins: Vec<_> = clients
            .iter()
            .enumerate()
            .map(|(t, client)| {
                let client = client.clone();
                std::thread::spawn(move || {
                    let mut rng = Rng::new(9_000 * seed + t as u64);
                    let mut kept_u32 = Vec::new();
                    let mut kept_u64 = Vec::new();
                    let mut kept_pairs = Vec::new();
                    for i in 0..150usize {
                        let len = 8 + rng.below(600);
                        let keep = i % 2 == 0;
                        // One of the three element kinds per
                        // iteration; ~half the handles are dropped on
                        // the floor immediately (the storm).
                        let shut = match rng.below(3) {
                            0 => match client.try_submit(rng.vec_u32(len)) {
                                Ok(h) => {
                                    if keep {
                                        kept_u32.push(h);
                                    }
                                    false
                                }
                                Err(b) => b.reason == BusyReason::Shutdown,
                            },
                            1 => match client.try_submit_u64(rng.vec_u64(len)) {
                                Ok(h) => {
                                    if keep {
                                        kept_u64.push(h);
                                    }
                                    false
                                }
                                Err(b) => b.reason == BusyReason::Shutdown,
                            },
                            _ => {
                                // Narrow keys force duplicate-key
                                // payload tie-breaks inside the sort.
                                let data: Vec<KeyValue> = (0..len)
                                    .map(|j| KeyValue::new(rng.next_u32() % 257, j as u32))
                                    .collect();
                                match client.try_submit_pairs(data) {
                                    Ok(h) => {
                                        if keep {
                                            kept_pairs.push(h);
                                        }
                                        false
                                    }
                                    Err(b) => b.reason == BusyReason::Shutdown,
                                }
                            }
                        };
                        if shut {
                            break; // shutdown won the race: permanent
                        }
                        // Drain a few mid-storm so completions
                        // interleave with fresh submits instead of
                        // queueing behind the whole storm.
                        if i % 16 == 15 {
                            if let Some(h) = kept_u32.pop() {
                                let _ = h.wait();
                            }
                        }
                    }
                    // Every kept handle must resolve — a result, an
                    // eviction, or a shutdown error — never park.
                    for h in kept_u32 {
                        let _ = h.wait();
                    }
                    for h in kept_u64 {
                        let _ = h.wait();
                    }
                    for h in kept_pairs {
                        let _ = h.wait();
                    }
                })
            })
            .collect();
        // Let the storm build, then shut down while submitters are
        // still racing (seed-staggered so the flag lands at a
        // different phase of the storm each run).
        std::thread::sleep(std::time::Duration::from_millis(2 + 3 * seed));
        svc.shutdown();
        for j in joins {
            j.join().unwrap();
        }
        for client in &clients {
            let t = client.tenant_metrics();
            assert_eq!(
                t.accepted,
                t.completed + t.cancelled,
                "seed {seed} tenant {}: accepted ({}) != completed ({}) + cancelled ({})",
                t.name,
                t.accepted,
                t.completed,
                t.cancelled
            );
            assert_eq!(
                t.in_flight_bytes, 0,
                "seed {seed} tenant {}: residual in-flight gauge",
                t.name
            );
            assert_eq!(t.queued_jobs, 0, "seed {seed} tenant {}: residual queue gauge", t.name);
        }
    }
}

#[test]
fn injected_sort_panics_are_contained_and_worker_survives() {
    // ~half the jobs panic inside the containment envelope: each must
    // resolve its handle to JobPanicked (counted failed +
    // panics_contained), the rest must complete normally on the same
    // workers, and the terminal ledger must balance exactly.
    let plan = FaultPlan { seed: 0xC0FFEE, sort_panic_per_mille: 500, ..Default::default() };
    let cfg = CoordinatorConfig {
        workers: 2,
        shards: 2,
        batch_max: 8,
        faults: Some(plan),
        ..Default::default()
    };
    let svc = SortService::start(cfg, None).unwrap();
    let client = svc.client("panicky");
    let mut rng = Rng::new(61);
    let mut pending = Vec::new();
    for _ in 0..60usize {
        let data = rng.vec_u32(64 + rng.below(500));
        let mut expect = data.clone();
        expect.sort_unstable();
        pending.push((client.submit(data), expect));
    }
    let mut completed = 0u64;
    let mut panicked = 0u64;
    for (h, expect) in pending {
        match h.wait() {
            Ok(sorted) => {
                assert_eq!(sorted, expect, "surviving jobs still match the oracle");
                completed += 1;
            }
            Err(err) => {
                assert_eq!(err, SortError::JobPanicked, "only the injected panic fails jobs");
                panicked += 1;
            }
        }
    }
    assert!(completed > 0, "some jobs must survive at 500 per-mille");
    assert!(panicked > 0, "some jobs must panic at 500 per-mille");
    let m = svc.metrics();
    assert_eq!(m.failed, panicked);
    assert_eq!(m.panics_contained, panicked, "every failure here is a contained panic");
    assert_eq!(m.workers_respawned, 0, "contained panics never kill workers");
    let t = &m.tenants[0];
    assert_eq!(t.accepted, 60);
    assert_eq!(t.accepted, t.completed + t.cancelled + t.failed, "terminal ledger balances");
    assert_eq!(t.failed, panicked);
    svc.shutdown();
    assert_eq!(client.tenant_metrics().in_flight_bytes, 0, "failed jobs release their charge");
}

#[test]
fn fatal_panic_respawns_worker_and_double_kill_quarantines() {
    // Every admitted job is flagged fatal: the single worker parks the
    // job and dies, the supervisor recovers + requeues it (death #1)
    // and respawns the worker, which dies again on the same job —
    // death #2 quarantines it instead of retrying forever.
    let plan = FaultPlan { seed: 7, fatal_panic_per_mille: 1000, ..Default::default() };
    let cfg = CoordinatorConfig {
        workers: 1,
        shards: 1,
        faults: Some(plan),
        ..Default::default()
    };
    let svc = SortService::start(cfg, None).unwrap();
    let client = svc.client("killer");
    let h = client.submit(vec![3u32, 1, 2]);
    assert_eq!(h.wait(), Err(SortError::Quarantined), "second kill quarantines the job");
    let m = svc.metrics();
    assert_eq!(m.workers_respawned, 2, "one respawn per death");
    assert_eq!(m.quarantined, 1);
    assert_eq!(m.failed, 1);
    let t = &m.tenants[0];
    assert_eq!(t.accepted, t.completed + t.cancelled + t.failed);
    assert_eq!(t.failed, 1);
    // The respawned worker is healthy: shutdown drains cleanly.
    svc.shutdown();
    assert_eq!(client.tenant_metrics().in_flight_bytes, 0);
}

#[test]
fn quarantine_deaths_knob_tightens_the_stop_rule() {
    // With quarantine_deaths = 1 the first kill quarantines: no
    // requeue, exactly one worker respawn.
    let plan = FaultPlan { seed: 7, fatal_panic_per_mille: 1000, ..Default::default() };
    let cfg = CoordinatorConfig {
        workers: 1,
        shards: 1,
        faults: Some(plan),
        quarantine_deaths: 1,
        ..Default::default()
    };
    let svc = SortService::start(cfg, None).unwrap();
    let client = svc.client("killer");
    let h = client.submit(vec![3u32, 1, 2]);
    assert_eq!(h.wait(), Err(SortError::Quarantined), "first kill quarantines at 1");
    let m = svc.metrics();
    assert_eq!(m.workers_respawned, 1, "no second death, no second respawn");
    assert_eq!(m.quarantined, 1);
    svc.shutdown();
}

#[test]
fn quarantined_payloads_are_retained_as_dead_letters() {
    // Same double-kill scenario as above, now checking that the
    // poisonous input survives its failed handle: operators can pull
    // the exact payload, byte-capped for oversized inputs.
    let plan = FaultPlan { seed: 7, fatal_panic_per_mille: 1000, ..Default::default() };
    let cfg = CoordinatorConfig {
        workers: 1,
        shards: 1,
        faults: Some(plan),
        ..Default::default()
    };
    let svc = SortService::start(cfg, None).unwrap();
    assert!(svc.quarantined().is_empty(), "no letters before any quarantine");
    let client = svc.client("killer");
    let h = client.submit(vec![9u32, 3, 7]);
    assert_eq!(h.wait(), Err(SortError::Quarantined));
    let letters = svc.quarantined();
    assert_eq!(letters.len(), 1);
    let l = &letters[0];
    assert_eq!(l.tenant, "killer");
    assert_eq!(l.kind, ElemKind::U32);
    assert_eq!(l.payload, ElemBuf::U32(vec![9, 3, 7]), "small payloads retained whole");
    assert!(!l.truncated);
    assert_eq!(l.total_elements, 3);
    assert_eq!(l.deaths, 2, "quarantined on the second kill");
    // An oversized poison payload (160 KiB of u32 > the 64 KiB cap)
    // keeps only its element prefix, flagged as truncated.
    let big: Vec<u32> = (0..40_000u32).rev().collect();
    let h = client.submit(big.clone());
    assert_eq!(h.wait(), Err(SortError::Quarantined));
    let letters = svc.quarantined();
    assert_eq!(letters.len(), 2, "letters accumulate newest-last");
    let l = &letters[1];
    assert!(l.truncated);
    assert_eq!(l.total_elements, 40_000);
    assert_eq!(l.payload, ElemBuf::U32(big[..16_384].to_vec()), "64 KiB / 4 B prefix");
    svc.shutdown();
    assert_eq!(client.tenant_metrics().in_flight_bytes, 0, "letters hold no QoS charge");
}

#[test]
fn dead_letter_store_is_bounded() {
    // Flood with poison (quarantine_deaths = 1 keeps it to one respawn
    // per job): the ring must retain only the newest 32 letters.
    let plan = FaultPlan { seed: 11, fatal_panic_per_mille: 1000, ..Default::default() };
    let cfg = CoordinatorConfig {
        workers: 1,
        shards: 1,
        faults: Some(plan),
        quarantine_deaths: 1,
        ..Default::default()
    };
    let svc = SortService::start(cfg, None).unwrap();
    let client = svc.client("flood");
    for i in 0..40u32 {
        let h = client.submit(vec![i, 2, 1]);
        assert_eq!(h.wait(), Err(SortError::Quarantined));
    }
    assert_eq!(svc.metrics().quarantined, 40);
    let letters = svc.quarantined();
    assert_eq!(letters.len(), 32, "ring keeps the most recent 32");
    assert_eq!(letters[0].payload, ElemBuf::U32(vec![8, 2, 1]), "oldest 8 were dropped");
    assert_eq!(letters[31].payload, ElemBuf::U32(vec![39, 2, 1]));
    assert!(letters.iter().all(|l| l.deaths == 1 && !l.truncated));
    svc.shutdown();
}

#[test]
fn invalid_failure_knobs_fail_startup() {
    let zero_threshold =
        CoordinatorConfig { breaker_threshold: 0, ..Default::default() };
    assert!(SortService::start(zero_threshold, None).is_err(), "threshold 0 rejected");
    let zero_quarantine =
        CoordinatorConfig { quarantine_deaths: 0, ..Default::default() };
    assert!(SortService::start(zero_quarantine, None).is_err(), "quarantine 0 rejected");
}

#[test]
fn backend_override_validated_at_start_and_scalar_serves() {
    use crate::simd::Backend;
    use crate::sort::SortConfig;
    // An explicitly requested unavailable backend is a start() error,
    // not a worker-thread panic.
    if let Some(missing) = Backend::all().into_iter().find(|k| !k.available()) {
        let bad = CoordinatorConfig {
            sort: SortConfig { backend: Some(missing), ..Default::default() },
            ..Default::default()
        };
        assert!(SortService::start(bad, None).is_err(), "unavailable backend rejected");
    }
    // Forcing scalar works on every machine and serves correctly.
    let cfg = CoordinatorConfig {
        workers: 1,
        shards: 1,
        sort: SortConfig { backend: Some(Backend::Scalar), ..Default::default() },
        ..Default::default()
    };
    let svc = SortService::start(cfg, None).unwrap();
    let mut rng = Rng::new(41);
    let h = svc.submit(rng.vec_u32(10_000));
    assert_sorted(&h.wait().unwrap(), "scalar-backend service");
    assert_eq!(svc.metrics().simd_backend, "scalar");
    svc.shutdown();
}

#[test]
fn deadlines_reap_lazily_with_refund() {
    // A zero deadline expires deterministically: the worker reaps it
    // at dequeue, the handle resolves DeadlineExceeded, and the QoS
    // charge is refunded (in-flight drains without a completion).
    let svc = SortService::start(
        CoordinatorConfig { workers: 1, shards: 1, ..Default::default() },
        None,
    )
    .unwrap();
    let client = svc.client("deadliner");
    let doomed = client.submit_with_deadline(vec![5u32, 4, 3], Duration::ZERO);
    assert_eq!(doomed.wait(), Err(SortError::DeadlineExceeded));
    // A per-call deadline long enough to never fire: completes.
    let fine = client.submit_with_deadline(vec![2u32, 1], Duration::from_secs(60));
    assert_eq!(fine.wait().unwrap(), vec![1, 2]);
    let t = client.tenant_metrics();
    assert_eq!(t.failed, 1);
    assert_eq!(t.deadline_expired, 1);
    assert_eq!(t.completed, 1);
    assert_eq!(t.accepted, t.completed + t.cancelled + t.failed);
    assert_eq!(t.in_flight_bytes, 0, "reaped charge is refunded");
    let m = svc.metrics();
    assert_eq!(m.deadline_expired, 1);
    svc.shutdown();
}

#[test]
fn tenant_default_deadline_applies_without_per_call_override() {
    // ClientConfig::default_deadline covers plain submit(); ZERO makes
    // every request expire at first dequeue.
    let svc = SortService::start(
        CoordinatorConfig { workers: 1, shards: 1, ..Default::default() },
        None,
    )
    .unwrap();
    let strict = svc.client_with(
        "strict",
        ClientConfig { default_deadline: Some(Duration::ZERO), ..Default::default() },
    );
    assert_eq!(strict.submit(vec![9u32, 8]).wait(), Err(SortError::DeadlineExceeded));
    // try_submit honors the tenant default too.
    let h = strict.try_submit(vec![7u32, 6]).expect("room");
    assert_eq!(h.wait(), Err(SortError::DeadlineExceeded));
    let t = strict.tenant_metrics();
    assert_eq!(t.deadline_expired, 2);
    assert_eq!(t.accepted, t.completed + t.cancelled + t.failed);
    svc.shutdown();
}

#[test]
fn retry_policy_exhausts_against_a_full_queue() {
    // workers=0 keeps the queue full forever, so the retry loop must
    // sleep through its bounded schedule and hand the input back.
    let cfg = CoordinatorConfig { workers: 0, queue_capacity: 2, ..Default::default() };
    let svc = SortService::start(cfg, None).unwrap();
    let client = svc.client("retrier");
    let _a = client.try_submit(vec![1u32]).expect("room");
    let _b = client.try_submit(vec![2u32]).expect("room");
    let policy = RetryPolicy {
        max_attempts: 3,
        base: Duration::from_micros(50),
        cap: Duration::from_millis(1),
        jitter_seed: 42,
    };
    let busy = match client.try_submit_with_retry(vec![9u32, 9], &policy) {
        Ok(_) => panic!("queue can never drain"),
        Err(busy) => busy,
    };
    assert_eq!(busy.data, vec![9, 9], "input handed back after exhaustion");
    assert!(busy.reason.retry_after().is_some(), "transient shed, not shutdown");
    // 1 initial + 3 retries, all shed.
    assert_eq!(client.tenant_metrics().shed, 4);
    svc.shutdown();
}

#[test]
fn identical_fault_seeds_produce_identical_schedules() {
    // Acceptance: the injection schedule is a pure function of the
    // plan — two services with equal plans make identical decisions
    // for the same admission sequence, a different seed diverges.
    let plan = FaultPlan {
        seed: 1234,
        sort_panic_per_mille: 200,
        fatal_panic_per_mille: 50,
        stall_per_mille: 100,
        shed_per_mille: 100,
        ..Default::default()
    };
    let a: Vec<FaultDecision> = (0..256).map(|s| plan.decide(s)).collect();
    let b: Vec<FaultDecision> = (0..256).map(|s| plan.decide(s)).collect();
    assert_eq!(a, b);
    let other = FaultPlan { seed: 4321, ..plan };
    let c: Vec<FaultDecision> = (0..256).map(|s| other.decide(s)).collect();
    assert_ne!(a, c, "different seed, different schedule");
}

#[test]
fn chaos_soak_accounting_identity_across_seeds() {
    // Satellite: 3-seed chaos soak. Randomized fault plan (contained
    // panics, worker-killing panics, stalls, forced sheds) x 3
    // tenants x mixed element kinds x dropped handles x a deadline'd
    // tenant, with shutdown racing the storm. Afterwards, per tenant:
    // accepted == completed + cancelled + failed, zero residual
    // gauges, and no handle may park forever.
    for seed in 0..3u64 {
        let mut prng = Rng::new(0xBAD5EED + seed);
        let plan = FaultPlan {
            seed: 0x50AC + seed,
            sort_panic_per_mille: (50 + prng.below(150)) as u16,
            fatal_panic_per_mille: (5 + prng.below(20)) as u16,
            stall_per_mille: (20 + prng.below(80)) as u16,
            stall: Duration::from_micros(200),
            shed_per_mille: (30 + prng.below(100)) as u16,
            ..Default::default()
        };
        let cfg = CoordinatorConfig {
            workers: 2,
            shards: 2,
            batch_max: 8,
            queue_capacity: 16, // small: real sheds and evictions too
            faults: Some(plan),
            ..Default::default()
        };
        let svc = SortService::start(cfg, None).unwrap();
        let clients: Vec<SortClient> = (0..3)
            .map(|t| {
                // Tenant 2 runs with a tight default deadline so the
                // stall injection drives real DeadlineExceeded reaps.
                let deadline = (t == 2).then(|| Duration::from_millis(1));
                svc.client_with(
                    &format!("chaos-{t}"),
                    ClientConfig {
                        weight: 1 + t as u32,
                        burst: 8 << 10,
                        default_deadline: deadline,
                    },
                )
            })
            .collect();
        let joins: Vec<_> = clients
            .iter()
            .enumerate()
            .map(|(t, client)| {
                let client = client.clone();
                std::thread::spawn(move || {
                    let mut rng = Rng::new(777 * seed + t as u64);
                    let mut kept = Vec::new();
                    let mut kept_u64 = Vec::new();
                    let mut kept_pairs = Vec::new();
                    for i in 0..120usize {
                        let len = 8 + rng.below(400);
                        let shut = match rng.below(4) {
                            0 => match client.try_submit(rng.vec_u32(len)) {
                                Ok(h) => {
                                    if i % 2 == 0 {
                                        kept.push(h);
                                    }
                                    false
                                }
                                Err(b) => b.reason == BusyReason::Shutdown,
                            },
                            1 => match client.try_submit_u64(rng.vec_u64(len)) {
                                Ok(h) => {
                                    if i % 2 == 0 {
                                        kept_u64.push(h);
                                    }
                                    false
                                }
                                Err(b) => b.reason == BusyReason::Shutdown,
                            },
                            2 => {
                                let data: Vec<KeyValue> = (0..len)
                                    .map(|j| KeyValue::new(rng.next_u32() % 509, j as u32))
                                    .collect();
                                match client.try_submit_pairs(data) {
                                    Ok(h) => {
                                        if i % 2 == 0 {
                                            kept_pairs.push(h);
                                        }
                                        false
                                    }
                                    Err(b) => b.reason == BusyReason::Shutdown,
                                }
                            }
                            _ => {
                                // Blocking submit interleaved: parks
                                // under pressure, must still resolve
                                // (post-shutdown it sheds and the
                                // handle errors instead of wedging).
                                let h = client.submit(rng.vec_u32(len));
                                if i % 2 == 0 {
                                    kept.push(h);
                                }
                                false
                            }
                        };
                        if shut {
                            break;
                        }
                        if i % 16 == 15 {
                            if let Some(h) = kept.pop() {
                                let _ = h.wait();
                            }
                        }
                    }
                    // Every kept handle resolves: a result or a typed
                    // error — never a wedged waiter.
                    for h in kept {
                        let _ = h.wait();
                    }
                    for h in kept_u64 {
                        let _ = h.wait();
                    }
                    for h in kept_pairs {
                        let _ = h.wait();
                    }
                })
            })
            .collect();
        std::thread::sleep(Duration::from_millis(2 + 3 * seed));
        svc.shutdown(); // races the storm
        for j in joins {
            j.join().unwrap();
        }
        for client in &clients {
            let t = client.tenant_metrics();
            assert_eq!(
                t.accepted,
                t.completed + t.cancelled + t.failed,
                "seed {seed} tenant {}: accepted ({}) != completed ({}) + cancelled ({}) + failed ({})",
                t.name,
                t.accepted,
                t.completed,
                t.cancelled,
                t.failed
            );
            assert_eq!(
                t.in_flight_bytes, 0,
                "seed {seed} tenant {}: residual in-flight gauge",
                t.name
            );
            assert_eq!(t.queued_jobs, 0, "seed {seed} tenant {}: residual queue gauge", t.name);
        }
    }
}
