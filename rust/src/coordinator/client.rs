//! Client-side completion primitives: the per-request [`Slot`] that
//! shard workers signal through, and the non-blocking [`SortHandle`]
//! callers hold.
//!
//! A submitted request no longer owns a channel endpoint; submitter
//! and worker share one heap slot. The worker stores the sorted
//! buffer and *signals* — waking a parked [`SortHandle::wait`] caller
//! through the slot's condvar and any registered async task through
//! its [`Waker`] — so completion costs one mutex hand-off, no channel
//! allocation per request, and the handle can be polled without ever
//! blocking. Dropping an unresolved handle flips the slot's
//! cancellation flag; workers check it before sorting and skip the
//! work, so an abandoned request can never wedge a shard worker (it
//! is counted under `cancelled` in the metrics instead).
//!
//! The slot itself is element-type-agnostic — it parks an [`ElemBuf`]
//! — while the handle is typed: `SortHandle<T>` resolves to the
//! `Vec<T>` the caller submitted (`T` defaults to `u32`, the original
//! API, so pre-element-generic code compiles unchanged).

use super::elem::{ElemBuf, SortElem};
use anyhow::Result;
use std::future::Future;
use std::marker::PhantomData;
use std::pin::Pin;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::task::{Context, Poll, Waker};

/// What a slot currently holds.
enum State {
    /// No result yet; a worker still owns the request.
    Pending,
    /// Sorted result parked by a worker, not yet taken by the handle.
    Done(ElemBuf),
    /// The service dropped the request without completing it; the
    /// handle resolves to an error carrying the recorded reason
    /// (shutdown raced the submit, or fair-share QoS evicted it).
    Closed(&'static str),
    /// The handle already took the result.
    Taken,
}

struct SlotInner {
    state: State,
    /// Async task to wake on completion (registered by `Future::poll`).
    waker: Option<Waker>,
}

/// One request's completion slot, shared between the queued job and
/// the caller's [`SortHandle`].
pub(super) struct Slot {
    /// Set when the handle is dropped unresolved. Kept outside the
    /// mutex so workers can check it with a single atomic load before
    /// paying for a sort.
    cancelled: AtomicBool,
    inner: Mutex<SlotInner>,
    /// Parks blocking [`SortHandle::wait`] callers.
    cv: Condvar,
}

impl Slot {
    pub(super) fn new() -> Arc<Slot> {
        Arc::new(Slot {
            cancelled: AtomicBool::new(false),
            inner: Mutex::new(SlotInner { state: State::Pending, waker: None }),
            cv: Condvar::new(),
        })
    }

    /// Worker side: deposit the sorted result and wake the owner.
    /// No-op if the slot already resolved (idempotent, so the job's
    /// drop guard can unconditionally [`Slot::close`]).
    pub(super) fn complete(&self, data: ElemBuf) {
        let waker = {
            let mut inner = self.inner.lock().unwrap();
            if !matches!(inner.state, State::Pending) {
                return;
            }
            inner.state = State::Done(data);
            inner.waker.take()
        };
        self.cv.notify_all();
        if let Some(w) = waker {
            w.wake();
        }
    }

    /// Worker side: resolve the slot *without* a result — the request
    /// was dropped un-sorted (service shut down, or the job was
    /// abandoned after its handle was cancelled). Idempotent.
    pub(super) fn close(&self) {
        self.close_with(CLOSED_MSG);
    }

    /// [`Slot::close`] with an explicit reason — the fair-share
    /// eviction path uses this so a displaced tenant's handle error
    /// says *why*. Idempotent; the first close (or completion) wins.
    pub(super) fn close_with(&self, msg: &'static str) {
        let waker = {
            let mut inner = self.inner.lock().unwrap();
            if !matches!(inner.state, State::Pending) {
                return;
            }
            inner.state = State::Closed(msg);
            inner.waker.take()
        };
        self.cv.notify_all();
        if let Some(w) = waker {
            w.wake();
        }
    }

    /// True once the owning handle was dropped unresolved. Workers
    /// check this before sorting and skip cancelled jobs.
    pub(super) fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::Relaxed)
    }

    fn cancel(&self) {
        self.cancelled.store(true, Ordering::Relaxed);
    }

    /// Non-blocking take. `None` while pending; registers `waker` (if
    /// given) to be woken exactly when the state next changes.
    fn poll_take(&self, waker: Option<&Waker>) -> Option<Result<ElemBuf>> {
        let mut inner = self.inner.lock().unwrap();
        match std::mem::replace(&mut inner.state, State::Taken) {
            State::Done(data) => Some(Ok(data)),
            State::Closed(msg) => Some(Err(anyhow::anyhow!(msg))),
            // `replace` already left `Taken` in place.
            State::Taken => {
                Some(Err(anyhow::anyhow!("sort handle polled after completion")))
            }
            State::Pending => {
                inner.state = State::Pending;
                if let Some(w) = waker {
                    // Replace rather than accumulate: only the latest
                    // task polling the handle needs the wakeup.
                    inner.waker = Some(w.clone());
                }
                None
            }
        }
    }

    /// Blocking take: park on the condvar until the slot resolves.
    fn wait_take(&self) -> Result<ElemBuf> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            match std::mem::replace(&mut inner.state, State::Taken) {
                State::Done(data) => return Ok(data),
                State::Closed(msg) => return Err(anyhow::anyhow!(msg)),
                State::Taken => {
                    return Err(anyhow::anyhow!("sort handle waited after completion"))
                }
                State::Pending => {
                    inner.state = State::Pending;
                    inner = self.cv.wait(inner).unwrap();
                }
            }
        }
    }
}

/// Default [`Slot::close`] reason (shutdown / abandoned request).
const CLOSED_MSG: &str = "sort service dropped the request before completing it";

/// Why a [`super::SortClient::try_submit`] was shed.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum BusyReason {
    /// Every shard was at capacity and no tenant was further over its
    /// fair share than this one — transient backpressure; a retry
    /// after draining some handles can succeed.
    QueueFull,
    /// Every shard was at capacity and **this tenant** was the one
    /// most over its fair share ([`super::ClientConfig`] weight/burst)
    /// — the fair-share analog of `QueueFull`, telling the tenant the
    /// overload is its own. Retrying before some of its in-flight
    /// work drains will be shed again; `retry_after_hint` estimates
    /// how long that drain takes (≈ one median queue-to-completion
    /// latency — a hint, not a promise).
    OverShare {
        /// Suggested back-off before the next `try_submit`.
        retry_after_hint: std::time::Duration,
    },
    /// The service has shut down — permanent; stop retrying.
    Shutdown,
}

/// The input handed back by [`super::SortClient::try_submit`] when
/// the request was shed: nothing was enqueued or copied, and the
/// caller decides whether to retry ([`BusyReason::QueueFull`]), back
/// off ([`BusyReason::OverShare`]), degrade, or stop
/// ([`BusyReason::Shutdown`]). `T` is the submitted element type
/// (`u32` by default; `u64` / [`crate::simd::KeyValue`] for the typed
/// submits), so the shed payload round-trips without conversion.
///
/// # Examples
///
/// A QoS-aware retry loop distinguishes the three reasons — retry
/// soon, back off by the hint, or stop:
///
/// ```
/// use neonms::coordinator::{Busy, BusyReason};
/// use std::time::Duration;
///
/// fn backoff(busy: &Busy) -> Option<Duration> {
///     match busy.reason {
///         BusyReason::QueueFull => Some(Duration::from_micros(100)),
///         BusyReason::OverShare { retry_after_hint } => Some(retry_after_hint),
///         BusyReason::Shutdown => None, // retrying can never succeed
///     }
/// }
///
/// let shed = Busy {
///     data: vec![3, 1, 2], // handed back untouched
///     reason: BusyReason::OverShare { retry_after_hint: Duration::from_micros(250) },
/// };
/// assert_eq!(backoff(&shed), Some(Duration::from_micros(250)));
/// assert_eq!(shed.data, vec![3, 1, 2]);
/// ```
#[derive(Debug)]
pub struct Busy<T: SortElem = u32> {
    /// The original, untouched input.
    pub data: Vec<T>,
    /// Transient overload ([`BusyReason::QueueFull`] /
    /// [`BusyReason::OverShare`]) or permanent shutdown.
    pub reason: BusyReason,
}

/// Non-blocking handle to a submitted sort request for element type
/// `T` (`u32` by default — [`super::SortClient::submit`]; `u64` and
/// [`crate::simd::KeyValue`] via the typed submits).
///
/// Three ways to consume it, all signalled by the shard worker
/// through the request's completion slot (no blocking join anywhere
/// in the service):
///
/// * **poll** — [`SortHandle::try_take`] / [`SortHandle::is_ready`]
///   never block; ideal for tenants multiplexing many requests.
/// * **await** — the handle implements [`Future`], resolving to the
///   sorted vector; any executor (or a hand-rolled `block_on`) works.
/// * **block** — [`SortHandle::wait`] parks the calling thread on the
///   slot's condvar, the migration path from the old blocking API.
///
/// Dropping a handle before taking its result **cancels** the
/// request: workers that haven't started it yet skip the sort
/// entirely (counted as `cancelled` in the metrics), and a result
/// that was already computed is discarded. Cancellation never blocks
/// and never wedges a worker.
pub struct SortHandle<T: SortElem = u32> {
    slot: Arc<Slot>,
    /// Set once the result (or error) has been taken; suppresses the
    /// drop-cancellation.
    resolved: bool,
    _elem: PhantomData<fn() -> T>,
}

impl<T: SortElem> SortHandle<T> {
    pub(super) fn new(slot: Arc<Slot>) -> SortHandle<T> {
        SortHandle { slot, resolved: false, _elem: PhantomData }
    }

    /// True once a result (or a shutdown error) is waiting; never
    /// blocks. Before the result is taken, a `true` here makes the
    /// next [`SortHandle::try_take`] return `Some`; after the take it
    /// stays `true` (the handle is resolved, not pending again).
    pub fn is_ready(&self) -> bool {
        !matches!(self.slot.inner.lock().unwrap().state, State::Pending)
    }

    /// Non-blocking take: `None` while the request is still in
    /// flight, `Some(result)` exactly once when it resolves, and
    /// `None` again on any call after the result was taken.
    pub fn try_take(&mut self) -> Option<Result<Vec<T>>> {
        if self.resolved {
            return None;
        }
        let out = self.slot.poll_take(None);
        if out.is_some() {
            self.resolved = true;
        }
        out.map(|r| r.map(T::unwrap))
    }

    /// Block the calling thread until the result arrives (parked on
    /// the slot's condvar; woken directly by the completing worker).
    pub fn wait(mut self) -> Result<Vec<T>> {
        self.resolved = true;
        self.slot.wait_take().map(T::unwrap)
    }
}

impl<T: SortElem> Future for SortHandle<T> {
    type Output = Result<Vec<T>>;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Self::Output> {
        let this = self.get_mut();
        match this.slot.poll_take(Some(cx.waker())) {
            Some(out) => {
                this.resolved = true;
                Poll::Ready(out.map(T::unwrap))
            }
            None => Poll::Pending,
        }
    }
}

impl<T: SortElem> Drop for SortHandle<T> {
    fn drop(&mut self) {
        if !self.resolved {
            self.slot.cancel();
        }
    }
}
