//! Client-side completion primitives: the per-request [`Slot`] that
//! shard workers signal through, the non-blocking [`SortHandle`]
//! callers hold, the typed [`SortError`] unsuccessful requests
//! resolve to, and the [`RetryPolicy`] backoff helper.
//!
//! A submitted request no longer owns a channel endpoint; submitter
//! and worker share one heap slot. The worker stores the sorted
//! buffer and *signals* — waking a parked [`SortHandle::wait`] caller
//! through the slot's condvar and any registered async task through
//! its [`Waker`] — so completion costs one mutex hand-off, no channel
//! allocation per request, and the handle can be polled without ever
//! blocking. Dropping an unresolved handle flips the slot's
//! cancellation flag; workers check it before sorting and skip the
//! work, so an abandoned request can never wedge a shard worker (it
//! is counted under `cancelled` in the metrics instead).
//!
//! The slot itself is element-type-agnostic — it parks an [`ElemBuf`]
//! — while the handle is typed: `SortHandle<T>` resolves to the
//! `Vec<T>` the caller submitted (`T` defaults to `u32`, the original
//! API, so pre-element-generic code compiles unchanged).
//!
//! A request that does not complete resolves its handle to a
//! [`SortError`] naming exactly what happened — shutdown, fair-share
//! eviction, a contained panic, a missed deadline, or quarantine —
//! so callers can branch on the failure domain instead of parsing a
//! message (see [`SortHandle::wait`] for the taxonomy).

use super::elem::{ElemBuf, SortElem};
use std::future::Future;
use std::marker::PhantomData;
use std::pin::Pin;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::task::{Context, Poll, Waker};
use std::time::Duration;

/// Why a request resolved without a sorted result. Returned by every
/// consuming path of a [`SortHandle`] ([`SortHandle::try_take`],
/// [`SortHandle::wait`], `.await`), carried by the slot's closed
/// state, and convertible into `anyhow::Error` via `?` (it implements
/// [`std::error::Error`]).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SortError {
    /// The service shut down (or the request was abandoned) before a
    /// worker completed it. The request counts as shed/cancelled;
    /// resubmitting against a *new* service instance is the only
    /// retry that can succeed.
    Shutdown,
    /// Fair-share QoS displaced this queued request to make room for
    /// a tenant further under its share (see
    /// [`super::BusyReason::OverShare`]). The tenant was over its
    /// burst allowance; back off and resubmit.
    Evicted,
    /// The sort panicked mid-request. The panic was contained: the
    /// worker (or a respawned replacement) keeps serving other jobs,
    /// and only this request fails. Counted under `failed` and
    /// `panics_contained`; a resubmit of different data is fine, a
    /// resubmit of the *same* data will likely panic again.
    JobPanicked,
    /// The request's deadline ([`super::ClientConfig::default_deadline`]
    /// or [`super::SortClient::submit_with_deadline`]) expired before
    /// a worker started sorting it. The QoS charge was refunded (the
    /// request consumed no service); resubmit with a larger deadline
    /// or at lower load.
    DeadlineExceeded,
    /// This request killed a worker thread twice and was quarantined
    /// rather than retried a third time — the supervisor's poison-job
    /// stop rule. Do **not** resubmit the same payload.
    Quarantined,
    /// The handle was consumed again after its result was already
    /// taken (API misuse, not a service failure).
    AlreadyTaken,
}

impl std::fmt::Display for SortError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SortError::Shutdown => {
                "sort service dropped the request before completing it"
            }
            SortError::Evicted => {
                "request evicted: tenant exceeded its fair share while the service was full"
            }
            SortError::JobPanicked => {
                "sort panicked mid-request; the panic was contained and the worker recovered"
            }
            SortError::DeadlineExceeded => {
                "request deadline expired before a worker completed it"
            }
            SortError::Quarantined => {
                "request quarantined: it killed two workers and will not be retried"
            }
            SortError::AlreadyTaken => {
                "sort handle used after its result was already taken"
            }
        })
    }
}

impl std::error::Error for SortError {}

/// What a slot currently holds.
enum State {
    /// No result yet; a worker still owns the request.
    Pending,
    /// Sorted result parked by a worker, not yet taken by the handle.
    Done(ElemBuf),
    /// The service resolved the request *without* a result; the
    /// handle resolves to the recorded [`SortError`].
    Closed(SortError),
    /// The handle already took the result.
    Taken,
}

struct SlotInner {
    state: State,
    /// Async task to wake on completion (registered by `Future::poll`).
    waker: Option<Waker>,
}

/// One request's completion slot, shared between the queued job and
/// the caller's [`SortHandle`].
pub(super) struct Slot {
    /// Set when the handle is dropped unresolved. Kept outside the
    /// mutex so workers can check it with a single atomic load before
    /// paying for a sort.
    cancelled: AtomicBool,
    inner: Mutex<SlotInner>,
    /// Parks blocking [`SortHandle::wait`] callers.
    cv: Condvar,
}

impl Slot {
    pub(super) fn new() -> Arc<Slot> {
        Arc::new(Slot {
            cancelled: AtomicBool::new(false),
            inner: Mutex::new(SlotInner { state: State::Pending, waker: None }),
            cv: Condvar::new(),
        })
    }

    /// Worker side: deposit the sorted result and wake the owner.
    /// No-op if the slot already resolved (idempotent, so the job's
    /// drop guard can unconditionally [`Slot::close`]).
    pub(super) fn complete(&self, data: ElemBuf) {
        let waker = {
            let mut inner = self.inner.lock().unwrap();
            if !matches!(inner.state, State::Pending) {
                return;
            }
            inner.state = State::Done(data);
            inner.waker.take()
        };
        self.cv.notify_all();
        if let Some(w) = waker {
            w.wake();
        }
    }

    /// Worker side: resolve the slot *without* a result under the
    /// default [`SortError::Shutdown`] — the request was dropped
    /// un-sorted (service shut down, or the job was abandoned after
    /// its handle was cancelled). Idempotent.
    pub(super) fn close(&self) {
        self.close_with(SortError::Shutdown);
    }

    /// [`Slot::close`] with an explicit [`SortError`] — eviction,
    /// contained panic, deadline expiry, and quarantine all record
    /// *why* here so the handle error names the failure domain.
    /// Idempotent; the first close (or completion) wins.
    pub(super) fn close_with(&self, err: SortError) {
        let waker = {
            let mut inner = self.inner.lock().unwrap();
            if !matches!(inner.state, State::Pending) {
                return;
            }
            inner.state = State::Closed(err);
            inner.waker.take()
        };
        self.cv.notify_all();
        if let Some(w) = waker {
            w.wake();
        }
    }

    /// True once the owning handle was dropped unresolved. Workers
    /// check this before sorting and skip cancelled jobs.
    pub(super) fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::Relaxed)
    }

    fn cancel(&self) {
        self.cancelled.store(true, Ordering::Relaxed);
    }

    /// Non-blocking take. `None` while pending; registers `waker` (if
    /// given) to be woken exactly when the state next changes.
    fn poll_take(&self, waker: Option<&Waker>) -> Option<Result<ElemBuf, SortError>> {
        let mut inner = self.inner.lock().unwrap();
        match std::mem::replace(&mut inner.state, State::Taken) {
            State::Done(data) => Some(Ok(data)),
            State::Closed(err) => Some(Err(err)),
            // `replace` already left `Taken` in place.
            State::Taken => Some(Err(SortError::AlreadyTaken)),
            State::Pending => {
                inner.state = State::Pending;
                if let Some(w) = waker {
                    // Replace rather than accumulate: only the latest
                    // task polling the handle needs the wakeup.
                    inner.waker = Some(w.clone());
                }
                None
            }
        }
    }

    /// Blocking take: park on the condvar until the slot resolves.
    fn wait_take(&self) -> Result<ElemBuf, SortError> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            match std::mem::replace(&mut inner.state, State::Taken) {
                State::Done(data) => return Ok(data),
                State::Closed(err) => return Err(err),
                State::Taken => return Err(SortError::AlreadyTaken),
                State::Pending => {
                    inner.state = State::Pending;
                    inner = self.cv.wait(inner).unwrap();
                }
            }
        }
    }
}

/// Why a [`super::SortClient::try_submit`] was shed.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum BusyReason {
    /// Every shard was at capacity and no tenant was further over its
    /// fair share than this one — transient backpressure; a retry
    /// after draining some handles can succeed. `retry_after_hint`
    /// estimates how long one median queue-to-completion latency
    /// takes — by then a popped slot has likely freed (a hint, not a
    /// promise; same derivation as [`BusyReason::OverShare`]'s).
    QueueFull {
        /// Suggested back-off before the next `try_submit`.
        retry_after_hint: Duration,
    },
    /// Every shard was at capacity and **this tenant** was the one
    /// most over its fair share ([`super::ClientConfig`] weight/burst)
    /// — the fair-share analog of `QueueFull`, telling the tenant the
    /// overload is its own. Retrying before some of its in-flight
    /// work drains will be shed again; `retry_after_hint` estimates
    /// how long that drain takes (≈ one median queue-to-completion
    /// latency — a hint, not a promise).
    OverShare {
        /// Suggested back-off before the next `try_submit`.
        retry_after_hint: Duration,
    },
    /// The service has shut down — permanent; stop retrying.
    Shutdown,
}

impl BusyReason {
    /// The back-off hint, if the shed is retryable: `Some` for both
    /// transient reasons (full queues / over share), `None` for
    /// [`BusyReason::Shutdown`] — exactly the shape a retry loop
    /// wants to match on. [`RetryPolicy::backoff`] consumes it.
    pub fn retry_after(&self) -> Option<Duration> {
        match self {
            BusyReason::QueueFull { retry_after_hint }
            | BusyReason::OverShare { retry_after_hint } => Some(*retry_after_hint),
            BusyReason::Shutdown => None,
        }
    }
}

/// The input handed back by [`super::SortClient::try_submit`] when
/// the request was shed: nothing was enqueued or copied, and the
/// caller decides whether to retry ([`BusyReason::QueueFull`]), back
/// off ([`BusyReason::OverShare`]), degrade, or stop
/// ([`BusyReason::Shutdown`]). `T` is the submitted element type
/// (`u32` by default; `u64` / [`crate::simd::KeyValue`] for the typed
/// submits), so the shed payload round-trips without conversion.
///
/// # Examples
///
/// A QoS-aware retry loop distinguishes the reasons — back off by the
/// hint both transient sheds carry, or stop on shutdown:
///
/// ```
/// use neonms::coordinator::{Busy, BusyReason};
/// use std::time::Duration;
///
/// fn backoff(busy: &Busy) -> Option<Duration> {
///     busy.reason.retry_after() // None ⇔ Shutdown: retrying can never succeed
/// }
///
/// let shed = Busy {
///     data: vec![3, 1, 2], // handed back untouched
///     reason: BusyReason::OverShare { retry_after_hint: Duration::from_micros(250) },
/// };
/// assert_eq!(backoff(&shed), Some(Duration::from_micros(250)));
/// assert_eq!(shed.data, vec![3, 1, 2]);
/// assert_eq!(
///     Busy { data: shed.data, reason: BusyReason::Shutdown }.reason.retry_after(),
///     None,
/// );
/// ```
#[derive(Debug)]
pub struct Busy<T: SortElem = u32> {
    /// The original, untouched input.
    pub data: Vec<T>,
    /// Transient overload ([`BusyReason::QueueFull`] /
    /// [`BusyReason::OverShare`]) or permanent shutdown.
    pub reason: BusyReason,
}

/// Bounded exponential backoff with deterministic jitter for
/// [`super::SortClient::try_submit_with_retry`] (or hand-rolled retry
/// loops via [`RetryPolicy::backoff`]).
///
/// Attempt `k` sleeps a jittered duration in `[base·2ᵏ/2, base·2ᵏ]`
/// (capped at `cap`), floored at the shed's `retry_after_hint` when
/// one was given — the service's own drain estimate always wins over
/// a smaller exponential step. Jitter is **deterministic** (splitmix
/// over `jitter_seed ⊕ attempt`), so a fixed-seed policy produces a
/// reproducible schedule — the same property the fault injector
/// guarantees, and for the same reason: replayable tests.
///
/// # Examples
///
/// ```
/// use neonms::coordinator::RetryPolicy;
/// use std::time::Duration;
///
/// let policy = RetryPolicy::default();
/// // Deterministic: the same attempt always maps to the same sleep.
/// assert_eq!(policy.backoff(0, None), policy.backoff(0, None));
/// // The service's hint floors the exponential step.
/// let hint = Duration::from_millis(5);
/// assert!(policy.backoff(0, Some(hint)).unwrap() >= hint);
/// // Attempts exhaust: `None` means give up.
/// assert_eq!(policy.backoff(policy.max_attempts, None), None);
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Backoffs granted before [`RetryPolicy::backoff`] returns
    /// `None` (so a submit is attempted at most `max_attempts + 1`
    /// times: the initial try plus one per granted backoff).
    pub max_attempts: u32,
    /// First attempt's full backoff window.
    pub base: Duration,
    /// Ceiling on any single backoff (pre-hint; a larger
    /// `retry_after_hint` still wins).
    pub cap: Duration,
    /// Seed for the deterministic jitter stream.
    pub jitter_seed: u64,
}

impl Default for RetryPolicy {
    /// 5 retries from a 100 µs base capped at 50 ms — tuned to the
    /// service's own `retry_after_hint` clamp (50 µs .. 1 s), so the
    /// default policy and the service's drain estimates are on the
    /// same scale.
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 5,
            base: Duration::from_micros(100),
            cap: Duration::from_millis(50),
            jitter_seed: 0x5EED,
        }
    }
}

impl RetryPolicy {
    /// The sleep before retry number `attempt` (0-based), or `None`
    /// when the policy is exhausted. `hint` is the shed's
    /// `retry_after_hint` ([`BusyReason::retry_after`]); when given
    /// it floors the result — backing off *less* than the service's
    /// own drain estimate just burns admissions.
    pub fn backoff(&self, attempt: u32, hint: Option<Duration>) -> Option<Duration> {
        if attempt >= self.max_attempts {
            return None;
        }
        let exp = self.base.saturating_mul(1u32 << attempt.min(20)).min(self.cap);
        // Jitter into [exp/2, exp]: decorrelates retry storms without
        // ever collapsing the backoff to zero.
        let ns = exp.as_nanos().min(u64::MAX as u128) as u64;
        let r = super::faults::splitmix64(self.jitter_seed ^ u64::from(attempt));
        let jittered = ns / 2 + if ns <= 1 { 0 } else { r % (ns / 2 + 1) };
        let d = Duration::from_nanos(jittered.max(1));
        Some(match hint {
            Some(h) => d.max(h),
            None => d,
        })
    }
}

/// Non-blocking handle to a submitted sort request for element type
/// `T` (`u32` by default — [`super::SortClient::submit`]; `u64` and
/// [`crate::simd::KeyValue`] via the typed submits).
///
/// Three ways to consume it, all signalled by the shard worker
/// through the request's completion slot (no blocking join anywhere
/// in the service):
///
/// * **poll** — [`SortHandle::try_take`] / [`SortHandle::is_ready`]
///   never block; ideal for tenants multiplexing many requests.
/// * **await** — the handle implements [`Future`], resolving to the
///   sorted vector; any executor (or a hand-rolled `block_on`) works.
/// * **block** — [`SortHandle::wait`] parks the calling thread on the
///   slot's condvar, the migration path from the old blocking API.
///
/// Dropping a handle before taking its result **cancels** the
/// request: workers that haven't started it yet skip the sort
/// entirely (counted as `cancelled` in the metrics), and a result
/// that was already computed is discarded. Cancellation never blocks
/// and never wedges a worker.
pub struct SortHandle<T: SortElem = u32> {
    slot: Arc<Slot>,
    /// Set once the result (or error) has been taken; suppresses the
    /// drop-cancellation.
    resolved: bool,
    _elem: PhantomData<fn() -> T>,
}

impl<T: SortElem> SortHandle<T> {
    pub(super) fn new(slot: Arc<Slot>) -> SortHandle<T> {
        SortHandle { slot, resolved: false, _elem: PhantomData }
    }

    /// True once a result (or a [`SortError`]) is waiting; never
    /// blocks. Before the result is taken, a `true` here makes the
    /// next [`SortHandle::try_take`] return `Some`; after the take it
    /// stays `true` (the handle is resolved, not pending again).
    pub fn is_ready(&self) -> bool {
        !matches!(self.slot.inner.lock().unwrap().state, State::Pending)
    }

    /// Non-blocking take: `None` while the request is still in
    /// flight, `Some(result)` exactly once when it resolves, and
    /// `None` again on any call after the result was taken. The
    /// `Err` cases are [`SortHandle::wait`]'s taxonomy.
    pub fn try_take(&mut self) -> Option<Result<Vec<T>, SortError>> {
        if self.resolved {
            return None;
        }
        let out = self.slot.poll_take(None);
        if out.is_some() {
            self.resolved = true;
        }
        out.map(|r| r.map(T::unwrap))
    }

    /// Block the calling thread until the request resolves (parked on
    /// the slot's condvar; woken directly by the completing worker).
    ///
    /// # Errors
    ///
    /// Resolving to `Err` means the service gave up on the request;
    /// the variant says which failure domain:
    ///
    /// * [`SortError::Shutdown`] — the service shut down before a
    ///   worker completed it.
    /// * [`SortError::Evicted`] — fair-share QoS displaced it while
    ///   this tenant was over its burst (see
    ///   [`super::SortClient::submit`]).
    /// * [`SortError::JobPanicked`] — the sort panicked; the panic
    ///   was contained to this request.
    /// * [`SortError::DeadlineExceeded`] — its deadline expired while
    ///   it was still queued.
    /// * [`SortError::Quarantined`] — it killed two workers and was
    ///   refused a third run.
    ///
    /// `wait().unwrap()` is therefore sound only for a well-behaved
    /// tenant (within its burst, no deadline, against a live service)
    /// sorting payloads that cannot panic the kernel — tests and
    /// examples qualify; production callers should match on the
    /// variant (retry, resubmit elsewhere, or drop) instead of
    /// unwrapping.
    pub fn wait(mut self) -> Result<Vec<T>, SortError> {
        self.resolved = true;
        self.slot.wait_take().map(T::unwrap)
    }
}

impl<T: SortElem> Future for SortHandle<T> {
    type Output = Result<Vec<T>, SortError>;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Self::Output> {
        let this = self.get_mut();
        match this.slot.poll_take(Some(cx.waker())) {
            Some(out) => {
                this.resolved = true;
                Poll::Ready(out.map(T::unwrap))
            }
            None => Poll::Pending,
        }
    }
}

impl<T: SortElem> Drop for SortHandle<T> {
    fn drop(&mut self) {
        if !self.resolved {
            self.slot.cancel();
        }
    }
}
