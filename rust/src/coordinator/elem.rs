//! Element-typed request payloads: the service-side representation of
//! "what is being sorted", lifted out of the former hard-wired
//! `Vec<u32>`.
//!
//! Every queued request carries an [`ElemBuf`] — a tagged buffer over
//! the three supported element types (`u32` keys, `u64` keys, packed
//! [`KeyValue`] key–payload pairs). The tag ([`ElemKind`]) is what the
//! coordinator's *policy* layers dispatch on:
//!
//! * **batch fusion** only fuses jobs of the same kind — a fused
//!   buffer is one contiguous typed allocation, and mixing widths
//!   would corrupt it (`take_batch` checks the kind before draining a
//!   follower);
//! * **XLA offload** is `u32`-only (the AOT artifacts are compiled
//!   for 32-bit rows), so routing falls back to the CPU tiers for the
//!   wider types;
//! * **QoS admission** costs requests in *bytes*
//!   ([`ElemBuf::byte_len`]), so an 8-byte element counts twice the
//!   budget of a 4-byte one and a tenant cannot double its effective
//!   fair share by switching element types.
//!
//! The client-facing side is the [`SortElem`] trait: the typed
//! submit/handle surface (`submit_u64`, `submit_pairs`,
//! `SortHandle<T>`) is generic over it, and its associated functions
//! are the only place the tag ↔ type correspondence lives.

use crate::simd::{KeyValue, Lane};

/// Which element type an [`ElemBuf`] holds. The coordinator's fusion,
/// routing, and metrics layers dispatch on this tag.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum ElemKind {
    /// 4-byte unsigned keys — the paper's element type, and the only
    /// kind eligible for XLA offload.
    U32,
    /// 8-byte unsigned keys (sorted on the `V128D`/`V256D` register
    /// types).
    U64,
    /// Packed `(u32 key, u32 payload)` pairs ([`KeyValue`]): key-major
    /// order with payload tie-break, 8 bytes per element.
    Pair,
}

impl ElemKind {
    /// Bytes per element of this kind.
    pub fn bytes(self) -> usize {
        match self {
            ElemKind::U32 => 4,
            ElemKind::U64 | ElemKind::Pair => 8,
        }
    }

    /// Stable lowercase label for logs and bench JSON.
    pub fn name(self) -> &'static str {
        match self {
            ElemKind::U32 => "u32",
            ElemKind::U64 => "u64",
            ElemKind::Pair => "pair",
        }
    }
}

/// A request payload: one typed, owned buffer. This is what a queued
/// job carries through the shards and what a completion slot hands
/// back — the typed [`super::SortHandle`] unwraps it to the `Vec<T>`
/// the caller submitted.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ElemBuf {
    U32(Vec<u32>),
    U64(Vec<u64>),
    Pair(Vec<KeyValue>),
}

impl Default for ElemBuf {
    /// An empty `u32` buffer — the `mem::take` placeholder used when
    /// a worker moves the payload out of a finished job.
    fn default() -> Self {
        ElemBuf::U32(Vec::new())
    }
}

impl ElemBuf {
    /// The element kind this buffer holds.
    pub fn kind(&self) -> ElemKind {
        match self {
            ElemBuf::U32(_) => ElemKind::U32,
            ElemBuf::U64(_) => ElemKind::U64,
            ElemBuf::Pair(_) => ElemKind::Pair,
        }
    }

    /// Element count (routing cutoffs and the size-class metrics are
    /// element-denominated — register occupancy scales with elements,
    /// not bytes).
    pub fn len(&self) -> usize {
        match self {
            ElemBuf::U32(v) => v.len(),
            ElemBuf::U64(v) => v.len(),
            ElemBuf::Pair(v) => v.len(),
        }
    }

    /// True when the buffer holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Payload size in bytes — the QoS admission-cost denomination
    /// (`len × kind().bytes()`).
    pub fn byte_len(&self) -> usize {
        self.len() * self.kind().bytes()
    }
}

/// An element type the sort service accepts end to end: `u32` keys,
/// `u64` keys, or packed [`KeyValue`] pairs. Implemented only by those
/// three types; the associated functions are the tag ↔ type
/// correspondence the generic client/worker paths dispatch through.
///
/// The `Lane` supertrait is what lets one generic worker path drive
/// the vectorized kernels for every kind: a `SortElem` always has
/// concrete 128-/256-bit register types ([`Lane::Reg128`] /
/// [`Lane::Reg256`]).
pub trait SortElem: Lane + Ord {
    /// The tag [`ElemBuf`]s of this type carry.
    const KIND: ElemKind;

    /// Wrap an owned buffer into the service's tagged representation.
    fn wrap(data: Vec<Self>) -> ElemBuf;

    /// Recover the owned buffer. Panics on a kind mismatch — the
    /// service completes every slot with the same kind it admitted,
    /// so a mismatch is a coordinator bug, not a caller error.
    fn unwrap(buf: ElemBuf) -> Vec<Self>;

    /// Borrow the elements. Panics on kind mismatch (see
    /// [`SortElem::unwrap`]).
    fn slice(buf: &ElemBuf) -> &[Self];

    /// Mutably borrow the elements. Panics on kind mismatch.
    fn slice_mut(buf: &mut ElemBuf) -> &mut [Self];
}

macro_rules! impl_sort_elem {
    ($ty:ty, $kind:expr, $variant:ident) => {
        impl SortElem for $ty {
            const KIND: ElemKind = $kind;

            fn wrap(data: Vec<Self>) -> ElemBuf {
                ElemBuf::$variant(data)
            }

            fn unwrap(buf: ElemBuf) -> Vec<Self> {
                match buf {
                    ElemBuf::$variant(v) => v,
                    other => panic!(
                        "slot completed with {:?} elements for a {:?} request",
                        other.kind(),
                        $kind
                    ),
                }
            }

            fn slice(buf: &ElemBuf) -> &[Self] {
                match buf {
                    ElemBuf::$variant(v) => v,
                    other => panic!("expected {:?} payload, found {:?}", $kind, other.kind()),
                }
            }

            fn slice_mut(buf: &mut ElemBuf) -> &mut [Self] {
                match buf {
                    ElemBuf::$variant(v) => v,
                    other => panic!("expected {:?} payload, found {:?}", $kind, other.kind()),
                }
            }
        }
    };
}

impl_sort_elem!(u32, ElemKind::U32, U32);
impl_sort_elem!(u64, ElemKind::U64, U64);
impl_sort_elem!(KeyValue, ElemKind::Pair, Pair);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_report_bytes_and_names() {
        assert_eq!(ElemKind::U32.bytes(), 4);
        assert_eq!(ElemKind::U64.bytes(), 8);
        assert_eq!(ElemKind::Pair.bytes(), 8);
        assert_eq!(ElemKind::U32.name(), "u32");
        assert_eq!(ElemKind::Pair.name(), "pair");
    }

    #[test]
    fn buf_len_and_byte_len_track_kind() {
        let b32 = ElemBuf::U32(vec![1, 2, 3]);
        let b64 = ElemBuf::U64(vec![1, 2, 3]);
        let bp = ElemBuf::Pair(vec![KeyValue::new(1, 0); 3]);
        assert_eq!((b32.len(), b32.byte_len()), (3, 12));
        assert_eq!((b64.len(), b64.byte_len()), (3, 24));
        assert_eq!((bp.len(), bp.byte_len()), (3, 24));
        assert_eq!(b32.kind(), ElemKind::U32);
        assert_eq!(b64.kind(), ElemKind::U64);
        assert_eq!(bp.kind(), ElemKind::Pair);
        assert!(!b32.is_empty());
        assert!(ElemBuf::default().is_empty());
        assert_eq!(ElemBuf::default().kind(), ElemKind::U32);
    }

    #[test]
    fn wrap_unwrap_roundtrip_all_kinds() {
        let u = vec![3u32, 1, 2];
        assert_eq!(u32::unwrap(u32::wrap(u.clone())), u);
        let d = vec![3u64, 1, 2];
        assert_eq!(u64::unwrap(u64::wrap(d.clone())), d);
        let p = vec![KeyValue::new(3, 0), KeyValue::new(1, 9)];
        assert_eq!(KeyValue::unwrap(KeyValue::wrap(p.clone())), p);
        let mut buf = u64::wrap(vec![5, 4]);
        u64::slice_mut(&mut buf).sort_unstable();
        assert_eq!(u64::slice(&buf), &[4, 5]);
    }

    #[test]
    #[should_panic(expected = "slot completed with")]
    fn unwrap_mismatch_panics() {
        let _ = u64::unwrap(ElemBuf::U32(vec![1]));
    }
}
